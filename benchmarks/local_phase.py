"""Paper Figure 1: local checkpointing phase throughput (blocking).

Increasing processes per node, 1 GiB per rank, Theta-like testbed.
All VELOC-based strategies write to node-local storage (the prefix sum
costs ~nothing); GIO writes synchronously straight to the PFS.
Higher is better.
"""
from __future__ import annotations

from benchmarks.common import Rows
from repro.core import make_plan, simulate_flush, theta_like

GiB = 1 << 30

STRATS = [
    ("file_per_process", {}),
    ("posix", {}),
    ("mpiio", {"chunk_stripes": 64}),
    ("stripe_aligned", {"pipeline_chunk": 256 << 20}),
    ("gio_sync", {"chunk_stripes": 64}),
]


def run(nodes: int = 64, ppn_list=(1, 2, 4, 8, 16), io_threads: int = 4) -> Rows:
    rows = Rows("local_phase")
    for ppn in ppn_list:
        cluster = theta_like(nodes, ppn)
        sizes = [GiB] * cluster.world_size
        for strat, kw in STRATS:
            plan = make_plan(strat, cluster, sizes, **kw)
            rep = simulate_flush(plan, io_threads=io_threads)
            rows.add(
                f"fig1/local/{strat}/n{nodes}xppn{ppn}",
                rep.local_time * 1e6,
                f"{rep.local_bw / 1e9:.1f}GBps",
                nodes=nodes, ppn=ppn, strategy=strat,
                local_bw=rep.local_bw, local_time=rep.local_time,
            )
    return rows


def main() -> None:
    run().emit()


if __name__ == "__main__":
    main()
