"""Flush-runtime bench: supersession, throttling, crash-resume — the
adaptive background-flush behaviours ISSUE 5 added, measured on real
files.

Three row kinds, committed as ``BENCH_flush_runtime.json`` and gated by
``tools/bench_check.py``:

* ``supersession`` — a save cadence deliberately faster than a
  throttled drain: the scheduler must skip stale queued/mid-flight
  flushes so the PFS converges to the newest state.  The acceptance
  bar is ``skipped_frac >= 0.5`` (at least half of all stored bytes
  never had to cross to the PFS).
* ``resume`` — one row per aggregation strategy: a flush interrupted
  by a fault hook after ~80% of its bytes, then finished by
  ``resume_flushes()``.  Bars: ``rewrite_frac < 0.25`` (the journal
  skips what already landed) and ``byte_identical`` (the resumed PFS
  payload equals an uninterrupted flush's, file for file).
* ``throttle`` — the same ``flush_bw_cap`` priced by the simulator and
  enforced by the real executor's token bucket: both flush times must
  sit at/above ``total_bytes / cap`` (the policy trade-off curve the
  engine and sim agree on).

Usage::

    PYTHONPATH=src python benchmarks/flush_runtime.py              # full run
    PYTHONPATH=src python benchmarks/flush_runtime.py --quick      # CI smoke
    PYTHONPATH=src python benchmarks/flush_runtime.py --out BENCH_flush_runtime.json
"""
from __future__ import annotations

import argparse
import json
import sys
import tempfile
import time
from pathlib import Path
from typing import Dict, List

import numpy as np

from repro.core import (
    CheckpointConfig,
    CheckpointManager,
    FlushJournal,
    make_plan,
    simulate_flush,
    theta_like,
)

MiB = 1 << 20
ALL_STRATEGIES = ["file_per_process", "posix", "mpiio", "stripe_aligned", "gio_sync"]


def make_state(total_bytes: int, n_leaves: int = 8) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(0)
    per = total_bytes // n_leaves // 4
    return {
        f"layer_{i:02d}": rng.standard_normal(per).astype(np.float32)
        for i in range(n_leaves)
    }


# ---------------------------------------------------------------------------
# supersession: cadence faster than the drain
# ---------------------------------------------------------------------------


def bench_supersession(
    nodes: int, ppn: int, state_mib: int, n_saves: int, cap_mibs: float,
) -> Dict[str, object]:
    state = make_state(state_mib * MiB)
    t0 = time.perf_counter()
    with tempfile.TemporaryDirectory(prefix="bench_super_") as root:
        mgr = CheckpointManager(
            CheckpointConfig(
                root=root, cluster=theta_like(nodes, ppn),
                strategy="stripe_aligned", supersede_stale=True,
                max_pending_flushes=4, flush_bw_cap=cap_mibs * MiB,
            )
        )
        try:
            for s in range(1, n_saves + 1):
                mgr.save(s, state)
            save_done = time.perf_counter() - t0
            mgr.wait()
            drain_done = time.perf_counter() - t0
            assert not mgr.flush_errors, mgr.flush_errors
            by_step = {st.step: st for st in mgr.stats}
            stored_total = sum(st.stored_bytes for st in mgr.stats)
            flushed = sum(
                st.flush.bytes_written for st in mgr.stats if st.flush is not None
            )
            # Honest accounting for mid-flight supersessions: bytes a
            # cancelled flush pushed to the PFS before its cancellation
            # (its journal survives) did cross the wire — count them as
            # flushed, not skipped.
            skipped = 0
            for s in mgr.superseded_steps:
                jp = mgr._journal_path(s)
                partial = (
                    min(FlushJournal(jp).completed_bytes,
                        by_step[s].stored_bytes)
                    if jp.exists() else 0
                )
                flushed += partial
                skipped += by_step[s].stored_bytes - partial
            newest_on_pfs = max(mgr.steps("pfs"), default=-1)
            row = {
                "kind": "supersession",
                "config": f"{nodes}x{ppn}/{state_mib}MiB/x{n_saves}"
                          f"/cap{cap_mibs:g}MiBps",
                "nodes": nodes,
                "ppn": ppn,
                "n_ranks": nodes * ppn,
                "n_saves": n_saves,
                "flush_bw_cap": cap_mibs * MiB,
                "stored_total": stored_total,
                "flushed_bytes": flushed,
                "skipped_bytes": skipped,
                "skipped_frac": round(skipped / stored_total, 4),
                "n_superseded": len(mgr.superseded_steps),
                "newest_flushed": newest_on_pfs == n_saves,
                "save_phase_s": round(save_done, 4),
                "drain_s": round(drain_done, 4),
            }
        finally:
            mgr.close()
    print(
        f"  supersession {row['config']}: {row['n_superseded']}/{n_saves} "
        f"superseded, skipped_frac={row['skipped_frac']}, "
        f"drain {row['drain_s']}s",
        flush=True,
    )
    return row


# ---------------------------------------------------------------------------
# crash-resume, one row per strategy
# ---------------------------------------------------------------------------


def _pfs_payload(root: Path) -> Dict[str, bytes]:
    out = {}
    for d in sorted((root / "pfs").glob("step_*")):
        for p in sorted(d.iterdir()):
            if p.suffix == ".json" or p.name == "flush_journal.bin":
                continue
            out[f"{d.name}/{p.name}"] = p.read_bytes()
    return out


def bench_resume(
    nodes: int, ppn: int, state_mib: int, strategy: str,
    interrupt_frac: float = 0.8,
) -> Dict[str, object]:
    import threading

    from repro.core.plan import coalesce_write_columns

    state = make_state(state_mib * MiB)
    cluster = theta_like(nodes, ppn)
    base = dict(cluster=cluster, strategy=strategy, async_flush=False)
    with tempfile.TemporaryDirectory(prefix="bench_resume_") as tmp:
        tmp = Path(tmp)
        mgr_ref = CheckpointManager(
            CheckpointConfig(root=str(tmp / "ref"), **base)
        )
        try:
            t0 = time.perf_counter()
            mgr_ref.save(1, state)
            full_flush_s = time.perf_counter() - t0
            sizes = [r.stored_size for r in mgr_ref._manifest_pfs(1).ranks]
            total = sum(sizes)
        finally:
            mgr_ref.close()

        # Deterministic interruption: let exactly K of the plan's N
        # coalesced write rows land, then fail every later row — the
        # hook is the only serialization point, so the journaled
        # fraction is K/N regardless of worker scheduling.
        n_rows = len(coalesce_write_columns(
            make_plan(strategy, cluster, sizes).ensure_arrays().writes
        ))
        k_pass = min(n_rows - 1, max(1, int(np.ceil(interrupt_frac * n_rows))))
        seen = {"rows": 0, "armed": True}
        hook_lock = threading.Lock()

        def hook(w):
            with hook_lock:
                if seen["armed"] and seen["rows"] >= k_pass:
                    raise IOError("bench-injected interruption")
                seen["rows"] += 1

        mgr = CheckpointManager(
            CheckpointConfig(root=str(tmp / "int"), **base), fault_hook=hook
        )
        try:
            try:
                mgr.save(1, state)
                raise RuntimeError("interruption hook never fired")
            except IOError:
                pass
            seen["armed"] = False
            t0 = time.perf_counter()
            res = mgr.resume_flushes()[1]
            resume_s = time.perf_counter() - t0
            identical = _pfs_payload(tmp / "int") == _pfs_payload(tmp / "ref")
        finally:
            mgr.close()
    row = {
        "kind": "resume",
        "config": f"{nodes}x{ppn}/{state_mib}MiB/{strategy}",
        "nodes": nodes,
        "ppn": ppn,
        "n_ranks": nodes * ppn,
        "strategy": strategy,
        "total_bytes": total,
        "interrupt_frac": interrupt_frac,
        "resume_rewritten_bytes": res.bytes_written,
        "resume_skipped_bytes": res.bytes_skipped,
        "rewrite_frac": round(res.bytes_written / total, 4),
        "byte_identical": bool(identical),
        "full_flush_s": round(full_flush_s, 4),
        "resume_s": round(resume_s, 4),
    }
    print(
        f"  resume {row['config']}: rewrote {row['rewrite_frac']:.0%}, "
        f"identical={identical}, {resume_s:.2f}s vs full {full_flush_s:.2f}s",
        flush=True,
    )
    return row


# ---------------------------------------------------------------------------
# throttle: sim and executor price the same cap
# ---------------------------------------------------------------------------


def bench_throttle(
    nodes: int, ppn: int, state_mib: int, cap_mibs: float,
) -> Dict[str, object]:
    state = make_state(state_mib * MiB)
    cap = cap_mibs * MiB
    with tempfile.TemporaryDirectory(prefix="bench_throttle_") as root:
        mgr = CheckpointManager(
            CheckpointConfig(
                root=root, cluster=theta_like(nodes, ppn),
                strategy="stripe_aligned", flush_bw_cap=cap,
            )
        )
        try:
            st = mgr.save(1, state)
            mgr.wait()
            assert not mgr.flush_errors, mgr.flush_errors
            real_s = st.flush.duration
            throttle_wait = st.flush.throttle_wait
            burst = mgr._limiter.burst
            sizes = [r.stored_size for r in mgr._manifest_pfs(1).ranks]
            total = sum(sizes)
        finally:
            mgr.close()
    plan = make_plan("stripe_aligned", theta_like(nodes, ppn), sizes)
    sim_s = simulate_flush(plan, io_threads=2, flush_bw_cap=cap).flush_time
    row = {
        "kind": "throttle",
        "config": f"{nodes}x{ppn}/{state_mib}MiB/cap{cap_mibs:g}MiBps",
        "nodes": nodes,
        "ppn": ppn,
        "n_ranks": nodes * ppn,
        "flush_bw_cap": cap,
        "total_bytes": total,
        "ideal_s": round(total / cap, 4),
        # the token bucket's opening burst rides for free; the steady
        # state drains at the cap — this is what the real time tracks
        "expected_s": round(max(0.0, total - burst) / cap, 4),
        "real_flush_s": round(real_s, 4),
        "real_throttle_wait_s": round(throttle_wait, 4),
        "sim_flush_s": round(sim_s, 4),
    }
    print(
        f"  throttle {row['config']}: ideal {row['ideal_s']}s "
        f"(expected {row['expected_s']}s after burst), "
        f"real {row['real_flush_s']}s, sim {row['sim_flush_s']}s",
        flush=True,
    )
    return row


# ---------------------------------------------------------------------------


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="CI smoke sizes")
    ap.add_argument("--out", default=None, help="write JSON results here")
    args = ap.parse_args(argv)

    rows: List[Dict[str, object]] = []
    if args.quick:
        print("supersession (quick)", flush=True)
        rows.append(bench_supersession(2, 2, 8, 6, cap_mibs=24))
        print("resume (quick)", flush=True)
        rows.append(bench_resume(4, 2, 8, "stripe_aligned"))
        print("throttle (quick)", flush=True)
        rows.append(bench_throttle(2, 2, 8, cap_mibs=32))
    else:
        print("supersession", flush=True)
        rows.append(bench_supersession(2, 2, 32, 8, cap_mibs=48))
        rows.append(bench_supersession(4, 4, 64, 8, cap_mibs=64))
        print("resume (all strategies)", flush=True)
        for strategy in ALL_STRATEGIES:
            rows.append(bench_resume(4, 2, 64, strategy))
        print("throttle", flush=True)
        rows.append(bench_throttle(2, 2, 32, cap_mibs=64))
        rows.append(bench_throttle(4, 2, 64, cap_mibs=128))

    doc = {"benchmark": "flush_runtime", "quick": bool(args.quick), "rows": rows}
    text = json.dumps(doc, indent=1)
    if args.out:
        Path(args.out).write_text(text + "\n")
        print(f"wrote {args.out}")
    else:
        print(text)
    return 0


if __name__ == "__main__":
    sys.exit(main())
