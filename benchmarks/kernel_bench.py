"""Pallas kernel micro-benchmarks (interpret mode on CPU: correctness-
path timing only; the derived column reports work size per call)."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import Rows, time_call
from repro.kernels.checksum import checksum_u32
from repro.kernels.delta import xor_delta
from repro.kernels.quantize import dequantize, quantize


def run(mib: int = 1) -> Rows:
    rows = Rows("kernels")
    n_words = mib * (1 << 20) // 4
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.integers(0, 2**32, n_words, dtype=np.uint32))
    x = jnp.asarray(rng.standard_normal(n_words).astype(np.float32))
    w2 = jnp.asarray(rng.integers(0, 2**32, n_words, dtype=np.uint32))

    jax.block_until_ready(checksum_u32(w))
    dt = time_call(lambda: jax.block_until_ready(checksum_u32(w)))
    rows.add("kernel/checksum_u32", dt * 1e6, f"{mib}MiB")

    q, s = quantize(x)
    jax.block_until_ready((q, s))
    dt = time_call(lambda: jax.block_until_ready(quantize(x)))
    rows.add("kernel/quantize_int8", dt * 1e6, f"{mib}MiB_f32")

    dt = time_call(lambda: jax.block_until_ready(dequantize(q, s, n=n_words)))
    rows.add("kernel/dequantize_int8", dt * 1e6, f"{mib}MiB_f32")

    jax.block_until_ready(xor_delta(w, w2)[0])
    dt = time_call(lambda: jax.block_until_ready(xor_delta(w, w2)[0]))
    rows.add("kernel/xor_delta", dt * 1e6, f"{mib}MiB")
    return rows


def main() -> None:
    run().emit()


if __name__ == "__main__":
    main()
