"""Pallas kernel benchmarks: per-kernel micro timings + the fused
pre-codec pass vs its unfused and host-oracle equivalents.

All kernels run in interpret mode on CPU, so absolute numbers are
correctness-path timings, not TPU throughput — what the committed
artifact witnesses is the *structural* claim of the fused pass: one
launch per leaf group producing delta + dirty counts + per-chunk
digests, vs the pre-fusion path of one ``xor_delta`` launch plus one
``checksum_u32`` launch per chunk plus a host-side dirty reduction.
The launch-count gap is geometry-independent, so the speedup survives
the interpret-mode caveat.

Row kinds in the emitted JSON:

* ``kernel`` — per-kernel microbenchmark rows (time per call);
* ``fused`` — fused pass vs per-kernel chain vs the pure-numpy oracle
  (``fused_ref``); each row carries ``speedup = per_kernel_s/fused_s``.

The committed ``BENCH_kernel.json`` is gated by ``tools/bench_check.py``
(schema + every fused row ``speedup >= 1``).

Usage::

    PYTHONPATH=src python benchmarks/kernel_bench.py                  # full
    PYTHONPATH=src python benchmarks/kernel_bench.py --quick          # CI smoke
    PYTHONPATH=src python benchmarks/kernel_bench.py --out BENCH_kernel.json
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Dict, List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels.checksum import checksum_u32
from repro.kernels.delta import xor_delta
from repro.kernels.fused import fused_precodec, fused_ref
from repro.kernels.quantize import dequantize, quantize

MiB = 1 << 20


def time_call(fn, *, repeat: int = 3) -> float:
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def bench_micro(mib: int, *, verbose: bool) -> List[Dict[str, object]]:
    n_words = mib * MiB // 4
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.integers(0, 2**32, n_words, dtype=np.uint32))
    x = jnp.asarray(rng.standard_normal(n_words).astype(np.float32))
    w2 = jnp.asarray(rng.integers(0, 2**32, n_words, dtype=np.uint32))

    q, s = quantize(x)
    jax.block_until_ready((q, s))
    calls = {
        "checksum_u32": lambda: jax.block_until_ready(checksum_u32(w)),
        "quantize_int8": lambda: jax.block_until_ready(quantize(x)),
        "dequantize_int8": lambda: jax.block_until_ready(
            dequantize(q, s, n=n_words)
        ),
        "xor_delta": lambda: jax.block_until_ready(xor_delta(w, w2)[0]),
    }
    rows: List[Dict[str, object]] = []
    for name, fn in calls.items():
        fn()  # warm the jit cache out of the timed region
        dt = time_call(fn)
        rows.append({
            "config": f"{mib}MiB",
            "kind": "kernel",
            "name": name,
            "state_bytes": mib * MiB,
            "time_us": round(dt * 1e6, 1),
        })
        if verbose:
            print(f"{mib}MiB {name:>16}  {dt*1e6:10.1f} us/call", flush=True)
    return rows


def _per_kernel_pass(cur, base, chunk_words: int):
    """The pre-fusion equivalent of ``fused_precodec``: one delta launch,
    one checksum launch per chunk, dirty counts reduced on host."""
    delta, _ = xor_delta(cur, base)
    n_chunks = cur.size // chunk_words
    chunks = cur.reshape(n_chunks, chunk_words)
    dchunks = delta.reshape(n_chunks, chunk_words)
    digests = [checksum_u32(chunks[ci]) for ci in range(n_chunks)]
    dirty = np.asarray(jnp.sum(dchunks != 0, axis=1))
    jax.block_until_ready((delta, digests))
    return delta, dirty, digests


def bench_fused(mib: int, chunk_bytes: int, *, verbose: bool) -> List[Dict[str, object]]:
    n_words = mib * MiB // 4
    chunk_words = chunk_bytes // 4
    rng = np.random.default_rng(1)
    cur_np = rng.integers(0, 2**32, n_words, dtype=np.uint32)
    base_np = cur_np.copy()
    base_np[:: 50] ^= 0xA5A5A5A5  # ~2% of words differ
    cur, base = jnp.asarray(cur_np), jnp.asarray(base_np)

    jax.block_until_ready(fused_precodec(cur, base, chunk_words=chunk_words)[1])
    fused_s = time_call(lambda: jax.block_until_ready(
        fused_precodec(cur, base, chunk_words=chunk_words)[1]
    ))
    _per_kernel_pass(cur, base, chunk_words)
    per_kernel_s = time_call(
        lambda: _per_kernel_pass(cur, base, chunk_words), repeat=1
    )
    t0 = time.perf_counter()
    fused_ref(cur_np, base_np, chunk_words)
    oracle_s = time.perf_counter() - t0

    row = {
        "config": f"{mib}MiB/{chunk_bytes//1024}KiB",
        "kind": "fused",
        "state_bytes": mib * MiB,
        "chunk_bytes": chunk_bytes,
        "n_chunks": n_words // chunk_words,
        "fused_s": round(fused_s, 4),
        "per_kernel_s": round(per_kernel_s, 4),
        "oracle_s": round(oracle_s, 4),
        "speedup": round(per_kernel_s / fused_s, 2),
    }
    if verbose:
        print(
            f"{row['config']:>14} fused={fused_s:7.3f}s  "
            f"per_kernel={per_kernel_s:7.3f}s  oracle={oracle_s:7.3f}s  "
            f"speedup={row['speedup']:5.2f}x", flush=True,
        )
    return [row]


def run(*, quick: bool, verbose: bool = True) -> List[Dict[str, object]]:
    rows: List[Dict[str, object]] = []
    rows.extend(bench_micro(1 if quick else 4, verbose=verbose))
    if quick:
        rows.extend(bench_fused(1, 16 * 1024, verbose=verbose))
    else:
        rows.extend(bench_fused(4, 16 * 1024, verbose=verbose))
        rows.extend(bench_fused(4, 64 * 1024, verbose=verbose))
    return rows


def main(argv: Optional[List[str]] = None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--quick", action="store_true", help="CI smoke configs")
    p.add_argument("--out", help="write JSON rows to this path")
    args = p.parse_args(argv)

    rows = run(quick=args.quick)
    doc = {"benchmark": "kernel_bench", "quick": bool(args.quick), "rows": rows}
    text = json.dumps(doc, indent=2)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
        print(f"wrote {args.out}")
    else:
        json.dump(doc, sys.stdout, indent=2)
        print()


if __name__ == "__main__":
    main()
