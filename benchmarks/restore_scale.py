"""Restore-scale sweep: read-plan build / validate wall times + a real
elastic-restore micro-benchmark.

The read-side twin of ``benchmarks/planner_scale.py``: the paper's
complaint about one-file-per-process checkpoints is that they are
"difficult to transfer and access as a whole" — so the restore path has
to *read* aggregated layouts as aggregated files.  This benchmark times
the read planner's three layers at paper-adjacent scales:

* ``invert_s``   — ``FileLayout.from_flush_plan``: flush-plan writes ->
  stored-space extent table;
* ``build_s``    — ``build_read_plan``: a consumer geometry's byte-range
  requests (one per producer blob, readers assigned elastically over M
  consumer nodes) cut at extent boundaries;
* ``validate_s`` — ``validate_read_plan`` with full layout-consistency
  checking.

Each scale also times a *partial* plan (scattered ~1 MiB leaf-style
requests — the serving workload), and the suite ends with a real
end-to-end elastic restore (N-node save -> M-node restore through
``CheckpointManager``) at toy scale so the ranged-pread executor is
exercised, not just priced.

Usage::

    PYTHONPATH=src python benchmarks/restore_scale.py                 # full sweep
    PYTHONPATH=src python benchmarks/restore_scale.py --quick         # CI smoke
    PYTHONPATH=src python benchmarks/restore_scale.py --only 1024x32  # one scale
    PYTHONPATH=src python benchmarks/restore_scale.py --out BENCH_restore.json
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import make_plan, theta_like
from repro.core.plan import (
    FileLayout,
    assign_readers,
    build_read_plan,
    stored_space_offsets,
    validate_read_plan,
)

GiB = 1 << 30
MiB = 1 << 20

# (nodes, ppn, strategy, strategy kwargs, consumer node counts)
FULL_CONFIGS: List[Tuple[int, int, str, Dict[str, object], List[int]]] = [
    (256, 16, "stripe_aligned", {"pipeline_chunk": 256 << 20}, [256, 64]),
    (256, 16, "mpiio", {"chunk_stripes": 64}, [256, 64]),
    (1024, 32, "stripe_aligned", {"pipeline_chunk": 1 << 30}, [1024, 256]),
    (1024, 32, "mpiio", {"chunk_stripes": 256}, [1024, 256]),
    (1024, 32, "file_per_process", {}, [256]),
]
QUICK_CONFIGS: List[Tuple[int, int, str, Dict[str, object], List[int]]] = [
    (16, 8, "stripe_aligned", {"pipeline_chunk": 64 << 20}, [16, 4]),
    (16, 8, "mpiio", {"chunk_stripes": 16}, [4]),
    (16, 8, "posix", {}, [4]),
]


def bench_one(
    nodes: int, ppn: int, strategy: str, kw: Dict[str, object],
    consumers: List[int],
) -> List[Dict[str, object]]:
    cluster = theta_like(nodes, ppn)
    rng = np.random.default_rng(0)
    # heterogeneous checkpoint sizes (0.5-1.5 GiB), matching planner_scale
    sizes = rng.integers(GiB // 2, 3 * GiB // 2, cluster.world_size).tolist()
    plan = make_plan(strategy, cluster, sizes, **kw)

    t0 = time.perf_counter()
    layout = FileLayout.from_flush_plan(plan)
    invert_s = time.perf_counter() - t0
    offsets = stored_space_offsets(sizes)

    rows: List[Dict[str, object]] = []
    for m in consumers:
        # full elastic restore: one request per producer blob, readers
        # balanced over the *consumer* geometry (m nodes)
        t1 = time.perf_counter()
        readers = assign_readers(sizes, m)
        rp = build_read_plan(
            layout, offsets[:-1], sizes, readers, validate=False
        )
        t2 = time.perf_counter()
        validate_read_plan(rp, layout)
        t3 = time.perf_counter()
        rows.append({
            "config": f"{nodes}x{ppn}/{strategy}->M{m}",
            "kind": "full_restore",
            "nodes": nodes,
            "ppn": ppn,
            "n_ranks": cluster.world_size,
            "strategy": strategy,
            "consumer_nodes": m,
            "invert_s": round(invert_s, 4),
            "build_s": round(t2 - t1, 4),
            "validate_s": round(t3 - t2, 4),
            "total_s": round(invert_s + (t3 - t1), 4),
            "n_extents": len(layout),
            "n_reads": rp.n_reads,
            "read_bytes": rp.total_bytes,
        })

    # partial restore: scattered ~1 MiB leaf-style requests (serving
    # fleets pulling params out of a multi-GB train-state checkpoint)
    n_req = min(4096, cluster.world_size)
    starts = np.sort(
        rng.integers(0, layout.total - MiB, n_req).astype(np.int64)
    )
    req_sizes = np.full(n_req, MiB, np.int64)
    t1 = time.perf_counter()
    rp = build_read_plan(
        layout, starts, req_sizes,
        np.arange(n_req, dtype=np.int64) % max(1, consumers[-1]),
        validate=False,
    )
    t2 = time.perf_counter()
    validate_read_plan(rp, layout)
    t3 = time.perf_counter()
    rows.append({
        "config": f"{nodes}x{ppn}/{strategy}->partial{n_req}",
        "kind": "partial_restore",
        "nodes": nodes,
        "ppn": ppn,
        "n_ranks": cluster.world_size,
        "strategy": strategy,
        "consumer_nodes": consumers[-1],
        "invert_s": round(invert_s, 4),
        "build_s": round(t2 - t1, 4),
        "validate_s": round(t3 - t2, 4),
        "total_s": round(invert_s + (t3 - t1), 4),
        "n_extents": len(layout),
        "n_reads": rp.n_reads,
        "read_bytes": rp.total_bytes,
    })
    return rows


def bench_real(tmp_root: str) -> Dict[str, object]:
    """Real end-to-end elastic restore at toy scale (executor included)."""
    import jax.numpy as jnp

    from repro.core import CheckpointConfig, CheckpointManager

    state = {
        "params": {"w": jnp.arange(1 << 20, dtype=jnp.float32)},
        "opt": {"mu": jnp.ones((1 << 18,), jnp.float32)},
    }
    mgr = CheckpointManager(
        CheckpointConfig(
            root=tmp_root, cluster=theta_like(8, 2),
            strategy="stripe_aligned", async_flush=False,
        )
    )
    mgr.save(1, state)
    mgr.close()
    target = {
        "params": {"w": np.zeros(1 << 20, np.float32)},
        "opt": {"mu": np.zeros(1 << 18, np.float32)},
    }
    mgr2 = CheckpointManager(
        CheckpointConfig(root=tmp_root, cluster=theta_like(3, 1),
                         strategy="posix")
    )
    for n in range(8):
        mgr2.local.drop_node(n)
    t0 = time.perf_counter()
    step, restored = mgr2.restore(target)
    restore_s = time.perf_counter() - t0
    assert step == 1
    np.testing.assert_array_equal(
        restored["params"]["w"], np.arange(1 << 20, dtype=np.float32)
    )
    rr = mgr2.last_read_result
    t1 = time.perf_counter()
    _, params = mgr2.restore_subtree(target["params"], "['params']")
    partial_s = time.perf_counter() - t1
    np.testing.assert_array_equal(
        params["w"], np.arange(1 << 20, dtype=np.float32)
    )
    pr = mgr2.last_read_result
    mgr2.close()
    return {
        "kind": "real_elastic_restore",
        "save_geometry": "8x2",
        "restore_geometry": "3x1",
        "restore_s": round(restore_s, 4),
        "restore_reads": rr.n_reads,
        "restore_bytes": rr.bytes_read,
        "partial_restore_s": round(partial_s, 4),
        "partial_reads": pr.n_reads,
        "partial_bytes": pr.bytes_read,
    }


def run(
    configs: List[Tuple[int, int, str, Dict[str, object], List[int]]],
    *, only: Optional[str] = None, verbose: bool = True, real: bool = True,
) -> List[Dict[str, object]]:
    rows: List[Dict[str, object]] = []
    for nodes, ppn, strategy, kw, consumers in configs:
        if only and only not in (f"{nodes}x{ppn}", f"{nodes}x{ppn}/{strategy}"):
            continue
        for row in bench_one(nodes, ppn, strategy, kw, consumers):
            rows.append(row)
            if verbose:
                print(
                    f"{row['config']:>40}  invert={row['invert_s']:7.3f}s  "
                    f"build={row['build_s']:7.3f}s  "
                    f"validate={row['validate_s']:7.3f}s  "
                    f"reads={row['n_reads']}",
                    flush=True,
                )
    if real and not only:
        import tempfile

        with tempfile.TemporaryDirectory() as root:
            row = bench_real(root)
        rows.append(row)
        if verbose:
            print(
                f"{'real 8x2 -> 3x1':>40}  restore={row['restore_s']:7.3f}s  "
                f"partial={row['partial_restore_s']:7.3f}s",
                flush=True,
            )
    return rows


def main(argv: Optional[List[str]] = None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--quick", action="store_true", help="CI smoke configs")
    p.add_argument("--only", help="restrict to one scale, e.g. 1024x32")
    p.add_argument("--no-real", action="store_true",
                   help="skip the real end-to-end restore micro-benchmark")
    p.add_argument("--out", help="write JSON rows to this path")
    args = p.parse_args(argv)

    configs = QUICK_CONFIGS if args.quick else FULL_CONFIGS
    rows = run(configs, only=args.only, real=not args.no_real)
    doc = {"benchmark": "restore_scale", "quick": bool(args.quick), "rows": rows}
    text = json.dumps(doc, indent=2)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
        print(f"wrote {args.out}")
    else:
        json.dump(doc, sys.stdout, indent=2)
        print()


if __name__ == "__main__":
    main()
