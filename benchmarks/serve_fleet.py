"""Serve-fleet bench: cold-start TTFT, concurrent fleet boot, hot swap.

The restore-for-inference workload the aggregation strategies exist
for, measured on real files.  Three row kinds, committed as
``BENCH_serve.json`` and gated by ``tools/bench_check.py``:

* ``ttft`` — one server cold-starting from an aggregated step written
  by a paper-scale training geometry (full run: 1024 ranks).  Streamed
  layer-priority loading must get the prefill-critical prefix
  (embedding + first blocks) resident before a full
  ``restore_subtree`` even finishes: the acceptance bar is
  ``ttft_s < full_restore_s``.
* ``cold_start_fleet`` — N replicas booting concurrently from ONE
  step through the shared node-local decoded-chunk cache; every
  replica must come up byte-identical (``byte_identical``), and with a
  chunk-framed codec the replicas after the first mostly hit the cache
  (``cache_hits``/``cache_bytes_saved``).
* ``hot_swap`` — a live fleet serving generates while the follower
  adopts a newer flush_done step: the bar is ``dropped == 0`` and
  ``torn == 0`` (every generate completes and matches exactly the
  params version it reports — no request ever sees half a swap).

Usage::

    PYTHONPATH=src python benchmarks/serve_fleet.py              # full run
    PYTHONPATH=src python benchmarks/serve_fleet.py --quick      # CI smoke
    PYTHONPATH=src python benchmarks/serve_fleet.py --out BENCH_serve.json
"""
from __future__ import annotations

import argparse
import json
import sys
import tempfile
import threading
import time
from typing import Dict, List

import numpy as np

MiB = 1 << 20


def make_model_state(n_blocks: int, block_kib: int, seed: int = 0):
    """A synthetic LM-shaped train state: embed + numbered blocks +
    head under ``params``, plus optimizer baggage serving must skip."""
    rng = np.random.default_rng(seed)

    def arr(kib):
        return rng.standard_normal(kib * 1024 // 8).astype(np.float64)

    params = {"embed": arr(4 * block_kib)}
    for i in range(n_blocks):
        params[f"block_{i:03d}"] = {"w": arr(block_kib), "b": arr(1)}
    params["head"] = arr(4 * block_kib)
    return {"params": params, "opt": {"mu": arr(4 * block_kib)}}


class _NullModel:
    """Placeholder for rows that never run a forward pass."""

    def decode_step(self, params, cache, tok):  # pragma: no cover
        raise NotImplementedError


# ---------------------------------------------------------------------------
# TTFT: streamed priority prefix vs full restore_subtree
# ---------------------------------------------------------------------------


def bench_ttft(
    nodes: int, ppn: int, serve_nodes: int, n_blocks: int, block_kib: int,
) -> Dict[str, object]:
    import jax

    from repro.core import CheckpointConfig, CheckpointManager, theta_like
    from repro.serve.stream import stream_restore
    from repro.utils.treelib import tree_bytes

    state = make_model_state(n_blocks, block_kib)
    template = jax.tree_util.tree_map(np.asarray, state["params"])
    with tempfile.TemporaryDirectory(prefix="bench_ttft_") as root:
        train = CheckpointManager(
            CheckpointConfig(
                root=root, cluster=theta_like(nodes, ppn),
                strategy="stripe_aligned", async_flush=False,
            )
        )
        try:
            train.save(1, state)
        finally:
            train.close()
        serve = CheckpointManager(
            CheckpointConfig(
                root=root, cluster=theta_like(serve_nodes, 1),
                strategy="stripe_aligned", async_flush=False,
            )
        )
        try:
            t0 = time.perf_counter()
            step, full = serve.restore_subtree(template, "['params']")
            full_s = time.perf_counter() - t0
            sr = stream_restore(serve, template, priority_blocks=2)
            identical = all(
                np.array_equal(a, b)
                for a, b in zip(
                    jax.tree_util.tree_leaves(sr.params),
                    jax.tree_util.tree_leaves(full),
                )
            )
        finally:
            serve.close()
    row = {
        "kind": "ttft",
        "config": f"{nodes}x{ppn}->r{serve_nodes}/{n_blocks}blk/{block_kib}KiB",
        "nodes": nodes,
        "ppn": ppn,
        "n_ranks": nodes * ppn,
        "serve_readers": serve_nodes,
        "n_blocks": n_blocks,
        "params_bytes": tree_bytes(template),
        "priority_bytes": sr.priority_bytes,
        "full_restore_s": round(full_s, 4),
        "stream_total_s": round(sr.total_s, 4),
        "ttft_s": round(sr.ttft_s, 4),
        "ttft_speedup": round(full_s / max(sr.ttft_s, 1e-9), 2),
        "byte_identical": bool(identical),
    }
    print(
        f"  ttft {row['config']}: full {row['full_restore_s']}s, "
        f"ttft {row['ttft_s']}s ({row['ttft_speedup']}x), "
        f"stream total {row['stream_total_s']}s",
        flush=True,
    )
    return row


# ---------------------------------------------------------------------------
# concurrent fleet cold start through the shared chunk cache
# ---------------------------------------------------------------------------


def bench_cold_start_fleet(
    nodes: int, ppn: int, serve_nodes: int, n_servers: int,
    n_blocks: int, block_kib: int,
) -> Dict[str, object]:
    import jax

    from repro.core import CheckpointConfig, CheckpointManager, theta_like
    from repro.serve import FleetConfig, ServeFleet

    state = make_model_state(n_blocks, block_kib)
    template = jax.tree_util.tree_map(np.asarray, state["params"])
    with tempfile.TemporaryDirectory(prefix="bench_fleet_") as root:
        common = dict(strategy="stripe_aligned", codec="zstd",
                      chunk_size=256 * 1024, async_flush=False)
        train = CheckpointManager(
            CheckpointConfig(root=root, cluster=theta_like(nodes, ppn), **common)
        )
        try:
            train.save(1, state)
        finally:
            train.close()
        serve = CheckpointManager(
            CheckpointConfig(root=root, cluster=theta_like(serve_nodes, 1), **common)
        )
        fleet = ServeFleet(
            _NullModel(), serve, template,
            cfg=FleetConfig(n_servers=n_servers),
        )
        try:
            cs = fleet.cold_start()
            ref = jax.tree_util.tree_leaves(template)
            got0 = jax.tree_util.tree_leaves(fleet.servers[0].params)
            identical = all(
                all(np.array_equal(a, b) for a, b in zip(
                    jax.tree_util.tree_leaves(srv.params), got0))
                for srv in fleet.servers
            ) and all(a.shape == b.shape for a, b in zip(got0, ref))
            cache = cs.cache or {}
        finally:
            fleet.close()
            serve.close()
    row = {
        "kind": "cold_start_fleet",
        "config": f"{nodes}x{ppn}->r{serve_nodes}/{n_servers}srv/{n_blocks}blk",
        "nodes": nodes,
        "ppn": ppn,
        "n_ranks": nodes * ppn,
        "serve_readers": serve_nodes,
        "n_servers": n_servers,
        "fleet_total_s": round(cs.total_s, 4),
        "ttft_max_s": round(max(cs.ttft_s), 4),
        "ttft_mean_s": round(sum(cs.ttft_s) / len(cs.ttft_s), 4),
        "cache_hits": int(cache.get("hits", 0)),
        "cache_misses": int(cache.get("misses", 0)),
        "cache_bytes_saved": int(cache.get("bytes_saved", 0)),
        "byte_identical": bool(identical),
    }
    print(
        f"  cold_start_fleet {row['config']}: {row['fleet_total_s']}s total, "
        f"ttft max {row['ttft_max_s']}s, cache hits {row['cache_hits']} "
        f"({row['cache_bytes_saved'] / MiB:.1f} MiB saved)",
        flush=True,
    )
    return row


# ---------------------------------------------------------------------------
# hot swap under live generates
# ---------------------------------------------------------------------------


def bench_hot_swap(run_seconds: float) -> Dict[str, object]:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_smoke_config
    from repro.core import CheckpointConfig, CheckpointManager, theta_like
    from repro.models import get_model
    from repro.serve import FleetConfig, ServeConfig, ServeFleet, Server

    cfg = get_smoke_config("tinyllama-1.1b")
    model = get_model(cfg)
    p0 = model.init(jax.random.PRNGKey(0))
    p1 = model.init(jax.random.PRNGKey(1))
    prompts = {"tokens": jnp.asarray(np.full((2, 8), 7, np.int32))}
    serve_cfg = ServeConfig(max_new_tokens=4)
    refs = {
        0: Server(model, p0, serve_cfg).generate(prompts)[0],
        1: Server(model, p1, serve_cfg).generate(prompts)[0],
    }
    with tempfile.TemporaryDirectory(prefix="bench_swap_") as root:
        def save(step, params):
            train = CheckpointManager(
                CheckpointConfig(root=root, cluster=theta_like(4, 2),
                                 strategy="stripe_aligned", async_flush=False)
            )
            try:
                train.save(step, {"params": params})
            finally:
                train.close()

        save(1, p0)
        serve = CheckpointManager(
            CheckpointConfig(root=root, cluster=theta_like(2, 1),
                             strategy="stripe_aligned", async_flush=False)
        )
        fleet = ServeFleet(
            model, serve, jax.tree_util.tree_map(np.asarray, p0),
            cfg=FleetConfig(n_servers=1, serve=serve_cfg, poll_interval=0.02),
        )
        try:
            fleet.cold_start()
            results: List = []
            dropped = [0]
            stop = threading.Event()

            def hammer():
                srv = fleet.servers[0]
                while not stop.is_set():
                    try:
                        toks, _, v = srv.generate(prompts, with_version=True)
                        results.append((v, toks))
                    except Exception:
                        dropped[0] += 1
                        return

            threads = [threading.Thread(target=hammer) for _ in range(2)]
            for t in threads:
                t.start()
            fleet.start_follower()
            time.sleep(run_seconds / 2)
            save(2, p1)                    # training publishes a new step
            deadline = time.monotonic() + 60
            while fleet.current_step != 2 and time.monotonic() < deadline:
                time.sleep(0.02)
            t_end = time.time() + run_seconds / 2
            while time.time() < t_end or not any(v == 1 for v, _ in results):
                if time.monotonic() > deadline:
                    break
                time.sleep(0.02)
            stop.set()
            for t in threads:
                t.join(timeout=120)
                if t.is_alive():
                    dropped[0] += 1
            fleet.stop()
            torn = sum(
                0 if np.array_equal(toks, refs[min(v, 1)]) else 1
                for v, toks in results
            )
            swap_step, swap_s = (
                fleet.swap_history[-1] if fleet.swap_history else (-1, -1.0)
            )
        finally:
            fleet.close()
            serve.close()
    row = {
        "kind": "hot_swap",
        "config": f"tinyllama-smoke/{run_seconds:g}s",
        "n_generates": len(results),
        "pre_swap_generates": sum(1 for v, _ in results if v == 0),
        "post_swap_generates": sum(1 for v, _ in results if v >= 1),
        "dropped": int(dropped[0]),
        "torn": int(torn),
        "adopted_step": int(swap_step),
        "swap_latency_s": round(float(swap_s), 4),
    }
    print(
        f"  hot_swap {row['config']}: {row['n_generates']} generates "
        f"({row['pre_swap_generates']} pre / {row['post_swap_generates']} post), "
        f"dropped={row['dropped']}, torn={row['torn']}, "
        f"swap {row['swap_latency_s']}s",
        flush=True,
    )
    return row


# ---------------------------------------------------------------------------


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="CI smoke sizes")
    ap.add_argument("--out", default=None, help="write JSON results here")
    args = ap.parse_args(argv)

    rows: List[Dict[str, object]] = []
    if args.quick:
        print("ttft (quick)", flush=True)
        rows.append(bench_ttft(8, 2, 4, n_blocks=8, block_kib=64))
        print("cold_start_fleet (quick)", flush=True)
        rows.append(bench_cold_start_fleet(8, 2, 4, 2, n_blocks=8, block_kib=64))
        print("hot_swap (quick)", flush=True)
        rows.append(bench_hot_swap(run_seconds=1.0))
    else:
        print("ttft (paper-scale geometries)", flush=True)
        rows.append(bench_ttft(16, 16, 8, n_blocks=16, block_kib=256))
        rows.append(bench_ttft(64, 16, 8, n_blocks=32, block_kib=512))
        print("cold_start_fleet", flush=True)
        rows.append(bench_cold_start_fleet(16, 16, 8, 4, n_blocks=16,
                                           block_kib=256))
        rows.append(bench_cold_start_fleet(64, 16, 8, 4, n_blocks=32,
                                           block_kib=512))
        print("hot_swap", flush=True)
        rows.append(bench_hot_swap(run_seconds=4.0))

    doc = {"benchmark": "serve_fleet", "quick": bool(args.quick), "rows": rows}
    text = json.dumps(doc, indent=1)
    if args.out:
        from pathlib import Path

        Path(args.out).write_text(text + "\n")
        print(f"wrote {args.out}")
    else:
        print(text)
    return 0


if __name__ == "__main__":
    sys.exit(main())
