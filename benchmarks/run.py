"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig1,fig2,...] [--fast]

Prints ``name,us_per_call,derived`` CSV rows; rich JSON sidecars land in
reports/bench/.
"""
from __future__ import annotations

import argparse
import sys
import time

BENCHES = {
    "fig1_local": "benchmarks.local_phase",
    "fig2_flush": "benchmarks.flush_phase",
    "s3_proposal": "benchmarks.proposal_scale",
    "metadata": "benchmarks.metadata",
    "interference": "benchmarks.interference",
    "kernels": "benchmarks.kernel_bench",
    "overhead": "benchmarks.overhead",
}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default=None, help="comma-separated bench keys")
    args = ap.parse_args()
    keys = args.only.split(",") if args.only else list(BENCHES)
    import importlib

    t0 = time.time()
    for key in keys:
        mod = importlib.import_module(BENCHES[key])
        sys.stderr.write(f"== {key} ==\n")
        t1 = time.time()
        mod.main()
        sys.stderr.write(f"   ({time.time() - t1:.1f}s)\n")
    sys.stderr.write(f"total {time.time() - t0:.1f}s\n")


if __name__ == "__main__":
    main()
