"""Save-phase bench: the *blocking* cost of ``save()`` — fast path vs
the seed reference path, on real files.

The paper's headline metric is how long the application is blocked per
checkpoint: the local phase must run at node-local hardware speed while
aggregation proceeds asynchronously.  PRs 1–2 made *planning* an array
program; this bench times the write-side *execution* pipeline that
ISSUE 3 rebuilt:

* ``reference`` — the seed path, preserved verbatim
  (``zero_copy=False, parallel_local=False``): per-leaf ``tobytes`` +
  join recopy, per-rank ``bytes`` slices, sequential CRC + L1 writes,
  one fsync per rank file.
* ``fast`` — the zero-copy twin (``zero_copy=True,
  parallel_local=True``): leaves serialized straight into one buffer,
  codec-``none`` blobs are memoryview slices of it, per-rank CRC + L1
  writes drain through the shared worker pool, fsyncs batched per node
  directory.

Each geometry reports the wall time of the ``save()`` call itself (the
blocking window; the async flush is excluded but drained between
repeats) plus its encode/local split, and fast rows carry
``speedup`` = reference ``save_s`` / fast ``save_s``.  The committed
``BENCH_save.json`` extends the bench trajectory (planner → restore →
save); ``tools/bench_check.py`` gates its schema in CI.

Usage::

    PYTHONPATH=src python benchmarks/save_phase.py                # full sweep
    PYTHONPATH=src python benchmarks/save_phase.py --quick        # CI smoke
    PYTHONPATH=src python benchmarks/save_phase.py --out BENCH_save.json
"""
from __future__ import annotations

import argparse
import json
import sys
import tempfile
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import CheckpointConfig, CheckpointManager, theta_like

MiB = 1 << 20

# (nodes, ppn, state MiB, strategy, repeats).  The last geometry is the
# paper-style shape — many ranks per node, ~1 MiB blobs — where the
# seed's per-rank Python loop + per-file fsync dominate the blocking
# window; it is the acceptance geometry for the >=3x bar.
FULL_CONFIGS: List[Tuple[int, int, int, str, int]] = [
    (4, 2, 64, "stripe_aligned", 3),
    (8, 4, 256, "stripe_aligned", 3),
    (16, 8, 512, "stripe_aligned", 3),
    (64, 16, 128, "stripe_aligned", 3),
]
QUICK_CONFIGS: List[Tuple[int, int, int, str, int]] = [
    (2, 2, 16, "stripe_aligned", 2),
]


def make_state(total_bytes: int, n_leaves: int = 8) -> Dict[str, np.ndarray]:
    """A float32 pytree of ``n_leaves`` leaves summing to total_bytes."""
    rng = np.random.default_rng(0)
    per = total_bytes // n_leaves // 4
    return {
        f"layer_{i:02d}": rng.standard_normal(per).astype(np.float32)
        for i in range(n_leaves)
    }


def bench_path(
    root: str, nodes: int, ppn: int, strategy: str, state, repeats: int,
    *, fast: bool,
) -> Dict[str, float]:
    mgr = CheckpointManager(
        CheckpointConfig(
            root=root, cluster=theta_like(nodes, ppn), strategy=strategy,
            parallel_local=fast, zero_copy=fast,
        )
    )
    save_s: List[float] = []
    try:
        for step in range(1, repeats + 1):
            t0 = time.perf_counter()
            st = mgr.save(step, state)
            save_s.append(time.perf_counter() - t0)
            mgr.wait()  # drain the async flush so repeats don't backpressure
            assert not mgr.flush_errors, mgr.flush_errors
        best = int(np.argmin(save_s))
        return {
            "save_s": round(min(save_s), 4),
            "encode_s": round(mgr.stats[best].encode_time, 4),
            "local_s": round(mgr.stats[best].local_time, 4),
        }
    finally:
        mgr.close()


def bench_one(
    nodes: int, ppn: int, state_mib: int, strategy: str, repeats: int,
    *, verbose: bool = True,
) -> List[Dict[str, object]]:
    state = make_state(state_mib * MiB)
    rows: List[Dict[str, object]] = []
    timings: Dict[str, Dict[str, float]] = {}
    for path in ("reference", "fast"):
        with tempfile.TemporaryDirectory() as root:
            timings[path] = bench_path(
                root, nodes, ppn, strategy, state, repeats,
                fast=(path == "fast"),
            )
    for path in ("reference", "fast"):
        row: Dict[str, object] = {
            "config": f"{nodes}x{ppn}/{state_mib}MiB/{strategy}",
            "kind": "save_phase",
            "nodes": nodes,
            "ppn": ppn,
            "n_ranks": nodes * ppn,
            "strategy": strategy,
            "state_bytes": state_mib * MiB,
            "path": path,
            **timings[path],
        }
        if path == "fast":
            row["speedup"] = round(
                timings["reference"]["save_s"] / timings["fast"]["save_s"], 2
            )
        rows.append(row)
        if verbose:
            extra = f"  speedup={row['speedup']:5.2f}x" if path == "fast" else ""
            print(
                f"{row['config']:>32} {path:>9}  save={row['save_s']:7.3f}s  "
                f"encode={row['encode_s']:7.3f}s  local={row['local_s']:7.3f}s"
                f"{extra}",
                flush=True,
            )
    return rows


def run(
    configs: List[Tuple[int, int, int, str, int]],
    *, only: Optional[str] = None, verbose: bool = True,
) -> List[Dict[str, object]]:
    rows: List[Dict[str, object]] = []
    for nodes, ppn, mib, strategy, repeats in configs:
        if only and only not in (f"{nodes}x{ppn}",):
            continue
        rows.extend(bench_one(nodes, ppn, mib, strategy, repeats, verbose=verbose))
    return rows


def main(argv: Optional[List[str]] = None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--quick", action="store_true", help="CI smoke configs")
    p.add_argument("--only", help="restrict to one geometry, e.g. 8x4")
    p.add_argument("--out", help="write JSON rows to this path")
    args = p.parse_args(argv)

    configs = QUICK_CONFIGS if args.quick else FULL_CONFIGS
    rows = run(configs, only=args.only)
    doc = {"benchmark": "save_phase", "quick": bool(args.quick), "rows": rows}
    text = json.dumps(doc, indent=2)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
        print(f"wrote {args.out}")
    else:
        json.dump(doc, sys.stdout, indent=2)
        print()


if __name__ == "__main__":
    main()
