"""Deterministic chaos sweep: seeded fault schedules vs the self-healing
storage runtime (ISSUE 6 acceptance harness).

Each seed derives one complete scenario — aggregation strategy, partner
replication, codec, and a :meth:`~repro.core.faults.FaultPlan.generate`
schedule of transient EIO, ENOSPC, torn writes, bit flips, I/O stalls
and node crashes at exact op indices — then drives the full
save → flush → scrub → repair → restore loop and asserts the runtime's
invariants:

1. every ``flush_done`` step that is not quarantined restores
   **byte-identically** (verify-phase read faults may delay it, never
   corrupt it);
2. schedules made only of transient kinds produce **zero**
   ``flush_errors`` — the retry layer heals them invisibly;
3. permanent flush failures (ENOSPC) stay journal-resumable:
   ``resume_flushes()`` finishes them and they then flush-verify;
4. single-domain damage is repaired back to a clean re-scrub
   (``repair_success_frac`` gated ≥ 0.95 by tools/bench_check.py);
5. irreparable damage lands in ``quarantined`` — restore raises a
   clean error, never returns wrong bytes.

Any violation is recorded per schedule (``invariant_violations``) and
fails the sweep's exit code; the committed ``BENCH_chaos.json`` is the
CI-gated record (``python tools/bench_check.py``).

Usage::

    PYTHONPATH=src python benchmarks/chaos.py                  # full sweep
    PYTHONPATH=src python benchmarks/chaos.py --quick          # CI smoke
    PYTHONPATH=src python benchmarks/chaos.py --out BENCH_chaos.json
"""
from __future__ import annotations

import argparse
import json
import sys
import tempfile
import time
from pathlib import Path
from typing import Any, Dict, List

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import (  # noqa: E402
    CheckpointConfig,
    CheckpointManager,
    theta_like,
)
from repro.core.faults import FAULT_KINDS, TRANSIENT_KINDS, FaultPlan  # noqa: E402

ALL_STRATEGIES = ["file_per_process", "posix", "mpiio", "stripe_aligned", "gio_sync"]
#: kinds whose firing leaves on-disk damage that only scrub-and-repair
#: (not the inline retry layer) can heal
DAMAGE_KINDS = {"bit_flip", "node_crash"}
N_STEPS = 3
QUICK_SEEDS = 12
FULL_SEEDS = 120


def ref_state(seed: int, step: int) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed * 1_000_003 + step)
    return {
        "w": rng.standard_normal((2048, 4)).astype(np.float32),
        "b": np.full((64,), step, np.float32),
        "c": rng.integers(0, 255, (4096,), dtype=np.uint8),
    }


def trees_equal(a: Dict, b: Dict) -> bool:
    return set(a) == set(b) and all(
        np.array_equal(np.asarray(a[k]), np.asarray(b[k])) for k in a
    )


def run_schedule(seed: int, *, root: str) -> Dict[str, Any]:
    """One seeded scenario end to end; returns its result row."""
    strategy = ALL_STRATEGIES[seed % len(ALL_STRATEGIES)]
    partner = seed % 2 == 0
    delta = seed % 3 == 0
    # max_index sized to the actual op streams of this geometry (a few
    # extents per file per step): larger indices would never fire
    faults = FaultPlan.generate(seed=seed * 7919 + 13, n_nodes=2, max_index=10)
    cfg = CheckpointConfig(
        root=str(Path(root) / "ckpt"),
        cluster=theta_like(2, 2),
        strategy=strategy,
        async_flush=False,
        partner_replication=partner,
        codec="zstd+delta" if delta else "none",
        delta_every=4,
        chunk_size=4096,
        retry_base_delay=0.002,
        retry_max_delay=0.02,
    )
    row: Dict[str, Any] = {
        "kind": "schedule",
        "seed": seed,
        "strategy": strategy,
        "partner_replication": partner,
        "codec": cfg.codec,
        "n_steps": N_STEPS,
        "planned_kinds": sorted({s.kind for s in faults.specs}),
        "invariant_violations": [],
    }
    violations: List[str] = row["invariant_violations"]
    t0 = time.perf_counter()
    mgr = CheckpointManager(cfg, faults=faults)
    try:
        # ---- save phase (faults armed) ----
        faults.arm("save")
        io_retries = 0
        save_failed: List[int] = []
        for s in range(1, N_STEPS + 1):
            try:
                st = mgr.save(s, ref_state(seed, s))
                if st.flush is not None:
                    io_retries += st.flush.io_retries
            except OSError:
                # a permanent fault crashed the save itself: either the
                # local phase died (no manifest — the step never exists)
                # or, under sync flush, the PFS flush raised through
                # save() leaving a journal-resumable flush_partial
                save_failed.append(s)
        flush_errors = list(mgr.flush_errors)
        failed_steps = {st for st, _ in flush_errors} | set(save_failed)
        resumed = {}
        if failed_steps:
            # permanent flush failures must stay journal-resumable
            resumed = mgr.resume_flushes()
            io_retries += sum(r.io_retries for r in resumed.values())
            for step in sorted(failed_steps):
                if step in resumed or step in mgr.steps("pfs"):
                    continue
                if step not in mgr.steps("local"):
                    continue  # local phase died: the step never committed
                # a second fault may legitimately fail the resume too;
                # only a *fault-free* failed resume is a violation
                if not any(e[0] == step for e in mgr.flush_errors):
                    violations.append(
                        f"step {step}: failed flush neither resumed "
                        "nor re-reported"
                    )
        faults.disarm()
        row["save_failed_steps"] = save_failed
        row["flush_errors"] = len(flush_errors)
        row["resumed_steps"] = sorted(resumed)
        row["io_retries"] = io_retries
        fired = faults.fired_kinds()
        row["fired_kinds"] = sorted(fired)
        row["n_fired"] = len(faults.fired)

        # invariant 2: transient-only schedules heal with zero errors
        planned = {s.kind for s in faults.specs}
        row["transient_only"] = bool(planned) and planned <= TRANSIENT_KINDS
        if row["transient_only"] and (flush_errors or save_failed):
            violations.append(
                "transient-only schedule produced failures: "
                f"flush={flush_errors} save={save_failed}"
            )

        # ---- scrub-and-repair phase (faults disarmed) ----
        known = sorted(set(mgr.steps("local")) | set(mgr.steps("pfs")))
        quarantined: List[int] = []
        repaired_ranks = 0
        rescrub_clean = True
        for s in known:
            rep = mgr.validate(s, repair=True)
            r = rep["repair"]
            repaired_ranks += len(r.pfs_repaired) + len(r.l1_restored) + len(
                r.partner_restored
            )
            if r.quarantined:
                quarantined.append(s)
                continue
            post = rep.get("post", {})
            for level in ("pfs", "local", "partner"):
                if not all(post.get(level, {}).values() or [True]):
                    rescrub_clean = False
                    violations.append(
                        f"step {s}: {level} still dirty after repair: "
                        f"{post.get(level)}"
                    )
        quarantined = sorted(
            set(quarantined)
            | {s for s in known if s not in mgr.steps("local") and s not in mgr.steps("pfs")}
        )
        row["quarantined_steps"] = quarantined
        row["repaired_ranks"] = repaired_ranks

        # invariant 4: single-domain damage with a surviving redundant
        # copy must repair back to a clean re-scrub.  The flush
        # aggregates PFS bytes *from the L1 blobs* (VELOC semantics),
        # so an un-replicated L1 bit flip propagates to the PFS — both
        # copies bad is genuinely irreparable and quarantine (inv. 5)
        # is the required outcome, not a repair failure.
        domains = {f[1] for f in faults.fired}
        row["single_domain"] = len(domains) == 1
        row["damage"] = bool(fired & DAMAGE_KINDS)
        redundant = all(
            partner
            or (kind == "bit_flip" and domain in ("pfs", "partner"))
            or kind not in DAMAGE_KINDS
            for kind, domain, _op, _idx in faults.fired
        )
        row["redundancy_survives"] = redundant
        row["repair_relevant"] = (
            row["single_domain"] and row["damage"] and redundant
        )
        row["repair_success"] = rescrub_clean and not quarantined
        if row["repair_relevant"] and quarantined:
            violations.append(
                f"repairable single-domain schedule quarantined {quarantined}"
            )

        # ---- verify phase (read-side faults armed) ----
        faults.arm("verify")
        restored_ok = True
        for s in mgr.steps("pfs"):
            mgr._l0 = None
            mgr._last_full = None
            try:
                got_step, tree = mgr.restore(ref_state(seed, s), step=s)
            except Exception as e:
                restored_ok = False
                violations.append(f"step {s}: flush_done restore raised {e!r}")
                continue
            if got_step != s or not trees_equal(tree, ref_state(seed, s)):
                restored_ok = False
                violations.append(f"step {s}: restore not byte-identical")
        # invariant 5: quarantined steps raise cleanly, never wrong bytes
        for s in quarantined:
            mgr._l0 = None
            mgr._last_full = None
            try:
                mgr.restore(ref_state(seed, s), step=s)
                restored_ok = False
                violations.append(f"step {s}: quarantined step restored")
            except Exception:
                pass
        faults.disarm()
        row["restored_identical"] = restored_ok
        row["verify_retries"] = sum(
            1 for f in faults.fired if f[2] == "read"
        )
    finally:
        mgr.close()
    row["elapsed_s"] = round(time.perf_counter() - t0, 4)
    return row


def run_sweep(seeds: List[int], *, workdir: str) -> List[Dict[str, Any]]:
    rows = []
    for i, seed in enumerate(seeds):
        row = run_schedule(seed, root=str(Path(workdir) / f"seed_{seed}"))
        rows.append(row)
        flag = "" if not row["invariant_violations"] else "  VIOLATION"
        print(
            f"[{i + 1:3d}/{len(seeds)}] seed={seed:<4d} {row['strategy']:<17s}"
            f" fired={','.join(row['fired_kinds']) or '-':<40s}"
            f" q={row['quarantined_steps']}{flag}"
        )
    return rows


def summarize(rows: List[Dict[str, Any]], quick: bool) -> Dict[str, Any]:
    relevant = [r for r in rows if r["repair_relevant"]]
    n_rel = len(relevant)
    kinds = set()
    for r in rows:
        kinds |= set(r["fired_kinds"])
    return {
        "kind": "chaos_summary",
        "n_schedules": len(rows),
        "n_violations": sum(len(r["invariant_violations"]) for r in rows),
        "restored_identical": all(r["restored_identical"] for r in rows),
        "transient_zero_errors": all(
            r["flush_errors"] == 0 and not r["save_failed_steps"]
            for r in rows
            if r["transient_only"]
        ),
        "n_repair_relevant": n_rel,
        "repair_success_frac": (
            round(sum(r["repair_success"] for r in relevant) / n_rel, 4)
            if n_rel
            else 1.0
        ),
        "n_quarantined": sum(len(r["quarantined_steps"]) for r in rows),
        "kinds_covered": sorted(kinds),
        "strategies_covered": sorted({r["strategy"] for r in rows}),
        "total_io_retries": sum(r["io_retries"] for r in rows),
        "quick": quick,
    }


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="CI smoke (fewer seeds)")
    ap.add_argument("--seeds", type=int, default=None, help="override seed count")
    ap.add_argument("--out", type=str, default=None, help="write BENCH json here")
    args = ap.parse_args()
    n = args.seeds or (QUICK_SEEDS if args.quick else FULL_SEEDS)
    seeds = list(range(n))
    with tempfile.TemporaryDirectory(prefix="chaos_") as workdir:
        rows = run_sweep(seeds, workdir=workdir)
    summary = summarize(rows, args.quick)
    rows.append(summary)
    print(json.dumps(summary, indent=1))

    ok = summary["n_violations"] == 0 and summary["restored_identical"]
    if not args.quick:
        # full-sweep coverage bars (quick mode is too small to demand them)
        if set(summary["kinds_covered"]) != set(FAULT_KINDS):
            print(
                f"chaos: kinds not covered: "
                f"{sorted(set(FAULT_KINDS) - set(summary['kinds_covered']))}",
                file=sys.stderr,
            )
            ok = False
        if set(summary["strategies_covered"]) != set(ALL_STRATEGIES):
            print("chaos: not all strategies covered", file=sys.stderr)
            ok = False
        if summary["repair_success_frac"] < 0.95:
            print(
                f"chaos: repair_success_frac {summary['repair_success_frac']}"
                " < 0.95",
                file=sys.stderr,
            )
            ok = False
    if args.out:
        doc = {"benchmark": "chaos", "quick": args.quick, "rows": rows}
        Path(args.out).write_text(json.dumps(doc, indent=1) + "\n")
        print(f"wrote {args.out}")
    if not ok:
        for r in rows:
            for v in r.get("invariant_violations", []):
                print(f"chaos: seed {r['seed']}: {v}", file=sys.stderr)
        return 1
    print(f"chaos: OK ({summary['n_schedules']} schedules, zero violations)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
