"""Shared benchmark plumbing: timing, CSV rows, report files."""
from __future__ import annotations

import csv
import json
import time
from pathlib import Path
from typing import Any, Dict, Iterable, List

REPORT_DIR = Path(__file__).resolve().parents[1] / "reports" / "bench"


class Rows:
    """Collects (name, us_per_call, derived) rows + a rich JSON sidecar."""

    def __init__(self, bench: str):
        self.bench = bench
        self.rows: List[Dict[str, Any]] = []

    def add(self, name: str, us_per_call: float, derived: str, **extra) -> None:
        self.rows.append(
            {"name": name, "us_per_call": us_per_call, "derived": derived, **extra}
        )

    def emit(self) -> None:
        REPORT_DIR.mkdir(parents=True, exist_ok=True)
        for r in self.rows:
            print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
        path = REPORT_DIR / f"{self.bench}.json"
        path.write_text(json.dumps(self.rows, indent=1, default=str))


def time_call(fn, *args, repeat: int = 3, **kw) -> float:
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return best
