"""Multi-tenant control-plane traffic replay (ISSUE 10 acceptance harness).

Drives one :class:`~repro.control.ControlPlane` — one PFS root, one
admission budget, one fair-share bandwidth cap — with a seeded,
replayable traffic trace of >= 100 concurrent clients spread over
>= 8 tenants, interleaving save / restore / GC, and records:

* ``replay``      — zero failed saves, per-tenant byte-identical final
  restores, p50/p99 *blocking* save latency (the training-loop stall,
  not the async drain);
* ``fairness``    — equal-weight tenants saturating one
  ``flush_bw_cap``: per-tenant achieved flush throughput and the Jain
  fairness index (gated >= 0.9), plus a weighted 2:1 split for the
  priced-priority record;
* ``utilization`` — aggregate PFS MB/s through the arbitrated plane vs
  N independent unthrottled managers on private roots (gated >= 0.8x:
  arbitration must not burn real bandwidth);
* ``preemption``  — a high-priority tenant preempts a queued
  low-priority flush; the cluster budget is never exceeded and the
  parked flush still drains to ``flush_done`` byte-identically;
* ``tenant_chaos`` — a PFS outage pinned to one tenant's flush: the
  shared breaker opens, the other tenant's saves never fail, and the
  post-heal drain publishes the higher-priority tenant first;
* ``control_summary`` — the CI-gated aggregate
  (``tools/bench_check.py``: Jain >= 0.9, zero failed saves,
  utilization >= 0.8, >= 100 clients / >= 8 tenants on a full run).

Usage::

    PYTHONPATH=src python benchmarks/control_plane.py                 # full
    PYTHONPATH=src python benchmarks/control_plane.py --quick         # CI smoke
    PYTHONPATH=src python benchmarks/control_plane.py --out BENCH_control.json
"""
from __future__ import annotations

import argparse
import json
import sys
import tempfile
import threading
import time
from pathlib import Path
from typing import Any, Dict, List

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.control import ControlPlane  # noqa: E402
from repro.core import (  # noqa: E402
    CheckpointConfig,
    CheckpointManager,
    ClusterSpec,
)
from repro.core.faults import FaultPlan  # noqa: E402

MiB = 1 << 20
STRATEGIES = ["posix", "file_per_process", "mpiio", "stripe_aligned"]


def cluster() -> ClusterSpec:
    return ClusterSpec(n_nodes=2, procs_per_node=2)


def tenant_state(name: str, step: int, kb: int = 32) -> Dict[str, np.ndarray]:
    seed = (hash(name) & 0xFFFF) * 1000 + step
    rng = np.random.default_rng(seed)
    return {
        "w": rng.standard_normal((kb * 1024 // 8,)).astype(np.float64),
        "s": np.full((16,), step, np.int32),
    }


def trees_equal(a: Dict, b: Dict) -> bool:
    return set(a) == set(b) and all(
        np.array_equal(np.asarray(a[k]), np.asarray(b[k])) for k in a
    )


def jain(xs: List[float]) -> float:
    x = np.asarray(xs, float)
    if not len(x) or not x.sum():
        return 0.0
    return float(x.sum() ** 2 / (len(x) * (x * x).sum()))


# ---------------------------------------------------------------------------
# traffic replay
# ---------------------------------------------------------------------------


def run_replay(
    root: str, *, n_tenants: int, clients_per_tenant: int,
    saves_per_client: int, seed: int,
) -> Dict[str, Any]:
    """Seeded trace: every client interleaves saves (serialized per
    tenant — training steps are ordered), restores and GC-inducing
    churn against ONE plane."""
    cp = ControlPlane(root, max_pending_flushes=4 * n_tenants)
    names = [f"tenant{i:02d}" for i in range(n_tenants)]
    for i, n in enumerate(names):
        cp.register_job(
            n, cluster(), priority=1.0 + (i % 3), keep_n=4,
            strategy=STRATEGIES[i % len(STRATEGIES)], codec="none",
        )
    step_alloc = {n: 0 for n in names}
    save_lock = {n: threading.Lock() for n in names}
    latencies: List[float] = []
    lat_lock = threading.Lock()
    failures: List[str] = []

    def client(tenant: str, cid: int) -> None:
        rng = np.random.default_rng(seed * 7919 + hash(tenant) % 1000 + cid)
        m = cp.manager(tenant)
        try:
            for _ in range(saves_per_client):
                with save_lock[tenant]:
                    step_alloc[tenant] += 1
                    s = step_alloc[tenant]
                    t0 = time.perf_counter()
                    m.save(s, tenant_state(tenant, s))
                    dt = time.perf_counter() - t0
                with lat_lock:
                    latencies.append(dt)
                op = rng.random()
                if op < 0.3:  # interleaved restore under live flush traffic
                    got_s, got = m.restore(tenant_state(tenant, 0))
                    if not trees_equal(got, tenant_state(tenant, got_s)):
                        failures.append(f"{tenant}: restore mismatch @ {got_s}")
                elif op < 0.5:
                    cp.list_steps(tenant)
        except BaseException as e:
            failures.append(f"{tenant}/c{cid}: {type(e).__name__}: {e}")

    threads = [
        threading.Thread(target=client, args=(n, c))
        for n in names
        for c in range(clients_per_tenant)
    ]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    for n in names:
        cp.manager(n).wait()
    elapsed = time.perf_counter() - t0
    byte_identical = True
    for n in names:
        if cp.manager(n).flush_errors:
            failures.append(f"{n}: flush_errors")
        got_s, got = cp.manager(n).restore(tenant_state(n, 0))
        if not trees_equal(got, tenant_state(n, got_s)):
            byte_identical = False
            failures.append(f"{n}: final restore mismatch @ {got_s}")
        steps = cp.list_steps(n)
        if len(steps) > 4:  # keep_n=4 GC ran under churn
            failures.append(f"{n}: GC left {len(steps)} steps")
    cp.close()
    lat = np.asarray(latencies)
    return {
        "kind": "replay",
        "n_tenants": n_tenants,
        "n_clients": n_tenants * clients_per_tenant,
        "n_saves": int(len(lat)),
        "failed_saves": len(failures),
        "failures": failures[:8],
        "byte_identical": byte_identical,
        "p50_blocking_save_s": round(float(np.percentile(lat, 50)), 6),
        "p99_blocking_save_s": round(float(np.percentile(lat, 99)), 6),
        "elapsed_s": round(elapsed, 4),
    }


# ---------------------------------------------------------------------------
# fairness under one saturated cap
# ---------------------------------------------------------------------------


def run_fairness(
    root: str, *, n_tenants: int, weights: List[float], cap: float,
    per_tenant_bytes: int,
) -> Dict[str, Any]:
    cp = ControlPlane(root, flush_bw_cap=cap,
                      max_pending_flushes=2 * n_tenants)
    mgrs = [
        cp.register_job(f"fair{i}", cluster(), priority=weights[i],
                        strategy="posix", codec="none")
        for i in range(n_tenants)
    ]
    state = {"w": np.ones(per_tenant_bytes // 8, np.float64)}
    barrier = threading.Barrier(n_tenants)

    def run(m: CheckpointManager) -> None:
        barrier.wait()  # all tenants saturate the cap together
        m.save(1, state)
        m.wait()

    threads = [threading.Thread(target=run, args=(m,)) for m in mgrs]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    mbps = []
    for m in mgrs:
        fl = m.stats[0].flush
        mbps.append(per_tenant_bytes / max(1e-9, fl.duration) / MiB)
    cp.close()
    return {
        "kind": "fairness",
        "n_tenants": n_tenants,
        "weights": weights,
        "flush_bw_cap_mbps": round(cap / MiB, 3),
        "per_tenant_bytes": per_tenant_bytes,
        "per_tenant_mbps": [round(x, 3) for x in mbps],
        "jain_index": round(jain(mbps), 4),
    }


# ---------------------------------------------------------------------------
# aggregate utilization: arbitrated plane vs independent managers
# ---------------------------------------------------------------------------


def run_utilization(
    workdir: str, *, n_tenants: int, saves: int, per_save_bytes: int,
) -> Dict[str, Any]:
    def drive(make_mgr) -> float:
        mgrs = [make_mgr(i) for i in range(n_tenants)]
        barrier = threading.Barrier(n_tenants)

        def run(m):
            barrier.wait()
            for s in range(1, saves + 1):
                m.save(s, {"w": np.full(per_save_bytes // 8, s, np.float64)})
            m.wait()

        threads = [threading.Thread(target=run, args=(m,)) for m in mgrs]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        elapsed = time.perf_counter() - t0
        for m in mgrs:
            assert m.flush_errors == []
            m.close()
        return elapsed

    # baseline: N unthrottled managers, private roots, private budgets
    base_elapsed = drive(lambda i: CheckpointManager(CheckpointConfig(
        root=f"{workdir}/solo{i}", cluster=cluster(), strategy="posix",
        codec="none", max_pending_flushes=2,
    )))
    # control plane: same traffic through one arbitrated runtime (no bw
    # cap — the question is whether arbitration itself costs bandwidth)
    cp = ControlPlane(f"{workdir}/plane", max_pending_flushes=2 * n_tenants)
    regs = [
        cp.register_job(f"util{i}", cluster(), strategy="posix", codec="none")
        for i in range(n_tenants)
    ]
    ctrl_elapsed = drive(lambda i: regs[i])
    cp.close()
    total = n_tenants * saves * per_save_bytes
    base_mbps = total / base_elapsed / MiB
    ctrl_mbps = total / ctrl_elapsed / MiB
    return {
        "kind": "utilization",
        "n_tenants": n_tenants,
        "total_bytes": total,
        "baseline_mbps": round(base_mbps, 2),
        "control_mbps": round(ctrl_mbps, 2),
        "utilization_frac": round(ctrl_mbps / base_mbps, 4),
    }


# ---------------------------------------------------------------------------
# preemption + chaos scenarios
# ---------------------------------------------------------------------------


def run_preemption(root: str) -> Dict[str, Any]:
    cp = ControlPlane(root, flush_bw_cap=4 * MiB, max_pending_flushes=2)
    lo = cp.register_job("lo", cluster(), priority=1.0, strategy="posix",
                         codec="none", health_tick=0.05)
    hi = cp.register_job("hi", cluster(), priority=10.0, strategy="posix",
                         codec="none", health_tick=0.05)
    max_held = [0]
    stop = threading.Event()

    def watch():
        while not stop.is_set():
            max_held[0] = max(max_held[0], cp.admission.held())
            time.sleep(0.002)

    w = threading.Thread(target=watch)
    w.start()
    lo.save(1, tenant_state("lo", 1, kb=2048))   # mid-flight under the cap
    lo.save(2, tenant_state("lo", 2, kb=64))     # queued: the victim
    t0 = time.perf_counter()
    hi.save(1, tenant_state("hi", 1, kb=64))
    hi_blocked_s = time.perf_counter() - t0
    deadline = time.monotonic() + 60
    while lo.step_status(2) != "flush_done" and time.monotonic() < deadline:
        time.sleep(0.05)
    lo.wait(), hi.wait()
    stop.set()
    w.join()
    got_s, got = lo.restore(tenant_state("lo", 0, kb=64))
    row = {
        "kind": "preemption",
        "budget": 2,
        "max_held": max_held[0],
        "budget_exceeded": max_held[0] > 2,
        "preemptions": cp.admission.preemptions,
        "hi_blocked_s": round(hi_blocked_s, 4),
        "victim_final_status": lo.step_status(2),
        "byte_identical": (
            got_s == 2 and trees_equal(got, tenant_state("lo", 2, kb=64))
        ),
    }
    cp.close()
    return row


def run_tenant_chaos(root: str) -> Dict[str, Any]:
    plans = FaultPlan.generate_fleet(11, 2, victim=0, outage_ops=10**9,
                                     max_index=1)
    cp = ControlPlane(root, max_pending_flushes=8,
                      health_min_ops=2, health_cooldown=0.05)
    common = dict(strategy="posix", codec="none",
                  retry_base_delay=0.001, retry_max_delay=0.002,
                  health_min_ops=2, health_cooldown=0.05, health_tick=10.0)
    vic = cp.register_job("victim", cluster(), priority=1.0,
                          faults=plans[0], **common)
    oth = cp.register_job("other", cluster(), priority=5.0,
                          faults=plans[1], **common)
    vic.faults.arm("save")
    done_order: List[str] = []
    cp.subscribe("victim", lambda s: done_order.append("victim"))
    cp.subscribe("other", lambda s: done_order.append("other"))
    other_failed = 0
    vic.save(1, tenant_state("victim", 1))
    deadline = time.monotonic() + 30
    while cp.health_state() == "closed" and time.monotonic() < deadline:
        time.sleep(0.01)
    try:
        oth.save(1, tenant_state("other", 1))
    except Exception:
        other_failed += 1
    deadline = time.monotonic() + 30
    while (not (vic.health().parked_steps and oth.health().parked_steps)
           and time.monotonic() < deadline):
        time.sleep(0.01)
    plans[0].heal()
    plans[0].disarm()
    order: List[str] = []
    deadline = time.monotonic() + 30
    while time.monotonic() < deadline:
        order = cp.drain()
        if (vic.step_status(1) == "flush_done"
                and oth.step_status(1) == "flush_done"):
            break
        time.sleep(0.05)
    got_s, got = oth.restore(tenant_state("other", 0))
    row = {
        "kind": "tenant_chaos",
        "victim": "victim",
        "breaker_shared": True,
        "other_failed_saves": other_failed,
        "other_flush_errors": len(oth.flush_errors),
        "other_giveups": oth.retry.giveups,
        "drained": (vic.step_status(1) == "flush_done"
                    and oth.step_status(1) == "flush_done"),
        "drain_priority_ok": (
            order == ["other", "victim"]
            and bool(done_order) and done_order[0] == "other"
        ),
        "byte_identical": (
            got_s == 1 and trees_equal(got, tenant_state("other", 1))
        ),
    }
    cp.close()
    return row


# ---------------------------------------------------------------------------
# sweep + summary
# ---------------------------------------------------------------------------


def summarize(rows: List[Dict[str, Any]], quick: bool) -> Dict[str, Any]:
    replay = next(r for r in rows if r["kind"] == "replay")
    fair = [r for r in rows if r["kind"] == "fairness"]
    equal = next(r for r in fair if len(set(r["weights"])) == 1)
    util = next(r for r in rows if r["kind"] == "utilization")
    pre = next(r for r in rows if r["kind"] == "preemption")
    chaos = next(r for r in rows if r["kind"] == "tenant_chaos")
    return {
        "kind": "control_summary",
        "n_tenants": replay["n_tenants"],
        "n_clients": replay["n_clients"],
        "failed_saves": replay["failed_saves"],
        "byte_identical": (
            replay["byte_identical"] and pre["byte_identical"]
            and chaos["byte_identical"]
        ),
        "p99_blocking_save_s": replay["p99_blocking_save_s"],
        "jain_index": equal["jain_index"],
        "utilization_frac": util["utilization_frac"],
        "preemptions": pre["preemptions"],
        "budget_exceeded": pre["budget_exceeded"],
        "chaos_isolated": (
            chaos["other_failed_saves"] == 0
            and chaos["other_flush_errors"] == 0
            and chaos["drained"]
        ),
        "quick": quick,
    }


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="CI smoke (small trace)")
    ap.add_argument("--out", type=str, default=None, help="write BENCH json here")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    n_tenants = 8
    clients = 4 if args.quick else 13           # 32 quick / 104 full clients
    saves = 2 if args.quick else 3
    rows: List[Dict[str, Any]] = []
    with tempfile.TemporaryDirectory(prefix="ctl_") as workdir:
        rows.append(run_replay(
            f"{workdir}/replay", n_tenants=n_tenants,
            clients_per_tenant=clients, saves_per_client=saves,
            seed=args.seed,
        ))
        nf = 2 if args.quick else 4
        rows.append(run_fairness(
            f"{workdir}/fair_eq", n_tenants=nf, weights=[1.0] * nf,
            cap=4.0 * nf * MiB, per_tenant_bytes=4 * MiB,
        ))
        rows.append(run_fairness(
            f"{workdir}/fair_w", n_tenants=2, weights=[2.0, 1.0],
            cap=6 * MiB, per_tenant_bytes=4 * MiB,
        ))
        rows.append(run_utilization(
            workdir, n_tenants=4, saves=1 if args.quick else 3,
            per_save_bytes=2 * MiB,
        ))
        rows.append(run_preemption(f"{workdir}/preempt"))
        rows.append(run_tenant_chaos(f"{workdir}/chaos"))
    summary = summarize(rows, args.quick)
    rows.append(summary)
    print(json.dumps(summary, indent=1))

    ok = (
        summary["failed_saves"] == 0
        and summary["byte_identical"]
        and not summary["budget_exceeded"]
        and summary["preemptions"] >= 1
        and summary["chaos_isolated"]
    )
    if not args.quick:
        # full-run acceptance bars (quick traces are too small/noisy)
        if summary["n_clients"] < 100 or summary["n_tenants"] < 8:
            print("control: trace below 100 clients / 8 tenants",
                  file=sys.stderr)
            ok = False
        if summary["jain_index"] < 0.9:
            print(f"control: jain {summary['jain_index']} < 0.9",
                  file=sys.stderr)
            ok = False
        if summary["utilization_frac"] < 0.8:
            print(
                f"control: utilization {summary['utilization_frac']} < 0.8x "
                "the unarbitrated baseline", file=sys.stderr,
            )
            ok = False
    if args.out:
        doc = {"benchmark": "control_plane", "quick": args.quick, "rows": rows}
        Path(args.out).write_text(json.dumps(doc, indent=1) + "\n")
        print(f"wrote {args.out}")
    if not ok:
        for r in rows:
            for f in r.get("failures", []):
                print(f"control: {f}", file=sys.stderr)
        return 1
    print(
        f"control: OK ({summary['n_clients']} clients / "
        f"{summary['n_tenants']} tenants, zero failed saves)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
