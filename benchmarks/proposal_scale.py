"""Paper §3 claim: the stripe-aligned async strategy at scale.

Sweeps node count (fixed ppn), non-uniform checkpoint sizes and loaded
nodes (exercising election criteria 1+2), and the leader count M.
Reports flush throughput + the metadata/file-count win over
file-per-process.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Rows
from repro.core import make_plan, simulate_flush, theta_like

GiB = 1 << 30


def run(ppn: int = 8, node_list=(16, 32, 64, 128, 256, 512), io_threads: int = 4) -> Rows:
    # The 256/512-node points were out of reach for the pre-columnar
    # planner (plan build alone took minutes); the PlanArrays pipeline
    # makes the whole sweep an array program.
    rows = Rows("proposal_scale")
    rng = np.random.default_rng(0)
    for nodes in node_list:
        cluster = theta_like(nodes, ppn)
        # heterogeneous checkpoint sizes (0.5-1.5 GiB) + 20% loaded nodes
        sizes = rng.integers(GiB // 2, 3 * GiB // 2, cluster.world_size).tolist()
        load = np.where(rng.random(nodes) < 0.2, 0.5, 0.0).tolist()
        cluster = cluster.with_(node_load=load)
        for strat, kw in [
            ("file_per_process", {}),
            ("stripe_aligned", {"pipeline_chunk": 256 << 20}),
        ]:
            plan = make_plan(strat, cluster, sizes, **kw)
            rep = simulate_flush(plan, io_threads=io_threads)
            rows.add(
                f"s3/scale/{strat}/n{nodes}xppn{ppn}",
                rep.flush_time * 1e6,
                f"{rep.flush_bw / 1e9:.1f}GBps",
                nodes=nodes, ppn=ppn, strategy=strat,
                flush_bw=rep.flush_bw, n_files=rep.n_files,
                metadata_ops=rep.metadata_ops,
                network_gib=rep.network_bytes / GiB,
            )
    # leader count sweep at 64 nodes (observation 1: match I/O servers?)
    cluster = theta_like(64, ppn)
    sizes = [GiB] * cluster.world_size
    for m in (8, 16, 32, 48, 64):
        plan = make_plan(
            "stripe_aligned", cluster, sizes, n_leaders=m,
            pipeline_chunk=256 << 20,
        )
        rep = simulate_flush(plan, io_threads=io_threads)
        rows.add(
            f"s3/leaders/m{m}/n64xppn{ppn}",
            rep.flush_time * 1e6,
            f"{rep.flush_bw / 1e9:.1f}GBps",
            m_leaders=m, flush_bw=rep.flush_bw,
            network_gib=rep.network_bytes / GiB,
        )
    # MPI-IO aggregator-count ablation (ADIO cb_nodes analogue)
    for m in (8, 16, 32, 48, 64):
        plan = make_plan("mpiio", cluster, sizes, n_leaders=m, chunk_stripes=64)
        rep = simulate_flush(plan, io_threads=io_threads)
        rows.add(
            f"mpiio/leaders/m{m}/n64xppn{ppn}",
            rep.flush_time * 1e6,
            f"{rep.flush_bw / 1e9:.1f}GBps",
            m_leaders=m, flush_bw=rep.flush_bw,
            network_gib=rep.network_bytes / GiB,
        )
    return rows


def main() -> None:
    run().emit()


if __name__ == "__main__":
    main()
