"""Codec-phase bench: the blocking cost of ``save()`` *with compression
on* — chunk-framed parallel codec vs the seed whole-blob path.

PR 3's save bench covered codec ``none``; this one measures exactly
where the paper's PFS-pressure argument is strongest, when every rank
blob is compressed before it is planned and flushed:

* ``reference`` — the seed path, preserved verbatim (``zero_copy=False,
  parallel_local=False``): per-leaf ``tobytes`` + join recopy, then one
  single-threaded whole-blob compressor call per rank, sequential CRC +
  L1 writes, one fsync per rank file.
* ``fast`` — the chunk-framed twin (``zero_copy=True,
  parallel_local=True``): leaves serialize straight into one buffer,
  each rank's chunks compress on the manager's worker pool with
  per-thread compressor reuse, L1 writes fuse into the encode tasks,
  fsyncs batch per node directory.

Row kinds in the emitted JSON:

* ``codec_save`` — reference/fast pairs per geometry (fast rows carry
  ``speedup``); ``stored_ratio`` = stored/raw bytes.
* ``delta_dirty`` — chunked ``zstd+delta`` save time and stored ratio
  as a function of the fraction of the state mutated since the base:
  unchanged chunks store zero bytes (base references), so small-update
  steps shrink toward the differential-checkpointing ideal.
* ``partial_restore_compressed`` — ``restore_leaves`` of one small leaf
  out of a chunk-framed compressed checkpoint: bytes actually read vs
  total stored (whole-blob framing would read every covering blob).

The committed ``BENCH_codec.json`` extends the bench trajectory
(planner → restore → save → codec); ``tools/bench_check.py`` gates its
schema and the ≥3x acceptance bar at the largest geometry in CI.

Usage::

    PYTHONPATH=src python benchmarks/codec_phase.py                # full sweep
    PYTHONPATH=src python benchmarks/codec_phase.py --quick        # CI smoke
    PYTHONPATH=src python benchmarks/codec_phase.py --out BENCH_codec.json
"""
from __future__ import annotations

import argparse
import json
import sys
import tempfile
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import (
    CheckpointConfig,
    CheckpointManager,
    default_codec_impl,
    theta_like,
)
from repro.core.serialize import CHUNK_BASE

MiB = 1 << 20

# (nodes, ppn, state MiB, repeats).  The last geometry is the paper-style
# shape — many ranks per node, small per-rank blobs — and the acceptance
# geometry for the >=3x bar with codec zstd.
FULL_CONFIGS: List[Tuple[int, int, int, int]] = [
    (4, 2, 64, 3),
    (8, 4, 128, 3),
    (64, 16, 128, 5),
]
QUICK_CONFIGS: List[Tuple[int, int, int, int]] = [
    (2, 2, 16, 2),
]

DIRTY_FRACS = [0.0, 0.01, 0.1, 0.5]


def make_state(total_bytes: int, n_leaves: int = 8) -> Dict[str, np.ndarray]:
    """A float32 pytree shaped like a real train state.

    3/4 dense standard-normal leaves (weights + first moments:
    high-entropy mantissas, effectively incompressible — the chunk
    probe stores them raw) and 1/4 90%-sparse second-moment-style
    leaves (~6x compressible).  This is the mix the chunk-framed codec
    is built for: the whole-blob reference burns its blocking window
    compressing the dense leaves for a few percent, while the chunked
    path probes them, stores them raw, and spends compression only
    where it pays.
    """
    rng = np.random.default_rng(0)
    per = total_bytes // n_leaves // 4
    n_dense = (3 * n_leaves) // 4
    out: Dict[str, np.ndarray] = {}
    for i in range(n_leaves):
        if i < n_dense:
            out[f"w_{i:02d}"] = rng.standard_normal(per).astype(np.float32)
        else:
            out[f"m_{i:02d}"] = np.where(
                rng.random(per) < 0.9, 0.0, rng.standard_normal(per)
            ).astype(np.float32)
    return out


def bench_save_path(
    root: str, nodes: int, ppn: int, state, repeats: int, *, fast: bool,
    codec: str = "zstd",
) -> Dict[str, float]:
    mgr = CheckpointManager(
        CheckpointConfig(
            root=root, cluster=theta_like(nodes, ppn),
            strategy="stripe_aligned", codec=codec,
            parallel_local=fast, zero_copy=fast,
        )
    )
    save_s: List[float] = []
    try:
        for step in range(1, repeats + 1):
            t0 = time.perf_counter()
            st = mgr.save(step, state)
            save_s.append(time.perf_counter() - t0)
            mgr.wait()  # drain the async flush so repeats don't backpressure
            assert not mgr.flush_errors, mgr.flush_errors
        best = int(np.argmin(save_s))
        return {
            "save_s": round(min(save_s), 4),
            "encode_s": round(mgr.stats[best].encode_time, 4),
            "local_s": round(mgr.stats[best].local_time, 4),
            "stored_ratio": round(st.stored_bytes / st.raw_bytes, 4),
        }
    finally:
        mgr.close()


def bench_codec_save(
    nodes: int, ppn: int, state_mib: int, repeats: int, *, verbose: bool,
) -> List[Dict[str, object]]:
    state = make_state(state_mib * MiB)
    timings: Dict[str, Dict[str, float]] = {}
    for path in ("reference", "fast"):
        with tempfile.TemporaryDirectory() as root:
            timings[path] = bench_save_path(
                root, nodes, ppn, state, repeats, fast=(path == "fast")
            )
    rows: List[Dict[str, object]] = []
    for path in ("reference", "fast"):
        row: Dict[str, object] = {
            "config": f"{nodes}x{ppn}/{state_mib}MiB/zstd",
            "kind": "codec_save",
            "nodes": nodes,
            "ppn": ppn,
            "n_ranks": nodes * ppn,
            "strategy": "stripe_aligned",
            "codec": "zstd",
            "impl": default_codec_impl(),
            "state_bytes": state_mib * MiB,
            "path": path,
            **timings[path],
        }
        if path == "fast":
            row["speedup"] = round(
                timings["reference"]["save_s"] / timings["fast"]["save_s"], 2
            )
        rows.append(row)
        if verbose:
            extra = f"  speedup={row['speedup']:5.2f}x" if path == "fast" else ""
            print(
                f"{row['config']:>28} {path:>9}  save={row['save_s']:7.3f}s  "
                f"encode={row['encode_s']:7.3f}s  local={row['local_s']:7.3f}s  "
                f"ratio={row['stored_ratio']:.3f}{extra}",
                flush=True,
            )
    return rows


def bench_delta_dirty(
    nodes: int, ppn: int, state_mib: int, *, verbose: bool,
) -> List[Dict[str, object]]:
    """Chunked zstd+delta: save cost / stored bytes vs dirty fraction."""
    state = make_state(state_mib * MiB)
    rows: List[Dict[str, object]] = []
    for frac in DIRTY_FRACS:
        with tempfile.TemporaryDirectory() as root:
            mgr = CheckpointManager(
                CheckpointConfig(
                    root=root, cluster=theta_like(nodes, ppn),
                    strategy="stripe_aligned", codec="zstd+delta",
                    delta_every=8,
                )
            )
            try:
                st1 = mgr.save(1, state)
                mgr.wait()
                # dirty a contiguous `frac` of the state (leaf by leaf
                # until the budget is spent): the differential-ideal
                # workload where most chunks stay byte-identical
                mutated = {k: v.copy() for k, v in state.items()}
                rng = np.random.default_rng(1)
                budget = int(sum(len(v) for v in state.values()) * frac)
                for v in mutated.values():
                    if budget <= 0:
                        break
                    k = min(len(v), budget)
                    v[:k] += rng.standard_normal(k).astype(np.float32)
                    budget -= k
                t0 = time.perf_counter()
                st2 = mgr.save(2, mutated)
                dt = time.perf_counter() - t0
                mgr.wait()
                assert not mgr.flush_errors, mgr.flush_errors
                man = mgr._manifest_pfs(2)
                assert man.base_step == 1
                base_frac = float(
                    ((man.chunks.flags & CHUNK_BASE) != 0).mean()
                )
                row = {
                    "config": f"{nodes}x{ppn}/{state_mib}MiB/zstd+delta",
                    "kind": "delta_dirty",
                    "nodes": nodes,
                    "ppn": ppn,
                    "n_ranks": nodes * ppn,
                    "state_bytes": state_mib * MiB,
                    "dirty_frac": frac,
                    "save_s": round(dt, 4),
                    "stored_ratio": round(st2.stored_bytes / max(1, st1.stored_bytes), 4),
                    "base_ref_frac": round(base_frac, 4),
                }
                rows.append(row)
                if verbose:
                    print(
                        f"{row['config']:>28} dirty={frac:5.2f}  "
                        f"save={row['save_s']:7.3f}s  "
                        f"stored={row['stored_ratio']:6.3f}x of full  "
                        f"base_ref={row['base_ref_frac']:5.1%}",
                        flush=True,
                    )
            finally:
                mgr.close()
    return rows


def bench_partial_restore(
    nodes: int, ppn: int, state_mib: int, *, verbose: bool,
) -> List[Dict[str, object]]:
    """restore_leaves of one small leaf out of a chunked zstd checkpoint."""
    state = make_state(state_mib * MiB)
    state["probe"] = np.arange(1024, dtype=np.float32)   # the serving leaf
    with tempfile.TemporaryDirectory() as root:
        mgr = CheckpointManager(
            CheckpointConfig(
                root=root, cluster=theta_like(nodes, ppn),
                strategy="stripe_aligned", codec="zstd",
            )
        )
        try:
            st = mgr.save(1, state)
            mgr.wait()
            assert not mgr.flush_errors, mgr.flush_errors
            mgr._l0 = None                     # force the PFS path
            t0 = time.perf_counter()
            _, got = mgr.restore_leaves(["['probe']"])
            dt = time.perf_counter() - t0
            np.testing.assert_array_equal(got["['probe']"], state["probe"])
            rr = mgr.last_read_result
            row = {
                "config": f"{nodes}x{ppn}/{state_mib}MiB/zstd",
                "kind": "partial_restore_compressed",
                "nodes": nodes,
                "ppn": ppn,
                "n_ranks": nodes * ppn,
                "state_bytes": len(state) and st.raw_bytes,
                "restore_s": round(dt, 4),
                "bytes_read": int(rr.bytes_read),
                "stored_total": int(st.stored_bytes),
                "read_frac": round(rr.bytes_read / max(1, st.stored_bytes), 6),
            }
            if verbose:
                print(
                    f"{row['config']:>28} partial  restore={row['restore_s']:7.3f}s  "
                    f"read {row['bytes_read']/1e3:.1f} kB of "
                    f"{row['stored_total']/1e6:.1f} MB stored "
                    f"({row['read_frac']:.2%})",
                    flush=True,
                )
            return [row]
        finally:
            mgr.close()


def run(
    configs: List[Tuple[int, int, int, int]], *, quick: bool, verbose: bool = True,
) -> List[Dict[str, object]]:
    rows: List[Dict[str, object]] = []
    for nodes, ppn, mib, repeats in configs:
        rows.extend(bench_codec_save(nodes, ppn, mib, repeats, verbose=verbose))
    d_nodes, d_ppn, d_mib = (2, 2, 8) if quick else (8, 4, 64)
    rows.extend(bench_delta_dirty(d_nodes, d_ppn, d_mib, verbose=verbose))
    rows.extend(bench_partial_restore(d_nodes, d_ppn, d_mib, verbose=verbose))
    return rows


def main(argv: Optional[List[str]] = None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--quick", action="store_true", help="CI smoke configs")
    p.add_argument("--out", help="write JSON rows to this path")
    args = p.parse_args(argv)

    configs = QUICK_CONFIGS if args.quick else FULL_CONFIGS
    rows = run(configs, quick=args.quick)
    doc = {
        "benchmark": "codec_phase",
        "quick": bool(args.quick),
        "impl": default_codec_impl(),
        "rows": rows,
    }
    text = json.dumps(doc, indent=2)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
        print(f"wrote {args.out}")
    else:
        json.dump(doc, sys.stdout, indent=2)
        print()


if __name__ == "__main__":
    main()
