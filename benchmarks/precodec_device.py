"""Device pre-codec bench: blocking-window time with staging overlap,
dirty-sweep stored-byte parity, and restore equivalence per strategy.

The tentpole claim: with ``device_precodec=True`` the pre-codec work
(int8 quantize, serialization into the logical stream, XOR delta vs the
previous step, per-chunk dirty detection + digests) runs as ONE fused
device pass during the *next train step*, and ``save()`` only consumes
the staged host buffers.  The host path pays all of it inside the
blocking window.  Rows:

* ``precodec_save`` — host/device pairs per geometry (codec
  ``zstd+delta``, precodec ``int8``, 5% of the state mutated per step).
  ``save_s`` is the blocking window; device rows carry ``stage_s`` (the
  off-path staging cost hidden behind compute), ``speedup`` =
  host ``save_s`` / device ``save_s``, and ``overlap_frac`` = the
  fraction of total checkpoint work (stage + save) off the blocking
  path.  The acceptance bar is ``speedup >= 2`` at the largest
  geometry (64x16 = 1024 ranks).
* ``dirty_parity`` — stored bytes of the device delta path vs the host
  ``zstd+delta`` path across a dirty-fraction sweep.  The device mask
  comes from the fused kernel, the host mask from ``np.array_equal``
  scans; both managers run with ``chunk_aligned_split`` so the chunk
  grids match and the bar (parity within 1%) measures the masks, not
  rank-boundary tail chunks.
* ``restore_equivalence`` — one row per aggregation strategy: a device
  checkpoint chain (anchor + delta) restores byte-identically
  (post-dequantize exact) to its host-path twin.

Timings run kernels in interpret mode on CPU; the staging cost is
inflated (the fused pass interprets tile-by-tile), but it is off the
blocking path by construction, so ``save_s`` — the measured claim —
compares the same host-side codec work on both paths.

Usage::

    PYTHONPATH=src python benchmarks/precodec_device.py                # full
    PYTHONPATH=src python benchmarks/precodec_device.py --quick        # CI smoke
    PYTHONPATH=src python benchmarks/precodec_device.py --out BENCH_precodec.json
"""
from __future__ import annotations

import argparse
import json
import sys
import tempfile
import time
from typing import Dict, List, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import CheckpointConfig, CheckpointManager, theta_like

MiB = 1 << 20

# (nodes, ppn, state MiB, chunk bytes, repeats).  The last geometry is
# the acceptance one: 64x16 = 1024 ranks.  Chunk sizes keep the fused
# pass at <= 512 grid steps so interpret-mode staging stays bounded.
FULL_CONFIGS: List[Tuple[int, int, int, int, int]] = [
    (4, 2, 8, 16 * 1024, 3),
    (8, 4, 16, 32 * 1024, 3),
    (64, 16, 32, 64 * 1024, 3),
]
QUICK_CONFIGS: List[Tuple[int, int, int, int, int]] = [
    (2, 2, 4, 16 * 1024, 2),
]

DIRTY_FRACS = [0.01, 0.05, 0.2, 0.5]
STRATEGIES = ["file_per_process", "posix", "mpiio", "stripe_aligned", "gio_sync"]
DIRTY_FRAC = 0.05  # per-step mutation for the save rows


def make_state(total_bytes: int, n_leaves: int = 8) -> Dict[str, jax.Array]:
    """float32 train-state mix: 3/4 dense weights, 1/4 sparse moments."""
    rng = np.random.default_rng(0)
    per = total_bytes // n_leaves // 4
    out: Dict[str, jax.Array] = {}
    for i in range(n_leaves):
        a = rng.standard_normal(per).astype(np.float32)
        if i >= (3 * n_leaves) // 4:
            a *= rng.random(per) < 0.1
            out[f"m_{i:02d}"] = jnp.asarray(a)
        else:
            out[f"w_{i:02d}"] = jnp.asarray(a)
    return out


def mutate(state: Dict[str, jax.Array], frac: float, seed: int) -> Dict[str, jax.Array]:
    """Dirty a leading `frac` of the state, leaf by leaf."""
    rng = np.random.default_rng(seed)
    out = dict(state)
    budget = int(sum(v.size for v in state.values()) * frac)
    for k, v in state.items():
        if budget <= 0:
            break
        n = min(v.size, budget)
        a = np.asarray(v).reshape(-1).copy()
        a[:n] += rng.standard_normal(n).astype(np.float32)
        out[k] = jnp.asarray(a.reshape(v.shape))
        budget -= n
    return out


def _mgr(root: str, nodes: int, ppn: int, chunk: int, *, device: bool,
         strategy: str = "stripe_aligned",
         aligned_split: bool = False) -> CheckpointManager:
    return CheckpointManager(CheckpointConfig(
        root=root, cluster=theta_like(nodes, ppn), strategy=strategy,
        codec="zstd+delta", chunk_size=chunk, precodec="int8",
        device_precodec=device, chunk_aligned_split=aligned_split,
        delta_every=8, parallel_local=True, zero_copy=True,
    ))


def bench_save(nodes: int, ppn: int, mib: int, chunk: int, repeats: int,
               *, verbose: bool) -> List[Dict[str, object]]:
    state = make_state(mib * MiB)
    timings: Dict[str, Dict[str, float]] = {}
    for path in ("host", "device"):
        device = path == "device"
        with tempfile.TemporaryDirectory() as root:
            mgr = _mgr(root, nodes, ppn, chunk, device=device)
            try:
                if device:
                    # anchor stage runs during "step 0 compute"
                    mgr.stage(1, state)
                    mgr._staged.future.result()
                mgr.save(1, state)
                mgr.wait()
                save_s: List[float] = []
                for step in range(2, repeats + 2):
                    s = mutate(state, DIRTY_FRAC, step)
                    if device:
                        # the overlap contract: staging kicked off at the
                        # top of the train step, finished before save()
                        mgr.stage(step, s)
                        mgr._staged.future.result()
                    t0 = time.perf_counter()
                    st = mgr.save(step, s)
                    save_s.append(time.perf_counter() - t0)
                    mgr.wait()
                    assert not mgr.flush_errors, mgr.flush_errors
                timings[path] = {
                    "save_s": round(min(save_s), 4),
                    "stage_s": round(mgr.stats[-1].stage_s, 4),
                    "stored_ratio": round(st.stored_bytes / st.raw_bytes, 4),
                }
            finally:
                mgr.close()
    rows: List[Dict[str, object]] = []
    for path in ("host", "device"):
        row: Dict[str, object] = {
            "config": f"{nodes}x{ppn}/{mib}MiB/int8+zstd+delta",
            "kind": "precodec_save",
            "nodes": nodes,
            "ppn": ppn,
            "n_ranks": nodes * ppn,
            "precodec": "int8",
            "state_bytes": mib * MiB,
            "chunk_bytes": chunk,
            "dirty_frac": DIRTY_FRAC,
            "path": path,
            **timings[path],
        }
        if path == "device":
            total = timings["device"]["stage_s"] + timings["device"]["save_s"]
            row["speedup"] = round(
                timings["host"]["save_s"] / timings["device"]["save_s"], 2
            )
            row["overlap_frac"] = round(timings["device"]["stage_s"] / total, 4)
        rows.append(row)
        if verbose:
            extra = (
                f"  speedup={row['speedup']:5.2f}x overlap={row['overlap_frac']:.1%}"
                if path == "device" else ""
            )
            print(
                f"{row['config']:>30} {path:>6}  save={row['save_s']:7.3f}s  "
                f"stage={row['stage_s']:7.3f}s{extra}", flush=True,
            )
    return rows


def bench_dirty_parity(nodes: int, ppn: int, mib: int, chunk: int,
                       *, verbose: bool) -> List[Dict[str, object]]:
    state = make_state(mib * MiB)
    rows: List[Dict[str, object]] = []
    for frac in DIRTY_FRACS:
        stored: Dict[str, int] = {}
        mutated = mutate(state, frac, 7)
        for path in ("host", "device"):
            with tempfile.TemporaryDirectory() as root:
                # chunk-aligned host split: both paths see the same
                # global chunk grid, so stored bytes compare like for like
                mgr = _mgr(root, nodes, ppn, chunk, device=(path == "device"),
                           aligned_split=True)
                try:
                    mgr.save(1, state)
                    mgr.wait()
                    st = mgr.save(2, mutated)
                    mgr.wait()
                    assert not mgr.flush_errors, mgr.flush_errors
                    assert mgr._manifest_pfs(2).base_step == 1
                    stored[path] = int(st.stored_bytes)
                finally:
                    mgr.close()
        rel_err = abs(stored["device"] - stored["host"]) / max(1, stored["host"])
        row = {
            "config": f"{nodes}x{ppn}/{mib}MiB/int8+zstd+delta",
            "kind": "dirty_parity",
            "n_ranks": nodes * ppn,
            "state_bytes": mib * MiB,
            "dirty_frac": frac,
            "host_stored": stored["host"],
            "device_stored": stored["device"],
            "rel_err": round(rel_err, 6),
        }
        rows.append(row)
        if verbose:
            print(
                f"{row['config']:>30} dirty={frac:5.2f}  "
                f"host={stored['host']/1e6:8.2f}MB  "
                f"device={stored['device']/1e6:8.2f}MB  "
                f"rel_err={rel_err:.4%}", flush=True,
            )
    return rows


def bench_restore_equivalence(mib: int, chunk: int,
                              *, verbose: bool) -> List[Dict[str, object]]:
    state = make_state(mib * MiB, n_leaves=4)
    s2 = mutate(state, 0.1, 3)
    rows: List[Dict[str, object]] = []
    for strategy in STRATEGIES:
        restored: Dict[str, object] = {}
        t_restore = 0.0
        for path in ("host", "device"):
            with tempfile.TemporaryDirectory() as root:
                mgr = _mgr(root, 2, 2, chunk, device=(path == "device"),
                           strategy=strategy)
                try:
                    mgr.save(1, state)
                    mgr.save(2, s2)  # delta step
                    mgr.wait()
                    assert not mgr.flush_errors, mgr.flush_errors
                    mgr._l0 = None  # force the decode path
                    tgt = jax.tree_util.tree_map(
                        lambda l: np.zeros(l.shape, l.dtype), state
                    )
                    t0 = time.perf_counter()
                    _, out = mgr.restore(tgt, 2)
                    t_restore = time.perf_counter() - t0
                    restored[path] = out
                finally:
                    mgr.close()
        identical = all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(
                jax.tree_util.tree_leaves(restored["host"]),
                jax.tree_util.tree_leaves(restored["device"]),
            )
        )
        row = {
            "config": f"2x2/{mib}MiB/int8+zstd+delta",
            "kind": "restore_equivalence",
            "strategy": strategy,
            "state_bytes": mib * MiB,
            "restore_s": round(t_restore, 4),
            "byte_identical": bool(identical),
        }
        rows.append(row)
        if verbose:
            print(
                f"{row['config']:>30} {strategy:>17}  "
                f"restore={t_restore:6.3f}s  identical={identical}", flush=True,
            )
    return rows


def run(configs: List[Tuple[int, int, int, int, int]], *, quick: bool,
        verbose: bool = True) -> List[Dict[str, object]]:
    rows: List[Dict[str, object]] = []
    for nodes, ppn, mib, chunk, repeats in configs:
        rows.extend(bench_save(nodes, ppn, mib, chunk, repeats, verbose=verbose))
    p_nodes, p_ppn, p_mib, p_chunk = (2, 2, 4, 16 * 1024) if quick \
        else (8, 4, 16, 32 * 1024)
    rows.extend(bench_dirty_parity(p_nodes, p_ppn, p_mib, p_chunk,
                                   verbose=verbose))
    rows.extend(bench_restore_equivalence(4 if quick else 8, 16 * 1024,
                                          verbose=verbose))
    return rows


def main(argv: Optional[List[str]] = None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--quick", action="store_true", help="CI smoke configs")
    p.add_argument("--out", help="write JSON rows to this path")
    args = p.parse_args(argv)

    configs = QUICK_CONFIGS if args.quick else FULL_CONFIGS
    rows = run(configs, quick=args.quick)
    doc = {
        "benchmark": "precodec_device",
        "quick": bool(args.quick),
        "rows": rows,
    }
    text = json.dumps(doc, indent=2)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
        print(f"wrote {args.out}")
    else:
        json.dump(doc, sys.stdout, indent=2)
        print()


if __name__ == "__main__":
    main()
