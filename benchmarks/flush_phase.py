"""Paper Figure 2: asynchronous flush phase throughput to the PFS.

Increasing processes per node, 1 GiB per rank.  The paper's observed
ordering — file-per-process above both naive aggregations (POSIX hurt by
extent-lock false sharing, MPI-IO by barrier rounds + gather traffic) —
plus our full implementation of the paper's §3 proposal, which closes
the gap (and surpasses file-per-process once the metadata storm counts).
Higher is better.
"""
from __future__ import annotations

from benchmarks.common import Rows
from benchmarks.local_phase import STRATS, GiB
from repro.core import make_plan, simulate_flush, theta_like
from repro.core.plan import count_false_sharing


def run(nodes: int = 64, ppn_list=(1, 2, 4, 8, 16), io_threads: int = 4) -> Rows:
    rows = Rows("flush_phase")
    for ppn in ppn_list:
        cluster = theta_like(nodes, ppn)
        sizes = [GiB] * cluster.world_size
        for strat, kw in STRATS:
            plan = make_plan(strat, cluster, sizes, **kw)
            rep = simulate_flush(plan, io_threads=io_threads)
            fs = count_false_sharing(plan) if strat == "posix" else {}
            rows.add(
                f"fig2/flush/{strat}/n{nodes}xppn{ppn}",
                rep.flush_time * 1e6,
                f"{rep.flush_bw / 1e9:.1f}GBps",
                nodes=nodes, ppn=ppn, strategy=strat,
                flush_bw=rep.flush_bw, flush_time=rep.flush_time,
                pfs_lock_eff=rep.pfs_lock_eff, n_files=rep.n_files,
                metadata_ops=rep.metadata_ops, network_gib=rep.network_bytes / GiB,
                app_slowdown=rep.app_slowdown, **fs,
            )
    return rows


def main() -> None:
    run().emit()


if __name__ == "__main__":
    main()
