"""VELOC's "very low overhead" claim on the *real* engine.

Trains a smoke model and checkpoints every step through the actual
multi-level engine (real files, real async flush threads), comparing
blocking time (local phase) against step compute, per strategy and
codec.  This is functional end-to-end evidence, not the simulator.
"""
from __future__ import annotations

import tempfile
import time

import jax
import numpy as np

from benchmarks.common import Rows
from repro.configs import get_smoke_config
from repro.core import CheckpointConfig, CheckpointManager, theta_like
from repro.data import DataConfig, SyntheticTokens
from repro.launch.mesh import make_host_mesh
from repro.models import get_model
from repro.train import OptConfig, TrainConfig, init_train_state, make_train_step


def run(steps: int = 8) -> Rows:
    rows = Rows("overhead")
    cfg = get_smoke_config("qwen1.5-0.5b")
    model = get_model(cfg)
    mesh = make_host_mesh()
    data = SyntheticTokens(
        DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8)
    )
    tcfg = TrainConfig(opt=OptConfig(total_steps=steps))
    batch_struct = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), data.peek(0)
    )
    step_fn, _, _ = make_train_step(model, tcfg, mesh, batch_struct)
    state = init_train_state(model, jax.random.PRNGKey(0), tcfg)
    state, _ = step_fn(state, data.next())  # compile

    for strat, codec in [
        ("file_per_process", "none"),
        ("posix", "none"),
        ("stripe_aligned", "none"),
        ("stripe_aligned", "zstd"),
        ("stripe_aligned", "zstd+delta"),
    ]:
        with tempfile.TemporaryDirectory() as root:
            mgr = CheckpointManager(
                CheckpointConfig(
                    root=root, cluster=theta_like(4, 2), strategy=strat,
                    codec=codec, io_threads=2,
                )
            )
            t_compute, t_block = 0.0, 0.0
            for i in range(steps):
                t0 = time.perf_counter()
                state, _ = step_fn(state, data.next())
                jax.block_until_ready(jax.tree_util.tree_leaves(state)[0])
                t_compute += time.perf_counter() - t0
                st = mgr.save(i, state)
                t_block += st.local_time + st.encode_time
            mgr.wait()
            assert not mgr.flush_errors, mgr.flush_errors
            flushes = [s.flush for s in mgr.stats if s.flush]
            flush_avg = sum(f.duration for f in flushes) / max(1, len(flushes))
            stored = mgr.stats[-1].stored_bytes
            mgr.close()
            rows.add(
                f"overhead/{strat}/{codec}",
                t_block / steps * 1e6,
                f"blk{100 * t_block / max(t_compute, 1e-9):.1f}pct",
                strategy=strat, codec=codec,
                block_ms_per_save=t_block / steps * 1e3,
                step_ms=t_compute / steps * 1e3,
                flush_ms=flush_avg * 1e3,
                stored_mb=stored / 1e6,
            )
    return rows


def main() -> None:
    run().emit()


if __name__ == "__main__":
    main()
