"""The Tseng-et-al. trade-off (paper §2): I/O threads vs interference.

More flush threads per active backend drain the node faster but steal
CPU/network from the application.  Sweeps io_threads and the
application's NIC load; reports (flush duration, app slowdown) pairs —
the frontier the co-design argument is about.
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import Rows
from repro.core import make_plan, simulate_flush, theta_like

GiB = 1 << 30


def run(nodes: int = 64, ppn: int = 8) -> Rows:
    rows = Rows("interference")
    for app_net in (0.0, 0.5):
        cluster = theta_like(nodes, ppn)
        cluster = cluster.with_(
            node=dataclasses.replace(cluster.node, app_net_load=app_net)
        )
        sizes = [GiB] * cluster.world_size
        for strat, kw in [
            ("file_per_process", {}),
            ("stripe_aligned", {"pipeline_chunk": 256 << 20}),
            ("mpiio", {"chunk_stripes": 64}),
        ]:
            for io_threads in (1, 2, 4, 8):
                plan = make_plan(strat, cluster, sizes, **kw)
                rep = simulate_flush(plan, io_threads=io_threads)
                rows.add(
                    f"interf/{strat}/net{app_net}/t{io_threads}",
                    rep.flush_time * 1e6,
                    f"slowdown{rep.app_slowdown:.3f}",
                    strategy=strat, io_threads=io_threads,
                    app_net_load=app_net, flush_time=rep.flush_time,
                    flush_bw=rep.flush_bw, app_slowdown=rep.app_slowdown,
                )
    return rows


def main() -> None:
    run().emit()


if __name__ == "__main__":
    main()
