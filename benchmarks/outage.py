"""Degraded-mode availability sweep: PFS outages and straggler readers
vs the circuit-breaker runtime (ISSUE 8 acceptance harness).

Two scenario kinds:

* ``outage_survival`` — one row per aggregation strategy.  A total PFS
  outage covers the whole save phase; the acceptance bars are that **no
  ``save()`` fails and no retry budget gives up** (the circuit opens
  and flushes park at ``flush_partial`` instead), and that after the
  outage heals the parked backlog **auto-drains byte-identically**
  (verified from the PFS copy alone — L0 forgotten, L1 dropped).
* ``hedged_restore`` — repeated restores against one straggler reader
  node, hedged vs unhedged.  The bar is that the hedged p99 beats the
  unhedged p99: the hedge re-issues slowed extents from L1 so the
  restore tail is bounded by the healthy medium, not the straggler.

Any violation is recorded per row (``violations``) and fails the
sweep's exit code; the committed ``BENCH_outage.json`` is the CI-gated
record (``python tools/bench_check.py``).

Usage::

    PYTHONPATH=src python benchmarks/outage.py                  # full sweep
    PYTHONPATH=src python benchmarks/outage.py --quick          # CI smoke
    PYTHONPATH=src python benchmarks/outage.py --out BENCH_outage.json
"""
from __future__ import annotations

import argparse
import json
import sys
import tempfile
import time
from pathlib import Path
from typing import Any, Dict, List

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import (  # noqa: E402
    CheckpointConfig,
    CheckpointManager,
    FaultPlan,
    FaultSpec,
    theta_like,
)

ALL_STRATEGIES = ["file_per_process", "posix", "mpiio", "stripe_aligned", "gio_sync"]
N_STEPS = 3
DRAIN_TIMEOUT_S = 60.0
STRAGGLER_DELAY_S = 0.12
FULL_TRIALS = 8
QUICK_TRIALS = 4


def ref_state(step: int) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(step * 7919 + 5)
    return {
        "w": rng.standard_normal((2048, 4)).astype(np.float32),
        "b": np.full((64,), step, np.float32),
        "c": rng.integers(0, 255, (4096,), dtype=np.uint8),
    }


def trees_equal(a: Dict, b: Dict) -> bool:
    return set(a) == set(b) and all(
        np.array_equal(np.asarray(a[k]), np.asarray(b[k])) for k in a
    )


def base_cfg(root: str, **kw: Any) -> CheckpointConfig:
    kw.setdefault("cluster", theta_like(2, 2))
    kw.setdefault("async_flush", False)
    kw.setdefault("retry_attempts", 5)
    kw.setdefault("retry_base_delay", 0.002)
    kw.setdefault("retry_max_delay", 0.02)
    kw.setdefault("health_min_ops", 2)
    kw.setdefault("health_cooldown", 0.05)
    return CheckpointConfig(root=root, **kw)


def run_outage_survival(strategy: str, *, root: str) -> Dict[str, Any]:
    """Total PFS outage across every save; heal; drain; verify."""
    row: Dict[str, Any] = {
        "kind": "outage_survival",
        "config": f"outage[{strategy}]",
        "strategy": strategy,
        "n_steps": N_STEPS,
        "violations": [],
    }
    violations: List[str] = row["violations"]
    faults = FaultPlan(
        [FaultSpec(kind="outage", domain="pfs", op="write", index=0, count=10**9)]
    )
    t0 = time.perf_counter()
    mgr = CheckpointManager(
        base_cfg(str(Path(root) / "ckpt"), strategy=strategy), faults=faults
    )
    try:
        faults.arm("save")
        saves_failed = 0
        for s in range(1, N_STEPS + 1):
            try:
                mgr.save(s, ref_state(s))
            except Exception as e:
                saves_failed += 1
                violations.append(f"save({s}) raised during outage: {e!r}")
        h = mgr.health()
        row["saves_failed"] = saves_failed
        row["parked_steps"] = len(h.parked_steps)
        row["mode_during_outage"] = h.mode
        row["giveups"] = mgr.retry.giveups
        row["flush_errors"] = len(mgr.flush_errors)
        if mgr.retry.giveups:
            violations.append(
                f"{mgr.retry.giveups} retry giveups during outage "
                "(the circuit must open first)"
            )
        if mgr.flush_errors:
            violations.append(f"flush_errors during outage: {mgr.flush_errors}")
        if h.mode != "degraded" or len(h.parked_steps) != N_STEPS:
            violations.append(
                f"expected {N_STEPS} parked steps in degraded mode, got "
                f"{h.parked_steps} in mode {h.mode!r}"
            )
        # ---- heal and drain ----
        faults.heal()
        faults.disarm()
        deadline = time.monotonic() + DRAIN_TIMEOUT_S
        while mgr.health().parked_steps and time.monotonic() < deadline:
            mgr.health_check()
            time.sleep(0.01)
        drained = (
            not mgr.health().parked_steps
            and mgr.steps("pfs") == list(range(1, N_STEPS + 1))
            and not mgr.flush_errors
        )
        row["drained"] = drained
        row["drained_steps"] = mgr.health().drained_steps
        if not drained:
            violations.append(
                f"drain incomplete: pfs={mgr.steps('pfs')} "
                f"parked={mgr.health().parked_steps} "
                f"errors={mgr.flush_errors}"
            )
    finally:
        mgr.close()
    # ---- byte-identical from the PFS copy alone ----
    identical = True
    m2 = CheckpointManager(base_cfg(str(Path(root) / "ckpt"), strategy=strategy))
    try:
        m2._l0 = None
        m2._last_full = None
        m2.local.drop_node(0)
        m2.local.drop_node(1)
        for s in range(1, N_STEPS + 1):
            try:
                got, tree = m2.restore(ref_state(s), step=s)
            except Exception as e:
                identical = False
                violations.append(f"step {s}: post-drain restore raised {e!r}")
                continue
            if got != s or not trees_equal(tree, ref_state(s)):
                identical = False
                violations.append(f"step {s}: post-drain restore not identical")
    finally:
        m2.close()
    row["byte_identical"] = identical
    row["elapsed_s"] = round(time.perf_counter() - t0, 4)
    return row


def run_hedged_restore(trials: int, *, root: str) -> Dict[str, Any]:
    """Straggler reader node: unhedged vs hedged restore tail."""
    row: Dict[str, Any] = {
        "kind": "hedged_restore",
        "config": f"hedge[posix,{trials}x]",
        "trials": trials,
        "straggler_delay_s": STRAGGLER_DELAY_S,
        "violations": [],
    }
    violations: List[str] = row["violations"]
    ckpt_root = str(Path(root) / "ckpt")
    writer = CheckpointManager(base_cfg(ckpt_root, strategy="posix"))
    try:
        writer.save(1, ref_state(1))
    finally:
        writer.close()

    def trial_times(hedged: bool) -> List[float]:
        faults = FaultPlan(
            [FaultSpec(kind="straggler", domain="pfs", op="read", node=1,
                       delay=STRAGGLER_DELAY_S, phase="verify")]
        )
        mgr = CheckpointManager(
            base_cfg(
                ckpt_root, strategy="posix",
                hedged_reads=hedged, hedge_min_delay=0.01,
            ),
            faults=faults,
        )
        times: List[float] = []
        issued = wins = 0
        try:
            faults.arm("verify")
            for _ in range(trials):
                mgr._l0 = None
                mgr._last_full = None
                t0 = time.perf_counter()
                got, tree = mgr.restore(ref_state(1), step=1)
                times.append(time.perf_counter() - t0)
                if got != 1 or not trees_equal(tree, ref_state(1)):
                    violations.append(
                        f"{'hedged' if hedged else 'unhedged'} restore "
                        "not byte-identical"
                    )
                # accumulate per trial: once straggler demotion shifts
                # the assignment off the slow reader, later trials may
                # legitimately need no hedges at all
                rr = mgr.last_read_result
                if rr is not None:
                    issued += rr.hedges_issued
                    wins += rr.hedge_wins
            if hedged:
                row["hedges_issued"] = issued
                row["hedge_wins"] = wins
        finally:
            mgr.close()
        return times

    def p99(times: List[float]) -> float:
        arr = sorted(times)
        return arr[min(len(arr) - 1, int(0.99 * len(arr)))]

    t_plain = trial_times(hedged=False)
    t_hedge = trial_times(hedged=True)
    row["unhedged_p99_s"] = round(p99(t_plain), 4)
    row["hedged_p99_s"] = round(p99(t_hedge), 4)
    row["unhedged_mean_s"] = round(float(np.mean(t_plain)), 4)
    row["hedged_mean_s"] = round(float(np.mean(t_hedge)), 4)
    row["speedup_p99"] = round(
        row["unhedged_p99_s"] / max(row["hedged_p99_s"], 1e-9), 2
    )
    row["byte_identical"] = not any("identical" in v for v in violations)
    if row["hedged_p99_s"] >= row["unhedged_p99_s"]:
        violations.append(
            f"hedged p99 {row['hedged_p99_s']}s did not beat unhedged "
            f"p99 {row['unhedged_p99_s']}s"
        )
    if not row.get("hedge_wins"):
        violations.append("no hedge ever won the race against the straggler")
    return row


def summarize(rows: List[Dict[str, Any]], quick: bool) -> Dict[str, Any]:
    surv = [r for r in rows if r["kind"] == "outage_survival"]
    hedge = [r for r in rows if r["kind"] == "hedged_restore"]
    return {
        "kind": "outage_summary",
        "n_rows": len(rows),
        "n_violations": sum(len(r["violations"]) for r in rows),
        "zero_failed_saves": all(r["saves_failed"] == 0 for r in surv),
        "zero_giveups": all(r["giveups"] == 0 for r in surv),
        "all_drained": all(r["drained"] for r in surv),
        "all_byte_identical": all(r["byte_identical"] for r in rows),
        "strategies_covered": sorted({r["strategy"] for r in surv}),
        "unhedged_p99_s": max((r["unhedged_p99_s"] for r in hedge), default=0.0),
        "hedged_p99_s": max((r["hedged_p99_s"] for r in hedge), default=0.0),
        "hedged_beats_unhedged": all(
            r["hedged_p99_s"] < r["unhedged_p99_s"] for r in hedge
        ) and bool(hedge),
        "quick": quick,
    }


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="CI smoke (fewer trials)")
    ap.add_argument("--trials", type=int, default=None, help="hedge trials override")
    ap.add_argument("--out", type=str, default=None, help="write BENCH json here")
    args = ap.parse_args()
    trials = args.trials or (QUICK_TRIALS if args.quick else FULL_TRIALS)
    rows: List[Dict[str, Any]] = []
    with tempfile.TemporaryDirectory(prefix="outage_") as workdir:
        for i, strategy in enumerate(ALL_STRATEGIES):
            row = run_outage_survival(
                strategy, root=str(Path(workdir) / f"surv_{strategy}")
            )
            rows.append(row)
            flag = "" if not row["violations"] else "  VIOLATION"
            print(
                f"[{i + 1}/{len(ALL_STRATEGIES) + 1}] {row['config']:<28s}"
                f" parked={row['parked_steps']} giveups={row['giveups']}"
                f" drained={row['drained']}"
                f" identical={row['byte_identical']}{flag}"
            )
        row = run_hedged_restore(trials, root=str(Path(workdir) / "hedge"))
        rows.append(row)
        flag = "" if not row["violations"] else "  VIOLATION"
        print(
            f"[{len(ALL_STRATEGIES) + 1}/{len(ALL_STRATEGIES) + 1}]"
            f" {row['config']:<28s} p99 unhedged={row['unhedged_p99_s']}s"
            f" hedged={row['hedged_p99_s']}s"
            f" wins={row.get('hedge_wins', 0)}{flag}"
        )
    summary = summarize(rows, args.quick)
    rows.append(summary)
    print(json.dumps(summary, indent=1))
    if args.out:
        doc = {"benchmark": "outage", "quick": args.quick, "rows": rows}
        Path(args.out).write_text(json.dumps(doc, indent=1) + "\n")
        print(f"wrote {args.out}")
    if summary["n_violations"]:
        for r in rows:
            for v in r.get("violations", []):
                print(f"outage: {r.get('config', '?')}: {v}", file=sys.stderr)
        return 1
    print(f"outage: OK ({len(rows) - 1} rows, zero violations)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
