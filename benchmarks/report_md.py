"""Generate EXPERIMENTS.md from the report JSONs.

    PYTHONPATH=src python -m benchmarks.report_md > EXPERIMENTS.md

Narrative sections are embedded here; tables regenerate from
reports/dryrun*/ and reports/bench/.
"""
from __future__ import annotations

import json
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
DRY = ROOT / "reports" / "dryrun"
DRY_BASE = ROOT / "reports" / "dryrun_baseline"
BENCH = ROOT / "reports" / "bench"

ARCH_ORDER = [
    "xlstm-350m", "qwen2-72b", "llama3-405b", "qwen1.5-0.5b", "tinyllama-1.1b",
    "llava-next-mistral-7b", "qwen2-moe-a2.7b", "llama4-scout-17b-a16e",
    "recurrentgemma-2b", "whisper-small",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _load(d: Path):
    out = {}
    for p in sorted(d.glob("*.json")):
        r = json.loads(p.read_text())
        out[(r["arch"], r["shape"], r["mesh"])] = r
    return out


def _g(x, *keys, default=None):
    for k in keys:
        if x is None:
            return default
        x = x.get(k)
    return x if x is not None else default


def dryrun_table(recs, mesh):
    lines = [
        "| arch | shape | status | compile (s) | bytes/device | HLO flops/dev | collectives/dev |",
        "|---|---|---|---|---|---|---|",
    ]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get((a, s, mesh))
            if r is None:
                continue
            if r["status"] == "skip":
                lines.append(f"| {a} | {s} | SKIP (full attention @500k) | — | — | — | — |")
                continue
            if r["status"] != "ok":
                lines.append(f"| {a} | {s} | **ERROR** | — | — | — | — |")
                continue
            mem = r["memory"]["per_device_bytes"] / 2**30
            fl = _g(r, "roofline", "flops_per_dev", default=0) / 1e12
            co = _g(r, "roofline", "coll_bytes_per_dev", default=0) / 2**30
            lines.append(
                f"| {a} | {s} | OK | {r['compile_s']:.1f} | {mem:.2f} GiB "
                f"| {fl:.1f} T | {co:.1f} GiB |"
            )
    return "\n".join(lines)


def roofline_table(recs, mesh="pod16x16"):
    lines = [
        "| arch | shape | compute (s) | memory (s) | collective (s) | dominant "
        "| MODEL_FLOPS/HLO | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get((a, s, mesh))
            if r is None or r["status"] != "ok":
                continue
            rf = r["roofline"]
            lines.append(
                f"| {a} | {s} | {rf['compute_s']:.3f} | {rf['memory_s']:.3f} "
                f"| {rf['collective_s']:.3f} | {rf['dominant']} "
                f"| {rf['useful_flops_ratio']:.2f} | {rf['roofline_fraction']:.3f} |"
            )
    return "\n".join(lines)


def bench_table(name, key_metric, fmt, group=None):
    p = BENCH / f"{name}.json"
    if not p.exists():
        return f"*(run `python -m benchmarks.run --only {name}` to regenerate)*"
    rows = json.loads(p.read_text())
    lines = ["| case | " + key_metric + " |", "|---|---|"]
    for r in rows:
        if group and group not in r["name"]:
            continue
        lines.append(f"| {r['name']} | {fmt(r)} |")
    return "\n".join(lines)


def fig_tables():
    out = []
    for fname, metric in [("local_phase", "local_bw"), ("flush_phase", "flush_bw")]:
        p = BENCH / f"{fname}.json"
        if not p.exists():
            out.append(f"*(run `python -m benchmarks.run` to regenerate {fname})*")
            continue
        rows = json.loads(p.read_text())
        ppns = sorted({r["ppn"] for r in rows})
        strats = []
        for r in rows:
            if r["strategy"] not in strats:
                strats.append(r["strategy"])
        head = "| strategy | " + " | ".join(f"ppn={p_}" for p_ in ppns) + " |"
        sep = "|---" * (len(ppns) + 1) + "|"
        lines = [head, sep]
        for st in strats:
            vals = []
            for p_ in ppns:
                v = next(
                    (r[metric] for r in rows if r["strategy"] == st and r["ppn"] == p_),
                    None,
                )
                vals.append(f"{v/1e9:.1f}" if v else "—")
            lines.append(f"| {st} | " + " | ".join(vals) + " |")
        title = (
            "**Figure 1 — local phase throughput (GB/s), 64 nodes, 1 GiB/rank**"
            if fname == "local_phase"
            else "**Figure 2 — async flush throughput (GB/s), 64 nodes, 1 GiB/rank**"
        )
        out.append(title + "\n\n" + "\n".join(lines))
    return "\n\n".join(out)


def perf_delta_table():
    base = _load(DRY_BASE) if DRY_BASE.exists() else {}
    opt = _load(DRY)
    cells = [
        ("recurrentgemma-2b", "prefill_32k"),
        ("llama4-scout-17b-a16e", "train_4k"),
        ("llama3-405b", "train_4k"),
        ("xlstm-350m", "prefill_32k"),
        ("whisper-small", "prefill_32k"),
        ("qwen2-72b", "train_4k"),
    ]
    lines = [
        "| cell | metric | baseline | optimized | delta |",
        "|---|---|---|---|---|",
    ]
    for a, s in cells:
        b = base.get((a, s, "pod16x16"))
        o = opt.get((a, s, "pod16x16"))
        if not (b and o and b["status"] == "ok" and o["status"] == "ok"):
            continue
        for metric, get, unit in [
            ("collective term", lambda r: r["roofline"]["collective_s"], "s"),
            ("compute term", lambda r: r["roofline"]["compute_s"], "s"),
            ("bytes/device", lambda r: r["memory"]["per_device_bytes"] / 2**30, "GiB"),
            ("roofline frac", lambda r: r["roofline"]["roofline_fraction"], ""),
        ]:
            vb, vo = get(b), get(o)
            if vb == 0:
                continue
            lines.append(
                f"| {a} / {s} | {metric} | {vb:.3f}{unit} | {vo:.3f}{unit} "
                f"| {vo/vb:.2f}x |"
            )
    return "\n".join(lines)


def main() -> None:
    sp = _load(DRY)
    n_ok_sp = sum(1 for r in sp.values() if r["mesh"] == "pod16x16" and r["status"] == "ok")
    n_ok_mp = sum(1 for r in sp.values() if r["mesh"] == "pod2x16x16" and r["status"] == "ok")
    n_skip = sum(1 for r in sp.values() if r["status"] == "skip") // 2

    print(TEMPLATE_HEAD.format(n_ok_sp=n_ok_sp, n_ok_mp=n_ok_mp, n_skip=n_skip))
    print(fig_tables())
    print(TEMPLATE_CKPT_PERF)
    print("## §Dry-run — single pod 16x16 (256 chips)\n")
    print(dryrun_table(sp, "pod16x16"))
    print("\n## §Dry-run — multi-pod 2x16x16 (512 chips)\n")
    print(dryrun_table(sp, "pod2x16x16"))
    print(TEMPLATE_ROOFLINE_INTRO)
    print(roofline_table(sp))
    print(TEMPLATE_PERF_HEAD)
    print(perf_delta_table())
    print(TEMPLATE_PERF_LOG)


TEMPLATE_HEAD = """# EXPERIMENTS

Reproduction + extension of *Towards Aggregated Asynchronous
Checkpointing* (SuperCheck-SC21) as a production-grade JAX framework.
All numbers regenerate via:

    PYTHONPATH=src python -m pytest tests/            # correctness
    PYTHONPATH=src python -m benchmarks.run           # paper figures
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
    PYTHONPATH=src python -m benchmarks.report_md > EXPERIMENTS.md

**Dry-run status: {n_ok_sp}/32 single-pod cells compile OK, {n_ok_mp}/32
multi-pod cells compile OK, {n_skip} cells skipped by design
(`long_500k` on quadratic-attention archs) — 80/80 accounted for.**

## §Calibration — the simulated testbed

The discrete-event simulator (`repro.core.sim`) models Theta-like
hardware: 48 Lustre OSTs x 4.5 GB/s (216 GB/s aggregate PFS), 1 MiB
stripes, one bounded-throughput metadata server (12k ops/s, 0.8 ms
latency), 8 GB/s/node NIC, 16 GB/s node-local in-memory tier, 3 GB/s
single-client stream ceiling, 0.5 ms extent-lock revocation penalty.
Constants were fixed once against the paper's *qualitative* results and
never tuned per-strategy:

* Fig. 1 — VELOC local phase is ~10x GIO-direct (paper: "orders of magnitude");
* Fig. 2 — POSIX aggregation lands ~3x below file-per-process (false
  sharing), MPI-IO ~1.6x below (barrier rounds + gather), both matching
  the paper's ordering;
* the §3 proposal then lands within ~5% of file-per-process *without*
  per-strategy retuning — i.e. the win is structural, not fitted.

## §Paper-claims validation
"""

TEMPLATE_CKPT_PERF = """
Claims checklist (asserted in `tests/test_sim.py`):

| paper claim | result |
|---|---|
| Fig 1: aggregation leaves the local phase unchanged (prefix sum ~free) | local bw within 5% across VELOC strategies |
| Fig 1: GIO writes directly to PFS, orders of magnitude slower locally | ~10-15x slower local phase |
| Fig 2: POSIX aggregation collapses from false sharing | ~3.1x below file-per-process; modeled lock efficiency 0.32 |
| Fig 2: MPI-IO collective rounds underperform | ~1.6x below file-per-process |
| §3: dedicated strategy can reach/surpass file-per-process | within 5% at 64 nodes; **surpasses** at 128 nodes (metadata gate) |
| §1: file-per-process melts the metadata server | 16k md ops vs 129 at 8k ranks (see `benchmarks/metadata.py`) |
| §2/Tseng: io_threads trade flush speed vs app slowdown | monotone trade-off reproduced (`benchmarks/interference.py`) |

## §Checkpoint-Perf — hillclimbing the paper's own technique

Setup: 128 nodes x 16 ppn (2048 ranks), 1 GiB/rank, io_threads=4.
Sequence: paper-faithful baseline first, then beyond-paper steps.

| iteration | hypothesis | result | verdict |
|---|---|---|---|
| baseline `file_per_process` | — | 212.0 GB/s flush, 2048 files, 4096 md ops | reference |
| paper-faithful §3 (M=48=#OSTs) | leaders matched to I/O servers suffice | 215.9 GB/s, 1 file, 49 md ops, but 1.28 TB gather traffic | **confirmed** (claim: reach/surpass fpp) |
| iter1: M = #nodes (128) | with uniform sizes, leader regions align with node boundaries => zero gather | 215.9 GB/s, gather 1280 GiB -> 0 | **confirmed** — beyond-paper: M should track #backends, not #OSTs, when PFS-bound |
| iter2: pipeline chunk 256 MiB -> 1 GiB | coarser chunks, same fluid bw | no change (PFS-bound) | confirmed (chunking matters for stealing granularity, not steady-state bw) |
| iter3: 25% nodes at 0.6 load, ragged sizes, election OFF (w=0) | stragglers drag leaders | 164.5 GB/s | baseline for criterion test |
| iter3b: election ON (paper criteria 1+2) | big holders + unloaded nodes lead | **193.3 GB/s (+17.5%)** | **confirmed** — quantifies §3's dynamic election |
| iter3c: fpp under same jitter | no mitigation possible | 205.4 GB/s | finding: under heavy jitter fpp still edges S3 — slow leaders throttle the pipeline |
| iter4: capacity-weighted leader regions (beyond-paper) | loaded leaders should own fewer stripes — zero-communication work-stealing analogue | 196.1 GB/s (+1.4% over iter3b, more gather traffic offsets the relief) | partially confirmed — the residual gap vs fpp is sender-side derating that no aggregation layout removes |
| beyond: zstd flush codec | PFS-bound => volume is the only lever | same bw, **1.7x less volume => 1.7x shorter flush window** | confirmed (real-engine codec, `benchmarks/overhead.py`) |
| beyond: int8+zstd (Pallas kernel) | 4-5x volume cut, bounded error | same bw, **5x shorter flush window**, lossy tier | confirmed |

The engine-level (real files, real threads) counterpart in
`benchmarks/overhead.py` shows blocking cost per save = local phase only
(~10 ms for smoke states), with the flush fully overlapped.
"""

TEMPLATE_ROOFLINE_INTRO = """
## §Roofline — single-pod (256 x v5e), per (arch x shape)

Hardware model: 197 bf16 TFLOP/s, 819 GB/s HBM, 50 GB/s/link ICI.
Sources: trip-count-corrected HLO analysis (`repro.launch.hlo_analysis`)
for FLOPs + collective bytes (XLA's `cost_analysis` counts scan bodies
once — corrected by recovered while-loop trip counts; validated against
nested-scan ground truth in `tests/test_hlo_analysis.py`); the memory
term uses the analytic HBM floor (`analytic_hbm_bytes`) because the
CPU-backend fusion granularity makes measured traffic pessimistic.
`MODEL_FLOPS/HLO` = 6·N·D (train) or 2·N·D (inference) over measured
HLO flops — the remat/redundancy waste factor.  `roofline frac` =
(MODEL_FLOPS/peak) / max(term): useful-compute fraction of the machine
at the modeled bound, assuming perfect compute/collective overlap.

Notes on structural bottlenecks (see §Perf for what was done):

* every train cell is **collective-dominated**: FSDP weight all-gathers
  repeat per microbatch x per pass; the knob is microbatch count (bounded
  by activation memory, which sequence-parallel residuals relax);
* decode cells show frac ~0 by construction (2·N·B useful flops against
  weight gathers) — serving wants dp-replicated weights, which don't fit
  405B on 256 v5e; the (2,128) serve-mesh experiment made it *worse*
  (refuted hypothesis, logged below);
* llama3-405b / llama4 / recurrentgemma train exceed 16 GB/device on the
  single pod — documented deficits: 405B at 256 chips is a deliberate
  stress cell (production would use 4-16x more chips; the multi-pod mesh
  already halves per-device state to 24.4 GiB), and recurrentgemma's
  python-loop layer structure (mixed block kinds prevent layer-stacking)
  defeats cross-layer buffer reuse in the CPU backend's assignment —
  chunking the RG-LRU associative scan did *not* move it (refuted,
  §Perf);
* the collective term prices every byte at the 50 GB/s ICI link rate;
  on the 2x16x16 mesh the FSDP gathers also span the `pod` axis, whose
  DCN links are ~8x slower — multi-pod fractions are therefore
  optimistic upper bounds for pod-crossing traffic (the fix at scale is
  pod-local FSDP + cross-pod gradient all-reduce only, which the mesh
  layout supports by moving weight sharding off the `pod` axis).
"""

TEMPLATE_PERF_HEAD = """
## §Perf — model-cell hillclimbing (baseline -> optimized)

Three cells selected per the rules: worst roofline fraction
(recurrentgemma-2b prefill_32k, 0.003), most collective-bound
(llama4-scout train_4k, 25.8s collective vs 10.6s compute), most
representative of where the paper's checkpointing matters
(llama3-405b train_4k).  Fixes that generalized were applied to the
whole zoo (xlstm/whisper prefill, qwen2-72b).
"""

TEMPLATE_PERF_LOG = """
### Iteration log (hypothesis -> change -> before -> after -> verdict)

1. **Activation sharding through remat+scan** — *hypothesis*: GSPMD drops
   batch sharding across `jax.checkpoint` + `lax.scan` boundaries,
   replicating compute.  *Change*: `shard_act` constraints at every
   block boundary (batch over dp, wide dims over tp).  tinyllama train:
   flops/dev 225T -> 43T (ideal 27T), temp 57 -> 10 GiB. **Confirmed.**
2. **Vocab-sharded embedding gather** — *hypothesis*: gather output
   resharding miscompiles / bloats (XLA CPU partitioner bug: "slice dim
   size > dynamic slice dimension").  *Change*: embedding table vocab
   over TP, d replicated (gather lowers to mask+all-reduce). Compile
   succeeds everywhere. **Confirmed** (workaround documented in
   `sharding.py`).
3. **Sequence-parallel residual stream** — *hypothesis*: the scan-saved
   per-layer activation stack ((126,1,4096,16384) bf16 = 15.75 GiB at
   405B) dominates train memory.  *Change*: carry constrained to
   P(dp, tp, None).  llama3-405b temp 62 -> 21 GiB. **Confirmed.**
4. **MoE dispatch scatter replicates batch** — *hypothesis*: flat
   advanced-indexing scatter loses the batch dim (llama4 prefill 63 GiB
   temps, 2.2 TB collectives).  *Change*: vmapped per-sequence
   scatter/gather (iota batch dims partition as parallel dims).
   llama4 prefill temp 63 -> 9.4 GiB, collectives 2166 -> 245 GiB.
   **Confirmed.**
5. **Head padding for TP** — *hypothesis*: 40 heads on 16-way TP
   replicate all attention compute (5x flop inflation).  *Change*: pad
   heads to the next TP multiple, slice padded outputs. llama4 train
   compute 10.6 -> 3.25s. **Confirmed** (also applied to gemma-10H,
   whisper-12H).
6. **Parallel prefill for recurrent archs** — *hypothesis*: token-scan
   prefill issues per-token weight gathers (recurrentgemma prefill:
   47.4s collective term, the worst cell).  *Change*: single forward
   pass + closed-form/chunkwise state extraction (RG-LRU associative
   scan; chunkwise mLSTM whose carry IS the decode state; teacher-forced
   whisper prefill).  recurrentgemma collective 47.4 -> 1.3s; whisper
   prefill mem 135 -> 1.2 GiB. **Confirmed.**
7. **Banded window attention** — *hypothesis*: dense 32k x 32k scores
   with a 2048 mask waste ~10x compute/collectives.  *Change*: per-chunk
   dynamic K/V band slice.  recurrentgemma train collective 6.3 -> 1.6s,
   frac 0.058 -> 0.221. **Confirmed.**
8. **Fewer microbatches = fewer FSDP re-gathers** — *hypothesis*:
   all-gather bytes scale ~linearly with microbatch count; memory rises
   (bounded thanks to #3).  llama3-405b k=16 -> 4: collective 284 ->
   148s, frac 0.178 -> 0.342.  llama4 k=8 -> 2: 26.2 -> 17.5s, frac
   0.083 -> 0.123. **Confirmed** (k=4/k=2 chosen; memory documented).
9. **(2,128) serve mesh for 405B decode** — *hypothesis*: more TP +
   dp-replication kills decode weight gathers.  *Result*: collective
   6.7 -> 43s (tiny-dim TP all-reduces dominate). **Refuted** — kept the
   (16,16) mesh; 405B decode on 256 v5e stays weight-gather-bound, noted
   as a machine-size constraint rather than a sharding fix.
10. **hd-sharded attention for indivisible heads** — *hypothesis*:
    sharding head_dim recovers TP for 10/12-head archs.  *Result*:
    psum of every score chunk (~2.4 TB/step at 32k). **Refuted** —
    superseded by head padding (#5).
11. **Gold-logit gather in the loss** — *hypothesis*: `take_along_axis`
    over the TP-sharded vocab all-gathers the logits every microbatch
    (suspected dominant for small-model/big-vocab train cells).
    *Change*: mask+reduce gold logit.  *Result*: collective bytes
    unchanged to 3 decimals — GSPMD already lowered the gather without
    an all-gather. **Refuted** (kept the mask form: it is no worse and
    removes the risk on other backends).
12a. **Chunked RG-LRU scan for train memory** — *hypothesis*: the
    full-sequence f32 gate tensors (~10 x (B,S,dr) live per layer, per
    the buffer dump) drive recurrentgemma train's 39 GiB temps.
    Three variants measured: (i) chunking only the associative scan —
    no change (coeffs still full-sequence); (ii) fusing coefficient
    computation into the chunk scan — memory 39.6 -> 34.2 GiB but
    collectives 1.72 -> 2.21s (frac 0.209 -> 0.164: per-chunk boundary
    re-gathers); (iii) hoisting the gate-weight gathers out of the scan
    — no further change.  **Net: refuted as a frac improvement** — the
    full parallel scan stays default for seq <= 8k (best frac), the
    fused-chunk form engages beyond 8k where its O(chunk) memory is the
    only viable shape; the residual 39 GiB is the python-loop block
    structure (26 distinct HLO bodies defeat cross-layer buffer reuse).
12. **Sequence-parallel carry hurts narrow models** — *hypothesis*:
    after #11's refutation, the per-layer seq re-gathers implied by the
    SP carry (134 MB x L x 3 passes x k) are themselves the dominant
    collective for d_model < 4096 — their activation stacks were small
    anyway.  *Change*: SP carry only when d_model >= 4096.  qwen1.5-0.5b
    collective 1.24 -> 0.43s (frac 0.062 -> **0.180**), tinyllama 2.47
    -> 1.00s (0.056 -> **0.137**), qwen2-moe 11.2 -> 6.8s; big models
    untouched; memory grows but stays under HBM (tinyllama 4.7 -> 10.2
    GiB). **Confirmed.**

Stopping criterion: the last three candidate changes on the three target
cells each projected <5% on the dominant term (further microbatch
reduction OOMs; collective overlap is already granted by the max-term
bound; remaining all-gathers are the irreducible FSDP weight traffic at
this chip count).

## §Beyond-paper extensions (summary)

* Full working implementation of the paper's §3 *proposal* (it was a
  sketch), incl. deterministic piggy-backed leader election with all
  three criteria, validated by property tests and priced at scale.
* M=#backends leader rule (beats the paper's implied M=#OSTs when
  PFS-bound: zero gather traffic at uniform sizes).
* Lossless (zstd) + lossy (Pallas int8) + incremental (XOR-delta) flush
  codecs: 1.7-5x flush-window reduction on top of any strategy.
* Multi-level redundancy: L0 twin, L1 + partner replication, L2
  aggregated; crash/corruption fallback chain tested end-to-end.
* Elastic restart: checkpoints are mesh/geometry-agnostic (save on 4x2,
  restore on 3x1 — bit-exact, tested).
* Device-side integrity checksums (TPU-adapted two-track Fletcher via
  Pallas) over every rank blob.
* Bounded flush pipeline (`max_pending_flushes` backpressure) +
  `validate(step)` cold-checkpoint scrubbing (per-rank CRC audit on every
  level).
* Model-side: sequence-parallel residuals, chunkwise mLSTM, banded local
  attention, TP head padding, vmapped MoE dispatch — none of which the
  paper needed, all of which the 40-cell matrix did.
"""


if __name__ == "__main__":
    main()
