"""Planner-scale sweep: plan build / validate / simulate wall times.

The paper's headline experiments run at Theta scale (thousands of nodes
x 32 ranks/node).  This benchmark times the three planner layers —
``make_plan`` (which validates internally), an explicit
``validate_plan`` pass, and ``simulate_flush`` — at paper-adjacent
scales, and emits JSON rows so the perf trajectory of the columnar
planner is recorded in-repo (``BENCH_planner.json``).

Usage::

    PYTHONPATH=src python benchmarks/planner_scale.py                # full sweep
    PYTHONPATH=src python benchmarks/planner_scale.py --quick        # CI smoke
    PYTHONPATH=src python benchmarks/planner_scale.py --only 256x16  # one scale
    PYTHONPATH=src python benchmarks/planner_scale.py --out BENCH_planner.json
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import make_plan, simulate_flush, theta_like
from repro.core.plan import validate_plan

GiB = 1 << 30

# (nodes, ppn, strategy, strategy kwargs)
FULL_CONFIGS: List[Tuple[int, int, str, Dict[str, object]]] = [
    (256, 16, "stripe_aligned", {"pipeline_chunk": 256 << 20}),
    (256, 16, "mpiio", {"chunk_stripes": 64}),
    (1024, 32, "stripe_aligned", {"pipeline_chunk": 1 << 30}),
    (1024, 32, "mpiio", {"chunk_stripes": 256}),
]
QUICK_CONFIGS: List[Tuple[int, int, str, Dict[str, object]]] = [
    (16, 8, "stripe_aligned", {"pipeline_chunk": 64 << 20}),
    (16, 8, "mpiio", {"chunk_stripes": 16}),
    (16, 8, "posix", {}),
]


def bench_one(
    nodes: int, ppn: int, strategy: str, kw: Dict[str, object], *,
    io_threads: int = 4,
) -> Dict[str, object]:
    cluster = theta_like(nodes, ppn)
    rng = np.random.default_rng(0)
    # heterogeneous checkpoint sizes (0.5-1.5 GiB) + 20% loaded nodes,
    # matching benchmarks/proposal_scale.py
    sizes = rng.integers(GiB // 2, 3 * GiB // 2, cluster.world_size).tolist()
    load = np.where(rng.random(nodes) < 0.2, 0.5, 0.0).tolist()
    cluster = cluster.with_(node_load=load)

    t0 = time.perf_counter()
    plan = make_plan(strategy, cluster, sizes, **kw)
    t1 = time.perf_counter()
    validate_plan(plan)
    t2 = time.perf_counter()
    rep = simulate_flush(plan, io_threads=io_threads)
    t3 = time.perf_counter()

    arrays = getattr(plan, "arrays", None)  # absent on the pre-columnar seed
    n_writes = arrays.n_writes if arrays is not None else len(plan.writes)
    n_sends = arrays.n_sends if arrays is not None else len(plan.sends)
    return {
        "config": f"{nodes}x{ppn}/{strategy}",
        "nodes": nodes,
        "ppn": ppn,
        "n_ranks": cluster.world_size,
        "strategy": strategy,
        "strategy_kwargs": {k: int(v) if isinstance(v, int) else v for k, v in kw.items()},
        "build_s": round(t1 - t0, 4),
        "validate_s": round(t2 - t1, 4),
        "simulate_s": round(t3 - t2, 4),
        "total_s": round(t3 - t0, 4),
        "n_writes": int(n_writes),
        "n_sends": int(n_sends),
        "sim_flush_time_s": round(rep.flush_time, 4),
        "sim_flush_bw_GBps": round(rep.flush_bw / 1e9, 2),
    }


def run(
    configs: List[Tuple[int, int, str, Dict[str, object]]],
    *, only: Optional[str] = None, verbose: bool = True,
) -> List[Dict[str, object]]:
    rows = []
    for nodes, ppn, strategy, kw in configs:
        if only and only not in (f"{nodes}x{ppn}", f"{nodes}x{ppn}/{strategy}"):
            continue
        row = bench_one(nodes, ppn, strategy, kw)
        rows.append(row)
        if verbose:
            print(
                f"{row['config']:>32}  build={row['build_s']:8.3f}s  "
                f"validate={row['validate_s']:8.3f}s  "
                f"simulate={row['simulate_s']:8.3f}s  "
                f"writes={row['n_writes']}",
                flush=True,
            )
    return rows


def main(argv: Optional[List[str]] = None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--quick", action="store_true", help="CI smoke configs")
    p.add_argument("--only", help="restrict to one scale, e.g. 256x16")
    p.add_argument("--out", help="write JSON rows to this path")
    args = p.parse_args(argv)

    configs = QUICK_CONFIGS if args.quick else FULL_CONFIGS
    rows = run(configs, only=args.only)
    doc = {"benchmark": "planner_scale", "quick": bool(args.quick), "rows": rows}
    text = json.dumps(doc, indent=2)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
        print(f"wrote {args.out}")
    else:
        json.dump(doc, sys.stdout, indent=2)
        print()


if __name__ == "__main__":
    main()
