"""The §1 motivation: metadata pressure of N files vs 1 aggregated file.

Reports metadata ops and MDS drain time per checkpoint at increasing
rank counts — the regime where one-file-per-process melts the metadata
server while aggregation stays flat.
"""
from __future__ import annotations

from benchmarks.common import Rows
from repro.core import make_plan, theta_like
from repro.core.sim import metadata_schedule

GiB = 1 << 30


def run(ppn: int = 16, node_list=(64, 128, 256, 512)) -> Rows:
    rows = Rows("metadata")
    for nodes in node_list:
        cluster = theta_like(nodes, ppn)
        sizes = [GiB] * cluster.world_size
        for strat, kw in [
            ("file_per_process", {}),
            ("stripe_aligned", {"pipeline_chunk": 1 << 30}),
        ]:
            plan = make_plan(strat, cluster, sizes, **kw)
            sched = metadata_schedule(plan)
            drain = max(sched.values(), default=0.0)
            rows.add(
                f"metadata/{strat}/ranks{cluster.world_size}",
                drain * 1e6,
                f"{plan.metadata_ops()}ops_{plan.n_files}files",
                nodes=nodes, ppn=ppn, strategy=strat,
                metadata_ops=plan.metadata_ops(), n_files=plan.n_files,
                mds_drain_s=drain,
            )
    return rows


def main() -> None:
    run().emit()


if __name__ == "__main__":
    main()
