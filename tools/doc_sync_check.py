#!/usr/bin/env python
"""Docs↔code sync checker (CI gate; stdlib + the package itself).

Every backtick-quoted dotted ``repro.*`` reference in README.md,
EXPERIMENTS.md, ROADMAP.md and docs/*.md must actually resolve: the longest
importable module prefix is imported and the remaining parts are
resolved with ``getattr`` (classes, functions, methods, dataclass
attributes).  Docs that name a module, class or function the code no
longer has fail CI — prose cannot silently drift from the API again.

Usage::

    PYTHONPATH=src python tools/doc_sync_check.py [FILES...]
    # default: README.md, EXPERIMENTS.md, docs/*.md
"""
from __future__ import annotations

import importlib
import re
import sys
from pathlib import Path

# `repro.x.y.Z` / `repro.x.y.Z()` inside backticks; trailing call parens
# and a trailing dot (sentence punctuation inside the backticks) are
# tolerated and stripped.
TOKEN_RE = re.compile(r"`(repro(?:\.[A-Za-z_][A-Za-z0-9_]*)+)(?:\(\))?\.?`")

DEFAULT_FILES = ["README.md", "EXPERIMENTS.md", "ROADMAP.md"]
DEFAULT_GLOBS = ["docs/*.md"]


def resolve(token: str) -> bool:
    parts = token.split(".")
    for i in range(len(parts), 0, -1):
        try:
            obj = importlib.import_module(".".join(parts[:i]))
        except ImportError:
            continue
        for attr in parts[i:]:
            if not hasattr(obj, attr):
                return False
            obj = getattr(obj, attr)
        return True
    return False


def check_file(path: Path) -> list:
    errors = []
    seen = set()
    for m in TOKEN_RE.finditer(path.read_text(encoding="utf-8")):
        token = m.group(1)
        if token in seen:
            continue
        seen.add(token)
        if not resolve(token):
            errors.append(f"{path}: `{token}` does not resolve via import")
    return errors


def main(argv) -> int:
    root = Path(__file__).resolve().parents[1]
    src = root / "src"
    if src.is_dir() and str(src) not in sys.path:
        sys.path.insert(0, str(src))
    if argv:
        files = [Path(a) for a in argv]
    else:
        files = [root / f for f in DEFAULT_FILES]
        files += sorted(p for g in DEFAULT_GLOBS for p in root.glob(g))
    errors = []
    checked = 0
    for f in files:
        if f.is_file():
            checked += 1
            errors.extend(check_file(f))
    for e in errors:
        print(e, file=sys.stderr)
    print(
        f"checked {checked} markdown files for repro.* references: "
        f"{'OK' if not errors else f'{len(errors)} drifted reference(s)'}"
    )
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
