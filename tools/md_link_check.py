"""Markdown link checker (stdlib only; CI gate).

Verifies that every relative link / image target in the repo's markdown
files points at a file or directory that exists.  External links
(http/https/mailto) are only syntax-checked, not fetched — CI must not
depend on the network.

Usage::

    python tools/md_link_check.py [FILES...]   # default: README, *.md, docs/
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")

DEFAULT_GLOBS = ["*.md", "docs/*.md"]


def check_file(path: Path, root: Path) -> list:
    errors = []
    text = path.read_text(encoding="utf-8")
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        if target.startswith("#"):  # intra-document anchor
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        resolved = (path.parent / rel).resolve()
        try:
            resolved.relative_to(root.resolve())
        except ValueError:
            errors.append(f"{path}: link escapes the repo: {target}")
            continue
        if not resolved.exists():
            errors.append(f"{path}: broken link: {target}")
    return errors


def main(argv) -> int:
    root = Path(__file__).resolve().parents[1]
    if argv:
        files = [Path(a) for a in argv]
    else:
        files = sorted({p for g in DEFAULT_GLOBS for p in root.glob(g)})
    errors = []
    for f in files:
        if f.is_file():
            errors.extend(check_file(f, root))
    for e in errors:
        print(e, file=sys.stderr)
    print(f"checked {len(files)} markdown files: "
          f"{'OK' if not errors else f'{len(errors)} broken links'}")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
