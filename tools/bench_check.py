#!/usr/bin/env python
"""CI gate for the committed bench trajectory: every ``BENCH_*.json`` at
the repo root must exist, parse, and carry the fields the docs and
regression tracking rely on.  Stdlib only (runs before any install).

Per-file schema (top level: ``benchmark`` string + non-empty ``rows``):

* ``BENCH_planner.json`` — plan build/validate/simulate rows;
* ``BENCH_restore.json`` — read-plan rows + one real elastic restore;
* ``BENCH_save.json``    — save-phase rows in reference/fast pairs; the
  fast row of the largest geometry must record the ISSUE 3 acceptance
  bar, ``speedup >= 3``;
* ``BENCH_codec.json``   — codec-phase rows (compressed saves in
  reference/fast pairs, delta dirty-fraction sweep, compressed partial
  restore); the fast ``codec_save`` row of the largest geometry must
  record the ISSUE 4 acceptance bar, ``speedup >= 3``;
* ``BENCH_flush_runtime.json`` — adaptive flush runtime rows; every
  ``supersession`` row must record the ISSUE 5 bar ``skipped_frac >=
  0.5``, every ``resume`` row ``rewrite_frac < 0.25`` with
  ``byte_identical`` true, and the ``resume`` rows together must cover
  all five aggregation strategies;
* ``BENCH_chaos.json``   — the self-healing chaos sweep (ISSUE 6): a
  full (non-quick) run of >= 100 seeded FaultPlan schedules, every
  ``schedule`` row with ``restored_identical`` true and zero
  ``invariant_violations``, the ``chaos_summary`` row with
  ``repair_success_frac >= 0.95``, all six fault kinds and all five
  strategies covered;
* ``BENCH_serve.json``   — the serving-fleet rows (ISSUE 7): every
  ``ttft`` row must beat the full restore (``ttft_s <
  full_restore_s``) and restore byte-identically, with the largest
  geometry at >= 1024 ranks; every ``cold_start_fleet`` row
  ``byte_identical``; every ``hot_swap`` row with generates on both
  sides of the swap and ``dropped == 0`` / ``torn == 0``;
* ``BENCH_outage.json``  — the degraded-mode sweep (ISSUE 8): a full
  (non-quick) run; every ``outage_survival`` row with zero failed
  saves, zero retry giveups, ``drained`` and ``byte_identical`` true,
  all five strategies covered; the ``hedged_restore`` row
  byte-identical with ``hedged_p99_s < unhedged_p99_s`` and at least
  one hedge win; the ``outage_summary`` row with zero violations;
* ``BENCH_kernel.json``  — kernel micro rows + fused-pass rows; every
  ``fused`` row must record ``speedup >= 1`` over the per-kernel chain;
* ``BENCH_precodec.json`` — device pre-codec rows (ISSUE 9): the
  device ``precodec_save`` row of the largest geometry must record the
  blocking-window bar ``speedup >= 2``; every ``dirty_parity`` row
  stored bytes within 1% of the host delta path; ``restore_equivalence``
  rows identical across all five aggregation strategies;
* ``BENCH_control.json`` — the multi-tenant control-plane replay
  (ISSUE 10): a full (non-quick) trace of >= 100 clients across >= 8
  tenants with zero failed saves and byte-identical restores; the
  equal-weight ``fairness`` row's Jain index >= 0.9; the
  ``utilization`` row >= 0.8x the unarbitrated baseline; the
  ``preemption`` row with >= 1 preemption, the budget never exceeded
  and the victim's parked flush drained; the ``tenant_chaos`` row with
  the non-victim tenant unharmed.

Exit code 0 = all good; 1 = any file missing/malformed (messages on
stderr).  Run as ``python tools/bench_check.py [root]``.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

# benchmark name -> (filename, required row fields common to every row)
EXPECTED = {
    "BENCH_planner.json": (
        "planner_scale",
        {"config", "n_ranks", "strategy", "build_s", "validate_s", "total_s"},
    ),
    "BENCH_restore.json": (
        "restore_scale",
        set(),  # rows are heterogeneous; per-kind fields checked below
    ),
    "BENCH_save.json": (
        "save_phase",
        {"config", "kind", "n_ranks", "state_bytes", "path", "save_s",
         "encode_s", "local_s"},
    ),
    "BENCH_codec.json": (
        "codec_phase",
        set(),  # rows are heterogeneous; per-kind fields checked below
    ),
    "BENCH_flush_runtime.json": (
        "flush_runtime",
        set(),  # rows are heterogeneous; per-kind fields checked below
    ),
    "BENCH_chaos.json": (
        "chaos",
        set(),  # rows are heterogeneous; per-kind fields checked below
    ),
    "BENCH_serve.json": (
        "serve_fleet",
        set(),  # rows are heterogeneous; per-kind fields checked below
    ),
    "BENCH_outage.json": (
        "outage",
        set(),  # rows are heterogeneous; per-kind fields checked below
    ),
    "BENCH_kernel.json": (
        "kernel_bench",
        set(),  # rows are heterogeneous; per-kind fields checked below
    ),
    "BENCH_precodec.json": (
        "precodec_device",
        set(),  # rows are heterogeneous; per-kind fields checked below
    ),
    "BENCH_control.json": (
        "control_plane",
        set(),  # rows are heterogeneous; per-kind fields checked below
    ),
}

RESTORE_KIND_FIELDS = {
    "full_restore": {"invert_s", "build_s", "validate_s", "n_reads"},
    "partial_restore": {"invert_s", "build_s", "validate_s", "n_reads"},
    "real_elastic_restore": {"restore_s", "partial_restore_s"},
}

CODEC_KIND_FIELDS = {
    "codec_save": {"config", "n_ranks", "codec", "state_bytes", "path",
                   "save_s", "encode_s", "local_s", "stored_ratio"},
    "delta_dirty": {"config", "n_ranks", "dirty_frac", "save_s",
                    "stored_ratio", "base_ref_frac"},
    "partial_restore_compressed": {"config", "n_ranks", "restore_s",
                                   "bytes_read", "stored_total", "read_frac"},
}

FLUSH_RUNTIME_KIND_FIELDS = {
    "supersession": {"config", "n_ranks", "n_saves", "stored_total",
                     "flushed_bytes", "skipped_bytes", "skipped_frac",
                     "n_superseded", "newest_flushed"},
    "resume": {"config", "n_ranks", "strategy", "total_bytes",
               "resume_rewritten_bytes", "rewrite_frac", "byte_identical"},
    "throttle": {"config", "n_ranks", "flush_bw_cap", "total_bytes",
                 "real_flush_s", "sim_flush_s"},
}

CHAOS_KIND_FIELDS = {
    "schedule": {"seed", "strategy", "partner_replication", "codec",
                 "fired_kinds", "flush_errors", "quarantined_steps",
                 "restored_identical", "repair_success",
                 "invariant_violations"},
    "chaos_summary": {"n_schedules", "n_violations", "restored_identical",
                      "transient_zero_errors", "repair_success_frac",
                      "kinds_covered", "strategies_covered", "quick"},
}

OUTAGE_KIND_FIELDS = {
    "outage_survival": {"config", "strategy", "n_steps", "saves_failed",
                        "parked_steps", "giveups", "flush_errors", "drained",
                        "byte_identical", "violations"},
    "hedged_restore": {"config", "trials", "straggler_delay_s",
                       "unhedged_p99_s", "hedged_p99_s", "hedges_issued",
                       "hedge_wins", "byte_identical", "violations"},
    "outage_summary": {"n_rows", "n_violations", "zero_failed_saves",
                       "zero_giveups", "all_drained", "all_byte_identical",
                       "strategies_covered", "unhedged_p99_s",
                       "hedged_p99_s", "hedged_beats_unhedged", "quick"},
}

SERVE_KIND_FIELDS = {
    "ttft": {"config", "n_ranks", "serve_readers", "params_bytes",
             "priority_bytes", "full_restore_s", "stream_total_s", "ttft_s",
             "ttft_speedup", "byte_identical"},
    "cold_start_fleet": {"config", "n_ranks", "serve_readers", "n_servers",
                         "fleet_total_s", "ttft_max_s", "ttft_mean_s",
                         "cache_hits", "cache_bytes_saved", "byte_identical"},
    "hot_swap": {"config", "n_generates", "pre_swap_generates",
                 "post_swap_generates", "dropped", "torn", "adopted_step",
                 "swap_latency_s"},
}

KERNEL_KIND_FIELDS = {
    "kernel": {"config", "name", "state_bytes", "time_us"},
    "fused": {"config", "state_bytes", "chunk_bytes", "n_chunks", "fused_s",
              "per_kernel_s", "oracle_s", "speedup"},
}

PRECODEC_KIND_FIELDS = {
    "precodec_save": {"config", "n_ranks", "precodec", "state_bytes",
                      "chunk_bytes", "dirty_frac", "path", "save_s",
                      "stage_s", "stored_ratio"},
    "dirty_parity": {"config", "n_ranks", "state_bytes", "dirty_frac",
                     "host_stored", "device_stored", "rel_err"},
    "restore_equivalence": {"config", "strategy", "state_bytes", "restore_s",
                            "byte_identical"},
}

CONTROL_KIND_FIELDS = {
    "replay": {"n_tenants", "n_clients", "n_saves", "failed_saves",
               "byte_identical", "p50_blocking_save_s",
               "p99_blocking_save_s", "elapsed_s"},
    "fairness": {"n_tenants", "weights", "flush_bw_cap_mbps",
                 "per_tenant_bytes", "per_tenant_mbps", "jain_index"},
    "utilization": {"n_tenants", "total_bytes", "baseline_mbps",
                    "control_mbps", "utilization_frac"},
    "preemption": {"budget", "max_held", "budget_exceeded", "preemptions",
                   "victim_final_status", "byte_identical"},
    "tenant_chaos": {"victim", "other_failed_saves", "other_flush_errors",
                     "other_giveups", "drained", "drain_priority_ok",
                     "byte_identical"},
    "control_summary": {"n_tenants", "n_clients", "failed_saves",
                        "byte_identical", "p99_blocking_save_s",
                        "jain_index", "utilization_frac", "preemptions",
                        "budget_exceeded", "chaos_isolated", "quick"},
}

ALL_STRATEGIES = {
    "file_per_process", "posix", "mpiio", "stripe_aligned", "gio_sync"
}
ALL_FAULT_KINDS = {
    "transient_eio", "enospc", "torn_write", "bit_flip", "stall", "node_crash"
}

SAVE_SPEEDUP_BAR = 3.0
KERNEL_FUSED_BAR = 1.0          # fused pass >= unfused chain (ISSUE 9b)
PRECODEC_SPEEDUP_BAR = 2.0      # device blocking window vs host (ISSUE 9)
PRECODEC_PARITY_BAR = 0.01      # dirty-sweep stored-byte rel_err < this
SUPERSESSION_SKIP_BAR = 0.5     # skipped_frac >= this (ISSUE 5a)
RESUME_REWRITE_BAR = 0.25       # rewrite_frac < this (ISSUE 5b)
CHAOS_MIN_SCHEDULES = 100       # full-sweep size floor (ISSUE 6)
CHAOS_REPAIR_BAR = 0.95         # repair_success_frac >= this (ISSUE 6)
SERVE_MIN_RANKS = 1024          # largest ttft geometry floor (ISSUE 7)
CONTROL_MIN_CLIENTS = 100       # replay trace size floor (ISSUE 10)
CONTROL_MIN_TENANTS = 8         # replay tenant floor (ISSUE 10)
CONTROL_JAIN_BAR = 0.9          # equal-weight fairness floor (ISSUE 10)
CONTROL_UTILIZATION_BAR = 0.8   # arbitrated vs unarbitrated MB/s (ISSUE 10)


def fail(msg: str, errors: list) -> None:
    errors.append(msg)
    print(f"bench_check: {msg}", file=sys.stderr)


def check_file(path: Path, benchmark: str, fields: set, errors: list) -> None:
    if not path.exists():
        return fail(f"{path.name}: missing", errors)
    try:
        doc = json.loads(path.read_text())
    except Exception as e:
        return fail(f"{path.name}: invalid JSON ({e})", errors)
    if doc.get("benchmark") != benchmark:
        return fail(
            f"{path.name}: benchmark={doc.get('benchmark')!r}, "
            f"want {benchmark!r}", errors,
        )
    rows = doc.get("rows")
    if not isinstance(rows, list) or not rows:
        return fail(f"{path.name}: rows must be a non-empty list", errors)
    for i, row in enumerate(rows):
        need = set(fields)
        if benchmark in ("restore_scale", "codec_phase", "flush_runtime",
                         "chaos", "serve_fleet", "outage", "kernel_bench",
                         "precodec_device", "control_plane"):
            kinds = {
                "restore_scale": RESTORE_KIND_FIELDS,
                "codec_phase": CODEC_KIND_FIELDS,
                "flush_runtime": FLUSH_RUNTIME_KIND_FIELDS,
                "chaos": CHAOS_KIND_FIELDS,
                "serve_fleet": SERVE_KIND_FIELDS,
                "outage": OUTAGE_KIND_FIELDS,
                "kernel_bench": KERNEL_KIND_FIELDS,
                "precodec_device": PRECODEC_KIND_FIELDS,
                "control_plane": CONTROL_KIND_FIELDS,
            }[benchmark]
            kind = row.get("kind")
            if kind not in kinds:
                fail(f"{path.name} row {i}: unknown kind {kind!r}", errors)
                continue
            need = kinds[kind]
        missing = need - set(row)
        if missing:
            fail(f"{path.name} row {i}: missing fields {sorted(missing)}", errors)

    if benchmark in ("save_phase", "codec_phase") and not errors:
        fast = [
            r for r in rows
            if r.get("path") == "fast"
            and (benchmark == "save_phase" or r.get("kind") == "codec_save")
        ]
        if not fast:
            return fail(f"{path.name}: no fast-path rows", errors)
        if any("speedup" not in r for r in fast):
            return fail(f"{path.name}: fast rows must carry 'speedup'", errors)
        largest = max(fast, key=lambda r: (r["n_ranks"], r["state_bytes"]))
        if largest["speedup"] < SAVE_SPEEDUP_BAR:
            fail(
                f"{path.name}: largest geometry {largest['config']} speedup "
                f"{largest['speedup']}x < {SAVE_SPEEDUP_BAR}x acceptance bar",
                errors,
            )

    if benchmark == "flush_runtime" and not errors:
        sup = [r for r in rows if r.get("kind") == "supersession"]
        res = [r for r in rows if r.get("kind") == "resume"]
        if not sup:
            fail(f"{path.name}: no supersession rows", errors)
        for r in sup:
            if r["skipped_frac"] < SUPERSESSION_SKIP_BAR:
                fail(
                    f"{path.name}: {r['config']} skipped_frac "
                    f"{r['skipped_frac']} < {SUPERSESSION_SKIP_BAR} bar",
                    errors,
                )
            if not r["newest_flushed"]:
                fail(
                    f"{path.name}: {r['config']} newest step did not reach "
                    "flush_done under supersession", errors,
                )
        for r in res:
            if r["rewrite_frac"] >= RESUME_REWRITE_BAR:
                fail(
                    f"{path.name}: {r['config']} rewrite_frac "
                    f"{r['rewrite_frac']} >= {RESUME_REWRITE_BAR} bar", errors,
                )
            if not r["byte_identical"]:
                fail(
                    f"{path.name}: {r['config']} resumed flush is not "
                    "byte-identical", errors,
                )
        covered = {r["strategy"] for r in res}
        if not ALL_STRATEGIES <= covered:
            fail(
                f"{path.name}: resume rows missing strategies "
                f"{sorted(ALL_STRATEGIES - covered)}", errors,
            )

    if benchmark == "kernel_bench" and not errors:
        check_kernel(path, rows, errors)

    if benchmark == "precodec_device" and not errors:
        check_precodec(path, rows, errors)

    if benchmark == "serve_fleet" and not errors:
        check_serve(path, rows, errors)

    if benchmark == "outage" and not errors:
        check_outage(path, rows, errors)

    if benchmark == "control_plane" and not errors:
        check_control(path, rows, errors)

    if benchmark == "chaos" and not errors:
        sched = [r for r in rows if r.get("kind") == "schedule"]
        summaries = [r for r in rows if r.get("kind") == "chaos_summary"]
        if len(summaries) != 1:
            return fail(
                f"{path.name}: want exactly one chaos_summary row, "
                f"got {len(summaries)}", errors,
            )
        s = summaries[0]
        if s["quick"]:
            fail(f"{path.name}: committed sweep must be a full run, not --quick",
                 errors)
        if s["n_schedules"] < CHAOS_MIN_SCHEDULES or len(sched) < CHAOS_MIN_SCHEDULES:
            fail(
                f"{path.name}: {s['n_schedules']} schedules < "
                f"{CHAOS_MIN_SCHEDULES} floor", errors,
            )
        for r in sched:
            if r["invariant_violations"]:
                fail(
                    f"{path.name}: seed {r.get('seed')} recorded violations "
                    f"{r['invariant_violations']}", errors,
                )
            if not r["restored_identical"]:
                fail(
                    f"{path.name}: seed {r.get('seed')} did not restore "
                    "byte-identically", errors,
                )
        if s["n_violations"] or not s["restored_identical"]:
            fail(f"{path.name}: summary records invariant violations", errors)
        if not s["transient_zero_errors"]:
            fail(
                f"{path.name}: transient-only schedules produced flush "
                "errors", errors,
            )
        if s["repair_success_frac"] < CHAOS_REPAIR_BAR:
            fail(
                f"{path.name}: repair_success_frac "
                f"{s['repair_success_frac']} < {CHAOS_REPAIR_BAR} bar", errors,
            )
        if not ALL_FAULT_KINDS <= set(s["kinds_covered"]):
            fail(
                f"{path.name}: fault kinds not covered: "
                f"{sorted(ALL_FAULT_KINDS - set(s['kinds_covered']))}", errors,
            )
        if not ALL_STRATEGIES <= set(s["strategies_covered"]):
            fail(
                f"{path.name}: strategies not covered: "
                f"{sorted(ALL_STRATEGIES - set(s['strategies_covered']))}",
                errors,
            )


def check_kernel(path: Path, rows: list, errors: list) -> None:
    fused = [r for r in rows if r.get("kind") == "fused"]
    if not fused:
        return fail(f"{path.name}: no fused rows", errors)
    for r in fused:
        if r["speedup"] < KERNEL_FUSED_BAR:
            fail(
                f"{path.name}: {r['config']} fused speedup {r['speedup']}x < "
                f"{KERNEL_FUSED_BAR}x bar (one launch must beat the "
                "per-kernel chain)", errors,
            )


def check_precodec(path: Path, rows: list, errors: list) -> None:
    saves = [r for r in rows if r.get("kind") == "precodec_save"
             and r.get("path") == "device"]
    parity = [r for r in rows if r.get("kind") == "dirty_parity"]
    equiv = [r for r in rows if r.get("kind") == "restore_equivalence"]
    if not saves:
        fail(f"{path.name}: no device precodec_save rows", errors)
    if any("speedup" not in r or "overlap_frac" not in r for r in saves):
        return fail(
            f"{path.name}: device rows must carry 'speedup' + 'overlap_frac'",
            errors,
        )
    if saves:
        largest = max(saves, key=lambda r: (r["n_ranks"], r["state_bytes"]))
        if largest["speedup"] < PRECODEC_SPEEDUP_BAR:
            fail(
                f"{path.name}: largest geometry {largest['config']} blocking-"
                f"window speedup {largest['speedup']}x < "
                f"{PRECODEC_SPEEDUP_BAR}x acceptance bar", errors,
            )
    if not parity:
        fail(f"{path.name}: no dirty_parity rows", errors)
    for r in parity:
        if r["rel_err"] > PRECODEC_PARITY_BAR:
            fail(
                f"{path.name}: dirty={r['dirty_frac']} stored-byte rel_err "
                f"{r['rel_err']} > {PRECODEC_PARITY_BAR} bar", errors,
            )
    for r in equiv:
        if not r["byte_identical"]:
            fail(
                f"{path.name}: {r['strategy']} device restore is not "
                "identical to the host path", errors,
            )
    covered = {r["strategy"] for r in equiv}
    if not ALL_STRATEGIES <= covered:
        fail(
            f"{path.name}: restore_equivalence rows missing strategies "
            f"{sorted(ALL_STRATEGIES - covered)}", errors,
        )


def check_serve(path: Path, rows: list, errors: list) -> None:
    ttft = [r for r in rows if r.get("kind") == "ttft"]
    fleet = [r for r in rows if r.get("kind") == "cold_start_fleet"]
    swap = [r for r in rows if r.get("kind") == "hot_swap"]
    if not ttft:
        fail(f"{path.name}: no ttft rows", errors)
    for r in ttft:
        if r["ttft_s"] >= r["full_restore_s"]:
            fail(
                f"{path.name}: {r['config']} ttft {r['ttft_s']}s did not "
                f"beat full restore {r['full_restore_s']}s", errors,
            )
        if not r["byte_identical"]:
            fail(
                f"{path.name}: {r['config']} streamed restore is not "
                "byte-identical to the full restore", errors,
            )
    if ttft and max(r["n_ranks"] for r in ttft) < SERVE_MIN_RANKS:
        fail(
            f"{path.name}: largest ttft geometry "
            f"{max(r['n_ranks'] for r in ttft)} ranks < {SERVE_MIN_RANKS} "
            "floor", errors,
        )
    for r in fleet:
        if not r["byte_identical"]:
            fail(
                f"{path.name}: {r['config']} fleet replicas are not "
                "byte-identical", errors,
            )
    if not swap:
        fail(f"{path.name}: no hot_swap rows", errors)
    for r in swap:
        if r["dropped"] or r["torn"]:
            fail(
                f"{path.name}: {r['config']} hot swap dropped={r['dropped']} "
                f"torn={r['torn']} (bar: zero of each)", errors,
            )
        if not r["pre_swap_generates"] or not r["post_swap_generates"]:
            fail(
                f"{path.name}: {r['config']} needs generates on both sides "
                "of the swap to witness linearizability", errors,
            )


def check_outage(path: Path, rows: list, errors: list) -> None:
    surv = [r for r in rows if r.get("kind") == "outage_survival"]
    hedge = [r for r in rows if r.get("kind") == "hedged_restore"]
    summaries = [r for r in rows if r.get("kind") == "outage_summary"]
    if len(summaries) != 1:
        return fail(
            f"{path.name}: want exactly one outage_summary row, "
            f"got {len(summaries)}", errors,
        )
    s = summaries[0]
    if s["quick"]:
        fail(f"{path.name}: committed sweep must be a full run, not --quick",
             errors)
    for r in surv:
        if r["saves_failed"]:
            fail(
                f"{path.name}: {r['config']} failed {r['saves_failed']} "
                "save(s) during the outage (bar: zero)", errors,
            )
        if r["giveups"]:
            fail(
                f"{path.name}: {r['config']} recorded {r['giveups']} retry "
                "giveups (the circuit must open first; bar: zero)", errors,
            )
        if not r["drained"]:
            fail(
                f"{path.name}: {r['config']} parked backlog did not drain "
                "after heal", errors,
            )
        if not r["byte_identical"]:
            fail(
                f"{path.name}: {r['config']} post-drain restore is not "
                "byte-identical", errors,
            )
        if r["violations"]:
            fail(
                f"{path.name}: {r['config']} recorded violations "
                f"{r['violations']}", errors,
            )
    covered = {r["strategy"] for r in surv}
    if not ALL_STRATEGIES <= covered:
        fail(
            f"{path.name}: outage_survival rows missing strategies "
            f"{sorted(ALL_STRATEGIES - covered)}", errors,
        )
    if not hedge:
        fail(f"{path.name}: no hedged_restore rows", errors)
    for r in hedge:
        if r["hedged_p99_s"] >= r["unhedged_p99_s"]:
            fail(
                f"{path.name}: {r['config']} hedged p99 {r['hedged_p99_s']}s "
                f"did not beat unhedged p99 {r['unhedged_p99_s']}s", errors,
            )
        if not r["hedge_wins"]:
            fail(
                f"{path.name}: {r['config']} no hedge ever won the race",
                errors,
            )
        if not r["byte_identical"]:
            fail(
                f"{path.name}: {r['config']} hedged restore is not "
                "byte-identical", errors,
            )
    if s["n_violations"] or not s["all_byte_identical"]:
        fail(f"{path.name}: summary records violations", errors)


def check_control(path: Path, rows: list, errors: list) -> None:
    summaries = [r for r in rows if r.get("kind") == "control_summary"]
    if len(summaries) != 1:
        return fail(
            f"{path.name}: want exactly one control_summary row, "
            f"got {len(summaries)}", errors,
        )
    s = summaries[0]
    if s["quick"]:
        fail(f"{path.name}: committed replay must be a full run, not --quick",
             errors)
    if (s["n_clients"] < CONTROL_MIN_CLIENTS
            or s["n_tenants"] < CONTROL_MIN_TENANTS):
        fail(
            f"{path.name}: trace {s['n_clients']} clients / "
            f"{s['n_tenants']} tenants below the "
            f"{CONTROL_MIN_CLIENTS}/{CONTROL_MIN_TENANTS} floor", errors,
        )
    if s["failed_saves"]:
        fail(f"{path.name}: {s['failed_saves']} failed save(s) (bar: zero)",
             errors)
    if not s["byte_identical"]:
        fail(f"{path.name}: replay restores are not byte-identical", errors)
    if s["jain_index"] < CONTROL_JAIN_BAR:
        fail(
            f"{path.name}: equal-weight Jain index {s['jain_index']} < "
            f"{CONTROL_JAIN_BAR} bar", errors,
        )
    if s["utilization_frac"] < CONTROL_UTILIZATION_BAR:
        fail(
            f"{path.name}: aggregate utilization {s['utilization_frac']} < "
            f"{CONTROL_UTILIZATION_BAR}x the unarbitrated baseline", errors,
        )
    if s["budget_exceeded"]:
        fail(f"{path.name}: cluster admission budget was exceeded", errors)
    if s["preemptions"] < 1:
        fail(f"{path.name}: no preemption was ever exercised", errors)
    if not s["chaos_isolated"]:
        fail(
            f"{path.name}: the tenant_chaos scenario harmed the non-victim "
            "tenant", errors,
        )
    for r in rows:
        if r.get("kind") == "preemption":
            if r["victim_final_status"] != "flush_done":
                fail(
                    f"{path.name}: preempted flush ended "
                    f"{r['victim_final_status']!r}, want 'flush_done'", errors,
                )
            if not r["byte_identical"]:
                fail(f"{path.name}: preempted step restore mismatch", errors)
        if r.get("kind") == "tenant_chaos" and not r["drain_priority_ok"]:
            fail(
                f"{path.name}: post-heal drain did not honor priority order",
                errors,
            )


def main() -> int:
    root = Path(sys.argv[1]) if len(sys.argv) > 1 else Path(__file__).parent.parent
    errors: list = []
    for fname, (benchmark, fields) in EXPECTED.items():
        check_file(root / fname, benchmark, fields, errors)
    # any stray BENCH_*.json must at least parse with the common shape
    for path in sorted(root.glob("BENCH_*.json")):
        if path.name in EXPECTED:
            continue
        try:
            doc = json.loads(path.read_text())
        except Exception as e:
            fail(f"{path.name}: invalid JSON ({e})", errors)
            continue
        if not isinstance(doc.get("benchmark"), str) or not doc.get("rows"):
            fail(f"{path.name}: needs 'benchmark' string + non-empty 'rows'", errors)
    if errors:
        print(f"bench_check: {len(errors)} problem(s)", file=sys.stderr)
        return 1
    print(f"bench_check: OK ({len(EXPECTED)} committed bench files valid)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
