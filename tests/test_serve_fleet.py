"""Fleet serving from one aggregated checkpoint — the ISSUE 7 spec.

Written test-first: this suite specifies the serving runtime the
tentpole adds before the runtime exists.

* **Layer-granular streaming** (`repro.serve.stream`): leaf names are
  grouped into layer groups (embedding first, numbered blocks
  ascending, head last) and loaded in priority order, so
  time-to-first-token — the moment the priority prefix is resident —
  beats a full ``restore_subtree``; the streamed result is
  byte-identical to the full restore, pinned to ONE step even when a
  newer step lands mid-stream.
* **Decoded-chunk cache** (`repro.serve.stream.ChunkCache`): a
  node-local LRU shared across co-located servers; the second replica
  restoring the same step (and delta steps sharing a base) hits the
  cache instead of re-reading/re-decoding `CHUNK_BASE`/delta-base
  chunks.
* **Snapshot hot-swap** (`repro.serve.fleet.ServeFleet`): a follower
  adopts only ``flush_done`` steps (never partial/superseded/
  quarantined) and rolls params atomically — every generate uses
  exactly ONE params version, in-flight generates are never dropped.
* **Engine hooks** (`repro.core.engine.CheckpointManager`):
  ``leaf_catalog`` (leaf-range enumeration), ``subscribe`` (new-step
  notification on flush_done), ``step_status``, ``chunk_cache``.
"""
import threading
import time

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core import (
    CheckpointConfig,
    CheckpointManager,
    assign_readers,
    theta_like,
)

ALL_STRATEGIES = ["file_per_process", "posix", "mpiio", "stripe_aligned", "gio_sync"]
KiB = 1024


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def blocky_state(step: int, n_blocks: int = 4, kib: int = 8):
    """A train state whose params look like a layered LM: embedding,
    numbered blocks, head — plus optimizer baggage serving must skip."""
    rng = np.random.default_rng(1000 + step)

    def arr(n):
        return rng.standard_normal(n).astype(np.float64) + step

    params = {"embed": arr(kib * KiB // 8)}
    for i in range(n_blocks):
        params[f"block_{i:03d}"] = {
            "w": arr(kib * KiB // 8), "b": arr(32),
        }
    params["head"] = arr(kib * KiB // 8)
    return {"params": params, "opt": {"mu": arr(kib * KiB // 8), "t": arr(4)}}


def params_template(state):
    return jax.tree_util.tree_map(np.asarray, state["params"])


def trees_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb)
    )


def make_mgr(root, cluster=None, **kw):
    kw.setdefault("async_flush", False)
    return CheckpointManager(
        CheckpointConfig(root=str(root), cluster=cluster or theta_like(2, 2), **kw)
    )


def forget_memory(mgr):
    mgr._l0 = None
    mgr._last_full = None


def smoke_server(max_new_tokens=4, seed=0):
    from repro.configs import get_smoke_config
    from repro.models import get_model
    from repro.serve import ServeConfig, Server

    cfg = get_smoke_config("tinyllama-1.1b")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    return model, params, Server(model, params, ServeConfig(max_new_tokens=max_new_tokens))


# ---------------------------------------------------------------------------
# layer grouping + leaf catalog
# ---------------------------------------------------------------------------


def test_plan_layer_groups_order_and_priority():
    from repro.serve.stream import plan_layer_groups

    entries = [
        ("['params']['head']", 10),
        ("['params']['block_001']['w']", 30),
        ("['params']['embed']", 20),
        ("['params']['block_000']['b']", 5),
        ("['params']['block_000']['w']", 25),
    ]
    groups = plan_layer_groups(entries, priority_blocks=1)
    names = [g.name for g in groups]
    assert names[0] == "embed"
    assert names[1].startswith("block") and "0" in names[1]
    assert names[-1] == "tail"
    # block order ascending, both block_000 leaves in one group
    b0 = groups[1]
    assert set(b0.leaves) == {
        "['params']['block_000']['w']", "['params']['block_000']['b']"
    }
    assert b0.nbytes == 30
    # priority prefix: embed + first block
    assert [g.priority for g in groups] == [True, True, False, False]


def test_plan_layer_groups_cover_every_leaf_exactly_once():
    from repro.serve.stream import plan_layer_groups

    state = blocky_state(1, n_blocks=6)
    from repro.utils.treelib import flatten_with_names

    named, _ = flatten_with_names(state["params"])
    entries = [("['params']" + n, int(np.asarray(l).nbytes)) for n, l in named]
    groups = plan_layer_groups(entries, priority_blocks=2)
    seen = [n for g in groups for n in g.leaves]
    assert sorted(seen) == sorted(n for n, _ in entries)
    assert len(seen) == len(set(seen))
    assert sum(g.nbytes for g in groups) == sum(s for _, s in entries)
    # priority prefix = embed + 2 blocks
    assert sum(g.priority for g in groups) == 3


def test_plan_layer_groups_unnumbered_stacked_fallback():
    """Stacked-layer params (one leaf spans all layers, tinyllama
    style) still plan: embedding first, un-numbered middle, head last,
    and the priority prefix degrades to the embedding group."""
    from repro.serve.stream import plan_layer_groups

    entries = [
        ("['embed']", 8), ("['final_norm']", 1), ("['layers']['wq']", 64),
        ("['layers']['wk']", 16), ("['out']", 8),
    ]
    groups = plan_layer_groups(entries)
    assert groups[0].name == "embed" and groups[0].priority
    assert groups[-1].name == "tail"
    mid = [g for g in groups if g.name == "mid"]
    assert len(mid) == 1 and set(mid[0].leaves) == {
        "['layers']['wq']", "['layers']['wk']"
    }
    assert not mid[0].priority


def test_leaf_catalog_newest_step_and_prefix(tmp_path):
    mgr = make_mgr(tmp_path)
    mgr.save(1, blocky_state(1))
    mgr.save(2, blocky_state(2))
    step, entries = mgr.leaf_catalog(prefix="['params']")
    assert step == 2
    assert entries and all(e.name.startswith("['params']") for e in entries)
    # sizes must match the saved arrays
    total = sum(e.size for e in entries)
    from repro.utils.treelib import tree_bytes

    assert total == tree_bytes(blocky_state(2)["params"])
    # explicit step
    step1, e1 = mgr.leaf_catalog(step=1, prefix="['opt']")
    assert step1 == 1 and all(e.name.startswith("['opt']") for e in e1)
    mgr.close()


def test_leaf_catalog_missing_prefix_and_empty_root(tmp_path):
    mgr = make_mgr(tmp_path)
    with pytest.raises(FileNotFoundError):
        mgr.leaf_catalog()
    mgr.save(1, blocky_state(1))
    with pytest.raises(FileNotFoundError):
        mgr.leaf_catalog(prefix="['nope']")
    mgr.close()


# ---------------------------------------------------------------------------
# streamed (lazy) restore
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("codec", ["none", "zstd", "zstd+delta"])
def test_stream_restore_matches_full_restore(tmp_path, codec):
    from repro.serve.stream import stream_restore

    mgr = make_mgr(tmp_path, codec=codec, delta_every=2)
    for s in (1, 2, 3):  # a delta chain under zstd+delta
        mgr.save(s, blocky_state(s))
    forget_memory(mgr)
    template = params_template(blocky_state(3))
    sr = stream_restore(mgr, template)
    assert sr.step == 3
    ref_step, ref = mgr.restore_subtree(template, "['params']")
    assert ref_step == 3
    assert trees_equal(sr.params, ref)
    assert 0 < sr.ttft_s <= sr.total_s
    mgr.close()


def test_stream_restore_priority_prefix_and_byte_accounting(tmp_path):
    from repro.serve.stream import stream_restore

    mgr = make_mgr(tmp_path)
    mgr.save(5, blocky_state(5, n_blocks=8))
    forget_memory(mgr)
    template = params_template(blocky_state(5, n_blocks=8))
    sr = stream_restore(mgr, template, priority_blocks=1)
    # priority prefix (embed + 1 block) is a strict subset of the bytes
    assert 0 < sr.priority_bytes < sr.total_bytes
    from repro.utils.treelib import tree_bytes

    assert sr.total_bytes == tree_bytes(template)
    # groups completed in plan order; ttft recorded at the prefix
    order = [g.name for g in sr.groups]
    assert order[0] == "embed" and order[-1] == "tail"
    done = [sr.group_done_s[n] for n in order]
    assert done == sorted(done)
    prefix_end = max(
        sr.group_done_s[g.name] for g in sr.groups if g.priority
    )
    assert abs(sr.ttft_s - prefix_end) < 1e-9
    mgr.close()


def test_stream_restore_pins_step_against_newer_arrivals(tmp_path):
    """A newer step landing mid-stream must NOT mix into the result:
    every group is pinned to the step chosen at stream start."""
    from repro.serve import stream as stream_mod
    from repro.serve.stream import stream_restore

    mgr = make_mgr(tmp_path)
    mgr.save(1, blocky_state(1))
    forget_memory(mgr)
    template = params_template(blocky_state(1))

    real = mgr.restore_leaves
    fired = []

    def racing_restore_leaves(names, step=None):
        if not fired:
            fired.append(True)
            mgr.save(2, blocky_state(2))  # newer step lands mid-stream
            forget_memory(mgr)
        return real(names, step=step)

    mgr.restore_leaves = racing_restore_leaves
    try:
        sr = stream_restore(mgr, template)
    finally:
        mgr.restore_leaves = real
    assert sr.step == 1
    assert trees_equal(sr.params, params_template(blocky_state(1)))
    mgr.close()


def test_stream_restore_applies_sharding_fn(tmp_path):
    from repro.serve.stream import stream_restore

    mgr = make_mgr(tmp_path)
    mgr.save(1, blocky_state(1))
    forget_memory(mgr)
    template = params_template(blocky_state(1))
    seen = []

    def shard(name, leaf):
        seen.append(name)
        return jnp.asarray(leaf)

    sr = stream_restore(mgr, template, sharding_fn=shard)
    assert len(seen) == len(jax.tree_util.tree_leaves(template))
    assert all(isinstance(l, jnp.ndarray) for l in jax.tree_util.tree_leaves(sr.params))
    mgr.close()


# ---------------------------------------------------------------------------
# decoded-chunk cache
# ---------------------------------------------------------------------------


def test_chunk_cache_unit_hits_misses_lru():
    from repro.serve.stream import ChunkCache

    c = ChunkCache(capacity_bytes=300)
    assert c.get(("s", 0)) is None           # miss
    a = np.arange(100, dtype=np.uint8)
    c.put(("s", 0), a)
    hit = c.get(("s", 0))
    assert hit is not None and np.array_equal(hit, a)
    assert not hit.flags.writeable            # frozen: shared across servers
    c.put(("s", 1), np.zeros(100, np.uint8))
    c.put(("s", 2), np.zeros(100, np.uint8))
    c.get(("s", 0))                           # refresh 0's recency
    c.put(("s", 3), np.zeros(100, np.uint8))  # evicts LRU (key 1)
    assert c.get(("s", 1)) is None
    assert c.get(("s", 0)) is not None
    st = c.stats()
    assert st["hits"] >= 3 and st["misses"] >= 2 and st["evictions"] >= 1
    assert st["size_bytes"] <= 300


def test_chunk_cache_dedups_second_restore(tmp_path):
    from repro.serve.stream import ChunkCache, stream_restore

    mgr = make_mgr(tmp_path, codec="zstd", chunk_size=4 * KiB)
    mgr.save(1, blocky_state(1, kib=32))
    forget_memory(mgr)
    mgr.chunk_cache = ChunkCache()
    template = params_template(blocky_state(1, kib=32))
    a = stream_restore(mgr, template)
    misses_after_first = mgr.chunk_cache.stats()["misses"]
    assert misses_after_first > 0
    b = stream_restore(mgr, template)
    st = mgr.chunk_cache.stats()
    assert st["hits"] > 0
    assert st["misses"] == misses_after_first  # second replica: all hits
    assert st["bytes_saved"] > 0
    assert trees_equal(a.params, b.params)
    mgr.close()


def test_chunk_cache_dedups_delta_base_reads(tmp_path):
    """Two delta steps share a full-snapshot base: after restoring the
    first, the second's base-referencing chunks hit the cache instead
    of re-reading the base step."""
    from repro.serve.stream import ChunkCache, stream_restore

    mgr = make_mgr(tmp_path, codec="zstd+delta", delta_every=4,
                   chunk_size=4 * KiB)
    base = blocky_state(1, kib=32)
    mgr.save(1, base)                 # full anchor
    s2 = jax.tree_util.tree_map(np.copy, jax.tree_util.tree_map(np.asarray, base))
    s2["params"]["embed"] = s2["params"]["embed"] + 1.0   # small update
    mgr.save(2, s2)
    s3 = jax.tree_util.tree_map(np.copy, s2)
    s3["params"]["head"] = s3["params"]["head"] + 1.0
    mgr.save(3, s3)
    forget_memory(mgr)
    mgr.chunk_cache = ChunkCache()
    template = params_template(base)
    a = stream_restore(mgr, template, step=2)
    st1 = mgr.chunk_cache.stats()
    b = stream_restore(mgr, template, step=3)
    st2 = mgr.chunk_cache.stats()
    assert st2["hits"] > st1["hits"]  # base chunks served from cache
    assert trees_equal(a.params, jax.tree_util.tree_map(np.asarray, s2)["params"] if isinstance(s2, dict) else s2)
    assert trees_equal(b.params, s3["params"])
    mgr.close()


def test_chunk_cache_capacity_zero_disables_without_breaking(tmp_path):
    from repro.serve.stream import ChunkCache, stream_restore

    mgr = make_mgr(tmp_path, codec="zstd", chunk_size=4 * KiB)
    mgr.save(1, blocky_state(1))
    forget_memory(mgr)
    mgr.chunk_cache = ChunkCache(capacity_bytes=0)
    template = params_template(blocky_state(1))
    sr = stream_restore(mgr, template)
    assert trees_equal(sr.params, params_template(blocky_state(1)))
    assert mgr.chunk_cache.stats()["size_bytes"] == 0
    mgr.close()


# ---------------------------------------------------------------------------
# engine hooks: subscribe / step_status
# ---------------------------------------------------------------------------


def test_subscribe_fires_on_flush_done_sync_and_async(tmp_path):
    got = []
    mgr = make_mgr(tmp_path)                      # sync flush
    mgr.subscribe(got.append)
    mgr.save(1, blocky_state(1))
    assert got == [1]
    mgr.close()

    got2 = []
    mgr2 = CheckpointManager(
        CheckpointConfig(root=str(tmp_path / "async"), cluster=theta_like(2, 2))
    )
    mgr2.subscribe(got2.append)
    mgr2.save(7, blocky_state(7))
    mgr2.wait()
    assert got2 == [7]
    mgr2.close()


def test_unsubscribe_and_callback_errors_are_isolated(tmp_path):
    def boom(step):
        raise RuntimeError("subscriber bug")

    got = []
    mgr = make_mgr(tmp_path)
    mgr.subscribe(boom)
    mgr.subscribe(got.append)
    mgr.save(1, blocky_state(1))      # boom must not break the flush
    assert got == [1]
    assert mgr.flush_errors == []
    assert 1 in mgr.steps("pfs")
    mgr.unsubscribe(got.append)
    mgr.save(2, blocky_state(2))
    assert got == [1]
    mgr.unsubscribe(boom)
    mgr.close()


def test_step_status_reports_lifecycle(tmp_path):
    mgr = make_mgr(tmp_path)
    assert mgr.step_status(9) is None
    mgr.save(9, blocky_state(9))
    assert mgr.step_status(9) == "flush_done"
    assert mgr.step_status(9, level="local") == "local_done"
    mgr.close()


# ---------------------------------------------------------------------------
# Server hot-swap primitives
# ---------------------------------------------------------------------------


def test_swap_params_bumps_version_and_generate_reports_it():
    model, p0, server = smoke_server()
    assert server.params_version == 0
    prompts = {"tokens": jnp.asarray(np.full((2, 5), 7, np.int32))}
    toks0, _, v0 = server.generate(prompts, with_version=True)
    assert v0 == 0
    p1 = model.init(jax.random.PRNGKey(1))
    v = server.swap_params(p1)
    assert v == 1 and server.params_version == 1
    toks1, _, v1 = server.generate(prompts, with_version=True)
    assert v1 == 1
    ref1, _ = type(server)(model, p1, server.cfg).generate(prompts)
    np.testing.assert_array_equal(toks1, ref1)
    # params property follows the swap
    assert server.params is p1


def test_generate_uses_exactly_one_version_under_concurrent_swaps():
    """Linearizability: each generate's output equals the reference of
    exactly the version it reports — never a torn mix."""
    from repro.serve import ServeConfig, Server

    model, p0, server = smoke_server(max_new_tokens=3)
    p1 = model.init(jax.random.PRNGKey(1))
    prompts = {"tokens": jnp.asarray(np.full((2, 4), 5, np.int32))}
    refs = {
        0: Server(model, p0, ServeConfig(max_new_tokens=3)).generate(prompts)[0],
        1: Server(model, p1, ServeConfig(max_new_tokens=3)).generate(prompts)[0],
    }
    assert not np.array_equal(refs[0], refs[1])  # distinguishable versions
    results, errors = [], []
    stop = threading.Event()

    def hammer():
        while not stop.is_set():
            try:
                toks, _, v = server.generate(prompts, with_version=True)
                results.append((v, toks))
            except Exception as e:  # pragma: no cover - failure reporting
                errors.append(e)
                return

    threads = [threading.Thread(target=hammer) for _ in range(3)]
    for t in threads:
        t.start()
    versions = [p1, p0, p1, p0, p1]
    for p in versions:
        time.sleep(0.05)
        server.swap_params(p)
    stop.set()
    for t in threads:
        t.join(timeout=60)
        assert not t.is_alive()
    assert not errors
    assert results                           # nothing dropped
    for v, toks in results:
        np.testing.assert_array_equal(toks, refs[v % 2])


def test_snapshot_state_tracks_swapped_params():
    model, p0, server = smoke_server()
    p1 = model.init(jax.random.PRNGKey(1))
    server.swap_params(p1)
    snap = server.snapshot_state(cache={"k": jnp.zeros((1,))})
    assert snap["params"] is p1


# ---------------------------------------------------------------------------
# ServeFleet: concurrent cold start
# ---------------------------------------------------------------------------


def fleet_checkpoint(tmp_path, strategy="stripe_aligned", **kw):
    """Save a real model train state under the training geometry; return
    (model, params, serving manager over the same root)."""
    from repro.configs import get_smoke_config
    from repro.models import get_model

    cfg = get_smoke_config("tinyllama-1.1b")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    state = {"params": params, "opt": {"mu": jnp.zeros((4096,), jnp.float32)}}
    train = make_mgr(tmp_path, cluster=theta_like(4, 2), strategy=strategy, **kw)
    train.save(3, state)
    train.close()
    serve_mgr = make_mgr(tmp_path, cluster=theta_like(2, 1), strategy=strategy, **kw)
    return model, params, serve_mgr


@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
def test_fleet_cold_start_concurrent_byte_identity(tmp_path, strategy):
    from repro.serve import FleetConfig, ServeConfig, ServeFleet

    model, params, mgr = fleet_checkpoint(tmp_path, strategy)
    fleet = ServeFleet(
        model, mgr, jax.tree_util.tree_map(np.asarray, params),
        cfg=FleetConfig(n_servers=3, serve=ServeConfig(max_new_tokens=3)),
    )
    cs = fleet.cold_start()
    assert cs.step == 3 and fleet.current_step == 3
    assert len(fleet.servers) == 3
    ref = jax.tree_util.tree_map(np.asarray, params)
    for srv in fleet.servers:
        assert trees_equal(srv.params, ref)
    assert len(cs.ttft_s) == 3 and all(t > 0 for t in cs.ttft_s)
    fleet.close()
    mgr.close()


def test_fleet_cold_start_shares_cache_across_servers(tmp_path):
    from repro.serve import FleetConfig, ServeFleet

    model, params, mgr = fleet_checkpoint(
        tmp_path, codec="zstd", chunk_size=4 * KiB
    )
    fleet = ServeFleet(
        model, mgr, jax.tree_util.tree_map(np.asarray, params),
        cfg=FleetConfig(n_servers=3),
    )
    cs = fleet.cold_start()
    st = cs.cache
    assert st is not None and st["hits"] > 0         # replicas 2..n dedup
    assert st["bytes_saved"] > 0
    assert mgr.chunk_cache is fleet.cache            # node-local, shared
    fleet.close()
    mgr.close()


def test_fleet_reader_balance_uses_serving_geometry(tmp_path):
    from repro.serve import FleetConfig, ServeFleet

    model, params, mgr = fleet_checkpoint(tmp_path)
    fleet = ServeFleet(
        model, mgr, jax.tree_util.tree_map(np.asarray, params),
        cfg=FleetConfig(n_servers=1),
    )
    bal = fleet.reader_balance()
    assert bal["n_readers"] == mgr.cluster.n_nodes   # the SERVING geometry
    assert bal["max_bytes"] >= bal["min_bytes"] >= 0
    # byte-balance: no reader exceeds an even share by more than the
    # largest single blob (the midpoint-assignment bound)
    man = mgr._manifest_pfs(3)
    sizes = [r.stored_size for r in man.ranks]
    assert bal["max_bytes"] <= sum(sizes) / bal["n_readers"] + max(sizes)
    np.testing.assert_array_equal(
        bal["readers"], assign_readers(sizes, mgr.cluster.n_nodes)
    )
    fleet.close()
    mgr.close()


def test_fleet_cold_start_generates_after_lazy_load(tmp_path):
    from repro.serve import FleetConfig, ServeConfig, ServeFleet, Server

    model, params, mgr = fleet_checkpoint(tmp_path)
    fleet = ServeFleet(
        model, mgr, jax.tree_util.tree_map(np.asarray, params),
        cfg=FleetConfig(n_servers=2, serve=ServeConfig(max_new_tokens=4)),
    )
    fleet.cold_start()
    prompts = {"tokens": jnp.asarray(np.full((2, 5), 7, np.int32))}
    ref, _ = Server(model, params, ServeConfig(max_new_tokens=4)).generate(prompts)
    for srv in fleet.servers:
        toks, _ = srv.generate(prompts)
        np.testing.assert_array_equal(toks, ref)
    fleet.close()
    mgr.close()


# ---------------------------------------------------------------------------
# ServeFleet: snapshot hot-swap
# ---------------------------------------------------------------------------


def test_swap_to_rolls_every_server(tmp_path):
    from repro.serve import FleetConfig, ServeConfig, ServeFleet, Server

    model, params, mgr = fleet_checkpoint(tmp_path)
    fleet = ServeFleet(
        model, mgr, jax.tree_util.tree_map(np.asarray, params),
        cfg=FleetConfig(n_servers=2, serve=ServeConfig(max_new_tokens=3)),
    )
    fleet.cold_start()
    # a newer step from "training" over the same PFS root
    p2 = model.init(jax.random.PRNGKey(2))
    train = make_mgr(tmp_path, cluster=theta_like(4, 2))
    train.save(5, {"params": p2, "opt": {"mu": jnp.zeros((4096,), jnp.float32)}})
    train.close()

    adopted = fleet.swap_to()
    assert adopted == 5 and fleet.current_step == 5
    prompts = {"tokens": jnp.asarray(np.full((1, 4), 3, np.int32))}
    ref, _ = Server(model, p2, ServeConfig(max_new_tokens=3)).generate(prompts)
    for srv in fleet.servers:
        toks, _, v = srv.generate(prompts, with_version=True)
        np.testing.assert_array_equal(toks, ref)
        assert v == 1                      # exactly one roll happened
    assert fleet.swap_history and fleet.swap_history[-1][0] == 5
    fleet.close()
    mgr.close()


def test_follower_adopts_only_flush_done(tmp_path):
    """Manifests at flush_partial / superseded / quarantined newer than
    the served step must never be adopted; a real flush_done step is."""
    from repro.core import Manifest
    from repro.serve import FleetConfig, ServeFleet

    model, params, mgr = fleet_checkpoint(tmp_path)
    fleet = ServeFleet(
        model, mgr, jax.tree_util.tree_map(np.asarray, params),
        cfg=FleetConfig(n_servers=1, poll_interval=0.02),
    )
    fleet.cold_start()
    # plant newer NON-final manifests on the PFS
    src = mgr.pfs_dir / "step_00000003" / "manifest.json"
    for step, status in ((7, "flush_partial"), (8, "superseded"),
                         (9, "quarantined")):
        man = Manifest.from_json(src.read_text())
        man.step = step
        man.status = status
        d = mgr.pfs_dir / f"step_{step:08d}"
        d.mkdir(parents=True, exist_ok=True)
        (d / "manifest.json").write_text(man.to_json())
    fleet.start_follower()
    time.sleep(0.3)
    assert fleet.current_step == 3          # nothing non-final adopted
    # now a genuine newer step
    p2 = model.init(jax.random.PRNGKey(2))
    train = make_mgr(tmp_path, cluster=theta_like(4, 2))
    train.save(11, {"params": p2, "opt": {"mu": jnp.zeros((4096,), jnp.float32)}})
    train.close()
    deadline = time.monotonic() + 30
    while fleet.current_step != 11 and time.monotonic() < deadline:
        time.sleep(0.02)
    assert fleet.current_step == 11
    assert trees_equal(
        fleet.servers[0].params, jax.tree_util.tree_map(np.asarray, p2)
    )
    fleet.stop()
    fleet.close()
    mgr.close()


def test_follower_hot_swap_drops_no_generates(tmp_path):
    """Generates hammering the fleet while the follower rolls params:
    every generate completes and matches exactly one version's
    reference output (no torn swap, nothing dropped)."""
    from repro.serve import FleetConfig, ServeConfig, ServeFleet, Server

    model, params, mgr = fleet_checkpoint(tmp_path)
    fleet = ServeFleet(
        model, mgr, jax.tree_util.tree_map(np.asarray, params),
        cfg=FleetConfig(n_servers=1, poll_interval=0.02,
                        serve=ServeConfig(max_new_tokens=3)),
    )
    fleet.cold_start()
    prompts = {"tokens": jnp.asarray(np.full((2, 4), 5, np.int32))}
    p2 = model.init(jax.random.PRNGKey(2))
    refs = {
        0: Server(model, params, ServeConfig(max_new_tokens=3)).generate(prompts)[0],
        1: Server(model, p2, ServeConfig(max_new_tokens=3)).generate(prompts)[0],
    }
    results, errors = [], []
    stop = threading.Event()

    def hammer():
        srv = fleet.servers[0]
        while not stop.is_set():
            try:
                toks, _, v = srv.generate(prompts, with_version=True)
                results.append((v, toks))
            except Exception as e:  # pragma: no cover - failure reporting
                errors.append(e)
                return

    threads = [threading.Thread(target=hammer) for _ in range(2)]
    for t in threads:
        t.start()
    fleet.start_follower()
    train = make_mgr(tmp_path, cluster=theta_like(4, 2))
    train.save(6, {"params": p2, "opt": {"mu": jnp.zeros((4096,), jnp.float32)}})
    train.close()
    deadline = time.monotonic() + 30
    while fleet.current_step != 6 and time.monotonic() < deadline:
        time.sleep(0.02)
    # keep hammering until at least one post-swap generate lands
    while not any(v == 1 for v, _ in list(results)) and time.monotonic() < deadline:
        time.sleep(0.02)
    stop.set()
    for t in threads:
        t.join(timeout=60)
        assert not t.is_alive()
    fleet.stop()
    assert not errors
    assert fleet.current_step == 6
    versions = {v for v, _ in results}
    assert 1 in versions                   # post-swap generates happened
    for v, toks in results:
        np.testing.assert_array_equal(toks, refs[min(v, 1)])
    fleet.close()
    mgr.close()


def test_fleet_stop_and_close_idempotent(tmp_path):
    from repro.serve import FleetConfig, ServeFleet

    model, params, mgr = fleet_checkpoint(tmp_path)
    fleet = ServeFleet(
        model, mgr, jax.tree_util.tree_map(np.asarray, params),
        cfg=FleetConfig(n_servers=1),
    )
    fleet.cold_start()
    fleet.start_follower()
    fleet.stop()
    fleet.stop()                          # second stop is a no-op
    fleet.close()
    fleet.close()
    mgr.close()
