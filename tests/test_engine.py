"""Multi-level CheckpointManager: the system-behaviour test suite."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CheckpointConfig, CheckpointManager, theta_like


def state_tree(step=0):
    return {
        "params": {
            "w": jnp.arange(2000, dtype=jnp.float32).reshape(40, 50) + step,
            "b": jnp.full((64,), step, jnp.bfloat16),
        },
        "opt": {"mu": jnp.ones((40, 50), jnp.float32) * step,
                "count": jnp.array(step, jnp.int32)},
    }


def np_target():
    return jax.tree_util.tree_map(np.asarray, state_tree())


def assert_tree_equal(a, b):
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_array_equal(np.asarray(x), np.asarray(y)),
        a, b,
    )


@pytest.mark.parametrize("strategy", ["file_per_process", "posix", "mpiio", "stripe_aligned"])
def test_roundtrip_strategies(tmp_path, strategy):
    mgr = CheckpointManager(
        CheckpointConfig(root=str(tmp_path), cluster=theta_like(3, 2), strategy=strategy)
    )
    mgr.save(7, state_tree(7))
    mgr.wait()
    assert not mgr.flush_errors
    mgr._l0 = None  # force the file path
    step, restored = mgr.restore(np_target())
    assert step == 7
    assert_tree_equal(restored, state_tree(7))
    mgr.close()


@pytest.mark.parametrize("codec", ["zstd", "zstd+delta"])
def test_codecs_roundtrip(tmp_path, codec):
    mgr = CheckpointManager(
        CheckpointConfig(
            root=str(tmp_path), cluster=theta_like(2, 2),
            strategy="stripe_aligned", codec=codec, delta_every=3,
        )
    )
    for s in (1, 2, 3, 4, 5):
        mgr.save(s, state_tree(s))
    mgr.wait()
    assert not mgr.flush_errors
    mgr._l0 = None
    for s in (5, 3, 1):
        step, restored = mgr.restore(np_target(), step=s)
        assert_tree_equal(restored, state_tree(s))
    if codec == "zstd+delta":
        manifests = [mgr._manifest_pfs(s) for s in (1, 2, 3, 4, 5)]
        assert [m.base_step for m in manifests] == [None, 1, 2, None, 4]
    mgr.close()


def big_state(step=0):
    return {
        "w": jnp.arange(128 * 64, dtype=jnp.float32).reshape(128, 64) / 77 + step,
        "tiny": jnp.full((8,), 1.5, jnp.float32),   # below quant threshold
        "count": jnp.array(step, jnp.int32),
    }


def test_int8_precodec_lossy_roundtrip(tmp_path):
    from repro.utils import tree_bytes

    mgr = CheckpointManager(
        CheckpointConfig(
            root=str(tmp_path), cluster=theta_like(2, 1),
            strategy="stripe_aligned", precodec="int8",
        )
    )
    original_bytes = tree_bytes(big_state(3))
    st = mgr.save(1, big_state(3))
    mgr.wait()
    # int8 precodec happens *before* serialization: raw stream ~ 1/4 of
    # the original float state (+ per-block scales)
    assert st.raw_bytes < 0.45 * original_bytes
    mgr._l0 = None
    target = jax.tree_util.tree_map(np.asarray, big_state())
    _, restored = mgr.restore(target)
    w = np.asarray(restored["w"])
    ref = np.asarray(big_state(3)["w"])
    blocks = np.abs(ref.reshape(-1, 128)).max(1)[:, None] / 127
    assert (np.abs(w - ref).reshape(-1, 128) <= blocks + 1e-6).all()
    np.testing.assert_array_equal(restored["tiny"], np.asarray(big_state(3)["tiny"]))
    assert int(restored["count"]) == 3  # int leaves stay exact
    mgr.close()


def test_flush_crash_falls_back_to_local(tmp_path):
    count = itertools.count()

    def bomb(_w):
        if next(count) == 2:
            raise IOError("injected backend crash")

    mgr = CheckpointManager(
        CheckpointConfig(root=str(tmp_path), cluster=theta_like(3, 2),
                         strategy="stripe_aligned"),
        fault_hook=bomb,
    )
    mgr.save(4, state_tree(4))
    mgr.wait()
    assert mgr.flush_errors and mgr.flush_errors[0][0] == 4
    assert mgr.steps("pfs") == []           # flush never completed
    mgr._l0 = None
    step, restored = mgr.restore(np_target())
    assert step == 4                        # L1 fallback
    assert_tree_equal(restored, state_tree(4))
    mgr.close()


def test_node_loss_recovers_via_partner(tmp_path):
    mgr = CheckpointManager(
        CheckpointConfig(
            root=str(tmp_path), cluster=theta_like(4, 2),
            strategy="file_per_process", partner_replication=True,
            async_flush=False,
        ),
        fault_hook=lambda w: (_ for _ in ()).throw(IOError("pfs down")),
    )
    with pytest.raises(IOError):
        mgr.save(9, state_tree(9))
    # PFS flush failed AND node 1's local storage dies:
    mgr.local.drop_node(1)
    mgr._l0 = None
    step, restored = mgr.restore(np_target())
    assert step == 9
    assert_tree_equal(restored, state_tree(9))
    mgr.close()


def test_elastic_restore_new_geometry(tmp_path):
    mgr = CheckpointManager(
        CheckpointConfig(root=str(tmp_path), cluster=theta_like(4, 2),
                         strategy="stripe_aligned")
    )
    mgr.save(11, state_tree(11))
    mgr.wait()
    mgr.close()
    # restart on a different cluster shape; local level is gone
    mgr2 = CheckpointManager(
        CheckpointConfig(root=str(tmp_path), cluster=theta_like(3, 1),
                         strategy="posix")
    )
    mgr2.local.drop_node(0)
    step, restored = mgr2.restore(np_target())
    assert step == 11
    assert_tree_equal(restored, state_tree(11))
    mgr2.close()


def test_corruption_detected(tmp_path):
    mgr = CheckpointManager(
        CheckpointConfig(root=str(tmp_path), cluster=theta_like(2, 1),
                         strategy="stripe_aligned")
    )
    mgr.save(1, state_tree(1))
    mgr.wait()
    # flip a byte in the aggregate file AND drop local copies
    agg = next((mgr.pfs_dir / "step_00000001").glob("aggregate.dat"))
    data = bytearray(agg.read_bytes())
    data[100] ^= 0xFF
    agg.write_bytes(bytes(data))
    for n in range(2):
        mgr.local.drop_node(n)
    mgr._l0 = None
    with pytest.raises(FileNotFoundError):
        mgr.restore(np_target())
    mgr.close()


def test_gc_keeps_n_and_delta_bases(tmp_path):
    mgr = CheckpointManager(
        CheckpointConfig(
            root=str(tmp_path), cluster=theta_like(2, 1),
            strategy="stripe_aligned", codec="zstd+delta",
            delta_every=3, keep_n=2,
        )
    )
    for s in range(1, 8):
        mgr.save(s, state_tree(s))
        mgr.wait()
    steps = mgr.steps("pfs")
    assert steps[-2:] == [6, 7]
    man7 = mgr._manifest_pfs(7)
    if man7.base_step is not None:  # chain bases survive gc
        assert man7.base_step in steps
    mgr._l0 = None
    _, restored = mgr.restore(np_target(), step=7)
    assert_tree_equal(restored, state_tree(7))
    mgr.close()


def test_async_overlap_is_real(tmp_path):
    """The flush genuinely runs in the background thread."""
    import time

    big = {"x": jnp.zeros((2_000_000,), jnp.float32)}
    mgr = CheckpointManager(
        CheckpointConfig(root=str(tmp_path), cluster=theta_like(2, 2),
                         strategy="stripe_aligned")
    )
    st = mgr.save(1, big)
    pending = mgr._q.unfinished_tasks > 0
    t0 = time.perf_counter()
    mgr.wait()
    waited = time.perf_counter() - t0
    assert not mgr.flush_errors
    # either we caught it in flight, or it finished before we checked
    assert pending or waited >= 0.0
    assert st.local_time < 5.0
    mgr.close()
