"""Strategy/plan unit + property tests (the paper's coordination layer)."""
import math

import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    ClusterSpec,
    count_false_sharing,
    elect_leaders,
    exclusive_prefix_sum,
    make_plan,
    piggybacked_scan,
    theta_like,
    validate_plan,
)
from repro.core.plan import PlanError
from repro.core.strategies import STRATEGIES

MiB = 1 << 20


def test_prefix_sum_basic():
    offs, total = exclusive_prefix_sum([3, 0, 5, 2])
    assert offs == [0, 3, 3, 8]
    assert total == 10


def test_scan_meta_costs():
    c = theta_like(8, 4)
    scan = piggybacked_scan(c, [MiB] * 32)
    assert scan.total_bytes == 32 * MiB
    assert scan.meta.messages == 2 * (8 - 1)
    assert scan.meta.rounds == 2 * math.ceil(math.log2(8))
    assert len(scan.node_summaries) == 8


@pytest.mark.parametrize("strategy", sorted(STRATEGIES))
@pytest.mark.parametrize("sizes_kind", ["uniform", "ragged", "with_zeros"])
def test_plans_validate(strategy, sizes_kind):
    c = theta_like(4, 3)
    n = c.world_size
    sizes = {
        "uniform": [4 * MiB] * n,
        "ragged": [(i % 5 + 1) * MiB + i * 1000 + 1 for i in range(n)],
        "with_zeros": [0 if i % 3 == 0 else 2 * MiB + i for i in range(n)],
    }[sizes_kind]
    plan = make_plan(strategy, c, sizes)
    validate_plan(plan)  # raises on violation
    assert plan.total_bytes == sum(sizes)
    if strategy == "file_per_process":
        assert plan.n_files == sum(1 for s in sizes if s)
        assert plan.network_bytes() == 0
    else:
        assert plan.n_files == 1


def test_posix_has_false_sharing_and_s3_does_not():
    c = theta_like(8, 2)
    sizes = [3 * MiB + 12345] * c.world_size  # unaligned on purpose
    posix = make_plan("posix", c, sizes)
    s3 = make_plan("stripe_aligned", c, sizes)
    assert count_false_sharing(posix)["stripes_shared"] > 0
    assert count_false_sharing(s3)["stripes_shared"] == 0
    # validator enforces the claim structurally
    assert s3.stripe_disjoint
    bad = make_plan("posix", c, sizes)
    bad.stripe_disjoint = True  # false claim -> validator must catch it
    with pytest.raises(PlanError):
        validate_plan(bad)


def test_mpiio_rounds_are_barriered():
    c = theta_like(4, 3)
    plan = make_plan("mpiio", c, [MiB] * 12)
    assert plan.barrier_per_round
    assert plan.n_rounds == 3  # one collective per node-local checkpoint
    rounds = {w.round for w in plan.writes}
    assert rounds == {1, 2, 3}


def test_leader_election_criteria():
    # criterion 1: big holders lead; criterion 2: loaded nodes don't
    c = theta_like(4, 1).with_(node_load=[0.0, 0.9, 0.0, 0.0])
    sizes = [MiB, 16 * MiB, 16 * MiB, MiB]
    scan = piggybacked_scan(c, sizes)
    assign = elect_leaders(c, scan, 2)
    assert 1 not in assign.leaders  # loaded node skipped
    assert 2 in assign.leaders      # big holder leads
    # deterministic: same inputs -> same assignment (no agreement protocol)
    assert assign == elect_leaders(c, scan, 2)


def test_stripe_aligned_minimizes_network_for_uniform_sizes():
    c = theta_like(8, 4)
    sizes = [8 * MiB] * c.world_size
    plan = make_plan("stripe_aligned", c, sizes, n_leaders=8)
    # uniform sizes + leaders == nodes: regions align with node data
    assert plan.network_bytes() == 0
    mpiio = make_plan("mpiio", c, sizes)
    assert mpiio.network_bytes() > 0


@settings(max_examples=40, deadline=None)
@given(
    nodes=st.integers(1, 6),
    ppn=st.integers(1, 4),
    strategy=st.sampled_from(sorted(STRATEGIES)),
    data=st.data(),
)
def test_plan_invariants_fuzz(nodes, ppn, strategy, data):
    c = theta_like(nodes, ppn)
    sizes = data.draw(
        st.lists(
            st.integers(0, 5 * MiB),
            min_size=c.world_size, max_size=c.world_size,
        )
    )
    plan = make_plan(strategy, c, sizes)
    validate_plan(plan)
    # conservation
    assert sum(w.size for w in plan.writes) == sum(sizes)
    # declared file sizes exactly hold the data
    assert sum(plan.files.values()) >= sum(sizes)
    # every send lands at a backend that writes those bytes
    writers = {w.backend for w in plan.writes}
    for s in plan.sends:
        assert s.dst_backend in writers
