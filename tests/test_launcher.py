"""End-to-end CLI driver test: train -> kill -> resume, via subprocess."""
import os
import subprocess
import sys
import tempfile
from pathlib import Path


def _run(args, env):
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.train"] + args,
        capture_output=True, text=True, timeout=560, env=env,
        cwd=str(Path(__file__).resolve().parents[1]),
    )


def test_train_cli_checkpoints_and_resumes():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    with tempfile.TemporaryDirectory() as root:
        common = [
            "--arch", "tinyllama-1.1b", "--smoke", "--global-batch", "4",
            "--seq-len", "32", "--ckpt-every", "3", "--root", root,
            "--strategy", "stripe_aligned", "--codec", "zstd",
        ]
        first = _run(common + ["--steps", "6"], env)
        assert first.returncode == 0, first.stderr[-2000:]
        assert "step     6" in first.stdout
        assert "[ckpt]" in first.stdout

        second = _run(common + ["--steps", "9", "--resume"], env)
        assert second.returncode == 0, second.stderr[-2000:]
        assert "[resume] restored step 6" in second.stdout
        assert "step     7" in second.stdout  # continued, not restarted
        assert "step     9" in second.stdout
