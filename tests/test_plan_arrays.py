"""Columnar planner equivalence: PlanArrays builders == seed item planners.

The columnar strategy builders in repro.core.strategies must produce
byte-identical coalesced write/send sets to the original item-loop
planners (preserved in repro.core.strategies_ref), for every strategy,
on small clusters covering mixed sizes, zero-size ranks and loaded
nodes.  PlanArrays <-> item-list round-trips must be lossless, and the
columnar validate_plan must accept/reject exactly like the item-loop
reference validator.
"""
import numpy as np
import pytest

from repro.core import (
    PlanArrays,
    make_plan,
    theta_like,
    validate_plan,
    validate_plan_reference,
)
from repro.core.plan import (
    PlanError,
    SendItem,
    WriteItem,
    coalesce_send_columns,
    coalesce_write_columns,
)
from repro.core.strategies import STRATEGIES
from repro.core.strategies_ref import (
    REFERENCE_STRATEGIES,
    _coalesce_sends_ref,
    _coalesce_writes_ref,
    make_plan_reference,
)

MiB = 1 << 20


def _wkey(w: WriteItem):
    return (w.round, w.backend, w.file, w.file_offset, w.size, w.src_rank, w.src_offset)


def _skey(s: SendItem):
    return (s.round, s.src_backend, s.dst_backend, s.src_rank, s.src_offset, s.size)


def _clusters_and_sizes():
    rng = np.random.default_rng(7)
    cases = []
    for nodes, ppn in [(4, 3), (5, 2), (1, 1), (2, 4)]:
        c = theta_like(nodes, ppn)
        n = c.world_size
        cases.append((c, [4 * MiB] * n, "uniform"))
        cases.append((c, [(i % 5 + 1) * MiB + i * 1000 + 1 for i in range(n)], "ragged"))
        cases.append((c, [0 if i % 3 == 0 else 2 * MiB + i for i in range(n)], "zeros"))
        cases.append((c, rng.integers(0, 5 * MiB, n).tolist(), "random"))
    # loaded nodes exercise election criterion 2 and capacity regions
    c = theta_like(6, 2).with_(node_load=[0.7, 0.0, 0.3, 0.0, 0.9, 0.0])
    n = c.world_size
    cases.append((c, rng.integers(MiB, 8 * MiB, n).tolist(), "loaded"))
    cases.append((c, [0] * n, "allzero"))
    return cases


CASES = _clusters_and_sizes()
KWARGS = {
    "file_per_process": [{}],
    "posix": [{}, {"write_chunk": 700_001}],
    "mpiio": [{}, {"chunk_stripes": 3}],
    "stripe_aligned": [{}, {"pipeline_chunk": 3 * MiB},
                       {"n_leaders": 2, "capacity_regions": True}],
    "gio_sync": [{}, {"chunk_stripes": 2}],
}


def test_registry_parity():
    assert sorted(STRATEGIES) == sorted(REFERENCE_STRATEGIES)


@pytest.mark.parametrize("strategy", sorted(STRATEGIES))
def test_columnar_matches_reference(strategy):
    for c, sizes, tag in CASES:
        for kw in KWARGS[strategy]:
            got = make_plan(strategy, c, sizes, **kw)
            ref = make_plan_reference(strategy, c, sizes, **kw)
            ctx = f"{strategy}/{tag}/{kw}/{c.n_nodes}x{c.procs_per_node}"
            assert sorted(map(_wkey, got.writes)) == sorted(map(_wkey, ref.writes)), ctx
            assert sorted(map(_skey, got.sends)) == sorted(map(_skey, ref.sends)), ctx
            assert got.files == ref.files, ctx
            assert got.n_rounds == ref.n_rounds and got.meta == ref.meta, ctx
            # both validators accept both plans
            validate_plan_reference(got)
            validate_plan(ref)


@pytest.mark.parametrize("strategy", sorted(STRATEGIES))
def test_plan_arrays_roundtrip_lossless(strategy):
    c = theta_like(4, 3)
    sizes = [(i % 4 + 1) * MiB + 37 * i for i in range(c.world_size)]
    plan = make_plan(strategy, c, sizes)
    pa = plan.ensure_arrays()
    # arrays -> items -> arrays -> items is identity
    items_w, items_s = pa.to_write_items(), pa.to_send_items()
    pa2 = PlanArrays.from_items(items_w, items_s, file_names=pa.file_names)
    assert pa2.file_names == pa.file_names
    for col in ("backend", "file_id", "file_offset", "size", "src_rank",
                "src_offset", "round"):
        np.testing.assert_array_equal(getattr(pa2.writes, col), getattr(pa.writes, col))
    for col in ("src_backend", "dst_backend", "src_rank", "src_offset",
                "size", "round"):
        np.testing.assert_array_equal(getattr(pa2.sends, col), getattr(pa.sends, col))
    assert pa2.to_write_items() == items_w
    assert pa2.to_send_items() == items_s


def test_columnar_coalesce_matches_reference():
    rng = np.random.default_rng(11)
    for trial in range(20):
        writes, sends = [], []
        pos = {}
        for _ in range(rng.integers(1, 60)):
            backend = int(rng.integers(0, 3))
            rank = int(rng.integers(0, 4))
            rnd = int(rng.integers(0, 2))
            key = (backend, rank, rnd)
            off = pos.get(key, 0)
            # randomly leave gaps so only some neighbours merge
            off += int(rng.integers(0, 2)) * 100
            size = int(rng.integers(1, 50))
            writes.append(WriteItem(backend=backend, file="f", file_offset=off,
                                    size=size, src_rank=rank, src_offset=off,
                                    round=rnd))
            sends.append(SendItem(src_backend=backend, dst_backend=(backend + 1) % 3,
                                  src_rank=rank, src_offset=off, size=size,
                                  round=rnd))
            pos[key] = off + size
        pa = PlanArrays.from_items(writes, sends, file_names=["f"])
        got_w = PlanArrays(pa.file_names, coalesce_write_columns(pa.writes),
                           pa.sends).to_write_items()
        got_s = PlanArrays(pa.file_names, pa.writes,
                           coalesce_send_columns(pa.sends)).to_send_items()
        assert sorted(map(_wkey, got_w)) == sorted(map(_wkey, _coalesce_writes_ref(writes)))
        assert sorted(map(_skey, got_s)) == sorted(map(_skey, _coalesce_sends_ref(sends)))


# ---------------------------------------------------------------------------
# Validator agreement: columnar validate_plan rejects exactly what the
# item-loop reference rejects.
# ---------------------------------------------------------------------------


def _fresh(strategy="stripe_aligned", **kw):
    c = theta_like(4, 2)
    sizes = [(i % 3 + 1) * MiB for i in range(c.world_size)]
    return make_plan_reference(strategy, c, sizes, **kw)


def _both_reject(plan):
    with pytest.raises(PlanError):
        validate_plan_reference(plan)
    # no cache reset needed: validate_plan re-reads mutated item lists
    with pytest.raises(PlanError):
        validate_plan(plan)


def test_validators_agree_on_good_plans():
    for strategy in sorted(STRATEGIES):
        plan = _fresh(strategy)
        validate_plan_reference(plan)
        validate_plan(plan)


def test_validate_rereads_mutated_items():
    # mutating the item view after a validate must not be masked by the
    # cached columnar arrays
    plan = _fresh("posix")
    validate_plan(plan)  # caches plan.arrays
    plan.writes.pop()
    with pytest.raises(PlanError):
        validate_plan(plan)


def test_validate_rereads_mutated_sends():
    plan = _fresh("mpiio")
    validate_plan(plan)
    assert plan.sends
    plan.sends.pop()  # mutate only the sends view
    with pytest.raises(PlanError):
        validate_plan(plan)


def test_validate_after_partial_materialization():
    # touching only .writes on a columnar-built plan must not make the
    # validator forget the (never-materialized) sends
    c = theta_like(4, 4)
    plan = make_plan("stripe_aligned", c, [1000] * c.world_size)
    assert plan.arrays.n_sends > 0
    _ = plan.writes  # materialize writes only
    validate_plan(plan)  # must still pass


def test_validators_reject_missing_write():
    plan = _fresh()
    plan.writes.pop()
    _both_reject(plan)


def test_validators_reject_src_overlap():
    plan = _fresh()
    w = plan.writes[0]
    plan.writes.append(WriteItem(backend=w.backend, file=w.file,
                                 file_offset=w.file_offset + (1 << 40),
                                 size=w.size, src_rank=w.src_rank,
                                 src_offset=w.src_offset, round=w.round))
    plan.files[w.file] = (1 << 40) + plan.files[w.file]
    _both_reject(plan)


def test_validators_reject_file_overlap():
    plan = _fresh("posix")
    w = plan.writes[1]
    plan.writes[1] = WriteItem(backend=w.backend, file=w.file,
                               file_offset=plan.writes[0].file_offset,
                               size=w.size, src_rank=w.src_rank,
                               src_offset=w.src_offset, round=w.round)
    _both_reject(plan)


def test_validators_reject_undeclared_file():
    plan = _fresh("file_per_process")
    w = plan.writes[0]
    plan.writes[0] = WriteItem(backend=w.backend, file="ghost.dat",
                               file_offset=w.file_offset, size=w.size,
                               src_rank=w.src_rank, src_offset=w.src_offset)
    _both_reject(plan)


def test_validators_reject_write_past_declared_size():
    plan = _fresh("posix")
    fname = next(iter(plan.files))
    plan.files[fname] -= 1
    _both_reject(plan)


def test_validators_reject_missing_send():
    plan = _fresh("mpiio")
    assert plan.sends
    plan.sends.pop()
    _both_reject(plan)


def test_validators_reject_send_from_wrong_home():
    plan = _fresh("mpiio")
    s = plan.sends[0]
    plan.sends[0] = SendItem(src_backend=(s.src_backend + 1) % 4,
                             dst_backend=s.dst_backend, src_rank=s.src_rank,
                             src_offset=s.src_offset, size=s.size, round=s.round)
    _both_reject(plan)


def test_validators_reject_false_stripe_disjoint_claim():
    c = theta_like(4, 2)
    sizes = [3 * MiB + 12345] * c.world_size  # unaligned => stripes shared
    plan = make_plan_reference("posix", c, sizes)
    plan.stripe_disjoint = True  # false claim -> both validators must catch
    _both_reject(plan)


def test_validators_reject_bad_rank():
    plan = _fresh("file_per_process")
    w = plan.writes[0]
    plan.writes[0] = WriteItem(backend=w.backend, file=w.file,
                               file_offset=w.file_offset, size=w.size,
                               src_rank=10_000, src_offset=w.src_offset)
    _both_reject(plan)
