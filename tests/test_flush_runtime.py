"""Adaptive flush runtime: supersession, throttling, resumable flushes.

The scheduler edge cases ISSUE 5 calls out:

* a superseded step's restore falls back to L1 (byte-identical);
* a resumed flush is byte-identical to an uninterrupted one, across
  all five strategies, rewriting only the unjournaled remainder;
* delta-base steps (full snapshots under ``zstd+delta``) are never
  superseded;
* ``flush_errors`` surfaces a mid-flush cancellation *correctly* —
  i.e. not at all: cancellation is a scheduling outcome, not a failure;
* ``close()`` never drops queued flushes silently — lost steps are
  enumerated and remain resumable.

Plus unit coverage for the runtime primitives (token bucket, progress
journal) and the sim/executor throttle-pricing agreement.
"""
import logging
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CheckpointConfig,
    CheckpointManager,
    FlushJournal,
    Manifest,
    TokenBucket,
    make_plan,
    simulate_flush,
    theta_like,
)
from repro.core.storage import CancelToken, FlushCancelled

ALL_STRATEGIES = ["file_per_process", "posix", "mpiio", "stripe_aligned", "gio_sync"]
MiB = 1 << 20


def state_tree(step=0):
    return {
        "params": {
            "w": jnp.arange(3000, dtype=jnp.float32).reshape(60, 50) + step,
            "b": jnp.full((64,), step, jnp.bfloat16),
        },
        "opt": {"mu": jnp.ones((40, 50), jnp.float32) * step,
                "count": jnp.array(step, jnp.int32)},
    }


def np_target():
    return jax.tree_util.tree_map(np.asarray, state_tree())


def assert_tree_equal(a, b):
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_array_equal(np.asarray(x), np.asarray(y)),
        a, b,
    )


# ---------------------------------------------------------------------------
# runtime primitives: token bucket + progress journal
# ---------------------------------------------------------------------------


def test_token_bucket_enforces_long_run_rate():
    tb = TokenBucket(rate=8 * MiB, burst=1 * MiB)
    t0 = time.perf_counter()
    waited = 0.0
    for _ in range(8):                 # 4 MiB through an 8 MiB/s bucket
        waited += tb.acquire(MiB // 2)
    elapsed = time.perf_counter() - t0
    # burst covers the first MiB; the remaining 3 MiB must take ~0.375 s
    assert elapsed >= 0.2
    assert waited > 0.0
    assert tb.wait_total >= waited - 1e-6


def test_token_bucket_cancel_aborts_throttled_acquire():
    tb = TokenBucket(rate=1024.0, burst=1024.0)
    tb.acquire(1 << 20)                # drive the bucket deep into debt
    token = CancelToken()
    token.cancel()
    with pytest.raises(FlushCancelled):
        tb.acquire(1, cancel=token)


def test_flush_journal_roundtrip_coverage_and_torn_tail(tmp_path):
    p = tmp_path / "flush_journal.bin"
    j = FlushJournal(p, flush_every=1)
    j.record(0, 0, 100)
    j.record(0, 100, 50)               # adjacent: merges with the first
    j.record(1, 10, 5)
    j.flush()
    # a torn trailing record (process death mid-append) must be ignored
    with open(p, "ab") as f:
        f.write(b"\x01\x02\x03")
    j2 = FlushJournal(p)
    assert len(j2.done) == 3
    assert j2.completed_bytes == 155
    assert j2.covers(0, 0, 150)        # merged interval
    assert j2.covers(0, 25, 100)
    assert not j2.covers(0, 100, 51)
    assert j2.covers(1, 10, 5)
    assert not j2.covers(1, 9, 5)
    assert not j2.covers(2, 0, 1)
    j2.unlink()
    assert not p.exists()
    assert len(FlushJournal(p).done) == 0


def test_flush_journal_pre_sync_runs_before_records_persist(tmp_path):
    """A journal record is a durability claim: the data-fd fsync hook
    must run strictly before each batch of records hits the file."""
    p = tmp_path / "flush_journal.bin"
    order = []
    j = FlushJournal(p, flush_every=2)
    j.pre_sync = lambda: order.append(("sync", p.stat().st_size if p.exists() else 0))
    j.record(0, 0, 10)
    assert not p.exists()                # buffered, no claim yet
    j.record(0, 10, 10)                  # batch full -> pre_sync + write
    assert order == [("sync", 0)]        # synced before any record landed
    assert p.stat().st_size == 2 * FlushJournal.RECORD
    j.record(1, 0, 5)
    j.flush()
    assert order[-1] == ("sync", 2 * FlushJournal.RECORD)


# ---------------------------------------------------------------------------
# supersession
# ---------------------------------------------------------------------------


def test_supersession_skips_stale_and_restore_falls_back_to_l1(tmp_path):
    """Saves faster than the drain: stale queued flushes are skipped,
    the newest step still reaches flush_done, superseded steps are not
    errors, and restoring a superseded step works from L1."""
    def slow(_w):
        time.sleep(0.05)

    mgr = CheckpointManager(
        CheckpointConfig(
            root=str(tmp_path), cluster=theta_like(2, 2),
            strategy="stripe_aligned", supersede_stale=True,
            max_pending_flushes=4,
        ),
        fault_hook=slow,
    )
    for s in range(1, 7):
        mgr.save(s, state_tree(s))
    mgr.wait()
    assert mgr.flush_errors == []
    skipped = mgr.superseded_steps
    assert skipped                      # the cadence outran the drain
    assert 6 not in skipped             # the newest step is never stale
    assert 6 in mgr.steps("pfs")
    by_step = {st.step: st for st in mgr.stats}
    for s in skipped:
        assert by_step[s].superseded
        assert by_step[s].flush is None
    # superseded-step restore: no flush_done PFS manifest -> L1 ladder
    mgr._l0 = None
    s = skipped[0]
    step, got = mgr.restore(np_target(), step=s)
    assert step == s
    assert_tree_equal(got, state_tree(s))
    mgr.close()


def test_mid_flush_cancellation_is_not_a_flush_error(tmp_path):
    """A flush cancelled mid-flight by supersession stops at a request
    boundary, is recorded as superseded (status="superseded" on disk),
    and never lands in flush_errors."""
    started = threading.Event()
    gate = threading.Event()

    def hook(_w):
        started.set()
        gate.wait(timeout=30)

    # 32 single-rank nodes -> 32 uncoalescable rows, more than the
    # 16-thread pool: cancellation lands between the two waves.
    mgr = CheckpointManager(
        CheckpointConfig(
            root=str(tmp_path), cluster=theta_like(32, 1),
            strategy="posix", supersede_stale=True, max_pending_flushes=2,
        ),
        fault_hook=hook,
    )
    small = {"x": jnp.ones((32 * 1024,), jnp.float32)}
    mgr.save(1, small)
    assert started.wait(timeout=10)     # step 1's flush is mid-flight
    mgr.save(2, small)                  # supersedes + cancels step 1
    gate.set()
    mgr.wait()
    assert mgr.flush_errors == []       # cancellation is not an error
    assert mgr.superseded_steps == [1]
    assert mgr.steps("pfs") == [2]
    man1 = Manifest.from_json(
        (mgr.pfs_dir / "step_00000001" / "manifest.json").read_text()
    )
    assert man1.status == "superseded"
    # and resume_flushes leaves the superseded partial alone
    assert mgr.resume_flushes() == {}
    mgr._l0 = None
    step, got = mgr.restore(jax.tree_util.tree_map(np.asarray, small), step=1)
    assert step == 1
    assert_tree_equal(got, small)
    mgr.close()


def test_delta_base_steps_are_never_superseded(tmp_path):
    """Full snapshots under zstd+delta anchor every delta chain: the
    scheduler must flush them even when stale."""
    def slow(_w):
        time.sleep(0.03)

    mgr = CheckpointManager(
        CheckpointConfig(
            root=str(tmp_path), cluster=theta_like(2, 2),
            strategy="stripe_aligned", codec="zstd+delta", delta_every=3,
            supersede_stale=True, max_pending_flushes=4,
        ),
        fault_hook=slow,
    )
    for s in range(1, 8):
        mgr.save(s, state_tree(s))
    mgr.wait()
    assert mgr.flush_errors == []
    full_steps = {1, 4, 7}              # delta_every=3 cadence anchors
    assert not (set(mgr.superseded_steps) & full_steps)
    pfs = set(mgr.steps("pfs"))
    assert full_steps <= pfs
    # every superseded delta still restores through the ladder
    mgr._l0 = None
    mgr._last_full = None
    for s in mgr.superseded_steps:
        step, got = mgr.restore(np_target(), step=s)
        assert step == s
        assert_tree_equal(got, state_tree(s))
    mgr.close()


def test_live_delta_window_survives_total_l1_loss(tmp_path):
    """Regression (confirmed repro): deltas chain through their
    predecessors, so pending steps inside the live delta window must
    never be superseded — otherwise a flush_done delta's base chain is
    missing from the PFS and node loss (the exact case L2 exists for)
    makes it unrestorable."""
    def slow(_w):
        time.sleep(0.03)

    cluster = theta_like(2, 2)
    mgr = CheckpointManager(
        CheckpointConfig(
            root=str(tmp_path), cluster=cluster, strategy="stripe_aligned",
            codec="zstd+delta", delta_every=8, supersede_stale=True,
            max_pending_flushes=4,
        ),
        fault_hook=slow,
    )
    for s in range(1, 5):
        mgr.save(s, state_tree(s))
    mgr.wait()
    assert mgr.flush_errors == []
    assert mgr.superseded_steps == []     # all four share one live window
    assert mgr.steps("pfs") == [1, 2, 3, 4]
    for n in range(cluster.n_nodes):      # total L1 loss
        mgr.local.drop_node(n)
    mgr._l0 = None
    mgr._last_full = None
    step, got = mgr.restore(np_target())  # PFS-only, full base chain
    assert step == 4
    assert_tree_equal(got, state_tree(4))
    mgr.close()


def test_full_app_net_load_still_throttles(tmp_path):
    """load -> 1.0 must floor the derived cap at the sim's 1e-3 derate,
    not flip the boundary value to 'unthrottled'."""
    from repro.core import ClusterSpec, NodeSpec

    cluster = ClusterSpec(
        n_nodes=2, procs_per_node=1, node=NodeSpec(app_net_load=1.0)
    )
    mgr = CheckpointManager(
        CheckpointConfig(root=str(tmp_path), cluster=cluster,
                         strategy="stripe_aligned", async_flush=False)
    )
    assert mgr._limiter is not None
    assert mgr._limiter.rate == pytest.approx(2 * cluster.node.nic_bw * 1e-3)
    mgr.close()


def test_keep_n_pins_steps_against_supersession(tmp_path):
    """Steps inside the keep_n newest window are retention-pinned: with
    keep_n covering every save, nothing may be superseded even under a
    slow drain."""
    def slow(_w):
        time.sleep(0.02)

    mgr = CheckpointManager(
        CheckpointConfig(
            root=str(tmp_path), cluster=theta_like(2, 2),
            strategy="stripe_aligned", supersede_stale=True,
            max_pending_flushes=4, keep_n=10,
        ),
        fault_hook=slow,
    )
    for s in range(1, 6):
        mgr.save(s, state_tree(s))
    mgr.wait()
    assert mgr.flush_errors == []
    assert mgr.superseded_steps == []
    assert mgr.steps("pfs") == [1, 2, 3, 4, 5]
    mgr.close()


def test_gc_reaps_superseded_steps(tmp_path):
    """Under supersession + keep_n, the L1 blobs, local manifests and
    partial PFS leavings of superseded steps must not accumulate past
    the retention window."""
    def slow(_w):
        time.sleep(0.03)

    mgr = CheckpointManager(
        CheckpointConfig(
            root=str(tmp_path), cluster=theta_like(2, 2),
            strategy="stripe_aligned", supersede_stale=True,
            max_pending_flushes=4, keep_n=2,
        ),
        fault_hook=slow,
    )
    for s in range(1, 9):
        mgr.save(s, state_tree(s))
    mgr.wait()
    assert mgr.flush_errors == []
    assert mgr.superseded_steps          # cadence outran the drain
    kept = mgr.steps("pfs")
    assert kept[-1] == 8
    reaped = [s for s in mgr.superseded_steps if s < min(kept)]
    assert reaped                        # something below the window
    for s in reaped:
        assert not mgr.local.has_blob(0, s, 0)
        assert not (mgr.root / "local" / "manifests"
                    / f"step_{s:08d}.json").exists()
        assert not (mgr.pfs_dir / f"step_{s:08d}").exists()
    for s in kept:                       # kept steps stay on both levels
        assert (mgr.root / "local" / "manifests"
                / f"step_{s:08d}.json").exists()
        assert (mgr.pfs_dir / f"step_{s:08d}" / "manifest.json").exists()
    mgr.close()


def test_gc_never_deletes_delta_bases_of_superseded_chains(tmp_path):
    """The GC base-chain walk must traverse superseded/partial
    manifests too: with delta + supersession + keep_n, the kept step's
    chain runs through superseded steps whose only durable copy is L1
    — deleting them would make every checkpoint unrestorable after
    restart."""
    def slow(_w):
        time.sleep(0.03)

    cfg = dict(cluster=theta_like(2, 2), strategy="stripe_aligned",
               codec="zstd+delta", delta_every=6)
    mgr = CheckpointManager(
        CheckpointConfig(root=str(tmp_path), supersede_stale=True,
                         max_pending_flushes=4, keep_n=1, **cfg),
        fault_hook=slow,
    )
    for s in range(1, 7):
        mgr.save(s, state_tree(s))
    mgr.wait()
    assert mgr.flush_errors == []
    mgr.close()
    # a fresh manager over the same root must restore the newest step
    mgr2 = CheckpointManager(CheckpointConfig(root=str(tmp_path), **cfg))
    step, got = mgr2.restore(np_target())
    assert step == 6
    assert_tree_equal(got, state_tree(6))
    mgr2.close()


# ---------------------------------------------------------------------------
# crash-resumable flushes
# ---------------------------------------------------------------------------


def test_fresh_flush_never_reuses_a_stale_journal(tmp_path):
    """A journal left by a previous incarnation of a step describes
    different bytes: a new flush of that step must ignore it entirely
    (fresh journal) or it would skip writes and mark corrupt data
    flush_done."""
    mgr = CheckpointManager(
        CheckpointConfig(root=str(tmp_path), cluster=theta_like(2, 2),
                         strategy="stripe_aligned", async_flush=False)
    )
    jp = mgr._journal_path(1)
    jp.parent.mkdir(parents=True, exist_ok=True)
    stale = FlushJournal(jp, flush_every=1)
    stale.record(0, 0, 1 << 30)          # "everything already written"
    stale.flush()
    st = mgr.save(1, state_tree(1))
    assert st.flush is not None
    assert st.flush.bytes_skipped == 0   # the stale cursor was discarded
    assert st.flush.bytes_written > 0
    # the PFS copy alone must round-trip (CRC-verified on arrival)
    for n in range(2):
        mgr.local.drop_node(n)
    mgr._l0 = None
    step, got = mgr.restore(np_target(), step=1)
    assert step == 1
    assert_tree_equal(got, state_tree(1))
    mgr.close()


def _pfs_payload_files(root):
    step_dirs = sorted((root / "pfs").glob("step_*"))
    out = {}
    for d in step_dirs:
        for p in sorted(d.iterdir()):
            if p.suffix == ".json" or p.name == "flush_journal.bin":
                continue
            out[f"{d.name}/{p.name}"] = p.read_bytes()
    return out


@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
def test_interrupted_flush_resumes_byte_identical(tmp_path, strategy):
    """Fault-hook interruption after ~80% of the bytes: the journal
    makes resume rewrite < 25% of the checkpoint, and the resumed PFS
    tree is byte-identical to an uninterrupted flush's."""
    tree = state_tree(5)
    cluster = theta_like(4, 2)
    kw = dict(
        cluster=cluster, strategy=strategy, async_flush=False,
        verify_on_restore=True,
    )
    ref_root = tmp_path / "ref"
    mgr_ref = CheckpointManager(CheckpointConfig(root=str(ref_root), **kw))
    mgr_ref.save(5, tree)
    mgr_ref.close()
    sizes = [r.stored_size for r in mgr_ref._manifest_pfs(5).ranks]
    total = sum(sizes)

    # deterministic interruption: exactly K of the plan's N coalesced
    # rows land, every later row fails (the hook is the serialization
    # point, so worker scheduling cannot change the journaled fraction)
    from repro.core.plan import coalesce_write_columns

    n_rows = len(coalesce_write_columns(
        make_plan(strategy, cluster, sizes).ensure_arrays().writes
    ))
    k_pass = min(n_rows - 1, max(1, int(np.ceil(0.8 * n_rows))))
    seen = {"rows": 0, "armed": True}
    hook_lock = threading.Lock()

    def hook(w):
        with hook_lock:
            if seen["armed"] and seen["rows"] >= k_pass:
                raise IOError("injected interruption")
            seen["rows"] += 1

    int_root = tmp_path / "interrupted"
    mgr = CheckpointManager(
        CheckpointConfig(root=str(int_root), **kw), fault_hook=hook
    )
    with pytest.raises(IOError):
        mgr.save(5, tree)
    man = Manifest.from_json(
        (mgr.pfs_dir / "step_00000005" / "manifest.json").read_text()
    )
    assert man.status == "flush_partial"
    assert (mgr.pfs_dir / "step_00000005" / "flush_journal.bin").exists()
    # not restorable from the PFS yet: the ladder falls back to L1
    assert mgr.steps("pfs") == []
    mgr._l0 = None
    step, got = mgr.restore(np_target(), step=5)
    assert step == 5
    assert_tree_equal(got, state_tree(5))

    seen["armed"] = False
    results = mgr.resume_flushes()
    assert list(results) == [5]
    res = results[5]
    assert res.bytes_written + res.bytes_skipped == total
    assert res.bytes_written < 0.25 * total      # the acceptance bound
    assert res.bytes_skipped > 0.75 * total
    assert not (mgr.pfs_dir / "step_00000005" / "flush_journal.bin").exists()
    assert mgr.steps("pfs") == [5]

    assert _pfs_payload_files(int_root) == _pfs_payload_files(ref_root)
    mgr._l0 = None
    step, got = mgr.restore(np_target(), step=5)
    assert step == 5
    assert_tree_equal(got, state_tree(5))
    mgr.close()


def test_resume_uses_partner_replicas_after_home_node_loss(tmp_path):
    """An interrupted flush must stay finishable through partner
    replicas — node loss is the exact case partner_replication covers,
    and resume reads the same L1 ladder restore does."""
    tree = state_tree(5)
    cluster = theta_like(3, 2)
    seen = {"rows": 0, "armed": True}
    hook_lock = threading.Lock()

    def hook(w):
        with hook_lock:
            if seen["armed"] and seen["rows"] >= 1:  # almost nothing lands
                raise IOError("injected interruption")
            seen["rows"] += 1

    mgr = CheckpointManager(
        CheckpointConfig(
            root=str(tmp_path), cluster=cluster, strategy="stripe_aligned",
            async_flush=False, partner_replication=True,
        ),
        fault_hook=hook,
    )
    with pytest.raises(IOError):
        mgr.save(5, tree)
    seen["armed"] = False
    mgr.local.drop_node(0)               # home of ranks 0-1 is gone
    results = mgr.resume_flushes()
    assert list(results) == [5]
    assert mgr.steps("pfs") == [5]
    mgr._l0 = None
    for n in range(cluster.n_nodes):     # PFS-only round trip
        mgr.local.drop_node(n)
    step, got = mgr.restore(np_target(), step=5)
    assert step == 5
    assert_tree_equal(got, state_tree(5))
    mgr.close()


def test_resume_survives_manager_restart(tmp_path):
    """Process-death shape: interrupt, build a *fresh* manager over the
    same root, resume there."""
    tree = state_tree(3)
    seen = {"rows": 0, "limit": 1 << 30}
    hook_lock = threading.Lock()

    def hook(w):
        with hook_lock:
            if seen["rows"] >= seen["limit"]:
                raise IOError("injected death")
            seen["rows"] += 1

    cfg = dict(cluster=theta_like(3, 2), strategy="stripe_aligned",
               async_flush=False)
    mgr = CheckpointManager(
        CheckpointConfig(root=str(tmp_path), **cfg), fault_hook=hook
    )
    # step 1 flushes unimpeded (and tells us the plan's row count)
    mgr.save(1, state_tree(1))
    n_rows = seen["rows"]
    seen["rows"], seen["limit"] = 0, max(1, (2 * n_rows) // 3)
    with pytest.raises(IOError):
        mgr.save(3, tree)
    mgr.close()

    mgr2 = CheckpointManager(CheckpointConfig(root=str(tmp_path), **cfg))
    results = mgr2.resume_flushes()
    assert list(results) == [3]
    assert results[3].bytes_skipped > 0
    assert sorted(mgr2.steps("pfs")) == [1, 3]
    step, got = mgr2.restore(np_target())
    assert step == 3
    assert_tree_equal(got, state_tree(3))
    mgr2.close()


def test_close_enumerates_and_preserves_undrained_flushes(tmp_path, caplog):
    """The seed bug: close() joined with a timeout, then dropped the
    queue.  Now: pending steps are enumerated in an error log, the
    in-flight flush is cancelled at a request boundary with journaled
    progress, and resume_flushes() finishes it."""
    started = threading.Event()
    gate = threading.Event()

    def hook(_w):
        started.set()
        gate.wait(timeout=15)

    mgr = CheckpointManager(
        CheckpointConfig(
            root=str(tmp_path), cluster=theta_like(32, 1), strategy="posix",
        ),
        fault_hook=hook,
    )
    small = {"x": jnp.ones((32 * 1024,), jnp.float32)}
    mgr.save(1, small)
    assert started.wait(timeout=10)
    with caplog.at_level(logging.ERROR, logger="repro.ckpt"):
        t = threading.Thread(target=lambda: (time.sleep(0.6), gate.set()))
        t.start()
        mgr.close(timeout=0.3)
        t.join()
    msgs = [r.getMessage() for r in caplog.records]
    assert any("still busy" in m and "[1]" in m for m in msgs)
    # the interrupted flush is resumable on a fresh manager
    if 1 not in mgr.steps("pfs"):      # cancelled before completion
        assert mgr.interrupted_steps == [1]
        mgr2 = CheckpointManager(
            CheckpointConfig(root=str(tmp_path), cluster=theta_like(32, 1),
                             strategy="posix")
        )
        assert list(mgr2.resume_flushes()) == [1]
        assert mgr2.steps("pfs") == [1]
        mgr2.close()


# ---------------------------------------------------------------------------
# interference-aware throttling
# ---------------------------------------------------------------------------


def test_real_flush_observes_flush_bw_cap(tmp_path):
    cap = 8 * MiB
    mgr = CheckpointManager(
        CheckpointConfig(
            root=str(tmp_path), cluster=theta_like(2, 2),
            strategy="stripe_aligned", flush_bw_cap=float(cap),
        )
    )
    state = {"x": jnp.zeros((MiB,), jnp.float32)}   # 4 MiB
    t0 = time.perf_counter()
    st = mgr.save(1, state)
    blocking = time.perf_counter() - t0
    mgr.wait()
    assert mgr.flush_errors == []
    # 4 MiB through an 8 MiB/s bucket with a 1 MiB burst: >= ~0.3 s of
    # drain, all of it off the blocking window
    assert st.flush is not None
    assert st.flush.duration >= 0.25
    assert st.flush.throttle_wait > 0.0
    assert blocking < st.flush.duration  # save() returned before the drain
    mgr.close()


def test_app_net_load_derives_cap_policy(tmp_path):
    from repro.core import NodeSpec, ClusterSpec

    cluster = ClusterSpec(
        n_nodes=2, procs_per_node=2,
        node=NodeSpec(app_net_load=0.5),
    )
    mgr = CheckpointManager(
        CheckpointConfig(root=str(tmp_path), cluster=cluster,
                         strategy="stripe_aligned", async_flush=False)
    )
    assert mgr._limiter is not None
    expected = 2 * cluster.node.nic_bw * 0.5
    assert mgr._limiter.rate == pytest.approx(expected)
    # explicit cap wins over the derived policy
    mgr2 = CheckpointManager(
        CheckpointConfig(root=str(tmp_path / "b"), cluster=cluster,
                         strategy="stripe_aligned", async_flush=False,
                         flush_bw_cap=123.0)
    )
    assert mgr2._limiter is not None and mgr2._limiter.rate == 123.0
    mgr.close()
    mgr2.close()


@pytest.mark.parametrize("strategy", ["stripe_aligned", "mpiio"])
def test_sim_prices_flush_bw_cap_consistently(strategy):
    """The simulator's flush_bw_cap is the same policy the executor's
    token bucket enforces: a cap well below the machine's bandwidth
    makes flush_time converge to total_bytes / cap (event-driven and
    barrier strategies alike)."""
    cluster = theta_like(8, 4)
    sizes = [4 * MiB] * cluster.world_size
    plan = make_plan(strategy, cluster, sizes)
    base = simulate_flush(plan, io_threads=4)
    cap = plan.total_bytes / (base.flush_time * 10)  # 10x slower than free
    capped = simulate_flush(plan, io_threads=4, flush_bw_cap=cap)
    assert capped.flush_bw_cap == pytest.approx(cap)
    assert capped.flush_time > base.flush_time
    assert capped.flush_time >= 0.8 * plan.total_bytes / cap
    # a cap far above the machine's bandwidth changes nothing material
    uncapped = simulate_flush(
        plan, io_threads=4, flush_bw_cap=1e3 * plan.total_bytes / base.flush_time
    )
    assert uncapped.flush_time == pytest.approx(base.flush_time, rel=0.05)


def test_concurrent_cold_start_during_flush_and_supersession(tmp_path):
    """Fleet stress: N threads cold-start from a settled step while a
    newer step's flush is mid-flight AND a supersession cancels that
    flush under them.  Every cold start must return byte-identical
    params (pinned to the settled step) and nothing may deadlock —
    reads share the executor's worker pool with the throttled writers.

    A tiny ``flush_bw_cap`` makes the mid-flight window deterministic:
    the newer flush's writers sit in ``TokenBucket.acquire`` (which a
    fired CancelToken aborts with FlushCancelled) while restore reads —
    which are never throttled — proceed on the free pool workers."""
    from repro.serve.stream import stream_restore

    armed = threading.Event()
    started = threading.Event()

    def hook(_w):
        if armed.is_set():
            started.set()

    def big(step):
        return {
            "params": {"w": jnp.full((1 << 20,), step, jnp.float32)},
            "opt": {"mu": jnp.full((64,), step, jnp.float32)},
        }

    mgr = CheckpointManager(
        CheckpointConfig(
            root=str(tmp_path), cluster=theta_like(8, 1),
            strategy="posix", supersede_stale=True,
            max_pending_flushes=2, flush_bw_cap=2 * MiB,
        ),
        fault_hook=hook,
    )
    try:
        mgr.save(1, big(1))
        mgr.wait()                          # step 1 settled on the PFS
        armed.set()
        mgr.save(2, big(2))                 # 4 MiB at 2 MiB/s: ~2 s window
        assert started.wait(timeout=10)     # step 2's flush is mid-flight

        n = 6
        results = [None] * n
        errors = []
        template = {"w": np.zeros((1 << 20,), np.float32)}

        def cold(i):
            try:
                sr = stream_restore(mgr, template, "['params']", step=1)
                results[i] = sr.params
            except Exception as e:  # pragma: no cover - failure reporting
                errors.append(e)

        threads = [threading.Thread(target=cold, args=(i,)) for i in range(n)]
        for t in threads:
            t.start()
        mgr.save(3, big(3))                 # supersession fires on step 2
        for t in threads:
            t.join(timeout=60)
            assert not t.is_alive(), "cold start deadlocked"
        mgr.wait()

        assert not errors
        ref = np.full((1 << 20,), 1, np.float32)
        for params in results:
            np.testing.assert_array_equal(params["w"], ref)
        assert mgr.flush_errors == []       # cancellation is not an error
        assert 2 in mgr.superseded_steps
        done = mgr.steps("pfs")
        assert 1 in done and 3 in done and 2 not in done
    finally:
        mgr.close()
