"""Trip-count-corrected HLO cost extraction (pure text-level tests +
a live nested-scan validation in a subprocess with >1 device)."""
import os
import subprocess
import sys
from pathlib import Path

from repro.launch.hlo_analysis import analyze_hlo, split_computations
from repro.launch.roofline import RooflineTerms, model_flops

HLO_TOY = """
HloModule toy, is_scheduled=true

%body (arg: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %arg = (s32[], f32[8,8]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%arg), index=1
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  %dot.1 = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8]{1,0} all-reduce(%dot.1), channel_id=1, replica_groups={}, to_apply=%sum
  ROOT %t = (s32[], f32[8,8]{1,0}) tuple(%ni, %ar)
}

%cond (arg2: (s32[], f32[8,8])) -> pred[] {
  %arg2 = (s32[], f32[8,8]{1,0}) parameter(0)
  %i2 = s32[] get-tuple-element(%arg2), index=0
  %n = s32[] constant(12)
  ROOT %lt = pred[] compare(%i2, %n), direction=LT
}

%sum (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (p: f32[8,8]) -> f32[8,8] {
  %p = f32[8,8]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,8]{1,0}) tuple(%zero, %p)
  %w = (s32[], f32[8,8]{1,0}) while(%init), condition=%cond, body=%body
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""


def test_split_and_multipliers():
    comps = split_computations(HLO_TOY)
    assert set(comps) == {"body", "cond", "sum", "main"}
    cost = analyze_hlo(HLO_TOY)
    # 12 iterations x one 8x8x8 dot
    assert cost.flops == 12 * 2 * 8 * 8 * 8
    assert cost.collectives["all-reduce"] == 12 * 8 * 8 * 4
    assert cost.n_while == 1
    assert cost.max_trip == 12


def test_roofline_terms_math():
    t = RooflineTerms(
        flops_per_dev=197e12, bytes_per_dev=819e9, coll_bytes_per_dev=0.0,
        n_chips=256, model_flops_global=197e12 * 256,
    )
    assert t.compute_s == 1.0
    assert t.memory_s == 1.0
    assert t.dominant == "compute"
    assert t.roofline_fraction == 1.0
    assert model_flops("train", 10, 2, 3) == 6 * 10 * 6
    assert model_flops("decode", 10, 4, 999) == 2 * 10 * 4


def test_live_nested_scan_counts():
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
from repro.launch.hlo_analysis import analyze_hlo
def f(x, w):
    def outer(c, _):
        def inner(c2, _):
            return c2 @ w, None
        y, _ = jax.lax.scan(inner, c, None, length=6)
        return y, None
    y, _ = jax.lax.scan(outer, x, None, length=5)
    return y
comp = jax.jit(f).lower(jax.ShapeDtypeStruct((32, 32), jnp.float32),
                        jax.ShapeDtypeStruct((32, 32), jnp.float32)).compile()
c = analyze_hlo(comp.as_text())
expect = 5 * 6 * 2 * 32 ** 3
assert c.flops == expect, (c.flops, expect)
print("OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=300, env=env, cwd=str(Path(__file__).resolve().parents[1]),
    )
    assert "OK" in out.stdout, out.stderr[-2000:]
