"""Training loop: learning happens, checkpoint resume is bit-exact."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import CheckpointConfig, CheckpointManager, theta_like
from repro.data import DataConfig, SyntheticTokens
from repro.launch.mesh import make_host_mesh
from repro.models import get_model
from repro.train import OptConfig, TrainConfig, init_train_state, make_train_step


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("tinyllama-1.1b")
    model = get_model(cfg)
    mesh = make_host_mesh()
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8)
    tcfg = TrainConfig(opt=OptConfig(lr=1e-2, warmup_steps=5, total_steps=60))
    data = SyntheticTokens(data_cfg)
    batch_struct = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), data.peek(0)
    )
    step_fn, _, _ = make_train_step(model, tcfg, mesh, batch_struct)
    return cfg, model, tcfg, data_cfg, step_fn


def test_loss_decreases(setup):
    cfg, model, tcfg, data_cfg, step_fn = setup
    data = SyntheticTokens(data_cfg)
    state = init_train_state(model, jax.random.PRNGKey(0), tcfg)
    losses = []
    for _ in range(30):
        state, metrics = step_fn(state, data.next())
        losses.append(float(metrics["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses


def test_grad_accumulation_equivalence(setup):
    cfg, model, _, data_cfg, _ = setup
    mesh = make_host_mesh()
    data = SyntheticTokens(data_cfg)
    batch = data.next()
    batch_struct = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch
    )
    out = {}
    for k in (1, 4):
        tcfg = TrainConfig(opt=OptConfig(lr=1e-3), microbatches=k)
        fn, _, _ = make_train_step(model, tcfg, mesh, batch_struct)
        state = init_train_state(model, jax.random.PRNGKey(1), tcfg)
        state, metrics = fn(state, batch)
        out[k] = (float(metrics["loss"]), state["params"])
    assert out[1][0] == pytest.approx(out[4][0], rel=1e-5)
    l1 = jax.tree_util.tree_leaves(out[1][1])
    l4 = jax.tree_util.tree_leaves(out[4][1])
    for a, b in zip(l1, l4):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=2e-4, atol=2e-5,
        )


def test_data_pipeline_deterministic_resume():
    cfg = DataConfig(vocab_size=1000, seq_len=16, global_batch=4, seed=9)
    d1 = SyntheticTokens(cfg)
    batches = [d1.next() for _ in range(5)]
    # resume from the state after batch 2
    d2 = SyntheticTokens(cfg)
    d2.next(); d2.next()
    d3 = SyntheticTokens(cfg, state=d2.state_tree())
    np.testing.assert_array_equal(
        np.asarray(d3.next()["tokens"]), np.asarray(batches[2]["tokens"])
    )


def test_train_ckpt_restore_bitexact(tmp_path, setup):
    cfg, model, tcfg, data_cfg, step_fn = setup
    data = SyntheticTokens(data_cfg)
    state = init_train_state(model, jax.random.PRNGKey(0), tcfg)
    mgr = CheckpointManager(
        CheckpointConfig(root=str(tmp_path), cluster=theta_like(2, 2),
                         strategy="stripe_aligned")
    )
    for i in range(4):
        state, _ = step_fn(state, data.next())
    mgr.save(4, {"train": state, "data": data.state_tree()})
    # snapshot the target template BEFORE step_fn donates these buffers
    target = {
        "train": jax.tree_util.tree_map(np.asarray, state),
        "data": {"batch_idx": np.asarray(0, np.int32)},
    }
    # continue to step 6 (ground truth)
    truth = state
    d_truth = SyntheticTokens(data_cfg, state=data.state_tree())
    for i in range(2):
        truth, _ = step_fn(truth, d_truth.next())
    mgr.wait()
    mgr._l0 = None
    step, restored = mgr.restore(target)
    assert step == 4
    r_state = jax.tree_util.tree_map(jnp.asarray, restored["train"])
    d_resume = SyntheticTokens(data_cfg)
    d_resume.load_state(restored["data"])
    for i in range(2):
        r_state, _ = step_fn(r_state, d_resume.next())
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        truth, r_state,
    )
    mgr.close()
