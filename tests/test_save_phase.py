"""Write-side execution pipeline: zero-copy encode, parallel local
phase, columnar flush execution.

The equivalence half mirrors tests/test_plan_arrays.py: the seed
item-loop paths survive as executable specs
(`repro.core.serialize_ref`, `RealExecutor.execute_reference`,
`parallel_local=False`) and every fast path must be byte-identical to
them.  The concurrency half exercises what the seed never could:
overlapping saves up to the backpressure bound, flush-stat delivery
races, and faults raised mid-parallel-flush.
"""
import itertools
import json
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CheckpointConfig,
    CheckpointManager,
    Manifest,
    Placement,
    make_plan,
    theta_like,
)
from repro.core.integrity import crc32
from repro.core.serialize import encode_state, serialize_tree
from repro.core.serialize_ref import (
    encode_state_reference,
    serialize_tree_reference,
)
from repro.core.storage import LocalStore, RealExecutor

ALL_STRATEGIES = ["file_per_process", "posix", "mpiio", "stripe_aligned", "gio_sync"]


def state_tree(step=0):
    return {
        "params": {
            "w": jnp.arange(3000, dtype=jnp.float32).reshape(60, 50) + step,
            "b": jnp.full((64,), step, jnp.bfloat16),
        },
        "opt": {"mu": jnp.ones((40, 50), jnp.float32) * step,
                "count": jnp.array(step, jnp.int32)},
    }


def np_target():
    return jax.tree_util.tree_map(np.asarray, state_tree())


def assert_tree_equal(a, b):
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_array_equal(np.asarray(x), np.asarray(y)),
        a, b,
    )


# ---------------------------------------------------------------------------
# crc32 buffer regression (satellite: no bytes() copy to hash a view)
# ---------------------------------------------------------------------------


def test_crc32_accepts_buffers_without_copy_semantics_change():
    payload = b"checkpoint bytes " * 4096
    ref = crc32(payload)
    assert crc32(memoryview(payload)) == ref
    assert crc32(bytearray(payload)) == ref
    assert crc32(np.frombuffer(payload, np.uint8)) == ref
    # read-only views of a numpy-backed stream (the encode path's shape)
    buf = np.frombuffer(payload, np.uint8).copy()
    assert crc32(memoryview(buf).toreadonly()) == ref
    # non-contiguous arrays still hash (via the compacting fallback)
    arr = np.arange(999, dtype=np.int64)
    assert crc32(arr[::3]) == crc32(arr[::3].copy().tobytes())


# ---------------------------------------------------------------------------
# zero-copy serialization equivalence
# ---------------------------------------------------------------------------


def mixed_tree():
    return {
        "f32": np.arange(501, dtype=np.float32),
        "f64_odd": np.ones((33,), np.float64),      # unaligned offsets downstream
        "i8": np.arange(7, dtype=np.int8),
        "fortran": np.asfortranarray(np.arange(24.0).reshape(4, 6)),
        "scalar": np.float32(2.5),
        "empty": np.empty((0, 3), np.float32),
        "bf16": jnp.full((11,), 1.25, jnp.bfloat16),
    }


def test_serialize_tree_matches_seed_reference():
    fast_stream, fast_leaves = serialize_tree(mixed_tree())
    ref_stream, ref_leaves = serialize_tree_reference(mixed_tree())
    assert fast_leaves == ref_leaves
    assert bytes(fast_stream) == ref_stream
    assert fast_stream.readonly


@pytest.mark.parametrize("codec", ["none", "zstd", "zstd+delta"])
def test_encode_state_matches_seed_reference(codec):
    """With whole-blob framing (chunk_size=0) the fast path must stay
    byte-identical to the seed encoder; chunk-framed equivalence is
    raw-stream-level and lives in tests/test_codec_pipeline.py."""
    c = theta_like(3, 2)
    fast = encode_state(1, mixed_tree(), c, codec=codec, chunk_size=0)
    ref = encode_state_reference(1, mixed_tree(), c, codec=codec)
    assert fast.manifest == ref.manifest
    assert [bytes(b) for b in fast.blobs] == [bytes(b) for b in ref.blobs]
    # delta against a prior step
    base_f = fast
    base_r = ref
    fast2 = encode_state(2, mixed_tree(), c, codec=codec, base=base_f, chunk_size=0)
    ref2 = encode_state_reference(2, mixed_tree(), c, codec=codec, base=base_r)
    assert fast2.manifest == ref2.manifest
    assert [bytes(b) for b in fast2.blobs] == [bytes(b) for b in ref2.blobs]


def test_codec_none_performs_zero_stream_copies():
    """The acceptance bar: with codec none, the state's bytes exist
    exactly once between the pytree and L1 — every rank blob is a
    read-only memoryview aliasing the one stream buffer."""
    c = theta_like(4, 2)
    enc = encode_state(3, mixed_tree(), c, codec="none")
    assert isinstance(enc.stream, memoryview) and enc.stream.readonly
    for blob in enc.blobs:
        assert isinstance(blob, memoryview)
        assert blob.obj is enc.stream.obj          # zero-copy: same buffer
    assert sum(len(b) for b in enc.blobs) == len(enc.stream)


def test_encode_pool_matches_sequential():
    from concurrent.futures import ThreadPoolExecutor

    c = theta_like(8, 4)
    with ThreadPoolExecutor(max_workers=8) as pool:
        pooled = encode_state(5, mixed_tree(), c, pool=pool)
    seq = encode_state(5, mixed_tree(), c)
    assert pooled.manifest == seq.manifest
    assert [bytes(b) for b in pooled.blobs] == [bytes(b) for b in seq.blobs]


# ---------------------------------------------------------------------------
# parallel local phase ≡ sequential reference, through the whole manager
# ---------------------------------------------------------------------------


def _tree_files(root):
    return sorted(
        p.relative_to(root).as_posix()
        for p in root.rglob("*")
        if p.is_file() and p.suffix != ".json"
    )


def _assert_checkpoint_dirs_identical(root_a, root_b):
    files_a, files_b = _tree_files(root_a), _tree_files(root_b)
    assert files_a == files_b
    for rel in files_a:
        assert (root_a / rel).read_bytes() == (root_b / rel).read_bytes(), rel


@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
@pytest.mark.parametrize("codec", ["none", "zstd", "zstd+delta"])
def test_parallel_local_phase_byte_identical(tmp_path, strategy, codec):
    """chunk_size=0 pins the whole-blob framing so fast vs reference
    stays a byte-level comparison; chunk-framed saves are covered by
    tests/test_codec_pipeline.py (raw-stream equivalence)."""
    cluster = theta_like(3, 2)
    roots = {}
    for name, fast in (("fast", True), ("ref", False)):
        root = tmp_path / name
        mgr = CheckpointManager(
            CheckpointConfig(
                root=str(root), cluster=cluster, strategy=strategy,
                codec=codec, delta_every=3, partner_replication=True,
                async_flush=False, parallel_local=fast, zero_copy=fast,
                chunk_size=0,
            )
        )
        for s in (1, 2, 3):
            mgr.save(s, state_tree(s))
        mgr.close()
        roots[name] = root
    _assert_checkpoint_dirs_identical(roots["fast"], roots["ref"])
    for s in (1, 2, 3):
        man_f = Manifest.from_json(
            (roots["fast"] / "pfs" / f"step_{s:08d}" / "manifest.json").read_text()
        )
        man_r = Manifest.from_json(
            (roots["ref"] / "pfs" / f"step_{s:08d}" / "manifest.json").read_text()
        )
        assert man_f == man_r


def test_fast_path_restores_across_levels(tmp_path):
    mgr = CheckpointManager(
        CheckpointConfig(root=str(tmp_path), cluster=theta_like(3, 2),
                         strategy="stripe_aligned")
    )
    mgr.save(7, state_tree(7))
    mgr.wait()
    assert not mgr.flush_errors
    # L0 (stream is a memoryview), then PFS, then L1
    step, got = mgr.restore(np_target())
    assert step == 7
    assert_tree_equal(got, state_tree(7))
    mgr._l0 = None
    step, got = mgr.restore(np_target())
    assert_tree_equal(got, state_tree(7))
    import shutil

    shutil.rmtree(mgr.pfs_dir / "step_00000007")
    mgr._man_cache.clear()
    step, got = mgr.restore(np_target())
    assert step == 7
    assert_tree_equal(got, state_tree(7))
    mgr.close()


# ---------------------------------------------------------------------------
# columnar executor ≡ item-loop reference executor
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("strategy,kw", [
    ("file_per_process", {}),
    ("posix", {}),
    ("mpiio", {"chunk_stripes": 2}),
    ("stripe_aligned", {"pipeline_chunk": 1 << 18}),
    ("gio_sync", {}),
])
def test_columnar_executor_byte_identical_files(tmp_path, strategy, kw):
    """`RealExecutor.execute` iterates PlanArrays columns (with
    coalescing, persistent pool); the seed item-loop `execute_reference`
    is the spec.  Same L1 input, same plan -> byte-identical PFS files."""
    cluster = theta_like(4, 2)
    rng = np.random.default_rng(7)
    sizes = rng.integers(1 << 16, 1 << 19, cluster.world_size).tolist()
    blobs = [rng.bytes(sz) for sz in sizes]
    local = LocalStore(tmp_path / "local", cluster.n_nodes)
    for step in (1, 2):  # identical L1 content for both steps
        for r, blob in enumerate(blobs):
            local.write_blob(cluster.node_of_rank(r), step, r, blob)
    plan = make_plan(strategy, cluster, sizes, **kw)
    ex = RealExecutor(tmp_path / "pfs", local, io_threads=4)
    res_fast = ex.execute(plan, 1)
    res_ref = ex.execute_reference(plan, 2)
    ex.close()
    assert res_fast.bytes_written == res_ref.bytes_written == sum(sizes)
    # coalescing may merge contiguous writes; never split or drop them
    assert res_fast.n_writes <= res_ref.n_writes
    files1 = sorted(p.name for p in (tmp_path / "pfs" / "step_00000001").iterdir())
    files2 = sorted(p.name for p in (tmp_path / "pfs" / "step_00000002").iterdir())
    assert files1 == files2
    for name in files1:
        a = (tmp_path / "pfs" / "step_00000001" / name).read_bytes()
        b = (tmp_path / "pfs" / "step_00000002" / name).read_bytes()
        assert a == b, name


def test_failed_batch_drains_before_reraise(tmp_path):
    """A worker exception mid-batch must not abandon in-flight tasks:
    with a persistent pool, stragglers would otherwise pwrite through
    fds the failed execute() already closed (and the OS may hand the
    fd numbers to the *next* step's files).  After a failed flush the
    pool stays usable and a subsequent flush is byte-correct."""
    cluster = theta_like(2, 2)
    sizes = [1 << 16] * cluster.world_size
    rng = np.random.default_rng(3)
    blobs = [rng.bytes(sz) for sz in sizes]
    local = LocalStore(tmp_path / "local", cluster.n_nodes)
    for step in (1, 2):
        for r, blob in enumerate(blobs):
            local.write_blob(cluster.node_of_rank(r), step, r, blob)
    plan = make_plan("posix", cluster, sizes)

    boom = itertools.count()
    hooks = {"on": True}

    def hook(_w):
        if hooks["on"] and next(boom) == 1:
            raise IOError("injected mid-batch failure")

    ex = RealExecutor(tmp_path / "pfs", local, io_threads=4, fault_hook=hook)
    with pytest.raises(IOError):
        ex.execute(plan, 1)
    hooks["on"] = False
    res = ex.execute(plan, 2)            # same pool, fresh fds
    assert res.bytes_written == sum(sizes)
    agg = (tmp_path / "pfs" / "step_00000002" / "aggregate.dat").read_bytes()
    assert agg == b"".join(blobs)
    ex.close()


def test_executor_pool_is_persistent(tmp_path):
    """One pool for the executor's lifetime: concurrent holders (an
    in-flight flush, a restore) must never have it swapped out and shut
    down under them, whatever worker count later callers request."""
    local = LocalStore(tmp_path / "local", 2)
    ex = RealExecutor(tmp_path / "pfs", local, io_threads=2)
    p1 = ex.pool(4)
    assert ex.pool(3) is p1
    assert ex.pool(64) is p1       # larger request: same pool, no swap
    assert ex.pool() is p1
    ex.close()
    assert ex._pool is None


# ---------------------------------------------------------------------------
# concurrency: overlapping saves, flush-stat delivery, faults mid-flush
# ---------------------------------------------------------------------------


def test_overlapping_saves_fill_flush_pipeline(tmp_path):
    """Saves overlap in-flight flushes up to max_pending_flushes; every
    step's FlushResult is delivered to its own SaveStats (the
    stats-by-step race fix) and the newest checkpoint restores."""
    mgr = CheckpointManager(
        CheckpointConfig(
            root=str(tmp_path), cluster=theta_like(2, 2),
            strategy="stripe_aligned", max_pending_flushes=2,
        )
    )
    for s in range(1, 9):
        mgr.save(s, state_tree(s))
    mgr.wait()
    assert not mgr.flush_errors
    assert [st.step for st in mgr.stats] == list(range(1, 9))
    for st in mgr.stats:
        assert st.flush is not None and not st.flush.failed
    mgr._l0 = None
    step, got = mgr.restore(np_target())
    assert step == 8
    assert_tree_equal(got, state_tree(8))
    mgr.close()


def test_concurrent_saves_and_flush_stats_no_lost_updates(tmp_path):
    """Hammer save() from the main thread while the flush worker
    delivers results: the old list-scan delivery could miss steps whose
    stats appended mid-scan; the dict-by-step delivery cannot."""
    mgr = CheckpointManager(
        CheckpointConfig(
            root=str(tmp_path), cluster=theta_like(1, 2),
            strategy="posix", max_pending_flushes=3,
        )
    )
    small = {"x": jnp.zeros((4096,), jnp.float32)}
    for s in range(1, 25):
        mgr.save(s, small)
    mgr.wait()
    assert not mgr.flush_errors
    missing = [st.step for st in mgr.stats if st.flush is None]
    assert missing == []
    mgr.close()


def test_fault_mid_parallel_flush_leaves_l1_restorable(tmp_path):
    """An active-backend crash partway through a parallel flush must
    leave the (parallel-written) L1 level restorable."""
    count = itertools.count()

    def bomb(_w):
        if next(count) == 2:
            raise IOError("injected backend crash")

    mgr = CheckpointManager(
        CheckpointConfig(
            root=str(tmp_path), cluster=theta_like(3, 2),
            strategy="stripe_aligned", partner_replication=True,
        ),
        fault_hook=bomb,
    )
    mgr.save(4, state_tree(4))
    mgr.wait()
    assert mgr.flush_errors and mgr.flush_errors[0][0] == 4
    assert mgr.steps("pfs") == []
    mgr._l0 = None
    step, restored = mgr.restore(np_target())
    assert step == 4
    assert_tree_equal(restored, state_tree(4))
    # and the partner replicas are real files too: drop a node, restore
    mgr.local.drop_node(1)
    step, restored = mgr.restore(np_target())
    assert step == 4
    assert_tree_equal(restored, state_tree(4))
    mgr.close()


def test_backpressure_still_bounds_parallel_saves(tmp_path):
    gate = threading.Event()

    def slow_hook(_w):
        gate.wait(timeout=30)

    mgr = CheckpointManager(
        CheckpointConfig(
            root=str(tmp_path), cluster=theta_like(1, 1),
            strategy="file_per_process", max_pending_flushes=1,
        ),
        fault_hook=slow_hook,
    )
    mgr.save(1, {"x": jnp.ones((1024,), jnp.float32)})
    done = threading.Event()

    def second_save():
        mgr.save(2, {"x": jnp.ones((1024,), jnp.float32)})
        done.set()

    t = threading.Thread(target=second_save, daemon=True)
    t.start()
    import time

    time.sleep(0.3)
    assert not done.is_set()          # blocked on backpressure
    gate.set()
    assert done.wait(timeout=30)
    mgr.wait()
    assert not mgr.flush_errors
    mgr.close()


# ---------------------------------------------------------------------------
# columnar manifest placement + manifest cache
# ---------------------------------------------------------------------------


def test_placement_roundtrip_and_legacy_json(tmp_path):
    mgr = CheckpointManager(
        CheckpointConfig(root=str(tmp_path), cluster=theta_like(2, 2),
                         strategy="stripe_aligned", async_flush=False)
    )
    mgr.save(1, state_tree(1))
    man = mgr._manifest_pfs(1)
    assert isinstance(man.placement, Placement)
    j = json.loads(man.to_json())
    # columnar persisted form: flat parallel lists, not a rank-keyed dict
    assert set(j["placement"]) == {
        "file_names", "rank", "file_id", "file_offset", "src_offset", "size"
    }
    again = Manifest.from_json(man.to_json())
    assert again.placement == man.placement
    assert again.file_layout().total == man.file_layout().total
    # legacy manifests (rank-keyed dict of tuples) still parse
    j["placement"] = {
        str(r): v for r, v in man.placement.by_rank().items()
    }
    legacy = Manifest.from_json(json.dumps(j))
    assert legacy.placement == man.placement
    np.testing.assert_array_equal(
        legacy.file_layout().start, man.file_layout().start
    )
    mgr.close()


def test_steps_caches_manifest_parsing(tmp_path, monkeypatch):
    mgr = CheckpointManager(
        CheckpointConfig(root=str(tmp_path), cluster=theta_like(2, 1),
                         strategy="stripe_aligned", async_flush=False)
    )
    for s in (1, 2, 3):
        mgr.save(s, state_tree(s))
    assert mgr.steps("pfs") == [1, 2, 3]
    assert mgr.steps("local") == [1, 2, 3]      # warm both levels

    calls = {"n": 0}
    orig = Manifest.from_json

    def counting(s):
        calls["n"] += 1
        return orig(s)

    monkeypatch.setattr(Manifest, "from_json", staticmethod(counting))
    assert mgr.steps("pfs") == [1, 2, 3]
    assert mgr.steps("local") == [1, 2, 3]
    assert calls["n"] == 0                      # all served from cache
    # a replaced manifest (new mtime/content) is re-parsed
    p = mgr.pfs_dir / "step_00000002" / "manifest.json"
    man = orig(p.read_text())
    tmp = p.with_suffix(".tmp")
    tmp.write_text(man.to_json())
    import os
    os.replace(tmp, p)
    os.utime(p, ns=(1, 1))                      # force a distinct mtime
    mgr.steps("pfs")
    assert calls["n"] >= 1
    mgr.close()
