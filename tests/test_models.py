"""Per-architecture smoke tests (reduced configs) + decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, cell_applicable, get_config, get_smoke_config
from repro.models import get_model
from repro.models import transformer as T

RNG = jax.random.PRNGKey(0)


def make_batch(cfg, b=2, s=16):
    batch = {"tokens": jax.random.randint(RNG, (b, s), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(RNG, (b, cfg.n_patches, cfg.d_model))
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(RNG, (b, cfg.enc_seq, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    params = model.init(RNG)
    batch = make_batch(cfg)
    logits, _ = model.forward(params, batch)
    s_expect = 16 + (cfg.n_patches if cfg.family == "vlm" else 0)
    assert logits.shape == (2, s_expect, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    loss, parts = model.loss(params, batch)
    assert np.isfinite(float(loss))
    # one gradient step moves the loss
    g = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    gn = sum(float(jnp.abs(x).sum()) for x in jax.tree_util.tree_leaves(g))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_decode(arch):
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    params = model.init(RNG)
    batch = make_batch(cfg, b=2, s=8)
    cache, logits = model.prefill(params, batch, s_max=12)
    assert logits.shape == (2, cfg.vocab_size)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, cache = model.decode_step(params, cache, tok)
    assert logits2.shape == (2, cfg.vocab_size)
    assert bool(jnp.isfinite(logits2).all())


@pytest.mark.parametrize(
    "arch", ["tinyllama-1.1b", "xlstm-350m", "recurrentgemma-2b", "whisper-small"]
)
def test_decode_matches_teacher_forcing(arch):
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    params = model.init(RNG)
    batch = make_batch(cfg, b=2, s=10)
    full, _ = model.forward(params, batch)
    cache, last = model.prefill(params, batch, s_max=12)
    np.testing.assert_allclose(
        np.asarray(last), np.asarray(full[:, -1]), rtol=2e-3, atol=2e-3
    )
    tok = jnp.argmax(last, -1)[:, None].astype(jnp.int32)
    lg2, _ = model.decode_step(params, cache, tok)
    batch2 = dict(batch)
    batch2["tokens"] = jnp.concatenate([batch["tokens"], tok], axis=1)
    full2, _ = model.forward(params, batch2)
    np.testing.assert_allclose(
        np.asarray(lg2), np.asarray(full2[:, -1]), rtol=2e-3, atol=2e-3
    )


def test_moe_capacity_drops_tokens_deterministically():
    cfg = get_smoke_config("qwen2-moe-a2.7b")
    model = get_model(cfg)
    params = model.init(RNG)
    batch = make_batch(cfg)
    l1, _ = model.loss(params, batch)
    l2, _ = model.loss(params, batch)
    assert float(l1) == float(l2)


def test_vlm_patches_change_text_logits():
    cfg = get_smoke_config("llava-next-mistral-7b")
    model = get_model(cfg)
    params = model.init(RNG)
    batch = make_batch(cfg)
    lo1, _ = model.forward(params, batch)
    batch2 = dict(batch)
    batch2["patches"] = batch["patches"] + 1.0
    lo2, _ = model.forward(params, batch2)
    # text positions attend to patch positions -> logits must differ
    assert float(jnp.abs(lo1[:, -1] - lo2[:, -1]).max()) > 1e-6


def test_window_attention_ignores_far_past():
    cfg = get_smoke_config("recurrentgemma-2b")  # window = 8
    model = get_model(cfg)
    params = model.init(RNG)
    toks = jax.random.randint(RNG, (1, 20), 0, cfg.vocab_size)
    toks2 = toks.at[:, 0].set((toks[:, 0] + 1) % cfg.vocab_size)
    f1, _ = model.forward(params, {"tokens": toks})
    f2, _ = model.forward(params, {"tokens": toks2})
    # position 0 is outside every window at the last position, but the
    # RG-LRU recurrence still carries it -> logits differ (hybrid), yet
    # remain finite and well-formed
    assert bool(jnp.isfinite(f1).all()) and bool(jnp.isfinite(f2).all())


def test_long_500k_applicability_matches_design():
    expected_runs = {"xlstm-350m", "recurrentgemma-2b"}
    cell = SHAPES["long_500k"]
    for arch in ARCHS:
        ok, why = cell_applicable(get_config(arch), cell)
        assert ok == (arch in expected_runs), (arch, why)


def test_exact_configs_match_table():
    c = get_config("qwen2-72b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab_size) == (
        80, 8192, 64, 8, 29568, 152064,
    ) and c.qkv_bias
    c = get_config("llama3-405b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab_size) == (
        126, 16384, 128, 8, 53248, 128256,
    )
    c = get_config("qwen2-moe-a2.7b")
    assert (c.moe.n_experts, c.moe.top_k, c.moe.n_shared) == (60, 4, 4)
    c = get_config("llama4-scout-17b-a16e")
    assert (c.moe.n_experts, c.moe.top_k) == (16, 1)
    c = get_config("recurrentgemma-2b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.window) == (
        26, 2560, 10, 1, 2048,
    )
    c = get_config("whisper-small")
    assert (c.n_layers, c.n_enc_layers, c.d_model, c.vocab_size) == (12, 12, 768, 51865)
