"""Columnar read planning: layout inversion, builder, validator, executor.

The read-side twin of tests/test_plan_arrays.py — every check compares
the array program against a brute-force byte-level simulation, and the
executor tests run against real files written by a real flush.
"""
import numpy as np
import pytest

from repro.core import (
    CheckpointConfig,
    CheckpointManager,
    FileLayout,
    make_plan,
    theta_like,
)
from repro.core.plan import (
    PlanError,
    ReadColumns,
    assign_readers,
    build_read_plan,
    coalesce_read_columns,
    stored_space_offsets,
    validate_read_plan,
)

STRATEGIES = ["file_per_process", "posix", "mpiio", "stripe_aligned", "gio_sync"]

SIZES = [3_000_001, 1_500_000, 0, 2_000_000, 777, 4_000_000, 123_456, 999_999]


def layout_for(strategy, cluster=None, sizes=None):
    cluster = cluster or theta_like(4, 2)
    sizes = sizes if sizes is not None else SIZES
    plan = make_plan(strategy, cluster, sizes, chunk_stripes=4)
    return FileLayout.from_flush_plan(plan), sizes


def materialize(layout, stored):
    """Brute-force: write the stored space into per-file byte arrays."""
    files = {nm: bytearray(sz) for nm, sz in layout.files.items()}
    for st, sz, f, fo in zip(
        layout.start.tolist(), layout.size.tolist(),
        layout.file_id.tolist(), layout.file_offset.tolist(),
    ):
        files[layout.file_names[f]][fo : fo + sz] = stored[st : st + sz]
    return files


def execute_in_memory(rp, files):
    """Brute-force read-plan executor against in-memory file images."""
    bufs = [bytearray(int(n)) for n in rp.req_size.tolist()]
    r = rp.reads
    for f, fo, sz, q, do in zip(
        r.file_id.tolist(), r.file_offset.tolist(), r.size.tolist(),
        r.dst_req.tolist(), r.dst_offset.tolist(),
    ):
        bufs[q][do : do + sz] = files[rp.file_names[f]][fo : fo + sz]
    return bufs


# ---------------------------------------------------------------------------
# stored-space helpers
# ---------------------------------------------------------------------------


def test_stored_space_offsets():
    np.testing.assert_array_equal(
        stored_space_offsets([3, 0, 5]), np.array([0, 3, 3, 8])
    )
    np.testing.assert_array_equal(stored_space_offsets([]), np.array([0]))


def test_assign_readers_balanced():
    sizes = [100] * 64
    a = assign_readers(sizes, 4)
    assert a.min() == 0 and a.max() == 3
    assert (np.diff(a) >= 0).all()  # contiguous
    _, counts = np.unique(a, return_counts=True)
    assert counts.tolist() == [16, 16, 16, 16]
    # skewed sizes still balance by bytes, not by count
    sizes = [1000] + [1] * 10
    a = assign_readers(sizes, 2)
    assert a[0] == 0 and (a[1:] == 1).all()
    # degenerate cases
    assert assign_readers([0, 0], 3).tolist() == [0, 0]
    assert assign_readers([5], 1).tolist() == [0]


# ---------------------------------------------------------------------------
# layout inversion
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_layout_inverts_every_strategy(strategy):
    layout, sizes = layout_for(strategy)
    assert layout.total == sum(sizes)
    # tiling is enforced by the constructor; spot-check the columns too
    ends = layout.start + layout.size
    assert layout.start[0] == 0 and int(ends[-1]) == layout.total
    assert (layout.start[1:] == ends[:-1]).all()


def test_layout_rejects_gaps():
    with pytest.raises(PlanError):
        FileLayout(
            file_names=["a"], files={"a": 10},
            start=[0, 6], size=[5, 4], file_id=[0, 0], file_offset=[0, 6],
            total=10,
        )
    with pytest.raises(PlanError):  # overlap
        FileLayout(
            file_names=["a"], files={"a": 10},
            start=[0, 4], size=[5, 6], file_id=[0, 0], file_offset=[0, 4],
            total=10,
        )
    with pytest.raises(PlanError):  # wrong total
        FileLayout(
            file_names=["a"], files={"a": 10},
            start=[0], size=[5], file_id=[0], file_offset=[0], total=10,
        )


# ---------------------------------------------------------------------------
# builder vs brute force
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_read_plan_matches_bruteforce(strategy):
    layout, sizes = layout_for(strategy)
    rng = np.random.default_rng(7)
    stored = bytes(rng.integers(0, 256, layout.total, dtype=np.uint8))
    files = materialize(layout, stored)

    # random scattered requests, including zero-size and whole-space
    starts = np.sort(rng.integers(0, layout.total, 40)).astype(np.int64)
    sz = np.minimum(
        rng.integers(0, 200_000, 40), layout.total - starts
    ).astype(np.int64)
    starts = np.concatenate([starts, [0, 0]])
    sz = np.concatenate([sz, [0, layout.total]])
    readers = rng.integers(0, 3, len(starts))

    rp = build_read_plan(layout, starts, sz, readers)
    bufs = execute_in_memory(rp, files)
    for a, n, got in zip(starts.tolist(), sz.tolist(), bufs):
        assert bytes(got) == stored[a : a + n]


def test_full_restore_reads_match_blobs():
    layout, sizes = layout_for("stripe_aligned")
    rng = np.random.default_rng(3)
    stored = bytes(rng.integers(0, 256, layout.total, dtype=np.uint8))
    files = materialize(layout, stored)
    offsets = stored_space_offsets(sizes)
    rp = build_read_plan(
        layout, offsets[:-1], sizes, assign_readers(sizes, 3)
    )
    bufs = execute_in_memory(rp, files)
    for r, (a, n) in enumerate(zip(offsets[:-1].tolist(), sizes)):
        assert bytes(bufs[r]) == stored[a : a + n]


def test_coalescing_merges_contiguous_file_runs():
    # posix: the whole stored space is one contiguous file run, so a
    # whole-space request must collapse to a single ranged read.
    layout, sizes = layout_for("posix")
    rp = build_read_plan(layout, [0], [layout.total])
    assert rp.n_reads == 1
    assert rp.total_bytes == layout.total


def test_builder_rejects_bad_requests():
    layout, _ = layout_for("posix")
    with pytest.raises(PlanError):
        build_read_plan(layout, [-1], [10])
    with pytest.raises(PlanError):
        build_read_plan(layout, [0], [layout.total + 1])
    with pytest.raises(PlanError):
        build_read_plan(layout, [0, 1], [1])


# ---------------------------------------------------------------------------
# validator
# ---------------------------------------------------------------------------


def _valid_plan():
    layout, sizes = layout_for("mpiio")
    offsets = stored_space_offsets(sizes)
    rp = build_read_plan(layout, offsets[:-1], sizes, coalesce=False)
    return rp, layout


def test_validator_catches_dropped_read():
    rp, layout = _valid_plan()
    r = rp.reads
    rp.reads = r.take(np.arange(1, len(r)))
    with pytest.raises(PlanError, match="gap|cover"):
        validate_read_plan(rp, layout)


def test_validator_catches_wrong_file_offset():
    rp, layout = _valid_plan()
    rp.reads.file_offset[0] += 1
    with pytest.raises(PlanError):
        validate_read_plan(rp, layout)


def test_validator_catches_out_of_bounds_read():
    rp, layout = _valid_plan()
    rp.files = {nm: 1 for nm in rp.files}
    with pytest.raises(PlanError, match="past declared size"):
        validate_read_plan(rp, layout)


def test_validator_accepts_coalesced_multi_extent_reads():
    rp, layout = _valid_plan()
    validate_read_plan(rp, layout)
    coalesced = coalesce_read_columns(rp.reads)
    assert len(coalesced) <= len(rp.reads)
    rp.reads = coalesced
    validate_read_plan(rp, layout)  # spans are split at extent boundaries


def test_validator_catches_dst_overlap():
    layout, _ = layout_for("posix")
    rp = build_read_plan(layout, [0], [100])
    r = rp.reads
    rp.reads = ReadColumns(
        reader=np.concatenate([r.reader, r.reader]),
        file_id=np.concatenate([r.file_id, r.file_id]),
        file_offset=np.concatenate([r.file_offset, r.file_offset]),
        size=np.concatenate([r.size, r.size]),
        dst_req=np.concatenate([r.dst_req, r.dst_req]),
        dst_offset=np.concatenate([r.dst_offset, r.dst_offset]),
    )
    with pytest.raises(PlanError):
        validate_read_plan(rp, layout)


# ---------------------------------------------------------------------------
# real executor
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_executor_reads_real_flush(tmp_path, strategy):
    """Flush with one strategy, read back through an aggregated plan, and
    compare byte-for-byte with the encoded blobs."""
    import jax.numpy as jnp

    state = {"w": jnp.arange(40_000, dtype=jnp.float32),
             "b": jnp.ones((1000,), jnp.int32)}
    mgr = CheckpointManager(
        CheckpointConfig(root=str(tmp_path), cluster=theta_like(3, 2),
                         strategy=strategy, async_flush=False)
    )
    mgr.save(2, state)
    assert not mgr.flush_errors
    man = mgr._manifest_pfs(2)

    # one aggregated plan for all blobs == per-rank read_rank_blob
    by_rank = mgr._read_blobs_pfs(man, 2)
    for r in range(man.world_size):
        assert by_rank[r] == mgr.executor.read_rank_blob(man, 2, r)
        # and both equal the L1 ground truth
        node = r // man.procs_per_node
        assert by_rank[r] == mgr.local.read_blob(node, 2, r)
    assert mgr.last_read_result.bytes_read == man.total_stored_bytes
    mgr.close()


def test_partial_leaf_reads_only_leaf_bytes(tmp_path):
    """codec='none' partial restore touches exactly the leaves' bytes."""
    import jax.numpy as jnp

    state = {"big": jnp.zeros((1 << 16,), jnp.float32),
             "small": jnp.arange(100, dtype=jnp.int32)}
    mgr = CheckpointManager(
        CheckpointConfig(root=str(tmp_path), cluster=theta_like(2, 2),
                         strategy="stripe_aligned", async_flush=False)
    )
    mgr.save(1, state)
    step, got = mgr.restore_leaves(["['small']"])
    assert step == 1
    np.testing.assert_array_equal(got["['small']"], np.arange(100, dtype=np.int32))
    assert mgr.last_read_result.bytes_read == 400  # 100 x int32, nothing more
    mgr.close()


def test_restore_leaves_unknown_name_raises(tmp_path):
    import jax.numpy as jnp

    mgr = CheckpointManager(
        CheckpointConfig(root=str(tmp_path), cluster=theta_like(2, 1),
                         strategy="posix", async_flush=False)
    )
    mgr.save(1, {"x": jnp.zeros((8,), jnp.float32)})
    with pytest.raises(FileNotFoundError, match="leaves not in checkpoint"):
        mgr.restore_leaves(["['nope']"])
    mgr.close()


def test_assign_readers_property_sweep():
    """Property sweep (hypothesis-style; the library is not vendored,
    so cases come from seeded generators): for adversarial stored
    layouts and arbitrary N-writer -> M-reader geometries,
    ``assign_readers`` must (1) assign every blob, (2) keep assignments
    contiguous and monotonic, (3) stay within the midpoint balance
    bound — no reader carries more than an even byte share plus one
    largest blob.
    """
    rng = np.random.default_rng(0xA55E7)

    def cases():
        for case in range(120):
            n_readers = int(rng.integers(1, 20))
            n_blobs = int(rng.integers(1, 200))
            kind = case % 5
            if kind == 0:      # uniform
                sizes = rng.integers(0, 1 << 20, n_blobs)
            elif kind == 1:    # power-law skew
                sizes = (rng.pareto(0.5, n_blobs) * 4096).astype(np.int64)
            elif kind == 2:    # one giant among dust
                sizes = rng.integers(0, 64, n_blobs)
                sizes[rng.integers(0, n_blobs)] = 1 << 28
            elif kind == 3:    # many zeros (empty ranks)
                sizes = rng.integers(0, 4096, n_blobs)
                sizes[rng.random(n_blobs) < 0.5] = 0
            else:              # N -> M: more readers than blobs
                n_readers = int(rng.integers(n_blobs, n_blobs + 50))
                sizes = rng.integers(1, 1 << 16, n_blobs)
            yield sizes.astype(np.int64), n_readers
        yield np.zeros(17, np.int64), 5          # all-empty layout
        yield np.asarray([1], np.int64), 19      # single tiny blob, many readers

    for sizes, n_readers in cases():
        a = assign_readers(sizes, n_readers)
        ctx = (sizes[:8], n_readers)
        # (1) full coverage: one reader per blob, all in range
        assert len(a) == len(sizes), ctx
        assert a.min() >= 0 and a.max() < n_readers, ctx
        # (2) contiguous + monotonic: each reader owns one run of blobs
        assert (np.diff(a) >= 0).all(), ctx
        # (3) byte-balance bound from the midpoint rule
        per = np.zeros(n_readers, np.int64)
        np.add.at(per, a, sizes)
        assert per.sum() == sizes.sum(), ctx
        total = int(sizes.sum())
        if total == 0:
            assert (a == 0).all(), ctx
            continue
        bound = total / n_readers + int(sizes.max())
        assert per.max() <= bound + 1e-9, (per.max(), bound, ctx)
