"""Multi-tenant control plane: arbitration, quotas, preemption, recovery.

ISSUE 10 coverage:

* concurrency stress — ≥ 8 tenants of interleaved save/restore/GC on
  ONE PFS root, per-tenant byte-identical restores, and no
  cross-tenant manifest leakage in ``list_steps``;
* admission preemption — a queued low-priority flush yields its slot
  to a higher-priority tenant, parks journaled/resumable, and still
  reaches ``flush_done``;
* the shared-budget regression (seed bug: per-manager
  ``BoundedSemaphore`` let co-located managers exceed
  ``max_pending_flushes``);
* fair-share bucket properties (hypothesis) and the two-tenant
  sim-vs-real throttle pricing equivalence at the single-job test's
  tolerance;
* registry crash-restart recovery, pins vs GC, shared-breaker outage
  isolation with priority-ordered drain, and the fleet's control-plane
  subscription path.
"""
import threading
import time

import numpy as np
import pytest

from repro.control import (
    AdmissionController,
    ControlPlane,
    FairShareLimiter,
    fair_share_rates,
)
from repro.core import (
    CheckpointConfig,
    CheckpointManager,
    ClusterSpec,
    make_plan,
    simulate_flush_shared,
    theta_like,
)
from repro.core.faults import FaultPlan, FaultSpec

MiB = 1 << 20


def cluster(n_nodes=2, ppn=2):
    return ClusterSpec(n_nodes=n_nodes, procs_per_node=ppn)


def tenant_state(name, step, kb=48):
    """Per-tenant, per-step state whose bytes encode both identities."""
    seed = (hash(name) & 0xFFFF) * 1000 + step
    rng = np.random.default_rng(seed)
    return {
        "w": rng.standard_normal((kb * 1024 // 8,)).astype(np.float64),
        "s": np.full((16,), step, np.int32),
    }


def trees_equal(a, b):
    return all(
        np.array_equal(np.asarray(a[k]), np.asarray(b[k])) for k in a
    )


# ---------------------------------------------------------------------------
# concurrency stress: >= 8 tenants on one root
# ---------------------------------------------------------------------------


def test_eight_tenant_stress_isolated_and_byte_identical(tmp_path):
    n_tenants = 8
    cp = ControlPlane(str(tmp_path), max_pending_flushes=2 * n_tenants)
    strategies = ["posix", "file_per_process", "mpiio", "stripe_aligned"]
    names = [f"team{i}" for i in range(n_tenants)]
    for i, n in enumerate(names):
        cp.register_job(
            n, cluster(), priority=1.0 + (i % 3), keep_n=2,
            strategy=strategies[i % len(strategies)], codec="none",
        )
    errors = []

    def client(name):
        try:
            m = cp.manager(name)
            for s in (1, 2, 3):
                m.save(s, tenant_state(name, s))
                if s == 2:  # interleave a mid-run restore with live flushes
                    got_s, got = m.restore(tenant_state(name, 0))
                    assert trees_equal(got, tenant_state(name, got_s))
            m.wait()
            got_s, got = m.restore(tenant_state(name, 0))
            assert got_s == 3 and trees_equal(got, tenant_state(name, 3))
        except BaseException as e:  # surfaced below, never swallowed
            errors.append((name, e))

    threads = [threading.Thread(target=client, args=(n,)) for n in names]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert errors == []
    for n in names:
        steps = cp.list_steps(n)
        # keep_n=2 GC ran per tenant; the newest steps survive
        assert steps[-1] == 3 and set(steps) <= {1, 2, 3}
        # no cross-tenant leakage: the listing is exactly this
        # tenant's namespace, and its bytes restore to ITS state
        got_s, got = cp.manager(n).restore(tenant_state(n, 0))
        assert got_s == 3 and trees_equal(got, tenant_state(n, 3))
    # every tenant's manifests live under its own subtree only
    for n in names:
        others = set(names) - {n}
        for d in (tmp_path / "jobs" / n).rglob("manifest.json"):
            assert not any(o in str(d.relative_to(tmp_path / "jobs" / n))
                           for o in others)
    cp.close()


# ---------------------------------------------------------------------------
# admission: shared budget + priority preemption
# ---------------------------------------------------------------------------


def test_shared_admission_budget_regression(tmp_path):
    """Seed bug (engine.py `_slots`): two managers, each configured with
    max_pending_flushes=2, could hold 4 slots between them.  Sharing one
    AdmissionController caps the CLUSTER at 2: a third save blocks until
    a slot frees."""
    ac = AdmissionController(2)
    mgrs = []
    for i in range(2):
        cfg = CheckpointConfig(
            root=str(tmp_path / f"m{i}"), cluster=cluster(),
            strategy="posix", codec="none", async_flush=True,
            max_pending_flushes=2, flush_bw_cap=1 * MiB,  # slow drain
        )
        mgrs.append(CheckpointManager(cfg, admission=ac, tenant=f"m{i}"))
    try:
        # 2 MiB states exceed the bucket burst, so each flush takes ~1 s
        mgrs[0].save(1, tenant_state("m0", 1, kb=2048))
        mgrs[1].save(1, tenant_state("m1", 1, kb=2048))
        assert ac.held() == 2 and ac.available() == 0
        done = threading.Event()

        def third():
            mgrs[0].save(2, tenant_state("m0", 2, kb=2048))
            done.set()

        t = threading.Thread(target=third)
        t.start()
        # the third save must block on the cluster budget (the seed
        # runtime would have admitted it instantly through m0's own
        # second slot)
        assert not done.wait(0.3)
        assert ac.held() == 2
        t.join(timeout=60)
        assert done.is_set()
    finally:
        for m in mgrs:
            m.close()
    assert ac.held() == 0


def test_priority_preemption_parks_queued_flush_resumably(tmp_path):
    cap = 4 * MiB
    cp = ControlPlane(str(tmp_path), flush_bw_cap=cap, max_pending_flushes=2)
    lo = cp.register_job(
        "lo", cluster(), priority=1.0, strategy="posix", codec="none",
        health_tick=0.05,
    )
    hi = cp.register_job(
        "hi", cluster(), priority=10.0, strategy="posix", codec="none",
        health_tick=0.05,
    )
    try:
        # lo fills the cluster budget: step 1 goes mid-flight (slowed by
        # the cap), step 2 sits queued behind it
        lo.save(1, tenant_state("lo", 1, kb=2048))
        lo.save(2, tenant_state("lo", 2, kb=64))
        assert cp.admission.held() == 2
        t0 = time.perf_counter()
        hi.save(1, tenant_state("hi", 1, kb=64))
        acquired_in = time.perf_counter() - t0
        # hi got its slot by preempting lo's QUEUED step 2 — well before
        # lo's mid-flight multi-second step-1 flush could have finished
        assert cp.admission.preemptions == 1
        assert acquired_in < 2.0
        deadline = time.monotonic() + 30
        while 2 not in lo.health().parked_steps:
            assert time.monotonic() < deadline
            time.sleep(0.01)
        assert lo.step_status(2) == "flush_partial"  # journaled, resumable
        assert lo.flush_errors == []
        # budget never exceeded
        assert cp.admission.held() <= 2
        # once the burst drains, the parked step auto-resumes to
        # flush_done (budget headroom gates the drain)
        deadline = time.monotonic() + 60
        while lo.step_status(2) != "flush_done":
            assert time.monotonic() < deadline
            time.sleep(0.05)
        lo.wait(), hi.wait()
        got_s, got = cp.manager("lo").restore(tenant_state("lo", 0, kb=64))
        assert got_s == 2 and trees_equal(got, tenant_state("lo", 2, kb=64))
    finally:
        cp.close()


# ---------------------------------------------------------------------------
# fair-share bucket: hypothesis properties + runtime rates
# ---------------------------------------------------------------------------


def test_fair_share_runtime_rates_follow_demand():
    f = FairShareLimiter(100.0)
    a = f.register("a", weight=1.0)
    b = f.register("b", weight=3.0)
    a.add_demand(1)
    assert a.rate == pytest.approx(100.0)  # idle b's share redistributed
    b.add_demand(1)
    assert a.rate == pytest.approx(25.0)
    assert b.rate == pytest.approx(75.0)
    a.sub_demand(1)
    assert b.rate == pytest.approx(100.0)
    f.unregister("a")
    assert f.tenants() == ["b"]


def test_fair_share_acquire_implies_demand():
    f = FairShareLimiter(64 * MiB)
    a = f.register("a")
    f.register("b")
    # no declared backlog: the acquire itself must register demand and
    # proceed at a real rate, not starve on the idle trickle
    t0 = time.perf_counter()
    a.acquire(1 * MiB)
    assert time.perf_counter() - t0 < 5.0
    assert a.rate >= 32 * MiB - 1


try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional dep
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    @settings(max_examples=200, deadline=None)
    @given(
        st.lists(
            st.tuples(
                st.floats(0.01, 100.0),   # weight
                st.floats(0.0, 1e9),      # demand (0 = idle)
            ),
            min_size=1,
            max_size=12,
        ),
        st.floats(1.0, 1e9),              # cap
    )
    def test_fair_share_properties(wd, cap):
        weights = [w for w, _ in wd]
        demands = [d for _, d in wd]
        r = fair_share_rates(weights, demands, cap)
        tol = 1e-6 * max(1.0, cap)
        # granted rates never exceed the cap or the tenant's own demand
        assert r.sum() <= cap + tol
        assert all(ri <= di + tol for ri, di in zip(r, demands))
        # no backlogged tenant starves: each gets >= its weighted share
        # of the cap (or its full demand, whichever is smaller)
        total_w = sum(w for w, d in zip(weights, demands) if d > 0)
        for ri, wi, di in zip(r, weights, demands):
            if di > 0:
                floor = min(di, cap * wi / total_w)
                assert ri >= floor - tol
        # idle tenants take nothing; their share is fully redistributed
        assert all(ri == 0 for ri, di in zip(r, demands) if di == 0)
        assert r.sum() == pytest.approx(
            min(cap, sum(demands)), rel=1e-6, abs=tol
        )


# ---------------------------------------------------------------------------
# sim-vs-real pricing equivalence (two throttled managers, one cap)
# ---------------------------------------------------------------------------


def test_two_tenant_throttle_prices_like_the_sim(tmp_path):
    """Two equal-weight tenants saturating one 8 MiB/s cap must each be
    priced like a single-job flush_bw_cap of 4 MiB/s — by the sim
    (`simulate_flush_shared`) and by the real runtime, within the same
    0.8x tolerance the single-job throttle test uses."""
    cap = 8 * MiB
    # >> the 1 MiB bucket burst, so the fluid sim's burstless price and
    # the real bucket's price converge
    per_tenant_bytes = 8 * MiB
    cp = ControlPlane(str(tmp_path), flush_bw_cap=cap, max_pending_flushes=4)
    c = theta_like(2, 2)
    mgrs = [
        cp.register_job(f"j{i}", c, strategy="posix", codec="none")
        for i in range(2)
    ]
    try:
        state = {"w": np.ones(per_tenant_bytes // 8, np.float64)}
        barrier = threading.Barrier(2)

        def run(m):
            barrier.wait()
            m.save(1, state)
            m.wait()

        threads = [threading.Thread(target=run, args=(m,)) for m in mgrs]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        elapsed = time.perf_counter() - t0
        from repro.core import simulate_flush

        stored = [r.stored_size for r in mgrs[0]._manifest_pfs(1).ranks]
        plan = make_plan("posix", c, stored)
        sims = simulate_flush_shared([plan, plan], flush_bw_cap=cap)
        single = simulate_flush(plan, flush_bw_cap=cap / 2)
        for m, sim in zip(mgrs, sims):
            # pricing identity: each saturated equal-weight tenant is
            # priced exactly like a single job capped at its cap/2 share
            assert sim.flush_time == pytest.approx(single.flush_time)
            # the single-job sim test's 0.8x floor, at the granted share
            assert sim.flush_time >= 0.8 * plan.total_bytes / (cap / 2)
            real = m.stats[0].flush
            assert real is not None and real.throttle_wait > 0
            # the real bucket grants a 1 MiB burst and lets the final
            # per-rank charge (total/4) ride as pay-ahead debt; net of
            # that credit the 0.8x floor applies to the wall clock too
            credit = 1 * MiB + plan.total_bytes / 4
            assert real.duration >= (
                0.8 * (plan.total_bytes - credit) / (cap / 2)
            )
        # aggregate: two tenants' bytes through one cap
        assert elapsed >= 0.8 * (2 * plan.total_bytes - 2 * credit) / cap
    finally:
        cp.close()


# ---------------------------------------------------------------------------
# registry: crash-restart recovery, pins, GC policy
# ---------------------------------------------------------------------------


def test_registry_crash_restart_recovery(tmp_path):
    cp = ControlPlane(str(tmp_path), max_pending_flushes=4)
    m = cp.register_job(
        "prod", cluster(), priority=2.0, keep_n=3,
        strategy="stripe_aligned", codec="none",
    )
    for s in (1, 2):
        m.save(s, tenant_state("prod", s))
    m.wait()
    cp.pin("prod", 1)
    cp.close()  # "crash": only the on-disk registry + manifests survive

    cp2 = ControlPlane(str(tmp_path), max_pending_flushes=4)
    assert cp2.jobs() == ["prod"]
    rec = cp2.record("prod")
    assert rec.priority == 2.0 and rec.keep_n == 3
    assert rec.config["strategy"] == "stripe_aligned"
    assert rec.pinned == [1]
    assert cp2.list_steps("prod") == [1, 2]
    m2 = cp2.manager("prod")  # lazily rebuilt from the record
    assert m2.pinned_steps() == [1]
    got_s, got = m2.restore(tenant_state("prod", 0))
    assert got_s == 2 and trees_equal(got, tenant_state("prod", 2))
    cp2.close()


def test_pin_survives_gc_and_unpin_releases(tmp_path):
    cp = ControlPlane(str(tmp_path), max_pending_flushes=4)
    m = cp.register_job(
        "j", cluster(), keep_n=1, strategy="posix", codec="none",
    )
    try:
        m.save(1, tenant_state("j", 1))
        m.wait()
        cp.pin("j", 1)
        for s in (2, 3):
            m.save(s, tenant_state("j", s))
            m.wait()
        assert 1 in cp.list_steps("j")  # keep_n=1 alone would have reaped it
        got_s, got = m.restore(tenant_state("j", 0), 1)
        assert got_s == 1 and trees_equal(got, tenant_state("j", 1))
        cp.unpin("j", 1)
        m.save(4, tenant_state("j", 4))
        m.wait()
        assert 1 not in cp.list_steps("j")
        assert cp.list_steps("j")[-1] == 4
    finally:
        cp.close()


def test_per_tenant_gc_policy(tmp_path):
    cp = ControlPlane(str(tmp_path), max_pending_flushes=8)
    a = cp.register_job("a", cluster(), keep_n=1, strategy="posix",
                        codec="none")
    b = cp.register_job("b", cluster(), strategy="posix", codec="none")
    try:
        for s in (1, 2, 3):
            a.save(s, tenant_state("a", s))
            b.save(s, tenant_state("b", s))
            a.wait(), b.wait()
        assert cp.list_steps("a") == [3]      # keep_n=1
        assert cp.list_steps("b") == [1, 2, 3]  # no GC policy
        cp.set_gc_policy("b", 2)
        b.save(4, tenant_state("b", 4))
        b.wait()
        assert cp.list_steps("b") == [3, 4]
        assert cp.record("b").keep_n == 2  # persisted
    finally:
        cp.close()


# ---------------------------------------------------------------------------
# chaos: shared breaker, tenant isolation, priority drain order
# ---------------------------------------------------------------------------


def test_outage_on_one_tenant_isolates_and_drains_by_priority(tmp_path):
    """PFS outage while tenant A flushes: the SHARED breaker opens (one
    PFS, one truth), so B's flushes park — but B's L1 saves never park,
    never fail, never burn a retry.  After heal, `drain()` publishes
    the higher-priority tenant's parked steps first."""
    # max_index=1 pins the outage to the victim's first PFS write
    plans = FaultPlan.generate_fleet(11, 2, victim=0, outage_ops=10**9,
                                     max_index=1)
    cp = ControlPlane(
        str(tmp_path), max_pending_flushes=8,
        health_min_ops=2, health_cooldown=0.05,
    )
    common = dict(
        strategy="posix", codec="none",
        retry_base_delay=0.001, retry_max_delay=0.002,
        health_min_ops=2, health_cooldown=0.05, health_tick=10.0,
    )
    vic = cp.register_job("victim", cluster(), priority=1.0,
                          faults=plans[0], **common)
    oth = cp.register_job("other", cluster(), priority=5.0,
                          faults=plans[1], **common)
    vic.faults.arm("save")
    done_order = []
    cp.subscribe("victim", lambda s: done_order.append(("victim", s)))
    cp.subscribe("other", lambda s: done_order.append(("other", s)))
    try:
        vic.save(1, tenant_state("victim", 1))
        deadline = time.monotonic() + 30
        while cp.health_state() == "closed":
            assert time.monotonic() < deadline
            time.sleep(0.01)
        # circuit is open for EVERYONE; other's saves still land on L1
        st = oth.save(1, tenant_state("other", 1))
        assert st is not None
        deadline = time.monotonic() + 30
        while not (vic.health().parked_steps and oth.health().parked_steps):
            assert time.monotonic() < deadline
            time.sleep(0.01)
        assert oth.flush_errors == [] and oth.retry.giveups == 0
        assert oth.health().mode == "degraded"
        # other's state is restorable from L1 during the outage
        got_s, got = oth.restore(tenant_state("other", 0))
        assert got_s == 1 and trees_equal(got, tenant_state("other", 1))
        # heal: the plane drains highest priority first
        plans[0].heal()
        plans[0].disarm()
        deadline = time.monotonic() + 30
        order = None
        while time.monotonic() < deadline:
            order = cp.drain()
            if (vic.step_status(1) == "flush_done"
                    and oth.step_status(1) == "flush_done"):
                break
            time.sleep(0.05)
        assert order == ["other", "victim"]  # priority 5 before 1
        assert done_order[0][0] == "other"
        assert vic.flush_errors == [] and oth.flush_errors == []
        h = cp.health()
        assert h["tenants"]["other"]["mode"] == "normal"
    finally:
        cp.close()


# ---------------------------------------------------------------------------
# serving: fleets subscribe through the plane
# ---------------------------------------------------------------------------


def test_plane_subscription_delivers_tenant_events_only(tmp_path):
    cp = ControlPlane(str(tmp_path), max_pending_flushes=4)
    a = cp.register_job("a", cluster(), strategy="posix", codec="none")
    b = cp.register_job("b", cluster(), strategy="posix", codec="none")
    seen = []
    cp.subscribe("a", lambda s: seen.append(s))
    try:
        a.save(1, tenant_state("a", 1))
        b.save(7, tenant_state("b", 7))
        a.wait(), b.wait()
        assert seen == [1]  # b's step 7 never leaks into a's stream
        fn = seen.append
    finally:
        cp.unsubscribe("a", lambda s: None)  # unknown fn: no-op
        cp.close()


def test_fleet_via_control_plane(tmp_path):
    """ServeFleet resolves its manager and its flush-done subscription
    through the plane — the multi-tenant serving path."""
    pytest.importorskip("jax")
    from repro.serve.fleet import FleetConfig, ServeFleet

    cp = ControlPlane(str(tmp_path), max_pending_flushes=4)
    m = cp.register_job("serve-me", cluster(), strategy="posix",
                        codec="none")

    def state(step):
        return {
            "params": {"w": np.arange(256, dtype=np.float32) + step},
            "opt": {"t": np.zeros(4, np.float32)},
        }

    m.save(1, state(1))
    m.wait()

    class IdModel:  # minimal Server stand-in target
        def apply(self, *a, **k):  # pragma: no cover - never generated
            return None

    fleet = ServeFleet.via_control_plane(
        IdModel(), cp, "serve-me", state(1)["params"],
        cfg=FleetConfig(n_servers=1, poll_interval=0.02),
    )
    try:
        cs = fleet.cold_start()
        assert cs.step == 1
        fleet.start_follower()
        assert fleet._plane is cp and fleet._job == "serve-me"
        m.save(2, state(2))
        m.wait()
        deadline = time.monotonic() + 30
        while fleet.current_step != 2:
            assert time.monotonic() < deadline
            time.sleep(0.02)
        np.testing.assert_array_equal(
            np.asarray(fleet.servers[0].params["w"]),
            np.arange(256, dtype=np.float32) + 2,
        )
    finally:
        fleet.close()
        cp.close()
