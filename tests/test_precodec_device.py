"""Device-resident pre-codec: staging equivalence, engine wiring, guards.

Everything runs in Pallas interpret mode on CPU; the host pre-codec +
serializer remain the executable reference spec, so every test here is a
byte-for-byte (or post-dequantize exact) comparison against that path.
"""
import json
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CheckpointConfig, CheckpointManager, theta_like
from repro.core.engine import UnsupportedPrecodecError
from repro.core.precodec import DevicePrecodec, quantize_tree
from repro.core.serialize import (
    chunk_aligned_sizes,
    decode_stream,
    encode_state,
    encode_state_staged,
    serialize_tree,
)

RNG = np.random.default_rng(99)


def mixed_state(step=0):
    return {
        "w": jnp.asarray(
            (RNG.standard_normal((64, 300)) * 3).astype(np.float32) + step
        ),
        "tiny": jnp.full((37,), 1.5 + step, jnp.float32),  # below quant floor
        "h": jnp.asarray(RNG.standard_normal((32, 256)).astype(np.float32) + step,
                         jnp.bfloat16),
        "i": jnp.asarray(RNG.integers(0, 100, 511), jnp.int32),
        "flag": jnp.asarray(RNG.random(65) < 0.5),
    }


def bump(state, key="w", amt=0.25):
    state = dict(state)
    state[key] = state[key] + jnp.asarray(amt, state[key].dtype)
    return state


def host_stream(state, precodec):
    tree = quantize_tree(state) if precodec == "int8" else state
    return serialize_tree(tree)


def assert_tree_equal(a, b):
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_array_equal(np.asarray(x), np.asarray(y)),
        a, b,
    )


# ---------------------------------------------------------------------------
# DevicePrecodec staging vs the host reference serializer
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("precodec", ["none", "int8"])
def test_stage_full_matches_host_serialize(precodec):
    dev = DevicePrecodec(chunk_size=4096, precodec=precodec)
    state = mixed_state()
    bufs = dev.consume(dev.stage(1, state))
    stream, leaves = host_stream(state, precodec)
    assert bytes(bufs.stream) == bytes(stream)
    assert bufs.leaves == leaves
    assert bufs.base_step is None
    assert bool(bufs.mask.all())  # anchors are dirty everywhere by definition
    dev.close()


@pytest.mark.parametrize("precodec", ["none", "int8"])
def test_stage_delta_matches_host_serialize(precodec):
    dev = DevicePrecodec(chunk_size=4096, precodec=precodec)
    s1 = mixed_state()
    b1 = dev.consume(dev.stage(1, s1))
    s2 = bump(s1)
    bufs = dev.consume(dev.stage(2, s2, base_step=1), base_stream=b1.stream)
    stream, _ = host_stream(s2, precodec)
    assert bytes(bufs.stream) == bytes(stream)
    assert bufs.base_step == 1
    mask = np.asarray(bufs.mask)
    assert 0 < mask.sum() < mask.size  # touched one leaf -> partial dirty set
    assert set(bufs.deltas) == set(np.flatnonzero(mask))
    dev.close()


def test_stage_base_miss_degrades_to_full():
    dev = DevicePrecodec(chunk_size=4096, precodec="none")
    s1 = mixed_state()
    dev.consume(dev.stage(1, s1))
    # ask for a base the device never staged -> silently re-anchors
    bufs = dev.consume(dev.stage(5, bump(s1), base_step=3))
    assert bufs.base_step is None
    assert bool(bufs.mask.all())
    dev.close()


def test_stage_rejects_wide_dtypes_without_x64():
    if jax.config.jax_enable_x64:
        pytest.skip("x64 enabled; narrow-on-transfer hazard absent")
    dev = DevicePrecodec(chunk_size=4096, precodec="none")
    with pytest.raises(ValueError, match="x64"):
        dev.stage(1, {"x": np.arange(8, dtype=np.int64)})
    dev.close()


# ---------------------------------------------------------------------------
# staged encode vs host encode_state (byte-for-byte)
# ---------------------------------------------------------------------------


def _staged_encode(dev, cluster, step, state, base_step, base_stream):
    staged = dev.stage(step, state, base_step=base_step)
    bufs = dev.consume(staged, base_stream=base_stream)
    enc = encode_state_staged(
        step, cluster,
        stream=bufs.stream, leaves=bufs.leaves, chunk_size=dev.chunk_size,
        base_step=bufs.base_step, dirty=bufs.mask, deltas=bufs.deltas,
        digests=bufs.digests,
    )
    return enc, bufs


def test_encode_staged_matches_host_encode(tmp_path):
    cluster = theta_like(2, 2)
    dev = DevicePrecodec(chunk_size=4096, precodec="none")
    s1, s2 = mixed_state(), None
    enc1, b1 = _staged_encode(dev, cluster, 1, s1, None, None)
    s2 = bump(s1, "h")
    enc2, _ = _staged_encode(dev, cluster, 2, s2, 1, b1.stream)

    stream1, _ = host_stream(s1, "none")
    sizes = chunk_aligned_sizes(len(bytes(stream1)), cluster.world_size, 4096)
    h1 = encode_state(1, s1, cluster, codec="zstd+delta",
                      chunk_size=4096, rank_sizes=sizes)
    stream2, _ = host_stream(s2, "none")
    h2 = encode_state(2, s2, cluster, codec="zstd+delta",
                      chunk_size=4096, base=h1, rank_sizes=sizes)

    for enc, h in ((enc1, h1), (enc2, h2)):
        assert [bytes(b) for b in enc.blobs] == [
            bytes(b) for b in h.blobs
        ]
        assert enc.manifest.base_step == h.manifest.base_step
        t, ht = enc.manifest.chunks, h.manifest.chunks
        for col in ("raw_off", "raw_len", "stored_off", "stored_len", "crc",
                    "flags"):
            np.testing.assert_array_equal(getattr(t, col), getattr(ht, col))
        assert t.digest is not None and ht.digest is None

    # digest-verified decode restores both steps exactly
    raw1 = decode_stream(enc1.manifest, [bytes(b) for b in enc1.blobs])
    raw2 = decode_stream(enc2.manifest, [bytes(b) for b in enc2.blobs],
                         base_stream=raw1)
    assert bytes(raw2) == bytes(stream2)
    dev.close()


def test_chunk_digest_corruption_detected():
    cluster = theta_like(1, 2)
    dev = DevicePrecodec(chunk_size=4096, precodec="none")
    enc, _ = _staged_encode(dev, cluster, 1, mixed_state(), None, None)
    enc.manifest.chunks.digest = enc.manifest.chunks.digest.copy()
    enc.manifest.chunks.digest[0] ^= 1
    with pytest.raises(IOError, match="digest mismatch"):
        decode_stream(enc.manifest, [bytes(b) for b in enc.blobs])
    dev.close()


def test_manifest_roundtrips_digest_column():
    cluster = theta_like(1, 2)
    dev = DevicePrecodec(chunk_size=4096, precodec="none")
    enc, _ = _staged_encode(dev, cluster, 1, mixed_state(), None, None)
    man2 = type(enc.manifest).from_json(enc.manifest.to_json())
    assert man2.chunks == enc.manifest.chunks
    np.testing.assert_array_equal(man2.chunks.digest, enc.manifest.chunks.digest)
    dev.close()


# ---------------------------------------------------------------------------
# CheckpointManager end-to-end: device path vs host twin
# ---------------------------------------------------------------------------


def _mgr(root, *, device, precodec="none", strategy="stripe_aligned"):
    return CheckpointManager(CheckpointConfig(
        root=str(root), cluster=theta_like(2, 2), strategy=strategy,
        codec="zstd+delta", chunk_size=4096, precodec=precodec,
        device_precodec=device, delta_every=3,
    ))


@pytest.mark.parametrize("precodec", ["none", "int8"])
def test_manager_device_matches_host(tmp_path, precodec):
    dm = _mgr(tmp_path / "dev", device=True, precodec=precodec)
    hm = _mgr(tmp_path / "host", device=False, precodec=precodec)
    s = mixed_state()
    for step in (1, 2, 3, 4, 5):
        dm.save(step, s)
        hm.save(step, s)
        s = bump(s, "w" if step % 2 else "h")
    dm.wait(); hm.wait()
    assert not dm.flush_errors and not hm.flush_errors
    tgt = jax.tree_util.tree_map(lambda l: np.zeros(l.shape, l.dtype),
                                 mixed_state())
    for step in (1, 2, 3, 4, 5):
        # same base chain and, post-dequantize, identical restored bytes
        assert (dm._manifest_local(step).base_step
                == hm._manifest_local(step).base_step)
        _, td = dm.restore(tgt, step)
        _, th = hm.restore(tgt, step)
        assert_tree_equal(td, th)
    assert dm._manifest_local(2).chunks.digest is not None
    assert hm._manifest_local(2).chunks.digest is None
    dm.close(); hm.close()


def test_manager_stage_overlap(tmp_path):
    mgr = _mgr(tmp_path, device=True)
    s = mixed_state()
    assert mgr.stage(1, s)  # staged while "compute" would run
    stats = mgr.save(1, s)  # consumes the staged handle
    assert stats.stage_s > 0.0 and stats.stage_wait_s >= 0.0
    s2 = bump(s)
    stats2 = mgr.save(2, s2)  # no stage() first -> stages synchronously
    assert stats2.stage_s > 0.0
    mgr.wait()
    tgt = jax.tree_util.tree_map(lambda l: np.zeros(l.shape, l.dtype), s)
    _, out = mgr.restore(tgt, 2)
    assert_tree_equal(out, s2)
    mgr.close()


def test_manager_stage_noop_when_disabled(tmp_path):
    mgr = _mgr(tmp_path, device=False)
    assert mgr.stage(1, mixed_state()) is False
    mgr.close()


def test_device_precodec_config_validation(tmp_path):
    mgr = CheckpointManager(CheckpointConfig(
        root=str(tmp_path), cluster=theta_like(1, 2), codec="zstd",
        device_precodec=True,
    ))
    with pytest.raises(ValueError, match="zstd\\+delta"):
        mgr.save(1, mixed_state())
    mgr.close()
    mgr = CheckpointManager(CheckpointConfig(
        root=str(tmp_path), cluster=theta_like(1, 2), codec="zstd+delta",
        chunk_size=1 << 20 | 512, device_precodec=True,
    ))
    with pytest.raises(ValueError, match="multiple"):
        mgr.save(1, mixed_state())
    mgr.close()


# ---------------------------------------------------------------------------
# satellite a: precodec change invalidates the delta chain
# ---------------------------------------------------------------------------


def test_precodec_change_reanchors_chain(tmp_path):
    mgr = CheckpointManager(CheckpointConfig(
        root=str(tmp_path), cluster=theta_like(2, 2), codec="zstd+delta",
        chunk_size=4096, precodec="none", delta_every=10,
    ))
    s = mixed_state()
    mgr.save(1, s)
    mgr.save(2, bump(s))
    assert mgr._manifest_local(2).base_step == 1
    mgr.cfg.precodec = "int8"
    mgr.save(3, bump(s, "h"))  # stream layout changed -> must re-anchor
    assert mgr._manifest_local(3).base_step is None
    mgr.save(4, bump(bump(s, "h")))
    assert mgr._manifest_local(4).base_step == 3  # chain resumes off new anchor
    mgr.wait()
    tgt = jax.tree_util.tree_map(lambda l: np.zeros(l.shape, l.dtype),
                                 mixed_state())
    mgr.restore(tgt, 4)  # int8 restore decodes through the new anchor
    mgr.close()


def test_delta_with_mismatched_base_precodec_rejected(tmp_path):
    mgr = CheckpointManager(CheckpointConfig(
        root=str(tmp_path), cluster=theta_like(1, 2), codec="zstd+delta",
        chunk_size=4096, precodec="none", delta_every=10,
    ))
    s = mixed_state()
    mgr.save(1, s)
    mgr.save(2, bump(s))
    mgr.wait()
    assert mgr._manifest_local(2).base_step == 1
    # tamper: rewrite the base manifest as if it came from another precodec
    mp = mgr.root / "local" / "manifests" / "step_00000001.json"
    obj = json.loads(mp.read_text())
    obj["precodec"] = "int8"
    mp.write_text(json.dumps(obj))
    mgr._man_cache.clear()
    mgr._l0 = None
    mgr._last_full = None
    tgt = jax.tree_util.tree_map(lambda l: np.zeros(l.shape, l.dtype), s)
    with pytest.raises(IOError, match="chain is invalid"):
        mgr._restore_from_local(2, tgt)
    mgr.close()


# ---------------------------------------------------------------------------
# satellite f: partial restore of int8 manifests fails at plan time
# ---------------------------------------------------------------------------


def test_partial_restore_int8_raises_before_io(tmp_path):
    mgr = CheckpointManager(CheckpointConfig(
        root=str(tmp_path), cluster=theta_like(1, 2), codec="zstd+delta",
        chunk_size=4096, precodec="int8",
    ))
    s = mixed_state()
    mgr.save(1, s)
    mgr.wait()
    reads = []

    def counting(fn):
        def wrapped(*a, **k):
            reads.append(fn.__name__)
            return fn(*a, **k)
        return wrapped

    mgr.executor.execute_read_plan = counting(mgr.executor.execute_read_plan)
    mgr.local.read_blob = counting(mgr.local.read_blob)
    with pytest.raises(UnsupportedPrecodecError):
        mgr.restore_leaves(["['w']"], step=1)
    with pytest.raises(UnsupportedPrecodecError):
        mgr.restore_subtree({"w": np.zeros((64, 300), np.float32)},
                            prefix="", step=1)
    assert reads == []  # planning failed before any byte was fetched
    mgr.close()
