"""Serving engine + distributed-collective twins."""
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import jax
import pytest

from repro.configs import get_smoke_config
from repro.models import get_model
from repro.serve import ServeConfig, Server


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "xlstm-350m", "recurrentgemma-2b"])
def test_server_generates(arch):
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    server = Server(model, params, ServeConfig(max_new_tokens=5))
    prompts = {"tokens": jnp.asarray(np.full((3, 7), 11, np.int32))}
    toks, cache = server.generate(prompts)
    assert toks.shape == (3, 5)
    assert (toks >= 0).all() and (toks < cfg.vocab_size).all()
    # greedy decode is deterministic
    toks2, _ = server.generate(prompts)
    np.testing.assert_array_equal(toks, toks2)


def test_server_boots_from_partial_restore(tmp_path):
    """Serving pulls ONLY the params subtree out of a full train-state
    checkpoint (aggregated partial read), on a different geometry."""
    from repro.core import CheckpointConfig, CheckpointManager, theta_like

    cfg = get_smoke_config("tinyllama-1.1b")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # a "train state": params + optimizer baggage serving must not read
    state = {"params": params, "opt": {"mu": jnp.zeros((4096,), jnp.float32)}}
    mgr = CheckpointManager(
        CheckpointConfig(root=str(tmp_path), cluster=theta_like(4, 2),
                         strategy="stripe_aligned", async_flush=False)
    )
    mgr.save(3, state)
    mgr.close()

    mgr2 = CheckpointManager(
        CheckpointConfig(root=str(tmp_path), cluster=theta_like(2, 1),
                         strategy="posix")
    )
    template = jax.tree_util.tree_map(np.asarray, params)
    server, step = Server.from_checkpoint(
        model, mgr2, template, cfg=ServeConfig(max_new_tokens=4)
    )
    assert step == 3
    # partial read: strictly fewer bytes than the whole checkpoint
    total = sum(r.stored_size for r in mgr2._manifest_pfs(3).ranks)
    assert mgr2.last_read_result.bytes_read < total
    prompts = {"tokens": jnp.asarray(np.full((2, 5), 7, np.int32))}
    toks, _ = server.generate(prompts)
    ref_server = Server(model, params, ServeConfig(max_new_tokens=4))
    ref, _ = ref_server.generate(prompts)
    np.testing.assert_array_equal(toks, ref)
    mgr2.close()


def test_device_prefix_sum_matches_host():
    """shard_map twin of the paper's scan == the host algorithm.

    Runs in a subprocess with 8 forced host devices (device count is
    locked at first jax init in this process).
    """
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core import exclusive_prefix_sum
from repro.dist import device_exclusive_prefix_sum
mesh = jax.make_mesh((8,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
sizes = np.array([3, 0, 7, 1, 9, 4, 2, 8], np.int64)
offs, total = device_exclusive_prefix_sum(jnp.asarray(sizes), mesh, "data")
ref_offs, ref_total = exclusive_prefix_sum(sizes.tolist())
np.testing.assert_array_equal(np.asarray(offs), np.array(ref_offs))
assert int(total) == ref_total
print("OK")
"""
    import os
    from pathlib import Path

    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=300,
        env=env, cwd=str(Path(__file__).resolve().parents[1]),
    )
    assert "OK" in out.stdout, out.stderr[-2000:]
