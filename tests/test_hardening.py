"""Production-hardening behaviours: backpressure, scrubbing, sim
metamorphic properties."""
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    CheckpointConfig,
    CheckpointManager,
    make_plan,
    simulate_flush,
    theta_like,
)

GiB = 1 << 30


def small_state(step=0):
    return {"w": jnp.full((50_000,), float(step), jnp.float32)}


def test_backpressure_bounds_pending_flushes(tmp_path):
    """save() must block once max_pending_flushes are in flight."""
    gate = threading.Event()
    in_flight = []

    def slow_hook(_w):
        in_flight.append(1)
        gate.wait(timeout=30)

    mgr = CheckpointManager(
        CheckpointConfig(
            root=str(tmp_path), cluster=theta_like(1, 1),
            strategy="file_per_process", max_pending_flushes=1,
        ),
        fault_hook=slow_hook,
    )
    mgr.save(1, small_state(1))          # occupies the single slot
    t0 = time.perf_counter()
    done = threading.Event()

    def second_save():
        mgr.save(2, small_state(2))
        done.set()

    t = threading.Thread(target=second_save, daemon=True)
    t.start()
    time.sleep(0.3)
    assert not done.is_set()             # blocked on backpressure
    gate.set()                           # let flush 1 (and 2) complete
    assert done.wait(timeout=30)
    mgr.wait()
    assert not mgr.flush_errors
    mgr.close()


def test_validate_scrub_flags_corruption(tmp_path):
    mgr = CheckpointManager(
        CheckpointConfig(root=str(tmp_path), cluster=theta_like(2, 2),
                         strategy="stripe_aligned")
    )
    mgr.save(5, small_state(5))
    mgr.wait()
    rep = mgr.validate(5)
    assert all(rep["pfs"].values()) and len(rep["pfs"]) == 4
    assert all(rep["local"].values()) and len(rep["local"]) == 4
    # corrupt one byte on the PFS aggregate: exactly one rank goes bad
    agg = next((mgr.pfs_dir / "step_00000005").glob("aggregate.dat"))
    data = bytearray(agg.read_bytes())
    data[10] ^= 0x01
    agg.write_bytes(bytes(data))
    rep2 = mgr.validate(5)
    assert sum(not ok for ok in rep2["pfs"].values()) == 1
    assert all(rep2["local"].values())   # local copies untouched
    mgr.close()


# ---------------------------------------------------------------------------
# metamorphic simulator properties
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(
    nodes=st.sampled_from([4, 8, 16]),
    ppn=st.sampled_from([1, 2, 4]),
    strategy=st.sampled_from(["file_per_process", "stripe_aligned"]),
)
def test_flush_time_monotone_in_bytes(nodes, ppn, strategy):
    c = theta_like(nodes, ppn)
    small = simulate_flush(make_plan(strategy, c, [256 << 20] * c.world_size))
    big = simulate_flush(make_plan(strategy, c, [1 << 30] * c.world_size))
    assert big.flush_time > small.flush_time


@settings(max_examples=8, deadline=None)
@given(load=st.floats(0.1, 0.8), nodes=st.sampled_from([4, 8]))
def test_load_never_speeds_up_flush(load, nodes):
    c = theta_like(nodes, 2)
    sizes = [GiB] * c.world_size
    clean = simulate_flush(make_plan("file_per_process", c, sizes))
    cj = c.with_(node_load=[load] + [0.0] * (nodes - 1))
    jit = simulate_flush(make_plan("file_per_process", cj, sizes))
    assert jit.flush_time >= clean.flush_time * 0.999


def test_more_nodes_never_slower_same_total_bytes():
    total = 64 * GiB
    times = []
    for nodes in (4, 8, 16):
        c = theta_like(nodes, 2)
        per = total // c.world_size
        rep = simulate_flush(make_plan("stripe_aligned", c, [per] * c.world_size))
        times.append(rep.flush_time)
    assert times[0] >= times[1] >= times[2] * 0.999
