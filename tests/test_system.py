"""End-to-end system behaviour: the paper's workflow, start to finish.

Train -> checkpoint (aggregated async) -> simulated node failure ->
elastic restart on a different geometry -> training continues bit-exact.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import CheckpointConfig, CheckpointManager, theta_like
from repro.data import DataConfig, SyntheticTokens
from repro.launch.mesh import make_host_mesh
from repro.models import get_model
from repro.serve import ServeConfig, Server
from repro.train import OptConfig, TrainConfig, init_train_state, make_train_step


def test_full_lifecycle(tmp_path):
    cfg = get_smoke_config("qwen1.5-0.5b")
    model = get_model(cfg)
    mesh = make_host_mesh()
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=24, global_batch=4)
    tcfg = TrainConfig(opt=OptConfig(lr=3e-3, total_steps=20))
    data = SyntheticTokens(data_cfg)
    bs = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), data.peek(0)
    )
    step_fn, _, _ = make_train_step(model, tcfg, mesh, bs)
    state = init_train_state(model, jax.random.PRNGKey(0), tcfg)

    mgr = CheckpointManager(
        CheckpointConfig(
            root=str(tmp_path), cluster=theta_like(4, 2),
            strategy="stripe_aligned", codec="zstd",
            partner_replication=True,
        )
    )
    for i in range(1, 7):
        state, metrics = step_fn(state, data.next())
        if i % 3 == 0:
            mgr.save(i, {"train": state, "data": data.state_tree()})
    mgr.wait()
    assert not mgr.flush_errors
    # snapshot the template before step_fn donates these buffers
    target = {
        "train": jax.tree_util.tree_map(np.asarray, state),
        "data": {"batch_idx": np.asarray(0, np.int32)},
    }
    truth = state
    d_truth = SyntheticTokens(data_cfg, state=data.state_tree())
    for _ in range(2):
        truth, _ = step_fn(truth, d_truth.next())
    mgr.close()

    # --- "the machine shrank": restart on 2x1 nodes, PFS only ---
    mgr2 = CheckpointManager(
        CheckpointConfig(root=str(tmp_path), cluster=theta_like(2, 1),
                         strategy="file_per_process")
    )
    for n in range(4):
        mgr2.local.drop_node(n)  # L1 died with the old allocation
    step, restored = mgr2.restore(target)
    assert step == 6
    r_state = jax.tree_util.tree_map(jnp.asarray, restored["train"])
    d2 = SyntheticTokens(data_cfg)
    d2.load_state(restored["data"])
    for _ in range(2):
        r_state, _ = step_fn(r_state, d2.next())
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        truth, r_state,
    )
    mgr2.close()

    # --- serve from the restored weights ---
    server = Server(model, r_state["params"], ServeConfig(max_new_tokens=4))
    toks, cache = server.generate(
        {"tokens": jnp.asarray(np.full((2, 6), 5, np.int32))}
    )
    assert toks.shape == (2, 4)
    # serving snapshot checkpoints through the same engine
    snap = server.snapshot_state(cache)
    st = mgr2.save(100, snap) if False else None  # snapshot is a pytree
