"""Restore correctness properties: byte-identical elastic restore for
every strategy under geometry change, and corrupt-aggregated-file
fallback to L1.

These are the read-side acceptance properties from the paper's framing:
aggregated checkpoints must be *accessible as a whole* — from any
consumer geometry, and degraded gracefully when the aggregate is
damaged.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CheckpointConfig, CheckpointManager, theta_like

STRATEGIES = ["file_per_process", "posix", "mpiio", "stripe_aligned", "gio_sync"]

# (save geometry, restore geometry) with M != N everywhere
GEOMETRIES = [((4, 2), (3, 1)), ((2, 3), (5, 2))]


def state_tree(step=0):
    return {
        "params": {
            "w": jnp.arange(3000, dtype=jnp.float32).reshape(60, 50) + step,
            "b": jnp.full((64,), step, jnp.bfloat16),
        },
        "opt": {"mu": jnp.ones((60, 50), jnp.float32) * step,
                "count": jnp.array(step, jnp.int32)},
    }


def np_target():
    return jax.tree_util.tree_map(np.asarray, state_tree())


def assert_tree_equal(a, b):
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_array_equal(np.asarray(x), np.asarray(y)),
        a, b,
    )


@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("geoms", GEOMETRIES, ids=["4x2->3x1", "2x3->5x2"])
def test_elastic_restore_byte_identical(tmp_path, strategy, geoms):
    """N-rank save -> M-rank restore (M != N), PFS only, every strategy."""
    (n1, p1), (n2, p2) = geoms
    mgr = CheckpointManager(
        CheckpointConfig(root=str(tmp_path), cluster=theta_like(n1, p1),
                         strategy=strategy)
    )
    mgr.save(7, state_tree(7))
    mgr.wait()
    assert not mgr.flush_errors
    mgr.close()

    mgr2 = CheckpointManager(
        CheckpointConfig(root=str(tmp_path), cluster=theta_like(n2, p2),
                         strategy="posix")
    )
    for n in range(n1):
        mgr2.local.drop_node(n)  # the old allocation's L1 is gone
    step, restored = mgr2.restore(np_target())
    assert step == 7
    assert_tree_equal(restored, state_tree(7))
    # the restore went through the aggregated ranged-read path
    rr = mgr2.last_read_result
    assert rr is not None and rr.bytes_read > 0
    assert rr.n_readers <= n2
    # partial restore agrees under the same geometry change
    s2, params = mgr2.restore_subtree(np_target()["params"], "['params']")
    assert s2 == 7
    assert_tree_equal(params, jax.tree_util.tree_map(np.asarray, state_tree(7)["params"]))
    mgr2.close()


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_corrupt_aggregated_file_falls_back_to_l1(tmp_path, strategy):
    """Flip a byte in every aggregated file: PFS restore must fail the
    CRC and fall back to the intact node-local (L1) copies."""
    mgr = CheckpointManager(
        CheckpointConfig(root=str(tmp_path), cluster=theta_like(3, 2),
                         strategy=strategy)
    )
    mgr.save(4, state_tree(4))
    mgr.wait()
    assert not mgr.flush_errors
    for agg in (mgr.pfs_dir / "step_00000004").glob("*.dat"):
        data = bytearray(agg.read_bytes())
        if len(data):
            data[len(data) // 2] ^= 0xFF
            agg.write_bytes(bytes(data))
    mgr._l0 = None
    step, restored = mgr.restore(np_target())
    assert step == 4                       # served from L1
    assert_tree_equal(restored, state_tree(4))
    # with L1 also gone there is nothing valid left
    for n in range(3):
        mgr.local.drop_node(n)
    with pytest.raises(FileNotFoundError):
        mgr.restore(np_target())
    mgr.close()


def test_truncated_aggregated_file_falls_back_to_l1(tmp_path):
    """Truncation (not just bit flips) is caught as a short read."""
    mgr = CheckpointManager(
        CheckpointConfig(root=str(tmp_path), cluster=theta_like(2, 2),
                         strategy="stripe_aligned")
    )
    mgr.save(3, state_tree(3))
    mgr.wait()
    assert not mgr.flush_errors
    agg = mgr.pfs_dir / "step_00000003" / "aggregate.dat"
    with open(agg, "r+b") as f:
        f.truncate(agg.stat().st_size // 2)
    mgr._l0 = None
    step, restored = mgr.restore(np_target())
    assert step == 3
    assert_tree_equal(restored, state_tree(3))
    mgr.close()


def test_partial_restore_uses_partner_replica(tmp_path):
    """Node loss + no PFS copy: restore_leaves must find the partner
    replica just like the full restore path does."""
    mgr = CheckpointManager(
        CheckpointConfig(
            root=str(tmp_path), cluster=theta_like(3, 2),
            strategy="file_per_process", partner_replication=True,
            async_flush=False,
        ),
        fault_hook=lambda w: (_ for _ in ()).throw(IOError("pfs down")),
    )
    with pytest.raises(IOError):
        mgr.save(2, state_tree(2))        # flush fails -> L1 only
    mgr.local.drop_node(1)                # and a node dies
    mgr._l0 = None
    step, got = mgr.restore_leaves(["['params']['w']"])
    assert step == 2
    np.testing.assert_array_equal(
        got["['params']['w']"], np.asarray(state_tree(2)["params"]["w"])
    )
    mgr.close()


def test_validate_scrub_flags_corrupt_rank_only(tmp_path):
    """The integrity scrub reads the PFS through one aggregated plan and
    still reports per-rank health; truncation degrades to the per-rank
    fallback without marking intact ranks unhealthy."""
    mgr = CheckpointManager(
        CheckpointConfig(root=str(tmp_path), cluster=theta_like(2, 2),
                         strategy="file_per_process")
    )
    mgr.save(1, state_tree(1))
    mgr.wait()
    assert not mgr.flush_errors
    rep = mgr.validate(1)
    assert all(rep["pfs"].values()) and all(rep["local"].values())
    # flip a byte in rank 2's file: exactly that rank goes unhealthy
    man = mgr._manifest_pfs(1)
    fname = man.placement.by_rank()[2][0][0]
    p = mgr.pfs_dir / "step_00000001" / fname
    data = bytearray(p.read_bytes())
    data[0] ^= 0xFF
    p.write_bytes(bytes(data))
    rep = mgr.validate(1)
    assert rep["pfs"][2] is False
    assert rep["pfs"][0] and rep["pfs"][1] and rep["pfs"][3]
    # truncate it: the aggregated read fails, per-rank fallback keeps
    # the other ranks healthy
    with open(p, "r+b") as f:
        f.truncate(1)
    rep = mgr.validate(1)
    assert rep["pfs"][2] is False
    assert rep["pfs"][0] and rep["pfs"][1] and rep["pfs"][3]
    mgr.close()


# ---------------------------------------------------------------------------
# property test: random geometries and leaf shapes (hypothesis-gated)
# ---------------------------------------------------------------------------

try:  # the rest of the module must still run without hypothesis
    from hypothesis import HealthCheck, given, settings, strategies as st

    _HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional test dep
    _HAVE_HYPOTHESIS = False


if _HAVE_HYPOTHESIS:

    @settings(
        max_examples=8, deadline=None,
        suppress_health_check=[HealthCheck.function_scoped_fixture],
    )
    @given(
        n1=st.integers(1, 4), p1=st.integers(1, 3),
        n2=st.integers(1, 4), p2=st.integers(1, 3),
        strategy=st.sampled_from(STRATEGIES),
        n_elems=st.integers(1, 5000),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_restore_roundtrip_random_geometry(
        tmp_path_factory, n1, p1, n2, p2, strategy, n_elems, seed
    ):
        rng = np.random.default_rng(seed)
        state = {
            "a": jnp.asarray(rng.standard_normal(n_elems).astype(np.float32)),
            "b": jnp.asarray(
                rng.integers(0, 1 << 30, max(1, n_elems // 7), np.int64)
            ),
        }
        target = jax.tree_util.tree_map(np.asarray, state)
        root = tmp_path_factory.mktemp("ckpt")
        mgr = CheckpointManager(
            CheckpointConfig(root=str(root), cluster=theta_like(n1, p1),
                             strategy=strategy, async_flush=False)
        )
        mgr.save(1, state)
        assert not mgr.flush_errors
        mgr.close()
        mgr2 = CheckpointManager(
            CheckpointConfig(root=str(root), cluster=theta_like(n2, p2),
                             strategy="file_per_process")
        )
        for n in range(n1):
            mgr2.local.drop_node(n)
        step, restored = mgr2.restore(target)
        assert step == 1
        assert_tree_equal(restored, target)
        mgr2.close()
