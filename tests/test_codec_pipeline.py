"""Chunk-framed codec pipeline: round trips, chunk-granular delta,
corruption attribution, legacy manifests, partial restore under
compression, thread-local compressor reuse, vectorized dequantize.

The equivalence contract differs from tests/test_save_phase.py: with
chunk framing the *stored* bytes legitimately differ from the seed
whole-blob codecs, so equivalence is at the raw-stream level — chunked
encode -> decode must reproduce exactly the bytes
``encode_blob_reference`` -> ``decode_blob_reference`` does (and both
must reproduce the pytree).  Whole-blob byte-identity is pinned by
``chunk_size=0`` in the older suite.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CheckpointConfig,
    CheckpointManager,
    ChunkTable,
    Manifest,
    theta_like,
)
from repro.core.plan import merge_intervals
from repro.core.serialize import (
    CHUNK_BASE,
    CHUNK_DELTA,
    CHUNK_RAW,
    decode_state,
    decode_stream,
    default_codec_impl,
    encode_state,
)
from repro.core.serialize_ref import encode_state_reference

CODECS = ["none", "zstd", "zstd+delta"]


def state_tree(step=0, scale=1):
    return {
        "params": {
            "w": jnp.arange(3000 * scale, dtype=jnp.float32).reshape(-1, 50) + step,
            "b": jnp.full((64,), step, jnp.bfloat16),
        },
        "opt": {"mu": jnp.ones((40, 50), jnp.float32) * step,
                "count": jnp.array(step, jnp.int32)},
    }


def np_target(scale=1):
    return jax.tree_util.tree_map(np.asarray, state_tree(scale=scale))


def assert_tree_equal(a, b):
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_array_equal(np.asarray(x), np.asarray(y)),
        a, b,
    )


# ---------------------------------------------------------------------------
# raw-stream equivalence: chunked encode/decode == whole-blob reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("codec", ["zstd", "zstd+delta"])
@pytest.mark.parametrize("chunk_size", [64, 1 << 12, 1 << 20])
def test_chunked_roundtrip_matches_reference_decode(codec, chunk_size):
    """The acceptance bar: chunked encode -> decode is byte-identical to
    the seed whole-blob reference pipeline's decode (both equal the
    original stream), across a delta chain."""
    c = theta_like(3, 2)
    prev_fast = prev_ref = None
    for step in (1, 2, 3):
        tree = state_tree(step)
        fast = encode_state(step, tree, c, codec=codec, base=prev_fast,
                            chunk_size=chunk_size)
        ref = encode_state_reference(step, tree, c, codec=codec, base=prev_ref)
        assert bytes(fast.stream) == bytes(ref.stream)
        assert fast.manifest.base_step == ref.manifest.base_step
        # raw/leaf bookkeeping identical; only the framing differs
        assert fast.manifest.leaves == ref.manifest.leaves
        assert [(r.offset, r.raw_size) for r in fast.manifest.ranks] == \
               [(r.offset, r.raw_size) for r in ref.manifest.ranks]
        base_stream = (
            bytes(prev_fast.stream) if fast.manifest.base_step is not None else None
        )
        got = decode_state(
            fast.manifest, fast.blobs, np_target(), base_stream=base_stream
        )
        ref_got = decode_state(
            ref.manifest, ref.blobs, np_target(),
            base_stream=bytes(prev_ref.stream) if ref.manifest.base_step is not None else None,
        )
        assert_tree_equal(got, ref_got)
        assert_tree_equal(got, tree)
        prev_fast, prev_ref = fast, ref


@pytest.mark.parametrize("codec", CODECS)
@pytest.mark.parametrize("chunk_size", [128, 1 << 12])
@pytest.mark.parametrize("geom", [(1, 1), (3, 2), (4, 4)])
def test_manager_roundtrip_matrix(tmp_path, codec, chunk_size, geom):
    """Full-manager round trip over codec x chunk size x world size:
    save a delta chain, restore from PFS and from L1."""
    n, p = geom
    root = tmp_path / f"{codec}-{chunk_size}-{n}x{p}"
    mgr = CheckpointManager(
        CheckpointConfig(
            root=str(root), cluster=theta_like(n, p), strategy="stripe_aligned",
            codec=codec, chunk_size=chunk_size, delta_every=3,
            async_flush=False,
        )
    )
    for s in (1, 2, 3):
        mgr.save(s, state_tree(s))
    assert not mgr.flush_errors
    mgr._l0 = None
    mgr._last_full = None
    step, got = mgr.restore(np_target())          # PFS
    assert step == 3
    assert_tree_equal(got, state_tree(3))
    import shutil

    shutil.rmtree(mgr.pfs_dir)
    mgr.pfs_dir.mkdir()
    mgr._man_cache.clear()
    step, got = mgr.restore(np_target())          # L1
    assert step == 3
    assert_tree_equal(got, state_tree(3))
    mgr.close()


# ---------------------------------------------------------------------------
# chunk-granular delta
# ---------------------------------------------------------------------------


def test_delta_skips_clean_chunks_and_roundtrips():
    c = theta_like(2, 2)
    chunk = 256
    base_tree = {"x": np.zeros(1 << 15, np.uint8)}
    base = encode_state(1, base_tree, c, codec="zstd+delta", chunk_size=chunk)
    # mutate a single narrow region: only the chunks covering it go dirty
    t2 = {"x": base_tree["x"].copy()}
    t2["x"][5000:5100] = 7
    enc = encode_state(2, t2, c, codec="zstd+delta", base=base, chunk_size=chunk)
    tab = enc.manifest.chunks
    flags = tab.flags
    n_base = int(((flags & CHUNK_BASE) != 0).sum())
    n_dirty = len(tab) - n_base
    assert n_dirty <= 2                      # the mutation spans <= 2 chunks
    assert n_base >= len(tab) - 2
    stored = sum(r.stored_size for r in enc.manifest.ranks)
    full = sum(r.stored_size for r in base.manifest.ranks)
    assert stored < full / 4                 # toward the differential ideal
    got = decode_state(
        enc.manifest, enc.blobs, {"x": np.empty(1 << 15, np.uint8)},
        base_stream=bytes(base.stream),
    )
    np.testing.assert_array_equal(got["x"], t2["x"])


def test_delta_identical_state_stores_zero_payload_bytes():
    """A step with no changes at all stores nothing but the manifest:
    every chunk is a base reference."""
    c = theta_like(2, 1)
    tree = {"x": np.arange(4096, dtype=np.int64)}
    base = encode_state(1, tree, c, codec="zstd+delta", chunk_size=512)
    enc = encode_state(2, tree, c, codec="zstd+delta", base=base, chunk_size=512)
    assert ((enc.manifest.chunks.flags & CHUNK_BASE) != 0).all()
    assert sum(r.stored_size for r in enc.manifest.ranks) == 0
    got = decode_state(
        enc.manifest, enc.blobs, {"x": np.empty(4096, np.int64)},
        base_stream=bytes(base.stream),
    )
    np.testing.assert_array_equal(got["x"], tree["x"])


@pytest.mark.parametrize(
    "strategy", ["file_per_process", "posix", "mpiio", "stripe_aligned", "gio_sync"]
)
def test_zero_byte_delta_step_flushes_and_restores(tmp_path, strategy):
    """An unchanged step stores 0 bytes per rank; every strategy must
    plan/flush/restore that degenerate (empty-rank) geometry, including
    partial restore, which then reads nothing but the base's chunks."""
    state = {"x": np.arange(8192, dtype=np.float32)}
    mgr = CheckpointManager(
        CheckpointConfig(
            root=str(tmp_path), cluster=theta_like(2, 2), strategy=strategy,
            codec="zstd+delta", chunk_size=512, delta_every=4,
            async_flush=False,
        )
    )
    mgr.save(1, state)
    st = mgr.save(2, state)
    assert not mgr.flush_errors
    assert st.stored_bytes == 0
    mgr._l0 = None
    mgr._last_full = None
    step, got = mgr.restore({"x": np.empty(8192, np.float32)})
    assert step == 2
    np.testing.assert_array_equal(got["x"], state["x"])
    s2, leaves = mgr.restore_leaves(["['x']"], step=2)
    assert s2 == 2
    np.testing.assert_array_equal(leaves["['x']"], state["x"])
    mgr.close()


def test_delta_mutated_base_produces_delta_or_raw_chunks():
    """Dirty chunks carry CHUNK_DELTA (XOR compressed) or CHUNK_RAW —
    never a silent stale base reference."""
    rng = np.random.default_rng(0)
    c = theta_like(1, 2)
    base_tree = {"x": rng.integers(0, 256, 1 << 14, np.uint8)}
    base = encode_state(1, base_tree, c, codec="zstd+delta", chunk_size=1024)
    t2 = {"x": rng.integers(0, 256, 1 << 14, np.uint8)}  # fully different
    enc = encode_state(2, t2, c, codec="zstd+delta", base=base, chunk_size=1024)
    tab = enc.manifest.chunks
    assert not ((tab.flags & CHUNK_BASE) != 0).any()
    assert (((tab.flags & CHUNK_DELTA) != 0) | ((tab.flags & CHUNK_RAW) != 0)).all()
    got = decode_state(
        enc.manifest, enc.blobs, {"x": np.empty(1 << 14, np.uint8)},
        base_stream=bytes(base.stream),
    )
    np.testing.assert_array_equal(got["x"], t2["x"])


# ---------------------------------------------------------------------------
# corruption: attribution at chunk granularity + restore fallback
# ---------------------------------------------------------------------------


def test_corrupt_single_chunk_detected_and_attributed():
    c = theta_like(2, 2)
    enc = encode_state(1, state_tree(1), c, codec="zstd", chunk_size=512)
    tab = enc.manifest.chunks
    # flip one byte inside rank 1's second chunk payload
    row = int(tab.rank_starts[1]) + 1
    blob = bytearray(enc.blobs[1])
    blob[int(tab.stored_off[row])] ^= 0xFF
    blobs = list(enc.blobs)
    blobs[1] = bytes(blob)
    with pytest.raises(IOError, match="chunk"):
        decode_stream(enc.manifest, blobs)
    # intact blobs still decode
    decode_stream(enc.manifest, enc.blobs)


def test_corrupt_chunk_in_pfs_falls_back_to_l1(tmp_path):
    mgr = CheckpointManager(
        CheckpointConfig(
            root=str(tmp_path), cluster=theta_like(2, 2),
            strategy="stripe_aligned", codec="zstd", chunk_size=512,
            async_flush=False,
        )
    )
    mgr.save(1, state_tree(1))
    assert not mgr.flush_errors
    agg = mgr.pfs_dir / "step_00000001" / "aggregate.dat"
    data = bytearray(agg.read_bytes())
    data[len(data) // 2] ^= 0xFF
    agg.write_bytes(bytes(data))
    mgr._l0 = None
    step, got = mgr.restore(np_target())
    assert step == 1                       # served from intact L1
    assert_tree_equal(got, state_tree(1))
    mgr.close()


def test_partial_restore_flags_corrupt_chunk(tmp_path):
    """Chunk CRCs close the old sub-blob integrity blind spot: a
    partial restore that touches a damaged chunk refuses it (and falls
    back to the intact L1 copy)."""
    mgr = CheckpointManager(
        CheckpointConfig(
            root=str(tmp_path), cluster=theta_like(2, 2),
            strategy="stripe_aligned", codec="zstd", chunk_size=256,
            async_flush=False,
        )
    )
    mgr.save(1, state_tree(1))
    man = mgr._manifest_pfs(1)
    agg = mgr.pfs_dir / "step_00000001" / "aggregate.dat"
    data = bytearray(agg.read_bytes())
    data[:] = bytes(len(data))             # wipe the whole aggregate
    agg.write_bytes(bytes(data))
    mgr._l0 = None
    # direct PFS partial read must raise (chunk checksum), manager falls back
    with pytest.raises(IOError, match="chunk"):
        mgr._leaves_from(man, 1, ["['params']['w']"], pfs=True)
    step, got = mgr.restore_leaves(["['params']['w']"])
    assert step == 1
    np.testing.assert_array_equal(
        got["['params']['w']"], np.asarray(state_tree(1)["params"]["w"])
    )
    mgr.close()


# ---------------------------------------------------------------------------
# legacy (whole-blob) manifests still parse and restore
# ---------------------------------------------------------------------------


def test_legacy_manifest_fields_default_to_whole_blob():
    c = theta_like(2, 1)
    enc = encode_state(1, state_tree(1), c, codec="zstd", chunk_size=0)
    d = json.loads(enc.manifest.to_json())
    # what a pre-chunking writer produced: no framing fields at all
    for k in ("chunk_size", "chunks", "codec_impl"):
        d.pop(k, None)
    man = Manifest.from_json(json.dumps(d))
    assert man.chunk_size == 0 and man.chunks is None
    assert man.codec_impl == "zstd"        # legacy manifests were zstd-only


@pytest.mark.parametrize("codec", ["zstd", "zstd+delta"])
def test_legacy_whole_blob_checkpoint_restores(tmp_path, codec):
    """A checkpoint written with whole-blob framing whose manifests are
    stripped back to the legacy schema (no chunk fields) must still
    restore — from PFS and from L1."""
    mgr = CheckpointManager(
        CheckpointConfig(
            root=str(tmp_path), cluster=theta_like(2, 2),
            strategy="stripe_aligned", codec=codec, chunk_size=0,
            delta_every=3, async_flush=False,
        )
    )
    for s in (1, 2):
        mgr.save(s, state_tree(s))
    assert not mgr.flush_errors
    impl = default_codec_impl()
    for p in list(mgr.pfs_dir.glob("step_*/manifest.json")) + list(
        (mgr.root / "local" / "manifests").glob("step_*.json")
    ):
        d = json.loads(p.read_text())
        d.pop("chunk_size", None)
        d.pop("chunks", None)
        # keep the backend honest for this environment (legacy default
        # is zstd, which may not be importable here)
        d["codec_impl"] = impl
        p.write_text(json.dumps(d))
    mgr._man_cache.clear()
    mgr._l0 = None
    mgr._last_full = None
    step, got = mgr.restore(np_target())
    assert step == 2
    assert_tree_equal(got, state_tree(2))
    # partial restore takes the whole-blob legacy path
    step, leaves = mgr.restore_leaves(["['opt']['mu']"])
    assert step == 2
    np.testing.assert_array_equal(
        leaves["['opt']['mu']"], np.asarray(state_tree(2)["opt"]["mu"])
    )
    mgr.close()


# ---------------------------------------------------------------------------
# partial restore under compression reads only the covering chunks
# ---------------------------------------------------------------------------


def big_state(step=0):
    rng = np.random.default_rng(1)
    return {
        "small": np.full((64,), step, np.float32),
        "big": (rng.standard_normal(1 << 16).astype(np.float32) + step),
        "tail": np.arange(333, dtype=np.int16) + step,
    }


def test_partial_restore_compressed_reads_only_covering_chunks(tmp_path):
    mgr = CheckpointManager(
        CheckpointConfig(
            root=str(tmp_path), cluster=theta_like(2, 2),
            strategy="stripe_aligned", codec="zstd", chunk_size=1 << 12,
            async_flush=False,
        )
    )
    st = mgr.save(1, big_state(1))
    mgr._l0 = None
    step, got = mgr.restore_leaves(["['small']"])
    assert step == 1
    np.testing.assert_array_equal(got["['small']"], big_state(1)["small"])
    rr = mgr.last_read_result
    assert rr is not None and 0 < rr.bytes_read < st.stored_bytes / 4
    # a leaf spanning many chunks still round-trips exactly
    _, got = mgr.restore_leaves(["['big']", "['tail']"])
    np.testing.assert_array_equal(got["['big']"], big_state(1)["big"])
    np.testing.assert_array_equal(got["['tail']"], big_state(1)["tail"])
    mgr.close()


def test_partial_restore_delta_recurses_into_base_chunks(tmp_path):
    """Partial restore of a delta step: base-referencing chunks pull
    their ranges out of the *base* checkpoint without materializing the
    whole base stream; changed chunks decode from the delta payload."""
    mgr = CheckpointManager(
        CheckpointConfig(
            root=str(tmp_path), cluster=theta_like(2, 2),
            strategy="stripe_aligned", codec="zstd+delta", chunk_size=1 << 12,
            delta_every=4, async_flush=False,
        )
    )
    s1 = big_state(1)
    mgr.save(1, s1)
    s2 = {k: v.copy() for k, v in s1.items()}
    s2["small"][:] = 42          # dirty a narrow region only
    mgr.save(2, s2)
    man2 = mgr._manifest_pfs(2)
    assert man2.base_step == 1
    assert ((man2.chunks.flags & CHUNK_BASE) != 0).any()
    # drop the in-memory twins: force the on-disk recursive path
    mgr._l0 = None
    mgr._last_full = None
    step, got = mgr.restore_leaves(["['small']", "['big']"], step=2)
    assert step == 2
    np.testing.assert_array_equal(got["['small']"], s2["small"])
    np.testing.assert_array_equal(got["['big']"], s2["big"])
    mgr.close()


# ---------------------------------------------------------------------------
# plumbing: merge_intervals, ChunkTable invariants, arrival callback
# ---------------------------------------------------------------------------


def test_merge_intervals_unions_and_drops_empty():
    s, n = merge_intervals([10, 0, 5, 30, 12], [5, 3, 5, 0, 2])
    np.testing.assert_array_equal(s, [0, 5])         # [5,10)+[10,15)+[12,14)
    np.testing.assert_array_equal(n, [3, 10])        # merge; [30,30) dropped
    s, n = merge_intervals([], [])
    assert len(s) == 0 and len(n) == 0


def test_chunk_table_validate_rejects_bad_tiling():
    c = theta_like(1, 2)
    enc = encode_state(1, state_tree(1), c, codec="zstd", chunk_size=512)
    tab = enc.manifest.chunks
    tab.validate(enc.manifest.ranks)       # the real table passes
    broken = ChunkTable(
        tab.rank_starts, tab.raw_off + 1, tab.raw_len,
        tab.stored_off, tab.stored_len, tab.crc, tab.flags,
    )
    with pytest.raises(ValueError, match="tile"):
        broken.validate(enc.manifest.ranks)


def test_read_plan_on_request_fires_once_per_request(tmp_path):
    from repro.core.plan import FileLayout, build_read_plan
    from repro.core.storage import LocalStore, RealExecutor

    rng = np.random.default_rng(5)
    payload = rng.bytes(1 << 14)
    sdir = tmp_path / "pfs" / "step_00000001"
    sdir.mkdir(parents=True)
    (sdir / "agg.dat").write_bytes(payload)
    layout = FileLayout(
        file_names=["agg.dat"], files={"agg.dat": len(payload)},
        start=[0], size=[len(payload)], file_id=[0], file_offset=[0],
        total=len(payload),
    )
    # several requests, including a zero-size one (fires up front)
    rp = build_read_plan(layout, [0, 100, 4000, 50], [100, 300, 1 << 10, 0])
    ex = RealExecutor(tmp_path / "pfs", LocalStore(tmp_path / "local", 1),
                      io_threads=4)
    seen = []
    bufs, _ = ex.execute_read_plan(rp, 1, on_request=lambda i, b: seen.append(i))
    ex.close()
    assert sorted(seen) == [0, 1, 2, 3]
    for i, (a, s) in enumerate([(0, 100), (100, 300), (4000, 1 << 10), (50, 0)]):
        assert bytes(bufs[i]) == payload[a : a + s]


def test_thread_local_compressor_reuse():
    """One compressor per worker thread, not one per chunk call."""
    zstd = pytest.importorskip("zstandard")
    from concurrent.futures import ThreadPoolExecutor

    from repro.core import serialize as ser

    made = []
    real = zstd.ZstdCompressor

    class Counting(real):
        def __init__(self, *a, **k):
            made.append(1)
            super().__init__(*a, **k)

    old = ser._zstd.ZstdCompressor
    ser._zstd.ZstdCompressor = Counting
    # fresh thread-locals for the counting run
    old_tls = ser._codec_tls
    ser._codec_tls = type(old_tls)()
    try:
        with ThreadPoolExecutor(max_workers=4) as pool:
            list(pool.map(
                lambda i: ser._zstd_c(bytes(1024)), range(256)
            ))
        assert 1 <= sum(made) <= 4         # bounded by threads, not calls
    finally:
        ser._zstd.ZstdCompressor = old
        ser._codec_tls = old_tls


# ---------------------------------------------------------------------------
# vectorized dequantize_tree == per-leaf kernel reference
# ---------------------------------------------------------------------------


def test_dequantize_tree_matches_reference():
    from concurrent.futures import ThreadPoolExecutor

    from repro.core.precodec import (
        dequantize_tree,
        dequantize_tree_reference,
        quantize_tree,
    )

    rng = np.random.default_rng(9)
    target = {
        "a": rng.standard_normal((64, 128)).astype(np.float32),
        "b": rng.standard_normal(5000).astype(np.float32) * 40,
        "small": np.float32(3.5),                     # below quant threshold
        "ints": np.arange(10, dtype=np.int32),        # not quantized
    }
    q = quantize_tree(target)
    ref = dequantize_tree_reference(q, target)
    fast = dequantize_tree(q, target)
    with ThreadPoolExecutor(max_workers=4) as pool:
        pooled = dequantize_tree(q, target, pool=pool)
    for k in target:
        np.testing.assert_array_equal(np.asarray(ref[k]), np.asarray(fast[k]))
        np.testing.assert_array_equal(np.asarray(ref[k]), np.asarray(pooled[k]))


# ---------------------------------------------------------------------------
# hypothesis sweep (optional dep, mirrors the other suites)
# ---------------------------------------------------------------------------

try:
    from hypothesis import HealthCheck, given, settings, strategies as hst

    _HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional test dep
    _HAVE_HYPOTHESIS = False


if _HAVE_HYPOTHESIS:

    @settings(
        max_examples=20, deadline=None,
        suppress_health_check=[HealthCheck.function_scoped_fixture],
    )
    @given(
        codec=hst.sampled_from(CODECS),
        chunk_size=hst.sampled_from([0, 64, 257, 1 << 12]),
        nodes=hst.integers(1, 4),
        ppn=hst.integers(1, 3),
        n_elems=hst.integers(0, 5000),
        dirty_frac=hst.floats(0, 1),
        seed=hst.integers(0, 2**31 - 1),
    )
    def test_codec_roundtrip_sweep(
        codec, chunk_size, nodes, ppn, n_elems, dirty_frac, seed
    ):
        rng = np.random.default_rng(seed)
        c = theta_like(nodes, ppn)
        t1 = {
            "a": rng.integers(0, 256, n_elems, np.uint8),
            "b": rng.standard_normal(max(1, n_elems // 9)).astype(np.float32),
        }
        e1 = encode_state(1, t1, c, codec=codec, chunk_size=chunk_size)
        tgt = {k: np.empty_like(v) for k, v in t1.items()}
        got = decode_state(e1.manifest, e1.blobs, tgt)
        for k in t1:
            np.testing.assert_array_equal(got[k], t1[k])
        # a second (possibly delta) step mutating a random fraction
        t2 = {k: v.copy() for k, v in t1.items()}
        if n_elems:
            k = int(n_elems * dirty_frac)
            t2["a"][:k] = rng.integers(0, 256, k, np.uint8)
        e2 = encode_state(2, t2, c, codec=codec, base=e1, chunk_size=chunk_size)
        base_stream = (
            bytes(e1.stream) if e2.manifest.base_step is not None else None
        )
        man2 = Manifest.from_json(e2.manifest.to_json())   # survives JSON
        got2 = decode_state(
            man2, e2.blobs, tgt, base_stream=base_stream
        )
        for k in t2:
            np.testing.assert_array_equal(got2[k], t2[k])
