"""Simulator tests: the paper's Figure 1/2 claims hold qualitatively."""
import dataclasses

import pytest

from repro.core import make_plan, simulate_flush, theta_like

GiB = 1 << 30


@pytest.fixture(scope="module")
def reports():
    c = theta_like(32, 8)
    sizes = [GiB] * c.world_size
    out = {}
    for strat, kw in [
        ("file_per_process", {}),
        ("posix", {}),
        ("mpiio", {"chunk_stripes": 64}),
        ("stripe_aligned", {"pipeline_chunk": 256 << 20}),
        ("gio_sync", {"chunk_stripes": 64}),
    ]:
        out[strat] = simulate_flush(make_plan(strat, c, sizes, **kw), io_threads=4)
    return out


def test_fig1_local_phase(reports):
    # aggregation leaves the local phase unchanged (prefix sum ~ free)
    base = reports["file_per_process"].local_time
    for s in ("posix", "mpiio", "stripe_aligned"):
        assert reports[s].local_time == pytest.approx(base, rel=0.05)
    # GIO writes synchronously to the PFS: much slower local phase
    assert reports["gio_sync"].local_time > 4 * base


def test_fig2_flush_ordering(reports):
    fpp = reports["file_per_process"].flush_bw
    # false sharing collapses POSIX aggregation (paper: §2.1)
    assert reports["posix"].flush_bw < 0.5 * fpp
    assert reports["posix"].pfs_lock_eff < 0.5
    # MPI-IO collective rounds underperform (paper: §2.2)
    assert reports["mpiio"].flush_bw < 0.8 * fpp
    # the §3 proposal is within 10% of embarrassingly-parallel flush
    assert reports["stripe_aligned"].flush_bw > 0.85 * fpp
    assert reports["stripe_aligned"].pfs_lock_eff > 0.99


def test_s3_aggregation_wins_on_metadata(reports):
    assert reports["stripe_aligned"].n_files == 1
    assert reports["file_per_process"].n_files == 256
    assert (
        reports["stripe_aligned"].metadata_ops
        < reports["file_per_process"].metadata_ops / 5
    )


def test_io_threads_tradeoff():
    # Tseng et al.: more flush threads -> more app slowdown
    c = theta_like(8, 4)
    plan = make_plan("stripe_aligned", c, [GiB] * 32)
    slow = [simulate_flush(plan, io_threads=t).app_slowdown for t in (1, 4, 8)]
    assert slow[0] < slow[1] < slow[2]


def test_straggler_derates_node():
    c = theta_like(8, 2)
    sizes = [GiB] * 16
    base = simulate_flush(make_plan("file_per_process", c, sizes)).flush_time
    c2 = c.with_(node_load=[0.8] + [0.0] * 7)
    slow = simulate_flush(make_plan("file_per_process", c2, sizes)).flush_time
    assert slow > 1.5 * base  # straggler dominates the unmitigated flush


def test_interference_shrinks_effective_nic():
    c = theta_like(8, 4)
    c = c.with_(node=dataclasses.replace(c.node, app_net_load=0.6))
    sizes = [GiB] * 32
    busy = simulate_flush(make_plan("file_per_process", c, sizes))
    quiet = simulate_flush(make_plan("file_per_process", theta_like(8, 4), sizes))
    assert busy.flush_time > quiet.flush_time
