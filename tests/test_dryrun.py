"""Dry-run machinery integration test (subprocess, 16 placeholder devices).

Compiles one real cell end-to-end on a 4x4 mesh and checks the record
has coherent roofline terms — the same code path the 256/512-chip
production dry-run uses.
"""
import json
import os
import subprocess
import sys
import tempfile
from pathlib import Path


def test_dryrun_cell_small_mesh():
    code = r"""
import repro.launch.dryrun as DR
import jax, json, sys
mesh = jax.make_mesh((4, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
from pathlib import Path
rec = DR.run_cell("tinyllama-1.1b", "decode_32k", multi_pod=False,
                  force=True, mesh=mesh, report_dir=Path(sys.argv[1]))
print(json.dumps({"status": rec["status"],
                  "flops": rec.get("roofline", {}).get("flops_per_dev", 0),
                  "coll": rec.get("roofline", {}).get("coll_bytes_per_dev", 0),
                  "mem": rec.get("memory", {}).get("per_device_bytes", 0),
                  "err": rec.get("error", "")}))
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env["REPRO_DRYRUN_DEVICES"] = "16"
    env.pop("XLA_FLAGS", None)
    with tempfile.TemporaryDirectory() as td:
        out = subprocess.run(
            [sys.executable, "-c", code, td],
            capture_output=True, text=True, timeout=560,
            env=env, cwd=str(Path(__file__).resolve().parents[1]),
        )
        assert out.returncode == 0, out.stderr[-3000:]
        rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["status"] == "ok", rec["err"]
    assert rec["flops"] > 0
    assert rec["mem"] > 0
