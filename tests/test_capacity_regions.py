"""Beyond-paper capacity-weighted leader regions stay valid plans."""
import numpy as np

from repro.core import make_plan, theta_like, validate_plan

GiB = 1 << 30


def test_capacity_regions_valid_and_skewed():
    rng = np.random.default_rng(3)
    c = theta_like(8, 2).with_(node_load=[0.8, 0, 0, 0, 0.8, 0, 0, 0])
    sizes = rng.integers(GiB // 4, GiB, c.world_size).tolist()
    plan = make_plan(
        "stripe_aligned", c, sizes, n_leaders=8, capacity_regions=True
    )
    validate_plan(plan)
    assert plan.stripe_disjoint
    sizes_per_region = [e - s for s, e in plan.leaders.regions]
    loads = [c.load_of(n) for n in plan.leaders.leaders]
    # loaded leaders own smaller regions than unloaded ones
    loaded = [sz for sz, ld in zip(sizes_per_region, loads) if ld > 0.5]
    clean = [sz for sz, ld in zip(sizes_per_region, loads) if ld <= 0.5]
    if loaded and clean:
        assert max(loaded) <= min(clean) * 1.01
