"""Per-kernel shape/dtype sweeps vs the pure-jnp/numpy oracles."""
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")  # interpret mode, no device needed

import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # fuzz tests skip; deterministic sweeps still run
    HAVE_HYPOTHESIS = False

    def given(*_a, **_kw):  # noqa: D103 - placeholder so decorators still apply
        return lambda fn: pytest.mark.skip(reason="hypothesis not installed")(fn)

    def settings(*_a, **_kw):
        return lambda fn: fn

    class _St:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _St()

from repro.kernels.checksum import checksum_u32, digest_bytes
from repro.kernels.checksum.ref import checksum_ref_np, digest_ref
from repro.kernels.delta import xor_delta
from repro.kernels.delta.ref import delta_ref
from repro.kernels.fused import (
    CHUNK_ALIGN,
    TILE,
    chunk_digests_ref,
    digests_from_meta,
    dirty_from_meta,
    fused_precodec,
    fused_ref,
)
from repro.kernels.quantize import dequantize, quantize
from repro.kernels.quantize.ref import dequantize_ref, quantize_ref

RNG = np.random.default_rng(1234)


# ---------------------------------------------------------------------------
# checksum
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [0, 1, 3, 1023, 1024, 1025, 4096, 100_003])
def test_checksum_shapes(n):
    w = RNG.integers(0, 2**32, n, dtype=np.uint32)
    s, t = np.asarray(checksum_u32(jnp.asarray(w)))
    rs, rt = checksum_ref_np(w)
    assert (int(s), int(t)) == (rs, rt)


def test_checksum_detects_flip_and_swap():
    w = RNG.integers(0, 2**32, 5000, dtype=np.uint32)
    base = digest_ref(w)
    flip = w.copy()
    flip[1234] ^= 1
    assert digest_ref(flip) != base
    swap = w.copy()
    swap[10], swap[4000] = swap[4000], swap[10]
    assert digest_ref(swap) != base  # position track catches moves


@settings(max_examples=25, deadline=None)
@given(st.binary(min_size=0, max_size=4096))
def test_checksum_bytes_fuzz(data):
    got = digest_bytes(data)
    pad = (-len(data)) % 4
    w = np.frombuffer(data + b"\0" * pad, dtype=np.uint32)
    assert got == digest_ref(w)


# ---------------------------------------------------------------------------
# quantize
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [np.float32, np.float16])
@pytest.mark.parametrize("n", [128, 4096, 4096 + 77, 50_000])
def test_quantize_matches_ref(dtype, n):
    x = (RNG.standard_normal(n) * 7).astype(dtype)
    q, s = quantize(jnp.asarray(x))
    pad = (-n) % 4096
    ref_q, ref_s = quantize_ref(
        np.pad(x.astype(np.float32), (0, pad)).reshape(-1, 128)
    )
    # XLA and numpy f32 division may differ by 1 ulp exactly at rounding
    # ties -> allow |q - ref| <= 1 on a vanishing fraction of elements.
    diff = np.abs(np.asarray(q).astype(np.int32) - ref_q.astype(np.int32))
    assert diff.max() <= 1
    assert (diff != 0).mean() < 1e-3
    np.testing.assert_allclose(np.asarray(s), ref_s, rtol=1e-6)
    back = np.asarray(dequantize(q, s, n=n))
    ref_back = dequantize_ref(ref_q, ref_s).reshape(-1)[:n]
    scale_full = np.repeat(ref_s, 128)[:n]
    assert np.abs(back - ref_back).max() <= scale_full.max() + 1e-6


def test_quantize_error_bound():
    x = (RNG.standard_normal(10_000) * 100).astype(np.float32)
    q, s = quantize(jnp.asarray(x))
    back = np.asarray(dequantize(q, s, n=x.size))
    blocks = np.pad(x, (0, (-x.size) % 4096)).reshape(-1, 128)
    bound = (np.abs(blocks).max(1) / 127.0)[:, None] * 0.5 + 1e-7
    err = np.abs(np.pad(x, (0, (-x.size) % 4096)).reshape(-1, 128)
                 - np.pad(back, (0, (-x.size) % 4096)).reshape(-1, 128))
    assert (err <= bound + 1e-6).all()


def test_quantize_zero_block():
    x = np.zeros(256, np.float32)
    q, s = quantize(jnp.asarray(x))
    assert np.asarray(q).sum() == 0
    np.testing.assert_array_equal(np.asarray(dequantize(q, s, n=256)), x)


# ---------------------------------------------------------------------------
# delta
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [1, 1024, 9999, 65536])
def test_delta_matches_ref(n):
    a = RNG.integers(0, 2**32, n, dtype=np.uint32)
    b = a.copy()
    b[:: max(1, n // 17)] ^= 0xA5A5A5A5
    d, cnt = xor_delta(jnp.asarray(a), jnp.asarray(b))
    rd, rcnt = delta_ref(a, b)
    np.testing.assert_array_equal(np.asarray(d), rd)
    assert int(cnt) == rcnt


def test_delta_roundtrip():
    a = RNG.integers(0, 2**32, 5000, dtype=np.uint32)
    b = RNG.integers(0, 2**32, 5000, dtype=np.uint32)
    d, _ = xor_delta(jnp.asarray(a), jnp.asarray(b))
    back, _ = xor_delta(d, jnp.asarray(a))
    np.testing.assert_array_equal(np.asarray(back), b)


# ---------------------------------------------------------------------------
# fused precodec pass (delta + dirty counts + checksums, one launch)
# ---------------------------------------------------------------------------

CW = TILE  # smallest legal chunk: one (8, 128) u32 tile = 4 KiB


def _fused_vs_ref(cur, base, chunk_words):
    delta, meta = fused_precodec(
        jnp.asarray(cur), jnp.asarray(base), chunk_words=chunk_words
    )
    rd, rc, rg = fused_ref(cur, base, chunk_words)
    np.testing.assert_array_equal(np.asarray(delta), rd)
    np.testing.assert_array_equal(np.asarray(meta)[:, 0], rc)
    np.testing.assert_array_equal(np.asarray(digests_from_meta(meta)), rg)
    np.testing.assert_array_equal(np.asarray(dirty_from_meta(meta)), rc > 0)


@pytest.mark.parametrize("n", [1, 1023, 1024, 4096, 4097, 12_305])
@pytest.mark.parametrize("chunk_words", [CW, 4 * CW])
def test_fused_matches_ref(n, chunk_words):
    cur = RNG.integers(0, 2**32, n, dtype=np.uint32)
    base = cur.copy()
    base[:: max(1, n // 13)] ^= 0xDEADBEEF
    _fused_vs_ref(cur, base, chunk_words)


def test_fused_all_clean_and_all_dirty():
    cur = RNG.integers(0, 2**32, 5 * CW, dtype=np.uint32)
    # all clean: every chunk digest still set, no chunk dirty
    _, meta = fused_precodec(jnp.asarray(cur), jnp.asarray(cur), chunk_words=CW)
    assert not np.asarray(dirty_from_meta(meta)).any()
    np.testing.assert_array_equal(
        np.asarray(digests_from_meta(meta)), chunk_digests_ref(cur, CW)
    )
    # all dirty (base = ~cur flips every word)
    _, meta = fused_precodec(jnp.asarray(cur), jnp.asarray(~cur), chunk_words=CW)
    assert np.asarray(dirty_from_meta(meta)).all()


def test_fused_digest_matches_per_chunk_checksum():
    # per-chunk digests restart indexing at the chunk boundary, so each one
    # must equal digest_ref of that chunk's words taken in isolation
    cur = RNG.integers(0, 2**32, 3 * CW + 100, dtype=np.uint32)
    _, meta = fused_precodec(
        jnp.asarray(cur), jnp.zeros(cur.shape, np.uint32), chunk_words=CW
    )
    got = np.asarray(digests_from_meta(meta))
    padded = np.pad(cur, (0, (-cur.size) % CW))
    for ci, chunk in enumerate(padded.reshape(-1, CW)):
        assert int(got[ci]) == digest_ref(chunk)


def test_fused_rejects_bad_chunk_words():
    w = jnp.zeros(CW, jnp.uint32)
    with pytest.raises(ValueError):
        fused_precodec(w, w, chunk_words=CW + 1)
    with pytest.raises(ValueError):
        fused_precodec(w, jnp.zeros(2 * CW, jnp.uint32), chunk_words=CW)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=3 * CW + 7),
    flips=st.integers(min_value=0, max_value=50),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_fused_fuzz(n, flips, seed):
    rng = np.random.default_rng(seed)
    cur = rng.integers(0, 2**32, n, dtype=np.uint32)
    base = cur.copy()
    if flips and n:
        base[rng.integers(0, n, flips)] ^= rng.integers(
            1, 2**32, flips, dtype=np.uint32
        )
    _fused_vs_ref(cur, base, CW)
