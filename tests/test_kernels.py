"""Per-kernel shape/dtype sweeps vs the pure-jnp/numpy oracles."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels.checksum import checksum_u32, digest_bytes
from repro.kernels.checksum.ref import checksum_ref_np, digest_ref
from repro.kernels.delta import xor_delta
from repro.kernels.delta.ref import delta_ref
from repro.kernels.quantize import dequantize, quantize
from repro.kernels.quantize.ref import dequantize_ref, quantize_ref

RNG = np.random.default_rng(1234)


# ---------------------------------------------------------------------------
# checksum
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [0, 1, 3, 1023, 1024, 1025, 4096, 100_003])
def test_checksum_shapes(n):
    w = RNG.integers(0, 2**32, n, dtype=np.uint32)
    s, t = np.asarray(checksum_u32(jnp.asarray(w)))
    rs, rt = checksum_ref_np(w)
    assert (int(s), int(t)) == (rs, rt)


def test_checksum_detects_flip_and_swap():
    w = RNG.integers(0, 2**32, 5000, dtype=np.uint32)
    base = digest_ref(w)
    flip = w.copy()
    flip[1234] ^= 1
    assert digest_ref(flip) != base
    swap = w.copy()
    swap[10], swap[4000] = swap[4000], swap[10]
    assert digest_ref(swap) != base  # position track catches moves


@settings(max_examples=25, deadline=None)
@given(st.binary(min_size=0, max_size=4096))
def test_checksum_bytes_fuzz(data):
    got = digest_bytes(data)
    pad = (-len(data)) % 4
    w = np.frombuffer(data + b"\0" * pad, dtype=np.uint32)
    assert got == digest_ref(w)


# ---------------------------------------------------------------------------
# quantize
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [np.float32, np.float16])
@pytest.mark.parametrize("n", [128, 4096, 4096 + 77, 50_000])
def test_quantize_matches_ref(dtype, n):
    x = (RNG.standard_normal(n) * 7).astype(dtype)
    q, s = quantize(jnp.asarray(x))
    pad = (-n) % 4096
    ref_q, ref_s = quantize_ref(
        np.pad(x.astype(np.float32), (0, pad)).reshape(-1, 128)
    )
    # XLA and numpy f32 division may differ by 1 ulp exactly at rounding
    # ties -> allow |q - ref| <= 1 on a vanishing fraction of elements.
    diff = np.abs(np.asarray(q).astype(np.int32) - ref_q.astype(np.int32))
    assert diff.max() <= 1
    assert (diff != 0).mean() < 1e-3
    np.testing.assert_allclose(np.asarray(s), ref_s, rtol=1e-6)
    back = np.asarray(dequantize(q, s, n=n))
    ref_back = dequantize_ref(ref_q, ref_s).reshape(-1)[:n]
    scale_full = np.repeat(ref_s, 128)[:n]
    assert np.abs(back - ref_back).max() <= scale_full.max() + 1e-6


def test_quantize_error_bound():
    x = (RNG.standard_normal(10_000) * 100).astype(np.float32)
    q, s = quantize(jnp.asarray(x))
    back = np.asarray(dequantize(q, s, n=x.size))
    blocks = np.pad(x, (0, (-x.size) % 4096)).reshape(-1, 128)
    bound = (np.abs(blocks).max(1) / 127.0)[:, None] * 0.5 + 1e-7
    err = np.abs(np.pad(x, (0, (-x.size) % 4096)).reshape(-1, 128)
                 - np.pad(back, (0, (-x.size) % 4096)).reshape(-1, 128))
    assert (err <= bound + 1e-6).all()


def test_quantize_zero_block():
    x = np.zeros(256, np.float32)
    q, s = quantize(jnp.asarray(x))
    assert np.asarray(q).sum() == 0
    np.testing.assert_array_equal(np.asarray(dequantize(q, s, n=256)), x)


# ---------------------------------------------------------------------------
# delta
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [1, 1024, 9999, 65536])
def test_delta_matches_ref(n):
    a = RNG.integers(0, 2**32, n, dtype=np.uint32)
    b = a.copy()
    b[:: max(1, n // 17)] ^= 0xA5A5A5A5
    d, cnt = xor_delta(jnp.asarray(a), jnp.asarray(b))
    rd, rcnt = delta_ref(a, b)
    np.testing.assert_array_equal(np.asarray(d), rd)
    assert int(cnt) == rcnt


def test_delta_roundtrip():
    a = RNG.integers(0, 2**32, 5000, dtype=np.uint32)
    b = RNG.integers(0, 2**32, 5000, dtype=np.uint32)
    d, _ = xor_delta(jnp.asarray(a), jnp.asarray(b))
    back, _ = xor_delta(d, jnp.asarray(a))
    np.testing.assert_array_equal(np.asarray(back), b)
