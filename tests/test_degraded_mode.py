"""Degraded-mode availability runtime: circuit breaker, parking,
backpressure, hedged reads.

ISSUE 8's acceptance surface:

* the :class:`StorageHealth` circuit breaker transitions exactly per
  its fault schedule (injectable clock — no wall-clock scheduling);
* a PFS outage never fails a ``save()`` and never burns a retry
  budget to a giveup: flushes *park* at ``flush_partial`` with their
  journals intact while saves keep landing on L0/L1;
* once the outage heals, the parked backlog auto-drains and every
  step restores byte-identically — on all five strategies;
* the L1 byte budget applies backpressure by evicting the oldest
  non-pinned step, and raises :class:`L1CapacityError` (before any
  byte is written) only when nothing is evictable;
* hedged reads cut the restore tail under a straggler reader and are
  harmless when the hedge loses the race (or has no alternate copy);
* ``TokenBucket.acquire`` sleeps the computed deficit, not fixed
  poll slices;
* the serve fleet's ``stop()`` never silently discards a live
  follower, and its follower defers adoption while the manager
  reports itself degraded.
"""
import errno
import threading
import time

import numpy as np
import pytest

from repro.core import (
    CheckpointConfig,
    CheckpointManager,
    CircuitOpenError,
    FaultPlan,
    FaultSpec,
    L1CapacityError,
    RetryPolicy,
    StorageHealth,
    TokenBucket,
    theta_like,
)
from repro.core.plan import PlanError, assign_readers

ALL_STRATEGIES = ["file_per_process", "posix", "mpiio", "stripe_aligned", "gio_sync"]


def state(step, kib=64):
    rng = np.random.default_rng(step)
    return {
        "w": rng.standard_normal((kib * 1024 // 8 // 2, 2)).astype(np.float64),
        "b": np.full((32,), step, np.float32),
    }


def trees_equal(a, b):
    return all(np.array_equal(np.asarray(a[k]), np.asarray(b[k])) for k in a)


def make_mgr(tmp_path, **kw):
    faults = kw.pop("_faults", None)
    kw.setdefault("cluster", theta_like(2, 2))
    kw.setdefault("async_flush", False)
    cfg = CheckpointConfig(root=str(tmp_path / "ckpt"), **kw)
    return CheckpointManager(cfg, faults=faults)


def forget_memory(mgr):
    mgr._l0 = None
    mgr._last_full = None


class FakeClock:
    def __init__(self, t=100.0):
        self.t = float(t)

    def __call__(self):
        return self.t


# ---------------------------------------------------- circuit transitions


def test_circuit_trips_on_window_error_rate():
    clk = FakeClock()
    sh = StorageHealth(min_ops=4, error_threshold=0.5, cooldown=10.0, clock=clk)
    # below min_ops: errors accumulate but never trip
    for _ in range(3):
        sh.record("pfs", False)
        assert sh.state("pfs") == "closed"
    sh.record("pfs", False)  # 4th error: rate 1.0 over >= min_ops
    assert sh.state("pfs") == "open"
    assert sh.trips == 1
    with pytest.raises(CircuitOpenError) as ei:
        sh.check("pfs")
    assert ei.value.errno == errno.EHOSTDOWN
    assert ei.value.domain == "pfs"
    assert 0 < ei.value.retry_in <= 10.0
    # a healthy domain is untouched
    sh.check("l1:n0")
    assert sh.state("l1:n0") == "closed"


def test_circuit_successes_dilute_error_rate():
    sh = StorageHealth(min_ops=4, error_threshold=0.5, clock=FakeClock())
    for ok in (True, True, True, False, True, False, True, True):
        sh.record("pfs", ok)
    assert sh.state("pfs") == "closed"  # 2/8 = 0.25 < 0.5


def test_circuit_half_open_probe_admission_and_close():
    clk = FakeClock()
    sh = StorageHealth(
        min_ops=2, cooldown=5.0, probe_successes=2, probe_parallel=2, clock=clk
    )
    sh.record("pfs", False)
    sh.record("pfs", False)
    assert sh.state("pfs") == "open"
    with pytest.raises(CircuitOpenError):
        sh.check("pfs")
    clk.t += 5.0  # cooldown elapsed: probes admitted
    assert sh.state("pfs") == "half_open"
    sh.check("pfs")  # probe 1 admitted
    sh.check("pfs")  # probe 2 admitted
    with pytest.raises(CircuitOpenError):
        sh.check("pfs")  # probe_parallel exhausted
    sh.record("pfs", True)
    assert sh.state("pfs") == "half_open"  # 1 of probe_successes
    sh.record("pfs", True)
    assert sh.state("pfs") == "closed"
    sh.check("pfs")  # and ops flow freely again


def test_circuit_failed_probe_reopens_with_fresh_cooldown():
    clk = FakeClock()
    sh = StorageHealth(min_ops=2, cooldown=5.0, clock=clk)
    sh.record("pfs", False)
    sh.record("pfs", False)
    clk.t += 5.0
    sh.check("pfs")  # admitted as probe
    sh.record("pfs", False)  # probe fails
    assert sh.state("pfs") == "open"
    assert sh.trips == 2
    with pytest.raises(CircuitOpenError) as ei:
        sh.check("pfs")
    assert ei.value.retry_in == pytest.approx(5.0)  # cooldown restarted


def test_circuit_opens_immediately_on_giveup():
    sh = StorageHealth(min_ops=64, clock=FakeClock())
    sh.record("pfs", False, giveup=True)
    assert sh.state("pfs") == "open"
    sh2 = StorageHealth(min_ops=64, open_on_giveup=False, clock=FakeClock())
    sh2.record("pfs", False, giveup=True)
    assert sh2.state("pfs") == "closed"


def test_retry_layer_feeds_health_but_never_enoent():
    """FileNotFoundError is a correct answer from a healthy medium
    (the restore ladder probes levels with it constantly) — it must
    not charge the circuit."""
    sh = StorageHealth(min_ops=2, clock=FakeClock())
    pol = RetryPolicy(attempts=3, base_delay=0.001, max_delay=0.002, seed=0)
    pol.health = sh

    def gone():
        raise FileNotFoundError(errno.ENOENT, "probe miss")

    for _ in range(8):
        with pytest.raises(FileNotFoundError):
            pol.run(gone, domain="pfs")
    snap = sh.snapshot()
    assert snap.get("pfs") is None or snap["pfs"].errors == 0
    assert sh.state("pfs") == "closed"
    # a genuinely permanent error IS recorded
    with pytest.raises(OSError):
        pol.run(
            lambda: (_ for _ in ()).throw(OSError(errno.ENOSPC, "full")),
            domain="pfs",
        )
    assert sh.snapshot()["pfs"].errors == 1


def test_open_circuit_fails_fast_without_running_the_op():
    sh = StorageHealth(min_ops=2, cooldown=60.0, clock=FakeClock())
    sh.record("pfs", False)
    sh.record("pfs", False)
    pol = RetryPolicy(attempts=5, base_delay=0.001, seed=0)
    pol.health = sh
    calls = {"n": 0}

    def op():
        calls["n"] += 1
        return "ok"

    with pytest.raises(CircuitOpenError):
        pol.run(op, domain="pfs")
    assert calls["n"] == 0, "check() must gate before the attempt"
    assert pol.giveups == 0 and pol.retries == 0


# ------------------------------------------------ outage -> park -> drain


@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
def test_outage_parks_then_drains_byte_identical(tmp_path, strategy):
    """A PFS outage across two sync saves: no save fails, no retry
    budget gives up, both steps park at flush_partial and stay
    L1-restorable; after heal the backlog drains byte-identically."""
    faults = FaultPlan(
        [FaultSpec(kind="outage", domain="pfs", op="write", index=0, count=10**9)]
    )
    mgr = make_mgr(
        tmp_path, strategy=strategy, _faults=faults,
        retry_attempts=5, retry_base_delay=0.001, retry_max_delay=0.002,
        health_min_ops=2, health_cooldown=0.05,
    )
    mgr.faults.arm("save")
    try:
        for s in (1, 2):
            st = mgr.save(s, state(s))
            assert st.flush is None, f"{strategy}: parked save must not flush"
        h = mgr.health()
        assert h.mode == "degraded"
        assert h.parked_steps == [1, 2]
        assert h.degraded_since is not None
        assert h.circuits["pfs"] in ("open", "half_open")
        assert mgr.flush_errors == []
        assert mgr.retry.giveups == 0
        assert mgr.storage_health.snapshot()["pfs"].giveups == 0
        assert mgr.steps("pfs") == []
        assert mgr.steps("local") == [1, 2]
        assert mgr.step_status(2) == "flush_partial"
        # parked steps restore from L1 during the outage
        forget_memory(mgr)
        s, tree = mgr.restore(state(2))
        assert s == 2 and trees_equal(tree, state(2))
        # heal -> the public health surface probes and drains
        faults.heal()
        faults.disarm()
        deadline = time.monotonic() + 30
        while mgr.health().parked_steps and time.monotonic() < deadline:
            mgr.health_check()
            time.sleep(0.01)
        h = mgr.health()
        assert h.parked_steps == []
        assert h.mode == "normal"
        assert h.drained_steps == 2
        assert mgr.flush_errors == []
        assert mgr.steps("pfs") == [1, 2]
    finally:
        mgr.close()
    # byte-identical from the PFS alone: fresh manager, no L0, no L1
    m2 = make_mgr(tmp_path, strategy=strategy)
    try:
        m2.local.drop_node(0)
        m2.local.drop_node(1)
        for s in (1, 2):
            got, tree = m2.restore(state(s), step=s)
            assert got == s and trees_equal(tree, state(s))
    finally:
        m2.close()


def test_outage_async_scheduler_parks_and_auto_drains(tmp_path):
    """Async manager: the flush scheduler parks jobs while the circuit
    is open and drains them on its own idle ticks after heal — no
    explicit resume_flushes()/health_check() calls."""
    faults = FaultPlan(
        [FaultSpec(kind="outage", domain="pfs", op="write", index=0, count=10**9)]
    )
    mgr = make_mgr(
        tmp_path, strategy="posix", async_flush=True, _faults=faults,
        retry_attempts=5, retry_base_delay=0.001, retry_max_delay=0.002,
        health_min_ops=2, health_cooldown=0.05, health_tick=0.05,
        max_pending_flushes=4,
    )
    mgr.faults.arm("save")
    try:
        for s in (1, 2, 3):
            mgr.save(s, state(s))
        deadline = time.monotonic() + 30
        while (
            len(mgr.health().parked_steps) < 3 and time.monotonic() < deadline
        ):
            time.sleep(0.01)
        h = mgr.health()
        assert h.parked_steps == [1, 2, 3]
        assert h.mode == "degraded"
        assert mgr.flush_errors == []
        assert mgr.retry.giveups == 0
        faults.heal()
        faults.disarm()
        deadline = time.monotonic() + 30
        while mgr.steps("pfs") != [1, 2, 3] and time.monotonic() < deadline:
            time.sleep(0.02)
        assert mgr.steps("pfs") == [1, 2, 3]
        assert mgr.flush_errors == []
        assert mgr.retry.giveups == 0
        h = mgr.health()
        assert h.mode == "normal" and h.parked_steps == []
        forget_memory(mgr)
        mgr.local.drop_node(0)
        mgr.local.drop_node(1)
        s, tree = mgr.restore(state(3))
        assert s == 3 and trees_equal(tree, state(3))
    finally:
        mgr.close()


def test_auto_resume_drains_leftover_partial_on_construction(tmp_path):
    """A flush_partial left by a crashed/failed run finishes during
    construction when auto_resume=True — no explicit call."""
    faults = FaultPlan(
        [FaultSpec(kind="enospc", domain="pfs", op="write", index=1)]
    )
    mgr = make_mgr(tmp_path, strategy="posix", _faults=faults)
    mgr.faults.arm("save")
    try:
        with pytest.raises(OSError):
            mgr.save(1, state(1))
        assert 1 not in mgr.steps("pfs")
        assert mgr.step_status(1) == "flush_partial"
    finally:
        mgr.close()
    m2 = make_mgr(tmp_path, strategy="posix", auto_resume=True)
    try:
        assert m2.steps("pfs") == [1]
        assert m2.step_status(1) == "flush_done"
        forget_memory(m2)
        m2.local.drop_node(0)
        m2.local.drop_node(1)
        s, tree = m2.restore(state(1))
        assert s == 1 and trees_equal(tree, state(1))
    finally:
        m2.close()


# ------------------------------------------------------- L1 backpressure


def _one_step_l1_cost(tmp_path):
    probe = make_mgr(tmp_path / "probe", strategy="posix")
    try:
        probe.save(0, state(0))
        return probe.health().l1_bytes
    finally:
        probe.close()


def test_l1_budget_evicts_oldest_keeps_pfs_intact(tmp_path):
    cost = _one_step_l1_cost(tmp_path)
    assert cost > 0
    mgr = make_mgr(
        tmp_path, strategy="posix",
        l1_capacity_bytes=int(cost * 3) + 256,
    )
    try:
        for s in range(6):
            mgr.save(s, state(s))
        h = mgr.health()
        assert h.l1_bytes <= h.l1_capacity
        assert h.evicted_steps, "over-budget saves must evict"
        assert min(h.evicted_steps) == 0, "victims are oldest-first"
        # every step still flushed: eviction never loses PFS data
        assert mgr.steps("pfs") == list(range(6))
        assert mgr.flush_errors == []
        # an evicted step restores from the PFS copy
        forget_memory(mgr)
        s, tree = mgr.restore(state(0), step=0)
        assert s == 0 and trees_equal(tree, state(0))
    finally:
        mgr.close()


def test_l1_budget_raises_before_writing_when_all_pinned(tmp_path):
    cost = _one_step_l1_cost(tmp_path)
    mgr = make_mgr(
        tmp_path, strategy="posix", keep_n=8,
        l1_capacity_bytes=int(cost * 2) + 256,
    )
    try:
        mgr.save(1, state(1))
        mgr.save(2, state(2))
        with pytest.raises(L1CapacityError) as ei:
            mgr.save(3, state(3))
        assert "L1 budget" in str(ei.value)
        # nothing of step 3 landed anywhere
        assert 3 not in mgr.steps("local")
        assert 3 not in mgr.steps("pfs")
        # and the resident steps are untouched
        forget_memory(mgr)
        s, tree = mgr.restore(state(2))
        assert s == 2 and trees_equal(tree, state(2))
    finally:
        mgr.close()


def test_l1_budget_never_evicts_delta_anchor(tmp_path):
    """Under zstd+delta the full-snapshot anchor must survive
    eviction pressure — evicting it would strand every delta built
    on it."""
    cost = _one_step_l1_cost(tmp_path)
    mgr = make_mgr(
        tmp_path, codec="zstd+delta", delta_every=4, chunk_size=4096,
        l1_capacity_bytes=int(cost * 3) + 256,
    )
    try:
        for s in range(1, 4):  # 1 = full anchor, 2..3 deltas
            mgr.save(s, state(s))
        assert 1 not in mgr.health().evicted_steps
        forget_memory(mgr)
        s, tree = mgr.restore(state(3))
        assert s == 3 and trees_equal(tree, state(3))
    finally:
        mgr.close()


# ---------------------------------------------------------- hedged reads


def test_hedged_restore_beats_straggler_reader(tmp_path):
    """One straggler reader node slows every PFS pread it runs; the
    hedge re-issues those extents from L1 and the restore finishes
    without waiting out the straggler."""
    mgr = make_mgr(tmp_path, strategy="posix")
    mgr.save(1, state(1))
    mgr.close()

    delay = 0.15
    faults = FaultPlan(
        [FaultSpec(kind="straggler", domain="pfs", op="read", node=1,
                   delay=delay, phase="verify")]
    )
    # unhedged: the plan waits out every slowed pread
    m_plain = make_mgr(tmp_path, strategy="posix", _faults=faults)
    try:
        faults.arm("verify")
        t0 = time.perf_counter()
        s, tree = m_plain.restore(state(1))
        t_plain = time.perf_counter() - t0
        assert s == 1 and trees_equal(tree, state(1))
    finally:
        m_plain.close()
    assert t_plain >= delay * 0.9

    m_hedge = make_mgr(
        tmp_path, strategy="posix", _faults=faults,
        hedged_reads=True, hedge_min_delay=0.01,
    )
    try:
        faults.arm("verify")
        t0 = time.perf_counter()
        s, tree = m_hedge.restore(state(1))
        t_hedge = time.perf_counter() - t0
        assert s == 1 and trees_equal(tree, state(1))
        rr = m_hedge.last_read_result
        assert rr is not None and rr.hedges_issued > 0
        assert rr.hedge_wins > 0, "the L1 hedge must beat the straggler"
        assert t_hedge < t_plain
    finally:
        m_hedge.close()


def test_hedge_losing_the_race_is_harmless(tmp_path):
    """With the L1 copies gone the hedge has no alternate source —
    issued hedges all lose, and the plan still completes correctly
    from the (slow) primary reads."""
    mgr = make_mgr(tmp_path, strategy="posix")
    mgr.save(1, state(1))
    mgr.close()

    faults = FaultPlan(
        [FaultSpec(kind="straggler", domain="pfs", op="read", node=1,
                   delay=0.08, phase="verify")]
    )
    m2 = make_mgr(
        tmp_path, strategy="posix", _faults=faults,
        hedged_reads=True, hedge_min_delay=0.01,
    )
    try:
        m2.local.drop_node(0)
        m2.local.drop_node(1)
        faults.arm("verify")
        s, tree = m2.restore(state(1))
        assert s == 1 and trees_equal(tree, state(1))
        rr = m2.last_read_result
        assert rr is not None and rr.hedge_wins == 0
    finally:
        m2.close()


def test_reader_weights_demote_straggler_node(tmp_path):
    mgr = make_mgr(tmp_path, strategy="posix", hedged_reads=True)
    try:
        assert mgr._reader_weights() is None  # no history yet
        sh = mgr.storage_health
        for _ in range(8):
            sh.note_latency("reader:n0", 0.25)
            sh.note_latency("reader:n1", 0.01)
        w = mgr._reader_weights()
        assert w is not None
        assert w[0] < w[1], "the slow reader must get less space"
    finally:
        mgr.close()


def test_assign_readers_weights_identity_and_skew():
    sizes = np.asarray([100, 100, 100, 100, 100, 100], np.int64)
    base = assign_readers(sizes, 2)
    # None and all-equal weights are byte-identical to unweighted
    assert np.array_equal(assign_readers(sizes, 2, weights=[3.0, 3.0]), base)
    # a demoted reader 0 takes a strictly smaller share
    skew = assign_readers(sizes, 2, weights=[0.2, 1.0])
    assert (skew == 0).sum() < (base == 0).sum()
    with pytest.raises(PlanError):
        assign_readers(sizes, 2, weights=[1.0])  # wrong length
    with pytest.raises(PlanError):
        assign_readers(sizes, 2, weights=[1.0, -1.0])  # non-positive


# ------------------------------------------------------------ TokenBucket


def test_token_bucket_sleeps_computed_deficit_not_poll_slices():
    rate = 4_000_000.0
    tb = TokenBucket(rate, burst=1_000_000)
    assert tb.acquire(1_000_000) == 0.0  # burst covers it
    tb.acquire(400_000)  # drives the bucket into debt
    t0 = time.monotonic()
    waited = tb.acquire(1)
    elapsed = time.monotonic() - t0
    # the debt refills in ~0.1 s; the old implementation polled in
    # fixed 0.25 s slices and would oversleep past 0.25 s here
    assert waited == pytest.approx(0.1, abs=0.06)
    assert elapsed < 0.24
    assert tb.wait_total == pytest.approx(waited, rel=0.5)


# --------------------------------------------------------- ManagerHealth


def test_manager_health_surface_normal_mode(tmp_path):
    mgr = make_mgr(tmp_path, strategy="posix")
    try:
        mgr.save(1, state(1))
        h = mgr.health()
        assert h.mode == "normal"
        assert h.queue_depth == 0
        assert h.parked_steps == [] and h.evicted_steps == []
        assert h.l1_bytes > 0 and h.l1_capacity == 0
        assert h.degraded_since is None and h.drained_steps == 0
        assert h.circuits.get("pfs", "closed") == "closed"
    finally:
        mgr.close()


def test_health_disabled_keeps_seed_retry_semantics(tmp_path):
    """health_enabled=False: an outage burns the retry budget and
    fails the flush the old way — no parking, no circuit."""
    faults = FaultPlan(
        [FaultSpec(kind="outage", domain="pfs", op="write", index=0, count=10**9)]
    )
    mgr = make_mgr(
        tmp_path, strategy="posix", _faults=faults, health_enabled=False,
        retry_attempts=3, retry_base_delay=0.001, retry_max_delay=0.002,
    )
    mgr.faults.arm("save")
    try:
        with pytest.raises(OSError):
            mgr.save(1, state(1))
        assert mgr.retry.giveups >= 1
        assert mgr.health().parked_steps == []
    finally:
        mgr.close()


# ------------------------------------------------------------ serve fleet


def test_fleet_stop_raises_on_stuck_follower(tmp_path):
    pytest.importorskip("jax")
    from repro.serve.fleet import FleetConfig, ServeFleet

    class _Mgr:
        def __init__(self):
            self.release = threading.Event()
            self.entered = threading.Event()

        def steps(self, level):
            self.entered.set()
            self.release.wait(20)  # a wedged PFS listing
            return []

    fm = _Mgr()
    fleet = ServeFleet(
        object(), fm, {"w": np.zeros(3)},
        cfg=FleetConfig(n_servers=1, poll_interval=0.01),
    )
    try:
        fleet.start_follower()
        assert fm.entered.wait(5)
        with pytest.raises(RuntimeError, match="did not stop"):
            fleet.stop(timeout=0.2)
        assert fleet._follower is not None, "live thread must not be dropped"
    finally:
        fm.release.set()
        fleet.close(timeout=10)
    assert fleet._follower is None
    assert fleet.servers == []


def test_fleet_follower_defers_adoption_while_degraded(tmp_path):
    pytest.importorskip("jax")
    from repro.serve.fleet import FleetConfig, ServeFleet

    class _H:
        def __init__(self, mode):
            self.mode = mode

    class _Mgr:
        def __init__(self):
            self.h = _H("degraded")
            self.steps_calls = 0

        def health(self):
            return self.h

        def steps(self, level):
            self.steps_calls += 1
            return []

    fm = _Mgr()
    fleet = ServeFleet(
        object(), fm, {"w": np.zeros(3)},
        cfg=FleetConfig(n_servers=1, poll_interval=0.01),
    )
    try:
        fleet.start_follower()
        time.sleep(0.2)
        assert fm.steps_calls == 0, "no adoption attempts while degraded"
        fm.h = _H("normal")
        deadline = time.monotonic() + 5
        while fm.steps_calls == 0 and time.monotonic() < deadline:
            time.sleep(0.01)
        assert fm.steps_calls > 0, "healthy manager resumes adoption"
    finally:
        fleet.stop(timeout=10)
