"""Self-healing storage runtime: retries, fault injection, scrub-and-repair.

ISSUE 6's acceptance surface:

* transient I/O errors (EIO & friends) heal inside the retry layer —
  zero ``flush_errors``, ``io_retries`` surfaced, restores byte-identical;
* permanent failures (ENOSPC, errno-less) fail fast and stay
  journal-resumable;
* scrub-and-repair rewrites damaged PFS extents from L1/partner,
  re-replicates lost L1 blobs back from the PFS (anti-entropy), and
  quarantines steps with no intact copy — including delta descendants
  of a quarantined base;
* the restore ladder, ``steps()``, and GC all honor quarantine;
* double failures (home-node loss x partner loss x corrupt PFS chunk)
  restore per the docs/OPERATIONS.md fallback matrix, all strategies;
* the deterministic chaos engine (seeded FaultPlan schedules) drives
  all of the above end to end.
"""
import errno
import os
import threading
import time

import numpy as np
import pytest

from repro.core import (
    CheckpointConfig,
    CheckpointManager,
    FaultPlan,
    FaultSpec,
    MissingBlobError,
    RetryPolicy,
    StorageError,
    classify_error,
    repair_step,
    theta_like,
)
from repro.core.faults import flip_bit
from repro.core.storage import CancelToken, FlushCancelled, LocalStore

ALL_STRATEGIES = ["file_per_process", "posix", "mpiio", "stripe_aligned", "gio_sync"]


def state(step, kib=64):
    rng = np.random.default_rng(step)
    return {
        "w": rng.standard_normal((kib * 1024 // 8 // 2, 2)).astype(np.float64),
        "b": np.full((32,), step, np.float32),
    }


def trees_equal(a, b):
    return all(np.array_equal(np.asarray(a[k]), np.asarray(b[k])) for k in a)


def make_mgr(tmp_path, **kw):
    faults = kw.pop("_faults", None)
    kw.setdefault("cluster", theta_like(2, 2))
    kw.setdefault("async_flush", False)
    cfg = CheckpointConfig(root=str(tmp_path / "ckpt"), **kw)
    return CheckpointManager(cfg, faults=faults)


def forget_memory(mgr):
    """Drop the in-memory L0/last-full twins so restores hit disk."""
    mgr._l0 = None
    mgr._last_full = None


# ---------------------------------------------------------------- classify


def test_classify_error_errno_taxonomy():
    assert classify_error(OSError(errno.EIO, "eio")) == "transient"
    assert classify_error(OSError(errno.EAGAIN, "again")) == "transient"
    assert classify_error(OSError(errno.ENOSPC, "full")) == "permanent"
    assert classify_error(OSError(errno.ENOENT, "gone")) == "permanent"
    # errno-less IOError stays permanent: legacy fault_hook semantics
    assert classify_error(IOError("injected backend crash")) == "permanent"
    assert classify_error(ValueError("not io")) == "permanent"


def test_storage_error_is_oserror_and_filenotfound():
    cause = FileNotFoundError(errno.ENOENT, "gone", "/x/y")
    e = MissingBlobError("l1", 7, 3, "/x/y", cause)
    assert isinstance(e, OSError)
    assert isinstance(e, FileNotFoundError)
    assert (e.level, e.step, e.rank) == ("l1", 7, 3)
    assert e.errno == errno.ENOENT
    g = StorageError("pfs", 1, 0, "/p", OSError(errno.EIO, "eio"))
    assert isinstance(g, OSError) and not isinstance(g, FileNotFoundError)
    assert "pfs" in str(g) and "step 1" in str(g)


# ------------------------------------------------------------- RetryPolicy


def test_retry_policy_heals_transient():
    pol = RetryPolicy(attempts=5, base_delay=0.001, max_delay=0.002, seed=0)
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise OSError(errno.EIO, "flaky")
        return "ok"

    stats = {"retries": 0, "giveups": 0}
    assert pol.run(flaky, stats=stats) == "ok"
    assert calls["n"] == 3
    assert stats["retries"] == 2 and stats["giveups"] == 0
    assert pol.retries == 2 and pol.giveups == 0


def test_retry_policy_permanent_fails_first_try():
    pol = RetryPolicy(attempts=5, base_delay=0.001, seed=0)
    calls = {"n": 0}

    def bad():
        calls["n"] += 1
        raise OSError(errno.ENOSPC, "disk full")

    with pytest.raises(OSError) as ei:
        pol.run(bad)
    assert ei.value.errno == errno.ENOSPC
    assert calls["n"] == 1 and pol.retries == 0


def test_retry_policy_gives_up_after_budget():
    pol = RetryPolicy(attempts=3, base_delay=0.001, max_delay=0.002, seed=0)
    calls = {"n": 0}

    def always():
        calls["n"] += 1
        raise OSError(errno.EIO, "always")

    stats = {"retries": 0, "giveups": 0}
    with pytest.raises(OSError):
        pol.run(always, stats=stats)
    assert calls["n"] == 3
    assert stats["giveups"] == 1 and pol.giveups == 1


def test_retry_policy_deadline_bounds_total_time():
    pol = RetryPolicy(attempts=50, base_delay=0.05, max_delay=0.05, deadline=0.12, seed=0)
    t0 = time.monotonic()
    with pytest.raises(OSError):
        pol.run(lambda: (_ for _ in ()).throw(OSError(errno.EIO, "x")))
    assert time.monotonic() - t0 < 1.0


def test_retry_policy_cancel_token_aborts_sleep():
    pol = RetryPolicy(attempts=10, base_delay=5.0, max_delay=5.0, seed=0)
    tok = CancelToken()
    threading.Timer(0.05, tok.cancel).start()
    t0 = time.monotonic()
    with pytest.raises(FlushCancelled):
        pol.run(lambda: (_ for _ in ()).throw(OSError(errno.EIO, "x")), cancel=tok)
    assert time.monotonic() - t0 < 2.0


def test_retry_policy_custom_classify():
    pol = RetryPolicy(
        attempts=3, base_delay=0.001, seed=0, classify=lambda e: "transient"
    )
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 2:
            raise ValueError("not even an OSError family we retry")
        return 1

    # classify override only applies to OSErrors; ValueError still raises
    with pytest.raises(ValueError):
        pol.run(flaky)
    calls["n"] = 0

    def flaky_os():
        calls["n"] += 1
        if calls["n"] < 2:
            raise OSError(errno.ENOENT, "would be permanent by errno")
        return 1

    assert pol.run(flaky_os) == 1 and calls["n"] == 2


# ---------------------------------------------------------- LocalStore I/O


def test_write_blob_atomic_fsyncs_parent_dir(tmp_path, monkeypatch):
    """Satellite 1: the atomic path must fsync the parent directory
    after os.replace, else the rename is not durable."""
    store = LocalStore(tmp_path / "l1", n_nodes=1)
    synced = []
    real_fsync = os.fsync

    def spy(fd):
        try:
            import stat

            if stat.S_ISDIR(os.fstat(fd).st_mode):
                synced.append(fd)
        except OSError:
            pass
        return real_fsync(fd)

    monkeypatch.setattr(os, "fsync", spy)
    store.write_blob(0, 1, 0, b"payload", sync=True, atomic=True)
    assert synced, "atomic+sync write_blob never fsynced the parent directory"
    assert store.read_blob(0, 1, 0) == b"payload"
    # non-sync path must NOT pay the dir fsync
    synced.clear()
    store.write_blob(0, 1, 1, b"p2", sync=False, atomic=False)
    assert not synced


def test_read_blob_missing_raises_structured_error(tmp_path):
    store = LocalStore(tmp_path / "l1", n_nodes=2)
    with pytest.raises(MissingBlobError) as ei:
        store.read_blob(0, 5, 3)
    e = ei.value
    assert (e.level, e.step, e.rank) == ("l1", 5, 3)
    assert "rank_000003" in str(e.path)
    # and it still satisfies the legacy except clauses
    with pytest.raises(FileNotFoundError):
        store.read_blob(0, 5, 3)
    with pytest.raises(OSError):
        store.read_slice(1, 5, 3, 0, 4, partner=True)


def test_read_slice_partner_domain_attribution(tmp_path):
    store = LocalStore(tmp_path / "l1", n_nodes=2)
    with pytest.raises(StorageError) as ei:
        store.read_slice(1, 2, 0, 0, 8, partner=True)
    assert ei.value.level == "partner"


def test_local_store_write_retries_transient(tmp_path):
    faults = FaultPlan(
        [FaultSpec(kind="transient_eio", domain="l1", op="write", index=0, count=2)]
    )
    faults.arm("save")
    store = LocalStore(
        tmp_path / "l1", 1, faults=faults,
        retry=RetryPolicy(attempts=5, base_delay=0.001, max_delay=0.002, seed=0),
    )
    store.write_blob(0, 1, 0, b"healed")
    assert store.read_blob(0, 1, 0) == b"healed"
    assert len(faults.fired) == 2


# ----------------------------------------------------------------- faults


def test_flip_bit():
    assert flip_bit(b"\x00\x00", 0) == b"\x01\x00"
    assert flip_bit(b"\x00\x00", 9) == b"\x00\x02"
    assert flip_bit(flip_bit(b"abc", 13), 13) == b"abc"


def test_fault_plan_fires_at_exact_index():
    plan = FaultPlan(
        [FaultSpec(kind="transient_eio", domain="pfs", op="write", index=2, count=1)]
    )
    plan.arm("save")
    plan.on_op("pfs", "write")  # index 0
    plan.on_op("pfs", "write")  # index 1
    with pytest.raises(OSError) as ei:
        plan.on_op("pfs", "write")  # index 2: fires
    assert ei.value.errno == errno.EIO
    plan.on_op("pfs", "write")  # index 3 (the "retry"): healed
    assert [f[:2] for f in plan.fired] == [("transient_eio", "pfs")]


def test_fault_plan_count_fails_consecutive_attempts():
    plan = FaultPlan(
        [FaultSpec(kind="transient_eio", domain="pfs", op="write", index=1, count=2)]
    )
    plan.arm("save")
    plan.on_op("pfs", "write")
    for _ in range(2):
        with pytest.raises(OSError):
            plan.on_op("pfs", "write")
    plan.on_op("pfs", "write")  # third attempt: healed
    assert len(plan.fired) == 2


def test_fault_plan_phases_isolate_save_from_verify():
    plan = FaultPlan(
        [FaultSpec(kind="transient_eio", domain="pfs", op="read", index=0,
                   count=1, phase="verify")]
    )
    plan.arm("save")
    plan.on_op("pfs", "read")  # save phase: verify-spec must not fire
    plan.arm("verify")
    with pytest.raises(OSError):
        plan.on_op("pfs", "read")
    assert plan.fired_kinds() == {"transient_eio"}


def test_fault_plan_disarm_and_rearm():
    plan = FaultPlan(
        [FaultSpec(kind="enospc", domain="l1", op="write", index=0)]
    )
    plan.disarm()
    for _ in range(5):
        plan.on_op("l1", "write")
    assert not plan.fired
    plan.arm("save")  # re-arms and zeroes the stream counters
    with pytest.raises(OSError):
        plan.on_op("l1", "write")


def test_fault_plan_generate_deterministic_and_valid():
    a = FaultPlan.generate(seed=1234)
    b = FaultPlan.generate(seed=1234)
    assert [repr(s) for s in a.specs] == [repr(s) for s in b.specs]
    c = FaultPlan.generate(seed=1235)
    assert [repr(s) for s in a.specs] != [repr(s) for s in c.specs]
    for s in a.specs:
        assert s.kind in ("transient_eio", "enospc", "torn_write",
                          "bit_flip", "stall", "node_crash")
        assert s.domain in ("l1", "partner", "pfs")
    # coverage: over many seeds every kind appears
    kinds = set()
    for seed in range(40):
        kinds |= {s.kind for s in FaultPlan.generate(seed=seed).specs}
    assert kinds == {"transient_eio", "enospc", "torn_write",
                     "bit_flip", "stall", "node_crash"}


# ----------------------------------------------- flush-path fault healing


def test_transient_pfs_eio_heals_with_zero_flush_errors(tmp_path):
    faults = FaultPlan(
        [FaultSpec(kind="transient_eio", domain="pfs", op="write", index=1, count=2)]
    )
    mgr = make_mgr(tmp_path, strategy="posix", _faults=faults)
    mgr.faults.arm("save")
    try:
        res = mgr.save(1, state(1)).flush
        assert res is not None and not res.failed
        assert res.io_retries >= 2 and res.io_giveups == 0
        assert mgr.flush_errors == []
        faults.disarm()
        forget_memory(mgr)
        s, tree = mgr.restore(state(1))
        assert s == 1 and trees_equal(tree, state(1))
    finally:
        mgr.close()


def test_torn_pfs_write_heals_idempotently(tmp_path):
    faults = FaultPlan(
        [FaultSpec(kind="torn_write", domain="pfs", op="write", index=0, frac=0.4)]
    )
    mgr = make_mgr(tmp_path, strategy="stripe_aligned", _faults=faults)
    mgr.faults.arm("save")
    try:
        mgr.save(1, state(1))
        assert mgr.flush_errors == []
        assert "torn_write" in faults.fired_kinds()
        faults.disarm()
        forget_memory(mgr)
        s, tree = mgr.restore(state(1))
        assert s == 1 and trees_equal(tree, state(1))
        rep = mgr.validate(1)
        assert all(rep["pfs"].values())
    finally:
        mgr.close()


def test_enospc_is_permanent_and_journal_resumable(tmp_path):
    faults = FaultPlan(
        [FaultSpec(kind="enospc", domain="pfs", op="write", index=1)]
    )
    mgr = make_mgr(tmp_path, strategy="posix", _faults=faults)
    mgr.faults.arm("save")
    try:
        # sync flush: the permanent error propagates out of save()
        # after exactly one attempt (no retry on ENOSPC)
        with pytest.raises(OSError) as ei:
            mgr.save(1, state(1))
        assert ei.value.errno == errno.ENOSPC
        assert len(faults.fired) == 1
        assert 1 not in mgr.steps("pfs")
        assert 1 in mgr.steps("local"), "local phase committed before the flush"
        # the spec is exhausted (count=1): resume finishes the flush
        resumed = mgr.resume_flushes()
        assert 1 in resumed
        assert 1 in mgr.steps("pfs")
        faults.disarm()
        forget_memory(mgr)
        mgr.local.drop_node(0)
        mgr.local.drop_node(1)
        s, tree = mgr.restore(state(1))
        assert s == 1 and trees_equal(tree, state(1))
    finally:
        mgr.close()


def test_node_crash_mid_flush_restores_via_partner(tmp_path):
    faults = FaultPlan(
        [FaultSpec(kind="node_crash", domain="pfs", op="write", index=0, node=0)]
    )
    mgr = make_mgr(tmp_path, strategy="file_per_process",
                   partner_replication=True, _faults=faults)
    mgr.faults.arm("save")
    try:
        mgr.save(1, state(1))
        assert mgr.flush_errors == []
        assert "node_crash" in faults.fired_kinds()
        faults.disarm()
        forget_memory(mgr)
        s, tree = mgr.restore(state(1))
        assert s == 1 and trees_equal(tree, state(1))
    finally:
        mgr.close()


def test_restore_read_retries_transient_pfs_reads(tmp_path):
    faults = FaultPlan(
        [FaultSpec(kind="transient_eio", domain="pfs", op="read", index=0,
                   count=2, phase="verify")]
    )
    mgr = make_mgr(tmp_path, strategy="mpiio", _faults=faults)
    try:
        mgr.save(1, state(1))
        assert mgr.flush_errors == []
        forget_memory(mgr)
        mgr.local.drop_node(0)
        mgr.local.drop_node(1)
        faults.arm("verify")
        s, tree = mgr.restore(state(1))
        assert s == 1 and trees_equal(tree, state(1))
        assert len(faults.fired) == 2
    finally:
        mgr.close()


# ------------------------------------------------------- scrub and repair


def test_scrub_reports_partner_level(tmp_path):
    mgr = make_mgr(tmp_path, partner_replication=True)
    try:
        mgr.save(1, state(1))
        rep = mgr.validate(1)
        assert set(rep["partner"]) == {0, 1, 2, 3}
        assert all(rep["partner"].values())
    finally:
        mgr.close()


@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
def test_repair_pfs_extent_from_l1(tmp_path, strategy):
    faults = FaultPlan(
        [FaultSpec(kind="bit_flip", domain="pfs", op="write", index=2, bit=5)]
    )
    mgr = make_mgr(tmp_path, strategy=strategy, _faults=faults)
    mgr.faults.arm("save")
    try:
        mgr.save(1, state(1))
        assert mgr.flush_errors == []
        faults.disarm()
        rep = mgr.validate(1)
        bad = [r for r, ok in rep["pfs"].items() if not ok]
        assert bad, "bit flip must be caught by the CRC scrub"
        rep = mgr.validate(1, repair=True)
        assert sorted(rep["repair"].pfs_repaired) == sorted(bad)
        assert not rep["repair"].quarantined
        assert all(rep["post"]["pfs"].values())
        forget_memory(mgr)
        mgr.local.drop_node(0)
        mgr.local.drop_node(1)
        s, tree = mgr.restore(state(1))
        assert s == 1 and trees_equal(tree, state(1))
    finally:
        mgr.close()


def test_anti_entropy_rereplicates_lost_node_from_pfs(tmp_path):
    mgr = make_mgr(tmp_path, partner_replication=True)
    try:
        mgr.save(1, state(1))
        mgr.local.drop_node(0)  # home blobs of ranks 0,1; partner of 2,3
        rep = mgr.validate(1, repair=True)
        r = rep["repair"]
        assert sorted(r.l1_restored) == [0, 1]
        assert sorted(r.partner_restored) == [2, 3]
        assert all(rep["post"]["local"].values())
        assert all(rep["post"]["partner"].values())
        # and the restored L1 is genuinely usable: kill PFS, restore
        forget_memory(mgr)
        for f in (mgr.pfs_dir / "step_00000001").glob("*"):
            if f.name != "manifest.json":
                f.unlink()
        s, tree = mgr.restore(state(1))
        assert s == 1 and trees_equal(tree, state(1))
    finally:
        mgr.close()


def test_irreparable_step_is_quarantined_never_wrong_bytes(tmp_path):
    mgr = make_mgr(tmp_path)
    try:
        mgr.save(1, state(1))
        mgr.save(2, state(2))
        for n in range(2):
            mgr.local.drop_node(n, 2)
        for f in (mgr.pfs_dir / "step_00000002").glob("*"):
            if f.name != "manifest.json":
                b = bytearray(f.read_bytes())
                if b:
                    b[0] ^= 0xFF
                    f.write_bytes(bytes(b))
        rep = mgr.validate(2, repair=True)
        assert rep["repair"].quarantined
        assert rep["repair"].unrepairable
        # honored by steps() on both levels...
        assert 2 not in mgr.steps("pfs")
        assert 2 not in mgr.steps("local")
        forget_memory(mgr)
        # ...by explicit restore (clean error, never wrong bytes)...
        with pytest.raises(FileNotFoundError) as ei:
            mgr.restore(state(2), step=2)
        assert "quarantined" in str(ei.value)
        # ...and by the ladder's fallback to the newest healthy step
        s, tree = mgr.restore(state(1))
        assert s == 1 and trees_equal(tree, state(1))
        # idempotent: repairing again stays quarantined, no crash
        r2 = repair_step(mgr, 2)
        assert r2.quarantined
    finally:
        mgr.close()


def test_quarantined_base_poisons_delta_descendants(tmp_path):
    mgr = make_mgr(tmp_path, codec="zstd+delta", delta_every=4, chunk_size=4096)
    try:
        for s in (1, 2, 3):
            mgr.save(s, state(s))
        assert mgr._manifest_pfs(3).base_step is not None
        for n in range(2):
            mgr.local.drop_node(n, 1)
        for f in (mgr.pfs_dir / "step_00000001").glob("*"):
            if f.name != "manifest.json":
                b = bytearray(f.read_bytes())
                if b:
                    b[0] ^= 0xFF
                    f.write_bytes(bytes(b))
        rep = mgr.validate(1, repair=True)
        r = rep["repair"]
        assert r.quarantined
        assert sorted(r.suspect_descendants) == [2, 3]
        assert mgr.steps("pfs") == []
        forget_memory(mgr)
        with pytest.raises(FileNotFoundError):
            mgr.restore(state(3), step=3)
        # next save must re-anchor with a full snapshot, not a delta
        # against the quarantined base
        mgr.save(4, state(4))
        assert mgr._manifest_pfs(4).base_step is None
        forget_memory(mgr)
        s, tree = mgr.restore(state(4))
        assert s == 4 and trees_equal(tree, state(4))
    finally:
        mgr.close()


def test_gc_reaps_quarantined_steps(tmp_path):
    mgr = make_mgr(tmp_path, keep_n=2)
    try:
        for s in (1, 2, 3):
            mgr.save(s, state(s))
        for n in range(2):
            mgr.local.drop_node(n, 1)
        for f in (mgr.pfs_dir / "step_00000001").glob("*"):
            if f.name != "manifest.json":
                b = bytearray(f.read_bytes())
                if b:
                    b[0] ^= 0xFF
                    f.write_bytes(bytes(b))
        mgr.validate(1, repair=True)
        mgr.save(4, state(4))  # triggers GC; quarantined 1 is below keep
        assert not (mgr.pfs_dir / "step_00000001").exists()
        assert mgr.steps("pfs") == [3, 4]
    finally:
        mgr.close()


# --------------------------------------- satellite 3: double-failure matrix


@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
def test_double_failure_matrix(tmp_path, strategy):
    """Home-node loss x partner loss x one corrupt PFS chunk: per the
    OPERATIONS.md fallback matrix every rank still has exactly one good
    source, so repair must fully heal and restore byte-identically."""
    mgr = make_mgr(tmp_path, strategy=strategy, partner_replication=True)
    try:
        mgr.save(1, state(1))
        # node 0 loses home blobs (ranks 0,1) and partner copies (2,3)
        mgr.local.drop_node(0)
        # corrupt one PFS payload region
        payloads = sorted(
            f for f in (mgr.pfs_dir / "step_00000001").glob("*")
            if f.name != "manifest.json"
        )
        b = bytearray(payloads[0].read_bytes())
        b[len(b) // 2] ^= 0x80
        payloads[0].write_bytes(bytes(b))
        rep = mgr.validate(1, repair=True)
        r = rep["repair"]
        assert not r.quarantined, f"{strategy}: {r.as_dict()}"
        assert all(rep["post"]["pfs"].values())
        assert all(rep["post"]["local"].values())
        assert all(rep["post"]["partner"].values())
        forget_memory(mgr)
        s, tree = mgr.restore(state(1))
        assert s == 1 and trees_equal(tree, state(1))
    finally:
        mgr.close()


def test_double_failure_delta_chain(tmp_path):
    """Same matrix cell but on a delta step: the repaired base must
    decode its descendants byte-identically."""
    mgr = make_mgr(
        tmp_path, codec="zstd+delta", delta_every=4, chunk_size=4096,
        partner_replication=True,
    )
    try:
        mgr.save(1, state(1))
        mgr.save(2, state(2))
        mgr.local.drop_node(1)  # home of ranks 2,3; partner of 0,1
        payloads = sorted(
            f for f in (mgr.pfs_dir / "step_00000001").glob("*")
            if f.name != "manifest.json"
        )
        b = bytearray(payloads[0].read_bytes())
        b[0] ^= 0x01
        payloads[0].write_bytes(bytes(b))
        rep = mgr.validate(1, repair=True)
        assert not rep["repair"].quarantined
        assert all(rep["post"]["pfs"].values())
        forget_memory(mgr)
        s, tree = mgr.restore(state(2))
        assert s == 2 and trees_equal(tree, state(2))
    finally:
        mgr.close()


# ------------------------------------------------------------------ serve


def test_serve_restore_retries_transient(tmp_path, monkeypatch):
    pytest.importorskip("jax")
    from repro.serve.engine import Server

    class _TinyModel:
        pass

    class _Mgr:
        def __init__(self):
            self.calls = 0

        def restore_subtree(self, template, prefix, *, step=None, sharding_fn=None):
            self.calls += 1
            if self.calls < 3:
                raise IOError("PFS briefly unavailable")  # errno-less
            return 7, {"w": np.ones(3)}

    mgr = _Mgr()
    pol = RetryPolicy(attempts=5, base_delay=0.001, max_delay=0.002, seed=0)
    srv, step = Server.from_checkpoint(
        _TinyModel(), mgr, {"w": np.zeros(3)}, retry=pol
    )
    assert step == 7 and mgr.calls == 3
    # the caller's policy must not have been mutated
    assert pol.classify is None


# ------------------------------------------------------------------ chaos


def test_chaos_smoke_fixed_seeds(tmp_path):
    """A handful of seeded FaultPlan schedules through the full
    save -> flush -> scrub -> repair -> restore loop (the benchmark
    harness runs hundreds; this is the in-suite smoke)."""
    import sys
    from pathlib import Path

    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    try:
        from benchmarks.chaos import run_schedule
    finally:
        sys.path.pop(0)

    for seed in (3, 11, 17, 29):
        row = run_schedule(seed, root=str(tmp_path / f"s{seed}"))
        assert row["invariant_violations"] == [], (seed, row)
        assert row["restored_identical"], (seed, row)


# ----------------------------------------------------- serve x self-healing


def serve_state(step, kib=64):
    """A train state with a ``params`` subtree, as the fleet restores it."""
    return {
        "params": state(step, kib),
        "opt": {"mu": np.full((64,), step, np.float64)},
    }


def test_serve_cold_start_heals_corrupt_pfs_extents(tmp_path):
    """A fleet cold start against a step whose PFS extents are corrupt
    falls back through the ladder (chunk CRCs catch the damage, L1
    serves the bytes) and still streams byte-identical params."""
    pytest.importorskip("jax")
    from repro.serve.stream import stream_restore

    mgr = make_mgr(tmp_path, codec="zstd", chunk_size=4 * 1024)
    try:
        mgr.save(1, serve_state(1))
        forget_memory(mgr)
        for f in (mgr.pfs_dir / "step_00000001").glob("*"):
            if f.name != "manifest.json":
                b = bytearray(f.read_bytes())
                if b:
                    b[len(b) // 2] ^= 0xFF
                    f.write_bytes(bytes(b))
        template = {k: np.zeros_like(v) for k, v in state(1).items()}
        sr = stream_restore(mgr, template)
        assert sr.step == 1
        assert trees_equal(sr.params, state(1))
    finally:
        mgr.close()


def test_serve_cold_start_from_quarantined_step_raises_cleanly(tmp_path):
    """Explicitly cold-starting from a quarantined step must raise a
    clean error naming the quarantine — never serve wrong bytes — and
    the default (newest-step) cold start falls back to the healthy
    predecessor."""
    pytest.importorskip("jax")
    from repro.serve.stream import stream_restore

    mgr = make_mgr(tmp_path)
    try:
        mgr.save(1, serve_state(1))
        mgr.save(2, serve_state(2))
        for n in range(2):
            mgr.local.drop_node(n, 2)
        for f in (mgr.pfs_dir / "step_00000002").glob("*"):
            if f.name != "manifest.json":
                b = bytearray(f.read_bytes())
                if b:
                    b[0] ^= 0xFF
                    f.write_bytes(bytes(b))
        rep = mgr.validate(2, repair=True)
        assert rep["repair"].quarantined
        forget_memory(mgr)
        template = {k: np.zeros_like(v) for k, v in state(1).items()}
        with pytest.raises(FileNotFoundError) as ei:
            stream_restore(mgr, template, step=2)
        assert "quarantined" in str(ei.value)
        sr = stream_restore(mgr, template)  # ladder falls back to step 1
        assert sr.step == 1 and trees_equal(sr.params, state(1))
    finally:
        mgr.close()


def test_follower_skips_quarantined_step(tmp_path):
    """The hot-swap follower never adopts a step that scrub-and-repair
    quarantined: it keeps serving the old step until a genuinely
    healthy newer step lands, then adopts that."""
    pytest.importorskip("jax")
    from repro.serve import FleetConfig, ServeFleet

    class _NoModel:
        def decode_step(self, p, c, t):  # never traced in this test
            raise AssertionError("decode unused")

    mgr = make_mgr(tmp_path)
    fleet = None
    try:
        mgr.save(1, serve_state(1))
        mgr.save(2, serve_state(2))
        for n in range(2):
            mgr.local.drop_node(n, 2)
        for f in (mgr.pfs_dir / "step_00000002").glob("*"):
            if f.name != "manifest.json":
                b = bytearray(f.read_bytes())
                if b:
                    b[0] ^= 0xFF
                    f.write_bytes(bytes(b))
        assert mgr.validate(2, repair=True)["repair"].quarantined
        forget_memory(mgr)
        template = {k: np.zeros_like(v) for k, v in state(1).items()}
        fleet = ServeFleet(
            _NoModel(), mgr, template,
            cfg=FleetConfig(n_servers=1, poll_interval=0.02),
        )
        fleet.cold_start(step=1)
        fleet.start_follower()
        time.sleep(0.3)
        assert fleet.current_step == 1        # quarantined step 2 skipped
        mgr.save(3, serve_state(3))           # healthy newer step
        deadline = time.monotonic() + 30
        while fleet.current_step != 3 and time.monotonic() < deadline:
            time.sleep(0.02)
        assert fleet.current_step == 3
        assert trees_equal(fleet.servers[0].params, state(3))
    finally:
        if fleet is not None:
            fleet.stop()
        mgr.close()


def test_serve_from_checkpoint_non_io_error_raises_immediately(tmp_path):
    """Regression: ``from_checkpoint(retry=...)`` used to classify EVERY
    exception transient, so a programming error (bad template, typo'd
    prefix → TypeError/KeyError) burned the whole retry deadline.  Now
    only I/O errors (OSError/StorageError) retry; anything else raises
    on the first attempt."""
    pytest.importorskip("jax")
    from repro.serve.engine import Server

    class _TinyModel:
        pass

    class _Mgr:
        def __init__(self, exc):
            self.exc = exc
            self.calls = 0

        def restore_subtree(self, template, prefix, *, step=None, sharding_fn=None):
            self.calls += 1
            raise self.exc

    pol = RetryPolicy(attempts=5, base_delay=0.001, max_delay=0.002, seed=0)
    for exc in (TypeError("template is not a pytree"), KeyError("['params']['w']")):
        mgr = _Mgr(exc)
        with pytest.raises(type(exc)):
            Server.from_checkpoint(_TinyModel(), mgr, {"w": np.zeros(3)}, retry=pol)
        assert mgr.calls == 1, "non-I/O errors must not retry"
    # while genuine I/O failures (StorageError is an OSError) still do
    mgr = _Mgr(StorageError("pfs", 1, 0, "/gone", OSError(errno.EIO, "eio")))
    with pytest.raises(StorageError):
        Server.from_checkpoint(_TinyModel(), mgr, {"w": np.zeros(3)}, retry=pol)
    assert mgr.calls == 5, "I/O errors retry to the attempt budget"


def test_retry_policy_non_oserror_respects_classify():
    """Non-OSErrors are never retried — a classify override only
    widens retries *within* the OSError family.  That is the contract
    ``Server.from_checkpoint``'s transient-I/O classifier relies on:
    programming errors propagate on the first call, while the
    ``FileNotFoundError`` the restore ladder raises during a PFS
    brown-out (an OSError subclass) is re-pulled."""
    calls = {"n": 0}

    def flaky_default():
        calls["n"] += 1
        raise ValueError("not I/O")

    pol = RetryPolicy(attempts=4, base_delay=0.001, max_delay=0.002, seed=0)
    with pytest.raises(ValueError):
        pol.run(flaky_default)
    assert calls["n"] == 1                    # not caught: no retry, ever

    calls["n"] = 0

    def flaky_fnf():
        calls["n"] += 1
        if calls["n"] < 3:
            raise FileNotFoundError("no restorable checkpoint yet")
        return "ok"

    wide = RetryPolicy(
        attempts=5, base_delay=0.001, max_delay=0.002, seed=0,
        classify=lambda e: "transient",
    )
    assert wide.run(flaky_fnf) == "ok"
    assert calls["n"] == 3
