"""End-to-end driver: train a ~0.5B-family model (reduced) with async
aggregated checkpointing, inject a mid-flush crash AND a node loss, then
restart elastically on a smaller cluster geometry — training resumes
bit-exactly.

    PYTHONPATH=src python examples/train_with_failures.py
"""
import itertools
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import CheckpointConfig, CheckpointManager, theta_like
from repro.data import DataConfig, SyntheticTokens
from repro.launch.mesh import make_host_mesh
from repro.models import get_model
from repro.train import OptConfig, TrainConfig, init_train_state, make_train_step

cfg = get_smoke_config("qwen1.5-0.5b")
model = get_model(cfg)
mesh = make_host_mesh()
data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)
tcfg = TrainConfig(opt=OptConfig(lr=1e-3, total_steps=40))
data = SyntheticTokens(data_cfg)
bs = jax.tree_util.tree_map(
    lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), data.peek(0)
)
step_fn, _, _ = make_train_step(model, tcfg, mesh, bs)
state = init_train_state(model, jax.random.PRNGKey(0), tcfg)

with tempfile.TemporaryDirectory() as root:
    # fault: the active backend dies mid-flush on the FIRST checkpoint
    crash_after = itertools.count()
    def moody_backend(_w):
        if next(crash_after) < 2:  # first flush (step 4) dies mid-write
            raise IOError("injected: backend crash mid-flush")

    mgr = CheckpointManager(
        CheckpointConfig(root=root, cluster=theta_like(4, 2),
                         strategy="stripe_aligned",
                         partner_replication=True),
        fault_hook=moody_backend,
    )
    for i in range(1, 9):
        state, metrics = step_fn(state, data.next())
        print(f"step {i} loss {float(metrics['loss']):.4f}")
        if i % 4 == 0:
            mgr.save(i, {"train": state, "data": data.state_tree()})
    mgr.wait()
    print("flush errors (expected: step 4 injected):", mgr.flush_errors)
    # snapshot the restore template BEFORE step_fn donates these buffers
    target = {
        "train": jax.tree_util.tree_map(np.asarray, state),
        "data": {"batch_idx": np.asarray(0, np.int32)},
    }
    truth = state
    d_truth = SyntheticTokens(data_cfg, state=data.state_tree())
    for _ in range(2):
        truth, _ = step_fn(truth, d_truth.next())
    mgr.close()

    # node 2's local storage dies too; restart on a 2-node cluster
    mgr2 = CheckpointManager(
        CheckpointConfig(root=root, cluster=theta_like(2, 1),
                         strategy="file_per_process")
    )
    mgr2.local.drop_node(2)
    step, restored = mgr2.restore(target)
    print(f"restored step {step} on the shrunken cluster")
    r_state = jax.tree_util.tree_map(jnp.asarray, restored["train"])
    d2 = SyntheticTokens(data_cfg)
    d2.load_state(restored["data"])
    for _ in range(2):
        r_state, m = step_fn(r_state, d2.next())
    same = all(
        bool(jnp.array_equal(a, b))
        for a, b in zip(jax.tree_util.tree_leaves(truth),
                        jax.tree_util.tree_leaves(r_state))
    )
    print("bit-exact resume after crash + node loss + reshard:", same)
    assert same
    mgr2.close()
