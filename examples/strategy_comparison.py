"""Reproduce the paper's evaluation (Figs 1-2) + §3 proposal in one run.

    PYTHONPATH=src python examples/strategy_comparison.py [--nodes 64]

Prints the two figures as text tables at one scale point and the
aggregate verdicts the paper draws from them.
"""
import argparse

from repro.core import make_plan, simulate_flush, theta_like
from repro.utils import fmt_bw

GiB = 1 << 30


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=64)
    ap.add_argument("--ppn", type=int, default=8)
    args = ap.parse_args()

    cluster = theta_like(args.nodes, args.ppn)
    sizes = [GiB] * cluster.world_size
    print(f"{args.nodes} nodes x {args.ppn} ppn, 1 GiB/rank "
          f"({cluster.world_size} GiB total), Lustre-like PFS\n")
    print(f"{'strategy':20s} {'local phase':>14s} {'async flush':>14s} "
          f"{'files':>7s} {'md ops':>7s} {'gather':>10s} {'lock eff':>9s}")
    reports = {}
    for strat, kw in [
        ("file_per_process", {}),
        ("posix", {}),
        ("mpiio", {"chunk_stripes": 64}),
        ("stripe_aligned", {"pipeline_chunk": 256 << 20}),
        ("gio_sync", {"chunk_stripes": 64}),
    ]:
        plan = make_plan(strat, cluster, sizes, **kw)
        rep = simulate_flush(plan, io_threads=4)
        reports[strat] = rep
        print(f"{strat:20s} {fmt_bw(rep.local_bw):>14s} "
              f"{fmt_bw(rep.flush_bw):>14s} {rep.n_files:7d} "
              f"{rep.metadata_ops:7d} {rep.network_bytes/GiB:9.1f}G "
              f"{rep.pfs_lock_eff:9.3f}")

    fpp = reports["file_per_process"]
    s3 = reports["stripe_aligned"]
    print("\npaper claims, checked:")
    print(f"  Fig1: VELOC local phase >> GIO direct: "
          f"{fpp.local_bw / reports['gio_sync'].local_bw:.1f}x")
    print(f"  Fig2: posix << fpp (false sharing): "
          f"{fpp.flush_bw / reports['posix'].flush_bw:.2f}x down")
    print(f"  Fig2: mpiio << fpp (collective rounds): "
          f"{fpp.flush_bw / reports['mpiio'].flush_bw:.2f}x down")
    print(f"  §3: stripe-aligned within {100 * (1 - s3.flush_bw / fpp.flush_bw):.1f}% "
          f"of fpp flush at {fpp.n_files}x fewer files "
          f"({s3.metadata_ops} vs {fpp.metadata_ops} metadata ops)")


if __name__ == "__main__":
    main()
