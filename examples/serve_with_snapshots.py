"""Batched serving with live-state snapshots through the checkpoint engine.

A recurrent-family model (RecurrentGemma smoke config) serves a batch;
mid-generation, the full serving state (params + per-request recurrent
state + ring KV caches) is checkpointed asynchronously; a second server
restores it and continues — emitting exactly the tokens the first one
would have.

    PYTHONPATH=src python examples/serve_with_snapshots.py
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import CheckpointConfig, CheckpointManager, theta_like
from repro.models import get_model

cfg = get_smoke_config("recurrentgemma-2b")
model = get_model(cfg)
params = model.init(jax.random.PRNGKey(7))
prompts = jnp.asarray(np.tile(np.arange(8, dtype=np.int32)[None], (4, 1)))

# serve 3 tokens, snapshot, then 3 more
cache, logits = model.prefill(params, {"tokens": prompts}, s_max=32)
decode = jax.jit(lambda p, c, t: model.decode_step(p, c, t))
tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
first, snap_cache, snap_tok = [], None, None
for i in range(6):
    first.append(np.asarray(tok)[:, 0])
    logits, cache = decode(params, cache, tok)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    if i == 2:
        snap_cache, snap_tok = cache, tok
print("continuous generation :", np.stack(first, 1)[0])

with tempfile.TemporaryDirectory() as root:
    mgr = CheckpointManager(
        CheckpointConfig(root=root, cluster=theta_like(2, 2),
                         strategy="stripe_aligned")
    )
    mgr.save(1, {"params": params, "cache": snap_cache, "tok": snap_tok})
    mgr.wait()
    assert not mgr.flush_errors
    target = jax.tree_util.tree_map(
        np.asarray, {"params": params, "cache": snap_cache, "tok": snap_tok}
    )
    mgr._l0 = None
    _, restored = mgr.restore(target)
    mgr.close()

r_cache = jax.tree_util.tree_map(jnp.asarray, restored["cache"])
r_tok = jnp.asarray(restored["tok"])
resumed = list(np.stack(first[:3], 1).T)
for _ in range(3):
    resumed.append(np.asarray(r_tok)[:, 0])
    logits, r_cache = decode(params, r_cache, r_tok)
    r_tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
resumed = np.stack(resumed, 1)
print("resumed-from-snapshot  :", resumed[0])
np.testing.assert_array_equal(np.stack(first, 1), resumed)
print("snapshot resume emits identical tokens")
