"""Quickstart: aggregated asynchronous checkpointing in 60 lines.

    PYTHONPATH=src python examples/quickstart.py

1. Builds a FlushPlan with the paper's §3 stripe-aligned strategy.
2. Prices the same plan at Theta scale on the simulator (Fig. 2 setup).
3. Saves/restores a real pytree through the multi-level engine —
   including an elastic restore on a *different* cluster geometry and a
   partial (params-only) restore through the columnar read planner.
"""
import tempfile

import jax.numpy as jnp
import numpy as np

from repro.core import (
    CheckpointConfig,
    CheckpointManager,
    count_false_sharing,
    make_plan,
    simulate_flush,
    theta_like,
)

GiB = 1 << 30

# --- 1. plan: who writes what where -------------------------------------
cluster = theta_like(n_nodes=8, procs_per_node=4)
sizes = [1 * GiB] * cluster.world_size
plan = make_plan("stripe_aligned", cluster, sizes)
print(f"strategy={plan.strategy}  files={plan.n_files}  "
      f"writes={len(plan.writes)}  gather_bytes={plan.network_bytes()}")
print(f"leaders={plan.leaders.leaders}")
print(f"false sharing: {count_false_sharing(plan)['stripes_shared']} shared stripes")

# --- 2. price it on the modeled Theta (paper Fig. 2) ---------------------
for strat in ("file_per_process", "posix", "mpiio", "stripe_aligned"):
    rep = simulate_flush(
        make_plan(strat, cluster, sizes, chunk_stripes=64), io_threads=4
    )
    print(f"{strat:18s} local {rep.local_bw/1e9:7.1f} GB/s   "
          f"flush {rep.flush_bw/1e9:6.1f} GB/s   files {rep.n_files}")

# --- 3. the real engine: save + elastic/partial restore ------------------
from repro.core import default_codec_impl

# chunk-framed compression works everywhere: zstandard when installed,
# the stdlib-zlib fallback otherwise (recorded in the manifest)
codec = "zstd"

state = {"params": {"w": jnp.arange(1 << 18, dtype=jnp.float32)},
         "step": jnp.array(3)}
with tempfile.TemporaryDirectory() as root:
    mgr = CheckpointManager(
        CheckpointConfig(root=root, cluster=cluster, strategy="stripe_aligned",
                         codec=codec)
    )
    st = mgr.save(1, state)
    mgr.wait()
    mgr.close()
    print(f"saved {st.raw_bytes/1e6:.1f} MB -> {st.stored_bytes/1e6:.1f} MB "
          f"(local {st.local_time*1e3:.1f} ms, codec={codec}, "
          f"backend={default_codec_impl()})")

    # elastic restart: the machine shrank to 3x1, L1 is gone — the PFS
    # checkpoint restores through one aggregated ReadPlan.
    mgr2 = CheckpointManager(
        CheckpointConfig(root=root, cluster=theta_like(3, 1))
    )
    for n in range(cluster.n_nodes):
        mgr2.local.drop_node(n)
    step, restored = mgr2.restore(
        {"params": {"w": np.zeros(1 << 18, np.float32)}, "step": np.array(0)}
    )
    assert step == 1 and int(restored["step"]) == 3
    np.testing.assert_array_equal(restored["params"]["w"], np.asarray(state["params"]["w"]))
    rr = mgr2.last_read_result
    print(f"elastic restore OK on 3x1 "
          f"({rr.n_reads} ranged reads, {rr.bytes_read/1e6:.1f} MB)")

    # partial restore: just the params subtree (the serving workload)
    _, params = mgr2.restore_subtree(
        {"w": np.zeros(1 << 18, np.float32)}, "['params']"
    )
    np.testing.assert_array_equal(params["w"], np.asarray(state["params"]["w"]))
    mgr2.close()
    print("partial (params-only) restore OK")
