"""Quickstart: aggregated asynchronous checkpointing in 60 lines.

    PYTHONPATH=src python examples/quickstart.py

1. Builds a FlushPlan with the paper's §3 stripe-aligned strategy.
2. Prices the same plan at Theta scale on the simulator (Fig. 2 setup).
3. Saves/restores a real pytree through the multi-level engine.
"""
import tempfile

import jax.numpy as jnp
import numpy as np

from repro.core import (
    CheckpointConfig,
    CheckpointManager,
    count_false_sharing,
    make_plan,
    simulate_flush,
    theta_like,
)

GiB = 1 << 30

# --- 1. plan: who writes what where -------------------------------------
cluster = theta_like(n_nodes=8, procs_per_node=4)
sizes = [1 * GiB] * cluster.world_size
plan = make_plan("stripe_aligned", cluster, sizes)
print(f"strategy={plan.strategy}  files={plan.n_files}  "
      f"writes={len(plan.writes)}  gather_bytes={plan.network_bytes()}")
print(f"leaders={plan.leaders.leaders}")
print(f"false sharing: {count_false_sharing(plan)['stripes_shared']} shared stripes")

# --- 2. price it on the modeled Theta (paper Fig. 2) ---------------------
for strat in ("file_per_process", "posix", "mpiio", "stripe_aligned"):
    rep = simulate_flush(
        make_plan(strat, cluster, sizes, chunk_stripes=64), io_threads=4
    )
    print(f"{strat:18s} local {rep.local_bw/1e9:7.1f} GB/s   "
          f"flush {rep.flush_bw/1e9:6.1f} GB/s   files {rep.n_files}")

# --- 3. the real engine: save + restore a pytree -------------------------
state = {"w": jnp.arange(1 << 18, dtype=jnp.float32), "step": jnp.array(3)}
with tempfile.TemporaryDirectory() as root:
    mgr = CheckpointManager(
        CheckpointConfig(root=root, cluster=cluster, strategy="stripe_aligned",
                         codec="zstd")
    )
    st = mgr.save(1, state)
    mgr.wait()
    print(f"saved {st.raw_bytes/1e6:.1f} MB -> {st.stored_bytes/1e6:.1f} MB "
          f"(local {st.local_time*1e3:.1f} ms)")
    step, restored = mgr.restore(
        {"w": np.zeros(1 << 18, np.float32), "step": np.array(0)}
    )
    assert step == 1 and int(restored["step"]) == 3
    np.testing.assert_array_equal(restored["w"], np.asarray(state["w"]))
    mgr.close()
    print("restore OK")
