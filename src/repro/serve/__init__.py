from repro.serve.engine import ServeConfig, Server
from repro.serve.fleet import FleetColdStart, FleetConfig, ServeFleet
from repro.serve.stream import (
    ChunkCache,
    LayerGroup,
    StreamedRestore,
    plan_layer_groups,
    stream_restore,
)

__all__ = [
    "ChunkCache",
    "FleetColdStart",
    "FleetConfig",
    "LayerGroup",
    "ServeConfig",
    "ServeFleet",
    "Server",
    "StreamedRestore",
    "plan_layer_groups",
    "stream_restore",
]
