"""ServeFleet: N replicas cold-started from ONE aggregated checkpoint,
kept current by a snapshot hot-swap follower.

This is the fleet-restore workload the paper's aggregation strategies
exist for: every replica pulls its weights out of the same aggregated
step through byte-balanced read plans computed from the *serving*
geometry (``assign_readers`` over the step's :class:`FileLayout` —
independent of how many ranks wrote it), streams layers in priority
order so time-to-first-token beats a full restore, and shares one
node-local :class:`~repro.serve.stream.ChunkCache` so co-located
replicas decode each chunk once per node.

The follower watches the PFS for the newest ``flush_done`` step — it
never adopts a ``flush_partial``, ``superseded``, or ``quarantined``
manifest, which is exactly the trust rule
:meth:`~repro.core.engine.CheckpointManager.steps` encodes.  While
the manager reports itself ``degraded`` (PFS circuit open, new steps
parked on L1) the follower defers adoption entirely: nothing newer
than what it already serves can have reached ``flush_done``, and the
post-heal drain will wake it normally.  It rolls
every server atomically via :meth:`Server.swap_params`.  In-flight
generates finish on the version they captured; nothing is dropped or
torn.  When the fleet shares a process with training it also
subscribes to the manager's flush-done hook, so swaps trail flushes by
a wakeup instead of a poll interval.
"""
from __future__ import annotations

import logging
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.plan import assign_readers
from repro.serve.engine import ServeConfig, Server
from repro.serve.stream import ChunkCache, StreamedRestore, stream_restore

log = logging.getLogger(__name__)


@dataclass(frozen=True)
class FleetConfig:
    n_servers: int = 2
    serve: ServeConfig = ServeConfig()
    priority_blocks: int = 1          # TTFT prefix: embed + this many blocks
    cache_bytes: int = 256 << 20      # node-local decoded-chunk cache
    poll_interval: float = 0.05       # follower PFS poll cadence (seconds)


@dataclass
class FleetColdStart:
    """Telemetry of one concurrent fleet cold start."""

    step: int
    total_s: float                    # slowest replica fully resident
    ttft_s: List[float]               # per-replica priority-prefix time
    total_bytes: int                  # per-replica params bytes
    cache: Optional[Dict[str, int]]   # shared ChunkCache stats snapshot


class ServeFleet:
    def __init__(
        self,
        model: Any,
        manager: Any,
        params_template: Any,
        *,
        prefix: str = "['params']",
        cfg: FleetConfig = FleetConfig(),
        sharding_fn: Optional[Callable[[str, Any], Any]] = None,
    ):
        self.model = model
        self.manager = manager
        self.template = params_template
        self.prefix = prefix
        self.cfg = cfg
        self.sharding_fn = sharding_fn
        self.servers: List[Server] = []
        self.current_step: Optional[int] = None
        self.swap_history: List[Tuple[int, float]] = []
        # one decoded-chunk cache per node: adopt the manager's if some
        # other co-located fleet already installed one, else install ours
        existing = getattr(manager, "chunk_cache", None)
        self.cache: ChunkCache = (
            existing if existing is not None else ChunkCache(cfg.cache_bytes)
        )
        manager.chunk_cache = self.cache
        self._swap_lock = threading.Lock()
        self._follower: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._wake = threading.Event()
        self._subscribed: Optional[Callable[[int], None]] = None
        # Control-plane attachment (multi-tenant runs): set by
        # via_control_plane(); the follower then subscribes through the
        # plane's per-tenant event surface instead of the raw manager.
        self._plane: Optional[Any] = None
        self._job: Optional[str] = None

    @classmethod
    def via_control_plane(
        cls,
        model: Any,
        plane: Any,
        job: str,
        params_template: Any,
        *,
        prefix: str = "['params']",
        cfg: FleetConfig = FleetConfig(),
        sharding_fn: Optional[Callable[[str, Any], Any]] = None,
    ) -> "ServeFleet":
        """Build a fleet that serves one *tenant* of a
        :class:`~repro.control.ControlPlane`.

        The manager handle is resolved through the plane's registry
        (``plane.manager(job)``) and the hot-swap follower subscribes
        via ``plane.subscribe(job, ...)`` — the fleet never owns a
        private manager, so the tenant's quotas, shared breaker state
        and admission budget all apply to the serving path's reads and
        the training path's flushes alike."""
        fleet = cls(
            model,
            plane.manager(job),
            params_template,
            prefix=prefix,
            cfg=cfg,
            sharding_fn=sharding_fn,
        )
        fleet._plane = plane
        fleet._job = job
        return fleet

    # ------------------------------------------------------------ cold start

    def cold_start(self, step: Optional[int] = None) -> FleetColdStart:
        """Boot ``cfg.n_servers`` replicas concurrently from one step.

        The step is pinned once (newest restorable, or ``step``);
        every replica streams THAT step — a flush landing mid-boot
        cannot split the fleet across versions.  Each replica's stream
        issues its own aggregated read plans (byte-balanced over the
        serving geometry) and shares the node-local chunk cache, so
        with a chunk-framed codec replicas after the first decode
        almost nothing."""
        n = self.cfg.n_servers
        pinned, _ = self.manager.leaf_catalog(step=step, prefix=self.prefix)
        results: List[Optional[StreamedRestore]] = [None] * n
        errors: List[BaseException] = []
        t0 = time.perf_counter()

        def boot(i: int) -> None:
            try:
                results[i] = stream_restore(
                    self.manager,
                    self.template,
                    self.prefix,
                    step=pinned,
                    priority_blocks=self.cfg.priority_blocks,
                    sharding_fn=self.sharding_fn,
                )
            except BaseException as e:  # surfaced to the caller below
                errors.append(e)

        threads = [
            threading.Thread(target=boot, args=(i,), name=f"fleet-boot-{i}")
            for i in range(n)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        if errors:
            raise errors[0]
        total = time.perf_counter() - t0
        self.servers = [
            Server(self.model, sr.params, self.cfg.serve) for sr in results
        ]
        self.current_step = pinned
        return FleetColdStart(
            step=pinned,
            total_s=total,
            ttft_s=[sr.ttft_s for sr in results],
            total_bytes=results[0].total_bytes if results else 0,
            cache=self.cache.stats(),
        )

    def reader_balance(self, step: Optional[int] = None) -> Dict[str, Any]:
        """How this fleet's reads spread over the serving geometry.

        Inverts the step's stored layout into the per-reader byte load
        ``assign_readers`` produces for the *serving* cluster — the
        balance every cold-start read plan actually uses, regardless of
        the (possibly larger, possibly gone) training geometry that
        wrote the step."""
        s = step if step is not None else self.current_step
        if s is None:
            s, _ = self.manager.leaf_catalog(prefix=self.prefix)
        man = self.manager._manifest_pfs(s)
        sizes = np.asarray([r.stored_size for r in man.ranks], np.int64)
        n_readers = self.manager.cluster.n_nodes
        readers = assign_readers(sizes, n_readers)
        per = np.zeros(n_readers, np.int64)
        np.add.at(per, readers, sizes)
        return {
            "step": s,
            "n_readers": n_readers,
            "readers": readers,
            "bytes_per_reader": per,
            "max_bytes": int(per.max()) if len(per) else 0,
            "min_bytes": int(per.min()) if len(per) else 0,
        }

    # -------------------------------------------------------------- hot swap

    def swap_to(self, step: Optional[int] = None) -> int:
        """Roll every server onto ``step`` (default: newest flush_done).

        The new params are streamed ONCE and then swapped into each
        server atomically (replicas share the loaded tree — same-node
        fleet semantics).  Returns the step now being served; a no-op
        (already serving the newest) returns the current step without
        bumping any server's version."""
        with self._swap_lock:
            pinned, _ = self.manager.leaf_catalog(step=step, prefix=self.prefix)
            if (
                step is None
                and self.current_step is not None
                and pinned <= self.current_step
            ):
                return self.current_step
            t0 = time.perf_counter()
            sr = stream_restore(
                self.manager,
                self.template,
                self.prefix,
                step=pinned,
                priority_blocks=self.cfg.priority_blocks,
                sharding_fn=self.sharding_fn,
            )
            for srv in self.servers:
                srv.swap_params(sr.params)
            self.current_step = pinned
            self.swap_history.append((pinned, time.perf_counter() - t0))
            return pinned

    def start_follower(self) -> None:
        """Watch for newer ``flush_done`` steps and hot-swap onto them.

        Polls ``manager.steps("pfs")`` — which lists ONLY flush_done
        manifests, so partial/superseded/quarantined steps are
        structurally invisible to the follower — every
        ``cfg.poll_interval`` seconds, and additionally wakes on the
        manager's flush-done notification when training shares the
        process.  Swap failures (e.g. the step got quarantined between
        listing and read) are logged and retried next round, never
        fatal to serving."""
        if self._follower is not None:
            return
        self._stop.clear()

        def on_flush_done(step: int) -> None:
            self._wake.set()

        self._subscribed = on_flush_done
        if self._plane is not None:
            self._plane.subscribe(self._job, on_flush_done)
        elif hasattr(self.manager, "subscribe"):
            self.manager.subscribe(on_flush_done)

        deferred = False  # degraded-mode notice logged once per outage

        def loop() -> None:
            nonlocal deferred
            while not self._stop.is_set():
                self._wake.wait(self.cfg.poll_interval)
                self._wake.clear()
                if self._stop.is_set():
                    return
                try:
                    health_fn = getattr(self.manager, "health", None)
                    if callable(health_fn):
                        mh = health_fn()
                        if getattr(mh, "mode", "normal") == "degraded":
                            # PFS circuit open: every step listed now
                            # predates the outage, and anything newer is
                            # parked on L1 — there is nothing new the
                            # follower can trust until the post-heal
                            # drain publishes flush_done manifests.
                            if not deferred:
                                deferred = True
                                log.warning(
                                    "fleet follower: manager degraded "
                                    "(PFS circuit open); deferring "
                                    "adoption until the drain completes"
                                )
                            continue
                        if deferred:
                            deferred = False
                            log.info(
                                "fleet follower: manager healthy again; "
                                "resuming adoption"
                            )
                    done = self.manager.steps("pfs")
                    if not done:
                        continue
                    newest = done[-1]
                    if (
                        self.current_step is not None
                        and newest <= self.current_step
                    ):
                        continue
                    if self.manager.step_status(newest) != "flush_done":
                        continue  # raced a supersession/quarantine
                    self.swap_to(newest)
                    log.info("fleet follower adopted step %d", newest)
                except Exception:
                    log.exception("fleet follower swap attempt failed")

        self._follower = threading.Thread(
            target=loop, name="fleet-follower", daemon=True
        )
        self._follower.start()

    def stop(self, *, timeout: float = 30.0) -> None:
        """Stop the follower (idempotent; servers keep serving).

        Raises ``RuntimeError`` if the follower thread is still alive
        after ``timeout`` seconds — a live thread holding a mid-swap
        stream must not be silently discarded, because it still shares
        the manager's read path and chunk cache.  The follower handle
        is kept so a later ``stop()`` can re-join it; the flush-done
        subscription is released either way so a wedged follower at
        least stops receiving wakeups."""
        self._stop.set()
        self._wake.set()
        follower = self._follower
        if follower is not None:
            follower.join(timeout=timeout)
        try:
            if follower is not None and follower.is_alive():
                log.error(
                    "fleet follower %r did not stop within %.1fs; "
                    "a swap is still in flight", follower.name, timeout,
                )
                raise RuntimeError(
                    f"fleet follower did not stop within {timeout:.1f}s"
                )
            self._follower = None
        finally:
            if self._subscribed is not None:
                if self._plane is not None:
                    self._plane.unsubscribe(self._job, self._subscribed)
                elif hasattr(self.manager, "unsubscribe"):
                    self.manager.unsubscribe(self._subscribed)
                self._subscribed = None

    def close(self, *, timeout: float = 30.0) -> None:
        """Stop the follower and release the fleet (idempotent).  The
        shutdown deadline is propagated to :meth:`stop`; the servers
        are released only once the follower is actually down.  The
        shared chunk cache stays on the manager — another fleet on this
        node keeps its contents warm."""
        self.stop(timeout=timeout)
        self.servers = []
