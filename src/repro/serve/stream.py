"""Layer-granular streamed restore + node-local decoded-chunk cache.

The serving-side answer to the paper's aggregation layer: a fleet
replica does not need the whole checkpoint resident before it can do
useful work — it needs the embedding and the first transformer blocks
(the prefill-critical prefix) first, then the rest in layer order.
:func:`stream_restore` plans that order from the manifest's leaf
catalog alone (no data reads), pulls each layer group through
:meth:`~repro.core.engine.CheckpointManager.restore_leaves` (each group
is one aggregated, byte-balanced read plan), and reports
time-to-first-token — the instant the priority prefix is resident —
separately from total load time.

:class:`ChunkCache` is the node-local dedup layer for chunk-framed
codecs: co-located replicas restoring the same step (or delta steps
sharing a base) decode every chunk once per node, not once per replica.
The manager consults it through its duck-typed ``chunk_cache``
attribute, keyed ``(step, chunk row)``.
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

# Leaf-name heuristics over jax.tree_util.keystr names.  Numbered
# repeated blocks — "['block_000']['w']", "['layers_3']", "['h5']" —
# order by their layer index; embedding-ish names load first and
# head/output-ish names last.  Params that fit neither (e.g. a single
# stacked-layer leaf spanning every layer) form one middle group.
import re

_BLOCK_RE = re.compile(r"\['(?:blocks?|layers?|h)[._]?(\d+)'?\]", re.IGNORECASE)
_EMBED_RE = re.compile(r"embed|wte|wpe|tok_|pos_|patch", re.IGNORECASE)
_TAIL_RE = re.compile(r"head|logits|unembed|\['out'\]|final|ln_f", re.IGNORECASE)


@dataclass(frozen=True)
class LayerGroup:
    """One streaming unit: the leaves loaded by a single read plan."""

    name: str
    leaves: Tuple[str, ...]
    nbytes: int
    priority: bool = False


def plan_layer_groups(
    entries: Iterable[Any], *, priority_blocks: int = 1
) -> List[LayerGroup]:
    """Group leaf entries into ordered layer groups.

    ``entries`` is an iterable of ``(name, size)`` pairs or objects with
    ``.name``/``.size`` (e.g. manifest :class:`LeafEntry` rows).  Order:
    embedding group, numbered block groups ascending, un-numbered middle
    group, tail (head/output) group.  The first ``1 + priority_blocks``
    groups (embedding + leading blocks, when present) are marked
    ``priority`` — the TTFT prefix a streamed restore loads first.
    Every leaf lands in exactly one group.
    """
    pairs: List[Tuple[str, int]] = []
    for e in entries:
        if isinstance(e, tuple):
            pairs.append((e[0], int(e[1])))
        else:
            pairs.append((e.name, int(e.size)))

    embed: List[Tuple[str, int]] = []
    tail: List[Tuple[str, int]] = []
    mid: List[Tuple[str, int]] = []
    blocks: Dict[int, List[Tuple[str, int]]] = {}
    for name, size in pairs:
        m = _BLOCK_RE.search(name)
        if m:
            blocks.setdefault(int(m.group(1)), []).append((name, size))
        elif _EMBED_RE.search(name):
            embed.append((name, size))
        elif _TAIL_RE.search(name):
            tail.append((name, size))
        else:
            mid.append((name, size))

    def group(name: str, leaves: List[Tuple[str, int]], prio: bool) -> LayerGroup:
        return LayerGroup(
            name=name,
            leaves=tuple(n for n, _ in leaves),
            nbytes=sum(s for _, s in leaves),
            priority=prio,
        )

    out: List[LayerGroup] = []
    if embed:
        out.append(group("embed", embed, True))
    for j, idx in enumerate(sorted(blocks)):
        out.append(
            group(f"block_{idx:05d}", blocks[idx], j < priority_blocks)
        )
    if mid:
        out.append(group("mid", mid, False))
    if tail:
        out.append(group("tail", tail, False))
    if out and not any(g.priority for g in out):
        # degenerate shapes (no embedding, no numbered blocks): the
        # first group is the best available prefix
        out[0] = LayerGroup(out[0].name, out[0].leaves, out[0].nbytes, True)
    return out


@dataclass
class StreamedRestore:
    """Result of :func:`stream_restore`."""

    step: int
    params: Any
    groups: List[LayerGroup]
    group_done_s: Dict[str, float]
    ttft_s: float          # priority prefix resident (time to first token)
    total_s: float         # every group resident
    priority_bytes: int
    total_bytes: int


def stream_restore(
    manager: Any,
    template: Any,
    prefix: str = "['params']",
    *,
    step: Optional[int] = None,
    priority_blocks: int = 1,
    sharding_fn: Optional[Callable[[str, Any], Any]] = None,
    on_group: Optional[Callable[[LayerGroup, float], None]] = None,
) -> StreamedRestore:
    """Restore ``template``'s leaves layer group by layer group.

    The step is pinned up front from
    :meth:`~repro.core.engine.CheckpointManager.leaf_catalog`, so a
    newer step flushed mid-stream can never mix into the result.  Each
    group is one ``restore_leaves`` call — one aggregated read plan,
    byte-balanced across the *serving* geometry's readers.  ``ttft_s``
    is the wall-clock moment the priority prefix (embedding + first
    ``priority_blocks`` block groups) became resident; prefill can
    start there while the tail streams in.  ``on_group(group,
    done_s)`` fires as each group lands (pipelined device upload).
    """
    from repro.utils.treelib import flatten_with_names

    import jax

    named, treedef = flatten_with_names(template)
    names = [prefix + n for n, _ in named]
    pinned, catalog = manager.leaf_catalog(step=step, prefix=prefix)
    by_name = {e.name: e for e in catalog}
    missing = [n for n in names if n not in by_name]
    if missing:
        raise KeyError(
            f"step {pinned}: template leaves absent from checkpoint: "
            + ", ".join(missing[:4])
        )
    groups = plan_layer_groups(
        [by_name[n] for n in names], priority_blocks=priority_blocks
    )

    vals: Dict[str, Any] = {}
    group_done: Dict[str, float] = {}
    ttft = 0.0
    t0 = time.perf_counter()
    for g in groups:
        got_step, got = manager.restore_leaves(list(g.leaves), step=pinned)
        if got_step != pinned:  # pragma: no cover - restore_leaves honors step
            raise IOError(f"stream pinned step {pinned}, read step {got_step}")
        if sharding_fn is not None:
            got = {n: sharding_fn(n, v) for n, v in got.items()}
        vals.update(got)
        now = time.perf_counter() - t0
        group_done[g.name] = now
        if g.priority:
            ttft = now
        if on_group is not None:
            on_group(g, now)
    total = time.perf_counter() - t0

    params = jax.tree_util.tree_unflatten(treedef, [vals[n] for n in names])
    return StreamedRestore(
        step=pinned,
        params=params,
        groups=groups,
        group_done_s=group_done,
        ttft_s=ttft,
        total_s=total,
        priority_bytes=sum(g.nbytes for g in groups if g.priority),
        total_bytes=sum(g.nbytes for g in groups),
    )


class ChunkCache:
    """Thread-safe byte-bounded LRU of decoded chunk bytes.

    One instance per node, shared by every co-located replica (the
    manager consults it via its ``chunk_cache`` attribute).  Keys are
    ``(step, chunk row)``; values are the decoded raw bytes of one
    chunk, frozen (non-writeable) because hits are returned by
    reference to concurrent readers.  ``bytes_saved`` counts decoded
    bytes served from the cache — reads and decodes that never
    happened."""

    def __init__(self, capacity_bytes: int = 256 << 20):
        self.capacity_bytes = int(capacity_bytes)
        self._lock = threading.Lock()
        self._data: "OrderedDict[Any, np.ndarray]" = OrderedDict()
        self._size = 0
        self._hits = 0
        self._misses = 0
        self._insertions = 0
        self._evictions = 0
        self._bytes_saved = 0

    def get(self, key: Any) -> Optional[np.ndarray]:
        with self._lock:
            arr = self._data.get(key)
            if arr is None:
                self._misses += 1
                return None
            self._data.move_to_end(key)
            self._hits += 1
            self._bytes_saved += arr.nbytes
            return arr

    def put(self, key: Any, value: Any) -> None:
        arr = np.frombuffer(memoryview(value), np.uint8) if not isinstance(
            value, np.ndarray
        ) else value
        if arr.nbytes > self.capacity_bytes:
            return  # would evict everything and still not fit
        try:
            arr.flags.writeable = False  # freeze in place when we can
        except ValueError:
            arr = arr.copy()
            arr.flags.writeable = False
        with self._lock:
            old = self._data.pop(key, None)
            if old is not None:
                self._size -= old.nbytes
            self._data[key] = arr
            self._size += arr.nbytes
            self._insertions += 1
            while self._size > self.capacity_bytes and self._data:
                _, ev = self._data.popitem(last=False)
                self._size -= ev.nbytes
                self._evictions += 1

    def clear(self) -> None:
        with self._lock:
            self._data.clear()
            self._size = 0

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {
                "hits": self._hits,
                "misses": self._misses,
                "insertions": self._insertions,
                "evictions": self._evictions,
                "size_bytes": self._size,
                "entries": len(self._data),
                "bytes_saved": self._bytes_saved,
            }
