"""Minimal batched serving engine: prefill once, decode greedily.

Serving snapshots (params + live caches/recurrent state) checkpoint
through the same CheckpointManager as training state — recurrent-state
snapshots are what make long-context serving restartable, one of the
paper-system's selling points for inference fleets.

Hot-swap safety: the server's weights live in one ``(params, version)``
tuple replaced atomically by :meth:`Server.swap_params`.  Each
:meth:`Server.generate` captures the tuple exactly once at entry, so a
swap landing mid-decode never tears a request across versions — the
in-flight generate finishes on the version it started with, the next
one picks up the new weights.  That single invariant is what lets
:class:`repro.serve.fleet.ServeFleet`'s follower roll a live fleet onto
each new training step without draining requests.
"""
from __future__ import annotations

import dataclasses
import threading
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model


@dataclass(frozen=True)
class ServeConfig:
    max_new_tokens: int = 32
    s_max: Optional[int] = None  # cache capacity (default: prompt + new)


class Server:
    def __init__(self, model: Model, params: Any, cfg: ServeConfig = ServeConfig()):
        self.model = model
        # (params, version): replaced as ONE reference by swap_params so
        # readers can never observe half a swap
        self._current: Tuple[Any, int] = (params, 0)
        self._swap_lock = threading.Lock()
        self.cfg = cfg
        self._decode = jax.jit(
            lambda p, c, t: self.model.decode_step(p, c, t)
        )

    @property
    def params(self) -> Any:
        return self._current[0]

    @property
    def params_version(self) -> int:
        return self._current[1]

    def swap_params(self, params: Any) -> int:
        """Atomically roll the server onto new weights.

        In-flight :meth:`generate` calls keep the reference they
        captured at entry and finish undisturbed; calls entering after
        the swap see only the new version.  Returns the new version
        number (monotonic from 0)."""
        with self._swap_lock:  # serialize swappers; readers never block
            version = self._current[1] + 1
            self._current = (params, version)
        return version

    @classmethod
    def from_checkpoint(
        cls,
        model: Model,
        manager: Any,
        params_template: Any,
        *,
        step: Optional[int] = None,
        prefix: str = "['params']",
        cfg: ServeConfig = ServeConfig(),
        sharding_fn: Optional[Any] = None,
        retry: Optional[Any] = None,
    ) -> Tuple["Server", int]:
        """Boot a server straight from a checkpoint's params subtree.

        Uses the manager's partial-restore path
        (:meth:`~repro.core.engine.CheckpointManager.restore_subtree`),
        so only the params' byte ranges are read from the aggregated
        files — an inference fleet pulls weights out of a multi-GB
        train-state checkpoint without touching optimizer state, and
        without the training geometry existing anymore.  ``prefix`` is
        the leaf-name prefix the params were saved under (``"['params']"``
        for both train states and :meth:`snapshot_state` snapshots).

        ``retry`` (a :class:`~repro.core.storage.RetryPolicy`) retries
        the whole restore: a serving fleet cold-starting hundreds of
        replicas against a PFS that is briefly unavailable should back
        off and re-pull, not crash-loop.  Only I/O failures
        (``OSError``, which covers :class:`StorageError` and the
        ``FileNotFoundError`` the restore ladder raises when every
        candidate fails) are treated as transient — a programming error
        (``TypeError``, ``KeyError``, a bad template) raises
        immediately instead of burning the retry deadline.
        """
        if retry is not None:
            restore = dataclasses.replace(
                retry,
                classify=lambda e: (
                    "transient" if isinstance(e, OSError) else "permanent"
                ),
            )
            step_out, params = restore.run(
                lambda: manager.restore_subtree(
                    params_template, prefix, step=step, sharding_fn=sharding_fn
                )
            )
        else:
            step_out, params = manager.restore_subtree(
                params_template, prefix, step=step, sharding_fn=sharding_fn
            )
        return cls(model, params, cfg), step_out

    def generate(
        self, batch: Dict[str, Any], *, with_version: bool = False
    ) -> Union[Tuple[np.ndarray, Any], Tuple[np.ndarray, Any, int]]:
        """Greedy decode; returns (generated tokens (B, T_new), final cache).

        With ``with_version=True`` also returns the params version this
        generate ran against.  The params reference is captured ONCE
        here — a concurrent :meth:`swap_params` cannot change the
        weights mid-request."""
        params, version = self._current  # the one atomic capture
        prompt = batch["tokens"]
        b, s = prompt.shape
        s_max = self.cfg.s_max or (s + self.cfg.max_new_tokens)
        cache, logits = self.model.prefill(params, batch, s_max=s_max)
        outs = []
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        for _ in range(self.cfg.max_new_tokens):
            outs.append(np.asarray(tok)[:, 0])
            logits, cache = self._decode(params, cache, tok)
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        toks = np.stack(outs, axis=1)
        if with_version:
            return toks, cache, version
        return toks, cache

    def snapshot_state(self, cache: Any) -> Dict[str, Any]:
        """Checkpointable serving snapshot (params + cache)."""
        return {"params": self.params, "cache": cache}
