"""Minimal batched serving engine: prefill once, decode greedily.

Serving snapshots (params + live caches/recurrent state) checkpoint
through the same CheckpointManager as training state — recurrent-state
snapshots are what make long-context serving restartable, one of the
paper-system's selling points for inference fleets.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model


@dataclass(frozen=True)
class ServeConfig:
    max_new_tokens: int = 32
    s_max: Optional[int] = None  # cache capacity (default: prompt + new)


class Server:
    def __init__(self, model: Model, params: Any, cfg: ServeConfig = ServeConfig()):
        self.model = model
        self.params = params
        self.cfg = cfg
        self._decode = jax.jit(
            lambda p, c, t: self.model.decode_step(p, c, t)
        )

    @classmethod
    def from_checkpoint(
        cls,
        model: Model,
        manager: Any,
        params_template: Any,
        *,
        step: Optional[int] = None,
        prefix: str = "['params']",
        cfg: ServeConfig = ServeConfig(),
        sharding_fn: Optional[Any] = None,
        retry: Optional[Any] = None,
    ) -> Tuple["Server", int]:
        """Boot a server straight from a checkpoint's params subtree.

        Uses the manager's partial-restore path
        (:meth:`~repro.core.engine.CheckpointManager.restore_subtree`),
        so only the params' byte ranges are read from the aggregated
        files — an inference fleet pulls weights out of a multi-GB
        train-state checkpoint without touching optimizer state, and
        without the training geometry existing anymore.  ``prefix`` is
        the leaf-name prefix the params were saved under (``"['params']"``
        for both train states and :meth:`snapshot_state` snapshots).

        ``retry`` (a :class:`~repro.core.storage.RetryPolicy`) retries
        the whole restore: a serving fleet cold-starting hundreds of
        replicas against a PFS that is briefly unavailable should back
        off and re-pull, not crash-loop.  Every error is retried here —
        the ladder inside ``restore_subtree`` folds transient I/O
        failures into its fallback errors, so errno classification
        cannot see them from this level.
        """
        if retry is not None:
            restore = dataclasses.replace(retry, classify=lambda e: "transient")
            step_out, params = restore.run(
                lambda: manager.restore_subtree(
                    params_template, prefix, step=step, sharding_fn=sharding_fn
                )
            )
        else:
            step_out, params = manager.restore_subtree(
                params_template, prefix, step=step, sharding_fn=sharding_fn
            )
        return cls(model, params, cfg), step_out

    def generate(self, batch: Dict[str, Any]) -> Tuple[np.ndarray, Any]:
        """Greedy decode; returns (generated tokens (B, T_new), final cache)."""
        prompt = batch["tokens"]
        b, s = prompt.shape
        s_max = self.cfg.s_max or (s + self.cfg.max_new_tokens)
        cache, logits = self.model.prefill(self.params, batch, s_max=s_max)
        outs = []
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        for _ in range(self.cfg.max_new_tokens):
            outs.append(np.asarray(tok)[:, 0])
            logits, cache = self._decode(self.params, cache, tok)
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        return np.stack(outs, axis=1), cache

    def snapshot_state(self, cache: Any) -> Dict[str, Any]:
        """Checkpointable serving snapshot (params + cache)."""
        return {"params": self.params, "cache": cache}
