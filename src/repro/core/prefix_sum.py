"""Prefix-sum offset computation + piggy-backed leader election.

The paper's key coordination primitive: one exclusive prefix sum over the
per-rank checkpoint sizes yields every rank's offset in the aggregated
remote file.  The proposed strategy (paper §3) *piggy-backs* extra
per-node summaries (local bytes, load, topology coordinate) on the same
scan so that every active backend can afterwards compute — independently
and deterministically — the identical leader assignment, without any
further agreement protocol.

Everything here is a pure algorithm (no I/O): the planner uses it
directly, the simulator prices its message complexity, and a
``shard_map`` twin in :mod:`repro.dist.collectives` shows the same scan
as a device-level JAX collective.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

from repro.core.cluster import ClusterSpec


@dataclass(frozen=True)
class ScanMeta:
    """Cost model of the scan used for coordination.

    A classic up-/down-sweep tree over P participants: ``2*ceil(log2 P)``
    latency-bound rounds, ``2*(P-1)`` point-to-point messages total, each
    carrying ``payload_bytes`` (offset partial + piggy-backed summary).
    """

    participants: int
    rounds: int
    messages: int
    payload_bytes: int

    @staticmethod
    def for_participants(p: int, payload_bytes: int) -> "ScanMeta":
        rounds = 2 * max(1, math.ceil(math.log2(max(2, p))))
        return ScanMeta(
            participants=p,
            rounds=rounds,
            messages=2 * max(0, p - 1),
            payload_bytes=payload_bytes,
        )


@dataclass(frozen=True)
class NodeSummary:
    """Per-node info carried by the piggy-backed scan (paper §3)."""

    node: int
    bytes: int          # total node-local checkpoint bytes on this node
    load: float         # current background load in [0, 1)
    coord: int          # topology coordinate (proximity = |a - b|)


@dataclass
class ScanResult:
    """Output of the (piggy-backed) exclusive prefix sum."""

    rank_offsets: List[int]           # exclusive prefix sum per rank
    total_bytes: int
    node_summaries: List[NodeSummary]
    meta: ScanMeta = field(default=None)  # type: ignore[assignment]


def exclusive_prefix_sum(sizes: Sequence[int]) -> Tuple[List[int], int]:
    offsets: List[int] = []
    acc = 0
    for s in sizes:
        if s < 0:
            raise ValueError("checkpoint sizes must be non-negative")
        offsets.append(acc)
        acc += int(s)
    return offsets, acc


def piggybacked_scan(
    cluster: ClusterSpec,
    rank_sizes: Sequence[int],
    *,
    payload_extra_bytes: int = 24,
) -> ScanResult:
    """Exclusive scan over rank sizes + per-node summary exchange.

    ``payload_extra_bytes`` models the piggy-backed (bytes, load, coord)
    triple added to each scan message; it appears only in the cost model.
    """
    if len(rank_sizes) != cluster.world_size:
        raise ValueError(
            f"expected {cluster.world_size} rank sizes, got {len(rank_sizes)}"
        )
    offsets, total = exclusive_prefix_sum(rank_sizes)
    summaries = []
    for node in range(cluster.n_nodes):
        ranks = cluster.ranks_of_node(node)
        summaries.append(
            NodeSummary(
                node=node,
                bytes=sum(int(rank_sizes[r]) for r in ranks),
                load=cluster.load_of(node),
                coord=cluster.coord_of(node),
            )
        )
    meta = ScanMeta.for_participants(
        cluster.n_nodes, payload_bytes=8 + payload_extra_bytes
    )
    return ScanResult(
        rank_offsets=offsets,
        total_bytes=total,
        node_summaries=summaries,
        meta=meta,
    )


# ---------------------------------------------------------------------------
# Leader election (paper §3, criteria 1-3)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LeaderAssignment:
    """M leaders, each statically owning a stripe-aligned file region.

    ``regions[j] = (start, end)`` in file-offset bytes, start/end aligned
    to the PFS stripe size (end of the last region = padded total).
    ``leaders[j]`` is the node id leading region j.
    """

    leaders: List[int]
    regions: List[Tuple[int, int]]

    def leader_of_offset(self, off: int) -> int:
        for j, (s, e) in enumerate(self.regions):
            if s <= off < e:
                return self.leaders[j]
        raise ValueError(f"offset {off} outside every region")

    @property
    def m(self) -> int:
        return len(self.leaders)


def elect_leaders(
    cluster: ClusterSpec,
    scan: ScanResult,
    m_leaders: int,
    *,
    w_size: float = 1.0,
    w_load: float = 0.75,
    w_topo: float = 0.25,
    capacity_regions: bool = False,
) -> LeaderAssignment:
    """Deterministic leader election from piggy-backed summaries.

    Every node evaluates this identical pure function on the identical
    scan output, hence all nodes agree on the assignment with zero extra
    communication (the paper's "no further agreement protocols").

    Scoring per (region, candidate node):
      + ``w_size`` * fraction of the region's bytes already held locally
        (criterion 1: big holders lead, minimizing network transfer)
      - ``w_load`` * node background load (criterion 2)
      - ``w_topo`` * normalized topology distance from the region's
        centroid sender (criterion 3: leaders near their senders)

    ``capacity_regions`` (beyond-paper straggler mitigation): after the
    election, region sizes are re-proportioned to each leader's capacity
    (1 - load) and re-snapped to stripes, so a loaded leader owns fewer
    stripes instead of the same S/M share — the deterministic analogue of
    work stealing (still zero extra communication: every backend computes
    the same resize from the same piggy-backed loads).
    """
    if m_leaders <= 0:
        raise ValueError("m_leaders must be positive")
    pfs = cluster.pfs
    stripe = pfs.stripe_size
    total = scan.total_bytes
    n_stripes = max(1, pfs.n_stripes(total))
    m = min(m_leaders, n_stripes, cluster.n_nodes)
    stripes_per_region = -(-n_stripes // m)

    regions: List[Tuple[int, int]] = []
    for j in range(m):
        start = j * stripes_per_region * stripe
        end = min((j + 1) * stripes_per_region * stripe, n_stripes * stripe)
        if start >= end:
            break
        regions.append((start, end))
    m = len(regions)

    # Node byte-extent in the aggregate file: [first rank offset, last end).
    node_extent: List[Tuple[int, int]] = []
    for node in range(cluster.n_nodes):
        ranks = cluster.ranks_of_node(node)
        starts = [scan.rank_offsets[r] for r in ranks]
        ends = [
            scan.rank_offsets[r]
            + (scan.total_bytes - scan.rank_offsets[r]
               if r == cluster.world_size - 1
               else scan.rank_offsets[r + 1] - scan.rank_offsets[r])
            for r in ranks
        ]
        node_extent.append((min(starts) if starts else 0, max(ends) if ends else 0))

    max_node_bytes = max(1, max(s.bytes for s in scan.node_summaries))
    coord_span = max(
        1, max(s.coord for s in scan.node_summaries) - min(s.coord for s in scan.node_summaries)
    )

    def overlap(a: Tuple[int, int], b: Tuple[int, int]) -> int:
        return max(0, min(a[1], b[1]) - max(a[0], b[0]))

    leaders: List[int] = []
    taken = set()
    allow_reuse = m > cluster.n_nodes  # only possible via tiny clusters
    for j, reg in enumerate(regions):
        reg_bytes = max(1, reg[1] - reg[0])
        # Topology centroid of the senders feeding this region, weighted by
        # how many of their bytes land here.
        wsum, csum = 0.0, 0.0
        for node in range(cluster.n_nodes):
            ob = overlap(node_extent[node], reg)
            if ob > 0:
                wsum += ob
                csum += ob * cluster.coord_of(node)
        centroid = csum / wsum if wsum > 0 else cluster.coord_of(0)

        best, best_score = -1, -math.inf
        for node in range(cluster.n_nodes):
            if node in taken and not allow_reuse:
                continue
            s = scan.node_summaries[node]
            local_frac = overlap(node_extent[node], reg) / reg_bytes
            size_term = w_size * (0.5 * local_frac + 0.5 * s.bytes / max_node_bytes)
            load_term = w_load * s.load
            topo_term = w_topo * abs(cluster.coord_of(node) - centroid) / coord_span
            score = size_term - load_term - topo_term
            if score > best_score or (score == best_score and node < best):
                best, best_score = node, score
        leaders.append(best)
        taken.add(best)

    if capacity_regions and len(leaders) > 1:
        caps = [max(1e-3, 1.0 - cluster.load_of(nd)) for nd in leaders]
        total_cap = sum(caps)
        new_regions: List[Tuple[int, int]] = []
        start_stripe = 0
        total_stripes = n_stripes
        for j, cap in enumerate(caps):
            if j == len(caps) - 1:
                n_str = total_stripes - start_stripe
            else:
                n_str = max(1, round(total_stripes * cap / total_cap))
                n_str = min(n_str, total_stripes - start_stripe - (len(caps) - 1 - j))
            s0 = start_stripe * stripe
            e0 = min((start_stripe + n_str) * stripe, n_stripes * stripe)
            new_regions.append((s0, e0))
            start_stripe += n_str
        regions = [r for r in new_regions if r[0] < r[1]]
        leaders = leaders[: len(regions)]

    return LeaderAssignment(leaders=leaders, regions=regions)
