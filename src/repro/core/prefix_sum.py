"""Prefix-sum offset computation + piggy-backed leader election.

The paper's key coordination primitive: one exclusive prefix sum over the
per-rank checkpoint sizes yields every rank's offset in the aggregated
remote file.  The proposed strategy (paper §3) *piggy-backs* extra
per-node summaries (local bytes, load, topology coordinate) on the same
scan so that every active backend can afterwards compute — independently
and deterministically — the identical leader assignment, without any
further agreement protocol.

Everything here is a pure algorithm (no I/O): the planner uses it
directly, the simulator prices its message complexity, and a
``shard_map`` twin in :mod:`repro.dist.collectives` shows the same scan
as a device-level JAX collective.

At paper scale the scan and the election inputs are array programs: the
exclusive scan is one ``np.cumsum``, per-node byte totals are a reshape-
sum, and the per-(region, node) election scores are computed as
broadcast NumPy expressions rather than nested Python loops.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cluster import ClusterSpec


@dataclass(frozen=True)
class ScanMeta:
    """Cost model of the scan used for coordination.

    A classic up-/down-sweep tree over P participants: ``2*ceil(log2 P)``
    latency-bound rounds, ``2*(P-1)`` point-to-point messages total, each
    carrying ``payload_bytes`` (offset partial + piggy-backed summary).
    """

    participants: int
    rounds: int
    messages: int
    payload_bytes: int

    @staticmethod
    def for_participants(p: int, payload_bytes: int) -> "ScanMeta":
        rounds = 2 * max(1, math.ceil(math.log2(max(2, p))))
        return ScanMeta(
            participants=p,
            rounds=rounds,
            messages=2 * max(0, p - 1),
            payload_bytes=payload_bytes,
        )


@dataclass(frozen=True)
class NodeSummary:
    """Per-node info carried by the piggy-backed scan (paper §3)."""

    node: int
    bytes: int          # total node-local checkpoint bytes on this node
    load: float         # current background load in [0, 1)
    coord: int          # topology coordinate (proximity = |a - b|)


@dataclass
class ScanResult:
    """Output of the (piggy-backed) exclusive prefix sum."""

    rank_offsets: List[int]           # exclusive prefix sum per rank
    total_bytes: int
    node_summaries: List[NodeSummary]
    meta: ScanMeta = field(default=None)  # type: ignore[assignment]
    # Columnar twins, populated by piggybacked_scan so the vectorized
    # planner layers never rebuild them from the Python lists.
    offsets_np: Optional[np.ndarray] = None   # int64, len world_size
    node_bytes_np: Optional[np.ndarray] = None  # int64, len n_nodes

    def offsets_array(self) -> np.ndarray:
        if self.offsets_np is None:
            self.offsets_np = np.asarray(self.rank_offsets, dtype=np.int64)
        return self.offsets_np


def exclusive_prefix_sum_np(sizes: Sequence[int]) -> Tuple[np.ndarray, int]:
    """Vectorized exclusive scan: (int64 offsets, total)."""
    arr = np.asarray(sizes, dtype=np.int64)
    if arr.size and int(arr.min()) < 0:
        raise ValueError("checkpoint sizes must be non-negative")
    offsets = np.zeros(arr.size, dtype=np.int64)
    if arr.size:
        np.cumsum(arr[:-1], out=offsets[1:])
    total = int(arr.sum())
    return offsets, total


def exclusive_prefix_sum(sizes: Sequence[int]) -> Tuple[List[int], int]:
    offsets, total = exclusive_prefix_sum_np(sizes)
    return offsets.tolist(), total


def piggybacked_scan(
    cluster: ClusterSpec,
    rank_sizes: Sequence[int],
    *,
    payload_extra_bytes: int = 24,
) -> ScanResult:
    """Exclusive scan over rank sizes + per-node summary exchange.

    ``payload_extra_bytes`` models the piggy-backed (bytes, load, coord)
    triple added to each scan message; it appears only in the cost model.
    """
    if len(rank_sizes) != cluster.world_size:
        raise ValueError(
            f"expected {cluster.world_size} rank sizes, got {len(rank_sizes)}"
        )
    offsets, total = exclusive_prefix_sum_np(rank_sizes)
    sizes = np.asarray(rank_sizes, dtype=np.int64)
    node_bytes = sizes.reshape(cluster.n_nodes, cluster.procs_per_node).sum(axis=1)
    loads = cluster.loads()
    coords = cluster.coords()
    summaries = [
        NodeSummary(node=node, bytes=int(node_bytes[node]),
                    load=float(loads[node]), coord=int(coords[node]))
        for node in range(cluster.n_nodes)
    ]
    meta = ScanMeta.for_participants(
        cluster.n_nodes, payload_bytes=8 + payload_extra_bytes
    )
    return ScanResult(
        rank_offsets=offsets.tolist(),
        total_bytes=total,
        node_summaries=summaries,
        meta=meta,
        offsets_np=offsets,
        node_bytes_np=node_bytes,
    )


# ---------------------------------------------------------------------------
# Leader election (paper §3, criteria 1-3)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LeaderAssignment:
    """M leaders, each statically owning a stripe-aligned file region.

    ``regions[j] = (start, end)`` in file-offset bytes, start/end aligned
    to the PFS stripe size (end of the last region = padded total).
    ``leaders[j]`` is the node id leading region j.
    """

    leaders: List[int]
    regions: List[Tuple[int, int]]

    def leader_of_offset(self, off: int) -> int:
        for j, (s, e) in enumerate(self.regions):
            if s <= off < e:
                return self.leaders[j]
        raise ValueError(f"offset {off} outside every region")

    @property
    def m(self) -> int:
        return len(self.leaders)


def elect_leaders(
    cluster: ClusterSpec,
    scan: ScanResult,
    m_leaders: int,
    *,
    w_size: float = 1.0,
    w_load: float = 0.75,
    w_topo: float = 0.25,
    capacity_regions: bool = False,
) -> LeaderAssignment:
    """Deterministic leader election from piggy-backed summaries.

    Every node evaluates this identical pure function on the identical
    scan output, hence all nodes agree on the assignment with zero extra
    communication (the paper's "no further agreement protocols").

    Scoring per (region, candidate node):
      + ``w_size`` * fraction of the region's bytes already held locally
        (criterion 1: big holders lead, minimizing network transfer)
      - ``w_load`` * node background load (criterion 2)
      - ``w_topo`` * normalized topology distance from the region's
        centroid sender (criterion 3: leaders near their senders)

    ``capacity_regions`` (beyond-paper straggler mitigation): after the
    election, region sizes are re-proportioned to each leader's capacity
    (1 - load) and re-snapped to stripes, so a loaded leader owns fewer
    stripes instead of the same S/M share — the deterministic analogue of
    work stealing (still zero extra communication: every backend computes
    the same resize from the same piggy-backed loads).
    """
    if m_leaders <= 0:
        raise ValueError("m_leaders must be positive")
    pfs = cluster.pfs
    stripe = pfs.stripe_size
    total = scan.total_bytes
    n_nodes = cluster.n_nodes
    ppn = cluster.procs_per_node
    n_stripes = max(1, pfs.n_stripes(total))
    m = min(m_leaders, n_stripes, n_nodes)
    stripes_per_region = -(-n_stripes // m)

    regions: List[Tuple[int, int]] = []
    for j in range(m):
        start = j * stripes_per_region * stripe
        end = min((j + 1) * stripes_per_region * stripe, n_stripes * stripe)
        if start >= end:
            break
        regions.append((start, end))
    m = len(regions)

    # Node byte-extent in the aggregate file: [first rank offset, last end).
    # Ranks of a node are contiguous, so the extent is simply the first
    # rank's offset up to the next node's first offset (or the total).
    offsets = scan.offsets_array()
    if offsets.size:
        ext_lo = offsets[::ppn]
        ext_hi = np.append(offsets[ppn::ppn], total)
    else:
        ext_lo = np.zeros(n_nodes, np.int64)
        ext_hi = np.zeros(n_nodes, np.int64)

    node_bytes = (
        scan.node_bytes_np
        if scan.node_bytes_np is not None
        else np.asarray([s.bytes for s in scan.node_summaries], np.int64)
    )
    loads = cluster.loads()
    coords = cluster.coords().astype(np.float64)
    max_node_bytes = max(1, int(node_bytes.max(initial=0)))
    coord_span = max(1.0, float(coords.max() - coords.min()))

    reg_lo = np.asarray([r[0] for r in regions], np.int64)
    reg_hi = np.asarray([r[1] for r in regions], np.int64)
    # (m, n_nodes) byte overlap between each region and each node extent.
    ob = np.maximum(
        0,
        np.minimum(ext_hi[None, :], reg_hi[:, None])
        - np.maximum(ext_lo[None, :], reg_lo[:, None]),
    ).astype(np.float64)

    leaders: List[int] = []
    taken = np.zeros(n_nodes, bool)
    allow_reuse = m > n_nodes  # only possible via tiny clusters
    base_score = (
        w_size * 0.5 * (node_bytes.astype(np.float64) / max_node_bytes)
        - w_load * loads
    )
    for j in range(m):
        reg_bytes = max(1, int(reg_hi[j] - reg_lo[j]))
        # Topology centroid of the senders feeding this region, weighted by
        # how many of their bytes land here.
        wsum = float(ob[j].sum())
        centroid = float((ob[j] * coords).sum() / wsum) if wsum > 0 else float(coords[0])
        score = (
            base_score
            + w_size * 0.5 * (ob[j] / reg_bytes)
            - w_topo * np.abs(coords - centroid) / coord_span
        )
        if not allow_reuse:
            score = np.where(taken, -np.inf, score)
        best = int(np.argmax(score))
        leaders.append(best)
        taken[best] = True

    if capacity_regions and len(leaders) > 1:
        caps = [max(1e-3, 1.0 - cluster.load_of(nd)) for nd in leaders]
        total_cap = sum(caps)
        new_regions: List[Tuple[int, int]] = []
        start_stripe = 0
        total_stripes = n_stripes
        for j, cap in enumerate(caps):
            if j == len(caps) - 1:
                n_str = total_stripes - start_stripe
            else:
                n_str = max(1, round(total_stripes * cap / total_cap))
                n_str = min(n_str, total_stripes - start_stripe - (len(caps) - 1 - j))
            s0 = start_stripe * stripe
            e0 = min((start_stripe + n_str) * stripe, n_stripes * stripe)
            new_regions.append((s0, e0))
            start_stripe += n_str
        regions = [r for r in new_regions if r[0] < r[1]]
        leaders = leaders[: len(regions)]

    return LeaderAssignment(leaders=leaders, regions=regions)
