"""Real filesystem executor: runs FlushPlans against actual files.

Directory layout (``root`` is the checkpoint root):

.. code-block:: text

    root/
      local/node_{j}/step_{s}/rank_{r}.blob      # L1 node-local files
      local/node_{j}/step_{s}/rank_{r}.partner   # optional peer replica
      local/manifests/step_{s}.json              # manifest @ local_done
      pfs/step_{s}/<plan files>                  # L2 aggregated/unaggregated
      pfs/step_{s}/manifest.json                 # manifest @ flush_done

"Network sends" in a single-process harness are leader-side reads of the
source node's L1 file — the executor never touches the in-memory blobs
during the flush, so the flush path exercises exactly what a distributed
deployment would: node-local read -> (ship) -> pwrite at the planned
offset of the shared file.

Fault injection: the canonical surface is a seeded
:class:`~repro.core.faults.FaultPlan` (``faults=`` on this executor and
on :class:`LocalStore`) scheduling faults at exact op indices per
domain; the legacy ``fault_hook(write_item)`` callback survives for
targeted tests and may still raise to simulate an active-backend crash
mid-flush.  Either way, partially written PFS state is left behind with
the manifest still at ``local_done``/``flush_partial`` — restart logic
must (and does, see tests) fall back to L1 or resume from the journal.

Transient-fault tolerance: every raw blob/extent I/O can be wrapped in
a :class:`RetryPolicy` — errno-classified transient failures
(:func:`classify_error`) are retried with bounded exponential backoff
+ deterministic jitter under a per-op deadline, sleeping through
``CancelToken.wait`` so a superseded flush cancels mid-backoff.
Retry/giveup counts surface in :class:`FlushResult`/:class:`ReadResult`.
Permanent failures propagate unchanged, so a failed flush keeps its
journal and stays resumable.  L1 blob reads that still fail after
retries are re-raised as structured :class:`StorageError`\\ s carrying
``(level, step, rank, path)`` so ladder-fallback logs say exactly
which copy failed and why.

The read side mirrors the write side: :meth:`RealExecutor.
execute_read_plan` runs a columnar :class:`~repro.core.plan.ReadPlan`
as ranged ``pread``\\ s through the same work-stealing thread pool, so
aggregated checkpoints are *read* as aggregated files — full elastic
restores, reshards and partial (per-leaf) restores all go through one
plan instead of per-rank whole-blob loops.

Adaptive flush runtime primitives (engine-facing; see
docs/OPERATIONS.md for the lifecycle):

* :class:`CancelToken` — cooperative cancellation, checked by the
  executor at *safe request boundaries* (between writes, never inside
  one), raising :class:`FlushCancelled`;
* :class:`TokenBucket` — a global byte-rate limiter the engine hangs on
  executor writes so the background drain does not steal the
  application's NIC share (the ``flush_bw_cap`` / ``app_net_load``
  policy, priced identically by :mod:`repro.core.sim`);
* :class:`FlushJournal` — an append-only *columnar* progress cursor
  (little-endian int64 ``(file_id, file_offset, size)`` triples)
  persisted next to the manifest: every completed destination extent is
  journaled, so a flush interrupted by ``close()``, a fault hook or
  process death resumes from the last completed extent
  (:meth:`RealExecutor.execute_resume`) instead of rewriting the whole
  checkpoint.
"""
from __future__ import annotations

import errno
import os
import random
import shutil
import threading
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor, as_completed
from concurrent.futures import wait as futures_wait
from dataclasses import dataclass
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.plan import (
    FileLayout,
    FlushPlan,
    ReadPlan,
    WriteColumns,
    WriteItem,
    build_read_plan,
    coalesce_write_columns,
    merge_intervals,
)
from repro.core.faults import FaultPlan, inject_write
from repro.core.serialize import Manifest, Placement


class FlushCancelled(Exception):
    """An executing flush observed its :class:`CancelToken` fired.

    Deliberately *not* an ``IOError``: the engine treats cancellation
    (supersession, ``close()`` deadline) as a scheduling outcome, not a
    flush failure — it must never land in ``flush_errors``.
    """


class CancelToken:
    """Cooperative cancellation for one in-flight flush.

    The executor polls :attr:`cancelled` at safe request boundaries
    (before each coalesced write row) and while sleeping in the rate
    limiter, so cancellation latency is one write (or one throttle
    tick), never a partial ``pwrite``.
    """

    __slots__ = ("_ev",)

    def __init__(self) -> None:
        self._ev = threading.Event()

    def cancel(self) -> None:
        self._ev.set()

    @property
    def cancelled(self) -> bool:
        return self._ev.is_set()

    def wait(self, timeout: float) -> bool:
        return self._ev.wait(timeout)


#: errno values classified transient: the storage under us hiccuped but
#: a retried attempt can plausibly succeed.  Everything else (ENOSPC,
#: ENOENT, EACCES, EROFS, errno-less IOErrors, ...) is permanent.
TRANSIENT_ERRNOS = frozenset(
    {
        errno.EIO,
        errno.EAGAIN,
        errno.EINTR,
        errno.EBUSY,
        errno.ETIMEDOUT,
        errno.ESTALE,
        errno.ECONNRESET,
        errno.ENETRESET,
    }
)


def classify_error(e: BaseException) -> str:
    """``"transient"`` or ``"permanent"`` for one I/O exception.

    Only ``OSError``\\ s with an errno in :data:`TRANSIENT_ERRNOS` are
    transient; an errno-less ``IOError`` (e.g. a test's injected
    backend crash) is deliberately permanent so legacy ``fault_hook``
    semantics — one raise fails the flush — are preserved.
    """
    if isinstance(e, OSError) and e.errno in TRANSIENT_ERRNOS:
        return "transient"
    return "permanent"


class StorageError(OSError):
    """A blob/extent I/O failure with full ladder attribution.

    Carries ``(level, step, rank, path)`` so restore-ladder fallback
    log lines say exactly which copy failed and why, instead of a bare
    ``[Errno 2] No such file or directory``.  Subclasses ``OSError``
    (errno preserved from the cause) so every existing
    ``except OSError`` fallback keeps working.
    """

    def __init__(self, level: str, step: int, rank: int, path, cause=None):
        eno = cause.errno if isinstance(cause, OSError) else None
        msg = (
            f"{level} copy failed: step {step} rank {rank} at {path}"
            f" ({cause if cause is not None else 'unknown error'})"
        )
        super().__init__(eno, msg)
        self.level = level
        self.step = int(step)
        self.rank = int(rank)
        self.path = str(path)
        self.filename = str(path)

    def __str__(self) -> str:  # no "[Errno n] msg: path" re-assembly
        return self.args[1] if len(self.args) > 1 else super().__str__()


class MissingBlobError(StorageError, FileNotFoundError):
    """A :class:`StorageError` whose cause was a missing file — also a
    ``FileNotFoundError`` so existence-based fallbacks still match."""


def wrap_storage_error(level: str, step: int, rank: int, path, cause) -> StorageError:
    cls = (
        MissingBlobError
        if isinstance(cause, FileNotFoundError)
        else StorageError
    )
    return cls(level, step, rank, path, cause)


class CircuitOpenError(OSError):
    """Fail-fast: the storage domain's circuit breaker is open.

    Raised *before* the raw op is attempted, so an unavailable domain
    costs microseconds instead of a full retry schedule.  Carries
    ``errno.EHOSTDOWN`` — deliberately **not** in
    :data:`TRANSIENT_ERRNOS`, so the retry layer re-raises it
    immediately (no backoff, no giveup accounting): the breaker, not
    the retry budget, owns recovery timing.
    """

    def __init__(self, domain: str, retry_in: float = 0.0):
        super().__init__(
            errno.EHOSTDOWN,
            f"storage domain {domain!r} circuit open"
            + (f" (probe in {retry_in:.2f}s)" if retry_in > 0 else ""),
        )
        self.domain = domain
        self.retry_in = float(retry_in)


@dataclass
class DomainHealth:
    """Point-in-time health snapshot of one storage domain."""

    domain: str
    state: str  # "closed" | "open" | "half_open"
    ops: int
    errors: int
    giveups: int
    error_rate: float  # over the sliding window
    p50_latency: float
    p95_latency: float
    opened_at: Optional[float] = None
    probes_ok: int = 0


class _DomainStats:
    __slots__ = (
        "outcomes", "lats", "ops", "errors", "giveups",
        "state", "opened_at", "probes_ok", "half_inflight",
    )

    def __init__(self, window: int):
        self.outcomes: deque = deque(maxlen=window)  # True=ok per attempt
        self.lats: deque = deque(maxlen=window)  # success latencies (s)
        self.ops = 0
        self.errors = 0
        self.giveups = 0
        self.state = "closed"
        self.opened_at: Optional[float] = None
        self.probes_ok = 0
        self.half_inflight = 0


class StorageHealth:
    """Per-domain sliding-window health registry + circuit breaker.

    Domains are free-form strings — the runtime uses ``"pfs"``,
    per-node ``"l1:n{j}"``/``"partner:n{j}"``, and per-reader
    ``"reader:n{k}"`` (latency-only, for straggler demotion).  Outcomes
    are fed per *attempt* by :meth:`RetryPolicy.run` (``domain=`` at
    the call sites), so the registry sees exactly what the retry layer
    sees: every transient failure, every giveup, every success with its
    latency.

    Circuit states (per domain):

    * **closed** — healthy.  Trips to *open* when a retry budget gives
      up (``open_on_giveup``) or when the sliding-window error rate
      reaches ``error_threshold`` over ≥ ``min_ops`` attempts — with
      concurrent writers each failed attempt lands here *between*
      backoff sleeps, so a real outage opens the circuit before any
      single op can burn its whole budget.
    * **open** — :meth:`check` raises :class:`CircuitOpenError`
      immediately.  After ``cooldown`` seconds the next ``check``
      admits up to ``probe_parallel`` ops as half-open probes.
    * **half_open** — probe ops flow, everything else still fails
      fast.  ``probe_successes`` consecutive successes close the
      circuit (window reset); one failure re-opens it with a fresh
      cooldown.

    ``clock`` is injectable so circuit-transition tests are pure
    functions of their fault schedule, not of wall-clock scheduling.
    """

    def __init__(
        self,
        *,
        window: int = 64,
        min_ops: int = 8,
        error_threshold: float = 0.5,
        open_on_giveup: bool = True,
        cooldown: float = 2.0,
        probe_successes: int = 2,
        probe_parallel: int = 2,
        clock: Callable[[], float] = time.monotonic,
    ):
        self.window = max(4, int(window))
        self.min_ops = max(1, int(min_ops))
        self.error_threshold = float(error_threshold)
        self.open_on_giveup = bool(open_on_giveup)
        self.cooldown = float(cooldown)
        self.probe_successes = max(1, int(probe_successes))
        self.probe_parallel = max(1, int(probe_parallel))
        self.clock = clock
        self._lock = threading.Lock()
        self._domains: Dict[str, _DomainStats] = {}
        self.trips = 0  # closed->open transitions (telemetry)

    def _dom(self, domain: str) -> _DomainStats:
        d = self._domains.get(domain)
        if d is None:
            d = self._domains[domain] = _DomainStats(self.window)
        return d

    def _trip(self, d: _DomainStats) -> None:
        d.state = "open"
        d.opened_at = self.clock()
        d.probes_ok = 0
        d.half_inflight = 0
        d.outcomes.clear()
        self.trips += 1

    def record(
        self,
        domain: str,
        ok: bool,
        latency: float = 0.0,
        *,
        giveup: bool = False,
    ) -> None:
        """Feed one attempt outcome (the retry layer calls this)."""
        with self._lock:
            d = self._dom(domain)
            d.ops += 1
            d.outcomes.append(bool(ok))
            if ok and latency > 0.0:
                d.lats.append(float(latency))
            if d.state == "half_open":
                d.half_inflight = max(0, d.half_inflight - 1)
                if ok:
                    d.probes_ok += 1
                    if d.probes_ok >= self.probe_successes:
                        d.state = "closed"
                        d.opened_at = None
                        d.outcomes.clear()
                else:
                    d.errors += 1
                    if giveup:
                        d.giveups += 1
                    self._trip(d)  # failed probe: fresh cooldown
                return
            if ok:
                return
            d.errors += 1
            if giveup:
                d.giveups += 1
            if d.state != "closed":
                return
            if giveup and self.open_on_giveup:
                self._trip(d)
                return
            n = len(d.outcomes)
            bad = n - sum(d.outcomes)
            if n >= self.min_ops and bad / n >= self.error_threshold:
                self._trip(d)

    def note_latency(self, domain: str, latency: float) -> None:
        """Latency-only sample (read-side reader stats): no outcome,
        no circuit effect — feeds quantiles for hedging/demotion."""
        with self._lock:
            d = self._dom(domain)
            d.ops += 1
            d.lats.append(float(latency))

    def check(self, domain: str) -> None:
        """Gate one op: no-op when closed, admits probes when
        half-open, raises :class:`CircuitOpenError` otherwise."""
        with self._lock:
            d = self._domains.get(domain)
            if d is None or d.state == "closed":
                return
            now = self.clock()
            if d.state == "open":
                waited = now - (d.opened_at or now)
                if waited < self.cooldown:
                    raise CircuitOpenError(domain, self.cooldown - waited)
                d.state = "half_open"
                d.probes_ok = 0
                d.half_inflight = 0
            if d.half_inflight < self.probe_parallel:
                d.half_inflight += 1  # admitted as a half-open probe
                return
            raise CircuitOpenError(domain)

    def allow(self, domain: str) -> bool:
        """Non-raising :meth:`check` (restore-ladder gating)."""
        try:
            self.check(domain)
            return True
        except CircuitOpenError:
            return False

    def state(self, domain: str) -> str:
        with self._lock:
            d = self._domains.get(domain)
            if d is None:
                return "closed"
            if (
                d.state == "open"
                and d.opened_at is not None
                and self.clock() - d.opened_at >= self.cooldown
            ):
                return "half_open"  # a check() would admit probes now
            return d.state

    def probe_due(self, domain: str) -> bool:
        """True when an explicit probe op would be admitted — the
        engine's degraded tick drives :meth:`RealExecutor.probe_pfs`
        off this, so a fully parked scheduler still recovers."""
        return self.state(domain) in ("half_open",)

    def latency_quantile(
        self, domain: str, q: float, default: float = 0.0
    ) -> float:
        with self._lock:
            d = self._domains.get(domain)
            if d is None or not d.lats:
                return default
            arr = sorted(d.lats)
            i = min(len(arr) - 1, max(0, int(q * len(arr))))
            return float(arr[i])

    def snapshot(self) -> Dict[str, DomainHealth]:
        with self._lock:
            out: Dict[str, DomainHealth] = {}
            for name, d in self._domains.items():
                n = len(d.outcomes)
                bad = n - sum(d.outcomes)
                lats = sorted(d.lats)
                out[name] = DomainHealth(
                    domain=name,
                    state=d.state,
                    ops=d.ops,
                    errors=d.errors,
                    giveups=d.giveups,
                    error_rate=(bad / n) if n else 0.0,
                    p50_latency=lats[len(lats) // 2] if lats else 0.0,
                    p95_latency=(
                        lats[min(len(lats) - 1, int(0.95 * len(lats)))]
                        if lats
                        else 0.0
                    ),
                    opened_at=d.opened_at,
                    probes_ok=d.probes_ok,
                )
            return out


@dataclass
class RetryPolicy:
    """Bounded retry with errno classification for raw storage ops.

    ``run(fn)`` retries ``fn`` while :func:`classify_error` (or the
    ``classify`` override) says the failure is transient, up to
    ``attempts`` total tries and a per-op wall-clock ``deadline``.
    Backoff is exponential from ``base_delay`` capped at ``max_delay``,
    with deterministic seeded jitter (multiplier in ``[1, 1+jitter]``).
    Sleeps go through ``CancelToken.wait`` when a token is passed, so a
    cancelled flush aborts mid-backoff with :class:`FlushCancelled`
    instead of sleeping out its schedule.

    Policy-level totals (``retries``/``giveups``) accumulate across all
    callers; per-call deltas go to the optional ``stats`` dict (keys
    ``"retries"``/``"giveups"``, updated under the policy lock) which
    the executor uses to fill :class:`FlushResult`/:class:`ReadResult`.

    When a :class:`StorageHealth` registry is attached (``health``) and
    the caller names its ``domain``, every attempt is gated by
    ``health.check(domain)`` — **before each try, including re-tries
    mid-backoff** — and every outcome is recorded.  That per-attempt
    gate is what makes an outage cheap: once concurrent failures trip
    the domain's breaker, every op still inside its retry schedule
    fails fast with :class:`CircuitOpenError` on its next attempt
    instead of sleeping out the budget and giving up.
    """

    attempts: int = 5
    base_delay: float = 0.02
    max_delay: float = 0.5
    deadline: float = 30.0
    jitter: float = 0.5
    seed: Optional[int] = None
    classify: Optional[Callable[[BaseException], str]] = None
    health: Optional[StorageHealth] = None

    def __post_init__(self) -> None:
        self._rng = random.Random(self.seed)
        self._lock = threading.Lock()
        self.retries = 0  # total sleeps taken before a re-attempt
        self.giveups = 0  # transient failures that exhausted the budget

    def _bump(self, key: str, stats: Optional[dict]) -> None:
        with self._lock:
            setattr(self, key, getattr(self, key) + 1)
            if stats is not None:
                stats[key] = stats.get(key, 0) + 1

    def run(
        self,
        fn: Callable,
        *,
        cancel: Optional[CancelToken] = None,
        stats: Optional[dict] = None,
        domain: Optional[str] = None,
    ):
        health = self.health if domain is not None else None
        t0 = time.monotonic()
        attempt = 0
        while True:
            if health is not None:
                health.check(domain)  # fail fast while the circuit is open
            t_att = time.monotonic()
            try:
                r = fn()
            except FlushCancelled:
                raise  # a scheduling outcome, never an I/O failure
            except CircuitOpenError:
                # a *nested* domain's breaker (our own check already
                # passed): propagate unrecorded — it is not an outcome
                # of this domain, and never worth a backoff
                raise
            except OSError as e:
                attempt += 1
                kind = (self.classify or classify_error)(e)
                if kind != "transient":
                    # ENOENT is a *correct answer* from a healthy medium
                    # — the fallback ladder probes for missing blobs all
                    # the time — so it must never charge the circuit
                    if health is not None and not isinstance(
                        e, FileNotFoundError
                    ):
                        health.record(domain, False)
                    raise
                elapsed = time.monotonic() - t0
                if attempt >= max(1, self.attempts) or elapsed >= self.deadline:
                    self._bump("giveups", stats)
                    if health is not None:
                        health.record(domain, False, giveup=True)
                    raise
                if health is not None:
                    health.record(domain, False)
                delay = min(self.max_delay, self.base_delay * (2 ** (attempt - 1)))
                with self._lock:
                    delay *= 1.0 + self.jitter * self._rng.random()
                delay = min(delay, max(0.0, self.deadline - elapsed))
                if cancel is not None:
                    if cancel.wait(delay):
                        raise FlushCancelled("cancelled while backing off")
                elif delay > 0:
                    time.sleep(delay)
                self._bump("retries", stats)
            else:
                if health is not None:
                    health.record(domain, True, time.monotonic() - t_att)
                return r


class TokenBucket:
    """Global token-bucket byte-rate limiter for executor writes.

    ``rate`` is bytes/second shared by *all* writer threads (one bucket
    per manager — the real-executor twin of the single extra capacity
    the simulator prices a ``flush_bw_cap`` as).  Requests may exceed
    ``burst``: a thread pays its bytes into the bucket debt and later
    acquirers wait until the debt refills, so arbitrarily large
    coalesced rows still observe the long-run rate.  ``acquire``
    returns the seconds it slept; a fired :class:`CancelToken` aborts
    the sleep with :class:`FlushCancelled`.
    """

    def __init__(self, rate: float, burst: Optional[float] = None):
        if rate <= 0:
            raise ValueError("TokenBucket rate must be positive")
        self.rate = float(rate)
        self.burst = float(burst) if burst else max(1 << 20, self.rate / 8)
        self._tokens = self.burst
        self._last = time.monotonic()
        self._lock = threading.Lock()
        self.wait_total = 0.0  # cumulative sleep across all acquirers

    def acquire(self, n: int, cancel: Optional[CancelToken] = None) -> float:
        if n <= 0:
            return 0.0
        waited = 0.0
        while True:
            with self._lock:
                now = time.monotonic()
                self._tokens = min(
                    self.burst, self._tokens + (now - self._last) * self.rate
                )
                self._last = now
                if self._tokens > 0:
                    self._tokens -= n  # may go negative: pay-ahead debt
                    self.wait_total += waited
                    return waited
                # the exact refill time is computable from the debt:
                # sleep it once instead of polling 0.25 s slices (the
                # loop re-checks only because a concurrent acquirer may
                # have deepened the debt meanwhile)
                delay = (1 - self._tokens) / self.rate
            if cancel is not None:
                if cancel.wait(delay):
                    raise FlushCancelled("cancelled while throttled")
            else:
                time.sleep(delay)
            waited += delay

    def set_rate(self, rate: float) -> None:
        """Retarget the refill rate in place (fair-share rebalances).

        Accrued tokens/debt are settled at the *old* rate first, so a
        tenant cannot bank the pre-rebalance rate into a burst, and the
        burst ceiling follows the constructor's sizing rule.
        """
        rate = max(1e-6, float(rate))
        with self._lock:
            now = time.monotonic()
            self._tokens = min(
                self.burst, self._tokens + (now - self._last) * self.rate
            )
            self._last = now
            self.rate = rate
            self.burst = max(1 << 20, rate / 8)
            self._tokens = min(self._tokens, self.burst)


def fair_share_rates(weights, demands, cap: float):
    """Weighted max-min (water-filling) split of one byte-rate cap.

    ``weights[i]`` is tenant *i*'s priority weight, ``demands[i]`` its
    offered rate (bytes/s it could use right now; 0 = idle).  Returns
    the granted rates: repeatedly hand every unsatisfied tenant its
    weighted share of the leftover cap, cap each grant at the tenant's
    remaining demand, and redistribute what saturated tenants returned.
    Invariants (the property-tested contract of the control plane's
    quota layer):

    - ``sum(granted) <= cap`` and ``granted[i] <= demands[i]``;
    - every backlogged tenant is granted ``> 0`` (no starvation) and
      at least its weighted share of ``cap`` unless its own demand is
      smaller;
    - idle tenants are granted exactly 0 — their share is fully
      redistributed, so ``sum(granted) == min(cap, sum(demands))``.
    """
    w = np.asarray(weights, dtype=np.float64)
    d = np.asarray(demands, dtype=np.float64)
    if w.shape != d.shape:
        raise ValueError("weights and demands must have the same length")
    r = np.zeros_like(d)
    if cap <= 0 or not len(d):
        return r
    active = (d > 0) & (w > 0)
    remaining = float(cap)
    # Each pass saturates >= 1 tenant or exhausts the cap: <= n passes.
    while remaining > 1e-12 * max(1.0, cap) and active.any():
        share = remaining * w[active] / w[active].sum()
        grant = np.minimum(share, d[active] - r[active])
        r[active] += grant
        remaining -= float(grant.sum())
        still = active & (r < d - 1e-9)
        if still.sum() == active.sum():
            break  # nobody saturated: every share was granted in full
        active = still
    return r


class TenantLimiter:
    """One tenant's leaf of a :class:`FairShareLimiter` hierarchy.

    Drop-in for :class:`TokenBucket` at the executor boundary — only
    ``acquire(n, cancel=)`` and ``wait_total`` are consumed there.  A
    charge pays two buckets in order: the tenant bucket (rate = the
    fair share the parent last granted) and the parent's root bucket
    (rate = the global cap), which bounds the aggregate during the
    window between a demand change and the next rebalance.
    """

    def __init__(self, parent: "FairShareLimiter", name: str, weight: float):
        self.parent = parent
        self.name = name
        self.weight = float(weight)
        self.backlog = 0  # offered-load signal, maintained by add/sub_demand
        self.bucket = TokenBucket(max(1e-6, parent.cap))
        self.wait_total = 0.0

    @property
    def rate(self) -> float:
        return self.bucket.rate

    def add_demand(self, n: int) -> None:
        self.parent._adjust_demand(self, int(n))

    def sub_demand(self, n: int) -> None:
        self.parent._adjust_demand(self, -int(n))

    def acquire(self, n: int, cancel: Optional[CancelToken] = None) -> float:
        if n <= 0:
            return 0.0
        # An acquire IS demand: a tenant that charges without having
        # declared a backlog (sync flushes, resumes) must not starve on
        # a stale zero-rate grant.
        if self.backlog <= 0:
            self.parent._adjust_demand(self, int(n))
        waited = self.bucket.acquire(n, cancel)
        waited += self.parent.root.acquire(n, cancel)
        self.wait_total += waited
        return waited


class FairShareLimiter:
    """Hierarchical token buckets: one global ``flush_bw_cap`` shared
    by N tenants, split by weighted fair share of the *backlogged*
    tenants (:func:`fair_share_rates` with demand = "wants the full
    cap" while a tenant has queued flush bytes, 0 when idle).

    Every demand transition rebalances the per-tenant bucket rates, so
    an idle tenant's share is redistributed immediately and returns to
    it on its next save.  The root bucket enforces the aggregate cap
    even mid-transition.  This is the real-runtime twin of
    ``sim.simulate_flush_shared``: both price tenant *i* exactly like a
    single-job ``flush_bw_cap`` equal to its granted share.
    """

    def __init__(self, cap: float):
        if cap <= 0:
            raise ValueError("FairShareLimiter cap must be positive")
        self.cap = float(cap)
        self.root = TokenBucket(self.cap)
        self._lock = threading.Lock()
        self._tenants: Dict[str, TenantLimiter] = {}

    def register(self, name: str, weight: float = 1.0) -> TenantLimiter:
        if weight <= 0:
            raise ValueError("tenant weight must be positive")
        with self._lock:
            if name in self._tenants:
                raise ValueError(f"tenant {name!r} already registered")
            t = TenantLimiter(self, name, weight)
            self._tenants[name] = t
            self._rebalance_locked()
        return t

    def unregister(self, name: str) -> None:
        with self._lock:
            self._tenants.pop(name, None)
            self._rebalance_locked()

    def rate_of(self, name: str) -> float:
        return self._tenants[name].rate

    def tenants(self) -> List[str]:
        with self._lock:
            return list(self._tenants)

    def _adjust_demand(self, t: TenantLimiter, delta: int) -> None:
        with self._lock:
            was = t.backlog > 0
            t.backlog = max(0, t.backlog + delta)
            if (t.backlog > 0) != was:
                self._rebalance_locked()

    def _rebalance_locked(self) -> None:
        ts = list(self._tenants.values())
        if not ts:
            return
        weights = [t.weight for t in ts]
        demands = [self.cap if t.backlog > 0 else 0.0 for t in ts]
        rates = fair_share_rates(weights, demands, self.cap)
        total_w = sum(weights)
        for t, r in zip(ts, rates):
            if r <= 0:
                # Idle standby trickle: first post-idle bytes flow at a
                # token share until the implicit-demand bump rebalances.
                r = self.cap * (t.weight / total_w) * 1e-3
            t.bucket.set_rate(r)


class FlushJournal:
    """Append-only columnar progress cursor for one step's flush.

    On-disk format: little-endian int64 triples ``(file_id,
    file_offset, size)``, one per completed destination extent —
    ``file_id`` indexes the manifest placement's ``file_names``.  The
    executor journals each row *after* its ``pwrite`` returns, buffered
    (``flush_every`` records) and fsynced on flush, so after a crash
    the journal only under-reports: every journaled extent is truly on
    the PFS — ``pre_sync`` (the executor passes a data-fd fsync) runs
    before each batch of records is persisted, so a record can never
    outlive a page-cache-only write through a power loss — and at most
    one buffer's worth of completed writes gets redone on resume.  A
    torn trailing record (process death mid-append) is truncated away
    on load.

    Coverage queries (:meth:`covers`) run against the extents loaded at
    construction, merged per file (``merge_intervals``) — the resume
    pass skips any write row whose destination interval is fully
    covered, regardless of how the original flush coalesced its rows.
    """

    RECORD = 24  # 3 x int64

    def __init__(
        self,
        path,
        flush_every: int = 32,
        *,
        fresh: bool = False,
        pre_sync: Optional[Callable[[], None]] = None,
    ):
        """``fresh=True`` discards any journal left on disk first — a
        *new* flush of a step must never inherit extents journaled by a
        previous incarnation of that step (different bytes!); only the
        resume path loads the existing cursor.  ``pre_sync`` runs
        before each batch of records is written (the executor fsyncs
        the data fds there) so the journal never claims durability the
        data does not have."""
        self.path = Path(path)
        self._flush_every = max(1, flush_every)
        self._buf: List[Tuple[int, int, int]] = []
        self._lock = threading.Lock()
        self.pre_sync = pre_sync
        if fresh:
            try:
                self.path.unlink()
            except FileNotFoundError:
                pass
        self.done = self._load(self.path)
        self._cov: Optional[Dict[int, Tuple[np.ndarray, np.ndarray]]] = None

    @staticmethod
    def _load(path: Path) -> np.ndarray:
        try:
            raw = path.read_bytes()
        except FileNotFoundError:
            return np.empty((0, 3), np.int64)
        n = len(raw) // FlushJournal.RECORD  # drop a torn trailing record
        if n == 0:
            return np.empty((0, 3), np.int64)
        return (
            np.frombuffer(raw[: n * FlushJournal.RECORD], dtype="<i8")
            .reshape(n, 3)
            .astype(np.int64)
        )

    @property
    def completed_bytes(self) -> int:
        """Journaled payload (may double-count overlapping rewrites)."""
        return int(self.done[:, 2].sum())

    def _coverage(self) -> Dict[int, Tuple[np.ndarray, np.ndarray]]:
        if self._cov is None:
            cov: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
            for f in np.unique(self.done[:, 0]).tolist():
                rows = self.done[self.done[:, 0] == f]
                start, size = merge_intervals(rows[:, 1], rows[:, 2])
                cov[int(f)] = (start, start + size)
            self._cov = cov
        return self._cov

    def covers(self, file_id: int, offset: int, size: int) -> bool:
        """True iff ``[offset, offset+size)`` of ``file_id`` is fully
        inside the journaled (merged) extents loaded at construction."""
        iv = self._coverage().get(int(file_id))
        if iv is None:
            return False
        start, end = iv
        i = int(np.searchsorted(start, offset, side="right")) - 1
        return i >= 0 and int(end[i]) >= offset + size

    def record(self, file_id: int, file_offset: int, size: int) -> None:
        with self._lock:
            self._buf.append((int(file_id), int(file_offset), int(size)))
            if len(self._buf) >= self._flush_every:
                self._flush_locked()

    def flush(self) -> None:
        with self._lock:
            self._flush_locked()

    def _flush_locked(self) -> None:
        if not self._buf:
            return
        if self.pre_sync is not None:
            self.pre_sync()  # data durability strictly before the claim
        arr = np.asarray(self._buf, dtype="<i8")
        with open(self.path, "ab") as f:
            f.write(arr.tobytes())
            f.flush()
            try:
                os.fsync(f.fileno())
            except OSError:  # pragma: no cover - fs without fsync
                pass
        self._buf.clear()

    def unlink(self) -> None:
        """Remove the journal (flush completed — the cursor is moot)."""
        with self._lock:
            self._buf.clear()
        try:
            self.path.unlink()
        except FileNotFoundError:
            pass


class LocalStore:
    """L1: per-node local directories (simulated node-local SSDs).

    ``faults`` is the deterministic injection surface (domains ``l1``
    for home blobs, ``partner`` for replicas); ``retry`` wraps every
    raw blob read/write so transient hiccups heal in place.  Read
    failures that survive retries are re-raised as structured
    :class:`StorageError`\\ s with ``(level, step, rank, path)``.
    """

    def __init__(
        self,
        root: Path,
        n_nodes: int,
        *,
        faults: Optional[FaultPlan] = None,
        retry: Optional[RetryPolicy] = None,
    ):
        self.root = Path(root)
        self.n_nodes = n_nodes
        self.faults = faults
        self.retry = retry
        # created-directory cache: the parallel local phase writes one
        # file per rank, and a paper-scale node dir must not pay a
        # mkdir round trip per blob
        self._dirs_seen: set = set()
        self._dirs_lock = threading.Lock()

    def _ensure_dir(self, d: Path) -> None:
        key = str(d)
        with self._dirs_lock:
            if key in self._dirs_seen:
                return
        d.mkdir(parents=True, exist_ok=True)
        with self._dirs_lock:
            self._dirs_seen.add(key)

    def _forget_dirs(self) -> None:
        with self._dirs_lock:
            self._dirs_seen.clear()

    def node_dir(self, node: int, step: int) -> Path:
        return self.root / f"node_{node:04d}" / f"step_{step:08d}"

    def blob_path(self, node: int, step: int, rank: int, partner: bool = False) -> Path:
        ext = "partner" if partner else "blob"
        return self.node_dir(node, step) / f"rank_{rank:06d}.{ext}"

    def write_blob(
        self, node: int, step: int, rank: int, data, *,
        partner: bool = False, sync: bool = True, atomic: bool = True,
    ) -> None:
        """Write one rank blob (any bytes-like buffer).

        ``sync=True`` (the seed behaviour) fsyncs the file;
        ``atomic=True`` (also the seed behaviour) writes through a tmp
        file + rename.  The parallel local phase passes both as False:
        the local *manifest* — replaced atomically after every blob
        landed — is the step's commit point, so a **process** crash
        mid-save leaves no manifest pointing at torn blobs.  Against
        node power loss this path is deliberately weaker than the seed
        (data blocks ride on OS writeback; :meth:`sync_dir` fsyncs
        directory metadata only): L1 is the level the multi-level
        ladder already assumes lost on node failure — partner replicas
        and the PFS level cover it, and restore CRC-checks every blob
        before trusting it.  Per-file power-loss durability remains
        available via the reference path (``parallel_local=False``).

        The atomic+sync path fsyncs the *parent directory* after the
        rename: ``os.replace`` alone leaves the new directory entry in
        volatile metadata, so without the dir fsync the blob could
        vanish across power loss even though its data blocks were
        synced — the rename itself must be made durable.
        """
        p = self.blob_path(node, step, rank, partner)
        self._ensure_dir(p.parent)
        domain = "partner" if partner else "l1"

        def _write(buf) -> None:
            if atomic:
                tmp = p.with_suffix(p.suffix + ".tmp")
                with open(tmp, "wb") as f:
                    f.write(buf)
                    f.flush()
                    if sync:
                        os.fsync(f.fileno())
                os.replace(tmp, p)
                if sync:
                    self._fsync_dir(p.parent)
            else:
                with open(p, "wb") as f:
                    f.write(buf)
                    f.flush()
                    if sync:
                        os.fsync(f.fileno())

        def attempt() -> None:
            inject_write(
                self.faults, domain, f"step{step}/rank{rank}", data, _write,
                node=node,
            )

        if self.retry is not None:
            self.retry.run(attempt, domain=f"{domain}:n{node}")
        else:
            attempt()

    @staticmethod
    def _fsync_dir(d: Path) -> None:
        """Directory-entry durability: fsync ``d`` through an fd."""
        try:
            fd = os.open(str(d), os.O_RDONLY)
        except OSError:
            return
        try:
            os.fsync(fd)
        except OSError:  # pragma: no cover - fs without dir fsync
            pass
        finally:
            os.close(fd)

    def sync_dir(self, node: int, step: int) -> None:
        """Batched metadata-durability point for one node's step
        directory: a single directory fsync covering every entry that
        landed there.  Blob *data* durability on the parallel path is
        explicitly entrusted to OS writeback + the level ladder (see
        :meth:`write_blob`); the per-file-fsync reference path keeps
        the seed's stronger guarantee."""
        self._fsync_dir(self.node_dir(node, step))

    def read_blob(
        self, node: int, step: int, rank: int, *, partner: bool = False
    ) -> bytes:
        p = self.blob_path(node, step, rank, partner)
        domain = "partner" if partner else "l1"

        def attempt() -> bytes:
            if self.faults is not None:
                self.faults.on_op(domain, "read", str(p), node=node)
            return p.read_bytes()

        try:
            if self.retry is not None:
                return self.retry.run(attempt, domain=f"{domain}:n{node}")
            return attempt()
        except OSError as e:
            raise wrap_storage_error(domain, step, rank, p, e) from e

    def read_slice(
        self, node: int, step: int, rank: int, offset: int, size: int,
        *, partner: bool = False,
    ) -> bytes:
        p = self.blob_path(node, step, rank, partner)
        domain = "partner" if partner else "l1"

        def attempt() -> bytes:
            if self.faults is not None:
                self.faults.on_op(domain, "read", str(p), node=node)
            with open(p, "rb") as f:
                f.seek(offset)
                return f.read(size)

        try:
            if self.retry is not None:
                return self.retry.run(attempt, domain=f"{domain}:n{node}")
            return attempt()
        except OSError as e:
            raise wrap_storage_error(domain, step, rank, p, e) from e

    def has_blob(self, node: int, step: int, rank: int, *, partner: bool = False) -> bool:
        return self.blob_path(node, step, rank, partner).exists()

    def drop_node(self, node: int, step: Optional[int] = None) -> None:
        """Simulate node-local storage loss (node failure)."""
        p = (
            self.root / f"node_{node:04d}"
            if step is None
            else self.node_dir(node, step)
        )
        if p.exists():
            shutil.rmtree(p)
        self._forget_dirs()

    def gc_step(self, step: int) -> None:
        for nd in self.root.glob("node_*"):
            p = nd / f"step_{step:08d}"
            if p.exists():
                shutil.rmtree(p)
        self._forget_dirs()


@dataclass
class FlushResult:
    step: int
    duration: float
    bytes_written: int
    n_writes: int
    failed: bool = False
    error: Optional[str] = None
    # adaptive-runtime telemetry: extents skipped because the progress
    # journal proved them already on the PFS (resume), and total seconds
    # writer threads slept in the rate limiter (throttle pressure).
    bytes_skipped: int = 0
    throttle_wait: float = 0.0
    # retry-layer telemetry: transient PFS-write failures healed by a
    # re-attempt, and ops that exhausted the retry budget anyway.
    io_retries: int = 0
    io_giveups: int = 0


@dataclass
class ReadResult:
    """Aggregate stats of one executed :class:`ReadPlan`."""

    step: int
    duration: float
    bytes_read: int
    n_reads: int
    n_readers: int
    io_retries: int = 0
    io_giveups: int = 0
    # tail-robustness telemetry: hedge requests issued past the latency
    # deadline, and how many beat their primary to the buffer.
    hedges_issued: int = 0
    hedge_wins: int = 0


@dataclass
class HedgePolicy:
    """Deadline-aware read hedging for :meth:`RealExecutor.execute_read_plan`.

    When a pread has been in flight longer than the hedge deadline —
    the ``quantile`` of latencies observed so far in this plan (seeded
    from the health registry's PFS history when attached), floored at
    ``min_delay_s`` — the extent is re-issued through ``alt_read`` (the
    engine maps it back to the L1/partner copy, ordered by health).
    First success wins and claims the destination; the loser's bytes
    are discarded (a blocking ``pread`` cannot be interrupted, so
    "cancellation" is claim-or-discard at the buffer boundary).
    Hedge *failures* are silent: hedging may only ever help the tail,
    never fail a plan the primary path would have completed.
    """

    alt_read: Callable[[int, int, int], Optional[bytes]]
    quantile: float = 0.95
    min_delay_s: float = 0.02
    poll_s: float = 0.005
    max_hedges: int = 16
    min_samples: int = 4  # latency samples needed before quantile kicks in


class RealExecutor:
    """Executes a FlushPlan against files under ``pfs_dir``.

    The write hot path iterates :class:`~repro.core.plan.PlanArrays`
    columns directly (mirroring :meth:`execute_read_plan`) — the lazy
    ``WriteItem`` dataclass lists are never materialized unless a
    ``fault_hook`` needs the item view — and all batches, rounds and
    steps share **one persistent thread pool** instead of constructing a
    fresh ``ThreadPoolExecutor`` per round.  Adjacent writes that are
    contiguous in both the source blob and the destination file coalesce
    into a single L1 pread + PFS pwrite before being issued.  The seed
    item-loop executor survives as :meth:`execute_reference`, the
    executable spec the byte-identical-files test holds this path to.
    """

    def __init__(
        self,
        pfs_dir: Path,
        local: LocalStore,
        *,
        io_threads: int = 2,
        fault_hook: Optional[Callable[[WriteItem], None]] = None,
        faults: Optional[FaultPlan] = None,
        retry: Optional[RetryPolicy] = None,
    ):
        self.pfs_dir = Path(pfs_dir)
        self.local = local
        self.io_threads = max(1, io_threads)
        self.fault_hook = fault_hook
        self.faults = faults  # deterministic injection (domain "pfs")
        self.retry = retry  # transient-retry wrap for pwrites/preads
        self._pool: Optional[ThreadPoolExecutor] = None
        self._pool_lock = threading.Lock()

    # ---- shared worker pool ----------------------------------------------

    POOL_CAP = 16  # the global worker cap every sizing heuristic min()s with

    def pool(self, workers: int = POOL_CAP) -> ThreadPoolExecutor:
        """The persistent shared worker pool, reused across rounds,
        batches, steps and read plans.  Created **once**, sized at the
        global cap (or the first caller's larger request), and never
        replaced — concurrent holders (an in-flight flush, a save()'s
        local phase) must never have their pool shut down under them.
        Per-call ``workers`` below the cap only decides inline-vs-pool
        execution in :meth:`_run_rows`, not pool size."""
        with self._pool_lock:
            if self._pool is None:
                self._pool = ThreadPoolExecutor(
                    max_workers=max(self.POOL_CAP, int(workers)),
                    thread_name_prefix="ckpt-io",
                )
            return self._pool

    def close(self) -> None:
        with self._pool_lock:
            if self._pool is not None:
                self._pool.shutdown(wait=True)
                self._pool = None

    def step_dir(self, step: int) -> Path:
        return self.pfs_dir / f"step_{step:08d}"

    def execute(
        self,
        plan: FlushPlan,
        step: int,
        *,
        cancel: Optional[CancelToken] = None,
        limiter: Optional[TokenBucket] = None,
        journal: Optional[FlushJournal] = None,
    ) -> FlushResult:
        """Run a flush plan.  ``cancel`` is polled at safe request
        boundaries (raising :class:`FlushCancelled` between writes),
        ``limiter`` throttles writer bytes through the shared token
        bucket, and ``journal`` both *skips* destination extents it
        already covers (resume) and records each completed write."""
        pa = plan.ensure_arrays()
        # Coalesce adjacent same-source reads: rows contiguous in both
        # (src_rank, src_offset) and (file, file_offset) become one
        # pread + one pwrite (pipeline-chunked and multi-round plans
        # split one rank's bytes into many such rows).
        w = coalesce_write_columns(pa.writes)
        homes = plan.cluster.nodes_of_ranks(w.src_rank)
        # Global worker pool == work stealing across backends: idle
        # backends' threads drain the shared queue (the straggler
        # mitigation used by our §3 implementation; see DESIGN.md).
        n_backends = len(np.unique(w.backend)) or 1
        workers = min(16, self.io_threads * n_backends)
        return self._execute_columns(
            plan.files, pa.file_names, w, homes, step,
            workers=workers, barrier_per_round=plan.barrier_per_round,
            cancel=cancel, limiter=limiter, journal=journal,
        )

    def execute_resume(
        self,
        manifest: Manifest,
        step: int,
        *,
        cancel: Optional[CancelToken] = None,
        limiter: Optional[TokenBucket] = None,
        journal: Optional[FlushJournal] = None,
    ) -> FlushResult:
        """Finish an interrupted flush from its persisted placement.

        A ``flush_partial`` manifest already carries the full write set
        (columnar :class:`~repro.core.serialize.Placement` — the same
        rows the original plan coalesced from) and its file size table,
        so resume needs no strategy re-run: rows are rebuilt straight
        from the placement columns, rows whose destination extents the
        ``journal`` covers are skipped, and only the remainder is read
        from L1 and rewritten.  Rows are deliberately **not**
        re-coalesced: placement rows are at least as fine as anything
        the original flush journaled (coalescing merges rows, never
        splits them), so every fully-flushed extent skips exactly —
        re-merging across what were different backends/rounds would
        glue flushed and unflushed extents into one row and force its
        rewrite.  Round barriers are irrelevant on resume (destinations
        are disjoint and writes idempotent), so the remainder runs as
        one free-running batch.
        """
        pl = manifest.placement
        homes_src = pl.rank // max(1, manifest.procs_per_node)
        w = WriteColumns(
            backend=homes_src,
            file_id=pl.file_id,
            file_offset=pl.file_offset,
            size=pl.size,
            src_rank=pl.rank,
            src_offset=pl.src_offset,
            round=np.zeros(len(pl.rank), np.int64),
        )
        homes = w.backend  # backend == the source rank's home node here
        workers = min(16, self.io_threads * (len(np.unique(w.backend)) or 1))
        return self._execute_columns(
            dict(manifest.files), list(pl.file_names), w, homes, step,
            workers=workers, barrier_per_round=False,
            cancel=cancel, limiter=limiter, journal=journal,
        )

    def _execute_columns(
        self,
        files: Dict[str, int],
        names: Sequence[str],
        w: WriteColumns,
        homes: np.ndarray,
        step: int,
        *,
        workers: int,
        barrier_per_round: bool,
        cancel: Optional[CancelToken] = None,
        limiter: Optional[TokenBucket] = None,
        journal: Optional[FlushJournal] = None,
    ) -> FlushResult:
        """Shared column runner behind :meth:`execute` and
        :meth:`execute_resume`: open+size the files, stream the rows
        through the persistent pool, fsync on success.  ``ftruncate``
        to an unchanged size preserves existing contents, so re-opening
        a partially flushed step never clobbers resumed extents."""
        t0 = time.perf_counter()
        sdir = self.step_dir(step)
        sdir.mkdir(parents=True, exist_ok=True)

        # Pre-create + size every file (the metadata phase).
        fds: Dict[str, int] = {}
        try:
            for fname, size in files.items():
                path = sdir / fname
                fd = os.open(str(path), os.O_CREAT | os.O_WRONLY, 0o644)
                os.ftruncate(fd, size)
                fds[fname] = fd
            if journal is not None:
                # a journal record is a durability claim: fsync the data
                # fds before any batch of records is persisted
                journal.pre_sync = lambda: [os.fsync(f) for f in fds.values()]

            lock = threading.Lock()
            total = {"bytes": 0, "writes": 0, "skipped": 0, "throttle": 0.0}
            retry_stats = {"retries": 0, "giveups": 0}
            hook = self.fault_hook

            def do_write(row: Tuple[int, ...]) -> None:
                backend, fid, foff, size, src_rank, soff, rnd, home = row
                if cancel is not None and cancel.cancelled:
                    # safe request boundary: nothing of this row started
                    raise FlushCancelled(f"step {step}: flush cancelled")
                if journal is not None and journal.covers(fid, foff, size):
                    with lock:
                        total["skipped"] += size
                    return
                if hook is not None:
                    # fault-injection surface: materialize the item view
                    # for this row only (never a whole-plan list)
                    hook(WriteItem(backend=backend, file=names[fid],
                                   file_offset=foff, size=size,
                                   src_rank=src_rank, src_offset=soff,
                                   round=rnd))
                waited = (
                    limiter.acquire(size, cancel=cancel)
                    if limiter is not None else 0.0
                )
                # leader pulls from the source node's L1 file ("the
                # send"); if the home node's copy is gone (node loss),
                # the partner replica on node+1 — the same invariant
                # restore uses — keeps the flush finishable
                try:
                    data = self.local.read_slice(
                        home, step, src_rank, soff, size
                    )
                except OSError:
                    partner = (home + 1) % max(1, self.local.n_nodes)
                    data = self.local.read_slice(
                        partner, step, src_rank, soff, size, partner=True
                    )
                if len(data) != size:
                    raise IOError(
                        f"short read: rank {src_rank} [{soff}:{soff + size})"
                    )

                def attempt() -> None:
                    # the injection + pwrite is the retried unit: a torn
                    # write's re-attempt rewrites the full extent
                    inject_write(
                        self.faults, "pfs", f"{names[fid]}@{foff}", data,
                        lambda buf: os.pwrite(fds[names[fid]], buf, foff),
                    )

                if self.retry is not None:
                    self.retry.run(
                        attempt, cancel=cancel, stats=retry_stats, domain="pfs"
                    )
                else:
                    attempt()
                if journal is not None:
                    journal.record(fid, foff, size)
                with lock:
                    total["bytes"] += size
                    total["writes"] += 1
                    total["throttle"] += waited

            rows = list(zip(
                w.backend.tolist(), w.file_id.tolist(),
                w.file_offset.tolist(), w.size.tolist(),
                w.src_rank.tolist(), w.src_offset.tolist(),
                w.round.tolist(), homes.tolist(),
            ))
            if barrier_per_round and len(rows) > 1:
                order = np.argsort(w.round, kind="stable")
                rnds = w.round[order]
                starts = np.flatnonzero(
                    np.concatenate(([True], rnds[1:] != rnds[:-1]))
                ).tolist()
                ordered = [rows[i] for i in order.tolist()]
                for b0, b1 in zip(starts, starts[1:] + [len(ordered)]):
                    self._run_rows(ordered[b0:b1], do_write, workers)
            else:
                self._run_rows(rows, do_write, workers)

            for fd in fds.values():
                os.fsync(fd)
            return FlushResult(
                step=step,
                duration=time.perf_counter() - t0,
                bytes_written=total["bytes"],
                n_writes=total["writes"],
                bytes_skipped=total["skipped"],
                throttle_wait=total["throttle"],
                io_retries=retry_stats["retries"],
                io_giveups=retry_stats["giveups"],
            )
        finally:
            if journal is not None:
                # persist whatever completed — cancellation/failure paths
                # rely on the journal under-reporting, never losing rows
                try:
                    journal.flush()
                finally:
                    journal.pre_sync = None  # fds close right below
            for fd in fds.values():
                try:
                    os.close(fd)
                except OSError:
                    pass

    def _run_rows(
        self, rows: List, fn: Callable, workers: int
    ) -> None:
        """Run one barrier batch through the persistent pool.

        On a worker exception every outstanding future is cancelled and
        the loop still drains to completion before re-raising: with a
        pool that outlives the batch, abandoning in-flight tasks would
        let them pwrite through fds the caller is about to close (and
        the OS may reuse for the next step's files)."""
        if not rows:
            return
        if workers <= 1 or len(rows) == 1:
            for r in rows:
                fn(r)
            return
        pool = self.pool(workers)
        futs = [pool.submit(fn, r) for r in rows]
        first_err: Optional[BaseException] = None
        for f in as_completed(futs):
            try:
                f.result()
            except BaseException as e:
                if first_err is None:
                    first_err = e
                    for g in futs:
                        g.cancel()
                # subsequent failures/cancellations: drain silently
        if first_err is not None:
            raise first_err

    # ---- seed executor (executable spec) ---------------------------------

    def execute_reference(self, plan: FlushPlan, step: int) -> FlushResult:
        """The seed item-loop executor, kept verbatim: materializes
        ``plan.writes``, spins up a fresh ``ThreadPoolExecutor`` per
        round, no coalescing.  tests/test_save_phase.py proves
        :meth:`execute` produces byte-identical files."""
        t0 = time.perf_counter()
        sdir = self.step_dir(step)
        sdir.mkdir(parents=True, exist_ok=True)

        fds: Dict[str, int] = {}
        try:
            for fname, size in plan.files.items():
                path = sdir / fname
                fd = os.open(str(path), os.O_CREAT | os.O_WRONLY, 0o644)
                os.ftruncate(fd, size)
                fds[fname] = fd

            cluster = plan.cluster
            lock = threading.Lock()
            total = {"bytes": 0, "writes": 0}

            def do_write(w: WriteItem) -> None:
                if self.fault_hook is not None:
                    self.fault_hook(w)
                home = cluster.node_of_rank(w.src_rank)
                data = self.local.read_slice(home, step, w.src_rank, w.src_offset, w.size)
                if len(data) != w.size:
                    raise IOError(
                        f"short read: rank {w.src_rank} [{w.src_offset}:"
                        f"{w.src_offset + w.size})"
                    )
                os.pwrite(fds[w.file], data, w.file_offset)
                with lock:
                    total["bytes"] += w.size
                    total["writes"] += 1

            n_backends = len({w.backend for w in plan.writes}) or 1
            workers = min(16, self.io_threads * n_backends)

            if plan.barrier_per_round:
                by_round: Dict[int, List[WriteItem]] = {}
                for w in plan.writes:
                    by_round.setdefault(w.round, []).append(w)
                for rnd in sorted(by_round):
                    self._run_batch(by_round[rnd], do_write, workers)
            else:
                self._run_batch(list(plan.writes), do_write, workers)

            for fd in fds.values():
                os.fsync(fd)
            return FlushResult(
                step=step,
                duration=time.perf_counter() - t0,
                bytes_written=total["bytes"],
                n_writes=total["writes"],
            )
        finally:
            for fd in fds.values():
                try:
                    os.close(fd)
                except OSError:
                    pass

    @staticmethod
    def _run_batch(
        batch: List[WriteItem],
        fn: Callable[[WriteItem], None],
        workers: int,
    ) -> None:
        if not batch:
            return
        if workers <= 1 or len(batch) == 1:
            for w in batch:
                fn(w)
            return
        with ThreadPoolExecutor(max_workers=workers) as ex:
            futs = [ex.submit(fn, w) for w in batch]
            for f in as_completed(futs):
                f.result()  # re-raise worker exceptions

    # ---- read side --------------------------------------------------------

    def execute_read_plan(
        self, rp: ReadPlan, step: int,
        *, on_request: Optional[Callable[[int, bytearray], None]] = None,
        hedge: Optional[HedgePolicy] = None,
    ) -> Tuple[List[bytearray], ReadResult]:
        """Run a :class:`ReadPlan` as ranged ``pread``s via the thread pool.

        Returns one buffer per request (``rp.req_size[i]`` bytes each)
        plus aggregate stats.  The worker-pool sizing mirrors the write
        side: idle readers steal from the shared queue, so one straggling
        consumer node does not serialize the restore.  Short reads raise
        ``IOError`` — corruption is then surfaced by the caller's CRC
        check, truncation right here.

        ``on_request(req_idx, buf)``, when given, fires on the worker
        thread that completes the *last* read of each request — the
        engine hangs arrival CRC verification here, so integrity checks
        of early blobs overlap the preads of later ones instead of
        running as a serial pass after the plan drains.  Exceptions it
        raises fail the plan like read errors.  Requests needing zero
        reads (zero-size, or none mapped) fire before the preads start.

        ``hedge``, when given, arms deadline-aware tail hedging: a
        watchdog re-issues any extent whose pread outlives the rolling
        latency-quantile deadline through ``hedge.alt_read`` — first
        success claims the destination buffer, the loser is discarded
        (see :class:`HedgePolicy`).
        """
        t0 = time.perf_counter()
        sdir = self.step_dir(step)
        bufs = [bytearray(int(n)) for n in rp.req_size.tolist()]
        r = rp.reads
        remaining = np.bincount(
            r.dst_req, minlength=rp.n_requests
        ).astype(np.int64) if len(r) else np.zeros(rp.n_requests, np.int64)
        if on_request is not None:
            for q in np.flatnonzero(remaining == 0).tolist():
                on_request(q, bufs[q])
        if not len(r):
            return bufs, ReadResult(
                step=step, duration=time.perf_counter() - t0,
                bytes_read=0, n_reads=0, n_readers=0,
            )
        fds: Dict[int, int] = {}
        lock = threading.Lock()
        total = {
            "bytes": 0, "reads": 0, "hedges": 0, "hedge_wins": 0,
            "claimed": 0,
        }
        retry_stats = {"retries": 0, "giveups": 0}
        health = self.retry.health if self.retry is not None else None
        rows = list(
            zip(
                r.file_id.tolist(), r.file_offset.tolist(), r.size.tolist(),
                r.dst_req.tolist(), r.dst_offset.tolist(), r.reader.tolist(),
            )
        )
        # per-row race state (hedging): start time, winner claim, done
        starts: Dict[int, float] = {}
        claimed = [False] * len(rows)
        finished = [False] * len(rows)
        hedged = [False] * len(rows)
        lat_samples: List[float] = (
            [health.latency_quantile("pfs", 0.5)]
            if health is not None and health.latency_quantile("pfs", 0.5) > 0
            else []
        )
        stop = threading.Event()
        all_claimed = threading.Event()
        hedge_threads: List[threading.Thread] = []

        def complete(i: int, row, data, *, won_hedge: bool) -> bool:
            """Claim row ``i`` for this result; the winner fills the
            destination and fires request completion.  Returns False if
            the other side already won (loser's bytes discarded)."""
            fid, foff, size, req, doff, reader = row
            with lock:
                if claimed[i]:
                    finished[i] = True
                    return False
                claimed[i] = True
                finished[i] = True
            bufs[req][doff : doff + size] = data
            with lock:
                total["bytes"] += size
                total["reads"] += 1
                if won_hedge:
                    total["hedge_wins"] += 1
                total["claimed"] += 1
                if total["claimed"] == len(rows):
                    all_claimed.set()  # plan complete: stop waiting on losers
                remaining[req] -= 1
                done = on_request is not None and remaining[req] == 0
            if done:
                on_request(req, bufs[req])
            return True

        def do_read(item) -> None:
            i, row = item
            fid, foff, size, req, doff, reader = row
            with lock:
                if claimed[i]:  # hedge already won while we queued
                    finished[i] = True
                    return
                starts[i] = time.monotonic()

            def attempt() -> bytes:
                if self.faults is not None:
                    self.faults.on_op(
                        "pfs", "read", rp.file_names[fid], node=reader
                    )
                return os.pread(fds[fid], size, foff)

            try:
                data = (
                    self.retry.run(attempt, stats=retry_stats, domain="pfs")
                    if self.retry is not None
                    else attempt()
                )
            except OSError:
                with lock:
                    finished[i] = True
                    if claimed[i]:
                        return  # the hedge already delivered this extent
                raise
            dt = time.monotonic() - starts[i]
            if len(data) != size:
                with lock:
                    finished[i] = True
                raise IOError(
                    f"short PFS read: {rp.file_names[fid]} "
                    f"[{foff}:{foff + size})"
                )
            if health is not None:
                health.note_latency(f"reader:n{reader}", dt)
            with lock:
                lat_samples.append(dt)
            complete(i, row, data, won_hedge=False)

        def run_hedge(i: int, row) -> None:
            fid, foff, size, req, doff, reader = row
            with lock:
                if claimed[i]:
                    return
            try:
                data = hedge.alt_read(fid, foff, size)
            except Exception:
                return  # hedge may only help, never hurt
            if data is None or len(data) != size:
                return
            complete(i, row, data, won_hedge=True)

        def watchdog() -> None:
            while not stop.wait(hedge.poll_s):
                now = time.monotonic()
                fire: List[int] = []
                with lock:
                    if total["hedges"] >= hedge.max_hedges:
                        return
                    if len(lat_samples) >= hedge.min_samples:
                        arr = sorted(lat_samples)
                        q = arr[min(len(arr) - 1, int(hedge.quantile * len(arr)))]
                        deadline = max(hedge.min_delay_s, q)
                    else:
                        deadline = hedge.min_delay_s
                    for i, t_start in starts.items():
                        if (
                            not finished[i]
                            and not hedged[i]
                            and now - t_start > deadline
                            and total["hedges"] < hedge.max_hedges
                        ):
                            hedged[i] = True
                            total["hedges"] += 1
                            fire.append(i)
                for i in fire:
                    th = threading.Thread(
                        target=run_hedge, args=(i, rows[i]), daemon=True
                    )
                    th.start()
                    hedge_threads.append(th)

        stragglers: List = []
        try:
            for f in np.unique(r.file_id).tolist():
                fds[f] = os.open(str(sdir / rp.file_names[f]), os.O_RDONLY)
            n_readers = len(np.unique(r.reader))
            workers = min(16, self.io_threads * max(1, n_readers))
            mon: Optional[threading.Thread] = None
            if hedge is not None:
                mon = threading.Thread(target=watchdog, daemon=True)
                mon.start()
            items = list(enumerate(rows))
            try:
                if hedge is None or workers <= 1 or len(items) == 1:
                    self._run_rows(items, do_read, workers)
                else:
                    # claim-aware variant of _run_rows: the plan returns
                    # as soon as every row is *claimed* (by its primary
                    # pread or a winning hedge) — a stragglered loser
                    # keeps running in the background, its bytes are
                    # discarded at the claim boundary, and the fds stay
                    # open until it returns (deferred close below).
                    pool = self.pool(workers)
                    futs = [pool.submit(do_read, it) for it in items]
                    first_err: Optional[BaseException] = None
                    pending = set(futs)
                    while pending:
                        done, pending = futures_wait(
                            pending, timeout=hedge.poll_s
                        )
                        for f in done:
                            try:
                                f.result()
                            except BaseException as e:
                                if first_err is None:
                                    first_err = e
                                    for g in futs:
                                        g.cancel()
                        if first_err is None and all_claimed.is_set():
                            stragglers = [
                                f for f in pending if not f.cancel()
                            ]
                            pending = set()
                    if first_err is not None:
                        raise first_err
            finally:
                stop.set()
                if mon is not None:
                    mon.join()
                for th in hedge_threads:
                    th.join()
            return bufs, ReadResult(
                step=step,
                duration=time.perf_counter() - t0,
                bytes_read=total["bytes"],
                n_reads=total["reads"],
                n_readers=n_readers,
                io_retries=retry_stats["retries"],
                io_giveups=retry_stats["giveups"],
                hedges_issued=total["hedges"],
                hedge_wins=total["hedge_wins"],
            )
        finally:
            if stragglers:
                # the losing preads still hold these fds; closing now
                # would hand their fd numbers to the next step's files.
                # A waiter owns the close instead — result() also
                # swallows the losers' post-claim exceptions.
                def _close_after(fs=list(stragglers), fdmap=dict(fds)):
                    for f in fs:
                        try:
                            f.result()
                        except BaseException:
                            pass
                    for fd in fdmap.values():
                        try:
                            os.close(fd)
                        except OSError:
                            pass

                threading.Thread(target=_close_after, daemon=True).start()
            else:
                for fd in fds.values():
                    try:
                        os.close(fd)
                    except OSError:
                        pass

    def read_rank_blob(
        self, manifest: Manifest, step: int, rank: int,
        layout: Optional["FileLayout"] = None,
    ) -> bytes:
        """Reassemble one rank's stored blob from the PFS placement.

        Kept as the single-rank convenience view; it is now a one-request
        :class:`ReadPlan` so the ranged-pread path is the only read path.
        Callers looping over many ranks should pass a pre-built
        ``layout`` (``manifest.file_layout()``) — or better, batch the
        ranks into one plan — instead of re-inverting the placement per
        call.
        """
        offsets = manifest.stored_offsets()
        rp = build_read_plan(
            layout if layout is not None else manifest.file_layout(),
            [int(offsets[rank])],
            [manifest.ranks[rank].stored_size],
        )
        bufs, _ = self.execute_read_plan(rp, step)
        return bytes(bufs[0])

    # ---- health probes -----------------------------------------------------

    def probe_pfs(self, payload: bytes = b"\x00" * 16) -> float:
        """One **single-attempt** write+readback through the ``pfs``
        fault surface — the half-open circuit's probe op.

        Deliberately unretried and unthrottled: a probe answers "is the
        domain back?" and must fail in one op if it is not.  Returns
        the op latency in seconds; raises the underlying ``OSError``
        on failure (the caller records the outcome into
        :class:`StorageHealth`).
        """
        self.pfs_dir.mkdir(parents=True, exist_ok=True)
        path = self.pfs_dir / ".health_probe"
        t0 = time.monotonic()
        inject_write(
            self.faults, "pfs", "health_probe", payload,
            lambda buf: path.write_bytes(bytes(buf)),
        )
        if self.faults is not None:
            self.faults.on_op("pfs", "read", "health_probe")
        if path.read_bytes() != bytes(payload):
            raise IOError("health probe readback mismatch")
        return time.monotonic() - t0


def placement_from_plan(plan: FlushPlan) -> Placement:
    """Columnar :class:`~repro.core.serialize.Placement` of the plan's
    write set — five int64 column copies, no per-item Python loop, and
    JSON-encodes as flat lists (the 32k-rank manifest fix)."""
    pa = plan.ensure_arrays()
    w = pa.writes
    return Placement(
        file_names=list(pa.file_names),
        rank=w.src_rank.copy(),
        file_id=w.file_id.copy(),
        file_offset=w.file_offset.copy(),
        src_offset=w.src_offset.copy(),
        size=w.size.copy(),
    )
