"""Real filesystem executor: runs FlushPlans against actual files.

Directory layout (``root`` is the checkpoint root):

.. code-block:: text

    root/
      local/node_{j}/step_{s}/rank_{r}.blob      # L1 node-local files
      local/node_{j}/step_{s}/rank_{r}.partner   # optional peer replica
      local/manifests/step_{s}.json              # manifest @ local_done
      pfs/step_{s}/<plan files>                  # L2 aggregated/unaggregated
      pfs/step_{s}/manifest.json                 # manifest @ flush_done

"Network sends" in a single-process harness are leader-side reads of the
source node's L1 file — the executor never touches the in-memory blobs
during the flush, so the flush path exercises exactly what a distributed
deployment would: node-local read -> (ship) -> pwrite at the planned
offset of the shared file.

Fault injection: ``fault_hook(write_item)`` may raise to simulate an
active-backend crash mid-flush; partially written PFS state is left
behind with the manifest still at ``local_done`` — restart logic must
(and does, see tests) fall back to L1.

The read side mirrors the write side: :meth:`RealExecutor.
execute_read_plan` runs a columnar :class:`~repro.core.plan.ReadPlan`
as ranged ``pread``\\ s through the same work-stealing thread pool, so
aggregated checkpoints are *read* as aggregated files — full elastic
restores, reshards and partial (per-leaf) restores all go through one
plan instead of per-rank whole-blob loops.
"""
from __future__ import annotations

import os
import shutil
import threading
import time
from concurrent.futures import ThreadPoolExecutor, as_completed
from dataclasses import dataclass
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.plan import (
    FileLayout,
    FlushPlan,
    ReadPlan,
    WriteItem,
    build_read_plan,
)
from repro.core.serialize import Manifest


class LocalStore:
    """L1: per-node local directories (simulated node-local SSDs)."""

    def __init__(self, root: Path, n_nodes: int):
        self.root = Path(root)
        self.n_nodes = n_nodes

    def node_dir(self, node: int, step: int) -> Path:
        return self.root / f"node_{node:04d}" / f"step_{step:08d}"

    def blob_path(self, node: int, step: int, rank: int, partner: bool = False) -> Path:
        ext = "partner" if partner else "blob"
        return self.node_dir(node, step) / f"rank_{rank:06d}.{ext}"

    def write_blob(
        self, node: int, step: int, rank: int, data: bytes, *, partner: bool = False
    ) -> None:
        p = self.blob_path(node, step, rank, partner)
        p.parent.mkdir(parents=True, exist_ok=True)
        tmp = p.with_suffix(p.suffix + ".tmp")
        with open(tmp, "wb") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, p)

    def read_blob(
        self, node: int, step: int, rank: int, *, partner: bool = False
    ) -> bytes:
        return self.blob_path(node, step, rank, partner).read_bytes()

    def read_slice(
        self, node: int, step: int, rank: int, offset: int, size: int,
        *, partner: bool = False,
    ) -> bytes:
        with open(self.blob_path(node, step, rank, partner), "rb") as f:
            f.seek(offset)
            return f.read(size)

    def has_blob(self, node: int, step: int, rank: int, *, partner: bool = False) -> bool:
        return self.blob_path(node, step, rank, partner).exists()

    def drop_node(self, node: int, step: Optional[int] = None) -> None:
        """Simulate node-local storage loss (node failure)."""
        p = (
            self.root / f"node_{node:04d}"
            if step is None
            else self.node_dir(node, step)
        )
        if p.exists():
            shutil.rmtree(p)

    def gc_step(self, step: int) -> None:
        for nd in self.root.glob("node_*"):
            p = nd / f"step_{step:08d}"
            if p.exists():
                shutil.rmtree(p)


@dataclass
class FlushResult:
    step: int
    duration: float
    bytes_written: int
    n_writes: int
    failed: bool = False
    error: Optional[str] = None


@dataclass
class ReadResult:
    """Aggregate stats of one executed :class:`ReadPlan`."""

    step: int
    duration: float
    bytes_read: int
    n_reads: int
    n_readers: int


class RealExecutor:
    """Executes a FlushPlan against files under ``pfs_dir``."""

    def __init__(
        self,
        pfs_dir: Path,
        local: LocalStore,
        *,
        io_threads: int = 2,
        fault_hook: Optional[Callable[[WriteItem], None]] = None,
    ):
        self.pfs_dir = Path(pfs_dir)
        self.local = local
        self.io_threads = max(1, io_threads)
        self.fault_hook = fault_hook

    def step_dir(self, step: int) -> Path:
        return self.pfs_dir / f"step_{step:08d}"

    def execute(self, plan: FlushPlan, step: int) -> FlushResult:
        t0 = time.perf_counter()
        sdir = self.step_dir(step)
        sdir.mkdir(parents=True, exist_ok=True)

        # Pre-create + size every file (the metadata phase).
        fds: Dict[str, int] = {}
        try:
            for fname, size in plan.files.items():
                path = sdir / fname
                fd = os.open(str(path), os.O_CREAT | os.O_WRONLY, 0o644)
                os.ftruncate(fd, size)
                fds[fname] = fd

            cluster = plan.cluster
            lock = threading.Lock()
            total = {"bytes": 0, "writes": 0}

            def do_write(w: WriteItem) -> None:
                if self.fault_hook is not None:
                    self.fault_hook(w)
                home = cluster.node_of_rank(w.src_rank)
                # leader pulls from the source node's L1 file ("the send")
                data = self.local.read_slice(home, step, w.src_rank, w.src_offset, w.size)
                if len(data) != w.size:
                    raise IOError(
                        f"short read: rank {w.src_rank} [{w.src_offset}:"
                        f"{w.src_offset + w.size})"
                    )
                os.pwrite(fds[w.file], data, w.file_offset)
                with lock:
                    total["bytes"] += w.size
                    total["writes"] += 1

            # Global worker pool == work stealing across backends: idle
            # backends' threads drain the shared queue (the straggler
            # mitigation used by our §3 implementation; see DESIGN.md).
            if plan.arrays is not None:
                n_backends = len(np.unique(plan.arrays.writes.backend)) or 1
            else:
                n_backends = len({w.backend for w in plan.writes}) or 1
            workers = min(16, self.io_threads * n_backends)

            if plan.barrier_per_round:
                by_round: Dict[int, List[WriteItem]] = {}
                for w in plan.writes:
                    by_round.setdefault(w.round, []).append(w)
                for rnd in sorted(by_round):
                    self._run_batch(by_round[rnd], do_write, workers)
            else:
                self._run_batch(list(plan.writes), do_write, workers)

            for fd in fds.values():
                os.fsync(fd)
            return FlushResult(
                step=step,
                duration=time.perf_counter() - t0,
                bytes_written=total["bytes"],
                n_writes=total["writes"],
            )
        finally:
            for fd in fds.values():
                try:
                    os.close(fd)
                except OSError:
                    pass

    @staticmethod
    def _run_batch(
        batch: List[WriteItem],
        fn: Callable[[WriteItem], None],
        workers: int,
    ) -> None:
        if not batch:
            return
        if workers <= 1 or len(batch) == 1:
            for w in batch:
                fn(w)
            return
        with ThreadPoolExecutor(max_workers=workers) as ex:
            futs = [ex.submit(fn, w) for w in batch]
            for f in as_completed(futs):
                f.result()  # re-raise worker exceptions

    # ---- read side --------------------------------------------------------

    def execute_read_plan(
        self, rp: ReadPlan, step: int
    ) -> Tuple[List[bytearray], ReadResult]:
        """Run a :class:`ReadPlan` as ranged ``pread``s via the thread pool.

        Returns one buffer per request (``rp.req_size[i]`` bytes each)
        plus aggregate stats.  The worker-pool sizing mirrors the write
        side: idle readers steal from the shared queue, so one straggling
        consumer node does not serialize the restore.  Short reads raise
        ``IOError`` — corruption is then surfaced by the caller's CRC
        check, truncation right here.
        """
        t0 = time.perf_counter()
        sdir = self.step_dir(step)
        bufs = [bytearray(int(n)) for n in rp.req_size.tolist()]
        r = rp.reads
        if not len(r):
            return bufs, ReadResult(
                step=step, duration=time.perf_counter() - t0,
                bytes_read=0, n_reads=0, n_readers=0,
            )
        fds: Dict[int, int] = {}
        lock = threading.Lock()
        total = {"bytes": 0, "reads": 0}
        try:
            for f in np.unique(r.file_id).tolist():
                fds[f] = os.open(str(sdir / rp.file_names[f]), os.O_RDONLY)

            rows = list(
                zip(
                    r.file_id.tolist(), r.file_offset.tolist(), r.size.tolist(),
                    r.dst_req.tolist(), r.dst_offset.tolist(),
                )
            )

            def do_read(row: Tuple[int, int, int, int, int]) -> None:
                fid, foff, size, req, doff = row
                data = os.pread(fds[fid], size, foff)
                if len(data) != size:
                    raise IOError(
                        f"short PFS read: {rp.file_names[fid]} "
                        f"[{foff}:{foff + size})"
                    )
                bufs[req][doff : doff + size] = data
                with lock:
                    total["bytes"] += size
                    total["reads"] += 1

            n_readers = len(np.unique(r.reader))
            workers = min(16, self.io_threads * max(1, n_readers))
            if workers <= 1 or len(rows) == 1:
                for row in rows:
                    do_read(row)
            else:
                with ThreadPoolExecutor(max_workers=workers) as ex:
                    futs = [ex.submit(do_read, row) for row in rows]
                    for f in as_completed(futs):
                        f.result()
            return bufs, ReadResult(
                step=step,
                duration=time.perf_counter() - t0,
                bytes_read=total["bytes"],
                n_reads=total["reads"],
                n_readers=n_readers,
            )
        finally:
            for fd in fds.values():
                try:
                    os.close(fd)
                except OSError:
                    pass

    def read_rank_blob(
        self, manifest: Manifest, step: int, rank: int,
        layout: Optional["FileLayout"] = None,
    ) -> bytes:
        """Reassemble one rank's stored blob from the PFS placement.

        Kept as the single-rank convenience view; it is now a one-request
        :class:`ReadPlan` so the ranged-pread path is the only read path.
        Callers looping over many ranks should pass a pre-built
        ``layout`` (``manifest.file_layout()``) — or better, batch the
        ranks into one plan — instead of re-inverting the placement per
        call.
        """
        offsets = manifest.stored_offsets()
        rp = build_read_plan(
            layout if layout is not None else manifest.file_layout(),
            [int(offsets[rank])],
            [manifest.ranks[rank].stored_size],
        )
        bufs, _ = self.execute_read_plan(rp, step)
        return bytes(bufs[0])


def placement_from_plan(plan: FlushPlan) -> Dict[int, List[Tuple[str, int, int, int]]]:
    """rank -> [(file, file_offset, src_offset, size)], ordered by src_offset."""
    if plan.arrays is not None:
        pa = plan.arrays
        w = pa.writes
        order = np.lexsort((w.src_offset, w.src_rank))
        out: Dict[int, List[Tuple[str, int, int, int]]] = {}
        names = pa.file_names
        for r, f, fo, so, sz in zip(
            w.src_rank[order].tolist(), w.file_id[order].tolist(),
            w.file_offset[order].tolist(), w.src_offset[order].tolist(),
            w.size[order].tolist(),
        ):
            out.setdefault(r, []).append((names[f], fo, so, sz))
        return out
    out = {}
    for w in plan.writes:
        out.setdefault(w.src_rank, []).append(
            (w.file, w.file_offset, w.src_offset, w.size)
        )
    for v in out.values():
        v.sort(key=lambda e: e[2])
    return out
