"""Reference (item-loop) strategy builders — the executable spec.

These are the original per-item planners that :mod:`repro.core.strategies`
replaced with columnar array programs.  They are kept verbatim (minus the
``validate_plan`` calls, which tests run explicitly) so that
``tests/test_plan_arrays.py`` can assert the columnar builders produce
byte-identical coalesced write/send sets on small clusters for every
strategy.  They are quadratic-ish in places and allocate one frozen
dataclass per movement — do not use them at scale.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from repro.core.cluster import ClusterSpec
from repro.core.plan import FlushPlan, SendItem, WriteItem
from repro.core.prefix_sum import (
    elect_leaders,
    exclusive_prefix_sum,
    piggybacked_scan,
)
from repro.core.strategies import AGGREGATE_FILE, _rank_file


def plan_file_per_process_ref(
    cluster: ClusterSpec, rank_sizes: Sequence[int], **_: object
) -> FlushPlan:
    writes: List[WriteItem] = []
    files: Dict[str, int] = {}
    for rank, size in enumerate(rank_sizes):
        if size == 0:
            continue
        fname = _rank_file(rank)
        files[fname] = int(size)
        writes.append(
            WriteItem(
                backend=cluster.node_of_rank(rank),
                file=fname,
                file_offset=0,
                size=int(size),
                src_rank=rank,
                src_offset=0,
            )
        )
    return FlushPlan(
        strategy="file_per_process",
        cluster=cluster,
        rank_sizes=[int(s) for s in rank_sizes],
        files=files,
        writes=writes,
        scan_meta=None,
        stripe_disjoint=True,
    )


def plan_posix_ref(
    cluster: ClusterSpec,
    rank_sizes: Sequence[int],
    *,
    write_chunk: Optional[int] = None,
    **_: object,
) -> FlushPlan:
    offsets, total = exclusive_prefix_sum(rank_sizes)
    scan = piggybacked_scan(cluster, rank_sizes, payload_extra_bytes=0)
    writes: List[WriteItem] = []
    for rank, size in enumerate(rank_sizes):
        size = int(size)
        if size == 0:
            continue
        backend = cluster.node_of_rank(rank)
        step = size if not write_chunk else max(1, int(write_chunk))
        pos = 0
        while pos < size:
            n = min(step, size - pos)
            writes.append(
                WriteItem(
                    backend=backend,
                    file=AGGREGATE_FILE,
                    file_offset=offsets[rank] + pos,
                    size=n,
                    src_rank=rank,
                    src_offset=pos,
                )
            )
            pos += n
    return FlushPlan(
        strategy="posix",
        cluster=cluster,
        rank_sizes=[int(s) for s in rank_sizes],
        files={AGGREGATE_FILE: total},
        writes=writes,
        scan_meta=scan.meta,
        stripe_disjoint=False,
    )


def plan_mpiio_ref(
    cluster: ClusterSpec,
    rank_sizes: Sequence[int],
    *,
    n_leaders: Optional[int] = None,
    chunk_stripes: int = 1,
    **_: object,
) -> FlushPlan:
    offsets, total = exclusive_prefix_sum(rank_sizes)
    scan = piggybacked_scan(cluster, rank_sizes, payload_extra_bytes=0)
    pfs = cluster.pfs
    stripe = pfs.stripe_size * max(1, int(chunk_stripes))
    m = min(
        n_leaders if n_leaders is not None else pfs.n_io_servers,
        cluster.n_nodes,
        max(1, pfs.n_stripes(total)),
    )
    leader_nodes = list(range(m))

    writes: List[WriteItem] = []
    sends: List[SendItem] = []
    for local_idx in range(cluster.procs_per_node):
        rnd = local_idx + 1
        for node in range(cluster.n_nodes):
            rank = node * cluster.procs_per_node + local_idx
            size = int(rank_sizes[rank])
            if size == 0:
                continue
            base = offsets[rank]
            pos = 0
            while pos < size:
                off = base + pos
                s_idx = off // stripe
                stripe_end = (s_idx + 1) * stripe
                n = min(size - pos, stripe_end - off)
                leader = leader_nodes[s_idx % m]
                if leader != node:
                    sends.append(
                        SendItem(
                            src_backend=node,
                            dst_backend=leader,
                            src_rank=rank,
                            src_offset=pos,
                            size=n,
                            round=rnd,
                        )
                    )
                writes.append(
                    WriteItem(
                        backend=leader,
                        file=AGGREGATE_FILE,
                        file_offset=off,
                        size=n,
                        src_rank=rank,
                        src_offset=pos,
                        round=rnd,
                    )
                )
                pos += n
    writes = _coalesce_writes_ref(writes)
    sends = _coalesce_sends_ref(sends)
    return FlushPlan(
        strategy="mpiio",
        cluster=cluster,
        rank_sizes=[int(s) for s in rank_sizes],
        files={AGGREGATE_FILE: total},
        writes=writes,
        sends=sends,
        scan_meta=scan.meta,
        n_rounds=cluster.procs_per_node,
        barrier_per_round=True,
        leaders=None,
        stripe_disjoint=True,
        meta={"interleaved_stripes": True, "m": m, "leader_nodes": leader_nodes},
    )


def plan_stripe_aligned_ref(
    cluster: ClusterSpec,
    rank_sizes: Sequence[int],
    *,
    n_leaders: Optional[int] = None,
    w_size: float = 1.0,
    w_load: float = 0.75,
    w_topo: float = 0.25,
    pipeline_chunk: Optional[int] = None,
    capacity_regions: bool = False,
    **_: object,
) -> FlushPlan:
    scan = piggybacked_scan(cluster, rank_sizes)
    pfs = cluster.pfs
    stripe = pfs.stripe_size
    total = scan.total_bytes
    m = n_leaders if n_leaders is not None else min(
        pfs.n_io_servers, cluster.n_nodes
    )
    assign = elect_leaders(
        cluster, scan, m, w_size=w_size, w_load=w_load, w_topo=w_topo,
        capacity_regions=capacity_regions,
    )
    chunk = int(pipeline_chunk) if pipeline_chunk else 8 * stripe

    writes: List[WriteItem] = []
    sends: List[SendItem] = []
    for rank, size in enumerate(rank_sizes):
        size = int(size)
        if size == 0:
            continue
        home = cluster.node_of_rank(rank)
        base = scan.rank_offsets[rank]
        pos = 0
        while pos < size:
            off = base + pos
            leader = assign.leader_of_offset(off)
            # Slice ends at the first of: blob end, leader-region end,
            # pipeline-chunk boundary (aligned to absolute file offsets so
            # chunk edges coincide with stripe edges).
            region_end = next(e for (s, e) in assign.regions if s <= off < e)
            chunk_end = (off // chunk + 1) * chunk
            n = min(size - pos, region_end - off, chunk_end - off)
            if leader != home:
                sends.append(
                    SendItem(
                        src_backend=home,
                        dst_backend=leader,
                        src_rank=rank,
                        src_offset=pos,
                        size=n,
                    )
                )
            writes.append(
                WriteItem(
                    backend=leader,
                    file=AGGREGATE_FILE,
                    file_offset=off,
                    size=n,
                    src_rank=rank,
                    src_offset=pos,
                )
            )
            pos += n
    return FlushPlan(
        strategy="stripe_aligned",
        cluster=cluster,
        rank_sizes=[int(s) for s in rank_sizes],
        files={AGGREGATE_FILE: total},
        writes=writes,
        sends=sends,
        scan_meta=scan.meta,
        leaders=assign,
        stripe_disjoint=True,
        meta={"m": assign.m, "pipeline_chunk": chunk},
    )


def plan_gio_sync_ref(
    cluster: ClusterSpec,
    rank_sizes: Sequence[int],
    *,
    n_leaders: Optional[int] = None,
    chunk_stripes: int = 1,
    **_: object,
) -> FlushPlan:
    inner = plan_mpiio_ref(
        cluster, rank_sizes, n_leaders=n_leaders, chunk_stripes=chunk_stripes
    )
    writes = [
        WriteItem(
            backend=w.backend,
            file=w.file,
            file_offset=w.file_offset,
            size=w.size,
            src_rank=w.src_rank,
            src_offset=w.src_offset,
            round=1,
        )
        for w in inner.writes
    ]
    sends = [
        SendItem(
            src_backend=s.src_backend,
            dst_backend=s.dst_backend,
            src_rank=s.src_rank,
            src_offset=s.src_offset,
            size=s.size,
            round=1,
        )
        for s in inner.sends
    ]
    return FlushPlan(
        strategy="gio_sync",
        cluster=cluster,
        rank_sizes=list(inner.rank_sizes),
        files=dict(inner.files),
        writes=writes,
        sends=sends,
        scan_meta=inner.scan_meta,
        n_rounds=1,
        barrier_per_round=True,
        leaders=inner.leaders,
        synchronous=True,
        stripe_disjoint=True,
        meta=dict(inner.meta),
    )


def _coalesce_writes_ref(items: List[WriteItem]) -> List[WriteItem]:
    """Merge adjacent stripe-chunk writes with identical (backend, file,
    rank, round) and contiguous offsets into maximal runs."""
    items = sorted(
        items, key=lambda w: (w.round, w.backend, w.file, w.src_rank, w.file_offset)
    )
    out: List[WriteItem] = []
    for w in items:
        if out:
            p = out[-1]
            if (
                p.round == w.round
                and p.backend == w.backend
                and p.file == w.file
                and p.src_rank == w.src_rank
                and p.file_offset + p.size == w.file_offset
                and p.src_offset + p.size == w.src_offset
            ):
                out[-1] = WriteItem(
                    backend=p.backend,
                    file=p.file,
                    file_offset=p.file_offset,
                    size=p.size + w.size,
                    src_rank=p.src_rank,
                    src_offset=p.src_offset,
                    round=p.round,
                )
                continue
        out.append(w)
    return out


def _coalesce_sends_ref(items: List[SendItem]) -> List[SendItem]:
    items = sorted(
        items,
        key=lambda s: (s.round, s.src_backend, s.dst_backend, s.src_rank, s.src_offset),
    )
    out: List[SendItem] = []
    for s in items:
        if out:
            p = out[-1]
            if (
                p.round == s.round
                and p.src_backend == s.src_backend
                and p.dst_backend == s.dst_backend
                and p.src_rank == s.src_rank
                and p.src_offset + p.size == s.src_offset
            ):
                out[-1] = SendItem(
                    src_backend=p.src_backend,
                    dst_backend=p.dst_backend,
                    src_rank=p.src_rank,
                    src_offset=p.src_offset,
                    size=p.size + s.size,
                    round=p.round,
                )
                continue
        out.append(s)
    return out


REFERENCE_STRATEGIES = {
    "file_per_process": plan_file_per_process_ref,
    "posix": plan_posix_ref,
    "mpiio": plan_mpiio_ref,
    "stripe_aligned": plan_stripe_aligned_ref,
    "gio_sync": plan_gio_sync_ref,
}


def make_plan_reference(
    name: str, cluster: ClusterSpec, rank_sizes: Sequence[int], **kw
) -> FlushPlan:
    return REFERENCE_STRATEGIES[name](cluster, rank_sizes, **kw)
