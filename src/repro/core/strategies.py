"""Aggregation strategies for the asynchronous flush phase.

Implemented strategies (paper section in brackets):

* ``file_per_process``  — VELOC default baseline: N ranks -> N files, zero
  coordination [§1].
* ``posix``             — prefix-sum offsets into one shared file, each
  active backend pwrite()s its co-located ranks' data [§2.1].  Suffers
  false sharing on PFS stripes.
* ``mpiio``             — GenericIO-style two-phase collective: I/O
  leaders matched to the number of I/O servers, disjoint stripe sets,
  one *barrier-synchronized collective round per node-local checkpoint*
  (the paper's multi-phase workaround for MPI-IO's single-contiguous-
  buffer restriction) [§2.2].
* ``stripe_aligned``    — the paper's §3 proposal, fully implemented:
  piggy-backed prefix-sum -> deterministic election of M leaders ->
  static stripe-aligned region ownership -> non-leaders stream their
  bytes to the owning leader(s); no barriers, no collectives.
* ``gio_sync``          — synchronous GenericIO-like baseline (blocks the
  application; used for the Fig. 1/2 comparison).

Every strategy returns a validated :class:`~repro.core.plan.FlushPlan`.

All builders are *columnar*: they emit :class:`~repro.core.plan.PlanArrays`
int64 columns via vectorized interval splitting (``np.searchsorted`` over
merged stripe/region/chunk boundary arrays) instead of per-chunk Python
loops, so plan construction at 100k+ ranks is an array program.  The
original item-loop builders are preserved verbatim in
:mod:`repro.core.strategies_ref` and the equivalence test suite
(tests/test_plan_arrays.py) asserts byte-identical write/send sets.

The column-by-column meaning of the emitted ``WriteColumns`` /
``SendColumns`` (``backend``, ``file_id``, ``file_offset``, ``size``,
``src_rank``, ``src_offset``, ``round`` / ``src_backend``,
``dst_backend``, …) and the invariants :func:`~repro.core.plan.
validate_plan` holds every builder to — source coverage, destination
disjointness, send coverage, stripe disjointness — are documented in the
:mod:`repro.core.plan` module docstring, which is the validator's source
of truth.  Because every builder satisfies *source coverage* (each
rank's stored bytes written exactly once), any plan built here inverts
losslessly into the read-side extent table
(:meth:`~repro.core.plan.FileLayout.from_flush_plan`): strategies only
ever decide the *write* layout, and restore planning works uniformly on
the inverse, whatever strategy wrote the checkpoint.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cluster import ClusterSpec
from repro.core.plan import (
    FlushPlan,
    PlanArrays,
    SendColumns,
    WriteColumns,
    coalesce_send_columns,
    coalesce_write_columns,
    validate_plan,
)
from repro.core.prefix_sum import (
    elect_leaders,
    exclusive_prefix_sum_np,
    piggybacked_scan,
)

AGGREGATE_FILE = "aggregate.dat"


def _rank_file(rank: int) -> str:
    return f"rank_{rank:06d}.dat"


def _split_at_multiples(
    starts: np.ndarray, sizes: np.ndarray, step: int
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Split intervals [starts, starts+sizes) at absolute multiples of step.

    Returns (interval_index, piece_start, piece_size); pieces are emitted
    in interval order, ascending within each interval — the same order a
    per-interval ``while`` loop would produce.
    """
    starts = starts.astype(np.int64)
    sizes = sizes.astype(np.int64)
    ends = starts + sizes
    n_pieces = (ends - 1) // step - starts // step + 1
    n_pieces = np.where(sizes > 0, n_pieces, 0)
    total = int(n_pieces.sum())
    idx = np.repeat(np.arange(len(starts), dtype=np.int64), n_pieces)
    first = np.cumsum(n_pieces) - n_pieces
    within = np.arange(total, dtype=np.int64) - np.repeat(first, n_pieces)
    bases = starts[idx] // step
    p_start = np.where(within == 0, starts[idx], (bases + within) * step)
    p_end = np.minimum(ends[idx], (bases + within + 1) * step)
    return idx, p_start, p_end - p_start


# ---------------------------------------------------------------------------
# Baseline: one file per process (VELOC default)
# ---------------------------------------------------------------------------


def plan_file_per_process(
    cluster: ClusterSpec, rank_sizes: Sequence[int], **_: object
) -> FlushPlan:
    sizes = np.asarray(rank_sizes, dtype=np.int64)
    nz = np.flatnonzero(sizes > 0)
    file_names = [_rank_file(int(r)) for r in nz]
    zeros = np.zeros(len(nz), np.int64)
    writes = WriteColumns(
        backend=cluster.nodes_of_ranks(nz),
        file_id=np.arange(len(nz), dtype=np.int64),
        file_offset=zeros,
        size=sizes[nz],
        src_rank=nz,
        src_offset=zeros,
        round=zeros,
    )
    files = {nm: int(sz) for nm, sz in zip(file_names, sizes[nz].tolist())}
    plan = FlushPlan(
        strategy="file_per_process",
        cluster=cluster,
        rank_sizes=[int(s) for s in rank_sizes],
        files=files,
        arrays=PlanArrays(file_names, writes, SendColumns.empty()),
        scan_meta=None,  # embarrassingly parallel: no coordination at all
        stripe_disjoint=True,  # distinct files => distinct OST objects
    )
    validate_plan(plan)
    return plan


# ---------------------------------------------------------------------------
# §2.1 POSIX-based aggregation
# ---------------------------------------------------------------------------


def plan_posix(
    cluster: ClusterSpec,
    rank_sizes: Sequence[int],
    *,
    write_chunk: Optional[int] = None,
    **_: object,
) -> FlushPlan:
    """Shared file, prefix-sum offsets, independent pwrites per backend.

    Writes are issued in ``write_chunk``-sized pieces (default: one write
    per rank blob) — the chunking matters to the simulator's request-size
    efficiency model and to straggler-mitigating work stealing, not to
    correctness.  No attempt is made to align to stripes: that is
    precisely the false-sharing bug this strategy exhibits.
    """
    offsets, total = exclusive_prefix_sum_np(rank_sizes)
    scan = piggybacked_scan(cluster, rank_sizes, payload_extra_bytes=0)
    sizes = np.asarray(rank_sizes, dtype=np.int64)
    nz = np.flatnonzero(sizes > 0)
    if write_chunk:
        step = max(1, int(write_chunk))
        # Chunk boundaries are relative to each blob start: split [0, size)
        # at multiples of step.
        idx, pos, psize = _split_at_multiples(
            np.zeros(len(nz), np.int64), sizes[nz], step
        )
        ranks = nz[idx]
    else:
        ranks = nz
        pos = np.zeros(len(nz), np.int64)
        psize = sizes[nz]
    writes = WriteColumns(
        backend=cluster.nodes_of_ranks(ranks),
        file_id=np.zeros(len(ranks), np.int64),
        file_offset=offsets[ranks] + pos,
        size=psize,
        src_rank=ranks,
        src_offset=pos,
        round=np.zeros(len(ranks), np.int64),
    )
    plan = FlushPlan(
        strategy="posix",
        cluster=cluster,
        rank_sizes=[int(s) for s in rank_sizes],
        files={AGGREGATE_FILE: total},
        arrays=PlanArrays([AGGREGATE_FILE], writes, SendColumns.empty()),
        scan_meta=scan.meta,
        stripe_disjoint=False,  # the whole point of §2.1's finding
    )
    validate_plan(plan)
    return plan


# ---------------------------------------------------------------------------
# §2.2 MPI-IO collective aggregation (two-phase I/O, multi-round)
# ---------------------------------------------------------------------------


def plan_mpiio(
    cluster: ClusterSpec,
    rank_sizes: Sequence[int],
    *,
    n_leaders: Optional[int] = None,
    chunk_stripes: int = 1,
    **_: object,
) -> FlushPlan:
    """Two-phase collective write with I/O leaders.

    Faithful to the paper's description of running GenericIO-style
    aggregation from the active backends:

    * leaders = min(#I/O servers, #backends) — observation (1);
    * each leader owns a disjoint, stripe-aligned *interleaved* stripe set
      (leader j owns stripes ``{s : s % M == j}``) — observation (2),
      eliminating false sharing;
    * MPI-IO accepts one contiguous region per rank per collective call,
      and each backend holds ``procs_per_node`` node-local checkpoints, so
      the flush needs ``procs_per_node`` successive barrier-synchronized
      collective rounds — the paper's multi-phase workaround.  Round k
      collectively writes every node's k-th local checkpoint.

    ``chunk_stripes`` coarsens the exchange granularity to ``chunk_stripes``
    PFS stripes per unit (ADIO ``cb_buffer_size`` analogue); 1 = exact
    stripe-granular two-phase I/O.  Benchmarks at Theta scale use larger
    values to keep plan sizes tractable; correctness is unaffected (the
    plan validator enforces coverage either way).
    """
    offsets, total = exclusive_prefix_sum_np(rank_sizes)
    scan = piggybacked_scan(cluster, rank_sizes, payload_extra_bytes=0)
    pfs = cluster.pfs
    stripe = pfs.stripe_size * max(1, int(chunk_stripes))
    m = min(
        n_leaders if n_leaders is not None else pfs.n_io_servers,
        cluster.n_nodes,
        max(1, pfs.n_stripes(total)),
    )
    sizes = np.asarray(rank_sizes, dtype=np.int64)
    nodes = np.arange(cluster.n_nodes, dtype=np.int64)

    w_parts: List[WriteColumns] = []
    s_parts: List[SendColumns] = []
    for local_idx in range(cluster.procs_per_node):  # one collective / round
        rnd = local_idx + 1
        ranks = nodes * cluster.procs_per_node + local_idx
        idx, p_start, p_size = _split_at_multiples(offsets[ranks], sizes[ranks], stripe)
        # Interleaved static stripe ownership: stripe s -> leader (s % m).
        leader = (p_start // stripe) % m
        src_rank = ranks[idx]
        src_off = p_start - offsets[src_rank]
        rnd_col = np.full(len(idx), rnd, np.int64)
        w_parts.append(
            WriteColumns(
                backend=leader,
                file_id=np.zeros(len(idx), np.int64),
                file_offset=p_start,
                size=p_size,
                src_rank=src_rank,
                src_offset=src_off,
                round=rnd_col,
            )
        )
        remote = leader != nodes[idx]
        s_parts.append(
            SendColumns(
                src_backend=nodes[idx][remote],
                dst_backend=leader[remote],
                src_rank=src_rank[remote],
                src_offset=src_off[remote],
                size=p_size[remote],
                round=rnd_col[remote],
            )
        )
    writes = coalesce_write_columns(WriteColumns.concat(w_parts))
    sends = coalesce_send_columns(SendColumns.concat(s_parts))
    plan = FlushPlan(
        strategy="mpiio",
        cluster=cluster,
        rank_sizes=[int(s) for s in rank_sizes],
        files={AGGREGATE_FILE: total},
        arrays=PlanArrays([AGGREGATE_FILE], writes, sends),
        scan_meta=scan.meta,
        n_rounds=cluster.procs_per_node,
        barrier_per_round=True,  # collective semantics: all ready, together
        leaders=None,  # interleaved stripe ownership, not contiguous regions
        stripe_disjoint=True,
        meta={"interleaved_stripes": True, "m": m,
              "leader_nodes": list(range(m))},
    )
    validate_plan(plan)
    return plan


# ---------------------------------------------------------------------------
# §3 The paper's proposal: stripe-aligned asynchronous aggregation
# ---------------------------------------------------------------------------


def plan_stripe_aligned(
    cluster: ClusterSpec,
    rank_sizes: Sequence[int],
    *,
    n_leaders: Optional[int] = None,
    w_size: float = 1.0,
    w_load: float = 0.75,
    w_topo: float = 0.25,
    pipeline_chunk: Optional[int] = None,
    capacity_regions: bool = False,
    **_: object,
) -> FlushPlan:
    """M elected leaders own static stripe-aligned regions; everyone else
    streams bytes to the owning leader(s).  One piggy-backed prefix sum is
    the only synchronization (paper §3).

    ``pipeline_chunk`` (default: 8 stripes) controls the granularity at
    which sends/writes are decomposed so leaders can overlap receive and
    write, and so the work-stealing executor has units to steal.

    Construction is one global subdivision: the rank offsets (prefix sum),
    leader-region starts and absolute pipeline-chunk multiples are merged
    into a single sorted cut array; each resulting segment maps to its
    source rank and owning leader with two ``np.searchsorted`` calls.
    """
    scan = piggybacked_scan(cluster, rank_sizes)
    pfs = cluster.pfs
    stripe = pfs.stripe_size
    total = scan.total_bytes
    m = n_leaders if n_leaders is not None else min(
        pfs.n_io_servers, cluster.n_nodes
    )
    assign = elect_leaders(
        cluster, scan, m, w_size=w_size, w_load=w_load, w_topo=w_topo,
        capacity_regions=capacity_regions,
    )
    chunk = int(pipeline_chunk) if pipeline_chunk else 8 * stripe

    offsets = scan.offsets_array()
    sizes = np.asarray(rank_sizes, dtype=np.int64)
    region_starts = np.asarray([s for s, _ in assign.regions], np.int64)
    region_leaders = np.asarray(assign.leaders, np.int64)

    # Every write is a maximal segment between consecutive cuts: rank blob
    # boundaries, leader-region starts, and absolute chunk multiples.
    cuts = np.unique(np.concatenate([
        offsets[sizes > 0],
        region_starts,
        np.arange(chunk, total, chunk, dtype=np.int64),
    ]))
    cuts = cuts[(cuts >= 0) & (cuts < total)]
    seg_a = cuts
    seg_b = np.append(cuts[1:], total) if len(cuts) else cuts
    src_rank = np.searchsorted(offsets, seg_a, side="right") - 1
    leader = region_leaders[np.searchsorted(region_starts, seg_a, side="right") - 1]
    home = cluster.nodes_of_ranks(src_rank)
    src_off = seg_a - offsets[src_rank]
    seg_size = seg_b - seg_a

    writes = WriteColumns(
        backend=leader,
        file_id=np.zeros(len(seg_a), np.int64),
        file_offset=seg_a,
        size=seg_size,
        src_rank=src_rank,
        src_offset=src_off,
        round=np.zeros(len(seg_a), np.int64),
    )
    remote = leader != home
    sends = SendColumns(
        src_backend=home[remote],
        dst_backend=leader[remote],
        src_rank=src_rank[remote],
        src_offset=src_off[remote],
        size=seg_size[remote],
        round=np.zeros(int(remote.sum()), np.int64),
    )
    plan = FlushPlan(
        strategy="stripe_aligned",
        cluster=cluster,
        rank_sizes=[int(s) for s in rank_sizes],
        files={AGGREGATE_FILE: total},
        arrays=PlanArrays([AGGREGATE_FILE], writes, sends),
        scan_meta=scan.meta,
        leaders=assign,
        stripe_disjoint=True,
        meta={"m": assign.m, "pipeline_chunk": chunk},
    )
    validate_plan(plan)
    return plan


# ---------------------------------------------------------------------------
# Synchronous GenericIO-like baseline (application blocked)
# ---------------------------------------------------------------------------


def plan_gio_sync(
    cluster: ClusterSpec,
    rank_sizes: Sequence[int],
    *,
    n_leaders: Optional[int] = None,
    chunk_stripes: int = 1,
    **_: object,
) -> FlushPlan:
    """Collective synchronous aggregation straight from application ranks.

    Structurally the MPI-IO plan with a single round (GenericIO hands MPI
    one contiguous buffer per rank) and ``synchronous=True`` — the
    executor charges the *application* for the full duration, and there is
    no separate local phase (Fig. 1 shows GIO writing directly to the
    PFS).
    """
    inner = plan_mpiio(
        cluster, rank_sizes, n_leaders=n_leaders, chunk_stripes=chunk_stripes
    )
    ia = inner.arrays
    plan = FlushPlan(
        strategy="gio_sync",
        cluster=cluster,
        rank_sizes=list(inner.rank_sizes),
        files=dict(inner.files),
        arrays=PlanArrays(
            list(ia.file_names),
            ia.writes.with_round(1),
            ia.sends.with_round(1),
        ),
        scan_meta=inner.scan_meta,
        n_rounds=1,
        barrier_per_round=True,
        leaders=inner.leaders,
        synchronous=True,
        stripe_disjoint=True,
        meta=dict(inner.meta),
    )
    validate_plan(plan)
    return plan


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


StrategyFn = Callable[..., FlushPlan]

STRATEGIES: Dict[str, StrategyFn] = {
    "file_per_process": plan_file_per_process,
    "posix": plan_posix,
    "mpiio": plan_mpiio,
    "stripe_aligned": plan_stripe_aligned,
    "gio_sync": plan_gio_sync,
}


def make_plan(
    name: str, cluster: ClusterSpec, rank_sizes: Sequence[int], **kw
) -> FlushPlan:
    try:
        fn = STRATEGIES[name]
    except KeyError:
        raise ValueError(
            f"unknown strategy {name!r}; available: {sorted(STRATEGIES)}"
        ) from None
    return fn(cluster, rank_sizes, **kw)
