"""Aggregation strategies for the asynchronous flush phase.

Implemented strategies (paper section in brackets):

* ``file_per_process``  — VELOC default baseline: N ranks -> N files, zero
  coordination [§1].
* ``posix``             — prefix-sum offsets into one shared file, each
  active backend pwrite()s its co-located ranks' data [§2.1].  Suffers
  false sharing on PFS stripes.
* ``mpiio``             — GenericIO-style two-phase collective: I/O
  leaders matched to the number of I/O servers, disjoint stripe sets,
  one *barrier-synchronized collective round per node-local checkpoint*
  (the paper's multi-phase workaround for MPI-IO's single-contiguous-
  buffer restriction) [§2.2].
* ``stripe_aligned``    — the paper's §3 proposal, fully implemented:
  piggy-backed prefix-sum -> deterministic election of M leaders ->
  static stripe-aligned region ownership -> non-leaders stream their
  bytes to the owning leader(s); no barriers, no collectives.
* ``gio_sync``          — synchronous GenericIO-like baseline (blocks the
  application; used for the Fig. 1/2 comparison).

Every strategy returns a validated :class:`~repro.core.plan.FlushPlan`.
"""
from __future__ import annotations

import math
from typing import Callable, Dict, List, Optional, Sequence

from repro.core.cluster import ClusterSpec
from repro.core.plan import FlushPlan, SendItem, WriteItem, validate_plan
from repro.core.prefix_sum import (
    elect_leaders,
    exclusive_prefix_sum,
    piggybacked_scan,
)

AGGREGATE_FILE = "aggregate.dat"


def _rank_file(rank: int) -> str:
    return f"rank_{rank:06d}.dat"


# ---------------------------------------------------------------------------
# Baseline: one file per process (VELOC default)
# ---------------------------------------------------------------------------


def plan_file_per_process(
    cluster: ClusterSpec, rank_sizes: Sequence[int], **_: object
) -> FlushPlan:
    writes: List[WriteItem] = []
    files: Dict[str, int] = {}
    for rank, size in enumerate(rank_sizes):
        if size == 0:
            continue
        fname = _rank_file(rank)
        files[fname] = int(size)
        writes.append(
            WriteItem(
                backend=cluster.node_of_rank(rank),
                file=fname,
                file_offset=0,
                size=int(size),
                src_rank=rank,
                src_offset=0,
            )
        )
    plan = FlushPlan(
        strategy="file_per_process",
        cluster=cluster,
        rank_sizes=[int(s) for s in rank_sizes],
        files=files,
        writes=writes,
        scan_meta=None,  # embarrassingly parallel: no coordination at all
        stripe_disjoint=True,  # distinct files => distinct OST objects
    )
    validate_plan(plan)
    return plan


# ---------------------------------------------------------------------------
# §2.1 POSIX-based aggregation
# ---------------------------------------------------------------------------


def plan_posix(
    cluster: ClusterSpec,
    rank_sizes: Sequence[int],
    *,
    write_chunk: Optional[int] = None,
    **_: object,
) -> FlushPlan:
    """Shared file, prefix-sum offsets, independent pwrites per backend.

    Writes are issued in ``write_chunk``-sized pieces (default: one write
    per rank blob) — the chunking matters to the simulator's request-size
    efficiency model and to straggler-mitigating work stealing, not to
    correctness.  No attempt is made to align to stripes: that is
    precisely the false-sharing bug this strategy exhibits.
    """
    offsets, total = exclusive_prefix_sum(rank_sizes)
    scan = piggybacked_scan(cluster, rank_sizes, payload_extra_bytes=0)
    writes: List[WriteItem] = []
    for rank, size in enumerate(rank_sizes):
        size = int(size)
        if size == 0:
            continue
        backend = cluster.node_of_rank(rank)
        step = size if not write_chunk else max(1, int(write_chunk))
        pos = 0
        while pos < size:
            n = min(step, size - pos)
            writes.append(
                WriteItem(
                    backend=backend,
                    file=AGGREGATE_FILE,
                    file_offset=offsets[rank] + pos,
                    size=n,
                    src_rank=rank,
                    src_offset=pos,
                )
            )
            pos += n
    plan = FlushPlan(
        strategy="posix",
        cluster=cluster,
        rank_sizes=[int(s) for s in rank_sizes],
        files={AGGREGATE_FILE: total},
        writes=writes,
        scan_meta=scan.meta,
        stripe_disjoint=False,  # the whole point of §2.1's finding
    )
    validate_plan(plan)
    return plan


# ---------------------------------------------------------------------------
# §2.2 MPI-IO collective aggregation (two-phase I/O, multi-round)
# ---------------------------------------------------------------------------


def plan_mpiio(
    cluster: ClusterSpec,
    rank_sizes: Sequence[int],
    *,
    n_leaders: Optional[int] = None,
    chunk_stripes: int = 1,
    **_: object,
) -> FlushPlan:
    """Two-phase collective write with I/O leaders.

    Faithful to the paper's description of running GenericIO-style
    aggregation from the active backends:

    * leaders = min(#I/O servers, #backends) — observation (1);
    * each leader owns a disjoint, stripe-aligned *interleaved* stripe set
      (leader j owns stripes ``{s : s % M == j}``) — observation (2),
      eliminating false sharing;
    * MPI-IO accepts one contiguous region per rank per collective call,
      and each backend holds ``procs_per_node`` node-local checkpoints, so
      the flush needs ``procs_per_node`` successive barrier-synchronized
      collective rounds — the paper's multi-phase workaround.  Round k
      collectively writes every node's k-th local checkpoint.

    ``chunk_stripes`` coarsens the exchange granularity to ``chunk_stripes``
    PFS stripes per unit (ADIO ``cb_buffer_size`` analogue); 1 = exact
    stripe-granular two-phase I/O.  Benchmarks at Theta scale use larger
    values to keep plan sizes tractable; correctness is unaffected (the
    plan validator enforces coverage either way).
    """
    offsets, total = exclusive_prefix_sum(rank_sizes)
    scan = piggybacked_scan(cluster, rank_sizes, payload_extra_bytes=0)
    pfs = cluster.pfs
    stripe = pfs.stripe_size * max(1, int(chunk_stripes))
    m = min(
        n_leaders if n_leaders is not None else pfs.n_io_servers,
        cluster.n_nodes,
        max(1, pfs.n_stripes(total)),
    )
    # Interleaved static stripe ownership: stripe s -> leader (s % m).
    leader_nodes = list(range(m))  # ADIO-style: first M backends aggregate

    writes: List[WriteItem] = []
    sends: List[SendItem] = []
    for local_idx in range(cluster.procs_per_node):  # one collective / round
        rnd = local_idx + 1
        for node in range(cluster.n_nodes):
            rank = node * cluster.procs_per_node + local_idx
            size = int(rank_sizes[rank])
            if size == 0:
                continue
            base = offsets[rank]
            pos = 0
            while pos < size:
                off = base + pos
                s_idx = off // stripe
                stripe_end = (s_idx + 1) * stripe
                n = min(size - pos, stripe_end - off)
                leader = leader_nodes[s_idx % m]
                if leader != node:
                    sends.append(
                        SendItem(
                            src_backend=node,
                            dst_backend=leader,
                            src_rank=rank,
                            src_offset=pos,
                            size=n,
                            round=rnd,
                        )
                    )
                writes.append(
                    WriteItem(
                        backend=leader,
                        file=AGGREGATE_FILE,
                        file_offset=off,
                        size=n,
                        src_rank=rank,
                        src_offset=pos,
                        round=rnd,
                    )
                )
                pos += n
    writes = _coalesce_writes(writes)
    sends = _coalesce_sends(sends)
    plan = FlushPlan(
        strategy="mpiio",
        cluster=cluster,
        rank_sizes=[int(s) for s in rank_sizes],
        files={AGGREGATE_FILE: total},
        writes=writes,
        sends=sends,
        scan_meta=scan.meta,
        n_rounds=cluster.procs_per_node,
        barrier_per_round=True,  # collective semantics: all ready, together
        leaders=None,  # interleaved stripe ownership, not contiguous regions
        stripe_disjoint=True,
        meta={"interleaved_stripes": True, "m": m, "leader_nodes": leader_nodes},
    )
    validate_plan(plan)
    return plan


# ---------------------------------------------------------------------------
# §3 The paper's proposal: stripe-aligned asynchronous aggregation
# ---------------------------------------------------------------------------


def plan_stripe_aligned(
    cluster: ClusterSpec,
    rank_sizes: Sequence[int],
    *,
    n_leaders: Optional[int] = None,
    w_size: float = 1.0,
    w_load: float = 0.75,
    w_topo: float = 0.25,
    pipeline_chunk: Optional[int] = None,
    capacity_regions: bool = False,
    **_: object,
) -> FlushPlan:
    """M elected leaders own static stripe-aligned regions; everyone else
    streams bytes to the owning leader(s).  One piggy-backed prefix sum is
    the only synchronization (paper §3).

    ``pipeline_chunk`` (default: 8 stripes) controls the granularity at
    which sends/writes are decomposed so leaders can overlap receive and
    write, and so the work-stealing executor has units to steal.
    """
    scan = piggybacked_scan(cluster, rank_sizes)
    pfs = cluster.pfs
    stripe = pfs.stripe_size
    total = scan.total_bytes
    m = n_leaders if n_leaders is not None else min(
        pfs.n_io_servers, cluster.n_nodes
    )
    assign = elect_leaders(
        cluster, scan, m, w_size=w_size, w_load=w_load, w_topo=w_topo,
        capacity_regions=capacity_regions,
    )
    chunk = int(pipeline_chunk) if pipeline_chunk else 8 * stripe

    writes: List[WriteItem] = []
    sends: List[SendItem] = []
    for rank, size in enumerate(rank_sizes):
        size = int(size)
        if size == 0:
            continue
        home = cluster.node_of_rank(rank)
        base = scan.rank_offsets[rank]
        pos = 0
        while pos < size:
            off = base + pos
            leader = assign.leader_of_offset(off)
            # Slice ends at the first of: blob end, leader-region end,
            # pipeline-chunk boundary (aligned to absolute file offsets so
            # chunk edges coincide with stripe edges).
            region_end = next(e for (s, e) in assign.regions if s <= off < e)
            chunk_end = (off // chunk + 1) * chunk
            n = min(size - pos, region_end - off, chunk_end - off)
            if leader != home:
                sends.append(
                    SendItem(
                        src_backend=home,
                        dst_backend=leader,
                        src_rank=rank,
                        src_offset=pos,
                        size=n,
                    )
                )
            writes.append(
                WriteItem(
                    backend=leader,
                    file=AGGREGATE_FILE,
                    file_offset=off,
                    size=n,
                    src_rank=rank,
                    src_offset=pos,
                )
            )
            pos += n
    plan = FlushPlan(
        strategy="stripe_aligned",
        cluster=cluster,
        rank_sizes=[int(s) for s in rank_sizes],
        files={AGGREGATE_FILE: total},
        writes=writes,
        sends=sends,
        scan_meta=scan.meta,
        leaders=assign,
        stripe_disjoint=True,
        meta={"m": assign.m, "pipeline_chunk": chunk},
    )
    validate_plan(plan)
    return plan


# ---------------------------------------------------------------------------
# Synchronous GenericIO-like baseline (application blocked)
# ---------------------------------------------------------------------------


def plan_gio_sync(
    cluster: ClusterSpec,
    rank_sizes: Sequence[int],
    *,
    n_leaders: Optional[int] = None,
    chunk_stripes: int = 1,
    **_: object,
) -> FlushPlan:
    """Collective synchronous aggregation straight from application ranks.

    Structurally the MPI-IO plan with a single round (GenericIO hands MPI
    one contiguous buffer per rank) and ``synchronous=True`` — the
    executor charges the *application* for the full duration, and there is
    no separate local phase (Fig. 1 shows GIO writing directly to the
    PFS).
    """
    inner = plan_mpiio(
        cluster, rank_sizes, n_leaders=n_leaders, chunk_stripes=chunk_stripes
    )
    writes = [
        WriteItem(
            backend=w.backend,
            file=w.file,
            file_offset=w.file_offset,
            size=w.size,
            src_rank=w.src_rank,
            src_offset=w.src_offset,
            round=1,
        )
        for w in inner.writes
    ]
    sends = [
        SendItem(
            src_backend=s.src_backend,
            dst_backend=s.dst_backend,
            src_rank=s.src_rank,
            src_offset=s.src_offset,
            size=s.size,
            round=1,
        )
        for s in inner.sends
    ]
    plan = FlushPlan(
        strategy="gio_sync",
        cluster=cluster,
        rank_sizes=list(inner.rank_sizes),
        files=dict(inner.files),
        writes=writes,
        sends=sends,
        scan_meta=inner.scan_meta,
        n_rounds=1,
        barrier_per_round=True,
        leaders=inner.leaders,
        synchronous=True,
        stripe_disjoint=True,
        meta=dict(inner.meta),
    )
    validate_plan(plan)
    return plan


# ---------------------------------------------------------------------------
# Helpers + registry
# ---------------------------------------------------------------------------


def _coalesce_writes(items: List[WriteItem]) -> List[WriteItem]:
    """Merge adjacent stripe-chunk writes with identical (backend, file,
    rank, round) and contiguous offsets into maximal runs."""
    items = sorted(
        items, key=lambda w: (w.round, w.backend, w.file, w.src_rank, w.file_offset)
    )
    out: List[WriteItem] = []
    for w in items:
        if out:
            p = out[-1]
            if (
                p.round == w.round
                and p.backend == w.backend
                and p.file == w.file
                and p.src_rank == w.src_rank
                and p.file_offset + p.size == w.file_offset
                and p.src_offset + p.size == w.src_offset
            ):
                out[-1] = WriteItem(
                    backend=p.backend,
                    file=p.file,
                    file_offset=p.file_offset,
                    size=p.size + w.size,
                    src_rank=p.src_rank,
                    src_offset=p.src_offset,
                    round=p.round,
                )
                continue
        out.append(w)
    return out


def _coalesce_sends(items: List[SendItem]) -> List[SendItem]:
    items = sorted(
        items,
        key=lambda s: (s.round, s.src_backend, s.dst_backend, s.src_rank, s.src_offset),
    )
    out: List[SendItem] = []
    for s in items:
        if out:
            p = out[-1]
            if (
                p.round == s.round
                and p.src_backend == s.src_backend
                and p.dst_backend == s.dst_backend
                and p.src_rank == s.src_rank
                and p.src_offset + p.size == s.src_offset
            ):
                out[-1] = SendItem(
                    src_backend=p.src_backend,
                    dst_backend=p.dst_backend,
                    src_rank=p.src_rank,
                    src_offset=p.src_offset,
                    size=p.size + s.size,
                    round=p.round,
                )
                continue
        out.append(s)
    return out


StrategyFn = Callable[..., FlushPlan]

STRATEGIES: Dict[str, StrategyFn] = {
    "file_per_process": plan_file_per_process,
    "posix": plan_posix,
    "mpiio": plan_mpiio,
    "stripe_aligned": plan_stripe_aligned,
    "gio_sync": plan_gio_sync,
}


def make_plan(
    name: str, cluster: ClusterSpec, rank_sizes: Sequence[int], **kw
) -> FlushPlan:
    try:
        fn = STRATEGIES[name]
    except KeyError:
        raise ValueError(
            f"unknown strategy {name!r}; available: {sorted(STRATEGIES)}"
        ) from None
    return fn(cluster, rank_sizes, **kw)
