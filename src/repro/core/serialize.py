"""State (de)serialization: pytree <-> per-rank byte blobs + manifest.

VELOC semantics: each *process* checkpoints its own bytes.  On a real
multi-host deployment those are the host's addressable shards of every
array; in this single-process framework we serialize the global state to
one logical byte stream and split it into ``world_size`` contiguous
rank blobs — byte-identical reassembly, and the aggregation strategies
only ever see the per-rank sizes.

The manifest stores the leaf table (name/dtype/shape/offset) and the rank
table (offset/size/crc), so restore can:

* reassemble from any subset of levels (PFS aggregate file, per-rank
  files, node-local files),
* verify integrity per rank blob,
* **re-shard elastically**: the logical stream is mesh-agnostic, so a
  checkpoint saved from an 8-node layout restores onto 3 nodes (or onto a
  different jax mesh) unchanged.

Codecs (applied per rank blob, after splitting): ``none`` | ``zstd`` |
``zstd+delta`` (XOR against the previous checkpoint's blob, then zstd —
incremental checkpointing).  Codecs change the *stored* sizes that the
flush plan sees; raw sizes are preserved in the manifest.
"""
from __future__ import annotations

import json
from concurrent.futures import Executor
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np
import jax

from repro.core.cluster import ClusterSpec
from repro.core.integrity import crc32
from repro.utils.treelib import flatten_with_names

try:
    import zstandard as _zstd
except Exception:  # pragma: no cover - zstd is an install-time dep
    _zstd = None


@dataclass(frozen=True)
class LeafEntry:
    name: str
    dtype: str
    shape: Tuple[int, ...]
    offset: int
    size: int


@dataclass
class RankEntry:
    rank: int
    offset: int          # offset in the logical stream
    raw_size: int
    stored_size: int
    crc: int             # crc of the *stored* blob


@dataclass(eq=False)
class Placement:
    """Columnar PFS placement: where each rank's stored blob landed.

    Parallel int64 columns, one row per write extent, sorted by
    ``(rank, src_offset)``.  This is the persisted form of a flush's
    write set: a 32k-rank manifest JSON-encodes as six flat lists
    instead of a rank-keyed dict of tuples, so manifest serialization
    no longer dominates the async flush tail at paper scale.

    * ``rank``        — producer rank whose stored blob the extent is from
    * ``file_id``     — index into ``file_names``
    * ``file_offset`` — destination byte offset inside that file
    * ``src_offset``  — offset inside the rank's stored blob
    * ``size``        — extent length (> 0)
    """

    file_names: List[str]
    rank: np.ndarray
    file_id: np.ndarray
    file_offset: np.ndarray
    src_offset: np.ndarray
    size: np.ndarray

    _COLS = ("rank", "file_id", "file_offset", "src_offset", "size")

    def __post_init__(self):
        for c in self._COLS:
            setattr(self, c, np.asarray(getattr(self, c), dtype=np.int64))
        if len({getattr(self, c).shape for c in self._COLS}) != 1:
            raise ValueError("Placement columns must have identical length")
        if len(self.rank) > 1:
            order = np.lexsort((self.src_offset, self.rank))
            for c in self._COLS:
                setattr(self, c, getattr(self, c)[order])

    def __len__(self) -> int:
        return len(self.rank)

    def __bool__(self) -> bool:
        return len(self) > 0

    def __eq__(self, other) -> bool:
        if not isinstance(other, Placement):
            return NotImplemented
        return self.file_names == other.file_names and all(
            np.array_equal(getattr(self, c), getattr(other, c))
            for c in self._COLS
        )

    @staticmethod
    def empty() -> "Placement":
        z = np.empty(0, np.int64)
        return Placement([], z, z, z, z, z)

    def by_rank(self) -> Dict[int, List[Tuple[str, int, int, int]]]:
        """Legacy item view: rank -> [(file, file_offset, src_offset,
        size)], ordered by src_offset.  Debug/test convenience only —
        hot paths stay on the columns."""
        out: Dict[int, List[Tuple[str, int, int, int]]] = {}
        for r, f, fo, so, sz in zip(
            self.rank.tolist(), self.file_id.tolist(),
            self.file_offset.tolist(), self.src_offset.tolist(),
            self.size.tolist(),
        ):
            out.setdefault(r, []).append((self.file_names[f], fo, so, sz))
        return out

    def to_json_obj(self) -> Dict[str, Any]:
        return {
            "file_names": list(self.file_names),
            "rank": self.rank.tolist(),
            "file_id": self.file_id.tolist(),
            "file_offset": self.file_offset.tolist(),
            "src_offset": self.src_offset.tolist(),
            "size": self.size.tolist(),
        }

    @staticmethod
    def from_json_obj(obj: Any) -> "Placement":
        """Parse either the columnar form or the legacy rank-keyed dict
        ``{rank: [(file, file_offset, src_offset, size), ...]}`` written
        by pre-columnar manifests."""
        if not obj:
            return Placement.empty()
        if isinstance(obj, dict) and "rank" in obj and "file_names" in obj:
            return Placement(
                file_names=list(obj["file_names"]),
                rank=obj["rank"],
                file_id=obj["file_id"],
                file_offset=obj["file_offset"],
                src_offset=obj["src_offset"],
                size=obj["size"],
            )
        names: List[str] = []
        fid: Dict[str, int] = {}
        rank: List[int] = []
        file_id: List[int] = []
        file_offset: List[int] = []
        src_offset: List[int] = []
        size: List[int] = []
        for r, entries in obj.items():
            for fname, foff, soff, n in entries:
                j = fid.get(fname)
                if j is None:
                    j = fid[fname] = len(names)
                    names.append(fname)
                rank.append(int(r))
                file_id.append(j)
                file_offset.append(foff)
                src_offset.append(soff)
                size.append(n)
        return Placement(names, rank, file_id, file_offset, src_offset, size)


@dataclass
class Manifest:
    step: int
    total_raw_bytes: int
    codec: str
    base_step: Optional[int]          # for delta codecs
    world_size: int
    procs_per_node: int
    leaves: List[LeafEntry]
    ranks: List[RankEntry]
    precodec: str = "none"            # device-side transform (e.g. int8)
    strategy: str = ""
    files: Dict[str, int] = field(default_factory=dict)
    # columnar file layout of every rank's stored blob on the PFS
    placement: Placement = field(default_factory=Placement.empty)
    status: str = "pending"           # pending | local_done | flush_done

    # -- read-side views ---------------------------------------------------
    #
    # "Stored space" is the concatenation of every rank's *stored*
    # (encoded) blob in rank order; "raw space" is the logical stream the
    # pytree serialized to.  With codec "none" the two coincide byte for
    # byte; with compression they differ and only whole stored blobs can
    # be decoded.  The read planner always works in stored space.

    def stored_offsets(self) -> "np.ndarray":
        """rank -> stored-space offset of its blob (len world_size + 1)."""
        from repro.core.plan import stored_space_offsets

        return stored_space_offsets([r.stored_size for r in self.ranks])

    @property
    def total_stored_bytes(self) -> int:
        return sum(r.stored_size for r in self.ranks)

    def file_layout(self) -> "FileLayout":
        """Invert the persisted placement into a :class:`FileLayout`
        extent table (requires ``status == "flush_done"``).  Columnar
        placements invert with one gather — no Python loop."""
        from repro.core.plan import FileLayout

        return FileLayout.from_placement(
            self.placement, [r.stored_size for r in self.ranks], self.files
        )

    def leaf_ranges(
        self, names: Sequence[str]
    ) -> List[Tuple[str, int, int]]:
        """(name, raw_offset, size) for the named leaves, in saved order.

        Raises ``KeyError`` on unknown names — partial restore must not
        silently return fewer leaves than asked for."""
        by_name = {l.name: l for l in self.leaves}
        missing = [n for n in names if n not in by_name]
        if missing:
            raise KeyError(f"leaves not in checkpoint: {missing[:5]}")
        return [(n, by_name[n].offset, by_name[n].size) for n in names]

    def _raw_bounds(self) -> Tuple["np.ndarray", "np.ndarray"]:
        """Cached (starts, ends) of each rank's raw segment — both
        non-decreasing because ranks slice the stream contiguously."""
        cached = self.__dict__.get("_raw_bounds_cache")
        if cached is None:
            starts = np.asarray([r.offset for r in self.ranks], np.int64)
            ends = starts + np.asarray(
                [r.raw_size for r in self.ranks], np.int64
            )
            cached = self.__dict__["_raw_bounds_cache"] = (starts, ends)
        return cached

    def ranks_covering(self, raw_a: int, raw_b: int) -> List[int]:
        """Ranks whose raw segment intersects ``[raw_a, raw_b)``.

        Two ``np.searchsorted`` calls over the cached prefix arrays — a
        partial restore of thousands of leaves at paper-scale world
        sizes must not do a linear Python scan per leaf."""
        if raw_b <= raw_a:
            return []
        starts, ends = self._raw_bounds()
        lo = int(np.searchsorted(ends, raw_a, side="right"))
        hi = int(np.searchsorted(starts, raw_b, side="left"))
        return [r for r in range(lo, hi) if ends[r] > starts[r]]

    def to_json(self) -> str:
        d = {
            "step": self.step,
            "total_raw_bytes": self.total_raw_bytes,
            "codec": self.codec,
            "base_step": self.base_step,
            "world_size": self.world_size,
            "procs_per_node": self.procs_per_node,
            "leaves": [asdict(l) for l in self.leaves],
            "ranks": [asdict(r) for r in self.ranks],
            "precodec": self.precodec,
            "strategy": self.strategy,
            "files": self.files,
            "placement": self.placement.to_json_obj(),
            "status": self.status,
        }
        return json.dumps(d, separators=(",", ":"))

    @staticmethod
    def from_json(s: str) -> "Manifest":
        d = json.loads(s)
        d.pop("_raw_bounds_cache", None)  # legacy manifests may carry it
        d["leaves"] = [LeafEntry(name=l["name"], dtype=l["dtype"],
                                 shape=tuple(l["shape"]), offset=l["offset"],
                                 size=l["size"]) for l in d["leaves"]]
        d["ranks"] = [RankEntry(**r) for r in d["ranks"]]
        d["placement"] = Placement.from_json_obj(d.get("placement"))
        return Manifest(**d)


# ---------------------------------------------------------------------------
# pytree -> logical stream
# ---------------------------------------------------------------------------


def _leaf_to_np(leaf: Any) -> np.ndarray:
    if isinstance(leaf, jax.Array):
        return np.asarray(leaf)
    return np.asarray(leaf)


Buffer = Union[bytes, bytearray, memoryview]


def serialize_tree(
    state: Any, *, pool: Optional[Executor] = None
) -> Tuple[memoryview, List[LeafEntry]]:
    """Pytree -> one logical byte stream, written in place.

    Leaf sizes are computed first, then every leaf is copied *directly*
    into its slice of one preallocated buffer (``np.copyto`` through a
    dtype view — C-order, like ``tobytes()``): one copy per leaf total,
    no per-leaf ``tobytes`` temporaries, no ``b"".join`` recopy of the
    whole stream.  Leaf slices are disjoint, so with ``pool`` the copies
    run concurrently (``np.copyto`` releases the GIL on large arrays).
    Returns a read-only :class:`memoryview`; downstream consumers
    (:func:`encode_state`, CRC, L1 writes) slice it without copying.
    The seed item-loop implementation survives as
    :func:`repro.core.serialize_ref.serialize_tree_reference` and the
    equivalence tests prove the streams byte-identical.
    """
    named, _ = flatten_with_names(state)
    arrs = [_leaf_to_np(leaf) for _, leaf in named]
    leaves: List[LeafEntry] = []
    off = 0
    for (name, _), arr in zip(named, arrs):
        size = int(arr.nbytes)
        leaves.append(
            LeafEntry(
                name=name, dtype=str(arr.dtype), shape=tuple(arr.shape),
                offset=off, size=size,
            )
        )
        off += size
    buf = np.empty(off, np.uint8)

    def copy_leaf(job: Tuple[LeafEntry, np.ndarray]) -> None:
        entry, arr = job
        if entry.size == 0:
            return
        dst = buf[entry.offset : entry.offset + entry.size]
        np.copyto(dst.view(arr.dtype).reshape(arr.shape), arr, casting="no")

    jobs = list(zip(leaves, arrs))
    if pool is not None and len(jobs) > 1:
        list(pool.map(copy_leaf, jobs))
    else:
        for j in jobs:
            copy_leaf(j)
    return memoryview(buf).toreadonly(), leaves


def deserialize_tree(stream: Buffer, leaves: Sequence[LeafEntry], target: Any) -> Any:
    """Fill `target`'s structure with leaf values from the stream.

    `target` may contain arrays or jax.ShapeDtypeStructs; only the
    structure is used.  Leaf order must match the saved order (name
    mismatches raise).
    """
    named, treedef = flatten_with_names(target)
    if len(named) != len(leaves):
        raise ValueError(
            f"target has {len(named)} leaves, checkpoint has {len(leaves)}"
        )
    vals = []
    for (name, _), entry in zip(named, leaves):
        if name != entry.name:
            raise ValueError(f"leaf mismatch: target {name!r} vs saved {entry.name!r}")
        buf = stream[entry.offset : entry.offset + entry.size]
        arr = np.frombuffer(buf, dtype=np.dtype(entry.dtype)).reshape(entry.shape)
        vals.append(arr.copy())
    return jax.tree_util.tree_unflatten(treedef, vals)


# ---------------------------------------------------------------------------
# logical stream -> per-rank blobs (+ codecs)
# ---------------------------------------------------------------------------


def split_ranks(
    total: int, world_size: int, *, sizes: Optional[Sequence[int]] = None
) -> List[Tuple[int, int]]:
    """(offset, size) per rank.  Balanced contiguous split by default."""
    if sizes is not None:
        if sum(sizes) != total or len(sizes) != world_size:
            raise ValueError("explicit sizes must sum to total")
        out, off = [], 0
        for s in sizes:
            out.append((off, int(s)))
            off += int(s)
        return out
    base, rem = divmod(total, world_size)
    out, off = [], 0
    for r in range(world_size):
        s = base + (1 if r < rem else 0)
        out.append((off, s))
        off += s
    return out


def _zstd_c(data: bytes, level: int = 3) -> bytes:
    if _zstd is None:
        raise RuntimeError("zstandard not available")
    return _zstd.ZstdCompressor(level=level).compress(data)


def _zstd_d(data: bytes, raw_size: int) -> bytes:
    if _zstd is None:
        raise RuntimeError("zstandard not available")
    return _zstd.ZstdDecompressor().decompress(data, max_output_size=max(raw_size, 1))


def encode_blob(
    raw: Buffer, codec: str, base: Optional[Buffer] = None
) -> Buffer:
    if codec == "none":
        return raw
    if codec == "zstd":
        return _zstd_c(raw)
    if codec == "zstd+delta":
        if base is not None and len(base) == len(raw):
            x = np.bitwise_xor(
                np.frombuffer(raw, np.uint8), np.frombuffer(base, np.uint8)
            ).tobytes()
            return _zstd_c(x)
        return _zstd_c(raw)  # no base -> plain zstd (self-contained)
    raise ValueError(f"unknown codec {codec!r}")


def decode_blob(
    stored: bytes, codec: str, raw_size: int, base: Optional[bytes] = None,
    *, has_base: bool = False,
) -> bytes:
    if codec == "none":
        return stored
    if codec == "zstd":
        return _zstd_d(stored, raw_size)
    if codec == "zstd+delta":
        x = _zstd_d(stored, raw_size)
        if has_base:
            if base is None or len(base) != len(x):
                raise ValueError("delta blob requires its base blob")
            return np.bitwise_xor(
                np.frombuffer(x, np.uint8), np.frombuffer(base, np.uint8)
            ).tobytes()
        return x
    raise ValueError(f"unknown codec {codec!r}")


@dataclass
class EncodedState:
    """One checkpoint, serialized + split + encoded, ready to plan/flush.

    Buffer ownership: with codec ``none`` every entry of ``blobs`` is a
    read-only :class:`memoryview` slice of ``stream`` — the pytree's
    bytes exist exactly once between serialization and the L1 files.
    Compression codecs materialize per-rank ``bytes`` (unavoidably: the
    stored bytes differ from the raw ones).  ``stream`` is kept alive by
    the L0 twin and by delta bases; the views never outlive it.
    """

    step: int
    stream: Buffer                  # raw logical stream (kept for L0/delta)
    blobs: List[Buffer]             # stored (encoded) blob per rank
    manifest: Manifest


def encode_state(
    step: int,
    state: Any,
    cluster: ClusterSpec,
    *,
    codec: str = "none",
    base: Optional[EncodedState] = None,
    rank_sizes: Optional[Sequence[int]] = None,
    pool: Optional[Executor] = None,
    rank_sink: Optional[Any] = None,
) -> EncodedState:
    """Serialize + split + encode one checkpoint.

    Zero-copy contract: rank blobs are memoryview slices of the stream
    (codec ``none`` stores them as-is — zero extra copies between the
    pytree and the L1 files), and :func:`~repro.core.integrity.crc32`
    hashes the views in place.

    ``pool`` runs the per-rank work concurrently; ``rank_sink(rank,
    blob)``, when given, is called inside each rank's task right after
    its CRC — the engine injects the L1 write here, so encode + CRC +
    node-local drain are **one fused parallel phase**: CRC (holding the
    GIL) of one rank overlaps the file write (GIL released) of another
    instead of running as two barriers.
    """
    stream, leaves = serialize_tree(state, pool=pool)
    total = len(stream)
    parts = split_ranks(total, cluster.world_size, sizes=rank_sizes)
    base_ok = (
        base is not None
        and codec == "zstd+delta"
        and len(base.stream) == total
        and [
            (r.offset, r.raw_size) for r in base.manifest.ranks
        ] == list(parts)
    )

    def encode_rank(job: Tuple[int, int, int]) -> Tuple[Buffer, RankEntry]:
        r, off, size = job
        raw = stream[off : off + size]
        b = encode_blob(
            raw, codec, base.stream[off : off + size] if base_ok else None
        )
        entry = RankEntry(
            rank=r, offset=off, raw_size=size, stored_size=len(b),
            crc=crc32(b),
        )
        if rank_sink is not None:
            rank_sink(r, b)
        return b, entry

    jobs = [(r, off, size) for r, (off, size) in enumerate(parts)]
    if pool is not None and len(jobs) > 1:
        results = list(pool.map(encode_rank, jobs))
    else:
        results = [encode_rank(j) for j in jobs]
    blobs = [b for b, _ in results]
    ranks = [e for _, e in results]
    man = Manifest(
        step=step,
        total_raw_bytes=total,
        codec=codec,
        base_step=base.step if base_ok else None,
        world_size=cluster.world_size,
        procs_per_node=cluster.procs_per_node,
        leaves=leaves,
        ranks=ranks,
    )
    return EncodedState(step=step, stream=stream, blobs=blobs, manifest=man)


def decode_state(
    manifest: Manifest,
    blobs: Sequence[bytes],
    target: Any,
    *,
    base_stream: Optional[bytes] = None,
    verify: bool = True,
) -> Any:
    parts: List[bytes] = []
    has_base = manifest.base_step is not None
    for entry, blob in zip(manifest.ranks, blobs):
        if verify and crc32(blob) != entry.crc:
            raise IOError(f"rank {entry.rank}: checksum mismatch")
        base = (
            base_stream[entry.offset : entry.offset + entry.raw_size]
            if (base_stream is not None and has_base)
            else None
        )
        parts.append(
            decode_blob(
                blob, manifest.codec, entry.raw_size, base, has_base=has_base
            )
        )
    stream = b"".join(parts)
    if len(stream) != manifest.total_raw_bytes:
        raise IOError("reassembled stream has wrong size")
    return deserialize_tree(stream, manifest.leaves, target)
