"""State (de)serialization: pytree <-> per-rank byte blobs + manifest.

VELOC semantics: each *process* checkpoints its own bytes.  On a real
multi-host deployment those are the host's addressable shards of every
array; in this single-process framework we serialize the global state to
one logical byte stream and split it into ``world_size`` contiguous
rank blobs — byte-identical reassembly, and the aggregation strategies
only ever see the per-rank sizes.

The manifest stores the leaf table (name/dtype/shape/offset) and the rank
table (offset/size/crc), so restore can:

* reassemble from any subset of levels (PFS aggregate file, per-rank
  files, node-local files),
* verify integrity per rank blob,
* **re-shard elastically**: the logical stream is mesh-agnostic, so a
  checkpoint saved from an 8-node layout restores onto 3 nodes (or onto a
  different jax mesh) unchanged.

Codecs (applied per rank blob, after splitting): ``none`` | ``zstd`` |
``zstd+delta`` (XOR against the previous checkpoint's stream, then
compress — incremental checkpointing).  Codecs change the *stored*
sizes that the flush plan sees; raw sizes are preserved in the
manifest.

Chunk framing
=============

Compression codecs are **chunk-framed**: each rank's raw segment is cut
into fixed-size chunks (``chunk_size``, last chunk ragged) and every
chunk is transformed independently, so encode/decode parallelize on the
manager's worker pool, corruption is detectable (and attributable) at
chunk granularity, and partial restore fetches only the chunks covering
the requested leaves instead of whole covering blobs.  The per-chunk
bookkeeping is the :class:`ChunkTable` — a structure-of-arrays with one
row per chunk (see its docstring for column semantics and invariants) —
persisted in the manifest as flat parallel int lists.  Under
``zstd+delta`` the transform is chunk-granular too: each chunk is
compared against the base stream's matching byte range (vectorized
``np.bitwise_xor`` / ``np.array_equal``), and *unchanged chunks store
zero bytes* — a base-reference flag — so small-update steps shrink
toward the differential-checkpointing ideal instead of re-compressing
the whole rank blob.

The seed whole-blob codecs survive as :func:`encode_blob_reference` /
:func:`decode_blob_reference` (the executable spec; also the on-disk
format of legacy manifests, which still parse and restore), selected by
``chunk_size=0``.

Compression backend: ``zstandard`` when importable, stdlib ``zlib``
otherwise (this keeps the codec matrix runnable — and benchmarked — on
machines without the optional dependency).  One compressor/decompressor
object is reused per worker thread; the backend that encoded a
checkpoint is recorded in the manifest (``codec_impl``) so decode always
uses the matching one.
"""
from __future__ import annotations

import json
import threading
import zlib as _zlib
from concurrent.futures import Executor
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np
import jax

from repro.core.cluster import ClusterSpec
from repro.core.integrity import crc32
from repro.kernels.checksum.ref import digest_ref
from repro.utils.treelib import flatten_with_names

try:
    import zstandard as _zstd
except Exception:  # pragma: no cover - optional dep; zlib fallback below
    _zstd = None


@dataclass(frozen=True)
class LeafEntry:
    name: str
    dtype: str
    shape: Tuple[int, ...]
    offset: int
    size: int


@dataclass
class RankEntry:
    rank: int
    offset: int          # offset in the logical stream
    raw_size: int
    stored_size: int
    crc: int             # crc of the *stored* blob


@dataclass(eq=False)
class Placement:
    """Columnar PFS placement: where each rank's stored blob landed.

    Parallel int64 columns, one row per write extent, sorted by
    ``(rank, src_offset)``.  This is the persisted form of a flush's
    write set: a 32k-rank manifest JSON-encodes as six flat lists
    instead of a rank-keyed dict of tuples, so manifest serialization
    no longer dominates the async flush tail at paper scale.

    * ``rank``        — producer rank whose stored blob the extent is from
    * ``file_id``     — index into ``file_names``
    * ``file_offset`` — destination byte offset inside that file
    * ``src_offset``  — offset inside the rank's stored blob
    * ``size``        — extent length (> 0)
    """

    file_names: List[str]
    rank: np.ndarray
    file_id: np.ndarray
    file_offset: np.ndarray
    src_offset: np.ndarray
    size: np.ndarray

    _COLS = ("rank", "file_id", "file_offset", "src_offset", "size")

    def __post_init__(self):
        for c in self._COLS:
            setattr(self, c, np.asarray(getattr(self, c), dtype=np.int64))
        if len({getattr(self, c).shape for c in self._COLS}) != 1:
            raise ValueError("Placement columns must have identical length")
        if len(self.rank) > 1:
            order = np.lexsort((self.src_offset, self.rank))
            for c in self._COLS:
                setattr(self, c, getattr(self, c)[order])

    def __len__(self) -> int:
        return len(self.rank)

    def __bool__(self) -> bool:
        return len(self) > 0

    def __eq__(self, other) -> bool:
        if not isinstance(other, Placement):
            return NotImplemented
        return self.file_names == other.file_names and all(
            np.array_equal(getattr(self, c), getattr(other, c))
            for c in self._COLS
        )

    @staticmethod
    def empty() -> "Placement":
        z = np.empty(0, np.int64)
        return Placement([], z, z, z, z, z)

    def by_rank(self) -> Dict[int, List[Tuple[str, int, int, int]]]:
        """Legacy item view: rank -> [(file, file_offset, src_offset,
        size)], ordered by src_offset.  Debug/test convenience only —
        hot paths stay on the columns."""
        out: Dict[int, List[Tuple[str, int, int, int]]] = {}
        for r, f, fo, so, sz in zip(
            self.rank.tolist(), self.file_id.tolist(),
            self.file_offset.tolist(), self.src_offset.tolist(),
            self.size.tolist(),
        ):
            out.setdefault(r, []).append((self.file_names[f], fo, so, sz))
        return out

    def to_json_obj(self) -> Dict[str, Any]:
        return {
            "file_names": list(self.file_names),
            "rank": self.rank.tolist(),
            "file_id": self.file_id.tolist(),
            "file_offset": self.file_offset.tolist(),
            "src_offset": self.src_offset.tolist(),
            "size": self.size.tolist(),
        }

    @staticmethod
    def from_json_obj(obj: Any) -> "Placement":
        """Parse either the columnar form or the legacy rank-keyed dict
        ``{rank: [(file, file_offset, src_offset, size), ...]}`` written
        by pre-columnar manifests."""
        if not obj:
            return Placement.empty()
        if isinstance(obj, dict) and "rank" in obj and "file_names" in obj:
            return Placement(
                file_names=list(obj["file_names"]),
                rank=obj["rank"],
                file_id=obj["file_id"],
                file_offset=obj["file_offset"],
                src_offset=obj["src_offset"],
                size=obj["size"],
            )
        names: List[str] = []
        fid: Dict[str, int] = {}
        rank: List[int] = []
        file_id: List[int] = []
        file_offset: List[int] = []
        src_offset: List[int] = []
        size: List[int] = []
        for r, entries in obj.items():
            for fname, foff, soff, n in entries:
                j = fid.get(fname)
                if j is None:
                    j = fid[fname] = len(names)
                    names.append(fname)
                rank.append(int(r))
                file_id.append(j)
                file_offset.append(foff)
                src_offset.append(soff)
                size.append(n)
        return Placement(names, rank, file_id, file_offset, src_offset, size)


@dataclass
class Manifest:
    step: int
    total_raw_bytes: int
    codec: str
    base_step: Optional[int]          # for delta codecs
    world_size: int
    procs_per_node: int
    leaves: List[LeafEntry]
    ranks: List[RankEntry]
    precodec: str = "none"            # device-side transform (e.g. int8)
    # chunk framing of the stored blobs (compression codecs only):
    # chunk_size == 0 means whole-blob (seed/legacy) framing, chunks is
    # then None; codec_impl records the compression backend that
    # encoded this checkpoint ("zstd" | "zlib"; "" for codec none).
    codec_impl: str = ""
    chunk_size: int = 0
    chunks: Optional[ChunkTable] = None
    strategy: str = ""
    files: Dict[str, int] = field(default_factory=dict)
    # columnar file layout of every rank's stored blob on the PFS
    placement: Placement = field(default_factory=Placement.empty)
    # Flush lifecycle state (full state machine in docs/OPERATIONS.md):
    # pending -> local_done -> [flush_partial ->] flush_done, with
    # superseded/failed edges.  "flush_partial" = an in-progress or
    # interrupted flush whose placement + extent journal make it
    # resumable (CheckpointManager.resume_flushes); "superseded" = a
    # flush abandoned because a newer step replaced it; "quarantined" =
    # scrub-and-repair (repro.core.repair) found some rank with *no*
    # intact copy on any level — terminal: excluded from restore,
    # steps(), delta-base selection, and reaped by GC.  restore() only
    # trusts "flush_done" PFS checkpoints — every other state falls
    # back down the level ladder.
    status: str = "pending"  # pending | local_done | flush_partial | flush_done | superseded | quarantined

    # -- read-side views ---------------------------------------------------
    #
    # "Stored space" is the concatenation of every rank's *stored*
    # (encoded) blob in rank order; "raw space" is the logical stream the
    # pytree serialized to.  With codec "none" the two coincide byte for
    # byte; with compression they differ and only whole stored blobs can
    # be decoded.  The read planner always works in stored space.

    def stored_offsets(self) -> "np.ndarray":
        """rank -> stored-space offset of its blob (len world_size + 1)."""
        from repro.core.plan import stored_space_offsets

        return stored_space_offsets([r.stored_size for r in self.ranks])

    @property
    def total_stored_bytes(self) -> int:
        return sum(r.stored_size for r in self.ranks)

    def file_layout(self) -> "FileLayout":
        """Invert the persisted placement into a :class:`FileLayout`
        extent table (requires ``status == "flush_done"``).  Columnar
        placements invert with one gather — no Python loop."""
        from repro.core.plan import FileLayout

        return FileLayout.from_placement(
            self.placement, [r.stored_size for r in self.ranks], self.files
        )

    def leaf_ranges(
        self, names: Sequence[str]
    ) -> List[Tuple[str, int, int]]:
        """(name, raw_offset, size) for the named leaves, in saved order.

        Raises ``KeyError`` on unknown names — partial restore must not
        silently return fewer leaves than asked for."""
        by_name = {l.name: l for l in self.leaves}
        missing = [n for n in names if n not in by_name]
        if missing:
            raise KeyError(f"leaves not in checkpoint: {missing[:5]}")
        return [(n, by_name[n].offset, by_name[n].size) for n in names]

    def _raw_bounds(self) -> Tuple["np.ndarray", "np.ndarray"]:
        """Cached (starts, ends) of each rank's raw segment — both
        non-decreasing because ranks slice the stream contiguously."""
        cached = self.__dict__.get("_raw_bounds_cache")
        if cached is None:
            starts = np.asarray([r.offset for r in self.ranks], np.int64)
            ends = starts + np.asarray(
                [r.raw_size for r in self.ranks], np.int64
            )
            cached = self.__dict__["_raw_bounds_cache"] = (starts, ends)
        return cached

    def ranks_covering(self, raw_a: int, raw_b: int) -> List[int]:
        """Ranks whose raw segment intersects ``[raw_a, raw_b)``.

        Two ``np.searchsorted`` calls over the cached prefix arrays — a
        partial restore of thousands of leaves at paper-scale world
        sizes must not do a linear Python scan per leaf."""
        if raw_b <= raw_a:
            return []
        starts, ends = self._raw_bounds()
        lo = int(np.searchsorted(ends, raw_a, side="right"))
        hi = int(np.searchsorted(starts, raw_b, side="left"))
        return [r for r in range(lo, hi) if ends[r] > starts[r]]

    def to_json(self) -> str:
        d = {
            "step": self.step,
            "total_raw_bytes": self.total_raw_bytes,
            "codec": self.codec,
            "base_step": self.base_step,
            "world_size": self.world_size,
            "procs_per_node": self.procs_per_node,
            "leaves": [asdict(l) for l in self.leaves],
            "ranks": [asdict(r) for r in self.ranks],
            "precodec": self.precodec,
            "codec_impl": self.codec_impl,
            "chunk_size": self.chunk_size,
            "chunks": self.chunks.to_json_obj() if self.chunks is not None else None,
            "strategy": self.strategy,
            "files": self.files,
            "placement": self.placement.to_json_obj(),
            "status": self.status,
        }
        return json.dumps(d, separators=(",", ":"))

    @staticmethod
    def from_json(s: str) -> "Manifest":
        d = json.loads(s)
        d.pop("_raw_bounds_cache", None)  # legacy manifests may carry it
        d["leaves"] = [LeafEntry(name=l["name"], dtype=l["dtype"],
                                 shape=tuple(l["shape"]), offset=l["offset"],
                                 size=l["size"]) for l in d["leaves"]]
        d["ranks"] = [RankEntry(**r) for r in d["ranks"]]
        d["placement"] = Placement.from_json_obj(d.get("placement"))
        d["chunks"] = ChunkTable.from_json_obj(d.get("chunks"))
        d.setdefault("chunk_size", 0)
        # legacy (pre-chunk-framing) manifests were zstd-only
        if "codec_impl" not in d or d["codec_impl"] is None:
            d["codec_impl"] = "zstd" if d.get("codec", "none") != "none" else ""
        return Manifest(**d)


# ---------------------------------------------------------------------------
# pytree -> logical stream
# ---------------------------------------------------------------------------


def _leaf_to_np(leaf: Any) -> np.ndarray:
    if isinstance(leaf, jax.Array):
        return np.asarray(leaf)
    return np.asarray(leaf)


Buffer = Union[bytes, bytearray, memoryview]


def serialize_tree(
    state: Any, *, pool: Optional[Executor] = None
) -> Tuple[memoryview, List[LeafEntry]]:
    """Pytree -> one logical byte stream, written in place.

    Leaf sizes are computed first, then every leaf is copied *directly*
    into its slice of one preallocated buffer (``np.copyto`` through a
    dtype view — C-order, like ``tobytes()``): one copy per leaf total,
    no per-leaf ``tobytes`` temporaries, no ``b"".join`` recopy of the
    whole stream.  Leaf slices are disjoint, so with ``pool`` the copies
    run concurrently (``np.copyto`` releases the GIL on large arrays).
    Returns a read-only :class:`memoryview`; downstream consumers
    (:func:`encode_state`, CRC, L1 writes) slice it without copying.
    The seed item-loop implementation survives as
    :func:`repro.core.serialize_ref.serialize_tree_reference` and the
    equivalence tests prove the streams byte-identical.
    """
    named, _ = flatten_with_names(state)
    arrs = [_leaf_to_np(leaf) for _, leaf in named]
    leaves: List[LeafEntry] = []
    off = 0
    for (name, _), arr in zip(named, arrs):
        size = int(arr.nbytes)
        leaves.append(
            LeafEntry(
                name=name, dtype=str(arr.dtype), shape=tuple(arr.shape),
                offset=off, size=size,
            )
        )
        off += size
    buf = np.empty(off, np.uint8)

    def copy_leaf(job: Tuple[LeafEntry, np.ndarray]) -> None:
        entry, arr = job
        if entry.size == 0:
            return
        dst = buf[entry.offset : entry.offset + entry.size]
        np.copyto(dst.view(arr.dtype).reshape(arr.shape), arr, casting="no")

    jobs = list(zip(leaves, arrs))
    if pool is not None and len(jobs) > 1:
        list(pool.map(copy_leaf, jobs))
    else:
        for j in jobs:
            copy_leaf(j)
    return memoryview(buf).toreadonly(), leaves


def deserialize_tree(stream: Buffer, leaves: Sequence[LeafEntry], target: Any) -> Any:
    """Fill `target`'s structure with leaf values from the stream.

    `target` may contain arrays or jax.ShapeDtypeStructs; only the
    structure is used.  Leaf order must match the saved order (name
    mismatches raise).
    """
    named, treedef = flatten_with_names(target)
    if len(named) != len(leaves):
        raise ValueError(
            f"target has {len(named)} leaves, checkpoint has {len(leaves)}"
        )
    vals = []
    for (name, _), entry in zip(named, leaves):
        if name != entry.name:
            raise ValueError(f"leaf mismatch: target {name!r} vs saved {entry.name!r}")
        buf = stream[entry.offset : entry.offset + entry.size]
        arr = np.frombuffer(buf, dtype=np.dtype(entry.dtype)).reshape(entry.shape)
        vals.append(arr.copy())
    return jax.tree_util.tree_unflatten(treedef, vals)


# ---------------------------------------------------------------------------
# logical stream -> per-rank blobs (+ codecs)
# ---------------------------------------------------------------------------


def split_ranks(
    total: int, world_size: int, *, sizes: Optional[Sequence[int]] = None
) -> List[Tuple[int, int]]:
    """(offset, size) per rank.  Balanced contiguous split by default."""
    if sizes is not None:
        if sum(sizes) != total or len(sizes) != world_size:
            raise ValueError("explicit sizes must sum to total")
        out, off = [], 0
        for s in sizes:
            out.append((off, int(s)))
            off += int(s)
        return out
    base, rem = divmod(total, world_size)
    out, off = [], 0
    for r in range(world_size):
        s = base + (1 if r < rem else 0)
        out.append((off, s))
        off += s
    return out


def chunk_aligned_sizes(total: int, world_size: int, chunk_size: int) -> List[int]:
    """Per-rank sizes whose boundaries all fall on ``chunk_size``
    multiples of the *global* stream (last rank ragged).

    The device pre-codec chunks the whole stream in one fused launch;
    aligning the rank split to the same grid makes every per-rank chunk
    a global chunk, so the device dirty mask and digests index straight
    into each rank's :func:`encode_rank_chunks` call.  Chunks are
    spread across ranks as evenly as chunk granularity allows; ranks
    may be empty when there are fewer chunks than ranks.
    """
    if chunk_size <= 0:
        raise ValueError("chunk_aligned_sizes requires chunk_size > 0")
    n_chunks = -(-total // chunk_size) if total else 0
    per, rem = divmod(n_chunks, world_size)
    sizes, off_c = [], 0
    for r in range(world_size):
        c = per + (1 if r < rem else 0)
        a = min(off_c * chunk_size, total)
        b = min((off_c + c) * chunk_size, total)
        sizes.append(b - a)
        off_c += c
    return sizes


# -- compression backends ---------------------------------------------------
#
# One compressor/decompressor object per worker thread: the chunked
# encode/decode paths call into the backend once per chunk, and zstd
# context construction (dictionaries, window allocation) must not be
# paid inside that loop.  ``zlib`` is the stdlib fallback backend so the
# codec matrix runs (and is benchmarked) without the optional dep; the
# backend an encode actually used is recorded in the manifest
# (``codec_impl``) and decode dispatches on it.

ZSTD_LEVEL = 3
# The zlib fallback is tuned for throughput, not density: level 1 with
# the Z_RLE strategy (run-length matches + Huffman literals) compresses
# checkpoint-shaped data (zero runs of sparse optimizer moments,
# low-entropy mantissas) 1.5-2x faster than default deflate at an equal
# or better ratio, which is what the codec tier needs — it exists to cut
# PFS volume without growing the blocking window.  Output is a standard
# deflate stream; ``zlib.decompress`` is unaffected.
ZLIB_LEVEL = 1

_codec_tls = threading.local()


def default_codec_impl() -> str:
    """Backend used for new checkpoints: zstd when available, else zlib."""
    return "zstd" if _zstd is not None else "zlib"


def _zstd_c(data: Buffer, level: int = ZSTD_LEVEL) -> bytes:
    """zstd-compress with a per-thread (per-level) compressor reuse."""
    if _zstd is None:
        raise RuntimeError("zstandard not available")
    cache = getattr(_codec_tls, "zstd_c", None)
    if cache is None:
        cache = _codec_tls.zstd_c = {}
    c = cache.get(level)
    if c is None:
        c = cache[level] = _zstd.ZstdCompressor(level=level)
    return c.compress(data)


def _zstd_d(data: Buffer, raw_size: int) -> bytes:
    """zstd-decompress with a per-thread decompressor reuse."""
    if _zstd is None:
        raise RuntimeError("zstandard not available")
    d = getattr(_codec_tls, "zstd_d", None)
    if d is None:
        d = _codec_tls.zstd_d = _zstd.ZstdDecompressor()
    return d.decompress(data, max_output_size=max(raw_size, 1))


def compress_bytes(data: Buffer, impl: str) -> bytes:
    if impl == "zstd":
        return _zstd_c(data)
    if impl == "zlib":
        # compressobj per call: zlib contexts are a cheap malloc (unlike
        # zstd's, which get the thread-local treatment above) and expose
        # the strategy knob that plain zlib.compress hides
        co = _zlib.compressobj(ZLIB_LEVEL, _zlib.DEFLATED, 15, 8, _zlib.Z_RLE)
        return co.compress(data) + co.flush()
    raise ValueError(f"unknown codec impl {impl!r}")


def decompress_bytes(data: Buffer, raw_size: int, impl: str) -> bytes:
    if impl == "zstd":
        return _zstd_d(data, raw_size)
    if impl == "zlib":
        return _zlib.decompress(data)
    raise ValueError(f"unknown codec impl {impl!r}")


def encode_blob_reference(
    raw: Buffer, codec: str, base: Optional[Buffer] = None,
    *, impl: Optional[str] = None,
) -> Buffer:
    """Seed whole-blob encode — the executable spec of the chunked path
    (and the stored format of ``chunk_size=0`` / legacy checkpoints):
    one compressor call over the entire rank blob, delta as a
    full-stream XOR."""
    impl = impl or default_codec_impl()
    if codec == "none":
        return raw
    if codec == "zstd":
        return compress_bytes(raw, impl)
    if codec == "zstd+delta":
        if base is not None and len(base) == len(raw):
            x = np.bitwise_xor(
                np.frombuffer(raw, np.uint8), np.frombuffer(base, np.uint8)
            ).tobytes()
            return compress_bytes(x, impl)
        return compress_bytes(raw, impl)  # no base -> self-contained
    raise ValueError(f"unknown codec {codec!r}")


def decode_blob_reference(
    stored: Buffer, codec: str, raw_size: int, base: Optional[Buffer] = None,
    *, has_base: bool = False, impl: Optional[str] = None,
) -> bytes:
    """Seed whole-blob decode (inverse of :func:`encode_blob_reference`)."""
    impl = impl or default_codec_impl()
    if codec == "none":
        return stored
    if codec == "zstd":
        return decompress_bytes(stored, raw_size, impl)
    if codec == "zstd+delta":
        x = decompress_bytes(stored, raw_size, impl)
        if has_base:
            if base is None or len(base) != len(x):
                raise ValueError("delta blob requires its base blob")
            return np.bitwise_xor(
                np.frombuffer(x, np.uint8), np.frombuffer(base, np.uint8)
            ).tobytes()
        return x
    raise ValueError(f"unknown codec {codec!r}")


# Back-compat aliases (serialize_ref and legacy callers import these).
encode_blob = encode_blob_reference
decode_blob = decode_blob_reference


# ---------------------------------------------------------------------------
# Chunk-framed codecs
# ---------------------------------------------------------------------------

# Chunk flags (bitfield, one int64 per chunk):
CHUNK_COMP = 0    # stored payload = compress(raw chunk)
CHUNK_RAW = 1     # stored payload = raw chunk verbatim (incompressible)
CHUNK_BASE = 2    # no payload: chunk byte-equal to the base's range
CHUNK_DELTA = 4   # stored payload = compress(raw XOR base-range)

DEFAULT_CHUNK_SIZE = 1 << 20

# Compressibility probe: before compressing a large chunk, compress two
# small samples (head + middle); if they barely shrink, the chunk is
# high-entropy (dense fp mantissas) and is stored CHUNK_RAW without
# paying for a full compression pass that would only buy a few percent.
# Compressing incompressible tensors is where a whole-blob codec burns
# most of its blocking time on real train states (dense weights next to
# sparse optimizer moments); chunk framing is what makes the skip
# decision local and cheap.  Lossless either way — the probe only
# trades a sliver of stored ratio for encode speed.
PROBE_SAMPLE = 4096           # bytes per sample, two samples per chunk
PROBE_MIN_CHUNK = 4 * PROBE_SAMPLE   # probe only chunks worth skipping
PROBE_RATIO = 0.9             # a <10% shrink is not worth the pass


@dataclass(eq=False)
class ChunkTable:
    """Structure-of-arrays chunk framing of every rank's stored blob.

    One row per chunk, rows grouped by rank (``rank_starts[r] ..
    rank_starts[r+1]`` are rank ``r``'s rows, in chunk order).  Parallel
    int64 columns:

    * ``raw_off``    — chunk offset inside the rank's *raw* segment
    * ``raw_len``    — raw chunk length (> 0; last chunk may be ragged)
    * ``stored_off`` — payload offset inside the rank's *stored* blob
    * ``stored_len`` — payload length (0 iff ``CHUNK_BASE``)
    * ``crc``        — crc32 of the stored payload (0 iff ``CHUNK_BASE``)
    * ``flags``      — ``CHUNK_COMP`` | ``CHUNK_RAW`` | ``CHUNK_BASE`` |
      ``CHUNK_DELTA``

    ``digest`` is an optional uint64 column of per-chunk two-track
    digests of the *raw* chunk bytes (``repro.kernels.checksum``
    semantics, index track restarted per chunk) — present on manifests
    encoded through the device pre-codec, where the fused pass computes
    them for free during its delta sweep.  Unlike ``crc`` (which covers
    the stored payload and is 0 for ``CHUNK_BASE`` rows), ``digest``
    covers the decoded content of *every* row, so decode can verify
    base-referenced chunks — i.e. that the resolved base stream really
    is the one the delta was taken against.

    Invariants (asserted by :meth:`validate`): per rank, ``raw`` rows
    tile ``[0, raw_size)`` exactly and ``stored`` rows tile
    ``[0, stored_size)`` exactly (base-referencing rows contribute zero
    stored bytes) — the chunk-granular restatement of the flush
    validator's source-coverage rule, which is what lets
    ``build_read_plan`` treat chunk payloads as ordinary stored-space
    extents.  ``CHUNK_BASE``/``CHUNK_DELTA`` rows may only appear in
    manifests whose ``base_step`` is set.
    """

    rank_starts: np.ndarray
    raw_off: np.ndarray
    raw_len: np.ndarray
    stored_off: np.ndarray
    stored_len: np.ndarray
    crc: np.ndarray
    flags: np.ndarray
    digest: Optional[np.ndarray] = None

    _COLS = ("raw_off", "raw_len", "stored_off", "stored_len", "crc", "flags")

    def __post_init__(self):
        self.rank_starts = np.asarray(self.rank_starts, np.int64)
        for c in self._COLS:
            setattr(self, c, np.asarray(getattr(self, c), dtype=np.int64))
        if len({getattr(self, c).shape for c in self._COLS}) != 1:
            raise ValueError("ChunkTable columns must have identical length")
        if self.digest is not None:
            self.digest = np.asarray(self.digest, dtype=np.uint64)
            if self.digest.shape != self.raw_off.shape:
                raise ValueError("ChunkTable digest column length mismatch")

    def __len__(self) -> int:
        return len(self.raw_off)

    def __eq__(self, other) -> bool:
        if not isinstance(other, ChunkTable):
            return NotImplemented
        if (self.digest is None) != (other.digest is None) or (
            self.digest is not None
            and not np.array_equal(self.digest, other.digest)
        ):
            return False
        return np.array_equal(self.rank_starts, other.rank_starts) and all(
            np.array_equal(getattr(self, c), getattr(other, c))
            for c in self._COLS
        )

    @property
    def n_ranks(self) -> int:
        return len(self.rank_starts) - 1

    def rank_rows(self, rank: int) -> slice:
        return slice(int(self.rank_starts[rank]), int(self.rank_starts[rank + 1]))

    def covering(self, rank: int, lo: int, hi: int) -> np.ndarray:
        """Global row indices of ``rank``'s chunks intersecting the
        within-rank raw interval ``[lo, hi)`` (empty for hi <= lo)."""
        if hi <= lo:
            return np.empty(0, np.int64)
        s, e = int(self.rank_starts[rank]), int(self.rank_starts[rank + 1])
        ro = self.raw_off[s:e]
        first = int(np.searchsorted(ro, lo, side="right")) - 1
        last = int(np.searchsorted(ro, hi - 1, side="right")) - 1
        return np.arange(s + max(first, 0), s + last + 1, dtype=np.int64)

    def validate(self, ranks: Sequence["RankEntry"]) -> None:
        """Assert the tiling invariants against the manifest rank table.

        Array program over the whole table (same style as
        ``validate_plan``): boundary masks from ``rank_starts`` replace
        the per-rank Python loop, so validating a paper-scale table on
        every restore costs milliseconds, not a serial O(n_ranks) pass.
        """
        if self.n_ranks != len(ranks):
            raise ValueError("chunk table rank count mismatch")
        starts = self.rank_starts
        counts = np.diff(starts)
        if (counts < 0).any() or int(starts[0]) != 0 or int(starts[-1]) != len(self):
            raise ValueError("chunk table rank_starts malformed")
        raw_sizes = np.asarray([r.raw_size for r in ranks], np.int64)
        stored_sizes = np.asarray([r.stored_size for r in ranks], np.int64)
        nz = counts > 0
        bad = (raw_sizes > 0) != nz
        if bad.any():
            r = int(np.flatnonzero(bad)[0])
            raise ValueError(
                f"rank {r}: "
                + ("empty but has chunks" if not raw_sizes[r]
                   else "chunk raw rows do not tile raw segment")
            )
        n = len(self)
        if n == 0:
            return
        if int(self.raw_len.min()) <= 0:
            raise ValueError("non-positive raw chunk length")
        # first/last row of every non-empty rank
        f = starts[:-1][nz]
        l = starts[1:][nz] - 1
        raw_ends = self.raw_off + self.raw_len
        stored_ends = self.stored_off + self.stored_len
        # chain within ranks: every row that is not a rank's last must be
        # followed by a row starting where it ends
        is_last = np.zeros(n, bool)
        is_last[starts[1:] - 1] = True
        chain = ~is_last[:-1]
        if (
            (self.raw_off[f] != 0).any()
            or (raw_ends[l] != raw_sizes[nz]).any()
            or (chain & (self.raw_off[1:] != raw_ends[:-1])).any()
        ):
            raise ValueError("chunk raw rows do not tile the raw segments")
        if (
            (self.stored_off[f] != 0).any()
            or (stored_ends[l] != stored_sizes[nz]).any()
            or (chain & (self.stored_off[1:] != stored_ends[:-1])).any()
        ):
            raise ValueError("chunk stored rows do not tile the stored blobs")
        base_rows = (self.flags & CHUNK_BASE) != 0
        if (self.stored_len[base_rows] != 0).any() or (
            self.stored_len[~base_rows] <= 0
        ).any():
            raise ValueError("stored_len inconsistent with flags")

    @staticmethod
    def from_rank_lists(per_rank: Sequence[Tuple[List[int], ...]]) -> "ChunkTable":
        """Assemble from per-rank column lists (encode's output), in
        rank order.  Each element is (raw_off, raw_len, stored_off,
        stored_len, crc, flags) lists for that rank."""
        counts = [len(p[0]) for p in per_rank]
        starts = np.zeros(len(per_rank) + 1, np.int64)
        np.cumsum(np.asarray(counts, np.int64), out=starts[1:])
        cols = [
            np.asarray([v for p in per_rank for v in p[i]], np.int64)
            for i in range(6)
        ]
        return ChunkTable(starts, *cols)

    def to_json_obj(self) -> Dict[str, Any]:
        obj = {
            "rank_starts": self.rank_starts.tolist(),
            **{c: getattr(self, c).tolist() for c in self._COLS},
        }
        if self.digest is not None:
            obj["digest"] = [int(d) for d in self.digest]
        return obj

    @staticmethod
    def from_json_obj(obj: Any) -> Optional["ChunkTable"]:
        if not obj:
            return None
        return ChunkTable(
            rank_starts=obj["rank_starts"],
            **{c: obj[c] for c in ChunkTable._COLS},
            digest=obj.get("digest"),
        )


def encode_rank_chunks(
    raw: Buffer,
    base: Optional[Buffer],
    codec: str,
    chunk_size: int,
    impl: str,
    *,
    dirty: Optional[Sequence[bool]] = None,
    deltas: Optional[Sequence[Optional[np.ndarray]]] = None,
) -> Tuple[Buffer, Tuple[List[int], ...]]:
    """Chunk-frame one rank's raw segment into its stored blob.

    Every ``chunk_size`` slice is transformed independently: compressed
    (``CHUNK_COMP``), stored raw when compression does not pay
    (``CHUNK_RAW``), or — under delta with a base — XOR-compressed
    against the base's matching range (``CHUNK_DELTA``) or elided
    entirely when byte-equal to it (``CHUNK_BASE``, zero stored bytes).
    The dirty-chunk comparison and the XOR are vectorized over the
    chunk's uint8 views; nothing here copies the raw stream beyond the
    one XOR scratch per dirty chunk.

    Staged mode (device pre-codec): when ``dirty`` is given, the
    per-chunk ``np.array_equal`` scan and the host XOR are skipped —
    the fused device pass already decided cleanliness and produced the
    XOR payloads.  ``dirty[i]`` is the within-rank chunk's dirtiness
    and ``deltas[i]`` its precomputed XOR bytes (``None`` for clean
    chunks); ``base`` is not consulted.  The probe/compress/flag logic
    is byte-identical to the host path, so staged and host encodes of
    the same rank segment produce the same stored blob.

    Returns the assembled stored blob plus the per-chunk column lists
    for :meth:`ChunkTable.from_rank_lists`.
    """
    n = len(raw)
    cols: Tuple[List[int], ...] = ([], [], [], [], [], [])
    raw_off, raw_len, stored_off, stored_len, crcs, flags = cols
    if n == 0:
        return b"", cols
    rv = np.frombuffer(raw, np.uint8)
    staged = dirty is not None
    bv = (
        np.frombuffer(base, np.uint8)
        if (
            not staged
            and codec == "zstd+delta"
            and base is not None
            and len(base) == n
        )
        else None
    )

    def probably_incompressible(data: np.ndarray) -> bool:
        ln = len(data)
        if ln < PROBE_MIN_CHUNK:
            return False               # small chunks: just compress them
        mid = (ln // 2) & ~7
        sample = np.concatenate(
            (data[:PROBE_SAMPLE], data[mid : mid + PROBE_SAMPLE])
        )
        # the probe is a heuristic signal, not the stored format, so it
        # always uses the one-call stdlib compressor: per-sample
        # compressobj construction would cost more than the sample
        return len(_zlib.compress(sample, 1)) >= PROBE_RATIO * len(sample)

    out = bytearray()
    for ci, off in enumerate(range(0, n, chunk_size)):
        ln = min(chunk_size, n - off)
        rc = rv[off : off + ln]
        # CHUNK_RAW payloads append the chunk view directly (one copy,
        # hashed in place) — raw-heavy blobs must not pay a tobytes
        # round trip per chunk on top of the bytearray append.
        payload: Optional[bytes] = None
        if staged or bv is not None:
            if staged:
                clean = not dirty[ci]
                x = None if clean else deltas[ci]
            else:
                bc = bv[off : off + ln]
                clean = np.array_equal(rc, bc)
                x = None if clean else np.bitwise_xor(rc, bc)
            if clean:
                payload, flag = b"", CHUNK_BASE
            elif probably_incompressible(x):
                flag = CHUNK_RAW
            else:
                comp = compress_bytes(x, impl)
                if len(comp) < ln:
                    payload, flag = comp, CHUNK_DELTA
                else:  # XOR didn't pay: store the raw chunk, self-contained
                    flag = CHUNK_RAW
        elif probably_incompressible(rc):
            flag = CHUNK_RAW
        else:
            comp = compress_bytes(rc, impl)
            if len(comp) < ln:
                payload, flag = comp, CHUNK_COMP
            else:
                flag = CHUNK_RAW
        raw_off.append(off)
        raw_len.append(ln)
        stored_off.append(len(out))
        if flag == CHUNK_RAW:
            stored_len.append(ln)
            crcs.append(crc32(rc))
            out += memoryview(rc)
        else:
            stored_len.append(len(payload))
            crcs.append(crc32(payload) if payload else 0)
            out += payload
        flags.append(flag)
    # hand back the bytearray itself: crc32, the L1 sink and the flush
    # path all take arbitrary buffers, and a bytes() here would recopy
    # nearly the whole state (raw-heavy blobs) inside the blocking window
    return out, cols


def decode_chunk_into(
    dst: np.ndarray,
    payload: Buffer,
    flag: int,
    crc: int,
    raw_len: int,
    base_seg: Optional[Buffer],
    impl: str,
    *,
    verify: bool = True,
    digest: Optional[int] = None,
    what: str = "chunk",
) -> None:
    """Decode one chunk directly into its slice of the output stream.

    ``dst`` is the preallocated uint8 view of the chunk's raw range —
    no ``b"".join``, no per-chunk output ``bytes``; the only temporary
    is the decompressor's output for compressed chunks.  ``verify``
    checks the chunk's stored-payload CRC first, so corruption is
    attributed to a single chunk even on sub-blob (partial-restore)
    reads where no whole-blob CRC exists.

    ``digest``, when given (manifests with a :class:`ChunkTable`
    ``digest`` column), is checked against the *decoded* raw bytes —
    this also covers ``CHUNK_BASE``/``CHUNK_DELTA`` rows, whose
    correctness otherwise depends on resolving the right base stream.
    """
    if flag & CHUNK_BASE:
        if base_seg is None or len(base_seg) != raw_len:
            raise IOError(f"{what}: base-referencing chunk without its base")
        np.copyto(dst, np.frombuffer(base_seg, np.uint8))
    elif verify and crc32(payload) != crc:
        raise IOError(f"{what}: chunk checksum mismatch")
    elif flag & CHUNK_RAW:
        if len(payload) != raw_len:
            raise IOError(f"{what}: raw chunk length mismatch")
        np.copyto(dst, np.frombuffer(payload, np.uint8))
    else:
        x = decompress_bytes(payload, raw_len, impl)
        if len(x) != raw_len:
            raise IOError(
                f"{what}: chunk decompressed to {len(x)} of {raw_len} bytes"
            )
        xv = np.frombuffer(x, np.uint8)
        if flag & CHUNK_DELTA:
            if base_seg is None or len(base_seg) != raw_len:
                raise IOError(f"{what}: delta chunk without its base")
            np.bitwise_xor(xv, np.frombuffer(base_seg, np.uint8), out=dst)
        else:
            np.copyto(dst, xv)
    if digest is not None and _raw_chunk_digest(dst) != digest:
        raise IOError(f"{what}: raw chunk digest mismatch")


def _raw_chunk_digest(dst: np.ndarray) -> int:
    """Two-track digest of a decoded chunk's raw bytes (zero-padded to
    a word boundary) — the host oracle for the fused pass's per-chunk
    checksum output."""
    n = dst.size
    rem = (-n) % 4
    if rem:
        w = np.zeros(n + rem, np.uint8)
        w[:n] = dst
    else:
        w = dst
    return digest_ref(w.view(np.uint32))


@dataclass
class EncodedState:
    """One checkpoint, serialized + split + encoded, ready to plan/flush.

    Buffer ownership: with codec ``none`` every entry of ``blobs`` is a
    read-only :class:`memoryview` slice of ``stream`` — the pytree's
    bytes exist exactly once between serialization and the L1 files.
    Compression codecs materialize per-rank ``bytes`` (unavoidably: the
    stored bytes differ from the raw ones).  ``stream`` is kept alive by
    the L0 twin and by delta bases; the views never outlive it.
    """

    step: int
    stream: Buffer                  # raw logical stream (kept for L0/delta)
    blobs: List[Buffer]             # stored (encoded) blob per rank
    manifest: Manifest


def _run_grouped(pool: Optional[Executor], fn, jobs: List, groups: int = 128):
    """Run ``fn`` over ``jobs`` on ``pool``, batched into at most
    ``groups`` tasks (order-preserving).

    At paper scale a save/restore has thousands of per-rank/per-chunk
    work items, each only ~a millisecond; submitting them individually
    spends more time in future bookkeeping and GIL hand-offs than in
    the work.  ~128 groups keeps the pool saturated (work stealing
    still balances stragglers) at 1/8th the scheduling traffic.
    """
    if pool is None or len(jobs) <= 1:
        return [fn(j) for j in jobs]
    size = max(1, -(-len(jobs) // groups))
    batches = [jobs[i : i + size] for i in range(0, len(jobs), size)]
    out: List = []
    for chunk in pool.map(lambda b: [fn(j) for j in b], batches):
        out.extend(chunk)
    return out


def encode_state(
    step: int,
    state: Any,
    cluster: ClusterSpec,
    *,
    codec: str = "none",
    base: Optional[EncodedState] = None,
    rank_sizes: Optional[Sequence[int]] = None,
    chunk_aligned: bool = False,
    pool: Optional[Executor] = None,
    rank_sink: Optional[Any] = None,
    chunk_size: int = DEFAULT_CHUNK_SIZE,
) -> EncodedState:
    """Serialize + split + encode one checkpoint.

    ``chunk_aligned=True`` derives ``rank_sizes`` from
    :func:`chunk_aligned_sizes`, so rank boundaries land on the global
    ``chunk_size`` grid — the same split :func:`encode_state_staged`
    uses, which makes host and device-staged encodings of the same
    state byte-comparable (no per-rank tail chunks).

    Zero-copy contract: rank blobs are memoryview slices of the stream
    (codec ``none`` stores them as-is — zero extra copies between the
    pytree and the L1 files), and :func:`~repro.core.integrity.crc32`
    hashes the views in place.

    Compression codecs are chunk-framed (see the module doc): each
    rank's task cuts its raw segment into ``chunk_size`` chunks and
    transforms them with the per-thread compressor, so at any world
    size above one, chunks compress in parallel across the pool's
    workers.  ``chunk_size=0`` selects the seed whole-blob framing
    (:func:`encode_blob_reference`) — the format of legacy checkpoints.

    ``pool`` runs the per-rank work concurrently; ``rank_sink(rank,
    blob)``, when given, is called inside each rank's task right after
    its CRC — the engine injects the L1 write here, so encode + CRC +
    node-local drain are **one fused parallel phase**: CRC (holding the
    GIL) of one rank overlaps the file write (GIL released) of another
    instead of running as two barriers.
    """
    stream, leaves = serialize_tree(state, pool=pool)
    total = len(stream)
    if chunk_aligned and rank_sizes is None and chunk_size > 0:
        rank_sizes = chunk_aligned_sizes(total, cluster.world_size, chunk_size)
    parts = split_ranks(total, cluster.world_size, sizes=rank_sizes)
    base_ok = (
        base is not None
        and codec == "zstd+delta"
        and len(base.stream) == total
        and [
            (r.offset, r.raw_size) for r in base.manifest.ranks
        ] == list(parts)
    )
    chunked = codec != "none" and chunk_size > 0
    impl = default_codec_impl() if codec != "none" else ""

    def encode_rank(job: Tuple[int, int, int]):
        r, off, size = job
        raw = stream[off : off + size]
        base_seg = base.stream[off : off + size] if base_ok else None
        if chunked:
            b, cols = encode_rank_chunks(raw, base_seg, codec, chunk_size, impl)
        else:
            b, cols = encode_blob_reference(raw, codec, base_seg, impl=impl), None
        entry = RankEntry(
            rank=r, offset=off, raw_size=size, stored_size=len(b),
            crc=crc32(b),
        )
        if rank_sink is not None:
            rank_sink(r, b)
        return b, entry, cols

    jobs = [(r, off, size) for r, (off, size) in enumerate(parts)]
    results = _run_grouped(pool, encode_rank, jobs)
    blobs = [b for b, _, _ in results]
    ranks = [e for _, e, _ in results]
    man = Manifest(
        step=step,
        total_raw_bytes=total,
        codec=codec,
        base_step=base.step if base_ok else None,
        world_size=cluster.world_size,
        procs_per_node=cluster.procs_per_node,
        leaves=leaves,
        ranks=ranks,
        codec_impl=impl,
        chunk_size=chunk_size if chunked else 0,
        chunks=(
            ChunkTable.from_rank_lists([c for _, _, c in results])
            if chunked
            else None
        ),
    )
    return EncodedState(step=step, stream=stream, blobs=blobs, manifest=man)


def encode_state_staged(
    step: int,
    cluster: ClusterSpec,
    *,
    stream: Buffer,
    leaves: List[LeafEntry],
    chunk_size: int,
    base_step: Optional[int],
    dirty: Optional[np.ndarray],
    deltas: Optional[Dict[int, np.ndarray]],
    digests: np.ndarray,
    pool: Optional[Executor] = None,
    rank_sink: Optional[Any] = None,
) -> EncodedState:
    """Encode a checkpoint from device pre-codec staging buffers.

    The staged twin of :func:`encode_state` for ``zstd+delta``: the
    pytree was already serialized on device (``stream`` is the staged
    host copy, ``leaves`` its table) and the fused pass already chunked
    it — ``dirty`` is the global per-chunk mask, ``deltas`` maps dirty
    global chunk indices to their XOR payloads, and ``digests`` the
    per-chunk raw digests that become the manifest's digest column.

    The rank split is :func:`chunk_aligned_sizes`, so global chunk
    ``i`` is exactly within-rank chunk ``i - off // chunk_size`` of its
    owner and the mask/payloads slice straight into each rank's
    :func:`encode_rank_chunks` call.  With ``base_step=None`` (anchor
    saves, or a device base miss) each rank encodes through the plain
    no-base host path — the stored blobs stay byte-identical to a host
    ``encode_state`` of the same stream over the same split.
    """
    total = len(stream)
    n_chunks = -(-total // chunk_size) if total else 0
    digests = np.asarray(digests, np.uint64)
    if len(digests) != n_chunks:
        raise ValueError(
            f"staged digests cover {len(digests)} chunks, stream has {n_chunks}"
        )
    delta_mode = base_step is not None
    if delta_mode and (dirty is None or len(dirty) != n_chunks):
        raise ValueError("staged delta encode requires a full dirty mask")
    parts = split_ranks(
        total, cluster.world_size,
        sizes=chunk_aligned_sizes(total, cluster.world_size, chunk_size),
    )
    impl = default_codec_impl()

    def encode_rank(job: Tuple[int, int, int]):
        r, off, size = job
        raw = stream[off : off + size]
        if delta_mode and size:
            c0 = off // chunk_size
            nc = -(-size // chunk_size)
            d = dirty[c0 : c0 + nc]
            x = [deltas[c0 + i] if d[i] else None for i in range(nc)]
            b, cols = encode_rank_chunks(
                raw, None, "zstd+delta", chunk_size, impl, dirty=d, deltas=x
            )
        else:
            b, cols = encode_rank_chunks(raw, None, "zstd+delta", chunk_size, impl)
        entry = RankEntry(
            rank=r, offset=off, raw_size=size, stored_size=len(b),
            crc=crc32(b),
        )
        if rank_sink is not None:
            rank_sink(r, b)
        return b, entry, cols

    jobs = [(r, off, size) for r, (off, size) in enumerate(parts)]
    results = _run_grouped(pool, encode_rank, jobs)
    table = ChunkTable.from_rank_lists([c for _, _, c in results])
    table.digest = digests
    man = Manifest(
        step=step,
        total_raw_bytes=total,
        codec="zstd+delta",
        base_step=base_step,
        world_size=cluster.world_size,
        procs_per_node=cluster.procs_per_node,
        leaves=leaves,
        ranks=[e for _, e, _ in results],
        codec_impl=impl,
        chunk_size=chunk_size,
        chunks=table,
    )
    return EncodedState(
        step=step, stream=stream, blobs=[b for b, _, _ in results], manifest=man
    )


def decode_stream(
    manifest: Manifest,
    blobs: Sequence[Buffer],
    *,
    base_stream: Optional[Buffer] = None,
    verify: bool = True,
    pool: Optional[Executor] = None,
) -> memoryview:
    """Rank blobs -> the raw logical stream, written in place.

    The decode twin of the zero-copy encode: one ``uint8`` output
    buffer is preallocated and every chunk (chunk-framed manifests) or
    rank blob (codec ``none`` / legacy whole-blob manifests)
    decompresses/copies *directly into its slice* — no ``b"".join``, no
    per-chunk ``bytes`` churn.  Slices are disjoint, so with ``pool``
    the work runs concurrently (decompression and ``np.copyto`` release
    the GIL).

    Integrity: chunk-framed manifests verify the per-chunk CRCs inside
    the (pooled) chunk tasks — same coverage as the rank CRC, since
    chunk payloads tile the stored blob, but parallel and attributable
    to a single chunk.  Whole-blob manifests verify per-rank CRCs, also
    on the pool.  Callers that already verified arrival CRCs pass
    ``verify=False``.
    """
    has_base = manifest.base_step is not None
    out = np.empty(manifest.total_raw_bytes, np.uint8)
    if len(blobs) != len(manifest.ranks):
        raise IOError("blob count does not match the manifest rank table")

    def run(fn, jobs) -> None:
        _run_grouped(pool, fn, jobs)

    table = manifest.chunks
    if manifest.codec == "none" or table is None:
        # codec none + legacy whole-blob manifests: per-rank decode.
        def decode_rank(i: int) -> None:
            entry, blob = manifest.ranks[i], blobs[i]
            if verify and crc32(blob) != entry.crc:
                raise IOError(f"rank {entry.rank}: checksum mismatch")
            base = (
                base_stream[entry.offset : entry.offset + entry.raw_size]
                if (base_stream is not None and has_base)
                else None
            )
            raw = decode_blob_reference(
                blob, manifest.codec, entry.raw_size, base,
                has_base=has_base, impl=manifest.codec_impl or None,
            )
            if len(raw) != entry.raw_size:
                raise IOError(f"rank {entry.rank}: decoded to wrong size")
            dst = out[entry.offset : entry.offset + entry.raw_size]
            np.copyto(dst, np.frombuffer(raw, np.uint8))

        run(decode_rank, list(range(len(blobs))))
    else:
        table.validate(manifest.ranks)
        impl = manifest.codec_impl or default_codec_impl()
        rank_of = np.repeat(
            np.arange(table.n_ranks, dtype=np.int64), np.diff(table.rank_starts)
        )
        # memoryviews once per blob: slicing bytes/bytearray copies,
        # slicing a view does not — chunk payloads stay zero-copy
        views = [memoryview(b) for b in blobs]

        def decode_chunk(row: int) -> None:
            r = int(rank_of[row])
            entry = manifest.ranks[r]
            ro = int(table.raw_off[row])
            rl = int(table.raw_len[row])
            so = int(table.stored_off[row])
            sl = int(table.stored_len[row])
            flag = int(table.flags[row])
            g = entry.offset + ro
            base_seg = (
                base_stream[g : g + rl]
                if (base_stream is not None and (flag & (CHUNK_BASE | CHUNK_DELTA)))
                else None
            )
            decode_chunk_into(
                out[g : g + rl], views[r][so : so + sl], flag,
                int(table.crc[row]), rl, base_seg, impl,
                verify=verify,
                digest=(
                    int(table.digest[row])
                    if (verify and table.digest is not None)
                    else None
                ),
                what=f"rank {r} chunk {row - int(table.rank_starts[r])}",
            )

        run(decode_chunk, list(range(len(table))))
    return memoryview(out)


def decode_state(
    manifest: Manifest,
    blobs: Sequence[Buffer],
    target: Any,
    *,
    base_stream: Optional[Buffer] = None,
    verify: bool = True,
    pool: Optional[Executor] = None,
) -> Any:
    stream = decode_stream(
        manifest, blobs, base_stream=base_stream, verify=verify, pool=pool
    )
    if len(stream) != manifest.total_raw_bytes:
        raise IOError("reassembled stream has wrong size")
    return deserialize_tree(stream, manifest.leaves, target)
