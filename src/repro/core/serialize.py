"""State (de)serialization: pytree <-> per-rank byte blobs + manifest.

VELOC semantics: each *process* checkpoints its own bytes.  On a real
multi-host deployment those are the host's addressable shards of every
array; in this single-process framework we serialize the global state to
one logical byte stream and split it into ``world_size`` contiguous
rank blobs — byte-identical reassembly, and the aggregation strategies
only ever see the per-rank sizes.

The manifest stores the leaf table (name/dtype/shape/offset) and the rank
table (offset/size/crc), so restore can:

* reassemble from any subset of levels (PFS aggregate file, per-rank
  files, node-local files),
* verify integrity per rank blob,
* **re-shard elastically**: the logical stream is mesh-agnostic, so a
  checkpoint saved from an 8-node layout restores onto 3 nodes (or onto a
  different jax mesh) unchanged.

Codecs (applied per rank blob, after splitting): ``none`` | ``zstd`` |
``zstd+delta`` (XOR against the previous checkpoint's blob, then zstd —
incremental checkpointing).  Codecs change the *stored* sizes that the
flush plan sees; raw sizes are preserved in the manifest.
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax

from repro.core.cluster import ClusterSpec
from repro.core.integrity import crc32
from repro.utils.treelib import flatten_with_names

try:
    import zstandard as _zstd
except Exception:  # pragma: no cover - zstd is an install-time dep
    _zstd = None


@dataclass(frozen=True)
class LeafEntry:
    name: str
    dtype: str
    shape: Tuple[int, ...]
    offset: int
    size: int


@dataclass
class RankEntry:
    rank: int
    offset: int          # offset in the logical stream
    raw_size: int
    stored_size: int
    crc: int             # crc of the *stored* blob


@dataclass
class Manifest:
    step: int
    total_raw_bytes: int
    codec: str
    base_step: Optional[int]          # for delta codecs
    world_size: int
    procs_per_node: int
    leaves: List[LeafEntry]
    ranks: List[RankEntry]
    precodec: str = "none"            # device-side transform (e.g. int8)
    strategy: str = ""
    files: Dict[str, int] = field(default_factory=dict)
    # file layout of each rank's stored blob on the PFS:
    # rank -> list of (file, file_offset, src_offset, size)
    placement: Dict[int, List[Tuple[str, int, int, int]]] = field(default_factory=dict)
    status: str = "pending"           # pending | local_done | flush_done

    def to_json(self) -> str:
        d = asdict(self)
        d["placement"] = {str(k): v for k, v in d["placement"].items()}
        return json.dumps(d)

    @staticmethod
    def from_json(s: str) -> "Manifest":
        d = json.loads(s)
        d["leaves"] = [LeafEntry(name=l["name"], dtype=l["dtype"],
                                 shape=tuple(l["shape"]), offset=l["offset"],
                                 size=l["size"]) for l in d["leaves"]]
        d["ranks"] = [RankEntry(**r) for r in d["ranks"]]
        d["placement"] = {
            int(k): [tuple(x) for x in v] for k, v in d["placement"].items()
        }
        return Manifest(**d)


# ---------------------------------------------------------------------------
# pytree -> logical stream
# ---------------------------------------------------------------------------


def _leaf_to_np(leaf: Any) -> np.ndarray:
    if isinstance(leaf, jax.Array):
        return np.asarray(leaf)
    return np.asarray(leaf)


def serialize_tree(state: Any) -> Tuple[bytes, List[LeafEntry]]:
    named, _ = flatten_with_names(state)
    chunks: List[bytes] = []
    leaves: List[LeafEntry] = []
    off = 0
    for name, leaf in named:
        arr = _leaf_to_np(leaf)  # tobytes() emits C-order regardless of layout
        raw = arr.tobytes()
        leaves.append(
            LeafEntry(
                name=name, dtype=str(arr.dtype), shape=tuple(arr.shape),
                offset=off, size=len(raw),
            )
        )
        chunks.append(raw)
        off += len(raw)
    return b"".join(chunks), leaves


def deserialize_tree(stream: bytes, leaves: Sequence[LeafEntry], target: Any) -> Any:
    """Fill `target`'s structure with leaf values from the stream.

    `target` may contain arrays or jax.ShapeDtypeStructs; only the
    structure is used.  Leaf order must match the saved order (name
    mismatches raise).
    """
    named, treedef = flatten_with_names(target)
    if len(named) != len(leaves):
        raise ValueError(
            f"target has {len(named)} leaves, checkpoint has {len(leaves)}"
        )
    vals = []
    for (name, _), entry in zip(named, leaves):
        if name != entry.name:
            raise ValueError(f"leaf mismatch: target {name!r} vs saved {entry.name!r}")
        buf = stream[entry.offset : entry.offset + entry.size]
        arr = np.frombuffer(buf, dtype=np.dtype(entry.dtype)).reshape(entry.shape)
        vals.append(arr.copy())
    return jax.tree_util.tree_unflatten(treedef, vals)


# ---------------------------------------------------------------------------
# logical stream -> per-rank blobs (+ codecs)
# ---------------------------------------------------------------------------


def split_ranks(
    total: int, world_size: int, *, sizes: Optional[Sequence[int]] = None
) -> List[Tuple[int, int]]:
    """(offset, size) per rank.  Balanced contiguous split by default."""
    if sizes is not None:
        if sum(sizes) != total or len(sizes) != world_size:
            raise ValueError("explicit sizes must sum to total")
        out, off = [], 0
        for s in sizes:
            out.append((off, int(s)))
            off += int(s)
        return out
    base, rem = divmod(total, world_size)
    out, off = [], 0
    for r in range(world_size):
        s = base + (1 if r < rem else 0)
        out.append((off, s))
        off += s
    return out


def _zstd_c(data: bytes, level: int = 3) -> bytes:
    if _zstd is None:
        raise RuntimeError("zstandard not available")
    return _zstd.ZstdCompressor(level=level).compress(data)


def _zstd_d(data: bytes, raw_size: int) -> bytes:
    if _zstd is None:
        raise RuntimeError("zstandard not available")
    return _zstd.ZstdDecompressor().decompress(data, max_output_size=max(raw_size, 1))


def encode_blob(
    raw: bytes, codec: str, base: Optional[bytes] = None
) -> bytes:
    if codec == "none":
        return raw
    if codec == "zstd":
        return _zstd_c(raw)
    if codec == "zstd+delta":
        if base is not None and len(base) == len(raw):
            x = np.bitwise_xor(
                np.frombuffer(raw, np.uint8), np.frombuffer(base, np.uint8)
            ).tobytes()
            return _zstd_c(x)
        return _zstd_c(raw)  # no base -> plain zstd (self-contained)
    raise ValueError(f"unknown codec {codec!r}")


def decode_blob(
    stored: bytes, codec: str, raw_size: int, base: Optional[bytes] = None,
    *, has_base: bool = False,
) -> bytes:
    if codec == "none":
        return stored
    if codec == "zstd":
        return _zstd_d(stored, raw_size)
    if codec == "zstd+delta":
        x = _zstd_d(stored, raw_size)
        if has_base:
            if base is None or len(base) != len(x):
                raise ValueError("delta blob requires its base blob")
            return np.bitwise_xor(
                np.frombuffer(x, np.uint8), np.frombuffer(base, np.uint8)
            ).tobytes()
        return x
    raise ValueError(f"unknown codec {codec!r}")


@dataclass
class EncodedState:
    """One checkpoint, serialized + split + encoded, ready to plan/flush."""

    step: int
    stream: bytes                   # raw logical stream (kept for L0/delta)
    blobs: List[bytes]              # stored (encoded) blob per rank
    manifest: Manifest


def encode_state(
    step: int,
    state: Any,
    cluster: ClusterSpec,
    *,
    codec: str = "none",
    base: Optional[EncodedState] = None,
    rank_sizes: Optional[Sequence[int]] = None,
) -> EncodedState:
    stream, leaves = serialize_tree(state)
    total = len(stream)
    parts = split_ranks(total, cluster.world_size, sizes=rank_sizes)
    base_ok = (
        base is not None
        and codec == "zstd+delta"
        and len(base.stream) == total
        and [
            (r.offset, r.raw_size) for r in base.manifest.ranks
        ] == list(parts)
    )
    blobs: List[bytes] = []
    ranks: List[RankEntry] = []
    for r, (off, size) in enumerate(parts):
        raw = stream[off : off + size]
        b = encode_blob(
            raw, codec, base.stream[off : off + size] if base_ok else None
        )
        blobs.append(b)
        ranks.append(
            RankEntry(
                rank=r, offset=off, raw_size=size, stored_size=len(b),
                crc=crc32(b),
            )
        )
    man = Manifest(
        step=step,
        total_raw_bytes=total,
        codec=codec,
        base_step=base.step if base_ok else None,
        world_size=cluster.world_size,
        procs_per_node=cluster.procs_per_node,
        leaves=leaves,
        ranks=ranks,
    )
    return EncodedState(step=step, stream=stream, blobs=blobs, manifest=man)


def decode_state(
    manifest: Manifest,
    blobs: Sequence[bytes],
    target: Any,
    *,
    base_stream: Optional[bytes] = None,
    verify: bool = True,
) -> Any:
    parts: List[bytes] = []
    has_base = manifest.base_step is not None
    for entry, blob in zip(manifest.ranks, blobs):
        if verify and crc32(blob) != entry.crc:
            raise IOError(f"rank {entry.rank}: checksum mismatch")
        base = (
            base_stream[entry.offset : entry.offset + entry.raw_size]
            if (base_stream is not None and has_base)
            else None
        )
        parts.append(
            decode_blob(
                blob, manifest.codec, entry.raw_size, base, has_base=has_base
            )
        )
    stream = b"".join(parts)
    if len(stream) != manifest.total_raw_bytes:
        raise IOError("reassembled stream has wrong size")
    return deserialize_tree(stream, manifest.leaves, target)
