"""State (de)serialization: pytree <-> per-rank byte blobs + manifest.

VELOC semantics: each *process* checkpoints its own bytes.  On a real
multi-host deployment those are the host's addressable shards of every
array; in this single-process framework we serialize the global state to
one logical byte stream and split it into ``world_size`` contiguous
rank blobs — byte-identical reassembly, and the aggregation strategies
only ever see the per-rank sizes.

The manifest stores the leaf table (name/dtype/shape/offset) and the rank
table (offset/size/crc), so restore can:

* reassemble from any subset of levels (PFS aggregate file, per-rank
  files, node-local files),
* verify integrity per rank blob,
* **re-shard elastically**: the logical stream is mesh-agnostic, so a
  checkpoint saved from an 8-node layout restores onto 3 nodes (or onto a
  different jax mesh) unchanged.

Codecs (applied per rank blob, after splitting): ``none`` | ``zstd`` |
``zstd+delta`` (XOR against the previous checkpoint's blob, then zstd —
incremental checkpointing).  Codecs change the *stored* sizes that the
flush plan sees; raw sizes are preserved in the manifest.
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax

from repro.core.cluster import ClusterSpec
from repro.core.integrity import crc32
from repro.utils.treelib import flatten_with_names

try:
    import zstandard as _zstd
except Exception:  # pragma: no cover - zstd is an install-time dep
    _zstd = None


@dataclass(frozen=True)
class LeafEntry:
    name: str
    dtype: str
    shape: Tuple[int, ...]
    offset: int
    size: int


@dataclass
class RankEntry:
    rank: int
    offset: int          # offset in the logical stream
    raw_size: int
    stored_size: int
    crc: int             # crc of the *stored* blob


@dataclass
class Manifest:
    step: int
    total_raw_bytes: int
    codec: str
    base_step: Optional[int]          # for delta codecs
    world_size: int
    procs_per_node: int
    leaves: List[LeafEntry]
    ranks: List[RankEntry]
    precodec: str = "none"            # device-side transform (e.g. int8)
    strategy: str = ""
    files: Dict[str, int] = field(default_factory=dict)
    # file layout of each rank's stored blob on the PFS:
    # rank -> list of (file, file_offset, src_offset, size)
    placement: Dict[int, List[Tuple[str, int, int, int]]] = field(default_factory=dict)
    status: str = "pending"           # pending | local_done | flush_done

    # -- read-side views ---------------------------------------------------
    #
    # "Stored space" is the concatenation of every rank's *stored*
    # (encoded) blob in rank order; "raw space" is the logical stream the
    # pytree serialized to.  With codec "none" the two coincide byte for
    # byte; with compression they differ and only whole stored blobs can
    # be decoded.  The read planner always works in stored space.

    def stored_offsets(self) -> "np.ndarray":
        """rank -> stored-space offset of its blob (len world_size + 1)."""
        from repro.core.plan import stored_space_offsets

        return stored_space_offsets([r.stored_size for r in self.ranks])

    @property
    def total_stored_bytes(self) -> int:
        return sum(r.stored_size for r in self.ranks)

    def file_layout(self) -> "FileLayout":
        """Invert the persisted placement into a :class:`FileLayout`
        extent table (requires ``status == "flush_done"``)."""
        from repro.core.plan import FileLayout

        return FileLayout.from_placement(
            self.placement, [r.stored_size for r in self.ranks], self.files
        )

    def leaf_ranges(
        self, names: Sequence[str]
    ) -> List[Tuple[str, int, int]]:
        """(name, raw_offset, size) for the named leaves, in saved order.

        Raises ``KeyError`` on unknown names — partial restore must not
        silently return fewer leaves than asked for."""
        by_name = {l.name: l for l in self.leaves}
        missing = [n for n in names if n not in by_name]
        if missing:
            raise KeyError(f"leaves not in checkpoint: {missing[:5]}")
        return [(n, by_name[n].offset, by_name[n].size) for n in names]

    def _raw_bounds(self) -> Tuple["np.ndarray", "np.ndarray"]:
        """Cached (starts, ends) of each rank's raw segment — both
        non-decreasing because ranks slice the stream contiguously."""
        cached = self.__dict__.get("_raw_bounds_cache")
        if cached is None:
            starts = np.asarray([r.offset for r in self.ranks], np.int64)
            ends = starts + np.asarray(
                [r.raw_size for r in self.ranks], np.int64
            )
            cached = self.__dict__["_raw_bounds_cache"] = (starts, ends)
        return cached

    def ranks_covering(self, raw_a: int, raw_b: int) -> List[int]:
        """Ranks whose raw segment intersects ``[raw_a, raw_b)``.

        Two ``np.searchsorted`` calls over the cached prefix arrays — a
        partial restore of thousands of leaves at paper-scale world
        sizes must not do a linear Python scan per leaf."""
        if raw_b <= raw_a:
            return []
        starts, ends = self._raw_bounds()
        lo = int(np.searchsorted(ends, raw_a, side="right"))
        hi = int(np.searchsorted(starts, raw_b, side="left"))
        return [r for r in range(lo, hi) if ends[r] > starts[r]]

    def to_json(self) -> str:
        d = asdict(self)
        d["placement"] = {str(k): v for k, v in d["placement"].items()}
        return json.dumps(d)

    @staticmethod
    def from_json(s: str) -> "Manifest":
        d = json.loads(s)
        d["leaves"] = [LeafEntry(name=l["name"], dtype=l["dtype"],
                                 shape=tuple(l["shape"]), offset=l["offset"],
                                 size=l["size"]) for l in d["leaves"]]
        d["ranks"] = [RankEntry(**r) for r in d["ranks"]]
        d["placement"] = {
            int(k): [tuple(x) for x in v] for k, v in d["placement"].items()
        }
        return Manifest(**d)


# ---------------------------------------------------------------------------
# pytree -> logical stream
# ---------------------------------------------------------------------------


def _leaf_to_np(leaf: Any) -> np.ndarray:
    if isinstance(leaf, jax.Array):
        return np.asarray(leaf)
    return np.asarray(leaf)


def serialize_tree(state: Any) -> Tuple[bytes, List[LeafEntry]]:
    named, _ = flatten_with_names(state)
    chunks: List[bytes] = []
    leaves: List[LeafEntry] = []
    off = 0
    for name, leaf in named:
        arr = _leaf_to_np(leaf)  # tobytes() emits C-order regardless of layout
        raw = arr.tobytes()
        leaves.append(
            LeafEntry(
                name=name, dtype=str(arr.dtype), shape=tuple(arr.shape),
                offset=off, size=len(raw),
            )
        )
        chunks.append(raw)
        off += len(raw)
    return b"".join(chunks), leaves


def deserialize_tree(stream: bytes, leaves: Sequence[LeafEntry], target: Any) -> Any:
    """Fill `target`'s structure with leaf values from the stream.

    `target` may contain arrays or jax.ShapeDtypeStructs; only the
    structure is used.  Leaf order must match the saved order (name
    mismatches raise).
    """
    named, treedef = flatten_with_names(target)
    if len(named) != len(leaves):
        raise ValueError(
            f"target has {len(named)} leaves, checkpoint has {len(leaves)}"
        )
    vals = []
    for (name, _), entry in zip(named, leaves):
        if name != entry.name:
            raise ValueError(f"leaf mismatch: target {name!r} vs saved {entry.name!r}")
        buf = stream[entry.offset : entry.offset + entry.size]
        arr = np.frombuffer(buf, dtype=np.dtype(entry.dtype)).reshape(entry.shape)
        vals.append(arr.copy())
    return jax.tree_util.tree_unflatten(treedef, vals)


# ---------------------------------------------------------------------------
# logical stream -> per-rank blobs (+ codecs)
# ---------------------------------------------------------------------------


def split_ranks(
    total: int, world_size: int, *, sizes: Optional[Sequence[int]] = None
) -> List[Tuple[int, int]]:
    """(offset, size) per rank.  Balanced contiguous split by default."""
    if sizes is not None:
        if sum(sizes) != total or len(sizes) != world_size:
            raise ValueError("explicit sizes must sum to total")
        out, off = [], 0
        for s in sizes:
            out.append((off, int(s)))
            off += int(s)
        return out
    base, rem = divmod(total, world_size)
    out, off = [], 0
    for r in range(world_size):
        s = base + (1 if r < rem else 0)
        out.append((off, s))
        off += s
    return out


def _zstd_c(data: bytes, level: int = 3) -> bytes:
    if _zstd is None:
        raise RuntimeError("zstandard not available")
    return _zstd.ZstdCompressor(level=level).compress(data)


def _zstd_d(data: bytes, raw_size: int) -> bytes:
    if _zstd is None:
        raise RuntimeError("zstandard not available")
    return _zstd.ZstdDecompressor().decompress(data, max_output_size=max(raw_size, 1))


def encode_blob(
    raw: bytes, codec: str, base: Optional[bytes] = None
) -> bytes:
    if codec == "none":
        return raw
    if codec == "zstd":
        return _zstd_c(raw)
    if codec == "zstd+delta":
        if base is not None and len(base) == len(raw):
            x = np.bitwise_xor(
                np.frombuffer(raw, np.uint8), np.frombuffer(base, np.uint8)
            ).tobytes()
            return _zstd_c(x)
        return _zstd_c(raw)  # no base -> plain zstd (self-contained)
    raise ValueError(f"unknown codec {codec!r}")


def decode_blob(
    stored: bytes, codec: str, raw_size: int, base: Optional[bytes] = None,
    *, has_base: bool = False,
) -> bytes:
    if codec == "none":
        return stored
    if codec == "zstd":
        return _zstd_d(stored, raw_size)
    if codec == "zstd+delta":
        x = _zstd_d(stored, raw_size)
        if has_base:
            if base is None or len(base) != len(x):
                raise ValueError("delta blob requires its base blob")
            return np.bitwise_xor(
                np.frombuffer(x, np.uint8), np.frombuffer(base, np.uint8)
            ).tobytes()
        return x
    raise ValueError(f"unknown codec {codec!r}")


@dataclass
class EncodedState:
    """One checkpoint, serialized + split + encoded, ready to plan/flush."""

    step: int
    stream: bytes                   # raw logical stream (kept for L0/delta)
    blobs: List[bytes]              # stored (encoded) blob per rank
    manifest: Manifest


def encode_state(
    step: int,
    state: Any,
    cluster: ClusterSpec,
    *,
    codec: str = "none",
    base: Optional[EncodedState] = None,
    rank_sizes: Optional[Sequence[int]] = None,
) -> EncodedState:
    stream, leaves = serialize_tree(state)
    total = len(stream)
    parts = split_ranks(total, cluster.world_size, sizes=rank_sizes)
    base_ok = (
        base is not None
        and codec == "zstd+delta"
        and len(base.stream) == total
        and [
            (r.offset, r.raw_size) for r in base.manifest.ranks
        ] == list(parts)
    )
    blobs: List[bytes] = []
    ranks: List[RankEntry] = []
    for r, (off, size) in enumerate(parts):
        raw = stream[off : off + size]
        b = encode_blob(
            raw, codec, base.stream[off : off + size] if base_ok else None
        )
        blobs.append(b)
        ranks.append(
            RankEntry(
                rank=r, offset=off, raw_size=size, stored_size=len(b),
                crc=crc32(b),
            )
        )
    man = Manifest(
        step=step,
        total_raw_bytes=total,
        codec=codec,
        base_step=base.step if base_ok else None,
        world_size=cluster.world_size,
        procs_per_node=cluster.procs_per_node,
        leaves=leaves,
        ranks=ranks,
    )
    return EncodedState(step=step, stream=stream, blobs=blobs, manifest=man)


def decode_state(
    manifest: Manifest,
    blobs: Sequence[bytes],
    target: Any,
    *,
    base_stream: Optional[bytes] = None,
    verify: bool = True,
) -> Any:
    parts: List[bytes] = []
    has_base = manifest.base_step is not None
    for entry, blob in zip(manifest.ranks, blobs):
        if verify and crc32(blob) != entry.crc:
            raise IOError(f"rank {entry.rank}: checksum mismatch")
        base = (
            base_stream[entry.offset : entry.offset + entry.raw_size]
            if (base_stream is not None and has_base)
            else None
        )
        parts.append(
            decode_blob(
                blob, manifest.codec, entry.raw_size, base, has_base=has_base
            )
        )
    stream = b"".join(parts)
    if len(stream) != manifest.total_raw_bytes:
        raise IOError("reassembled stream has wrong size")
    return deserialize_tree(stream, manifest.leaves, target)
