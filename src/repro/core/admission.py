"""Cluster-wide flush admission control shared by co-located managers.

The seed runtime bounded its flush pipeline with a *per-manager*
``threading.BoundedSemaphore`` (``CheckpointManager._slots``), so two
managers checkpointing through one PFS could hold ``2 x
max_pending_flushes`` slots between them — exactly the
many-writers-one-PFS collision the paper's aggregation strategies
exist to avoid.  :class:`AdmissionController` replaces it: one slot
pool for every manager attached to the same storage target
(``CheckpointConfig.max_pending_flushes`` becomes a cluster-wide
budget when the control plane hands all tenants the same controller;
a private controller preserves the single-job semantics).

Priority preemption: when the pool is full and a higher-priority
tenant asks for a slot, the lowest-priority holder that registered a
``yield_fn`` is asked to give one back.  The engine's yield callback
parks its oldest *queued* (never mid-flight) flush as a journaled
``flush_partial`` — the PR-5 resumable-flush machinery — so the
preempted step loses its place in line, not its bytes, and drains
once the budget has room again.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, List, Optional, Tuple

__all__ = ["AdmissionController", "AdmissionSlot"]


@dataclass
class AdmissionSlot:
    """One held slot: who holds it and how it can be reclaimed."""

    owner: Any
    priority: float
    yield_fn: Optional[Callable[[], bool]]


class AdmissionController:
    """A preemptible counting semaphore over the pending-flush budget.

    ``acquire``/``release`` match the blocking semantics the engine's
    old per-manager semaphore had; ``yield_fn`` (returns True after
    parking one queued flush *and* calling :meth:`release`) is what
    makes a holder preemptible.  The condition uses an RLock so a
    victim's release — executed on the preemptor's thread, inside the
    wait loop — re-enters cleanly.
    """

    def __init__(self, total: int):
        self.total = max(1, int(total))
        self._cv = threading.Condition(threading.RLock())
        self._held: List[AdmissionSlot] = []
        self.preemptions = 0  # telemetry: slots reclaimed by priority

    # ------------------------------------------------------------- acquire

    def acquire(
        self,
        owner: Any,
        *,
        priority: float = 1.0,
        yield_fn: Optional[Callable[[], bool]] = None,
        timeout: Optional[float] = None,
    ) -> bool:
        """Block until a slot is granted (or ``timeout`` elapses).

        When the pool is full, holders with strictly lower priority
        that offered a ``yield_fn`` are asked — lowest priority first —
        to park a queued flush and return their slot before this caller
        falls back to waiting.
        """
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            while True:
                if len(self._held) < self.total:
                    self._held.append(AdmissionSlot(owner, priority, yield_fn))
                    return True
                if not self._preempt_locked(priority):
                    remain: Optional[float] = None
                    if deadline is not None:
                        remain = deadline - time.monotonic()
                        if remain <= 0:
                            return False
                    # Bounded nap even with no deadline: a victim whose
                    # queue was momentarily unpreemptible (all jobs
                    # mid-flight) may become preemptible next round.
                    self._cv.wait(min(0.05, remain) if remain else 0.05)

    def try_acquire(self, owner: Any, *, priority: float = 1.0) -> bool:
        with self._cv:
            if len(self._held) < self.total:
                self._held.append(AdmissionSlot(owner, priority, None))
                return True
            return False

    def _preempt_locked(self, priority: float) -> bool:
        """Ask one strictly-lower-priority holder to yield; True if a
        slot was freed (the victim's yield path called release)."""
        victims = sorted(
            (s for s in self._held
             if s.priority < priority and s.yield_fn is not None),
            key=lambda s: s.priority,
        )
        before = len(self._held)
        for v in victims:
            try:
                if v.yield_fn() and len(self._held) < before:
                    self.preemptions += 1
                    return True
            except Exception:
                continue  # a broken victim must not wedge the pool
        return False

    # ------------------------------------------------------------- release

    def release(self, owner: Any) -> None:
        with self._cv:
            for i, s in enumerate(self._held):
                if s.owner is owner:
                    del self._held[i]
                    self._cv.notify_all()
                    return
        raise ValueError("release() without a held slot for this owner")

    # ----------------------------------------------------------- telemetry

    def held(self) -> int:
        with self._cv:
            return len(self._held)

    def available(self) -> int:
        with self._cv:
            return self.total - len(self._held)

    def snapshot(self) -> List[Tuple[str, float]]:
        with self._cv:
            return [(getattr(s.owner, "name", repr(s.owner)), s.priority)
                    for s in self._held]
