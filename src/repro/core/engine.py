"""CheckpointManager: multi-level asynchronous checkpointing with
pluggable aggregation (the paper's system, as a JAX training feature).

Levels (VELOC semantics):

* **L0** — in-memory twin of the last encoded checkpoint (instant
  restart after a soft fault, survives nothing);
* **L1** — node-local files, written *blockingly* in the local phase
  (fast: node-local storage), optionally replicated to a partner node.
  The local phase is **fused and parallel**: per-rank encode + CRC + L1
  write run as one task each on the manager's local worker pool (its
  own pool — never queued behind async flush traffic), with fsyncs
  batched per node directory — the blocking window is parallel
  node-local bandwidth, not a per-rank Python loop
  (``parallel_local=False`` keeps the seed sequential path);
* **L2** — external PFS, written *asynchronously* by the active backend
  through one of the aggregation strategies (``file_per_process`` |
  ``posix`` | ``mpiio`` | ``stripe_aligned`` | ``gio_sync``).

``save()`` returns after the local phase; the flush proceeds on a
background worker (the "active backend") and training overlaps it.
``restore()`` prefers the deepest *complete* level and falls back
(L2 -> L1 -> L0 -> older steps) on missing/corrupt data — node failures
mid-flush therefore cost at most one checkpoint interval.

The background flush path is an **adaptive flush runtime** (see
docs/OPERATIONS.md for the full lifecycle state machine):

* **supersession** (``supersede_stale=True``): when step N+k is
  enqueued while step N is still queued or mid-flush, N's flush is
  skipped (queued) or cancelled at a safe request boundary
  (mid-flight, via a :class:`~repro.core.storage.CancelToken` threaded
  through ``RealExecutor.execute``) — the PFS only ever converges
  toward the *newest* state, VELOC-style.  Protected steps are never
  superseded: full snapshots under ``zstd+delta`` (the ``delta_every``
  cadence anchors every delta chain needs), every step inside the
  *live* delta window (deltas chain through their predecessors, so a
  pending window step is transitively a base of the newest one —
  window steps only become stale when the next full snapshot opens a
  new window), and steps inside the ``keep_n`` newest window.
  Superseded steps stay restorable from L1
  (and from delta bases) through the normal fallback ladder, and are
  reported via :attr:`CheckpointManager.superseded_steps` — never as
  flush errors.
* **interference-aware throttling**: a global token bucket
  (``flush_bw_cap`` explicitly, or derived from the cluster's
  ``app_net_load`` as ``(1 - load) * nic_bw * n_nodes``) caps executor
  write bandwidth so the drain leaves the application its NIC share —
  the same policy :mod:`repro.core.sim` prices, so the simulated and
  real trade-off curves agree.
* **crash-resumable flushes** (``resumable_flushes=True``): each flush
  first persists its manifest at ``status="flush_partial"`` (carrying
  the full columnar placement) and journals every completed extent
  (:class:`~repro.core.storage.FlushJournal`); a flush interrupted by
  ``close()``, a fault hook or process death is finished by
  :meth:`CheckpointManager.resume_flushes` from the last completed
  extent instead of rewriting the whole checkpoint.  ``restore()``
  never trusts a ``flush_partial``/``superseded`` manifest — those
  steps fall back to L1 until resumed.
* **degraded-mode availability** (``health_enabled``, on by default
  with the retry layer): a per-domain
  :class:`~repro.core.storage.StorageHealth` circuit breaker watches
  every retry attempt.  When the PFS circuit opens, flushes **park**
  at ``flush_partial`` (write set + journal persisted) instead of
  burning retry budgets — ``save()`` keeps succeeding on L0/L1, an
  ``l1_capacity_bytes`` budget applies backpressure by evicting the
  oldest non-pinned step, and the scheduler probes the PFS
  (:meth:`~repro.core.storage.RealExecutor.probe_pfs`) until the
  circuit closes, then auto-drains the parked steps through
  ``resume_flushes()``.  :meth:`CheckpointManager.health` surfaces
  the mode / circuits / parked set; ``hedged_reads`` adds
  deadline-aware read hedging (PFS extent re-issued from L1/partner
  past the latency-quantile deadline) plus health-weighted reader
  assignment.  See docs/OPERATIONS.md "Degraded mode".

Elasticity: L2 checkpoints are mesh-agnostic (logical byte stream +
manifest); a checkpoint saved under one cluster geometry restores under
any other, and onto any jax mesh via ``sharding_fn``.

The PFS level is read through aggregated :class:`~repro.core.plan.
ReadPlan`\\ s (manifest placement inverted into a ``FileLayout``, reads
balanced over the *restoring* cluster's nodes), and partial restore —
:meth:`CheckpointManager.restore_leaves` /
:meth:`CheckpointManager.restore_subtree` — pulls single leaves or
subtrees (e.g. just the params, for serving) out of an aggregated
checkpoint without reading the rest.
"""
from __future__ import annotations

import logging
import os
import queue
import random
import shutil
import threading
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field as dfield
from pathlib import Path
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.cluster import ClusterSpec
from repro.core.plan import (
    FlushPlan,
    assign_readers,
    build_read_plan,
    merge_intervals,
)
from repro.core.serialize import (
    CHUNK_BASE,
    CHUNK_DELTA,
    DEFAULT_CHUNK_SIZE,
    Buffer,
    EncodedState,
    LeafEntry,
    Manifest,
    decode_blob_reference,
    decode_chunk_into,
    decode_state,
    decode_stream,
    default_codec_impl,
    deserialize_tree,
    encode_state,
    _run_grouped,
)
from repro.core.admission import AdmissionController
from repro.core.faults import FaultPlan
from repro.core.storage import (
    CancelToken,
    CircuitOpenError,
    FlushCancelled,
    FlushJournal,
    FlushResult,
    HedgePolicy,
    LocalStore,
    ReadResult,
    RealExecutor,
    RetryPolicy,
    StorageHealth,
    TokenBucket,
    placement_from_plan,
)
from repro.core.strategies import make_plan
from repro.core.integrity import crc32

log = logging.getLogger("repro.ckpt")


@dataclass
class CheckpointConfig:
    root: str
    cluster: ClusterSpec
    strategy: str = "stripe_aligned"
    strategy_kwargs: Dict[str, Any] = dfield(default_factory=dict)
    io_threads: int = 2
    codec: str = "none"                # none | zstd | zstd+delta
    # Chunk framing of compressed rank blobs: chunks of this size are
    # compressed/decompressed in parallel, delta-skipped when unchanged,
    # and fetched individually by partial restore.  0 = the seed
    # whole-blob framing (one compressor call per rank blob; also what
    # legacy checkpoints on disk use).
    chunk_size: int = DEFAULT_CHUNK_SIZE
    precodec: str = "none"             # none | int8 (device-side, lossy)
    # Device-resident pre-codec (requires codec zstd+delta and a
    # chunk_size that is a multiple of 4096): the state is serialized,
    # quantized (one grouped launch) and diffed against the previous
    # step *on device* by the fused Pallas pass, and only dirty chunks
    # are copied D2H.  ``stage(step, state)`` starts the pass
    # asynchronously so it overlaps the next train step; ``save()``
    # consumes the staged buffers (or stages synchronously when the
    # caller never staged).  False = the host reference path
    # (quantize_tree + serialize_tree + np.array_equal dirty scan),
    # kept as the executable spec the staged path is byte-identical to.
    device_precodec: bool = False
    # Align host-path rank splits to the global chunk_size grid (the
    # split encode_state_staged always uses).  Off, ranks balance by
    # bytes and interior ranks get tail chunks; on, host and device
    # encodings of the same state are chunk-for-chunk comparable.
    chunk_aligned_split: bool = False
    delta_every: int = 4               # full ckpt cadence under zstd+delta
    partner_replication: bool = False  # L1 peer replica (node-failure cover)
    keep_n: Optional[int] = None       # GC: retain this many newest steps
    async_flush: bool = True
    verify_on_restore: bool = True
    # Backpressure: at most this many flushes may be queued/in-flight;
    # save() blocks in the local phase once the PFS falls this far behind
    # (VELOC semantics: never let the async channel grow unboundedly).
    max_pending_flushes: int = 2
    # Local-phase execution.  parallel_local runs per-rank encode + CRC
    # + L1 write (+ partner replica) through the manager's own local
    # worker pool (kept separate from the executor's flush pool so the
    # blocking window never queues behind async PFS writes), with
    # fsyncs batched per node directory; zero_copy uses the
    # preallocated-buffer serializer whose codec-none blobs are
    # memoryview slices of the stream.  Turning either off selects the
    # seed reference path (sequential item loop, per-file fsync) that
    # the equivalence tests and benchmarks/save_phase.py compare against.
    parallel_local: bool = True
    zero_copy: bool = True
    local_workers: int = 0             # 0 = auto: min(16, max(8, 2*cpus))
    # ---- adaptive flush runtime (docs/OPERATIONS.md) ----
    # Supersession: skip/cancel stale queued or mid-flight flushes when
    # a newer step arrives.  Protected (never superseded): full
    # snapshots under zstd+delta (delta-chain anchors) and steps inside
    # the keep_n newest window.  Off by default: every save still
    # reaches the PFS unless you opt into newest-state-wins semantics.
    supersede_stale: bool = False
    # Throttle policy for executor writes (token bucket, bytes/s
    # globally).  > 0: explicit cap.  0: derived from the cluster's
    # app_net_load as (1 - load) * nic_bw * n_nodes when load > 0 (the
    # Tseng interference trade-off, priced identically by core/sim.py
    # via simulate_flush(flush_bw_cap=...)); no throttle when load = 0.
    flush_bw_cap: float = 0.0
    # Crash-resumable flushes: persist the manifest at "flush_partial"
    # (with its full placement) before writing and journal each
    # completed extent, so interrupted flushes finish via
    # resume_flushes() instead of restarting from byte zero.
    resumable_flushes: bool = True
    # ---- transient-retry I/O (self-healing runtime) ----
    # Every raw blob/extent read and write is retried on transient
    # errno failures (classify_error) with bounded exponential backoff
    # + jitter under a per-op deadline; retry_attempts <= 1 disables
    # the retry layer entirely (seed behaviour: first error wins).
    retry_attempts: int = 5
    retry_base_delay: float = 0.02     # seconds, doubles per attempt
    retry_max_delay: float = 0.5       # backoff ceiling per sleep
    retry_deadline: float = 30.0       # per-op wall-clock budget
    # ---- degraded-mode availability runtime (docs/OPERATIONS.md) ----
    # Storage health registry + circuit breaker per domain ("pfs",
    # "l1:n{j}", "partner:n{j}").  Fed per retry attempt; when the PFS
    # circuit opens, flushes *park* at flush_partial (journals intact)
    # instead of burning retry budgets, and the scheduler probes +
    # auto-drains via resume_flushes() once the circuit closes.
    # Requires the retry layer (retry_attempts > 1); health_enabled is
    # ignored without it.
    health_enabled: bool = True
    health_min_ops: int = 8            # window attempts before rate trips
    health_error_threshold: float = 0.5
    health_cooldown: float = 2.0       # open -> half-open probe delay (s)
    health_tick: float = 0.25          # idle scheduler probe/drain cadence
    # Re-queue flush_partial steps found under root at construction
    # (crash recovery without an explicit resume_flushes() call).  The
    # degraded-mode auto-drain reuses the same path.
    auto_resume: bool = False
    # L1 byte budget across all nodes (0 = unbounded).  When a save
    # would overflow it, the oldest evictable step's L1 blobs are
    # dropped first (never delta anchors, live-window bases, keep_n
    # steps, or queued/mid-flight flushes; parked steps are superseded,
    # not silently lost); save() raises L1CapacityError only when
    # nothing is evictable.
    l1_capacity_bytes: int = 0
    # Deadline-aware read hedging: a PFS extent pread outstanding past
    # the hedge_quantile of observed latencies (floored at
    # hedge_min_delay seconds) is re-issued from the L1/partner copy;
    # first success wins, the loser's bytes are discarded.  Also turns
    # on health-weighted reader assignment (straggler demotion).
    hedged_reads: bool = False
    hedge_quantile: float = 0.95
    hedge_min_delay: float = 0.02


@dataclass
class SaveStats:
    """Per-save telemetry.  On the fused fast path (``zero_copy`` +
    ``parallel_local``) the per-rank L1 writes happen *inside* the
    encode tasks, so ``encode_time`` covers serialize+encode+CRC+drain
    and ``local_time`` is the durability tail (batched directory fsyncs
    + local manifest).  On the reference path they keep the seed split:
    encode vs sequential L1 writes.  ``encode_time + local_time`` is the
    blocking window either way."""

    step: int
    local_time: float
    raw_bytes: int
    stored_bytes: int
    encode_time: float
    flush: Optional[FlushResult] = None
    # True when the adaptive runtime superseded this step's flush (a
    # newer step replaced it before/while it drained); flush stays None.
    superseded: bool = False
    # Device pre-codec telemetry: total device-side staging span (worker
    # thread) and how much of it save() actually blocked on.  A staged
    # step overlapped with training has stage_wait_s ~ 0;
    # stage_s - stage_wait_s is the work hidden behind the train step.
    stage_s: float = 0.0
    stage_wait_s: float = 0.0


class UnsupportedPrecodecError(IOError):
    """Partial restore was planned against a precodec-transformed
    manifest: the stored leaves are the transformed tree (``q``/``s``
    blocks under ``int8``), not the caller's names.  Raised at *plan
    time* — before any blob or extent read is issued — and never
    swallowed by the candidate fallback, so a serving caller cannot
    silently receive an older step's leaves instead.  Restore such
    checkpoints with :meth:`CheckpointManager.restore` (which
    dequantizes), or save the serving tier with ``precodec="none"``."""


class L1CapacityError(RuntimeError):
    """``save()`` refused: the L1 byte budget is full and every resident
    step is pinned (delta anchor, live delta window, ``keep_n``, or
    queued/mid-flight).  Raised *before* any byte of the new step is
    written — the caller can drop the save, raise the budget, or wait
    for a flush to retire a pinned step."""


@dataclass
class ManagerHealth:
    """Operator/follower view of the manager's availability state.

    ``mode`` is ``"normal"`` (PFS circuit closed, nothing parked),
    ``"degraded"`` (PFS circuit open or probing: new flushes park at
    ``flush_partial`` with journals intact, saves keep landing on
    L0/L1), or ``"draining"`` (circuit closed again, parked flushes
    re-queuing through ``resume_flushes()``).  The serving fleet's
    follower treats ``degraded`` as "do not adopt new steps" — only a
    ``flush_done`` manifest published after the drain is trustworthy.
    """

    mode: str
    queue_depth: int            # jobs queued/mid-flight in the scheduler
    parked_steps: List[int]     # flush_partial steps awaiting the drain
    l1_bytes: int               # tracked L1 occupancy (replicas included)
    l1_capacity: int            # configured budget (0 = unbounded)
    circuits: Dict[str, str]    # domain -> closed | open | half_open
    degraded_since: Optional[float] = None  # monotonic ts of first park
    drained_steps: int = 0      # parked flushes completed by auto-drain
    evicted_steps: List[int] = dfield(default_factory=list)


@dataclass
class _FlushJob:
    """One enqueued flush: the encoded step, its plan, and the runtime
    control surface (cancellation token + supersession marking)."""

    enc: EncodedState
    plan: FlushPlan
    token: CancelToken
    protected: bool          # delta-base anchor / keep_n-pinned
    superseded: bool = False  # set (under the manager lock) by newer saves
    started: bool = False    # scheduler picked it up: no longer preemptible
    preempted: bool = False  # yielded its admission slot (parked, resumable)


# Scheduler-queue sentinel: run resume_flushes() on the flush worker
# (auto_resume re-queues crash-leftover flush_partial steps this way so
# the constructor never blocks on PFS I/O).
_AUTO_RESUME = object()


class CheckpointManager:
    def __init__(
        self,
        config: CheckpointConfig,
        *,
        fault_hook: Optional[Callable] = None,
        faults: Optional["FaultPlan"] = None,
        limiter: Optional[Any] = None,
        admission: Optional[AdmissionController] = None,
        storage_health: Optional[StorageHealth] = None,
        tenant: Optional[str] = None,
        priority: float = 1.0,
    ):
        self.cfg = config
        self.cluster = config.cluster
        self.root = Path(config.root)
        # Multi-tenant identity (control-plane managed runs): the
        # tenant name labels admission snapshots/logs, the priority
        # orders preemption and drain against co-located managers.
        self.name = tenant if tenant is not None else str(self.root)
        self.priority = float(priority)
        # transient-retry layer shared by L1 blob I/O and PFS extent I/O
        self.retry: Optional[RetryPolicy] = (
            RetryPolicy(
                attempts=config.retry_attempts,
                base_delay=config.retry_base_delay,
                max_delay=config.retry_max_delay,
                deadline=config.retry_deadline,
            )
            if config.retry_attempts > 1
            else None
        )
        # Storage health registry: fed per retry attempt, drives the
        # PFS circuit breaker and the degraded-mode scheduler below.
        self.storage_health: Optional[StorageHealth] = None
        if config.health_enabled and self.retry is not None:
            # An injected registry (control plane) is SHARED: tenants on
            # one PFS see one breaker — tenant A's giveups open the
            # circuit tenant B's flushes must also respect.
            self.storage_health = (
                storage_health
                if storage_health is not None
                else StorageHealth(
                    min_ops=config.health_min_ops,
                    error_threshold=config.health_error_threshold,
                    cooldown=config.health_cooldown,
                )
            )
            self.retry.health = self.storage_health
        self.faults = faults  # deterministic chaos schedule (core/faults.py)
        self.local = LocalStore(
            self.root / "local", self.cluster.n_nodes,
            faults=faults, retry=self.retry,
        )
        if faults is not None:
            faults.bind(self.local)  # node_crash specs drop L1 dirs
        self.pfs_dir = self.root / "pfs"
        self.pfs_dir.mkdir(parents=True, exist_ok=True)
        (self.root / "local" / "manifests").mkdir(parents=True, exist_ok=True)
        self.executor = RealExecutor(
            self.pfs_dir,
            self.local,
            io_threads=config.io_threads,
            fault_hook=fault_hook,
            faults=faults,
            retry=self.retry,
        )
        self._l0: Optional[EncodedState] = None
        self._last_full: Optional[EncodedState] = None
        self._saves_since_full = 0
        # Device pre-codec runtime (lazy — only when device_precodec):
        # the staging worker + device-held base words, and the handle of
        # the step currently staged ahead of its save().
        self._device_precodec = None
        self._staged = None
        self.stats: List[SaveStats] = []
        # Flush results are delivered by step through this index (under
        # _lock) — the flush worker never scans the list save() appends to.
        self._stats_by_step: Dict[int, SaveStats] = {}
        # Parsed-manifest cache keyed by (ino, mtime_ns, size) per path:
        # steps() runs per save (via _gc) and per restore candidate scan,
        # and must not re-parse every manifest JSON each time.
        self._man_cache: Dict[str, Tuple[Tuple[int, int, int], Manifest]] = {}
        self._MAN_CACHE_CAP = 128  # bounds RAM when keep_n is None
        self._q: "queue.Queue[Optional[_FlushJob]]" = queue.Queue()
        # Flush admission: the seed's per-manager BoundedSemaphore let
        # two managers on one node hold 2x the intended pending-flush
        # budget; the controller is shared across managers when the
        # control plane injects one (max_pending_flushes then reads as
        # a cluster-wide budget), private otherwise (same semantics as
        # the old semaphore, preemption never fires with one tenant).
        self._admission: AdmissionController = (
            admission
            if admission is not None
            else AdmissionController(max(1, config.max_pending_flushes))
        )
        self._worker: Optional[threading.Thread] = None
        self._local_exec: Optional[ThreadPoolExecutor] = None
        self._flush_errors: List[Tuple[int, str]] = []
        self._lock = threading.Lock()
        # Adaptive flush runtime state: jobs queued or mid-flight (by
        # step), supersession/interruption records, saved-step history
        # (keep_n pinning), and the global write-rate token bucket.
        self._pending: Dict[int, _FlushJob] = {}
        # Bounded telemetry: a multi-week supersession run records one
        # entry per save — deques cap the memory, newest entries win.
        self._superseded: Deque[Tuple[int, str]] = deque(maxlen=4096)
        self._interrupted: Deque[int] = deque(maxlen=4096)
        self._resuming: set = set()  # steps mid-resume, shielded from _gc
        self._saved_steps: List[int] = []  # trimmed in save(); keep_n pins
        # Operator pins (control-plane `pin`): steps GC, supersession,
        # preemption and L1 eviction must all leave alone.
        self._pins: set = set()
        # Steps parked by admission preemption (not by a PFS outage):
        # their drain additionally waits for budget headroom.
        self._preempt_parked: set = set()
        if limiter is not None:
            # Injected fair-share leaf (TenantLimiter): the control
            # plane's global cap replaces the per-manager policy.
            self._limiter: Optional[TokenBucket] = limiter
        else:
            cap = self._flush_bw_policy()
            self._limiter = TokenBucket(cap) if cap > 0 else None
        # Stats of the most recent aggregated PFS read (restore telemetry).
        self.last_read_result: Optional[ReadResult] = None
        # New-step notification: callbacks fired (with the step number)
        # after a manifest flips to flush_done — the serving fleet's
        # hot-swap follower subscribes here when it shares the process.
        self._subscribers: List[Callable[[int], None]] = []
        # Optional node-local decoded-chunk cache (duck-typed:
        # get(key)/put(key, bytes) — see repro.serve.stream.ChunkCache).
        # Keyed (step, chunk row); the delta-base recursion reuses the
        # same keying for the base step, so co-located replicas dedup
        # CHUNK_BASE/delta-base decodes for free.
        self.chunk_cache = None
        # Degraded-mode availability runtime state (docs/OPERATIONS.md
        # "Degraded mode"): parked flush_partial steps awaiting the
        # post-outage drain, L1 occupancy accounting for backpressure,
        # and the seeded probe-payload generator for half-open checks.
        self._parked: Dict[int, None] = {}  # insertion-ordered step set
        self._degraded_since: Optional[float] = None
        self._draining = False
        self._drained_total = 0
        self._evicted: Deque[int] = deque(maxlen=4096)
        self._l1_bytes: Dict[int, int] = {}
        self._l1_anchors: set = set()  # full snapshots under zstd+delta
        self._last_l1_cost = 0  # newest step's L1 bytes (reserve estimate)
        self._probe_rng = random.Random(0x5EED)
        if config.l1_capacity_bytes > 0:
            self._scan_l1_occupancy()
        if config.async_flush:
            self._worker = threading.Thread(
                target=self._scheduler_loop, name="active-backend", daemon=True
            )
            self._worker.start()
        if config.auto_resume:
            if self._worker is not None:
                self._q.put(_AUTO_RESUME)  # re-queue partials on the worker
            else:
                self.resume_flushes()

    # ------------------------------------------------------------------ save

    def save(self, step: int, state: Any) -> SaveStats:
        cfg = self.cfg
        t0 = time.perf_counter()
        if cfg.precodec not in ("none", "int8"):
            raise ValueError(f"unknown precodec {cfg.precodec!r}")
        base = None
        if cfg.device_precodec:
            self._check_device_cfg()
        else:
            if cfg.precodec == "int8":
                from repro.core.precodec import quantize_tree

                state = quantize_tree(state)
            base = self._delta_base()
        c = self.cluster
        pool = self._local_pool() if cfg.parallel_local else None
        replicate = cfg.partner_replication and c.n_nodes > 1
        # L1 backpressure: make room for this step *before* its first
        # blob lands (the fused path writes L1 inside encode).  The
        # newest step's cost is the estimate; the post-write true-up
        # below reconciles against the real size.
        self._enforce_l1_budget(step, self._last_l1_cost, strict=True)

        def drain_rank(rank: int, blob: Any) -> None:
            # non-atomic, unsynced writes: the local manifest written
            # after the batch is the commit point, sync_dir the
            # durability point
            node = c.node_of_rank(rank)
            self.local.write_blob(
                node, step, rank, blob, sync=False, atomic=False
            )
            if replicate:
                partner = (node + 1) % c.n_nodes
                self.local.write_blob(
                    partner, step, rank, blob, partner=True,
                    sync=False, atomic=False,
                )

        fused = cfg.zero_copy and pool is not None
        stage_s = stage_wait_s = 0.0
        if cfg.device_precodec:
            enc, stage_s, stage_wait_s = self._encode_device(
                step, state, pool, drain_rank if fused else None
            )
        elif cfg.zero_copy:
            # fused parallel local phase: each pooled rank task encodes,
            # CRCs and writes its L1 blob (+ partner replica) in one go —
            # CRC of one rank overlaps the file write of another
            enc = encode_state(
                step, state, self.cluster, codec=cfg.codec, base=base,
                chunk_aligned=cfg.chunk_aligned_split,
                pool=pool, rank_sink=drain_rank if fused else None,
                chunk_size=cfg.chunk_size,
            )
        else:
            from repro.core.serialize_ref import encode_state_reference

            enc = encode_state_reference(
                step, state, self.cluster, codec=cfg.codec, base=base
            )
        enc.manifest.precodec = cfg.precodec
        t_enc = time.perf_counter() - t0

        # ---- local phase (blocking) ----
        t1 = time.perf_counter()
        if pool is None:
            # seed reference path: sequential writes, fsync per file
            for rank, blob in enumerate(enc.blobs):
                node = c.node_of_rank(rank)
                self.local.write_blob(node, step, rank, blob)
                if cfg.partner_replication and c.n_nodes > 1:
                    partner = (node + 1) % c.n_nodes
                    self.local.write_blob(partner, step, rank, blob, partner=True)
        else:
            if not fused:  # reference encode + parallel drain
                list(pool.map(lambda j: drain_rank(*j), enumerate(enc.blobs)))
            # batched durability: one fsync per node directory (the
            # blobs span every rank, hence every node — replicas too)
            list(pool.map(
                lambda n: self.local.sync_dir(n, step), range(c.n_nodes)
            ))
        enc.manifest.status = "local_done"
        self._write_manifest_local(enc.manifest)
        t_local = time.perf_counter() - t1

        st = SaveStats(
            step=step,
            local_time=t_local,
            raw_bytes=enc.manifest.total_raw_bytes,
            stored_bytes=sum(r.stored_size for r in enc.manifest.ranks),
            encode_time=t_enc,
            stage_s=stage_s,
            stage_wait_s=stage_wait_s,
        )
        l1_cost = st.stored_bytes * (2 if replicate else 1)
        with self._lock:
            self._l0 = enc
            if enc.manifest.base_step is None:
                self._last_full = enc
                self._saves_since_full = 0
                if cfg.codec == "zstd+delta":
                    self._l1_anchors.add(step)
            else:
                self._saves_since_full += 1
            self._l1_bytes[step] = l1_cost
            self._last_l1_cost = l1_cost
            self.stats.append(st)
            self._stats_by_step[step] = st
            self._saved_steps.append(step)
            # keep_n pinning only ever reads the tail; don't let the
            # history grow with the run
            bound = 4 * max(cfg.keep_n or 0, 256)
            if len(self._saved_steps) > bound:
                del self._saved_steps[: len(self._saved_steps) - bound // 2]

        # ---- flush phase (async) ----
        sizes = [r.stored_size for r in enc.manifest.ranks]
        plan = make_plan(cfg.strategy, c, sizes, **cfg.strategy_kwargs)
        job = _FlushJob(
            enc, plan, CancelToken(), protected=self._is_protected(enc.manifest)
        )
        if cfg.async_flush:
            if cfg.supersede_stale:
                # mark stale pending flushes *before* taking a slot:
                # skipped jobs release their slots, so a fast save
                # cadence drains the queue instead of stalling on it
                self._supersede_stale(step)
            # backpressure: bounded flush pipeline.  Under a shared
            # controller this blocks on the CLUSTER budget; offering
            # _yield_queued_flush makes this manager's queued (never
            # mid-flight) jobs preemptible by higher-priority tenants.
            self._admission.acquire(
                self, priority=self.priority,
                yield_fn=self._yield_queued_flush,
            )
            with self._lock:
                self._pending[step] = job
            self._q.put(job)
            self._add_demand(plan.total_bytes)
        else:
            try:
                st.flush = self._do_flush(job)
            except (CircuitOpenError, OSError) as e:
                # degraded mode, sync flavor: save() still succeeds —
                # the step parks at flush_partial, health_check() drains
                if self._pfs_degraded() and cfg.resumable_flushes:
                    self._park_job(job, e)
                else:
                    raise
        # post-write true-up: the real cost is now known; evict (never
        # raise — the bytes are already durable on L1) if it overshot
        self._enforce_l1_budget(step, 0, strict=False)
        return st

    # ------------------------------------------------- device pre-codec path

    def _delta_base(self) -> Optional[EncodedState]:
        """The delta base for the next save, or ``None`` (anchor).

        Re-anchors when ``cfg.precodec`` changed since the base was
        encoded: XORing streams of different transforms would store a
        "delta" that decodes into garbage under the new manifest's
        precodec label, so the stale in-memory ``_l0``/``_last_full``
        bases are invalidated and the next save is a full snapshot.
        """
        cfg = self.cfg
        if cfg.codec != "zstd+delta" or self._last_full is None:
            return None
        if self._last_full.manifest.precodec != cfg.precodec or (
            self._l0 is not None and self._l0.manifest.precodec != cfg.precodec
        ):
            with self._lock:
                self._l0 = None
                self._last_full = None
                self._saves_since_full = 0
            if self._device_precodec is not None:
                self._device_precodec.invalidate_base()
            return None
        if self._saves_since_full < cfg.delta_every - 1:
            return self._l0 or self._last_full
        return None

    def _check_device_cfg(self) -> None:
        cfg = self.cfg
        if cfg.codec != "zstd+delta":
            raise ValueError("device_precodec requires codec 'zstd+delta'")
        from repro.kernels.fused.ops import CHUNK_ALIGN

        if cfg.chunk_size <= 0 or cfg.chunk_size % CHUNK_ALIGN:
            raise ValueError(
                f"device_precodec requires chunk_size to be a positive "
                f"multiple of {CHUNK_ALIGN}, got {cfg.chunk_size}"
            )

    def _device_codec(self):
        if self._device_precodec is None:
            from repro.core.precodec import DevicePrecodec

            self._device_precodec = DevicePrecodec(
                chunk_size=self.cfg.chunk_size, precodec=self.cfg.precodec
            )
        return self._device_precodec

    def stage(self, step: int, state: Any) -> bool:
        """Start the device pre-codec pass for ``step`` ahead of its
        ``save()``.

        Returns immediately: the grouped quantize + fused
        delta/dirty/checksum pass and the dirty-chunk D2H copy run on
        the staging worker while the caller's next train step executes.
        ``save(step, state)`` then consumes the staged buffers instead
        of doing a fresh full-state device_get — the state must not be
        mutated between the two calls (the staged bytes are the bytes
        saved).  No-op returning ``False`` when ``device_precodec`` is
        off.
        """
        if not self.cfg.device_precodec:
            return False
        self._check_device_cfg()
        base = self._delta_base()
        staged = self._device_codec().stage(
            step, state, base_step=None if base is None else base.step
        )
        with self._lock:
            self._staged = staged
        return True

    def _encode_device(self, step: int, state: Any, pool, rank_sink):
        """Consume (or synchronously produce) the staged device buffers
        and encode them — the device-path body of ``save()``'s encode
        phase.  Returns ``(enc, stage_s, stage_wait_s)``."""
        from repro.core.serialize import encode_state_staged

        with self._lock:
            staged, self._staged = self._staged, None
        if staged is None or staged.step != step:
            base = self._delta_base()
            staged = self._device_codec().stage(
                step, state, base_step=None if base is None else base.step
            )
        base_stream = None
        if staged.base_step is not None:
            with self._lock:
                for cand in (self._l0, self._last_full):
                    if cand is not None and cand.step == staged.base_step:
                        base_stream = cand.stream
                        break
        bufs = self._device_codec().consume(staged, base_stream)
        enc = encode_state_staged(
            step, self.cluster,
            stream=bufs.stream,
            leaves=bufs.leaves,
            chunk_size=self.cfg.chunk_size,
            base_step=bufs.base_step,
            dirty=bufs.mask,
            deltas=bufs.deltas,
            digests=bufs.digests,
            pool=pool,
            rank_sink=rank_sink,
        )
        return enc, bufs.stage_s, bufs.wait_s

    # ----------------------------------------------------------------- flush

    def _local_pool(self) -> ThreadPoolExecutor:
        """One shared pool for the whole local phase — serialize leaf
        copies, fused encode+CRC+L1 tasks, batched directory fsyncs —
        and for restore-side decode.

        Deliberately **not** the executor's flush pool: ``save()`` is
        the blocking window, and its tasks must never queue in FIFO
        order behind a backlog of async PFS writes from earlier steps.
        Sizing is codec-aware: with codec ``none`` the fused rank tasks
        spend their time in GIL-free file writes, so the pool is sized
        for I/O latency; with compression on they alternate short
        GIL-holding bookkeeping with GIL-free compressor calls, and
        oversubscribing the physical cores just convoys the GIL — so
        the pool tracks core count instead."""
        if self._local_exec is None:
            cpus = os.cpu_count() or 4
            if self.cfg.codec == "none":
                auto = min(16, max(8, 2 * cpus))
            else:
                auto = min(16, max(4, cpus + 2))
            workers = self.cfg.local_workers or auto
            self._local_exec = ThreadPoolExecutor(
                max_workers=workers, thread_name_prefix="ckpt-local"
            )
        return self._local_exec

    def _flush_bw_policy(self) -> float:
        """Effective executor write cap in bytes/s (0 = unthrottled).

        Explicit ``flush_bw_cap`` wins; otherwise a positive
        ``app_net_load`` on the cluster's nodes derives the cap the
        simulator prices for the same spec: the flush may use at most
        the NIC share the application is not keeping, summed over
        nodes.  Consistency between this policy and
        ``simulate_flush(flush_bw_cap=...)`` is what lets the sim's
        throttle curve predict the real executor's.
        """
        cfg = self.cfg
        if cfg.flush_bw_cap > 0:
            return float(cfg.flush_bw_cap)
        load = self.cluster.node.app_net_load
        if load > 0:
            # floor the share at 1e-3 (the simulator's derate floor):
            # load -> 1.0 must throttle to near-zero, not flip the cap
            # to 0.0 == "unthrottled" at exactly the boundary
            return (
                self.cluster.n_nodes * self.cluster.node.nic_bw
                * max(1e-3, 1.0 - load)
            )
        return 0.0

    def _is_protected(self, man: Manifest) -> bool:
        """Steps supersession must never skip: full snapshots under
        ``zstd+delta`` — every delta chain resolves through them, so
        dropping one would strand the whole ``delta_every`` window on
        L1 durability alone."""
        return self.cfg.codec == "zstd+delta" and man.base_step is None

    # ------------------------------------------- multi-tenant control surface

    def _add_demand(self, n: int) -> None:
        """Offered-load signal for a fair-share limiter (duck-typed:
        plain TokenBuckets have no demand and ignore rebalancing)."""
        f = getattr(self._limiter, "add_demand", None)
        if f is not None:
            f(n)

    def _sub_demand(self, n: int) -> None:
        f = getattr(self._limiter, "sub_demand", None)
        if f is not None:
            f(n)

    def pin_step(self, step: int) -> None:
        """Pin ``step`` against GC, supersession, L1 eviction and
        admission preemption (the control plane's ``pin`` verb)."""
        with self._lock:
            self._pins.add(int(step))

    def unpin_step(self, step: int) -> None:
        with self._lock:
            self._pins.discard(int(step))

    def pinned_steps(self) -> List[int]:
        with self._lock:
            return sorted(self._pins)

    def _yield_queued_flush(self) -> bool:
        """Admission-preemption callback: park this manager's oldest
        queued-but-not-started flush as a journaled ``flush_partial``
        and give its slot back to the controller.

        Only *queued* jobs yield — a mid-flight flush already paid for
        its bytes and cancelling it would waste more PFS bandwidth than
        it frees.  The parked step keeps its placement + journal (the
        resumable-flush machinery), so it drains through
        :meth:`resume_flushes` once the budget has headroom again;
        without ``resumable_flushes`` there is nothing to park *with*,
        so this manager is simply not preemptible.  Returns True when a
        slot was released.
        """
        if not self.cfg.resumable_flushes:
            return False
        with self._lock:
            victim: Optional[_FlushJob] = None
            for s in sorted(self._pending):
                job = self._pending[s]
                if (
                    job.started or job.preempted or job.superseded
                    or job.protected or s in self._pins
                ):
                    continue
                victim = job
                break
            if victim is None:
                return False
            victim.preempted = True
            self._preempt_parked.add(victim.enc.step)
        self._park_job(
            victim,
            RuntimeError("admission slot preempted by a higher-priority job"),
        )
        self._admission.release(self)
        log.info(
            "flush for step %d preempted: slot yielded to a "
            "higher-priority tenant; journaled state drains when the "
            "budget has headroom", victim.enc.step,
        )
        return True

    def _supersede_stale(self, new_step: int) -> None:
        """Mark every stale pending flush superseded and fire its token.

        Stale = enqueued for an older step than ``new_step``, not
        protected (:meth:`_is_protected`), not pinned by ``keep_n``
        (a step inside the keep_n-newest saved window is one the user
        asked to retain on the PFS — skipping its flush would leave a
        hole GC semantics promise not to have), and — under
        ``zstd+delta`` — not inside the **live delta window**: deltas
        chain through their predecessors (``base = L0``, the previous
        step), so every pending step at or above the current full
        anchor is transitively a base of ``new_step`` and skipping its
        flush would leave newer flush_done deltas unrestorable from the
        PFS alone.  Delta-window steps only become superseded-able when
        the next full snapshot opens a new window.

        *Parked* steps (degraded mode) follow the same rule: a newer
        save supersedes an older parked flush under the identical
        protections, so an outage with a live save cadence drains only
        the newest state afterwards instead of replaying the backlog.
        """
        keep = self.cfg.keep_n
        parked_stale: List[int] = []
        with self._lock:
            pinned = set(self._saved_steps[-keep:]) if keep is not None else set()
            window_floor = None
            if self.cfg.codec == "zstd+delta" and self._last_full is not None:
                window_floor = self._last_full.step
            for s, job in self._pending.items():
                if s >= new_step or job.superseded or job.protected:
                    continue
                if s in pinned or s in self._pins or job.preempted:
                    continue
                if window_floor is not None and s >= window_floor:
                    continue  # live delta window: s is a base of new_step
                job.superseded = True
                job.token.cancel()
            for s in list(self._parked):
                if (
                    s >= new_step or s in pinned or s in self._l1_anchors
                    or s in self._pins
                ):
                    continue
                if window_floor is not None and s >= window_floor:
                    continue
                self._parked.pop(s, None)
                self._preempt_parked.discard(s)
                parked_stale.append(s)
        for s in parked_stale:
            try:
                man = self._gc_manifest_any(s)
                man.status = "superseded"
                self._write_manifest_pfs(man)
            except Exception:
                log.exception("failed to supersede parked step %d", s)
            self._note_superseded(s, "parked")

    def _journal_path(self, step: int) -> Path:
        return self.pfs_dir / f"step_{step:08d}" / "flush_journal.bin"

    def _scheduler_loop(self) -> None:
        """The adaptive flush scheduler (replaces the seed FIFO
        ``_flush_loop``): skips superseded queued jobs, runs the rest
        through the cancellable/throttled/journaled executor, and
        classifies every outcome — delivered, superseded (queued or
        mid-flush), interrupted-but-resumable, parked (PFS circuit
        open: journaled flush_partial awaiting the post-outage drain),
        or failed.  Between jobs the loop wakes every
        ``cfg.health_tick`` seconds to probe an open PFS circuit and to
        auto-drain parked steps once it closes."""
        tick = max(0.05, float(self.cfg.health_tick))
        while True:
            try:
                job = self._q.get(timeout=tick)
            except queue.Empty:
                self._health_tick()
                continue
            if job is None:
                self._q.task_done()
                return
            if job is _AUTO_RESUME:
                try:
                    self.resume_flushes()
                except Exception:
                    log.exception("auto_resume drain failed")
                finally:
                    self._q.task_done()
                continue
            step = job.enc.step
            try:
                with self._lock:
                    skip = job.superseded
                    preempted = job.preempted
                    if not skip and not preempted:
                        # past this point the job is mid-flight: the
                        # admission yield path must never park it
                        job.started = True
                if preempted:
                    # already parked + slot released by the yield path;
                    # nothing to run — the drain owns it now
                    pass
                elif skip:
                    self._note_superseded(step, "queued")
                else:
                    if self._pfs_degraded():
                        # a busy queue must not starve recovery: give the
                        # circuit its probe/drain opportunity before
                        # deciding this job's fate
                        self._health_tick()
                    if self._pfs_degraded():
                        # fail fast — park with the placement persisted
                        # instead of burning a retry budget per job
                        # against a PFS the breaker already knows is out
                        self._park_job(job, CircuitOpenError("pfs"))
                    else:
                        res = self._do_flush(job)
                        # deliver by step, under the lock save() appends
                        # under — never scan the list a save() is growing
                        with self._lock:
                            st = self._stats_by_step.get(step)
                            if st is not None:
                                st.flush = res
            except CircuitOpenError as e:
                self._park_job(job, e)
            except FlushCancelled:
                if job.superseded:
                    self._note_superseded(step, "mid_flush")
                else:
                    # close()-deadline interruption.  Not an error —
                    # but only resumable when journaling was on.
                    with self._lock:
                        self._interrupted.append(step)
                    if self.cfg.resumable_flushes:
                        log.warning(
                            "flush for step %d interrupted; resumable "
                            "via resume_flushes()", step,
                        )
                    else:
                        log.warning(
                            "flush for step %d interrupted with "
                            "resumable_flushes=False: the step exists on "
                            "L1 only — re-save or re-flush it before "
                            "relying on the PFS", step,
                        )
            except OSError as e:
                if self._pfs_degraded():
                    # the op that tripped the breaker: same parking as a
                    # short-circuited job — its journaled state drains
                    self._park_job(job, e)
                else:
                    log.exception("flush for step %d failed", step)
                    with self._lock:
                        self._flush_errors.append((step, repr(e)))
            except Exception as e:  # crash of the active backend
                log.exception("flush for step %d failed", step)
                with self._lock:
                    self._flush_errors.append((step, repr(e)))
            finally:
                with self._lock:
                    self._pending.pop(step, None)
                    was_preempted = job.preempted
                self._sub_demand(job.plan.total_bytes)
                if not was_preempted:
                    # a preempted job's slot was already returned by
                    # _yield_queued_flush on the preemptor's thread
                    self._admission.release(self)
                self._q.task_done()

    def _note_superseded(self, step: int, phase: str) -> None:
        with self._lock:
            self._superseded.append((step, phase))
            st = self._stats_by_step.get(step)
            if st is not None:
                st.superseded = True
        log.info("flush for step %d superseded (%s)", step, phase)

    # ------------------------------------------- degraded-mode availability

    def _pfs_degraded(self) -> bool:
        """True while the PFS circuit is open or probing (half-open)."""
        sh = self.storage_health
        return sh is not None and sh.state("pfs") != "closed"

    def _park_job(self, job: _FlushJob, err: BaseException) -> None:
        """Park a flush the PFS outage prevented: persist the write set
        (manifest at ``flush_partial`` with full placement) so the
        post-outage drain finishes it via :meth:`resume_flushes` —
        journaled progress, if any, is kept.  Without
        ``resumable_flushes`` there is nothing to park *with*, so the
        step records a flush error exactly like the pre-health runtime.
        """
        step = job.enc.step
        if not self.cfg.resumable_flushes:
            log.error(
                "flush for step %d failed with the PFS circuit open and "
                "resumable_flushes=False: the step exists on L1 only", step,
            )
            with self._lock:
                self._flush_errors.append((step, repr(err)))
            return
        man = job.enc.manifest
        if man.status != "flush_partial" or man.placement is None:
            # short-circuited before _do_flush persisted the write set
            man.strategy = job.plan.strategy
            man.files = dict(job.plan.files)
            man.placement = placement_from_plan(job.plan)
            man.status = "flush_partial"
            self._write_manifest_pfs(man)
        with self._lock:
            self._parked[step] = None
            if self._degraded_since is None:
                self._degraded_since = time.monotonic()
        log.warning(
            "flush for step %d parked (%s); journaled state drains "
            "automatically when the PFS circuit closes", step, err,
        )

    def _health_tick(self) -> None:
        """One probe/drain opportunity: probe an open PFS circuit once
        its cooldown elapses; once it closes, drain parked flushes.
        Driven by the scheduler between jobs; sync managers and tests
        drive it through :meth:`health_check`."""
        sh = self.storage_health
        if sh is None:
            return
        state = sh.state("pfs")
        if state == "closed":
            with self._lock:
                parked = bool(self._parked)
                only_preempted = (
                    parked and set(self._parked) <= self._preempt_parked
                )
                if not parked:
                    self._degraded_since = None
            # Preemption-parked steps additionally wait for budget
            # headroom: draining them the instant they parked would
            # hand the yielded bandwidth straight back to the victim.
            if parked and not (
                only_preempted and self._admission.available() <= 0
            ):
                self._drain_parked()
            return
        if state == "half_open":
            self._probe_pfs_once()

    def _probe_pfs_once(self) -> None:
        """One half-open probe op (seeded payload) through
        :meth:`RealExecutor.probe_pfs`; the outcome feeds the breaker."""
        sh = self.storage_health
        try:
            sh.check("pfs")  # open -> half_open; admits this op as a probe
        except CircuitOpenError:
            return
        payload = self._probe_rng.getrandbits(64).to_bytes(8, "little") * 2
        try:
            lat = self.executor.probe_pfs(payload)
        except OSError:
            sh.record("pfs", False)
            return
        sh.record("pfs", True, lat)

    def _drain_parked(self) -> None:
        """Finish every parked flush now that the circuit closed.

        Reuses :meth:`resume_flushes` (placement + journal on disk is
        exactly the resume input).  Steps the resume finished — or
        definitively failed, or that stopped being ``flush_partial``
        (superseded/GC'd) — leave the parked set; steps deferred by a
        circuit that re-opened mid-drain stay parked for the next tick.
        """
        with self._lock:
            if self._draining or not self._parked:
                return
            self._draining = True
            n = len(self._parked)
        log.info("PFS circuit closed: draining %d parked flush(es)", n)
        try:
            with self._lock:
                pre_err = {s for s, _ in self._flush_errors}
            out = self.resume_flushes()
            with self._lock:
                new_err = {s for s, _ in self._flush_errors} - pre_err
                for s in list(self._parked):
                    if s in out or s in new_err:
                        self._parked.pop(s, None)
                for s, res in out.items():
                    st = self._stats_by_step.get(s)
                    if st is not None:
                        st.flush = res
                self._drained_total += len(out)
            for s in sorted(self._parked):
                if self.step_status(s, "pfs") != "flush_partial":
                    with self._lock:
                        self._parked.pop(s, None)
            with self._lock:
                self._preempt_parked &= set(self._parked)
                if not self._parked:
                    self._degraded_since = None
        finally:
            with self._lock:
                self._draining = False

    def health(self) -> ManagerHealth:
        """Current availability snapshot (see :class:`ManagerHealth`)."""
        sh = self.storage_health
        circuits: Dict[str, str] = {}
        pfs_state = "closed"
        if sh is not None:
            circuits = {name: sh.state(name) for name in sh.snapshot()}
            pfs_state = sh.state("pfs")
        with self._lock:
            parked = sorted(self._parked)
            l1 = sum(self._l1_bytes.values())
            since = self._degraded_since
            drained = self._drained_total
            evicted = list(self._evicted)
            draining = self._draining
        if pfs_state != "closed":
            mode = "degraded"
        elif parked or draining:
            mode = "draining"
        else:
            mode = "normal"
        return ManagerHealth(
            mode=mode,
            queue_depth=self._q.qsize(),
            parked_steps=parked,
            l1_bytes=l1,
            l1_capacity=self.cfg.l1_capacity_bytes,
            circuits=circuits,
            degraded_since=since,
            drained_steps=drained,
            evicted_steps=evicted,
        )

    def health_check(self) -> ManagerHealth:
        """Drive one probe/drain opportunity, then return the snapshot.

        The async scheduler ticks on its own; sync managers (and
        deterministic tests) call this to advance the open → half-open
        → closed → drained recovery explicitly."""
        self._health_tick()
        return self.health()

    # ------------------------------------------------ L1 capacity accounting

    def _scan_l1_occupancy(self) -> None:
        """Rebuild L1 byte accounting from the local manifests on disk
        (manager constructed over an existing root with a budget set)."""
        mult = 2 if (
            self.cfg.partner_replication and self.cluster.n_nodes > 1
        ) else 1
        for p in sorted(
            (self.root / "local" / "manifests").glob("step_*.json")
        ):
            try:
                man = self._cached_manifest(p)
            except Exception:
                continue
            if man.status == "quarantined":
                continue
            cost = sum(r.stored_size for r in man.ranks) * mult
            self._l1_bytes[man.step] = cost
            self._last_l1_cost = cost
            if man.base_step is None and self.cfg.codec == "zstd+delta":
                self._l1_anchors.add(man.step)

    def _enforce_l1_budget(self, new_step: int, need: int, *, strict: bool) -> None:
        """Evict oldest evictable steps until ``need`` more L1 bytes fit.

        ``strict=True`` (the pre-write reservation in :meth:`save`)
        raises :class:`L1CapacityError` when the budget is full and
        nothing is evictable; ``strict=False`` (the post-write
        true-up, where the step's real cost is first known) only logs —
        the bytes are already on disk and the next save reconciles.
        """
        cap = self.cfg.l1_capacity_bytes
        if cap <= 0:
            return
        while True:
            with self._lock:
                occ = sum(self._l1_bytes.values())
                if occ + need <= cap:
                    return
                victim = self._pick_l1_victim_locked(new_step)
            if victim is None:
                if strict:
                    raise L1CapacityError(
                        f"save({new_step}): L1 budget of {cap} bytes is "
                        f"full ({occ} resident + ~{need} incoming) and "
                        "every resident step is pinned (delta anchor, "
                        "live delta window, keep_n, or in-flight flush)"
                    )
                log.warning(
                    "L1 occupancy %d exceeds the %d-byte budget and no "
                    "step is evictable", occ, cap,
                )
                return
            self._evict_l1(victim)

    def _pick_l1_victim_locked(self, new_step: int) -> Optional[int]:
        """Oldest L1-resident step safe to drop (caller holds _lock).

        Never: the incoming step, delta anchors, live-delta-window
        bases, ``keep_n``-pinned steps, or steps queued/mid-flight/
        mid-resume.  Parked steps *are* candidates — last in save
        order — and are superseded (not silently lost) by the evictor.
        """
        keep = self.cfg.keep_n
        pinned = set(self._saved_steps[-keep:]) if keep is not None else set()
        window_floor = None
        if self.cfg.codec == "zstd+delta" and self._last_full is not None:
            window_floor = self._last_full.step
        for s in sorted(self._l1_bytes):
            if s == new_step or s in pinned or s in self._l1_anchors:
                continue
            if s in self._pins:
                continue
            if s in self._pending or s in self._resuming:
                continue
            if window_floor is not None and s >= window_floor:
                continue
            return s
        return None

    def _evict_l1(self, step: int) -> None:
        """Drop one step's L1 blobs (+ replicas + local manifest) for
        the byte budget.  A parked step loses its only path to the PFS
        with its L1, so it is superseded first — visible in
        ``superseded_steps``, skipped by the drain — never silently
        unfinishable."""
        with self._lock:
            parked = step in self._parked
        if parked:
            try:
                man = self._gc_manifest_any(step)
                man.status = "superseded"
                self._write_manifest_pfs(man)
            except Exception:
                log.exception(
                    "failed to mark evicted parked step %d superseded", step
                )
            with self._lock:
                self._parked.pop(step, None)
            self._note_superseded(step, "parked")
        self.local.gc_step(step)
        mp = self.root / "local" / "manifests" / f"step_{step:08d}.json"
        if mp.exists():
            mp.unlink()
        with self._lock:
            self._l1_bytes.pop(step, None)
            self._l1_anchors.discard(step)
            self._evicted.append(step)
            self._man_cache.pop(str(mp), None)
        log.info("L1 budget: evicted step %d%s", step,
                 " (parked; superseded)" if parked else "")

    def _do_flush(self, job: _FlushJob) -> FlushResult:
        enc, plan = job.enc, job.plan
        man = enc.manifest
        man.strategy = plan.strategy
        man.files = dict(plan.files)
        man.placement = placement_from_plan(plan)
        journal: Optional[FlushJournal] = None
        if self.cfg.resumable_flushes:
            # commit the write set *before* the first byte: a
            # flush_partial manifest (full columnar placement + file
            # sizes) plus the extent journal is everything
            # resume_flushes() needs after any interruption.  fresh=True:
            # a journal left by a previous incarnation of this step
            # describes *different bytes* and must never skip writes here.
            man.status = "flush_partial"
            self._write_manifest_pfs(man)
            journal = FlushJournal(self._journal_path(enc.step), fresh=True)
        try:
            res = self.executor.execute(
                plan, enc.step,
                cancel=job.token, limiter=self._limiter, journal=journal,
            )
        except FlushCancelled:
            if job.superseded and self.cfg.resumable_flushes:
                # a superseded partial is dead, not resumable: newer
                # state already replaced it — mark it so resume skips it
                man.status = "superseded"
                self._write_manifest_pfs(man)
            raise
        man.status = "flush_done"
        self._write_manifest_pfs(man)
        self._notify_flush_done(enc.step)
        if journal is not None:
            journal.unlink()
        if self.cfg.keep_n is not None:
            try:
                self._gc()
            except Exception:
                log.exception("gc failed")
        return res

    def resume_flushes(self) -> Dict[int, FlushResult]:
        """Finish every interrupted (``flush_partial``) flush on the PFS.

        Scans the step manifests, rebuilds each partial flush's write
        set from its persisted columnar placement, skips the extents
        its journal proves already written, and rewrites only the rest
        (``FlushResult.bytes_skipped`` reports the saved volume).  On
        success the manifest flips to ``flush_done`` and the journal is
        deleted.  Requires the step's L1 blobs to still exist on the
        home node or (with ``partner_replication``) on its partner;
        a step whose copies are all gone is unfinishable, is recorded
        in ``flush_errors``, and restore falls back as usual — other
        steps still resume.  Superseded partials are left alone.
        Returns ``{step: FlushResult}`` for the steps that finished.
        """
        out: Dict[int, FlushResult] = {}
        for p in sorted(self.pfs_dir.glob("step_*/manifest.json")):
            try:
                man = self._cached_manifest(p)
            except Exception:
                continue
            if man.status != "flush_partial":
                continue
            with self._lock:
                # one acquisition: never race a live flush, and shield
                # the step from a concurrently running _gc sweep
                if man.step in self._pending or man.step in self._resuming:
                    continue
                self._resuming.add(man.step)
            try:
                journal = FlushJournal(self._journal_path(man.step))
                res = self.executor.execute_resume(
                    man, man.step, limiter=self._limiter, journal=journal
                )
                man.status = "flush_done"
                self._write_manifest_pfs(man)
                self._notify_flush_done(man.step)
                journal.unlink()
            except CircuitOpenError:
                # the PFS circuit (re)opened mid-resume: not a dead
                # step — it stays flush_partial/journaled/parked and a
                # later drain retries it once the circuit closes
                log.warning(
                    "resume of step %d deferred: PFS circuit open", man.step
                )
                continue
            except Exception as e:  # one dead step must not block the rest
                log.exception("resume of step %d failed", man.step)
                with self._lock:
                    self._flush_errors.append((man.step, repr(e)))
                continue
            finally:
                with self._lock:
                    self._resuming.discard(man.step)
            out[man.step] = res
            log.info(
                "resumed flush for step %d: %d bytes rewritten, %d skipped",
                man.step, res.bytes_written, res.bytes_skipped,
            )
        return out

    def wait(self) -> None:
        """Drain all pending flushes (returns when the PFS is settled)."""
        if self.cfg.async_flush:
            self._q.join()

    def close(self, *, timeout: float = 60.0) -> None:
        """Shut down, draining pending flushes — never dropping them
        silently.

        The worker gets ``timeout`` seconds to drain.  If it is still
        busy after that, every pending flush's token is cancelled: the
        in-flight flush stops at its next request boundary with its
        progress journaled (manifest at ``flush_partial``), queued ones
        fail fast the same way, and the steps left unflushed are
        enumerated in an error log — all of them recoverable via
        :meth:`resume_flushes` on a manager over the same root.  (The
        seed bug: ``join(timeout=60)`` could return with the worker
        alive, ``_worker`` was set to ``None`` anyway, and the queued
        flushes vanished without a trace.)  Raises ``RuntimeError`` if
        the worker ignores cancellation too (e.g. a hook blocked in
        foreign code) rather than pretend the shutdown was clean.
        """
        if self._worker is not None:
            self._q.put(None)
            self._worker.join(timeout=timeout)
            if self._worker.is_alive():
                with self._lock:
                    lost = sorted(self._pending)
                    for job in self._pending.values():
                        job.token.cancel()
                log.error(
                    "close(): flush worker still busy after %.1fs; "
                    "cancelling %d pending flush(es) for steps %s (%s)",
                    timeout, len(lost), lost,
                    "progress journaled; finish with resume_flushes()"
                    if self.cfg.resumable_flushes
                    else "resumable_flushes=False: these steps exist on "
                    "L1 only — re-save or flush them before relying on "
                    "the PFS",
                )
                self._worker.join(timeout=max(5.0, timeout))
                if self._worker.is_alive():
                    raise RuntimeError(
                        "close(): flush worker did not stop; steps "
                        f"{lost} not flushed (journaled state on disk)"
                    )
            self._worker = None
        if self._local_exec is not None:
            self._local_exec.shutdown(wait=True)
            self._local_exec = None
        if self._device_precodec is not None:
            self._device_precodec.close()
            self._device_precodec = None
        self.executor.close()

    @property
    def flush_errors(self) -> List[Tuple[int, str]]:
        with self._lock:
            return list(self._flush_errors)

    @property
    def superseded_steps(self) -> List[int]:
        """Steps whose flush the runtime superseded (queued or
        mid-flush).  Restorable from L1 via the normal ladder."""
        with self._lock:
            return sorted({s for s, _ in self._superseded})

    @property
    def interrupted_steps(self) -> List[int]:
        """Steps whose flush was interrupted (e.g. by a ``close()``
        deadline).  With ``resumable_flushes=True`` their progress is
        journaled — finish via :meth:`resume_flushes`; with it off they
        exist on L1 only and must be re-saved or re-flushed."""
        with self._lock:
            return sorted(set(self._interrupted))

    # --------------------------------------------------------------- restore

    def _cached_manifest(self, p: Path) -> Manifest:
        """Parse a manifest JSON through a stat-keyed cache.

        ``steps()`` runs on every save (via ``_gc``) and on every restore
        candidate scan; re-parsing an unchanged 32k-rank manifest each
        time would dominate those paths.  Manifests are replaced
        atomically (``os.replace``), which allocates a fresh inode, so
        (ino, mtime_ns, size) identifies the content even on
        coarse-mtime filesystems; anything else falls through to a
        fresh parse.  The cache is insertion-order bounded (paper-scale
        manifests hold MBs of placement columns, and with the default
        ``keep_n=None`` the step count is unbounded); ``_gc`` also
        evicts deleted steps eagerly."""
        stat = p.stat()
        sig = (stat.st_ino, stat.st_mtime_ns, stat.st_size)
        key = str(p)
        with self._lock:
            hit = self._man_cache.get(key)
            if hit is not None and hit[0] == sig:
                return hit[1]
        man = Manifest.from_json(p.read_text())
        with self._lock:
            self._man_cache.pop(key, None)   # reinsert at the newest slot
            self._man_cache[key] = (sig, man)
            while len(self._man_cache) > self._MAN_CACHE_CAP:
                self._man_cache.pop(next(iter(self._man_cache)))
        return man

    def steps(self, level: str = "pfs") -> List[int]:
        if level == "pfs":
            out = []
            for p in sorted(self.pfs_dir.glob("step_*/manifest.json")):
                try:
                    man = self._cached_manifest(p)
                    if man.status == "flush_done":
                        out.append(man.step)
                except Exception:
                    continue
            return out
        if level == "local":
            out = []
            for p in sorted((self.root / "local" / "manifests").glob("step_*.json")):
                try:
                    man = self._cached_manifest(p)
                    if man.status == "quarantined":
                        continue  # no good copy anywhere: never listed
                    out.append(man.step)
                except Exception:
                    continue
            return out
        raise ValueError(level)

    def latest_step(self) -> Optional[int]:
        pfs = self.steps("pfs")
        local = self.steps("local")
        allsteps = sorted(set(pfs) | set(local))
        return allsteps[-1] if allsteps else None

    def step_status(self, step: int, level: str = "pfs") -> Optional[str]:
        """Manifest lifecycle status of ``step`` at ``level`` (``"pfs"``
        or ``"local"``), or ``None`` if no manifest exists there.

        Unlike :meth:`steps` this reports *every* state — including
        ``flush_partial``/``superseded``/``quarantined`` — so operators
        and the serving follower can see why a step is not servable.
        """
        if level == "pfs":
            p = self.pfs_dir / f"step_{step:08d}" / "manifest.json"
        elif level == "local":
            p = self.root / "local" / "manifests" / f"step_{step:08d}.json"
        else:
            raise ValueError(level)
        try:
            return self._cached_manifest(p).status
        except (OSError, ValueError):
            return None

    # ------------------------------------------------- new-step notification

    def subscribe(self, fn: Callable[[int], None]) -> None:
        """Register ``fn(step)`` to fire after each flush reaches
        ``flush_done`` (sync saves, async flushes, and resumed partials
        alike).  Callbacks run on the flushing thread and must be
        cheap/non-blocking — the serving follower just records the step
        and wakes its own thread.  Exceptions are logged, never allowed
        to fail the flush."""
        with self._lock:
            self._subscribers.append(fn)

    def unsubscribe(self, fn: Callable[[int], None]) -> None:
        with self._lock:
            try:
                self._subscribers.remove(fn)
            except ValueError:
                pass

    def _notify_flush_done(self, step: int) -> None:
        with self._lock:
            subs = list(self._subscribers)
        for fn in subs:
            try:
                fn(step)
            except Exception:
                log.exception("flush_done subscriber failed for step %d", step)

    def leaf_catalog(
        self, step: Optional[int] = None, prefix: str = ""
    ) -> Tuple[int, List["LeafEntry"]]:
        """Enumerate the stored leaves of a step without reading any data.

        Returns ``(step, entries)`` where each entry carries the leaf's
        manifest name, dtype, shape, and raw byte range — everything a
        streamed restore needs to plan layer groups before issuing a
        single read.  ``prefix`` filters to a subtree (e.g.
        ``"['params']"``); ``step=None`` picks the newest restorable
        step, falling back PFS → L1 like :meth:`restore_leaves`.
        Raises ``FileNotFoundError`` when no step has leaves under the
        prefix."""
        candidates = (
            [step]
            if step is not None
            else sorted(
                set(self.steps("pfs")) | set(self.steps("local")), reverse=True
            )
        )
        errors: List[str] = []
        for s in candidates:
            for getter, level in (
                (self._manifest_pfs, "pfs"),
                (self._manifest_local, "local"),
            ):
                try:
                    man = getter(s)
                except Exception as e:
                    errors.append(f"step {s} via {level}: {e!r}")
                    continue
                entries = [l for l in man.leaves if l.name.startswith(prefix)]
                if entries:
                    return s, entries
                errors.append(f"step {s}: no leaves under prefix {prefix!r}")
                break  # both levels carry the same leaf table
        raise FileNotFoundError(
            "no step with leaves under prefix "
            f"{prefix!r}; attempts: " + "; ".join(errors[:8])
        )

    def restore(
        self,
        target: Any,
        step: Optional[int] = None,
        *,
        sharding_fn: Optional[Callable[[str, Any], Any]] = None,
    ) -> Tuple[int, Any]:
        """Restore the newest (or given) step into ``target``'s structure.

        Tries, in order: L0 twin, L2 (PFS), L1 (local, incl. partner
        replicas), then older steps.  ``sharding_fn(name, np_array)`` may
        map each leaf onto devices (elastic re-shard).
        """
        candidates: List[int]
        if step is not None:
            candidates = [step]
        else:
            candidates = sorted(
                set(self.steps("pfs")) | set(self.steps("local")), reverse=True
            )
        errors: List[str] = []
        for s in candidates:
            with self._lock:
                l0 = self._l0
            if l0 is not None and l0.step == s:
                tgt = self._decode_target(l0.manifest, target)
                tree = deserialize_tree(l0.stream, l0.manifest.leaves, tgt)
                tree = self._maybe_dequant(l0.manifest, tree, target)
                return s, self._place(tree, sharding_fn)
            for loader in (self._restore_from_pfs, self._restore_from_local):
                try:
                    tree = loader(s, target)
                    return s, self._place(tree, sharding_fn)
                except Exception as e:
                    errors.append(f"step {s} via {loader.__name__}: {e!r}")
        raise FileNotFoundError(
            "no restorable checkpoint found; attempts: " + "; ".join(errors[:8])
        )

    def _place(self, tree: Any, sharding_fn) -> Any:
        if sharding_fn is None:
            return tree
        from repro.utils.treelib import flatten_with_names

        named, treedef = flatten_with_names(tree)
        placed = [sharding_fn(name, leaf) for name, leaf in named]
        return jax.tree_util.tree_unflatten(treedef, placed)

    # -- level loaders ----

    def _manifest_pfs(self, step: int) -> Manifest:
        p = self.pfs_dir / f"step_{step:08d}" / "manifest.json"
        man = self._cached_manifest(p)
        if man.status == "quarantined":
            raise IOError(
                f"step {step}: quarantined (scrub-and-repair found no "
                "intact copy) — excluded from restore and delta-base use"
            )
        if man.status != "flush_done":
            raise IOError(f"step {step}: flush incomplete")
        return man

    def _manifest_local(self, step: int) -> Manifest:
        p = self.root / "local" / "manifests" / f"step_{step:08d}.json"
        man = self._cached_manifest(p)
        if man.status == "quarantined":
            raise IOError(
                f"step {step}: quarantined (scrub-and-repair found no "
                "intact copy) — excluded from restore and delta-base use"
            )
        return man

    @staticmethod
    def _decode_target(man: Manifest, target: Any) -> Any:
        if man.precodec == "int8":
            from repro.core.precodec import quant_target_like

            return quant_target_like(target)
        return target

    def _maybe_dequant(self, man: Manifest, tree: Any, target: Any) -> Any:
        if man.precodec == "int8":
            from repro.core.precodec import dequantize_tree

            return dequantize_tree(tree, target, pool=self._decode_pool())
        return tree

    def _decode_pool(self) -> Optional[ThreadPoolExecutor]:
        """Pool for restore-side work (chunk decompress, CRC, dequant):
        the manager's own local pool — restores never queue behind async
        flush traffic either.  ``parallel_local=False`` keeps the seed's
        sequential decode."""
        return self._local_pool() if self.cfg.parallel_local else None

    def _hedge_policy(
        self, man: Manifest, step: int
    ) -> Optional[HedgePolicy]:
        """Alternate-source read policy for one PFS plan (or ``None``
        when ``hedged_reads`` is off / the manifest has no placement).

        ``alt_read(file_id, file_offset, size)`` inverts the manifest's
        placement back to (rank, blob offset) and serves the extent
        from the surviving L1/partner copy via :meth:`_local_slice` —
        the L1 → partner → PFS preference order the restore ladder
        already encodes.  It returns ``None`` (hedge declines) when no
        local copy survives: hedging may only ever help the tail.
        """
        if not self.cfg.hedged_reads or man.placement is None:
            return None
        pl = man.placement
        order = np.argsort(np.asarray(pl.file_offset), kind="stable")
        fids = np.asarray(pl.file_id)[order]
        f_off = np.asarray(pl.file_offset)[order]
        s_off = np.asarray(pl.src_offset)[order]
        f_sz = np.asarray(pl.size)[order]
        f_rk = np.asarray(pl.rank)[order]
        by_file: Dict[int, Tuple[np.ndarray, ...]] = {}
        for f in np.unique(fids).tolist():
            m = fids == f
            by_file[int(f)] = (f_off[m], s_off[m], f_sz[m], f_rk[m])

        def alt_read(fid: int, foff: int, size: int) -> Optional[bytes]:
            ent = by_file.get(int(fid))
            if ent is None:
                return None
            offs, srcs, szs, rks = ent
            parts: List[bytes] = []
            cur, remaining = int(foff), int(size)
            try:
                while remaining > 0:
                    i = int(np.searchsorted(offs, cur, side="right")) - 1
                    if i < 0 or cur >= int(offs[i]) + int(szs[i]):
                        return None  # hole: not covered by this placement
                    take = min(remaining, int(offs[i]) + int(szs[i]) - cur)
                    parts.append(self._local_slice(
                        man, step, int(rks[i]),
                        int(srcs[i]) + cur - int(offs[i]), take,
                    ))
                    cur += take
                    remaining -= take
            except OSError:
                return None  # no surviving L1/partner copy: decline
            return b"".join(parts)

        return HedgePolicy(
            alt_read=alt_read,
            quantile=self.cfg.hedge_quantile,
            min_delay_s=self.cfg.hedge_min_delay,
        )

    def _reader_weights(self) -> Optional[np.ndarray]:
        """Health-derived per-reader byte weights for
        :func:`~repro.core.plan.assign_readers` — straggler demotion.

        A reader whose observed median pread latency exceeds twice the
        cross-reader median gets its byte share scaled down by the
        slowdown ratio (floored at 1/8 so no reader is starved and its
        recovery stays observable).  ``None`` — the exact unweighted
        assignment — until at least two readers have latency history.
        """
        sh = self.storage_health
        if sh is None or not self.cfg.hedged_reads:
            return None
        n = self.cluster.n_nodes
        if n < 2:
            return None
        meds = [sh.latency_quantile(f"reader:n{k}", 0.5) for k in range(n)]
        known = sorted(m for m in meds if m > 0)
        if len(known) < 2:
            return None
        # lower middle on even counts: with two readers the straggler
        # must compare against the healthy one, not against itself
        global_med = known[(len(known) - 1) // 2]
        if global_med <= 0:
            return None
        w = np.ones(n, np.float64)
        for k, m in enumerate(meds):
            if m > 2.0 * global_med:
                w[k] = max(0.125, global_med / m)
        if np.allclose(w, 1.0):
            return None
        return w

    def _read_blobs_pfs(
        self, man: Manifest, step: int, ranks: Optional[List[int]] = None,
        *, record: bool = True, verify: bool = False,
    ) -> Dict[int, bytearray]:
        """Fetch stored rank blobs through ONE aggregated :class:`ReadPlan`.

        The read-side twin of the flush: the manifest's placement is
        inverted into a :class:`FileLayout`, each requested producer blob
        becomes a byte-range request, and the *current* cluster geometry
        (``self.cluster`` — not the one that saved the checkpoint)
        supplies the reader assignment, so an N-rank save restores onto M
        consumer nodes with balanced ranged preads instead of N
        sequential whole-blob fetches.

        ``verify=True`` hangs the manifest CRC check on the executor's
        ``on_request`` hook, so each blob is verified *on the worker
        pool as it arrives* — integrity work overlaps the remaining
        preads instead of running as a serial pass in ``decode_state``
        afterwards.  All mismatches are collected and raised together
        after the plan drains.
        """
        layout = man.file_layout()
        offsets = man.stored_offsets()
        sizes = np.asarray([r.stored_size for r in man.ranks], np.int64)
        readers = assign_readers(
            sizes, self.cluster.n_nodes, weights=self._reader_weights()
        )
        sel = (
            np.arange(man.world_size, dtype=np.int64)
            if ranks is None
            else np.asarray(sorted(ranks), np.int64)
        )
        rp = build_read_plan(layout, offsets[sel], sizes[sel], readers[sel])
        on_request = None
        bad: List[int] = []
        if verify:
            expected = [man.ranks[int(r)].crc for r in sel.tolist()]

            def on_request(i: int, buf: bytearray) -> None:
                if crc32(buf) != expected[i]:
                    bad.append(int(sel[i]))  # list.append is atomic

        bufs, res = self.executor.execute_read_plan(
            rp, step, on_request=on_request, hedge=self._hedge_policy(man, step)
        )
        if record:  # the scrub passes False so restore telemetry survives
            self.last_read_result = res
        if bad:
            raise IOError(
                f"rank {sorted(bad)[0]}: checksum mismatch on arrival "
                f"({len(bad)} blob(s) failed)"
            )
        return {int(r): b for r, b in zip(sel.tolist(), bufs)}

    def _check_delta_base(self, man: Manifest) -> None:
        """Reject a delta whose base was encoded under a different
        ``precodec``: the XOR would "decode" into bytes that are neither
        transform's stream.  Checked against whichever level's base
        manifest is readable; an unreadable base fails later in
        ``_load_stream`` anyway."""
        if man.base_step is None:
            return
        for getter in (self._manifest_local, self._manifest_pfs):
            try:
                bman = getter(man.base_step)
            except Exception:
                continue
            if bman.precodec != man.precodec:
                raise IOError(
                    f"step {man.step}: delta base {man.base_step} was "
                    f"encoded with precodec {bman.precodec!r}, not "
                    f"{man.precodec!r} — chain is invalid"
                )
            return

    def _restore_from_pfs(self, step: int, target: Any) -> Any:
        man = self._manifest_pfs(step)
        self._check_delta_base(man)
        verify = self.cfg.verify_on_restore
        by_rank = self._read_blobs_pfs(man, step, verify=verify)
        blobs = [by_rank[r] for r in range(man.world_size)]
        base_stream = (
            self._load_stream(man.base_step) if man.base_step is not None else None
        )
        tree = decode_state(
            man, blobs, self._decode_target(man, target), base_stream=base_stream,
            verify=False,  # arrival hook above already CRC-checked each blob
            pool=self._decode_pool(),
        )
        return self._maybe_dequant(man, tree, target)

    def _restore_from_local(self, step: int, target: Any) -> Any:
        man = self._manifest_local(step)
        self._check_delta_base(man)
        blobs = self._local_blobs(man, step)
        base_stream = (
            self._load_stream(man.base_step) if man.base_step is not None else None
        )
        tree = decode_state(
            man, blobs, self._decode_target(man, target), base_stream=base_stream,
            verify=self.cfg.verify_on_restore, pool=self._decode_pool(),
        )
        return self._maybe_dequant(man, tree, target)

    def _local_location(
        self, man: Manifest, step: int, rank: int
    ) -> Tuple[int, bool]:
        """(node, is_partner) of the surviving L1 copy of ``rank``'s blob.

        The single definition of the partner-replication invariant: the
        home node first, else the replica on node+1.  Both full and
        partial local restore resolve through here.
        """
        node = rank // man.procs_per_node
        if self.local.has_blob(node, step, rank):
            return node, False
        partner = (node + 1) % max(1, man.world_size // man.procs_per_node)
        if self.local.has_blob(partner, step, rank, partner=True):
            return partner, True
        raise IOError(f"rank {rank}: no local or partner copy for step {step}")

    def _local_blob(self, man: Manifest, step: int, rank: int) -> bytes:
        node, partner = self._local_location(man, step, rank)
        return self.local.read_blob(node, step, rank, partner=partner)

    def _local_blobs(self, man: Manifest, step: int) -> List[bytes]:
        return [self._local_blob(man, step, r) for r in range(man.world_size)]

    def _local_slice(
        self, man: Manifest, step: int, rank: int, offset: int, size: int
    ) -> bytes:
        node, partner = self._local_location(man, step, rank)
        return self.local.read_slice(
            node, step, rank, offset, size, partner=partner
        )

    def _load_stream(self, step: int) -> Buffer:
        """Raw logical stream of ``step`` (resolving delta chains).

        Decodes through :func:`~repro.core.serialize.decode_stream`
        (preallocated buffer, chunk-parallel on the local pool), with
        PFS arrival-CRC verification; a damaged level falls through to
        the next one instead of aborting the chain.
        """
        with self._lock:
            if self._l0 is not None and self._l0.step == step:
                return self._l0.stream
            if self._last_full is not None and self._last_full.step == step:
                return self._last_full.stream
        verify = self.cfg.verify_on_restore
        errors: List[str] = []
        for getter, pfs in (
            (self._manifest_pfs, True),
            (self._manifest_local, False),
        ):
            try:
                man = getter(step)
                self._check_delta_base(man)
                if pfs:
                    by_rank = self._read_blobs_pfs(man, step, verify=verify)
                    blobs: List[Any] = [by_rank[r] for r in range(man.world_size)]
                else:
                    blobs = self._local_blobs(man, step)
                base = (
                    self._load_stream(man.base_step)
                    if man.base_step is not None
                    else None
                )
                return decode_stream(
                    man, blobs, base_stream=base,
                    verify=verify and not pfs,  # pfs: verified on arrival
                    pool=self._decode_pool(),
                )
            except Exception as e:
                errors.append(f"{'pfs' if pfs else 'local'}: {e!r}")
        raise IOError(
            f"cannot load base stream for step {step}; " + "; ".join(errors)
        )

    # -------------------------------------------------------- partial restore

    def restore_leaves(
        self, names: List[str], step: Optional[int] = None
    ) -> Tuple[int, Dict[str, np.ndarray]]:
        """Restore only the named leaves (manifest leaf names) as numpy
        arrays, without touching the rest of the checkpoint.

        With ``codec="none"`` this reads *exactly* the leaves' byte
        ranges from the aggregated files (a partial :class:`ReadPlan`) —
        the serving-fleet workload: pull just the params out of a
        multi-GB train-state checkpoint.  With a chunk-framed
        compression codec, only the *chunks* covering those ranges are
        read and decompressed (base-referencing delta chunks recurse
        into the base step for just their own ranges); legacy
        whole-blob checkpoints fall back to reading the covering
        producer blobs (still one aggregated plan each way).

        Integrity: whole-blob paths verify the per-blob CRC and
        chunk-framed paths the per-chunk CRCs, so compressed partial
        restores are fully verified; only codec-``none`` sub-blob
        ranged reads have no checksum of their own — run
        :meth:`validate` scrubs for cold-checkpoint assurance there.

        Falls back PFS -> L1 like :meth:`restore`.  Checkpoints saved
        with a ``precodec`` raise :class:`UnsupportedPrecodecError` at
        plan time — before any blob or extent read is issued, and
        *without* falling through to an older step (the stored leaves
        are the transformed tree; restore them with :meth:`restore`).
        """
        candidates = (
            [step]
            if step is not None
            else sorted(set(self.steps("pfs")) | set(self.steps("local")), reverse=True)
        )
        errors: List[str] = []
        for s in candidates:
            for getter, pfs in (
                (self._manifest_pfs, True),
                (self._manifest_local, False),
            ):
                try:
                    man = getter(s)
                    return s, self._leaves_from(man, s, names, pfs=pfs)
                except UnsupportedPrecodecError:
                    # never falls through to an older step: silently
                    # serving stale leaves is worse than failing loudly
                    raise
                except Exception as e:
                    errors.append(
                        f"step {s} via {'pfs' if pfs else 'local'}: {e!r}"
                    )
        raise FileNotFoundError(
            "no checkpoint with the requested leaves; attempts: "
            + "; ".join(errors[:8])
        )

    def restore_subtree(
        self,
        target: Any,
        prefix: str,
        step: Optional[int] = None,
        *,
        sharding_fn: Optional[Callable[[str, Any], Any]] = None,
    ) -> Tuple[int, Any]:
        """Restore the subtree saved under ``prefix`` into ``target``.

        ``prefix`` is the leaf-name prefix in the saved tree: a snapshot
        saved as ``{"params": P, "opt": O}`` yields leaf names like
        ``"['params']['w']"``, so ``restore_subtree(params_template,
        "['params']")`` rebuilds P alone — the elastic-serving entry
        point (:meth:`repro.serve.engine.Server.from_checkpoint`).
        """
        from repro.utils.treelib import flatten_with_names

        named, treedef = flatten_with_names(target)
        names = [prefix + n for n, _ in named]
        step_out, vals = self.restore_leaves(names, step=step)
        tree = jax.tree_util.tree_unflatten(treedef, [vals[n] for n in names])
        return step_out, self._place(tree, sharding_fn)

    def _leaves_from(
        self, man: Manifest, step: int, names: List[str], *, pfs: bool
    ) -> Dict[str, np.ndarray]:
        # plan-time rejection: nothing has been read beyond the manifest
        if man.precodec != "none":
            raise UnsupportedPrecodecError(
                f"step {step}: partial restore unsupported with precodec "
                f"{man.precodec!r} — restore() handles the inverse transform"
            )
        entries = {l.name: l for l in man.leaves}
        ranges = man.leaf_ranges(names)
        segs = self._raw_segments(
            man, step, [(a, a + s) for _, a, s in ranges], pfs=pfs
        )
        out: Dict[str, np.ndarray] = {}
        for (n, _, size), seg in zip(ranges, segs):
            e = entries[n]
            if len(seg) != size:
                raise IOError(f"leaf {n}: read {len(seg)} of {size} bytes")
            out[n] = (
                np.frombuffer(seg, np.dtype(e.dtype)).reshape(e.shape).copy()
            )
        return out

    def _raw_segments(
        self,
        man: Manifest,
        step: int,
        intervals: List[Tuple[int, int]],
        *,
        pfs: bool,
    ) -> List[Buffer]:
        """Bytes of arbitrary raw-space intervals of one checkpoint,
        reading as little stored data as the manifest's framing allows.

        * codec ``none`` — stored == raw byte for byte: exactly the
          requested ranges (one aggregated plan on PFS, ranged L1
          slices locally).
        * chunk-framed compression — only the *chunks* covering the
          intervals: their stored extents merge into minimal requests
          (:func:`~repro.core.plan.merge_intervals`) for one aggregated
          plan (PFS) or ranged L1 slices (local); each fetched chunk is
          CRC-verified individually — sub-blob reads are no longer an
          integrity blind spot — and base-referencing/delta chunks pull
          just their own byte ranges out of the base step, recursively,
          instead of materializing the whole base stream.
        * legacy whole-blob compression — the covering rank blobs (the
          pre-chunking behaviour).
        """
        if man.codec == "none":
            return self._raw_segments_codec_none(man, step, intervals, pfs=pfs)
        if man.chunks is not None:
            return self._raw_segments_chunked(man, step, intervals, pfs=pfs)
        return self._raw_segments_whole_blob(man, step, intervals, pfs=pfs)

    def _raw_segments_codec_none(
        self, man, step, intervals, *, pfs: bool
    ) -> List[Buffer]:
        if pfs:
            offs = [a for a, _ in intervals]
            szs = [b - a for a, b in intervals]
            readers = assign_readers(
                szs, self.cluster.n_nodes, weights=self._reader_weights()
            )
            rp = build_read_plan(man.file_layout(), offs, szs, readers)
            bufs, res = self.executor.execute_read_plan(
                rp, step, hedge=self._hedge_policy(man, step)
            )
            self.last_read_result = res
            return bufs
        out: List[Buffer] = []
        for a, b in intervals:
            parts = []
            for rk in man.ranks_covering(a, b):
                e = man.ranks[rk]
                lo, hi = max(a, e.offset), min(b, e.offset + e.raw_size)
                parts.append(
                    self._local_slice(man, step, rk, lo - e.offset, hi - lo)
                )
            out.append(b"".join(parts))
        return out

    def _raw_segments_chunked(
        self, man, step, intervals, *, pfs: bool
    ) -> List[Buffer]:
        table = man.chunks
        # 1. chunk rows covering the intervals (global row indices)
        need: List[np.ndarray] = []
        for a, b in intervals:
            for rk in man.ranks_covering(a, b):
                e = man.ranks[rk]
                need.append(
                    table.covering(rk, max(a, e.offset) - e.offset,
                                   min(b, e.offset + e.raw_size) - e.offset)
                )
        all_rows = (
            np.unique(np.concatenate(need)) if need else np.empty(0, np.int64)
        )

        # 1b. decoded-chunk cache (node-local, shared across co-located
        #     servers): rows already decoded for this step — by an
        #     earlier replica's restore, or as another step's delta
        #     base — skip the stored read AND the decode entirely.
        cache = self.chunk_cache
        cached: Dict[int, np.ndarray] = {}
        if cache is not None and len(all_rows):
            for row in all_rows.tolist():
                hit = cache.get((step, int(row)))
                if hit is not None:
                    cached[int(row)] = hit
        rows = (
            all_rows[~np.isin(all_rows, np.fromiter(cached, np.int64))]
            if cached
            else all_rows
        )
        rank_of = np.searchsorted(table.rank_starts, rows, side="right") - 1

        # 2. fetch the stored payloads of every non-base-ref chunk
        payloads: Dict[int, Buffer] = {}
        stored = rows[table.stored_len[rows] > 0]
        if pfs and len(stored):
            offsets = man.stored_offsets()
            g_off = (
                offsets[np.searchsorted(table.rank_starts, stored, side="right") - 1]
                + table.stored_off[stored]
            )
            g_len = table.stored_len[stored]
            req_start, req_size = merge_intervals(g_off, g_len)
            readers = assign_readers(
                req_size, self.cluster.n_nodes, weights=self._reader_weights()
            )
            rp = build_read_plan(man.file_layout(), req_start, req_size, readers)
            bufs, res = self.executor.execute_read_plan(
                rp, step, hedge=self._hedge_policy(man, step)
            )
            self.last_read_result = res
            views = [memoryview(b) for b in bufs]
            req_of = np.searchsorted(req_start, g_off, side="right") - 1
            for row, q, off, ln in zip(
                stored.tolist(), req_of.tolist(),
                (g_off - req_start[req_of]).tolist(), g_len.tolist(),
            ):
                payloads[row] = views[q][off : off + ln]
        else:
            for row, rk in zip(stored.tolist(),
                               (np.searchsorted(table.rank_starts, stored,
                                                side="right") - 1).tolist()):
                payloads[row] = self._local_slice(
                    man, step, rk,
                    int(table.stored_off[row]), int(table.stored_len[row]),
                )

        # 3. base byte ranges for base-referencing / delta chunks —
        #    recursively partial against the base step (never the whole
        #    base stream)
        base_rows = rows[
            (table.flags[rows] & (CHUNK_BASE | CHUNK_DELTA)) != 0
        ]
        base_segs: Dict[int, Buffer] = {}
        if len(base_rows):
            if man.base_step is None:
                raise IOError("base-referencing chunks without a base step")
            br_rank = np.searchsorted(table.rank_starts, base_rows, side="right") - 1
            b_ivs = [
                (man.ranks[int(rk)].offset + int(table.raw_off[row]),
                 man.ranks[int(rk)].offset + int(table.raw_off[row])
                 + int(table.raw_len[row]))
                for row, rk in zip(base_rows.tolist(), br_rank.tolist())
            ]
            # the recursive base fetch runs its own read plans; restore
            # *this* step's stats afterwards so last_read_result keeps
            # describing the plan the caller asked about
            outer_rr = self.last_read_result
            try:
                for row, seg in zip(
                    base_rows.tolist(),
                    self._base_raw_segments(man.base_step, b_ivs),
                ):
                    base_segs[row] = seg
            finally:
                self.last_read_result = outer_rr

        # 4. decode each needed chunk (pooled: disjoint outputs, the
        #    decompressor releases the GIL) with per-chunk CRC verify
        verify = self.cfg.verify_on_restore
        impl = man.codec_impl or default_codec_impl()
        decoded: Dict[int, np.ndarray] = {
            int(row): np.empty(int(table.raw_len[row]), np.uint8)
            for row in rows.tolist()
        }

        def decode_row(row: int) -> None:
            rl = int(table.raw_len[row])
            decode_chunk_into(
                decoded[row],
                payloads.get(row, b""),
                int(table.flags[row]),
                int(table.crc[row]),
                rl,
                base_segs.get(row),
                impl,
                verify=verify,
                digest=(
                    int(table.digest[row])
                    if (verify and table.digest is not None)
                    else None
                ),
                what=f"rank {int(rank_of[np.searchsorted(rows, row)])} chunk",
            )

        _run_grouped(self._decode_pool(), decode_row, rows.tolist())

        if cache is not None:
            for row, arr in decoded.items():
                cache.put((step, row), arr)
        decoded.update(cached)

        # 5. assemble each interval from the decoded chunks
        out: List[Buffer] = []
        for a, b in intervals:
            seg = np.empty(b - a, np.uint8)
            for rk in man.ranks_covering(a, b):
                e = man.ranks[rk]
                lo, hi = max(a, e.offset), min(b, e.offset + e.raw_size)
                for row in table.covering(rk, lo - e.offset, hi - e.offset).tolist():
                    g = e.offset + int(table.raw_off[row])  # chunk's global start
                    cs = max(lo, g)
                    ce = min(hi, g + int(table.raw_len[row]))
                    seg[cs - a : ce - a] = decoded[row][cs - g : ce - g]
            out.append(seg)
        return out

    def _raw_segments_whole_blob(
        self, man, step, intervals, *, pfs: bool
    ) -> List[Buffer]:
        """Legacy (pre-chunking) compressed manifests: whole covering
        blobs, one aggregated plan."""
        need = sorted(
            {rk for a, b in intervals for rk in man.ranks_covering(a, b)}
        )
        verify = self.cfg.verify_on_restore
        if pfs:
            blobs = self._read_blobs_pfs(man, step, ranks=need, verify=verify)
        else:
            blobs = {rk: self._local_blob(man, step, rk) for rk in need}
        base = (
            self._load_stream(man.base_step)
            if man.base_step is not None
            else None
        )
        seg: Dict[int, bytes] = {}
        for rk in need:
            e = man.ranks[rk]
            if verify and not pfs and crc32(blobs[rk]) != e.crc:
                raise IOError(f"rank {rk}: checksum mismatch")
            seg_base = (
                base[e.offset : e.offset + e.raw_size]
                if base is not None
                else None
            )
            seg[rk] = decode_blob_reference(
                blobs[rk], man.codec, e.raw_size, seg_base,
                has_base=man.base_step is not None,
                impl=man.codec_impl or None,
            )
        out: List[Buffer] = []
        for a, b in intervals:
            parts = []
            for rk in man.ranks_covering(a, b):
                e = man.ranks[rk]
                lo, hi = max(a, e.offset), min(b, e.offset + e.raw_size)
                parts.append(seg[rk][lo - e.offset : hi - e.offset])
            out.append(b"".join(parts))
        return out

    def _base_raw_segments(
        self, base_step: int, intervals: List[Tuple[int, int]]
    ) -> List[Buffer]:
        """Raw byte ranges of a delta base, cheapest source first: the
        in-memory L0/last-full twin, else a recursive partial read of
        the base checkpoint (PFS then L1), else the full stream."""
        with self._lock:
            for cand in (self._l0, self._last_full):
                if cand is not None and cand.step == base_step:
                    stream = cand.stream
                    return [stream[a:b] for a, b in intervals]
        errors: List[str] = []
        for getter, pfs in (
            (self._manifest_pfs, True),
            (self._manifest_local, False),
        ):
            try:
                bman = getter(base_step)
                return self._raw_segments(bman, base_step, intervals, pfs=pfs)
            except Exception as e:
                errors.append(repr(e))
        stream = self._load_stream(base_step)  # last resort (raises if gone)
        return [stream[a:b] for a, b in intervals]

    # ----------------------------------------------------------------- scrub

    def validate(self, step: int, *, repair: bool = False) -> Dict[str, Any]:
        """Integrity scrub of one checkpoint: re-read every rank blob on
        every available level and verify its manifest CRC.

        Returns ``{"pfs": {rank: ok}, "local": {rank: ok}, "partner":
        {rank: ok}}`` (levels missing entirely are reported as ``{}``;
        ``partner`` only appears when partner replication is configured).
        Production fleets run this against cold checkpoints before
        relying on them for elastic restarts.

        ``repair=True`` turns the scrub into scrub-and-repair
        (:func:`repro.core.repair.repair_step`): damaged PFS extents are
        rewritten from surviving L1/partner copies through the columnar
        placement, lost L1/partner blobs are re-replicated from the PFS
        (anti-entropy), and a step with *no* intact copy of some rank is
        quarantined — the report gains ``"repair"`` (a
        :class:`~repro.core.repair.RepairReport`) and ``"post"`` (the
        re-scrub after repair).
        """
        report = self._scrub(step)
        if repair:
            from repro.core.repair import repair_step

            report["repair"] = repair_step(self, step, scrub=report)
            report["post"] = self._scrub(step)
        return report

    def _scrub(self, step: int) -> Dict[str, Any]:
        report: Dict[str, Any] = {"pfs": {}, "local": {}}
        try:
            man = self._manifest_pfs(step)
            try:
                layout = man.file_layout()
            except Exception:
                layout = None
            # Aggregated read plans in byte-bounded batches: one plan per
            # ~256 MiB of blobs keeps the ranged-pread win without
            # materializing a paper-scale checkpoint in memory at once.
            batch_limit = 256 << 20
            batch: List[int] = []
            batch_bytes = 0
            for r in range(man.world_size):
                batch.append(r)
                batch_bytes += man.ranks[r].stored_size
                if batch_bytes >= batch_limit or r == man.world_size - 1:
                    self._scrub_batch(man, step, batch, layout, report["pfs"])
                    batch, batch_bytes = [], 0
        except Exception:
            pass
        try:
            man = self._manifest_local(step)
            ppn = man.procs_per_node
            n_nodes = max(1, man.world_size // ppn)
            replicated = self.cfg.partner_replication and n_nodes > 1
            if replicated:
                report["partner"] = {}
            for r in range(man.world_size):
                try:
                    blob = self.local.read_blob(r // ppn, step, r)
                    report["local"][r] = crc32(blob) == man.ranks[r].crc
                except Exception:
                    report["local"][r] = False
                if replicated:
                    partner = (r // ppn + 1) % n_nodes
                    try:
                        blob = self.local.read_blob(
                            partner, step, r, partner=True
                        )
                        report["partner"][r] = crc32(blob) == man.ranks[r].crc
                    except Exception:
                        report["partner"][r] = False
        except Exception:
            pass
        return report

    def _scrub_batch(
        self,
        man: Manifest,
        step: int,
        batch: List[int],
        layout,
        out: Dict[int, bool],
    ) -> None:
        """CRC-check one batch of ranks; a damaged file fails the batch's
        aggregated read, so degrade to per-rank reads (sharing the
        already-inverted layout) and keep intact ranks reporting healthy."""
        try:
            if layout is None:
                raise IOError("placement does not invert")
            blobs = self._read_blobs_pfs(man, step, ranks=batch, record=False)
            for r in batch:
                out[r] = crc32(blobs[r]) == man.ranks[r].crc
        except Exception:
            for r in batch:
                try:
                    blob = self.executor.read_rank_blob(man, step, r, layout)
                    out[r] = crc32(blob) == man.ranks[r].crc
                except Exception:
                    out[r] = False

    # ------------------------------------------------------------------- gc

    def _gc(self) -> None:
        keep = self.cfg.keep_n
        pfs_steps = self.steps("pfs")
        # No early-out at len(pfs_steps) <= keep: under supersession
        # most steps never reach flush_done, and their L1/partial-PFS
        # leavings still need reaping below the newest kept checkpoint.
        if keep is None or not pfs_steps:
            return
        with self._lock:
            pins = set(self._pins)
        # Operator pins widen retention beyond the keep_n window: a
        # pinned step (and, via the chain walk below, its delta bases)
        # survives GC until unpinned, whatever its age.
        kept = set(pfs_steps[-keep:]) | pins
        # Retain delta bases of kept steps.  The chain must traverse
        # *any* surviving manifest, not just flush_done ones: under
        # supersession a base step's PFS manifest may be superseded (or
        # absent) while its L1 level is exactly what keeps the kept
        # checkpoint restorable — breaking the walk there would let the
        # sweep below delete live bases, full-snapshot anchors included.
        needed = set(kept)
        for s in kept:
            cur = s
            while True:
                man = None
                for getter in (self._gc_manifest_any, self._manifest_local):
                    try:
                        man = getter(cur)
                        break
                    except Exception:
                        continue
                if man is None or man.base_step is None:
                    break
                needed.add(man.base_step)
                cur = man.base_step
        # Sweep set: every step known to either level — including steps
        # that never reached flush_done (superseded, or stale partials
        # the operator chose not to resume).  Under a fast supersession
        # cadence those are the *majority* of steps, and their L1 blobs
        # and partial PFS dirs must not accumulate past the retention
        # window.  Steps newer than the newest kept checkpoint, and
        # steps still queued/mid-flight, are left alone (they may still
        # be flushing or awaiting resume).
        with self._lock:
            # parked steps are shielded like mid-resume ones: their
            # journaled flush_partial state is what the post-outage
            # drain finishes — only supersession/eviction may drop it
            pending = set(self._pending) | set(self._resuming) | set(self._parked)
        max_kept = max(kept)
        known = set(pfs_steps)
        for d in self.pfs_dir.glob("step_*"):
            try:
                known.add(int(d.name[5:]))
            except ValueError:
                continue
        for p in (self.root / "local" / "manifests").glob("step_*.json"):
            try:
                known.add(int(p.stem[5:]))
            except ValueError:
                continue
        for s in sorted(known):
            if s in needed or s in pending or s > max_kept:
                continue
            sdir = self.pfs_dir / f"step_{s:08d}"
            if sdir.exists():
                shutil.rmtree(sdir)
            self.local.gc_step(s)
            mp = self.root / "local" / "manifests" / f"step_{s:08d}.json"
            if mp.exists():
                mp.unlink()
            # evict the deleted step's parsed manifests — at paper scale
            # each caches MBs of placement columns, and a long run with
            # GC must not accumulate one dead entry per checkpoint taken
            with self._lock:
                self._man_cache.pop(str(sdir / "manifest.json"), None)
                self._man_cache.pop(str(mp), None)
                self._l1_bytes.pop(s, None)
                self._l1_anchors.discard(s)

    # ------------------------------------------------------------- manifests

    def _gc_manifest_any(self, step: int) -> Manifest:
        """PFS manifest of ``step`` in *any* status — GC chain walking
        only needs ``base_step``, unlike the restore path's
        :meth:`_manifest_pfs` which rightly rejects non-final states."""
        return self._cached_manifest(
            self.pfs_dir / f"step_{step:08d}" / "manifest.json"
        )

    def _write_manifest_local(self, man: Manifest) -> None:
        p = self.root / "local" / "manifests" / f"step_{man.step:08d}.json"
        tmp = p.with_suffix(".tmp")
        tmp.write_text(man.to_json())
        tmp.replace(p)

    def _write_manifest_pfs(self, man: Manifest) -> None:
        p = self.pfs_dir / f"step_{man.step:08d}" / "manifest.json"
        p.parent.mkdir(parents=True, exist_ok=True)
        tmp = p.with_suffix(".tmp")
        tmp.write_text(man.to_json())
        tmp.replace(p)
