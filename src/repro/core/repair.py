"""Scrub-and-repair: turn detected damage back into healthy replicas.

:func:`repair_step` is the detect-and-repair half of
``CheckpointManager.validate(step, repair=True)`` — the anti-entropy
pass a self-healing fleet runs after faults, node replacements, or a
cold-storage scrub flags damage.  Three repair actions, in order:

1. **PFS extent rewrite** — a rank whose aggregated-file bytes fail
   their manifest CRC (bit flip, torn write, lost file) is rewritten
   *in place* from a surviving L1 or partner copy.  The columnar
   :class:`~repro.core.serialize.Placement` gives the exact
   ``(file, file_offset, src_offset, size)`` extents of that rank, so
   the rewrite touches only the damaged rank's bytes — never the whole
   aggregated file.
2. **L1 / partner re-replication** — a home-node blob lost to
   ``drop_node`` (node failure + replacement) is written back from the
   PFS copy (CRC-verified on read), and, with partner replication
   configured, so is the partner replica: the replica count heals back
   to its configured level instead of staying degraded forever.
3. **Quarantine** — a rank with *no* intact copy on any level is
   irreparable; the step's manifests are flipped to
   ``status="quarantined"`` (terminal), which the restore ladder,
   ``steps()``, delta-base selection and GC all honor — a quarantined
   step can delay a restore (fall back to an older step), never corrupt
   one.  Delta descendants of a quarantined step decode through its
   bytes (``CHUNK_BASE``/``CHUNK_DELTA`` chunks), so the delta chain is
   walked and every descendant is marked suspect and quarantined with
   it.

Repairs use the same hardened I/O as the rest of the runtime: blob
reads/writes go through :class:`~repro.core.storage.LocalStore` (retry
+ structured errors) and PFS reads through the executor's read plans;
the targeted extent pwrites are wrapped in the manager's
:class:`~repro.core.storage.RetryPolicy`.
"""
from __future__ import annotations

import logging
import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.integrity import crc32
from repro.core.serialize import Manifest

log = logging.getLogger("repro.repair")


@dataclass
class RepairReport:
    """What one :func:`repair_step` pass did (all rank lists sorted)."""

    step: int
    pfs_repaired: List[int] = field(default_factory=list)
    l1_restored: List[int] = field(default_factory=list)
    partner_restored: List[int] = field(default_factory=list)
    unrepairable: List[int] = field(default_factory=list)
    quarantined: bool = False
    #: delta descendants of a quarantined step — marked suspect and
    #: quarantined with it (their chunks decode through its bytes)
    suspect_descendants: List[int] = field(default_factory=list)
    errors: List[str] = field(default_factory=list)

    @property
    def repaired(self) -> bool:
        return bool(self.pfs_repaired or self.l1_restored or self.partner_restored)

    def as_dict(self) -> Dict:
        return {
            "step": self.step,
            "pfs_repaired": list(self.pfs_repaired),
            "l1_restored": list(self.l1_restored),
            "partner_restored": list(self.partner_restored),
            "unrepairable": list(self.unrepairable),
            "quarantined": self.quarantined,
            "suspect_descendants": list(self.suspect_descendants),
            "errors": list(self.errors),
        }


# ---------------------------------------------------------------- manifests


def _load_any_manifest(mgr, step: int, *, pfs: bool) -> Optional[Manifest]:
    """Manifest of ``step`` in *any* status (repair must see quarantined
    and partial steps the restore-path loaders rightly reject)."""
    p = (
        mgr.pfs_dir / f"step_{step:08d}" / "manifest.json"
        if pfs
        else mgr.root / "local" / "manifests" / f"step_{step:08d}.json"
    )
    try:
        return mgr._cached_manifest(p)
    except Exception:
        return None


def _known_steps(mgr) -> List[int]:
    out = set()
    for p in (mgr.root / "local" / "manifests").glob("step_*.json"):
        try:
            out.add(int(p.stem[5:]))
        except ValueError:
            continue
    for d in mgr.pfs_dir.glob("step_*"):
        try:
            out.add(int(d.name[5:]))
        except ValueError:
            continue
    return sorted(out)


def _base_of(mgr, step: int) -> Optional[int]:
    for pfs in (False, True):
        man = _load_any_manifest(mgr, step, pfs=pfs)
        if man is not None:
            return man.base_step
    return None


def _descendants_of(mgr, step: int) -> List[int]:
    """Steps whose delta chain passes through ``step`` (transitively)."""
    out = []
    for s in _known_steps(mgr):
        if s == step:
            continue
        cur, seen = s, set()
        while cur is not None and cur not in seen:
            seen.add(cur)
            cur = _base_of(mgr, cur)
            if cur == step:
                out.append(s)
                break
    return out


def _ancestor_quarantined(mgr, step: int) -> Optional[int]:
    """Nearest quarantined ancestor on the delta chain, if any."""
    cur, seen = _base_of(mgr, step), set()
    while cur is not None and cur not in seen:
        seen.add(cur)
        for pfs in (True, False):
            man = _load_any_manifest(mgr, cur, pfs=pfs)
            if man is not None and man.status == "quarantined":
                return cur
        cur = _base_of(mgr, cur)
    return None


def quarantine_step(mgr, step: int) -> None:
    """Flip every manifest of ``step`` to the terminal ``quarantined``
    state (idempotent; manifests that don't exist are not created,
    except the PFS one is only rewritten where a PFS dir already is)."""
    man = _load_any_manifest(mgr, step, pfs=True)
    if man is not None and man.status != "quarantined":
        man.status = "quarantined"
        mgr._write_manifest_pfs(man)
    man = _load_any_manifest(mgr, step, pfs=False)
    if man is not None and man.status != "quarantined":
        man.status = "quarantined"
        mgr._write_manifest_local(man)
    # Never let a future delta chain onto a quarantined anchor: the
    # in-memory twin may still be intact, but deltas encoded against it
    # become undecodable the moment this process exits.
    with mgr._lock:
        if mgr._last_full is not None and mgr._last_full.step == step:
            mgr._last_full = None
            mgr._saves_since_full = 0
        if mgr._l0 is not None and mgr._l0.step == step:
            mgr._l0 = None


# ------------------------------------------------------------------ sources


def _read_l1(mgr, man: Manifest, step: int, rank: int, *, partner: bool):
    """CRC-verified L1/partner blob of ``rank``, or None."""
    ppn = max(1, man.procs_per_node)
    n_nodes = max(1, man.world_size // ppn)
    node = rank // ppn
    if partner:
        node = (node + 1) % n_nodes
    try:
        blob = mgr.local.read_blob(node, step, rank, partner=partner)
    except OSError:
        return None
    if crc32(blob) != man.ranks[rank].crc:
        return None
    return blob


def _read_pfs(mgr, man: Manifest, step: int, rank: int, layout):
    """CRC-verified PFS blob of ``rank``, or None."""
    try:
        blob = mgr.executor.read_rank_blob(man, step, rank, layout)
    except Exception:
        return None
    if crc32(blob) != man.ranks[rank].crc:
        return None
    return blob


def _rewrite_pfs_extents(mgr, man: Manifest, step: int, ranks: Dict[int, bytes]) -> None:
    """pwrite the given ranks' blobs back into the aggregated files at
    exactly the byte ranges the columnar placement assigns them."""
    pl = man.placement
    sdir = mgr.executor.step_dir(step)
    sdir.mkdir(parents=True, exist_ok=True)
    fds: Dict[int, int] = {}
    try:
        for rank, blob in ranks.items():
            mv = memoryview(blob)
            for i in np.flatnonzero(pl.rank == rank).tolist():
                fid = int(pl.file_id[i])
                fd = fds.get(fid)
                if fd is None:
                    fname = pl.file_names[fid]
                    fd = os.open(str(sdir / fname), os.O_CREAT | os.O_WRONLY, 0o644)
                    planned = man.files.get(fname)
                    if planned is not None:
                        # re-establish the planned size (no-op when the
                        # file survived; re-extends a lost/truncated one)
                        os.ftruncate(fd, int(planned))
                    fds[fid] = fd
                foff = int(pl.file_offset[i])
                soff = int(pl.src_offset[i])
                sz = int(pl.size[i])

                def _pwrite(fd=fd, mv=mv, soff=soff, sz=sz, foff=foff):
                    os.pwrite(fd, mv[soff : soff + sz], foff)

                if mgr.retry is not None:
                    mgr.retry.run(_pwrite)
                else:
                    _pwrite()
        for fd in fds.values():
            os.fsync(fd)
    finally:
        for fd in fds.values():
            try:
                os.close(fd)
            except OSError:
                pass


# ------------------------------------------------------------------- repair


def repair_step(mgr, step: int, *, scrub: Optional[Dict] = None) -> RepairReport:
    """Detect-and-repair one step across the multi-level ladder.

    ``mgr`` is the :class:`~repro.core.engine.CheckpointManager`;
    ``scrub`` may carry a just-computed ``validate()`` report to skip
    re-probing levels it already CRC-checked.  Returns a
    :class:`RepairReport`; irreparable damage quarantines the step (and
    its delta descendants) rather than ever leaving wrong bytes
    restorable.
    """
    rep = RepairReport(step=step)
    man_pfs = _load_any_manifest(mgr, step, pfs=True)
    man_local = _load_any_manifest(mgr, step, pfs=False)
    man = man_pfs if man_pfs is not None else man_local
    if man is None:
        rep.errors.append(f"step {step}: no manifest on any level")
        return rep
    if (man_pfs is not None and man_pfs.status == "quarantined") or (
        man_local is not None and man_local.status == "quarantined"
    ):
        quarantine_step(mgr, step)  # idempotent: align both manifests
        rep.quarantined = True
        return rep
    anc = _ancestor_quarantined(mgr, step)
    if anc is not None:
        # a damaged CHUNK_BASE ancestor poisons every descendant: this
        # step's delta chunks decode through bytes that no longer exist
        rep.errors.append(f"delta ancestor step {anc} is quarantined")
        rep.quarantined = True
        quarantine_step(mgr, step)
        log.warning("step %d quarantined: ancestor %d is quarantined", step, anc)
        return rep

    # the PFS level is a trusted source/repair target only once its
    # flush completed — partial flushes belong to resume_flushes()
    pfs_trusted = man_pfs is not None and man_pfs.status == "flush_done"
    ppn = max(1, man.procs_per_node)
    n_nodes = max(1, man.world_size // ppn)
    replicate = bool(getattr(mgr.cfg, "partner_replication", False)) and n_nodes > 1
    scrub = scrub or {}
    layout = None
    if pfs_trusted:
        try:
            layout = man_pfs.file_layout()
        except Exception:
            layout = None

    # ---- per-rank source census (lazy blob reads, scrub-informed) ----
    l1_blob: Dict[int, bytes] = {}
    partner_blob: Dict[int, bytes] = {}
    pfs_bad: List[int] = []
    for r in range(man.world_size):
        if pfs_trusted:
            ok = scrub.get("pfs", {}).get(r)
            if ok is None:
                ok = _read_pfs(mgr, man_pfs, step, r, layout) is not None
            if not ok:
                pfs_bad.append(r)

    # ---- 1. PFS extent rewrite from surviving L1/partner copies ----
    if pfs_trusted and pfs_bad:
        fixes: Dict[int, bytes] = {}
        for r in pfs_bad:
            blob = _read_l1(mgr, man, step, r, partner=False)
            if blob is not None:
                l1_blob[r] = blob
            elif replicate:
                blob = _read_l1(mgr, man, step, r, partner=True)
                if blob is not None:
                    partner_blob[r] = blob
            if blob is not None:
                fixes[r] = blob
        if fixes:
            try:
                _rewrite_pfs_extents(mgr, man_pfs, step, fixes)
                for r in sorted(fixes):
                    # trust only a verified rewrite
                    if _read_pfs(mgr, man_pfs, step, r, layout) is not None:
                        rep.pfs_repaired.append(r)
                    else:
                        rep.errors.append(
                            f"rank {r}: PFS rewrite did not verify"
                        )
            except Exception as e:
                rep.errors.append(f"PFS extent rewrite failed: {e!r}")

    # ---- 2. anti-entropy: re-replicate L1 / partner from the PFS ----
    still_bad_pfs = set(pfs_bad) - set(rep.pfs_repaired)
    if man_local is not None:
        for r in range(man.world_size):
            need_home = _read_l1(mgr, man, step, r, partner=False) is None
            need_partner = (
                replicate
                and _read_l1(mgr, man, step, r, partner=True) is None
            )
            if not (need_home or need_partner):
                continue
            blob = l1_blob.get(r)
            if blob is None:
                blob = partner_blob.get(r)
            if blob is None and not need_home:
                # surviving home copy heals a lost/corrupt partner
                blob = _read_l1(mgr, man, step, r, partner=False)
            if blob is None and replicate and not need_partner:
                # surviving partner copy heals a lost/corrupt home
                blob = _read_l1(mgr, man, step, r, partner=True)
            if blob is None and pfs_trusted and r not in still_bad_pfs:
                blob = _read_pfs(mgr, man_pfs, step, r, layout)
            if blob is None:
                continue  # rank-level verdict handled below
            node = r // ppn
            try:
                if need_home:
                    mgr.local.write_blob(node, step, r, blob)
                    rep.l1_restored.append(r)
                if need_partner:
                    mgr.local.write_blob(
                        (node + 1) % n_nodes, step, r, blob, partner=True
                    )
                    rep.partner_restored.append(r)
            except OSError as e:
                rep.errors.append(f"rank {r}: re-replication failed: {e!r}")

    # ---- 3. quarantine: any rank with no intact copy anywhere ----
    for r in range(man.world_size):
        pfs_ok = pfs_trusted and r not in still_bad_pfs
        l1_ok = _read_l1(mgr, man, step, r, partner=False) is not None
        p_ok = replicate and _read_l1(mgr, man, step, r, partner=True) is not None
        if not (pfs_ok or l1_ok or p_ok):
            rep.unrepairable.append(r)
    if rep.unrepairable:
        rep.quarantined = True
        quarantine_step(mgr, step)
        rep.suspect_descendants = _descendants_of(mgr, step)
        for d in rep.suspect_descendants:
            quarantine_step(mgr, d)
        log.warning(
            "step %d quarantined (ranks %s irreparable); "
            "descendants quarantined: %s",
            step, rep.unrepairable[:8], rep.suspect_descendants,
        )
    elif rep.repaired:
        log.info(
            "step %d repaired: pfs=%s l1=%s partner=%s",
            step, rep.pfs_repaired, rep.l1_restored, rep.partner_restored,
        )
    return rep
