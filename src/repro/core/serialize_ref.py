"""Seed (item-loop) serialization path, kept verbatim as the executable
spec for the zero-copy encode pipeline in :mod:`repro.core.serialize`.

Same pattern as :mod:`repro.core.strategies_ref`: the original
implementation survives unchanged so the equivalence suite
(tests/test_save_phase.py) can prove the fast path byte-identical —
same logical stream, same rank blobs, same CRCs, same manifest — and so
``benchmarks/save_phase.py`` can measure the speedup against the real
pre-PR code instead of a synthetic stand-in.

Copy accounting of this path (what the zero-copy rewrite removes), for
a checkpoint of S bytes under codec ``none``:

* per-leaf ``tobytes()``            — S bytes of temporaries
* ``b"".join(chunks)``              — S bytes (the stream)
* per-rank ``stream[off:off+size]`` — S bytes (the blobs)
* ``crc32(bytes(blob))``            — S bytes (pre-PR ``crc32`` copied)

i.e. the state crossed memory ~4x before reaching the L1 files; the
fast path crosses once (pytree -> stream) and hands out views.
"""
from __future__ import annotations

from typing import Any, List, Optional, Sequence, Tuple

from repro.core.cluster import ClusterSpec
from repro.core.integrity import crc32
from repro.core.serialize import (
    EncodedState,
    LeafEntry,
    Manifest,
    RankEntry,
    encode_blob,
    split_ranks,
)
from repro.utils.treelib import flatten_with_names


def _leaf_to_np(leaf: Any):
    import numpy as np

    return np.asarray(leaf)


def serialize_tree_reference(state: Any) -> Tuple[bytes, List[LeafEntry]]:
    """The seed serializer: per-leaf ``tobytes()`` + one join recopy."""
    named, _ = flatten_with_names(state)
    chunks: List[bytes] = []
    leaves: List[LeafEntry] = []
    off = 0
    for name, leaf in named:
        arr = _leaf_to_np(leaf)  # tobytes() emits C-order regardless of layout
        raw = arr.tobytes()
        leaves.append(
            LeafEntry(
                name=name, dtype=str(arr.dtype), shape=tuple(arr.shape),
                offset=off, size=len(raw),
            )
        )
        chunks.append(raw)
        off += len(raw)
    return b"".join(chunks), leaves


def encode_state_reference(
    step: int,
    state: Any,
    cluster: ClusterSpec,
    *,
    codec: str = "none",
    base: Optional[EncodedState] = None,
    rank_sizes: Optional[Sequence[int]] = None,
) -> EncodedState:
    """The seed encoder: sequential per-rank ``bytes`` slices + CRC."""
    stream, leaves = serialize_tree_reference(state)
    total = len(stream)
    parts = split_ranks(total, cluster.world_size, sizes=rank_sizes)
    base_ok = (
        base is not None
        and codec == "zstd+delta"
        and len(base.stream) == total
        and [
            (r.offset, r.raw_size) for r in base.manifest.ranks
        ] == list(parts)
    )
    blobs: List[bytes] = []
    ranks: List[RankEntry] = []
    for r, (off, size) in enumerate(parts):
        raw = stream[off : off + size]
        b = encode_blob(
            raw, codec,
            bytes(base.stream[off : off + size]) if base_ok else None,
        )
        blobs.append(bytes(b))
        ranks.append(
            RankEntry(
                rank=r, offset=off, raw_size=size, stored_size=len(b),
                crc=crc32(bytes(b)),
            )
        )
    from repro.core.serialize import default_codec_impl

    man = Manifest(
        step=step,
        total_raw_bytes=total,
        codec=codec,
        base_step=base.step if base_ok else None,
        world_size=cluster.world_size,
        procs_per_node=cluster.procs_per_node,
        leaves=leaves,
        ranks=ranks,
        # whole-blob framing: chunk_size stays 0, chunks stays None; the
        # backend is still recorded so decode dispatches correctly
        codec_impl=default_codec_impl() if codec != "none" else "",
    )
    return EncodedState(step=step, stream=stream, blobs=blobs, manifest=man)
