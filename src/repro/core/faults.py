"""Deterministic fault injection for the storage runtime.

The chaos surface for the self-healing storage stack: a seeded
:class:`FaultPlan` schedules faults at **exact operation indices** in
each storage domain, so a failure scenario is a pure function of its
seed — rerunning a seed replays the same schedule, and a sweep of seeds
(``benchmarks/chaos.py``) becomes a reproducible robustness suite.
This supersedes the ad-hoc ``fault_hook(write_item)`` callback as the
injection surface (the hook survives for targeted tests).

Domains and operations
----------------------

Every raw I/O call in the storage layer is an *operation* in one of
three domains:

* ``l1`` — home-node blob reads/writes (:class:`~repro.core.storage.
  LocalStore`);
* ``partner`` — partner-replica blob reads/writes;
* ``pfs`` — aggregated-file ``pwrite``/``pread`` through
  :class:`~repro.core.storage.RealExecutor`.

Each ``(domain, op)`` stream keeps a monotonically increasing counter
(every *attempt* counts, including retries); a :class:`FaultSpec`
fires when its stream's counter reaches ``index``.

Fault kinds
-----------

=================  ======================================================
``transient_eio``  raises ``OSError(EIO)`` for ``count`` consecutive
                   attempts, then heals — the retry policy's bread and
                   butter.
``enospc``         raises ``OSError(ENOSPC)`` once — classified
                   permanent, never retried; the flush fails but stays
                   journal-resumable.
``torn_write``     writes only a prefix (``frac``) of the payload, then
                   raises ``OSError(EIO)`` — a retried attempt rewrites
                   the full extent (idempotent destinations).
``bit_flip``       silently flips one bit of the payload before the
                   write — caught later by CRC scrub, never by errno.
``stall``          sleeps ``delay`` seconds, then proceeds — exercises
                   deadline accounting without failing the op.
``node_crash``     drops node ``node``'s L1 directory mid-flush
                   (:meth:`~repro.core.storage.LocalStore.drop_node`)
                   — subsequent source reads fall back to the partner
                   replica or fail the flush.
``outage``         the whole domain fails (``OSError(EIO)`` on every
                   op, both reads and writes) from attempt ``index``
                   until the window closes — ``duration`` seconds of
                   wall clock, or ``count`` ops when ``duration`` is 0,
                   or an explicit :meth:`FaultPlan.heal`.  The signal
                   the PFS circuit breaker exists to absorb.
``brownout``       sustained high latency: every op in the domain
                   sleeps ``delay`` seconds for the same window shape
                   as ``outage`` — slow, not failing.
``straggler``      node ``node`` is slow for the *whole armed phase*:
                   every op that reports that node sleeps ``delay``
                   seconds — exercises hedged reads and reader
                   demotion, not retries.
=================  ======================================================

``outage``/``brownout``/``straggler`` are *windowed* kinds: they are
listed in :data:`FAULT_KINDS_V2` but deliberately **not** in the
:data:`FAULT_KINDS` default of :meth:`FaultPlan.generate`, so existing
seeded chaos schedules (``benchmarks/chaos.py``) are byte-identical to
before.

Phases
------

Specs carry a ``phase`` (``"save"`` or ``"verify"``); only specs of
the currently armed phase fire.  :meth:`FaultPlan.arm` switches phase
and zeroes all counters, so a chaos schedule can target the
save→flush window and, separately, the scrub→restore window with
index spaces that both start at zero.
"""
from __future__ import annotations

import errno
import random
import threading
import time
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Tuple

FAULT_KINDS = (
    "transient_eio",
    "enospc",
    "torn_write",
    "bit_flip",
    "stall",
    "node_crash",
)
#: windowed availability kinds (PR 8) — valid in specs, excluded from
#: the ``generate`` default so old seeds replay identically
WINDOW_KINDS = ("outage", "brownout", "straggler")
FAULT_KINDS_V2 = FAULT_KINDS + WINDOW_KINDS
DOMAINS = ("l1", "partner", "pfs")
PHASES = ("save", "verify")

#: kinds that errno-classify as transient — a schedule made only of
#: these must produce zero ``flush_errors`` (the retry layer heals them)
TRANSIENT_KINDS = frozenset({"transient_eio", "stall"})


@dataclass
class FaultSpec:
    """One scheduled fault: ``kind`` fires at attempt ``index`` of the
    ``(domain, op)`` operation stream while ``phase`` is armed."""

    kind: str
    domain: str = "pfs"
    op: str = "write"  # "write" | "read"
    index: int = 0
    count: int = 1  # consecutive failing attempts (transient_eio)
    phase: str = "save"
    frac: float = 0.5  # fraction actually written by a torn write
    bit: int = 0  # bit position flipped by bit_flip (mod payload bits)
    delay: float = 0.02  # stall / brownout / straggler seconds per op
    node: int = 0  # node dropped by node_crash, or slowed by straggler
    duration: float = 0.0  # outage/brownout wall-clock window (0 -> count ops)

    def __post_init__(self) -> None:
        if self.kind not in FAULT_KINDS_V2:
            raise ValueError(f"unknown fault kind: {self.kind!r}")
        if self.domain not in DOMAINS:
            raise ValueError(f"unknown fault domain: {self.domain!r}")
        if self.op not in ("write", "read"):
            raise ValueError(f"unknown fault op: {self.op!r}")
        if self.phase not in PHASES:
            raise ValueError(f"unknown fault phase: {self.phase!r}")


def flip_bit(data, bit: int) -> bytes:
    """Return ``data`` with one bit flipped (position ``bit`` modulo
    the payload's bit length); empty payloads pass through."""
    buf = bytearray(data)
    if not buf:
        return bytes(buf)
    b = bit % (len(buf) * 8)
    buf[b >> 3] ^= 1 << (b & 7)
    return bytes(buf)


class FaultPlan:
    """A seeded, deterministic schedule of :class:`FaultSpec`\\ s.

    Thread-safe: the per-``(domain, op)`` attempt counters and the
    armed-spec state live behind one lock, so concurrent writer/reader
    threads observe a single global index space per stream.  Which
    thread's attempt lands on a scheduled index may vary with
    interleaving; *that an attempt does*, and what happens to it, is
    fixed by the seed.

    ``fired`` records every injection as ``(kind, domain, op, index)``
    tuples for assertions and harness telemetry.
    """

    def __init__(self, specs: Sequence[FaultSpec], *, seed: Optional[int] = None):
        self.specs = list(specs)
        self.seed = seed
        self._lock = threading.Lock()
        self._counters: dict = {}
        self._remaining = {id(s): max(1, int(s.count)) for s in self.specs}
        self._armed: dict = {}  # (domain, op) -> spec currently failing
        self._phase = "save"
        self._local = None  # bound LocalStore (node_crash target)
        self._enabled = True
        # domain -> (spec, deadline_monotonic | None, ops_left | None)
        self._windows: dict = {}
        self._straggler_fired: set = set()
        self.window_hits: dict = {}  # domain -> ops hit while a window was active
        self.fired: List[Tuple[str, str, str, int]] = []

    # ---- lifecycle --------------------------------------------------------

    def bind(self, local) -> None:
        """Attach the :class:`~repro.core.storage.LocalStore` that
        ``node_crash`` specs drop nodes from (the manager does this)."""
        self._local = local

    def arm(self, phase: str) -> None:
        """Switch the active phase and zero every stream counter."""
        if phase not in PHASES:
            raise ValueError(f"unknown fault phase: {phase!r}")
        with self._lock:
            self._phase = phase
            self._enabled = True
            self._counters.clear()
            self._armed.clear()
            self._windows.clear()
            self._straggler_fired.clear()

    def disarm(self) -> None:
        """Stop injecting entirely (schedule exhausted / out of window)."""
        with self._lock:
            self._enabled = False
            self._windows.clear()

    def heal(self, domain: Optional[str] = None) -> None:
        """Close active outage/brownout windows (all domains, or one).

        Lets a harness end an op-count or long wall-clock window at an
        exact point instead of waiting out the clock.
        """
        with self._lock:
            if domain is None:
                self._windows.clear()
            else:
                self._windows.pop(domain, None)

    def outage_active(self, domain: str) -> bool:
        """True while an ``outage``/``brownout`` window covers ``domain``."""
        with self._lock:
            return self._window_check(domain) is not None

    @property
    def phase(self) -> str:
        return self._phase

    def fired_kinds(self) -> set:
        return {k for (k, _, _, _) in self.fired}

    # ---- injection surface -----------------------------------------------

    def _window_check(self, domain: str, consume: bool = False):
        """Return the spec of an active outage/brownout window covering
        ``domain`` (or ``None``), expiring stale windows.  Lock held by
        the caller; ``consume`` burns one op of an op-count window."""
        w = self._windows.get(domain)
        if w is None:
            return None
        spec, deadline, ops_left = w
        if deadline is not None and time.monotonic() >= deadline:
            del self._windows[domain]
            return None
        if ops_left is not None:
            if ops_left <= 0:
                del self._windows[domain]
                return None
            if consume:
                self._windows[domain] = (spec, deadline, ops_left - 1)
        return spec

    def on_op(
        self, domain: str, op: str, what: str = "", node: Optional[int] = None
    ) -> Optional[FaultSpec]:
        """Account one attempt of ``(domain, op)`` and inject its fault.

        Raises for ``transient_eio``/``enospc``/``torn-write-less``
        error kinds, sleeps for ``stall``, drops a node for
        ``node_crash``; returns the spec for the data-transforming
        kinds (``bit_flip``, ``torn_write``) so the write site can
        apply them, else ``None``.

        ``node`` identifies the L1/partner node or the PFS reader the
        op runs on — ``straggler`` specs match it; windowed
        ``outage``/``brownout`` specs cover every op of the domain
        regardless of node once activated at their stream ``index``.
        """
        sleep_s = 0.0
        with self._lock:
            if not self._enabled:
                return None
            key = (domain, op)
            idx = self._counters.get(key, 0)
            self._counters[key] = idx + 1
            # stragglers are ambient: every matching-node op of the
            # armed phase is slowed, no index bookkeeping
            for s in self.specs:
                if (
                    s.kind == "straggler"
                    and s.phase == self._phase
                    and s.domain == domain
                    and node is not None
                    and s.node == node
                ):
                    sleep_s += max(0.0, s.delay)
                    fkey = (id(s), self._phase)
                    if fkey not in self._straggler_fired:
                        self._straggler_fired.add(fkey)
                        self.fired.append((s.kind, domain, op, idx))
            wspec = self._window_check(domain, consume=True)
            if wspec is not None:
                self.window_hits[domain] = self.window_hits.get(domain, 0) + 1
            spec = None
            if wspec is None:
                spec = self._armed.get(key)
                if spec is None:
                    for s in self.specs:
                        if (
                            s.phase == self._phase
                            and s.domain == domain
                            and s.op == op
                            and s.index == idx
                            and self._remaining[id(s)] > 0
                            and s.kind != "straggler"
                        ):
                            spec = s
                            break
                if spec is not None:
                    self._remaining[id(spec)] -= 1
                    if spec.kind == "transient_eio" and self._remaining[id(spec)] > 0:
                        self._armed[key] = spec  # keep failing the next attempts
                    else:
                        self._armed.pop(key, None)
                    self.fired.append((spec.kind, domain, op, idx))
                    if spec.kind in ("outage", "brownout"):
                        deadline = (
                            time.monotonic() + spec.duration
                            if spec.duration > 0
                            else None
                        )
                        ops_left = (
                            None
                            if spec.duration > 0
                            else max(0, int(spec.count) - 1)
                        )
                        self._windows[domain] = (spec, deadline, ops_left)
                        self.window_hits[domain] = (
                            self.window_hits.get(domain, 0) + 1
                        )
                        wspec = spec
                        spec = None  # handled as a window below
            local = self._local
        if sleep_s > 0.0:
            time.sleep(sleep_s)
        if wspec is not None:
            if wspec.kind == "outage":
                raise OSError(
                    errno.EIO, f"injected outage: {domain}/{op}[{idx}] {what}"
                )
            time.sleep(max(0.0, wspec.delay))  # brownout: slow, not failing
            return None
        if spec is None:
            return None
        if spec.kind == "transient_eio":
            raise OSError(
                errno.EIO, f"injected transient EIO: {domain}/{op}[{idx}] {what}"
            )
        if spec.kind == "enospc":
            raise OSError(
                errno.ENOSPC, f"injected ENOSPC: {domain}/{op}[{idx}] {what}"
            )
        if spec.kind == "stall":
            time.sleep(max(0.0, spec.delay))
            return None
        if spec.kind == "node_crash":
            if local is not None:
                local.drop_node(spec.node)
            return None
        return spec  # bit_flip / torn_write: caller applies

    # ---- seeded generation ------------------------------------------------

    #: minimum index gap between same-stream specs — keeps the worst
    #: consecutive-failure run below the default retry budget
    MIN_GAP = 8

    @staticmethod
    def generate(
        seed: int,
        *,
        n_faults: Optional[int] = None,
        kinds: Sequence[str] = FAULT_KINDS,
        domains: Sequence[str] = DOMAINS,
        max_index: int = 40,
        n_nodes: int = 2,
        verify_reads: bool = True,
    ) -> "FaultPlan":
        """Build a deterministic schedule from ``seed``.

        Constraints keep schedules *survivable by design*: transient
        counts stay ≤ 2, same-stream indices are spaced ≥
        :attr:`MIN_GAP` apart (a retry run can never eat through more
        than one transient spec plus its neighbour), and verify-phase
        specs are restricted to read-side transient kinds so a restore
        is delayed, never doomed, by them.
        """
        rng = random.Random(seed)
        n = n_faults if n_faults is not None else rng.randint(1, 3)
        specs: List[FaultSpec] = []
        used: dict = {}  # (phase, domain, op) -> list of taken indices
        for _ in range(int(n)):
            kind = rng.choice(list(kinds))
            if kind == "node_crash":
                domain, op = "pfs", "write"
            elif kind in ("enospc", "torn_write", "bit_flip"):
                domain, op = rng.choice(list(domains)), "write"
            else:  # transient_eio / stall: either side
                domain = rng.choice(list(domains))
                op = rng.choice(["write", "read"]) if domain != "partner" else "write"
            phase = "save"
            if (
                verify_reads
                and kind in TRANSIENT_KINDS
                and rng.random() < 0.25
            ):
                phase, domain, op = "verify", "pfs", "read"
            key = (phase, domain, op)
            taken = used.setdefault(key, [])
            for _try in range(16):
                idx = rng.randrange(0, max(1, max_index))
                if all(abs(idx - t) >= FaultPlan.MIN_GAP for t in taken):
                    break
            else:
                continue  # stream too crowded: drop this fault
            taken.append(idx)
            specs.append(
                FaultSpec(
                    kind=kind,
                    domain=domain,
                    op=op,
                    index=idx,
                    count=rng.randint(1, 2) if kind == "transient_eio" else 1,
                    phase=phase,
                    frac=rng.uniform(0.1, 0.9),
                    bit=rng.randrange(0, 1 << 20),
                    delay=rng.uniform(0.005, 0.03),
                    node=rng.randrange(0, max(1, n_nodes)),
                )
            )
        return FaultPlan(specs, seed=seed)

    @staticmethod
    def generate_fleet(
        seed: int,
        n_tenants: int,
        *,
        victim: Optional[int] = None,
        outage_duration: float = 0.0,
        outage_ops: int = 24,
        transient_rate: float = 0.25,
        max_index: int = 40,
    ) -> List["FaultPlan"]:
        """Per-tenant schedules for the multi-tenant (control-plane)
        path, derived from ONE seed.

        One tenant — ``victim``, or a seeded pick — gets a windowed PFS
        ``outage`` (wall-clock ``outage_duration`` seconds, or
        ``outage_ops`` write ops when the duration is 0) opening at a
        seeded op index of its *own* flush stream.  Because tenants
        share one PFS, the harness is expected to wire all tenants'
        managers to one :class:`~repro.core.storage.StorageHealth`
        (the control plane does): the victim's giveups open the shared
        circuit, and the invariant under test is isolation — other
        tenants' **L1 saves** keep succeeding (their flushes may park,
        that is the breaker doing its job) and the post-heal drain
        order honors tenant priority.  Non-victim tenants get either a
        clean plan or (with probability ``transient_rate`` each) one
        survivable L1-side transient, so the multi-tenant path also
        sees the retry machinery without a second breaker trip.
        """
        if n_tenants <= 0:
            raise ValueError("n_tenants must be positive")
        rng = random.Random(seed)
        v = rng.randrange(n_tenants) if victim is None else int(victim)
        plans: List[FaultPlan] = []
        for t in range(n_tenants):
            if t == v:
                specs = [
                    FaultSpec(
                        kind="outage",
                        domain="pfs",
                        op="write",
                        index=rng.randrange(0, max(1, max_index // 2)),
                        count=max(1, int(outage_ops)),
                        duration=float(outage_duration),
                    )
                ]
            elif rng.random() < transient_rate:
                specs = [
                    FaultSpec(
                        kind="transient_eio",
                        domain="l1",
                        op="write",
                        index=rng.randrange(0, max(1, max_index)),
                        count=rng.randint(1, 2),
                    )
                ]
            else:
                specs = []
            plans.append(FaultPlan(specs, seed=seed * 1009 + t))
        return plans


def inject_write(
    faults: Optional[FaultPlan],
    domain: str,
    what: str,
    data,
    write_fn: Callable,
    node: Optional[int] = None,
) -> None:
    """Run one write through the injection surface.

    ``write_fn(buf)`` performs the raw write.  Error kinds raise before
    any byte lands; ``bit_flip`` corrupts the payload silently;
    ``torn_write`` writes a prefix and then raises ``EIO`` (the retry
    layer rewrites the full extent — destinations are idempotent).
    """
    spec = (
        faults.on_op(domain, "write", what, node=node) if faults is not None else None
    )
    if spec is None:
        write_fn(data)
        return
    if spec.kind == "bit_flip":
        write_fn(flip_bit(data, spec.bit))
        return
    if spec.kind == "torn_write":
        n = max(1, int(len(data) * spec.frac)) if len(data) else 0
        write_fn(bytes(data)[:n])
        raise OSError(errno.EIO, f"injected torn write: {domain} {what}")
    write_fn(data)  # pragma: no cover - no other data-transforming kinds
