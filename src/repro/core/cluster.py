"""Cluster / PFS descriptions shared by every aggregation strategy.

These dataclasses describe the machine the checkpoint planner reasons
about.  The *same* specs drive both executors:

* the **real** executor only uses the topology part (which ranks live on
  which node, who the active backends are);
* the **sim** executor additionally uses the performance part (bandwidths,
  metadata capacity, lock-contention constants) to price a FlushPlan at
  Theta-like scale.

Performance constants are calibrated so that the simulated micro-benchmark
reproduces the *relative* behaviour of the paper's Figures 1-2 (see
EXPERIMENTS.md); they are not meant to be an exact digital twin of Theta.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import numpy as np
from typing import List, Optional, Sequence


@dataclass(frozen=True)
class PFSSpec:
    """A Lustre-like parallel file system.

    A file is striped round-robin in `stripe_size` chunks over
    `stripe_count` of the `n_io_servers` object storage targets (OSTs).
    Writes from different clients into the same file+OST object suffer
    extent-lock ping-pong ("false sharing" in the paper's terminology);
    `lock_switch_penalty`/`lock_conflict_alpha` price that.  Metadata
    operations (file create/open per client) are served by a single
    metadata server with bounded throughput.
    """

    n_io_servers: int = 48
    server_bw: float = 4.5e9           # B/s per OST
    stripe_size: int = 1 << 20         # 1 MiB (Lustre default)
    stripe_count: int = 48             # OSTs a single file is striped over
    server_latency: float = 0.5e-3     # per-request latency (s)
    max_conc_per_server: int = 8       # streams an OST overlaps efficiently
    lock_switch_penalty: float = 0.5e-3  # extent-lock revocation cost (s)
    client_stream_bw: float = 3.0e9    # single client stream ceiling (B/s)
    md_latency: float = 0.8e-3         # base metadata op latency (s)
    md_ops_per_sec: float = 12_000.0   # metadata server capacity

    @property
    def aggregate_bw(self) -> float:
        return self.n_io_servers * self.server_bw

    def n_stripes(self, nbytes: int) -> int:
        return -(-int(nbytes) // self.stripe_size)

    def stripe_of(self, offset: int) -> int:
        return int(offset) // self.stripe_size

    def server_of_stripe(self, stripe: int) -> int:
        return stripe % min(self.stripe_count, self.n_io_servers)


@dataclass(frozen=True)
class NodeSpec:
    """A compute node (Theta: Cray XC40 KNL node w/ local SSD + Aries NIC)."""

    local_bw: float = 2.1e9    # node-local SSD sequential write B/s
    local_read_bw: float = 2.4e9
    mem_bw: float = 16.0e9     # effective tmpfs/memcpy B/s (in-memory tier)
    nic_bw: float = 8.0e9      # injection bandwidth B/s
    cores: int = 64
    # Fraction of NIC the application claims while computing; the async
    # flush competes for the rest (Tseng et al. interference trade-off).
    app_net_load: float = 0.0


@dataclass(frozen=True)
class ClusterSpec:
    """The checkpointing cluster: nodes x processes-per-node + PFS."""

    n_nodes: int
    procs_per_node: int
    node: NodeSpec = NodeSpec()
    pfs: PFSSpec = PFSSpec()
    # Optional per-node background load in [0,1) used by leader election
    # criterion (2) and by the simulator's straggler model.  len == n_nodes.
    node_load: Optional[Sequence[float]] = None
    # Topology coordinate per node (e.g. dragonfly group); proximity is
    # |coord_a - coord_b|.  Defaults to linear placement.
    node_coord: Optional[Sequence[int]] = None

    def __post_init__(self):
        if self.n_nodes <= 0 or self.procs_per_node <= 0:
            raise ValueError("n_nodes and procs_per_node must be positive")
        if self.node_load is not None and len(self.node_load) != self.n_nodes:
            raise ValueError("node_load must have n_nodes entries")
        if self.node_coord is not None and len(self.node_coord) != self.n_nodes:
            raise ValueError("node_coord must have n_nodes entries")

    @property
    def world_size(self) -> int:
        return self.n_nodes * self.procs_per_node

    def node_of_rank(self, rank: int) -> int:
        return rank // self.procs_per_node

    def nodes_of_ranks(self, ranks) -> np.ndarray:
        """Vectorized :meth:`node_of_rank` over an int array."""
        return np.asarray(ranks, dtype=np.int64) // self.procs_per_node

    def ranks_of_node(self, node: int) -> List[int]:
        base = node * self.procs_per_node
        return list(range(base, base + self.procs_per_node))

    def load_of(self, node: int) -> float:
        if self.node_load is None:
            return 0.0
        return float(self.node_load[node])

    def loads(self) -> np.ndarray:
        """Per-node background load as a float64 vector (len n_nodes)."""
        if self.node_load is None:
            return np.zeros(self.n_nodes)
        return np.asarray(self.node_load, dtype=np.float64)

    def coord_of(self, node: int) -> int:
        if self.node_coord is None:
            return node
        return int(self.node_coord[node])

    def coords(self) -> np.ndarray:
        """Per-node topology coordinate as an int64 vector (len n_nodes)."""
        if self.node_coord is None:
            return np.arange(self.n_nodes, dtype=np.int64)
        return np.asarray(self.node_coord, dtype=np.int64)

    def with_(self, **kw) -> "ClusterSpec":
        return dataclasses.replace(self, **kw)


def theta_like(
    n_nodes: int,
    procs_per_node: int,
    *,
    local_tier: str = "mem",
    **node_kw,
) -> ClusterSpec:
    """The testbed used in the paper's evaluation (Theta, Cray XC40+Lustre).

    ``local_tier='mem'`` checkpoints to the in-memory tier (tmpfs on KNL
    DDR4) — the configuration behind the paper's Fig. 1 "orders of
    magnitude faster than GIO" observation; ``'ssd'`` models the node
    SSDs instead.
    """
    if local_tier == "mem":
        node_kw.setdefault("local_bw", 16.0e9)
        node_kw.setdefault("local_read_bw", 16.0e9)
    elif local_tier != "ssd":
        raise ValueError(f"unknown local_tier {local_tier!r}")
    return ClusterSpec(
        n_nodes=n_nodes,
        procs_per_node=procs_per_node,
        node=NodeSpec(**node_kw),
        pfs=PFSSpec(),
    )
