"""Discrete-event, max-min-fair flow simulator for pricing FlushPlans.

The real executor (:mod:`repro.core.storage`) runs plans against actual
files; this module prices the *same* plans on a modeled Theta-like
machine so the benchmark harness can reproduce the paper's Figures 1-2 at
thousands-of-ranks scale on one CPU box.

Model
-----
Byte movements become *flows* traversing shared resources; concurrent
flows share capacity max-min fairly (progressive filling, recomputed at
every flow start/finish — the standard fluid network approximation).
Resources:

* per-node NIC tx / rx (Aries injection, application keeps
  ``app_net_load`` of tx for itself — the Tseng et al. interference
  trade-off),
* per-node local-storage read bandwidth (draining L1 checkpoints),
* the PFS data path as one aggregate resource (writes stripe round-robin
  over all OSTs, so every writer engages every OST ~uniformly; per-OST
  lock conflicts are priced separately as a capacity derating),
* a metadata server with bounded op throughput gating file opens,
* a per-flow stream cap (one client stream cannot saturate Lustre).

Flow shapes are derived from plan *structure*, not strategy name:

* direct writes (file-per-process, POSIX aggregation):
  ``[SSD_read(home), NIC_tx(home), PFS]``;
* pipelined leader aggregation (paper §3): one cut-through flow
  ``[SSD_read(home), NIC_tx(home), NIC_rx(leader), NIC_tx(leader), PFS]``
  — leaders stream, receive and write overlap;
* barrier-synchronized collective rounds (MPI-IO, GIO) are priced with a
  closed-form per-round model (gather makespan + write makespan, rounds
  strictly ordered) — barriers remove the overlap that the event loop
  exists to capture, so the analytic form is both faster and faithful.

Lock contention ("false sharing", §2.1) derates PFS capacity:

* non-stripe-aligned shared-file writes: each write RPC into a file with
  ``W > 1`` concurrent writers risks a Lustre extent-lock revocation;
  conflict cost ``rpcs * (W-1)/W * penalty`` serialized across OSTs
  ⇒ ``eff = T_pure / (T_pure + T_lock)``;
* stripe-disjoint plans (MPI-IO leaders, §3 proposal): only ownership
  switches between adjacent extents conflict, with lockahead (half
  penalty) — near-zero derating, by construction.

Calibration targets (see EXPERIMENTS.md §Calibration): POSIX aggregation
degrades ~3x vs file-per-process at paper scale (Fig. 2), local phase is
orders of magnitude faster than GIO-direct (Fig. 1), aggregation leaves
the local phase unchanged (Fig. 1).
"""
from __future__ import annotations

import heapq
import math
from collections import defaultdict, deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.cluster import ClusterSpec
from repro.core.plan import FlushPlan, SendItem, WriteItem

MAX_RPC = 4 << 20  # Lustre max RPC size (4 MiB)


# ---------------------------------------------------------------------------
# Static plan analytics
# ---------------------------------------------------------------------------


def pfs_lock_efficiency(
    plan: FlushPlan, *, rpc_size: Optional[int] = None
) -> Tuple[float, float]:
    """Return (PFS efficiency in (0,1], lock seconds serialized per OST)."""
    pfs = plan.cluster.pfs
    n_srv = max(1, min(pfs.stripe_count, pfs.n_io_servers))
    rpc = min(int(rpc_size or pfs.stripe_size), MAX_RPC)
    penalty = pfs.lock_switch_penalty

    per_file_writers: Dict[str, set] = defaultdict(set)
    per_file_bytes: Dict[str, int] = defaultdict(int)
    per_file_extents: Dict[str, List[Tuple[int, int]]] = defaultdict(list)
    for w in plan.writes:
        per_file_writers[w.file].add(w.backend)
        per_file_bytes[w.file] += w.size
        per_file_extents[w.file].append((w.file_offset, w.backend))

    if plan.stripe_disjoint:
        # Only extent-ownership switches conflict; stripe-aligned writers
        # benefit from Lustre lockahead => half penalty.
        switches = 0
        for f, ext in per_file_extents.items():
            if len(per_file_writers[f]) <= 1:
                continue
            ext.sort()
            switches += sum(
                1 for (_, a), (_, b) in zip(ext, ext[1:]) if a != b
            )
        lock_time = switches / n_srv * (penalty * 0.5)
    else:
        conflicted = 0.0
        for f, wset in per_file_writers.items():
            w_count = len(wset)
            if w_count <= 1:
                continue
            conflicted += per_file_bytes[f] / rpc * (w_count - 1) / w_count
        lock_time = conflicted / n_srv * penalty

    t_pure = plan.total_bytes / pfs.aggregate_bw
    if lock_time <= 0 or t_pure <= 0:
        return 1.0, max(lock_time, 0.0)
    eff = t_pure / (t_pure + lock_time)
    return max(eff, 1e-3), lock_time


def metadata_schedule(plan: FlushPlan) -> Dict[Tuple[int, str], float]:
    """Completion time of each (backend, file) open through the MDS queue.

    File creates (one per file) are serviced first, then opens, all by a
    single metadata server with bounded throughput.  The returned times
    gate the first write of each (backend, file).
    """
    pfs = plan.cluster.pfs
    opens = sorted({(w.backend, w.file) for w in plan.writes})
    n_creates = len(plan.files)
    done: Dict[Tuple[int, str], float] = {}
    for i, key in enumerate(opens):
        ops_before = n_creates + i + 1
        done[key] = pfs.md_latency + ops_before / pfs.md_ops_per_sec
    return done


def _coalesce_writes_for_sim(writes: List[WriteItem]) -> List[WriteItem]:
    """Contiguous-run merge per (round, backend, file, src_rank)."""
    ws = sorted(
        writes, key=lambda w: (w.round, w.backend, w.file, w.src_rank, w.file_offset)
    )
    out: List[WriteItem] = []
    for w in ws:
        if out:
            p = out[-1]
            if (
                p.round == w.round
                and p.backend == w.backend
                and p.file == w.file
                and p.src_rank == w.src_rank
                and p.file_offset + p.size == w.file_offset
                and p.src_offset + p.size == w.src_offset
            ):
                out[-1] = WriteItem(
                    backend=p.backend, file=p.file, file_offset=p.file_offset,
                    size=p.size + w.size, src_rank=p.src_rank,
                    src_offset=p.src_offset, round=p.round,
                )
                continue
        out.append(w)
    return out


# ---------------------------------------------------------------------------
# Report
# ---------------------------------------------------------------------------


@dataclass
class SimReport:
    strategy: str
    n_ranks: int
    total_bytes: int
    local_time: float
    local_bw: float
    flush_time: float
    flush_bw: float
    md_gate_time: float
    pfs_lock_eff: float
    lock_time_per_ost: float
    network_bytes: int
    n_files: int
    metadata_ops: int
    scan_time: float
    app_slowdown: float
    n_rounds: int
    synchronous: bool
    per_backend_finish: Dict[int, float] = field(default_factory=dict)

    def row(self) -> Dict[str, object]:
        d = dict(self.__dict__)
        d.pop("per_backend_finish")
        return d


# ---------------------------------------------------------------------------
# Event-driven fluid simulation (asynchronous, pipelined strategies)
# ---------------------------------------------------------------------------


@dataclass
class _Flow:
    fid: int
    nbytes: float
    resources: Tuple[int, ...]
    slot_nodes: Tuple[int, ...]
    gate: float = 0.0
    max_rate: float = math.inf
    remaining: float = 0.0
    backend: int = -1

    def __post_init__(self):
        self.remaining = float(self.nbytes)


class _FluidSim:
    """Max-min fair sharing with per-node worker slots and start gates."""

    def __init__(self, caps: np.ndarray, io_threads: int, n_nodes: int):
        self.caps = caps
        self.slots = [io_threads] * n_nodes
        self.active: List[_Flow] = []
        self.queues: List[deque] = [deque() for _ in range(n_nodes)]
        self.arrivals: List[Tuple[float, int, _Flow]] = []
        self.started: set = set()
        self.finish_times: Dict[int, float] = {}

    def run(self, flows: List[_Flow], t0: float = 0.0) -> Tuple[float, Dict[int, float]]:
        if not flows:
            return t0, {}
        for f in flows:
            heapq.heappush(self.arrivals, (max(f.gate, t0), f.fid, f))
        now = t0
        per_backend: Dict[int, float] = {}
        rates = np.zeros(0)

        def try_start_from(node: int) -> bool:
            changed = False
            q = self.queues[node]
            n = len(q)
            for _ in range(n):
                f = q.popleft()
                if f.fid in self.started:
                    changed = changed  # duplicate entry; drop
                    continue
                if all(self.slots[nd] > 0 for nd in f.slot_nodes):
                    for nd in f.slot_nodes:
                        self.slots[nd] -= 1
                    self.started.add(f.fid)
                    self.active.append(f)
                    changed = True
                else:
                    q.append(f)
            return changed

        def admit(f: _Flow) -> bool:
            if all(self.slots[nd] > 0 for nd in f.slot_nodes):
                for nd in f.slot_nodes:
                    self.slots[nd] -= 1
                self.started.add(f.fid)
                self.active.append(f)
                return True
            for nd in set(f.slot_nodes):
                self.queues[nd].append(f)
            return False

        while self.active or self.arrivals:
            # admit everything that has arrived by `now`
            changed = False
            while self.arrivals and self.arrivals[0][0] <= now + 1e-12:
                _, _, f = heapq.heappop(self.arrivals)
                changed |= admit(f)
            if not self.active:
                if self.arrivals:
                    now = self.arrivals[0][0]
                    continue
                break
            rates = _maxmin_rates(self.active, self.caps)
            rem = np.array([f.remaining for f in self.active])
            with np.errstate(divide="ignore"):
                ttf = np.where(rates > 0, rem / np.maximum(rates, 1e-30), np.inf)
            dt = float(ttf.min())
            next_arrival = self.arrivals[0][0] if self.arrivals else math.inf
            dt = min(dt, next_arrival - now)
            if not math.isfinite(dt):
                raise RuntimeError("simulation stalled: active flows with zero rate")
            dt = max(dt, 0.0)
            now += dt
            # progress + completions
            new_active: List[_Flow] = []
            freed_nodes: List[int] = []
            for f, r in zip(self.active, rates):
                f.remaining -= r * dt
                if f.remaining <= 1e-6:
                    self.finish_times[f.fid] = now
                    per_backend[f.backend] = max(per_backend.get(f.backend, 0.0), now)
                    for nd in f.slot_nodes:
                        self.slots[nd] += 1
                        freed_nodes.append(nd)
                else:
                    new_active.append(f)
            self.active = new_active
            for nd in set(freed_nodes):
                try_start_from(nd)
        return now, per_backend


def _maxmin_rates(active: List[_Flow], caps: np.ndarray) -> np.ndarray:
    """Progressive-filling max-min fair rates (vectorized)."""
    nf = len(active)
    max_deg = max(len(f.resources) for f in active)
    res = np.full((nf, max_deg), -1, dtype=np.int64)
    for i, f in enumerate(active):
        res[i, : len(f.resources)] = f.resources
    flow_cap = np.array([f.max_rate for f in active])
    rates = np.zeros(nf)
    frozen = np.zeros(nf, dtype=bool)
    res_cap = caps.astype(np.float64).copy()
    nres = len(caps)

    valid = res >= 0
    for _ in range(nres + nf + 1):
        if frozen.all():
            break
        un = ~frozen
        # per-resource count of unfrozen flows
        idx = res[un][valid[un]]
        if idx.size == 0:
            rates[un] = np.minimum(flow_cap[un], np.inf)
            break
        counts = np.bincount(idx, minlength=nres)
        with np.errstate(divide="ignore", invalid="ignore"):
            share = np.where(counts > 0, res_cap / np.maximum(counts, 1), np.inf)
        bottleneck = int(np.argmin(share))
        b_share = float(share[bottleneck])
        # flows capped below the bottleneck share freeze at their own cap
        capped = un & (flow_cap <= b_share + 1e-9)
        if capped.any():
            rates[capped] = flow_cap[capped]
            frozen |= capped
            for i in np.where(capped)[0]:
                for r in active[i].resources:
                    res_cap[r] -= rates[i]
            continue
        if not math.isfinite(b_share):
            rates[un] = flow_cap[un]
            break
        touch = un & (res == bottleneck).any(axis=1)
        rates[touch] = b_share
        frozen |= touch
        for i in np.where(touch)[0]:
            for r in active[i].resources:
                if r != bottleneck:
                    res_cap[r] -= b_share
        res_cap[bottleneck] = 0.0
    return np.maximum(rates, 0.0)


# ---------------------------------------------------------------------------
# The simulator facade
# ---------------------------------------------------------------------------


class FlushSimulator:
    def __init__(
        self,
        plan: FlushPlan,
        *,
        io_threads: int = 2,
        rpc_size: Optional[int] = None,
        msg_latency: float = 5e-6,
    ) -> None:
        self.plan = plan
        self.cluster = plan.cluster
        self.io_threads = max(1, int(io_threads))
        self.rpc_size = rpc_size
        self.msg_latency = msg_latency

    # resource ids: [0,n) NIC_tx · [n,2n) NIC_rx · [2n,3n) SSD_read · [3n] PFS
    def _caps(self, pfs_eff: float) -> np.ndarray:
        c = self.cluster
        n = c.n_nodes
        caps = np.empty(3 * n + 1)
        for i in range(n):
            derate = max(1e-3, 1.0 - c.load_of(i))
            caps[i] = c.node.nic_bw * (1.0 - c.node.app_net_load) * derate
            caps[n + i] = c.node.nic_bw * derate
            caps[2 * n + i] = c.node.local_read_bw * derate
        caps[3 * n] = c.pfs.aggregate_bw * pfs_eff
        return caps

    def run(self) -> SimReport:
        plan = self.plan
        c = self.cluster
        pfs_eff, lock_time = pfs_lock_efficiency(plan, rpc_size=self.rpc_size)
        md_gate = metadata_schedule(plan)
        md_max = max(md_gate.values(), default=0.0)

        scan_time = 0.0
        if plan.scan_meta is not None:
            scan_time = (
                plan.scan_meta.rounds * self.msg_latency
                + plan.scan_meta.messages * plan.scan_meta.payload_bytes / c.node.nic_bw
            )

        if plan.barrier_per_round:
            flush_time, per_backend = self._analytic_rounds(pfs_eff, md_max)
        else:
            flush_time, per_backend = self._event_driven(pfs_eff, md_gate)
        flush_time += scan_time

        total = plan.total_bytes
        if plan.synchronous:
            local_time = flush_time  # GIO: app blocked for the whole write
        else:
            per_node_bytes: Dict[int, int] = defaultdict(int)
            for r, s in enumerate(plan.rank_sizes):
                per_node_bytes[c.node_of_rank(r)] += s
            local_time = (
                max(
                    (
                        b / (c.node.local_bw * max(1e-3, 1.0 - c.load_of(nd)))
                        for nd, b in per_node_bytes.items()
                    ),
                    default=0.0,
                )
                + scan_time
            )

        net_bytes = plan.network_bytes()
        cpu_steal = self.io_threads / c.node.cores
        net_frac = 0.0
        if flush_time > 0 and not plan.synchronous:
            net_frac = min(
                1.0, (net_bytes + total) / (c.n_nodes * c.node.nic_bw * flush_time)
            )
        app_slowdown = (
            1.0
            if plan.synchronous
            else cpu_steal + net_frac * c.node.app_net_load
        )

        return SimReport(
            strategy=plan.strategy,
            n_ranks=c.world_size,
            total_bytes=total,
            local_time=local_time,
            local_bw=total / local_time if local_time > 0 else float("inf"),
            flush_time=flush_time,
            flush_bw=total / flush_time if flush_time > 0 else float("inf"),
            md_gate_time=md_max,
            pfs_lock_eff=pfs_eff,
            lock_time_per_ost=lock_time,
            network_bytes=net_bytes,
            n_files=plan.n_files,
            metadata_ops=plan.metadata_ops(),
            scan_time=scan_time,
            app_slowdown=app_slowdown,
            n_rounds=plan.n_rounds,
            synchronous=plan.synchronous,
            per_backend_finish=per_backend,
        )

    # -- asynchronous strategies: event loop --------------------------------
    def _event_driven(
        self, pfs_eff: float, md_gate: Dict[Tuple[int, str], float]
    ) -> Tuple[float, Dict[int, float]]:
        plan = self.plan
        c = self.cluster
        n = c.n_nodes
        R_TX, R_RX, R_SSD, R_PFS = 0, n, 2 * n, 3 * n
        stream_cap = c.pfs.client_stream_bw
        writes = _coalesce_writes_for_sim(plan.writes)
        flows: List[_Flow] = []
        for fid, w in enumerate(writes):
            home = c.node_of_rank(w.src_rank)
            gate = md_gate.get((w.backend, w.file), 0.0)
            if w.backend == home:
                flows.append(
                    _Flow(
                        fid, w.size,
                        (R_SSD + home, R_TX + home, R_PFS),
                        slot_nodes=(home,),
                        gate=gate, max_rate=stream_cap, backend=w.backend,
                    )
                )
            else:
                # pipelined cut-through gather+write (paper §3 streaming)
                flows.append(
                    _Flow(
                        fid, w.size,
                        (R_SSD + home, R_TX + home, R_RX + w.backend,
                         R_TX + w.backend, R_PFS),
                        slot_nodes=(home, w.backend),
                        gate=gate, max_rate=stream_cap, backend=w.backend,
                    )
                )
        sim = _FluidSim(self._caps(pfs_eff), self.io_threads, n)
        return sim.run(flows)

    # -- collective strategies: closed-form barrier rounds -------------------
    def _analytic_rounds(
        self, pfs_eff: float, md_max: float
    ) -> Tuple[float, Dict[int, float]]:
        plan = self.plan
        c = self.cluster
        stream_cap = c.pfs.client_stream_bw
        nic_tx_eff = c.node.nic_bw * (1.0 - c.node.app_net_load)

        rounds = sorted({w.round for w in plan.writes} | {s.round for s in plan.sends})
        sends_by_round: Dict[int, List[SendItem]] = defaultdict(list)
        for s in plan.sends:
            sends_by_round[s.round].append(s)
        writes_by_round: Dict[int, List[WriteItem]] = defaultdict(list)
        for w in plan.writes:
            writes_by_round[w.round].append(w)

        t = md_max  # all backends must open before the first collective
        per_backend: Dict[int, float] = {}
        for rnd in rounds:
            out_b: Dict[int, int] = defaultdict(int)
            in_b: Dict[int, int] = defaultdict(int)
            read_b: Dict[int, int] = defaultdict(int)
            for s in sends_by_round.get(rnd, []):
                out_b[s.src_backend] += s.size
                in_b[s.dst_backend] += s.size
                if not plan.synchronous:
                    read_b[s.src_backend] += s.size
            wr_b: Dict[int, int] = defaultdict(int)
            round_bytes = 0
            for w in writes_by_round.get(rnd, []):
                wr_b[w.backend] += w.size
                round_bytes += w.size
                home = c.node_of_rank(w.src_rank)
                if home == w.backend and not plan.synchronous:
                    read_b[home] += w.size

            def _derate(nd: int) -> float:
                return max(1e-3, 1.0 - c.load_of(nd))

            t_gather = 0.0
            for nd in set(out_b) | set(in_b) | set(read_b):
                d = _derate(nd)
                t_gather = max(
                    t_gather,
                    out_b.get(nd, 0) / (nic_tx_eff * d),
                    in_b.get(nd, 0) / (c.node.nic_bw * d),
                    read_b.get(nd, 0) / (c.node.local_read_bw * d),
                )
            t_write = round_bytes / (c.pfs.aggregate_bw * pfs_eff) if round_bytes else 0.0
            for nd, b in wr_b.items():
                t_write = max(
                    t_write,
                    b / min(nic_tx_eff * _derate(nd),
                            stream_cap * self.io_threads),
                )
            t += t_gather + t_write
            for nd in wr_b:
                per_backend[nd] = t
        return t, per_backend


def simulate_flush(
    plan: FlushPlan, *, io_threads: int = 2, rpc_size: Optional[int] = None
) -> SimReport:
    return FlushSimulator(plan, io_threads=io_threads, rpc_size=rpc_size).run()
