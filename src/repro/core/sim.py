"""Discrete-event, max-min-fair flow simulator for pricing FlushPlans.

The real executor (:mod:`repro.core.storage`) runs plans against actual
files; this module prices the *same* plans on a modeled Theta-like
machine so the benchmark harness can reproduce the paper's Figures 1-2 at
thousands-of-ranks scale on one CPU box.

Model
-----
Byte movements become *flows* traversing shared resources; concurrent
flows share capacity max-min fairly (progressive filling, recomputed at
every flow start/finish — the standard fluid network approximation).
Resources:

* per-node NIC tx / rx (Aries injection, application keeps
  ``app_net_load`` of tx for itself — the Tseng et al. interference
  trade-off),
* per-node local-storage read bandwidth (draining L1 checkpoints),
* the PFS data path as one aggregate resource (writes stripe round-robin
  over all OSTs, so every writer engages every OST ~uniformly; per-OST
  lock conflicts are priced separately as a capacity derating),
* a metadata server with bounded op throughput gating file opens,
* a per-flow stream cap (one client stream cannot saturate Lustre).

Flow shapes are derived from plan *structure*, not strategy name:

* direct writes (file-per-process, POSIX aggregation):
  ``[SSD_read(home), NIC_tx(home), PFS]``;
* pipelined leader aggregation (paper §3): one cut-through flow
  ``[SSD_read(home), NIC_tx(home), NIC_rx(leader), NIC_tx(leader), PFS]``
  — leaders stream, receive and write overlap;
* barrier-synchronized collective rounds (MPI-IO, GIO) are priced with a
  closed-form per-round model (gather makespan + write makespan, rounds
  strictly ordered) — barriers remove the overlap that the event loop
  exists to capture, so the analytic form is both faster and faithful.

Lock contention ("false sharing", §2.1) derates PFS capacity:

* non-stripe-aligned shared-file writes: each write RPC into a file with
  ``W > 1`` concurrent writers risks a Lustre extent-lock revocation;
  conflict cost ``rpcs * (W-1)/W * penalty`` serialized across OSTs
  ⇒ ``eff = T_pure / (T_pure + T_lock)``;
* stripe-disjoint plans (MPI-IO leaders, §3 proposal): only ownership
  switches between adjacent extents conflict, with lockahead (half
  penalty) — near-zero derating, by construction.

The front-end consumes :class:`~repro.core.plan.PlanArrays` columns
directly: lock-efficiency, the metadata schedule, write coalescing and
flow construction are array programs, and the fluid engine itself runs
on flat NumPy state (per-flow resource rows, residual capacities updated
with ``np.add.at`` scatters).  Flows with identical resource signatures
receive identical max-min rates, so rates are cached per signature class
and only recomputed when the active class census actually changes —
most starts that replace a same-shaped completion reuse the last rates.

Calibration targets (see EXPERIMENTS.md §Calibration): POSIX aggregation
degrades ~3x vs file-per-process at paper scale (Fig. 2), local phase is
orders of magnitude faster than GIO-direct (Fig. 1), aggregation leaves
the local phase unchanged (Fig. 1).
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.cluster import ClusterSpec
from repro.core.plan import (
    FlushPlan,
    PlanArrays,
    coalesce_write_columns,
)

MAX_RPC = 4 << 20  # Lustre max RPC size (4 MiB)


# ---------------------------------------------------------------------------
# Static plan analytics
# ---------------------------------------------------------------------------


def pfs_lock_efficiency(
    plan: FlushPlan, *, rpc_size: Optional[int] = None
) -> Tuple[float, float]:
    """Return (PFS efficiency in (0,1], lock seconds serialized per OST)."""
    pfs = plan.cluster.pfs
    n_srv = max(1, min(pfs.stripe_count, pfs.n_io_servers))
    rpc = min(int(rpc_size or pfs.stripe_size), MAX_RPC)
    penalty = pfs.lock_switch_penalty

    pa = plan.ensure_arrays()
    w = pa.writes
    n_files = max(1, len(pa.file_names))
    n_nodes = plan.cluster.n_nodes

    if len(w) == 0:
        return 1.0, 0.0

    if plan.stripe_disjoint:
        # Only extent-ownership switches conflict; stripe-aligned writers
        # benefit from Lustre lockahead => half penalty.  A switch is a
        # backend change between offset-adjacent writes of the same file.
        order = np.lexsort((w.backend, w.file_offset, w.file_id))
        f = w.file_id[order]
        b = w.backend[order]
        switches = int(np.sum((f[1:] == f[:-1]) & (b[1:] != b[:-1])))
        lock_time = switches / n_srv * (penalty * 0.5)
    else:
        # writers per file (distinct backends) and bytes per file
        pairs = np.unique(w.file_id * n_nodes + w.backend)
        writers = np.bincount((pairs // n_nodes).astype(np.intp), minlength=n_files)
        fbytes = np.zeros(n_files, np.int64)
        np.add.at(fbytes, w.file_id, w.size)
        multi = writers > 1
        conflicted = float(
            (fbytes[multi] / rpc * (writers[multi] - 1) / writers[multi]).sum()
        )
        lock_time = conflicted / n_srv * penalty

    t_pure = plan.total_bytes / pfs.aggregate_bw
    if lock_time <= 0 or t_pure <= 0:
        return 1.0, max(lock_time, 0.0)
    eff = t_pure / (t_pure + lock_time)
    return max(eff, 1e-3), lock_time


def _open_schedule(plan: FlushPlan, pa: PlanArrays) -> Tuple[np.ndarray, np.ndarray]:
    """Unique (backend, file) opens and their MDS completion times.

    File creates (one per file) are serviced first, then opens in
    (backend, file) order, all by a single metadata server with bounded
    throughput.  Returns (encoded backend*n_files+file_id, done_time).
    """
    pfs = plan.cluster.pfs
    w = pa.writes
    n_files = max(1, len(pa.file_names))
    enc = np.unique(w.backend * n_files + w.file_id)
    n_creates = len(plan.files)
    done = pfs.md_latency + (
        n_creates + np.arange(1, len(enc) + 1, dtype=np.float64)
    ) / pfs.md_ops_per_sec
    return enc, done


def metadata_schedule(plan: FlushPlan) -> Dict[Tuple[int, str], float]:
    """Completion time of each (backend, file) open through the MDS queue.

    The returned times gate the first write of each (backend, file).
    (Opens are ordered by (backend, file_id); strategy builders assign
    file ids in name order, so this matches the historical name sort.)
    """
    pa = plan.ensure_arrays()
    enc, done = _open_schedule(plan, pa)
    n_files = max(1, len(pa.file_names))
    names = pa.file_names
    return {
        (int(e // n_files), names[int(e % n_files)]): float(t)
        for e, t in zip(enc, done)
    }


# ---------------------------------------------------------------------------
# Report
# ---------------------------------------------------------------------------


@dataclass
class SimReport:
    strategy: str
    n_ranks: int
    total_bytes: int
    local_time: float
    local_bw: float
    flush_time: float
    flush_bw: float
    md_gate_time: float
    pfs_lock_eff: float
    lock_time_per_ost: float
    network_bytes: int
    n_files: int
    metadata_ops: int
    scan_time: float
    app_slowdown: float
    n_rounds: int
    synchronous: bool
    # Global flush write cap priced into this report (0 = unthrottled);
    # the real-executor twin is the engine's TokenBucket with the same
    # bytes/s, so the simulated and measured trade-off curves agree.
    flush_bw_cap: float = 0.0
    per_backend_finish: Dict[int, float] = field(default_factory=dict)

    def row(self) -> Dict[str, object]:
        d = dict(self.__dict__)
        d.pop("per_backend_finish")
        return d


# ---------------------------------------------------------------------------
# Event-driven fluid simulation (asynchronous, pipelined strategies)
# ---------------------------------------------------------------------------


class _FluidSim:
    """Max-min fair sharing with per-node worker slots and start gates.

    All flow state is columnar: ``res`` holds each flow's resource ids
    (-1 padded), ``slot_nodes`` the nodes whose worker slots it occupies.
    A flow arrives at its gate time, starts when every slot node has a
    free slot (otherwise it queues on each of them), and finishes when
    its bytes drain at the max-min fair rate.
    """

    def __init__(self, caps: np.ndarray, io_threads: int, n_nodes: int):
        self.caps = caps
        self.io_threads = io_threads
        self.n_nodes = n_nodes

    def run(
        self,
        res: np.ndarray,          # (nf, deg) int64, -1 padded
        slot_nodes: np.ndarray,   # (nf, 2) int64, -1 padded
        nbytes: np.ndarray,       # (nf,) float64
        gates: np.ndarray,        # (nf,) float64
        max_rate: float,
        backend: np.ndarray,      # (nf,) int64
        t0: float = 0.0,
    ) -> Tuple[float, Dict[int, float]]:
        nf = len(nbytes)
        if nf == 0:
            return t0, {}
        n_nodes = self.n_nodes
        valid = res >= 0
        # Signature classes: flows with identical resource rows get equal
        # max-min rates, so rates are cached per class (see module doc).
        _, cls = np.unique(res, axis=0, return_inverse=True)
        cls = cls.astype(np.intp)

        remaining = nbytes.astype(np.float64).copy()
        started = np.zeros(nf, bool)
        slots = np.full(n_nodes, self.io_threads, np.int64)
        queues: List[deque] = [deque() for _ in range(n_nodes)]
        arrivals = np.argsort(gates, kind="stable")
        gates_sorted = gates[arrivals]
        ptr = 0

        active = np.empty(nf, np.intp)
        n_active = 0
        per_backend = np.full(n_nodes, -1.0)
        class_rate = np.zeros(int(cls.max()) + 1)
        rate_deltas: Dict[int, int] = {}

        slot_rows = slot_nodes  # alias
        flow_cap = float(max_rate)

        def note(c: int, d: int) -> None:
            v = rate_deltas.get(c, 0) + d
            if v:
                rate_deltas[c] = v
            else:
                rate_deltas.pop(c, None)

        def can_start(fid: int) -> bool:
            a, b = slot_rows[fid]
            if b == a:  # duplicated row: start() takes (and free returns) two
                return slots[a] > 1
            if slots[a] <= 0:
                return False
            return b < 0 or slots[b] > 0

        def start(fid: int) -> None:
            nonlocal n_active
            a, b = slot_rows[fid]
            slots[a] -= 1
            if b >= 0:
                slots[b] -= 1
            started[fid] = True
            active[n_active] = fid
            n_active += 1
            note(int(cls[fid]), +1)

        def admit(fid: int) -> None:
            if can_start(fid):
                start(fid)
            else:
                a, b = slot_rows[fid]
                queues[a].append(fid)
                if b >= 0 and b != a:
                    queues[b].append(fid)

        def try_start_from(node: int) -> None:
            q = queues[node]
            for _ in range(len(q)):
                if slots[node] <= 0:
                    # every flow queued here needs a slot on this node
                    break
                fid = q.popleft()
                if started[fid]:
                    continue  # duplicate entry (queued on several slot
                    # nodes, started via another one): drop, don't requeue
                if can_start(fid):
                    start(fid)
                else:
                    q.append(fid)

        now = t0
        eps = 1e-12
        while True:
            while ptr < nf and gates_sorted[ptr] <= now + eps:
                admit(int(arrivals[ptr]))
                ptr += 1
            if n_active == 0:
                if ptr < nf:
                    now = max(now, float(gates_sorted[ptr]))
                    continue
                break

            act = active[:n_active]
            if rate_deltas:
                rates_a = _maxmin_rates(
                    res[act], valid[act], flow_cap, self.caps
                )
                class_rate[cls[act]] = rates_a
                rate_deltas.clear()
            else:
                rates_a = class_rate[cls[act]]

            rem_a = remaining[act]
            with np.errstate(divide="ignore"):
                ttf = np.where(rates_a > 0, rem_a / np.maximum(rates_a, 1e-30), np.inf)
            dt = float(ttf.min())
            next_arrival = float(gates_sorted[ptr]) if ptr < nf else math.inf
            dt = min(dt, next_arrival - now)
            if not math.isfinite(dt):
                raise RuntimeError("simulation stalled: active flows with zero rate")
            dt = max(dt, 0.0)
            now += dt

            rem_a = rem_a - rates_a * dt
            remaining[act] = rem_a
            comp = rem_a <= 1e-6
            if comp.any():
                done = act[comp]
                per_backend[backend[done]] = now  # monotone: later is larger
                freed = slot_rows[done]
                freed = freed[freed >= 0]
                np.add.at(slots, freed, 1)
                for c in cls[done].tolist():
                    note(int(c), -1)
                keep = act[~comp]
                n_active = len(keep)
                active[:n_active] = keep
                for nd in np.unique(freed).tolist():
                    try_start_from(int(nd))

        out = {int(b): float(t) for b, t in enumerate(per_backend) if t >= 0.0}
        return now, out


def _maxmin_rates(
    res: np.ndarray, valid: np.ndarray, flow_cap: float, caps: np.ndarray
) -> np.ndarray:
    """Progressive-filling max-min fair rates.

    ``res``/``valid`` are the active flows' resource rows, flattened once
    into (flow, resource) incidence arrays; residual capacities are
    updated with ``np.add.at`` scatters (no per-flow Python loops).  All
    resources whose share ties the bottleneck saturate at the same water
    level, so they freeze together in one iteration — with symmetric
    node groups this collapses the iteration count to the number of
    *distinct* bottleneck levels.
    """
    nf = len(res)
    rates = np.zeros(nf)
    frozen = np.zeros(nf, bool)
    res_cap = caps.astype(np.float64).copy()
    nres = len(caps)
    valid_flat = valid.ravel()
    flat_res = res.ravel()[valid_flat].astype(np.intp)
    flat_flow = np.repeat(np.arange(nf, dtype=np.intp), res.shape[1])[valid_flat]

    for _ in range(nres + nf + 1):
        if frozen.all():
            break
        un = ~frozen
        live = un[flat_flow]
        idx = flat_res[live]
        if idx.size == 0:
            rates[un] = flow_cap
            break
        counts = np.bincount(idx, minlength=nres)
        with np.errstate(divide="ignore", invalid="ignore"):
            share = np.where(counts > 0, res_cap / np.maximum(counts, 1), np.inf)
        b_share = float(share.min())
        # flows capped below the bottleneck share freeze at their own cap
        if flow_cap <= b_share + 1e-9:
            rates[un] = flow_cap
            frozen |= un
            np.add.at(res_cap, idx, -flow_cap)
            continue
        if not math.isfinite(b_share):
            rates[un] = flow_cap
            break
        bmask = share == b_share
        touch = np.zeros(nf, bool)
        touch[flat_flow[live][bmask[idx]]] = True
        rates[touch] = b_share
        frozen |= touch
        flat_t = touch[flat_flow]
        sub_idx = flat_res[flat_t]
        keep = ~bmask[sub_idx]
        np.add.at(res_cap, sub_idx[keep], -b_share)
        res_cap[bmask] = 0.0
    return np.maximum(rates, 0.0)


# ---------------------------------------------------------------------------
# The simulator facade
# ---------------------------------------------------------------------------


class FlushSimulator:
    def __init__(
        self,
        plan: FlushPlan,
        *,
        io_threads: int = 2,
        rpc_size: Optional[int] = None,
        msg_latency: float = 5e-6,
        flush_bw_cap: Optional[float] = None,
    ) -> None:
        self.plan = plan
        self.cluster = plan.cluster
        self.io_threads = max(1, int(io_threads))
        self.rpc_size = rpc_size
        self.msg_latency = msg_latency
        # Global flush write cap (bytes/s) — the engine's token-bucket
        # throttle priced as one extra shared resource every write flow
        # traverses (event-driven strategies) / a per-round floor of
        # round_bytes / cap (barrier strategies).  None/<=0 = off.
        self.flush_bw_cap = (
            float(flush_bw_cap) if flush_bw_cap and flush_bw_cap > 0 else None
        )

    # resource ids: [0,n) NIC_tx · [n,2n) NIC_rx · [2n,3n) SSD_read · [3n] PFS
    # · [3n+1] the global flush_bw_cap token bucket (only when set)
    def _caps(self, pfs_eff: float) -> np.ndarray:
        c = self.cluster
        n = c.n_nodes
        derate = np.maximum(1e-3, 1.0 - c.loads())
        caps = np.empty(3 * n + 1 + (1 if self.flush_bw_cap else 0))
        caps[:n] = c.node.nic_bw * (1.0 - c.node.app_net_load) * derate
        caps[n: 2 * n] = c.node.nic_bw * derate
        caps[2 * n: 3 * n] = c.node.local_read_bw * derate
        caps[3 * n] = c.pfs.aggregate_bw * pfs_eff
        if self.flush_bw_cap:
            caps[3 * n + 1] = self.flush_bw_cap
        return caps

    def run(self) -> SimReport:
        plan = self.plan
        c = self.cluster
        pfs_eff, lock_time = pfs_lock_efficiency(plan, rpc_size=self.rpc_size)
        pa = plan.ensure_arrays()
        enc_opens, open_done = _open_schedule(plan, pa)
        md_max = float(open_done[-1]) if len(open_done) else 0.0

        scan_time = 0.0
        if plan.scan_meta is not None:
            scan_time = (
                plan.scan_meta.rounds * self.msg_latency
                + plan.scan_meta.messages * plan.scan_meta.payload_bytes / c.node.nic_bw
            )

        if plan.barrier_per_round:
            flush_time, per_backend = self._analytic_rounds(pfs_eff, md_max)
        else:
            flush_time, per_backend = self._event_driven(
                pfs_eff, enc_opens, open_done
            )
        flush_time += scan_time

        total = plan.total_bytes
        if plan.synchronous:
            local_time = flush_time  # GIO: app blocked for the whole write
        else:
            sizes = np.asarray(plan.rank_sizes, np.int64)
            node_bytes = sizes.reshape(c.n_nodes, c.procs_per_node).sum(axis=1)
            derate = np.maximum(1e-3, 1.0 - c.loads())
            local_time = (
                float((node_bytes / (c.node.local_bw * derate)).max(initial=0.0))
                + scan_time
            )

        net_bytes = plan.network_bytes()
        cpu_steal = self.io_threads / c.node.cores
        net_frac = 0.0
        if flush_time > 0 and not plan.synchronous:
            net_frac = min(
                1.0, (net_bytes + total) / (c.n_nodes * c.node.nic_bw * flush_time)
            )
        app_slowdown = (
            1.0
            if plan.synchronous
            else cpu_steal + net_frac * c.node.app_net_load
        )

        return SimReport(
            strategy=plan.strategy,
            n_ranks=c.world_size,
            total_bytes=total,
            local_time=local_time,
            local_bw=total / local_time if local_time > 0 else float("inf"),
            flush_time=flush_time,
            flush_bw=total / flush_time if flush_time > 0 else float("inf"),
            md_gate_time=md_max,
            pfs_lock_eff=pfs_eff,
            lock_time_per_ost=lock_time,
            network_bytes=net_bytes,
            n_files=plan.n_files,
            metadata_ops=plan.metadata_ops(),
            scan_time=scan_time,
            app_slowdown=app_slowdown,
            n_rounds=plan.n_rounds,
            synchronous=plan.synchronous,
            flush_bw_cap=self.flush_bw_cap or 0.0,
            per_backend_finish=per_backend,
        )

    # -- asynchronous strategies: event loop --------------------------------
    def _event_driven(
        self, pfs_eff: float, opens: np.ndarray, open_done: np.ndarray
    ) -> Tuple[float, Dict[int, float]]:
        plan = self.plan
        c = self.cluster
        n = c.n_nodes
        stream_cap = c.pfs.client_stream_bw
        pa = plan.ensure_arrays()
        w = coalesce_write_columns(pa.writes)
        nf = len(w)
        if nf == 0:
            return 0.0, {}
        n_files = max(1, len(pa.file_names))
        enc = w.backend * n_files + w.file_id
        gates = open_done[np.searchsorted(opens, enc)]

        home = c.nodes_of_ranks(w.src_rank)
        direct = w.backend == home
        remote = ~direct
        # direct: [SSD(home), TX(home), PFS]
        # remote: pipelined cut-through gather+write (paper §3 streaming)
        #         [SSD(home), TX(home), RX(leader), TX(leader), PFS]
        # with a flush_bw_cap every flow additionally traverses the
        # shared token-bucket resource (id 3n+1)
        width = 6 if self.flush_bw_cap else 5
        res = np.full((nf, width), -1, np.int64)
        res[:, 0] = 2 * n + home
        res[:, 1] = home
        res[direct, 2] = 3 * n
        res[remote, 2] = n + w.backend[remote]
        res[remote, 3] = w.backend[remote]
        res[remote, 4] = 3 * n
        if self.flush_bw_cap:
            res[direct, 3] = 3 * n + 1
            res[remote, 5] = 3 * n + 1
        slot_nodes = np.full((nf, 2), -1, np.int64)
        slot_nodes[:, 0] = home
        slot_nodes[remote, 1] = w.backend[remote]

        sim = _FluidSim(self._caps(pfs_eff), self.io_threads, n)
        return sim.run(
            res, slot_nodes, w.size.astype(np.float64), gates,
            stream_cap, w.backend,
        )

    # -- collective strategies: closed-form barrier rounds -------------------
    def _analytic_rounds(
        self, pfs_eff: float, md_max: float
    ) -> Tuple[float, Dict[int, float]]:
        plan = self.plan
        c = self.cluster
        n = c.n_nodes
        stream_cap = c.pfs.client_stream_bw
        nic_tx_eff = c.node.nic_bw * (1.0 - c.node.app_net_load)
        pa = plan.ensure_arrays()
        w, s = pa.writes, pa.sends

        rounds = np.union1d(np.unique(w.round), np.unique(s.round))
        R = len(rounds)
        if R == 0:
            return md_max, {}
        ri_w = np.searchsorted(rounds, w.round)
        ri_s = np.searchsorted(rounds, s.round)

        out_b = np.zeros((R, n), np.int64)
        in_b = np.zeros((R, n), np.int64)
        read_b = np.zeros((R, n), np.int64)
        wr_b = np.zeros((R, n), np.int64)
        np.add.at(out_b, (ri_s, s.src_backend), s.size)
        np.add.at(in_b, (ri_s, s.dst_backend), s.size)
        if not plan.synchronous:
            np.add.at(read_b, (ri_s, s.src_backend), s.size)
            home_w = c.nodes_of_ranks(w.src_rank)
            local = home_w == w.backend
            np.add.at(read_b, (ri_w[local], home_w[local]), w.size[local])
        np.add.at(wr_b, (ri_w, w.backend), w.size)
        round_bytes = wr_b.sum(axis=1)

        derate = np.maximum(1e-3, 1.0 - c.loads())
        t_gather = np.maximum(
            out_b / (nic_tx_eff * derate),
            np.maximum(in_b / (c.node.nic_bw * derate),
                       read_b / (c.node.local_read_bw * derate)),
        ).max(axis=1)
        t_write = np.where(
            round_bytes > 0, round_bytes / (c.pfs.aggregate_bw * pfs_eff), 0.0
        )
        per_node_write = wr_b / np.minimum(
            nic_tx_eff * derate, stream_cap * self.io_threads
        )
        t_write = np.maximum(t_write, per_node_write.max(axis=1))
        if self.flush_bw_cap:
            # the token bucket is global: each barrier round drains no
            # faster than the cap, exactly like the real executor
            t_write = np.maximum(t_write, round_bytes / self.flush_bw_cap)

        cum = md_max + np.cumsum(t_gather + t_write)
        per_backend: Dict[int, float] = {}
        writes_in_round = wr_b > 0
        any_write = writes_in_round.any(axis=0)
        last_round = R - 1 - np.argmax(writes_in_round[::-1, :], axis=0)
        for nd in np.flatnonzero(any_write).tolist():
            per_backend[int(nd)] = float(cum[last_round[nd]])
        return float(cum[-1]), per_backend


def simulate_flush(
    plan: FlushPlan,
    *,
    io_threads: int = 2,
    rpc_size: Optional[int] = None,
    flush_bw_cap: Optional[float] = None,
) -> SimReport:
    return FlushSimulator(
        plan, io_threads=io_threads, rpc_size=rpc_size,
        flush_bw_cap=flush_bw_cap,
    ).run()


def simulate_flush_shared(
    plans: List[FlushPlan],
    *,
    flush_bw_cap: float,
    weights: Optional[List[float]] = None,
    io_threads: int = 2,
    rpc_size: Optional[int] = None,
) -> List[SimReport]:
    """Multi-tenant pricing of one shared ``flush_bw_cap``.

    ``plans[i]`` is tenant *i*'s concurrent flush.  The global cap is
    split by :func:`repro.core.storage.fair_share_rates` — each
    tenant's *demand* is the bandwidth its flush would sustain
    unthrottled (its uncapped sim), its *weight* the operator
    priority — and tenant *i* is then priced exactly like a single-job
    ``flush_bw_cap`` equal to its granted share.  This is the fluid
    twin of the runtime's hierarchical token buckets
    (:class:`repro.core.storage.FairShareLimiter`): both layers reduce
    a tenant's view of the shared PFS to "one private cap of my
    granted rate", so the single-job sim-vs-real throttle equivalence
    carries over tenant by tenant.

    A zero/negative cap means unthrottled: every plan is simulated
    independently (no shared resource to split).
    """
    from repro.core.storage import fair_share_rates

    if not plans:
        return []
    w = list(weights) if weights is not None else [1.0] * len(plans)
    if len(w) != len(plans):
        raise ValueError("weights must match plans")
    base = [
        simulate_flush(p, io_threads=io_threads, rpc_size=rpc_size)
        for p in plans
    ]
    if flush_bw_cap <= 0:
        return base
    demands = [
        min(b.flush_bw, 1e30) if p.total_bytes > 0 else 0.0
        for p, b in zip(plans, base)
    ]
    rates = fair_share_rates(w, demands, flush_bw_cap)
    out: List[SimReport] = []
    for i, (p, b, r) in enumerate(zip(plans, base, rates)):
        if p.total_bytes <= 0 or r >= demands[i] - 1e-9:
            out.append(b)  # its own demand binds before the quota does
        else:
            out.append(
                simulate_flush(
                    p, io_threads=io_threads, rpc_size=rpc_size,
                    flush_bw_cap=float(r),
                )
            )
    return out
