"""Aggregated asynchronous multi-level checkpointing (the paper's core).

Public API:

* :class:`~repro.core.engine.CheckpointManager` — multi-level async
  checkpointing with pluggable aggregation, integrated with JAX training.
* :func:`~repro.core.strategies.make_plan` — build a FlushPlan from a
  strategy name (``file_per_process`` | ``posix`` | ``mpiio`` |
  ``stripe_aligned`` | ``gio_sync``).
* :func:`~repro.core.sim.simulate_flush` — price a plan on the modeled
  Theta-like machine (benchmark harness).
"""
from repro.core.cluster import ClusterSpec, NodeSpec, PFSSpec, theta_like
from repro.core.engine import CheckpointConfig, CheckpointManager, SaveStats
from repro.core.plan import (
    FlushPlan,
    PlanArrays,
    SendColumns,
    SendItem,
    WriteColumns,
    WriteItem,
    count_false_sharing,
    validate_plan,
    validate_plan_reference,
)
from repro.core.prefix_sum import (
    LeaderAssignment,
    ScanResult,
    elect_leaders,
    exclusive_prefix_sum,
    piggybacked_scan,
)
from repro.core.sim import FlushSimulator, SimReport, simulate_flush
from repro.core.strategies import STRATEGIES, make_plan

__all__ = [
    "ClusterSpec",
    "NodeSpec",
    "PFSSpec",
    "theta_like",
    "CheckpointConfig",
    "CheckpointManager",
    "SaveStats",
    "FlushPlan",
    "PlanArrays",
    "SendColumns",
    "SendItem",
    "WriteColumns",
    "WriteItem",
    "validate_plan",
    "validate_plan_reference",
    "count_false_sharing",
    "LeaderAssignment",
    "ScanResult",
    "elect_leaders",
    "exclusive_prefix_sum",
    "piggybacked_scan",
    "FlushSimulator",
    "SimReport",
    "simulate_flush",
    "STRATEGIES",
    "make_plan",
]
