"""Aggregated asynchronous multi-level checkpointing (the paper's core).

Public API:

* :class:`~repro.core.engine.CheckpointManager` — multi-level async
  checkpointing with pluggable aggregation, integrated with JAX training.
* :func:`~repro.core.strategies.make_plan` — build a FlushPlan from a
  strategy name (``file_per_process`` | ``posix`` | ``mpiio`` |
  ``stripe_aligned`` | ``gio_sync``).
* :func:`~repro.core.sim.simulate_flush` — price a plan on the modeled
  Theta-like machine (benchmark harness).
"""
from repro.core.admission import AdmissionController
from repro.core.cluster import ClusterSpec, NodeSpec, PFSSpec, theta_like
from repro.core.engine import (
    CheckpointConfig,
    CheckpointManager,
    L1CapacityError,
    ManagerHealth,
    SaveStats,
)
from repro.core.faults import FaultPlan, FaultSpec
from repro.core.repair import RepairReport, repair_step
from repro.core.plan import (
    FileLayout,
    FlushPlan,
    PlanArrays,
    ReadColumns,
    ReadPlan,
    SendColumns,
    SendItem,
    WriteColumns,
    WriteItem,
    assign_readers,
    build_read_plan,
    count_false_sharing,
    merge_intervals,
    stored_space_offsets,
    validate_plan,
    validate_plan_reference,
    validate_read_plan,
)
from repro.core.prefix_sum import (
    LeaderAssignment,
    ScanResult,
    elect_leaders,
    exclusive_prefix_sum,
    piggybacked_scan,
)
from repro.core.serialize import (
    ChunkTable,
    EncodedState,
    Manifest,
    Placement,
    decode_state,
    decode_stream,
    default_codec_impl,
    encode_state,
    serialize_tree,
)
from repro.core.sim import (
    FlushSimulator,
    SimReport,
    simulate_flush,
    simulate_flush_shared,
)
from repro.core.storage import (
    CancelToken,
    CircuitOpenError,
    DomainHealth,
    FairShareLimiter,
    FlushCancelled,
    FlushJournal,
    FlushResult,
    HedgePolicy,
    LocalStore,
    MissingBlobError,
    RealExecutor,
    RetryPolicy,
    StorageError,
    StorageHealth,
    TenantLimiter,
    TokenBucket,
    classify_error,
    fair_share_rates,
)
from repro.core.strategies import STRATEGIES, make_plan

__all__ = [
    "AdmissionController",
    "ClusterSpec",
    "NodeSpec",
    "PFSSpec",
    "theta_like",
    "CheckpointConfig",
    "CheckpointManager",
    "L1CapacityError",
    "ManagerHealth",
    "SaveStats",
    "FileLayout",
    "FlushPlan",
    "PlanArrays",
    "ReadColumns",
    "ReadPlan",
    "SendColumns",
    "SendItem",
    "WriteColumns",
    "WriteItem",
    "assign_readers",
    "build_read_plan",
    "merge_intervals",
    "stored_space_offsets",
    "validate_plan",
    "validate_plan_reference",
    "validate_read_plan",
    "count_false_sharing",
    "ChunkTable",
    "EncodedState",
    "Manifest",
    "Placement",
    "decode_state",
    "decode_stream",
    "default_codec_impl",
    "encode_state",
    "serialize_tree",
    "LeaderAssignment",
    "ScanResult",
    "elect_leaders",
    "exclusive_prefix_sum",
    "piggybacked_scan",
    "FlushSimulator",
    "SimReport",
    "simulate_flush",
    "simulate_flush_shared",
    "CancelToken",
    "CircuitOpenError",
    "DomainHealth",
    "FairShareLimiter",
    "FlushCancelled",
    "FlushJournal",
    "FlushResult",
    "HedgePolicy",
    "LocalStore",
    "MissingBlobError",
    "RealExecutor",
    "RetryPolicy",
    "StorageError",
    "StorageHealth",
    "TenantLimiter",
    "TokenBucket",
    "classify_error",
    "fair_share_rates",
    "FaultPlan",
    "FaultSpec",
    "RepairReport",
    "repair_step",
    "STRATEGIES",
    "make_plan",
]
