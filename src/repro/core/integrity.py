"""Checkpoint integrity: fast host-side checksums.

Host path uses zlib.crc32 (C speed).  The device path — checksumming
checkpoint shards *before* D2H so corruption in the flush pipeline is
detectable — is the Pallas kernel in :mod:`repro.kernels.checksum`,
whose reference oracle matches :func:`fletcher64_np` below.
"""
from __future__ import annotations

import zlib

import numpy as np

_MOD = (1 << 32) - 1


def crc32(data) -> int:
    """CRC-32 of any C-contiguous buffer — bytes, bytearray, memoryview,
    ndarray — hashed in place via the buffer protocol.

    The zero-copy encode path hands out memoryview slices of one shared
    stream buffer; hashing them must not materialize a ``bytes`` copy of
    every rank blob.  Non-contiguous objects (strided array views) fall
    back to a compacting copy, which is the only case that needs one.
    """
    try:
        return zlib.crc32(data) & 0xFFFFFFFF
    except (TypeError, BufferError, ValueError):
        if isinstance(data, np.ndarray):
            data = np.ascontiguousarray(data)
            return zlib.crc32(data.view(np.uint8)) & 0xFFFFFFFF
        return zlib.crc32(bytes(data)) & 0xFFFFFFFF


def fletcher64_np(words: np.ndarray) -> int:
    """Fletcher-64 over uint32 words (the kernel's oracle, vectorized).

    sum1 = (Σ w_i) mod (2^32 - 1);  sum2 = (Σ partial sums) mod (2^32 - 1)
    Equivalently sum2 = Σ (n - i) * w_i.
    """
    w = np.ascontiguousarray(words, dtype=np.uint32).astype(np.uint64)
    n = w.size
    if n == 0:
        return 0
    sum1 = int(w.sum() % _MOD)
    weights = np.arange(n, 0, -1, dtype=np.uint64)
    # chunk to avoid overflow: max term < 2^32 * n, accumulate in python int
    sum2 = 0
    CH = 1 << 16
    for i in range(0, n, CH):
        sum2 += int((w[i : i + CH] * weights[i : i + CH] % _MOD).sum())
    sum2 %= _MOD
    return (sum2 << 32) | sum1


def fletcher64_bytes(data: bytes) -> int:
    buf = np.frombuffer(data, dtype=np.uint8)
    pad = (-buf.size) % 4
    if pad:
        buf = np.concatenate([buf, np.zeros(pad, dtype=np.uint8)])
    return fletcher64_np(buf.view(np.uint32))
